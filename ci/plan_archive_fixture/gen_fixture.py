#!/usr/bin/env python3
"""Regenerate the golden plan-archive fixture.

The fixture is a byte-exact, minimal-but-valid archive (an empty
session's export) used by `rust/tests/plan_archive.rs` to pin the
on-disk format: payload headers, the length-prefixed little-endian
codec, payload sha256s, and the manifest's canonical-JSON self-hash.
If `cargo test` fails against these files after a codec change, the
format changed — bump the archive `SCHEMA_VERSION`/`PAYLOAD_VERSION`
in `rust/src/orchestrator/archive.rs`, then rerun:

    python3 ci/plan_archive_fixture/gen_fixture.py

and commit the regenerated files together with the version bump.

Everything here mirrors rust/src/orchestrator/archive.rs and
rust/src/util/json.rs; the replication is deliberate — an independent
writer is exactly what catches accidental format drift.
"""

import decimal
import hashlib
import pathlib
import struct

HERE = pathlib.Path(__file__).resolve().parent

MAGIC = b"OMLLMAR1"
PAYLOAD_VERSION = 1
KIND_CACHES, KIND_PLANS, KIND_PROFILES = 1, 2, 3
SCHEMA_VERSION = "1.0.0"

# A fixed provenance instant; the manifest must be byte-stable.
CREATED_UNIX = 1754500000
# Topology::h100(4)
TOPOLOGY = dict(
    instances=4,
    per_node=8,
    intra_bw=450.0e9,
    inter_bw=50.0e9,
    base_latency=20e-6,
)
CACHE_CAPACITY = 32


def u64(v):
    return struct.pack("<Q", v)


def u16(v):
    return struct.pack("<H", v)


def f64(v):
    return struct.pack("<d", v)


def header(kind):
    return MAGIC + u16(kind) + u16(PAYLOAD_VERSION)


def empty_cache():
    # capacity, clock, entry count
    return u64(CACHE_CAPACITY) + u64(0) + u64(0)


def caches_bin():
    out = header(KIND_CACHES)
    for _phase in range(3):
        out += u64(0)  # empty prev_local assignment
        out += empty_cache()  # phase-level plan cache
    out += empty_cache()  # step-level plan cache
    return out


def plans_bin():
    return header(KIND_PLANS) + u64(0) + u64(0)  # entries, blobs


def profiles_bin():
    return header(KIND_PROFILES) + u64(0) + u64(0) * 3  # steps, 3 phases


def topology_fingerprint():
    raw = (
        u64(TOPOLOGY["instances"])
        + u64(TOPOLOGY["per_node"])
        + f64(TOPOLOGY["intra_bw"])
        + f64(TOPOLOGY["inter_bw"])
        + f64(TOPOLOGY["base_latency"])
    )
    return hashlib.sha256(raw).hexdigest()


def fmt_num(n):
    # Mirror Json::write: integers in range print without a fraction,
    # everything else prints shortest-round-trip positional (Rust's f64
    # Display never uses exponent notation).
    f = float(n)
    if f == int(f) and abs(f) < 9.0e15:
        return str(int(f))
    return format(decimal.Decimal(repr(f)).normalize(), "f")


def pretty(value, depth=0):
    # Mirror Json::pretty: sorted keys, 1-space indent per level.
    pad, pad_in = " " * depth, " " * (depth + 1)
    if value is None:
        return "null"
    if isinstance(value, bool):
        return "true" if value else "false"
    if isinstance(value, (int, float)):
        return fmt_num(value)
    if isinstance(value, str):
        return '"' + value + '"'  # fixture strings need no escaping
    if isinstance(value, list):
        if not value:
            return "[]"
        items = ",\n".join(
            pad_in + pretty(v, depth + 1) for v in value
        )
        return "[\n" + items + "\n" + pad + "]"
    if isinstance(value, dict):
        if not value:
            return "{}"
        items = ",\n".join(
            pad_in + '"' + k + '": ' + pretty(value[k], depth + 1)
            for k in sorted(value)
        )
        return "{\n" + items + "\n" + pad + "}"
    raise TypeError(type(value))


def main():
    payloads = [
        ("caches.bin", caches_bin()),
        ("plans.bin", plans_bin()),
        ("profiles.bin", profiles_bin()),
    ]
    manifest = {
        "schema_version": SCHEMA_VERSION,
        "created_unix": CREATED_UNIX,
        "generator": "orchmllm plan archive",
        "git_describe": "fixture",
        "topology": dict(TOPOLOGY),
        "topology_fingerprint": topology_fingerprint(),
        # Not a real config digest: fixture tests exercise decode and
        # checksum paths, not session fingerprint matching.
        "config_fingerprint": hashlib.sha256(b"fixture").hexdigest(),
        "stats": {
            "steps": 0,
            "step_cache_hits": 0,
            "warm_rate": 0,
            "cache_hit_rate": 0,
            "mean_plan_ms": 0,
        },
        "plan_chain": {"len": 0, "head": None},
        "payloads": [
            {
                "name": name,
                "bytes": len(data),
                "sha256": hashlib.sha256(data).hexdigest(),
            }
            for name, data in payloads
        ],
    }
    canonical = pretty(manifest)
    manifest["manifest_sha256"] = hashlib.sha256(
        canonical.encode()
    ).hexdigest()

    for name, data in payloads:
        (HERE / name).write_bytes(data)
    (HERE / "manifest.json").write_text(pretty(manifest) + "\n")
    print(f"wrote fixture to {HERE}")


if __name__ == "__main__":
    main()
