//! End-to-end validation driver: real DP training of the tiny MLLM.
//!
//! Proves all three layers compose: Pallas kernels (L1) inside the JAX
//! model (L2) AOT-lowered to HLO, executed from the rust coordinator
//! (L3) across DP worker threads with post-balancing dispatch, composed
//! All-to-All rearrangements, gradient all-reduce, and SGD — and that
//! the loss descends on a learnable synthetic multimodal corpus.
//!
//! Also validates the paper's consequence-invariance claim (§3.3): from
//! the same sampled global batches, training WITH post-balancing
//! produces the same loss trajectory as training WITHOUT it (the
//! rearrangement only moves examples between instances).
//!
//! Also proves the pluggable comm layer: the same run, re-executed
//! over the loopback-TCP transport, must produce bit-identical metrics
//! (the rearrangement bytes and the fixed-order all-reduce do not care
//! what substrate carries them).
//!
//! Run: `make artifacts && cargo run --release --example train_tiny_mllm
//!       [-- --steps 300 --workers 4 --mini-batch 6 --lr 4
//!           --artifacts artifacts/test --transport inproc
//!           --pipeline-depth 3 --plan-cache-size 32]`

use orchmllm::config::TrainRunConfig;
use orchmllm::trainer;
use orchmllm::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let cfg = TrainRunConfig {
        artifacts: args.get_or("artifacts", "artifacts/test").to_string(),
        workers: args.usize("workers", 4),
        mini_batch: args.usize("mini-batch", 6),
        steps: args.usize("steps", 300),
        lr: args.f64("lr", 4.0),
        seed: args.u64("seed", 0),
        balance: true,
        balancer: args.get("balancer").map(str::to_string),
        // Deep step pipeline + plan cache: depth 3 keeps planning
        // spikes off the critical path; the cache replays recurring
        // batch shapes bit-identically.
        pipeline_depth: args.usize("pipeline-depth", 3),
        plan_cache_size: args.usize("plan-cache-size", 32),
        transport: args.get_or("transport", "inproc").to_string(),
        calibrate_comm: args.flag("calibrate-comm"),
        ..TrainRunConfig::default()
    };
    cfg.validate().expect("invalid train configuration");
    let invariance_steps = args.usize("invariance-steps", 5);

    println!(
        "== end-to-end tiny-MLLM training: {} workers, mb {}, {} steps, \
         lr {}, pipeline depth {}, plan cache {}, transport {} ==",
        cfg.workers,
        cfg.mini_batch,
        cfg.steps,
        cfg.lr,
        cfg.pipeline_depth,
        cfg.plan_cache_size,
        cfg.transport
    );
    let t0 = std::time::Instant::now();
    let report = trainer::run_collect(&cfg).expect("training failed");
    println!("{}", report.render());
    println!("wallclock: {:.1}s", t0.elapsed().as_secs_f64());

    // Every worker plans through a PlanSession, so provenance is a
    // direct read off the report instead of an inference: how many
    // phase solves the tolerance gate warm-accepted, and how many a
    // sketch cache replayed. (A fresh-every-step synthetic stream may
    // legitimately plan all-cold; the rates just get printed here.)
    println!(
        "session provenance: {:.0}% warm solves, {:.0}% cache hits",
        report.plan_warm_rate * 100.0,
        report.plan_cache_hit_rate * 100.0
    );

    let first = report.losses.first().copied().unwrap_or(f64::NAN);
    let last10: f64 = report.losses.iter().rev().take(10).sum::<f64>()
        / 10f64.min(report.losses.len() as f64);
    assert!(
        last10 < first - 0.05,
        "loss did not descend: {first:.4} -> {last10:.4}"
    );
    println!(
        "loss descended: {first:.4} -> {last10:.4} (last-10 mean) ✓"
    );

    // ---- consequence-invariance check (§3.3) ---------------------------
    println!(
        "\n== consequence-invariance: balanced vs unbalanced, \
         {invariance_steps} steps from the same sampled batches =="
    );
    let short = TrainRunConfig {
        steps: invariance_steps,
        balance: true,
        ..cfg.clone()
    };
    let balanced = trainer::run_collect(&short).expect("balanced run");
    let unbalanced = trainer::run_collect(&TrainRunConfig {
        balance: false,
        ..short.clone()
    })
    .expect("unbalanced run");
    for (i, (a, b)) in balanced
        .losses
        .iter()
        .zip(&unbalanced.losses)
        .enumerate()
    {
        let rel = (a - b).abs() / a.abs().max(1e-9);
        println!(
            "  step {i}: balanced {a:.6}  unbalanced {b:.6}  (rel {rel:.2e})"
        );
        assert!(
            rel < 1e-3,
            "rearrangement changed the training result at step {i}!"
        );
    }
    println!("rearrangement is consequence-invariant ✓");

    // ---- transport invariance: inproc vs tcp, bit for bit --------------
    println!(
        "\n== transport invariance: the same {invariance_steps} steps \
         over every registered comm backend =="
    );
    let mut reference: Option<(String, Vec<f64>, f64)> = None;
    for name in orchmllm::comm::transport::registry::NAMES {
        let run = trainer::run_collect(&TrainRunConfig {
            transport: name.to_string(),
            // Identical plans require the identical (hard-coded)
            // planner topology: per-backend calibration would move
            // examples differently, which is consequence-invariant but
            // not bit-identical.
            calibrate_comm: false,
            ..short.clone()
        })
        .unwrap_or_else(|e| panic!("run over '{name}' failed: {e:#}"));
        println!(
            "  {name}: final loss {:.6}, {:.1} ms comm/step",
            run.losses.last().copied().unwrap_or(f64::NAN),
            run.comm_secs_per_step * 1e3
        );
        match &reference {
            None => {
                reference =
                    Some((name.to_string(), run.losses, run.tokens_per_step))
            }
            Some((ref_name, losses, tokens)) => {
                assert_eq!(
                    &run.losses, losses,
                    "'{name}' diverged from '{ref_name}' — transports \
                     must be bit-identical"
                );
                assert_eq!(run.tokens_per_step, *tokens);
            }
        }
    }
    println!("comm transports are bit-identical ✓");
}
