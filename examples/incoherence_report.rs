//! Fig. 3 regeneration: Modality Composition Incoherence in the
//! synthetic task-mixture dataset.
//!
//! Prints, per modality, the distribution of the modality's share of
//! each example's interleaved LLM sequence (histogram sparkline, mean,
//! std, absent fraction), and per-task breakdowns that show *why* the
//! mixture is incoherent (ASR's audio/text correlation vs spoken-QA's
//! decorrelation, caption's missing audio, ...).
//!
//! Run: `cargo run --release --example incoherence_report [-- --n 100000]`

use orchmllm::data::incoherence::IncoherenceReport;
use orchmllm::data::synth::{DatasetConfig, Generator, Task};
use orchmllm::util::cli::Args;
use orchmllm::util::stats::Summary;

fn main() {
    let args = Args::from_env();
    let n = args.usize("n", 100_000);
    let seed = args.u64("seed", 7);

    let examples = Generator::new(DatasetConfig::default(), seed).batch(n);
    let report = IncoherenceReport::from_examples(&examples, 24);
    println!("{}\n", report.render());
    assert!(report.is_incoherent(), "generator lost its incoherence!");

    println!("per-task composition (mean ratios / lengths):");
    println!(
        "{:<12} {:>6} {:>9} {:>9} {:>9} {:>9} {:>9}",
        "task", "count", "vis%", "aud%", "vis_len", "aud_len", "text_len"
    );
    for task in Task::ALL {
        let sub: Vec<_> =
            examples.iter().filter(|e| e.task == task).collect();
        let mean = |xs: Vec<f64>| Summary::from_slice(&xs).mean();
        println!(
            "{:<12} {:>6} {:>8.1}% {:>8.1}% {:>9.0} {:>9.0} {:>9.0}",
            task.name(),
            sub.len(),
            100.0 * mean(sub.iter().map(|e| e.vis_ratio()).collect()),
            100.0 * mean(sub.iter().map(|e| e.aud_ratio()).collect()),
            mean(sub.iter().map(|e| e.vis_len as f64).collect()),
            mean(sub.iter().map(|e| e.aud_len as f64).collect()),
            mean(sub.iter().map(|e| e.text_len as f64).collect()),
        );
    }

    println!(
        "\nconclusion: per-modality shares range 0%..90%+ across tasks — \
         no example-level pre-balancing can equalize every phase at once \
         (paper §3.1)."
    );
}
