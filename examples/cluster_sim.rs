//! Cluster-scale simulation: regenerate the paper's headline comparison
//! (Fig. 8 MFU + Fig. 9 TPT) on the modelled 2560-H100 cluster, plus a
//! compact version of every ablation (Fig. 10–13) at 128 GPUs.
//!
//! Run: `cargo run --release --example cluster_sim
//!       [-- --gpus 2560 --steps 3 --full]`

use orchmllm::model::config::MllmConfig;
use orchmllm::sim::engine::{simulate_run, SystemKind};
use orchmllm::sim::report;
use orchmllm::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let gpus = args.usize("gpus", 2560);
    let steps = args.usize("steps", 3);
    let seed = args.u64("seed", 42);

    // ---- Fig. 8 + 9 ------------------------------------------------------
    let mb_orch = [80, 60, 30];
    let mb_none = [65, 40, 15];
    let mut rows = Vec::new();
    for system in
        [SystemKind::OrchMllm, SystemKind::Megatron, SystemKind::NoBalance]
    {
        let mut row = Vec::new();
        for (mi, model) in MllmConfig::all().iter().enumerate() {
            let mb = if system == SystemKind::NoBalance {
                mb_none[mi]
            } else {
                mb_orch[mi]
            };
            row.push(simulate_run(system, model, gpus, mb, steps, seed));
        }
        rows.push(row);
    }
    println!("== Fig. 8/9: overall MFU + TPT ({gpus} GPUs) ==\n");
    print!("{}", report::render_overall(&rows));
    let speedup =
        rows[0][2].tpt / rows[1][2].tpt.max(1e-9);
    println!(
        "\nOrchMLLM vs Megatron-LM TPT at MLLM-84B: {speedup:.1}x \
         (paper: up to 3.1x)\n"
    );

    // ---- Fig. 10–13 ablations at 128 GPUs --------------------------------
    let abl_gpus = 128;
    let mb_abl = [75, 50, 25];
    let ablations: &[(&str, SystemKind)] = &[
        ("Fig.10 LLM-only balance", SystemKind::LlmOnly),
        ("Fig.11 all pad", SystemKind::AllPad),
        ("Fig.11 all rmpad", SystemKind::AllRmpad),
        ("Fig.12 All-Gather comm", SystemKind::AllGatherComm),
        ("Fig.13 w/o node-wise", SystemKind::NoNodewise),
    ];
    println!("== Fig. 10–13 ablations ({abl_gpus} GPUs, mb 75/50/25) ==\n");
    let mut abl_rows = vec![Vec::new()];
    for (mi, model) in MllmConfig::all().iter().enumerate() {
        abl_rows[0].push(simulate_run(
            SystemKind::OrchMllm, model, abl_gpus, mb_abl[mi], steps, seed,
        ));
    }
    for (label, system) in ablations {
        let mut row = Vec::new();
        for (mi, model) in MllmConfig::all().iter().enumerate() {
            row.push(simulate_run(
                *system, model, abl_gpus, mb_abl[mi], steps, seed,
            ));
        }
        println!("-- {label}");
        abl_rows.push(row);
    }
    print!("{}", report::render_mfu_memory(&abl_rows));

    // Fig. 13 metric: inter-node communication volume per modality.
    let with = &abl_rows[0][0];
    let without = abl_rows.last().unwrap()[0].clone();
    println!(
        "\nFig.13 inter-node MB/iter (MLLM-10B): vision {:.0} vs {:.0}, \
         audio {:.0} vs {:.0}, text {:.0} vs {:.0} (node-wise vs w/o)",
        with.inter_node_mb[0],
        without.inter_node_mb[0],
        with.inter_node_mb[1],
        without.inter_node_mb[1],
        with.inter_node_mb[2],
        without.inter_node_mb[2],
    );
}
