//! Quickstart: the OrchMLLM public API in ~60 lines.
//!
//! Samples an incoherent multimodal global batch across 8 DP instances,
//! plans one step with the MLLM Global Orchestrator, and prints the
//! per-phase imbalance before/after post-balancing plus the priced
//! communication cost of the rearrangement.
//!
//! Run: `cargo run --release --example quickstart`

use orchmllm::balance::cost::CostModel;
use orchmllm::comm::topology::Topology;
use orchmllm::data::synth::{DatasetConfig, Example, Generator};
use orchmllm::model::flops::PhaseKind;
use orchmllm::orchestrator::global::{Orchestrator, OrchestratorConfig};

fn main() {
    let d = 8;
    let mini_batch = 32;
    let topo = Topology::h100(d);

    // 1. Every DP instance samples a mini-batch of multimodal examples
    //    (task mixture with Modality Composition Incoherence, §3.1).
    let mut generator = Generator::new(DatasetConfig::default(), 42);
    let minibatches: Vec<Vec<Example>> =
        (0..d).map(|_| generator.batch(mini_batch)).collect();

    // 2. Plan the step: per-phase Batch Post-Balancing Dispatchers +
    //    node-wise all-to-all + rearrangement composition (§5, §6).
    let orch = Orchestrator::new(OrchestratorConfig::orchmllm(3584.0 * 2.0));
    let plan = orch.plan_step(&topo, &minibatches);

    // 3. Per-phase imbalance (max/mean token cost across instances).
    let lin = CostModel::Linear { alpha: 1.0 };
    println!("phase     before   after   (max/mean token cost, 1.0 = perfect)");
    let baseline = Orchestrator::new(OrchestratorConfig::no_balance(
        3584.0 * 2.0,
    ))
    .plan_step(&topo, &minibatches);
    for phase in PhaseKind::ALL {
        println!(
            "{:<8}  {:>6.3}   {:>6.3}",
            phase.name(),
            lin.imbalance(baseline.assignment(phase)),
            lin.imbalance(plan.assignment(phase)),
        );
    }

    // 4. What the rearrangement costs on the wire.
    println!(
        "\nrearrangement comm: {:.2} ms on the critical path \
         ({} of {} examples moved for the LLM phase)",
        plan.comm_seconds() * 1e3,
        plan.llm.route.moved(),
        plan.examples.len(),
    );
    println!(
        "dispatcher compute: {:.2} ms (overlapped with the forward pass)",
        plan.compute_nanos as f64 / 1e6
    );
}
