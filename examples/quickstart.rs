//! Quickstart: the OrchMLLM public API in ~60 lines.
//!
//! Samples an incoherent multimodal global batch across 8 DP instances,
//! plans one step through a [`PlanSession`] — the single entry point
//! into the MLLM Global Orchestrator — and prints the per-phase
//! imbalance before/after post-balancing plus the priced communication
//! cost of the rearrangement and the plan's provenance report.
//!
//! Run: `cargo run --release --example quickstart`

use orchmllm::balance::cost::CostModel;
use orchmllm::comm::topology::Topology;
use orchmllm::data::synth::{DatasetConfig, Example, Generator};
use orchmllm::model::flops::PhaseKind;
use orchmllm::orchestrator::global::OrchestratorConfig;
use orchmllm::orchestrator::session::{PlanOptions, PlanSession};

fn main() {
    let d = 8;
    let mini_batch = 32;
    let topo = Topology::h100(d);

    // 1. Every DP instance samples a mini-batch of multimodal examples
    //    (task mixture with Modality Composition Incoherence, §3.1).
    let mut generator = Generator::new(DatasetConfig::default(), 42);
    let minibatches: Vec<Vec<Example>> =
        (0..d).map(|_| generator.batch(mini_batch)).collect();

    // 2. Plan the step: a session owns all planning state, and one
    //    `plan` call runs the per-phase Batch Post-Balancing
    //    Dispatchers + node-wise all-to-all + rearrangement composition
    //    (§5, §6).
    let mut session = PlanSession::with_defaults(
        OrchestratorConfig::orchmllm(3584.0 * 2.0),
        topo,
    );
    let plan = session.plan(&minibatches, PlanOptions::auto());

    // 3. Per-phase imbalance (max/mean token cost across instances).
    let lin = CostModel::Linear { alpha: 1.0 };
    println!("phase     before   after   (max/mean token cost, 1.0 = perfect)");
    let baseline = PlanSession::with_defaults(
        OrchestratorConfig::no_balance(3584.0 * 2.0),
        topo,
    )
    .plan(&minibatches, PlanOptions::auto());
    for phase in PhaseKind::ALL {
        println!(
            "{:<8}  {:>6.3}   {:>6.3}",
            phase.name(),
            lin.imbalance(baseline.assignment(phase)),
            lin.imbalance(plan.assignment(phase)),
        );
    }

    // 4. What the rearrangement costs on the wire.
    println!(
        "\nrearrangement comm: {:.2} ms on the critical path \
         ({} of {} examples moved for the LLM phase)",
        plan.comm_seconds() * 1e3,
        plan.llm.route.moved(),
        plan.examples.len(),
    );
    println!(
        "dispatcher compute: {:.2} ms (overlapped with the forward pass)",
        plan.compute_nanos as f64 / 1e6
    );

    // 5. Where the plan came from — the session's provenance report.
    let report = session.report().expect("one step planned");
    println!(
        "provenance: step {} via {:?}, sources {:?}",
        report.step, report.mode, report.sources
    );
}
