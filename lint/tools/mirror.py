#!/usr/bin/env python3
"""Reference mirror of the orchlint analyzer (lint/src/*.rs), line-for-line.

Why this exists: the orchlint baseline (`ci/orchlint_baseline.json`) must be
an *exact* snapshot of what the Rust binary reports, and the self-check test
(`lint/tests/selfcheck.rs`) pins that equality in CI. This mirror lets the
baseline be regenerated and the golden fixtures validated in environments
without a Rust toolchain. It is a maintenance aid, not the source of truth:
if the mirror and the Rust analyzer ever disagree, the Rust analyzer wins
and this file must be fixed to match.

Usage:
  python3 lint/tools/mirror.py rust/src [--hot-paths ci/hot_paths.toml]
      [--write-baseline ci/orchlint_baseline.json] [--check ci/orchlint_baseline.json]
      [--list]
"""

import json
import os
import sys

IDENT = "ident"
PUNCT = "punct"
LIT = "lit"


# --- lexer.rs -------------------------------------------------------------

def lex(src):
    b = list(src)
    n = len(b)
    toks = []  # (kind, text, line)
    comments = []  # (line, text)
    i = 0
    line = 1

    def is_ident_start(c):
        return c.isalpha() or c == "_"

    def is_ident_cont(c):
        return c.isalnum() or c == "_"

    while i < n:
        c = b[i]
        if c == "\n":
            line += 1
            i += 1
            continue
        if c.isspace():
            i += 1
            continue
        if c == "/" and i + 1 < n and b[i + 1] == "/":
            start = i + 2
            j = start
            while j < n and b[j] != "\n":
                j += 1
            comments.append((line, "".join(b[start:j])))
            i = j
            continue
        if c == "/" and i + 1 < n and b[i + 1] == "*":
            depth = 1
            j = i + 2
            while j < n and depth > 0:
                if b[j] == "\n":
                    line += 1
                    j += 1
                elif b[j] == "/" and j + 1 < n and b[j + 1] == "*":
                    depth += 1
                    j += 2
                elif b[j] == "*" and j + 1 < n and b[j + 1] == "/":
                    depth -= 1
                    j += 2
                else:
                    j += 1
            i = j
            continue
        if c in ("r", "b"):
            j = i + 1
            if c == "b" and j < n and b[j] == "r":
                j += 1
            hashes = 0
            k = j
            while k < n and b[k] == "#":
                hashes += 1
                k += 1
            if k < n and b[k] == '"':
                lit_line = line
                m = k + 1
                while m < n:
                    if b[m] == "\n":
                        line += 1
                        m += 1
                        continue
                    if b[m] == '"':
                        h = 0
                        while m + 1 + h < n and h < hashes and b[m + 1 + h] == "#":
                            h += 1
                        if h == hashes:
                            m += 1 + hashes
                            break
                    if hashes == 0 and b[m] == "\\" and m + 1 < n:
                        m += 2
                        continue
                    m += 1
                toks.append((LIT, "", lit_line))
                i = m
                continue
            if (
                c == "r"
                and i + 1 < n
                and b[i + 1] == "#"
                and i + 2 < n
                and is_ident_start(b[i + 2])
            ):
                i += 2
                start = i
                j = i
                while j < n and is_ident_cont(b[j]):
                    j += 1
                toks.append((IDENT, "".join(b[start:j]), line))
                i = j
                continue
        if c == '"':
            lit_line = line
            j = i + 1
            while j < n:
                if b[j] == "\\" and j + 1 < n:
                    j += 2
                    continue
                if b[j] == "\n":
                    line += 1
                    j += 1
                    continue
                if b[j] == '"':
                    j += 1
                    break
                j += 1
            toks.append((LIT, "", lit_line))
            i = j
            continue
        if c == "'":
            if i + 1 < n and b[i + 1] == "\\":
                j = i + 2
                while j < n and b[j] != "'":
                    j += 1
                toks.append((LIT, "", line))
                i = j + 1
                continue
            if i + 2 < n and b[i + 2] == "'":
                toks.append((LIT, "", line))
                i += 3
                continue
            toks.append((PUNCT, "'", line))
            i += 1
            continue
        if c.isdigit() and c in "0123456789":
            j = i + 1
            while j < n:
                d = b[j]
                if (d.isalnum() and d.isascii()) or d == "_":
                    j += 1
                    continue
                if d == "." and j + 1 < n and b[j + 1].isdigit() and b[j + 1].isascii():
                    j += 1
                    continue
                if (
                    d in "+-"
                    and b[j - 1] in "eE"
                    and j + 1 < n
                    and b[j + 1].isdigit()
                    and b[j + 1].isascii()
                ):
                    j += 1
                    continue
                break
            toks.append((LIT, "", line))
            i = j
            continue
        if is_ident_start(c):
            start = i
            j = i
            while j < n and is_ident_cont(b[j]):
                j += 1
            toks.append((IDENT, "".join(b[start:j]), line))
            i = j
            continue
        if c == ":" and i + 1 < n and b[i + 1] == ":":
            toks.append((PUNCT, "::", line))
            i += 2
            continue
        toks.append((PUNCT, c, line))
        i += 1
    return toks, comments


# --- parse.rs -------------------------------------------------------------

class FnRec:
    def __init__(self, file, qname, name, line):
        self.file = file
        self.qname = qname
        self.name = name
        self.line = line
        self.end_line = 0
        self.is_test = False
        self.body = (0, 0)
        self.holes = []
        self.allows = {}  # class -> justified (bool)

    def allowed(self, cls):
        return cls in self.allows


def parse_file(file, toks, comments, out):
    first_rec = len(out)
    stack = []  # ("mod", test) | ("impl", ty) | ("trait", name) | ("fn", rec) | ("other",)
    pending_test_attr = False
    i = 0
    n = len(toks)

    def in_test_mod():
        return any(c[0] == "mod" and c[1] for c in stack)

    def enclosing_ty():
        for c in reversed(stack):
            if c[0] == "fn":
                return None
            if c[0] in ("impl", "trait"):
                return c[1]
        return None

    while i < n:
        kind, text, tline = toks[i]
        if kind == PUNCT and text == "#":
            j = i + 1
            if j < n and toks[j][1] == "!":
                j += 1
            if j < n and toks[j][1] == "[":
                depth = 1
                k = j + 1
                idents = []
                while k < n and depth > 0:
                    tk = toks[k][1]
                    if tk == "[":
                        depth += 1
                    elif tk == "]":
                        depth -= 1
                    elif toks[k][0] == IDENT:
                        idents.append(tk)
                    k += 1
                is_test = (len(idents) > 0 and idents[0] == "test") or (
                    len(idents) > 0 and idents[0] == "cfg" and "test" in idents
                )
                if is_test:
                    pending_test_attr = True
                i = k
                continue
            i += 1
        elif kind == IDENT and text == "mod":
            name = toks[i + 1][1] if i + 1 < n and toks[i + 1][0] == IDENT else ""
            j = i + 1
            while j < n and toks[j][1] not in ("{", ";"):
                j += 1
            if j < n and toks[j][1] == "{":
                test = pending_test_attr or name in ("tests", "test")
                stack.append(("mod", test))
                i = j + 1
            else:
                i = j + 1
            pending_test_attr = False
        elif kind == IDENT and text == "impl":
            j = i + 1
            if j < n and toks[j][1] == "<":
                angle = 1
                j += 1
                while j < n and angle > 0:
                    tj = toks[j][1]
                    if tj == "<":
                        angle += 1
                    elif tj == ">":
                        angle -= 1
                    j += 1
            before = []
            after = []
            saw_for = False
            angle = 0
            while j < n and not (angle == 0 and toks[j][1] == "{"):
                tk, tt, _ = toks[j]
                if tt == "<":
                    angle += 1
                elif tt == ">":
                    if angle > 0:
                        angle -= 1
                elif tt == "for" and angle == 0 and tk == IDENT:
                    saw_for = True
                elif tt == "where" and angle == 0 and tk == IDENT:
                    while j < n and toks[j][1] != "{":
                        j += 1
                    break
                elif tk == IDENT and angle == 0:
                    if saw_for:
                        after.append(tt)
                    else:
                        before.append(tt)
                j += 1
            if saw_for:
                ty = after[-1] if after else ""
            else:
                ty = before[-1] if before else ""
            if j < n and toks[j][1] == "{":
                stack.append(("impl", ty))
                i = j + 1
            else:
                i = j
            pending_test_attr = False
        elif kind == IDENT and text == "trait":
            name = toks[i + 1][1] if i + 1 < n and toks[i + 1][0] == IDENT else ""
            j = i + 1
            while j < n and toks[j][1] not in ("{", ";"):
                j += 1
            if j < n and toks[j][1] == "{":
                stack.append(("trait", name))
                i = j + 1
            else:
                i = j + 1
            pending_test_attr = False
        elif kind == IDENT and text == "fn":
            if i + 1 >= n or toks[i + 1][0] != IDENT:
                i += 1
                continue
            name = toks[i + 1][1]
            fline = tline
            j = i + 2
            depth = 0
            while j < n:
                tj = toks[j][1]
                if tj in ("(", "["):
                    depth += 1
                elif tj in (")", "]"):
                    depth -= 1
                elif tj == ";" and depth == 0:
                    break
                elif tj == "{" and depth == 0:
                    break
                j += 1
            if j >= n or toks[j][1] == ";":
                pending_test_attr = False
                i = j + 1
                continue
            ty = enclosing_ty()
            qname = f"{ty}::{name}" if ty else name
            rec = FnRec(file, qname, name, fline)
            rec.is_test = pending_test_attr or in_test_mod()
            pending_test_attr = False
            rec.body = (j, j)
            out.append(rec)
            stack.append(("fn", len(out) - 1))
            i = j + 1
        elif kind == PUNCT and text == "{":
            stack.append(("other",))
            i += 1
        elif kind == PUNCT and text == "}":
            if stack:
                ctx = stack.pop()
                if ctx[0] == "fn":
                    rec = out[ctx[1]]
                    rec.body = (rec.body[0], i)
                    rec.end_line = tline
                    for c in reversed(stack):
                        if c[0] == "fn":
                            out[c[1]].holes.append(rec.body)
                            break
            i += 1
        else:
            i += 1

    attach_pragmas(out[first_rec:], comments)


def attach_pragmas(recs, comments):
    for cline, ctext in comments:
        text = ctext.strip()
        if not text.startswith("orchlint:"):
            continue
        rest = text[len("orchlint:"):].lstrip()
        if not rest.startswith("allow"):
            continue
        rest = rest[len("allow"):].lstrip()
        if not rest.startswith("("):
            continue
        rest = rest[1:]
        close = rest.find(")")
        if close < 0:
            continue
        classes = [s.strip() for s in rest[:close].split(",") if s.strip()]
        tail = rest[close + 1:].strip()
        justification = tail[1:].strip() if tail.startswith(":") else tail
        justified = len(justification) > 0

        target = None
        for idx, r in enumerate(recs):
            if r.line <= cline <= r.end_line:
                if target is not None:
                    prev = recs[target]
                    if prev.end_line - prev.line <= max(r.end_line - r.line, 0):
                        continue
                target = idx
        if target is None:
            best = None
            for idx, r in enumerate(recs):
                if r.line >= cline:
                    if best is not None and recs[best].line <= r.line:
                        continue
                    best = idx
            target = best
        if target is not None:
            for cls in classes:
                prev = recs[target].allows.get(cls, False)
                recs[target].allows[cls] = prev or justified


# --- analyses.rs ----------------------------------------------------------

COLLECTIVES = [
    "all_to_all_bytes",
    "all_to_all_shards",
    "all_gather_bytes",
    "all_reduce_sum",
    "barrier",
    "heartbeat",
]
CLASS_SYMMETRY = "collective-asymmetry"
CLASS_HOT_PATH = "hot-path-alloc"
CLASS_ERROR_PROP = "error-propagation"
KNOWN_CLASSES = [CLASS_SYMMETRY, CLASS_HOT_PATH, CLASS_ERROR_PROP]
RANK_IDENTS = ["rank", "me", "my_rank", "rank_id"]


class Findings:
    def __init__(self):
        self.map = {}

    def add(self, cls, rec, detail, line):
        key = f"{cls}::{rec.file}::{rec.qname}::{detail}"
        f = self.map.setdefault(
            key,
            {
                "key": key,
                "class": cls,
                "file": rec.file,
                "function": rec.qname,
                "detail": detail,
                "lines": [],
            },
        )
        if line not in f["lines"]:
            f["lines"].append(line)
            f["lines"].sort()

    def into_sorted(self):
        return [self.map[k] for k in sorted(self.map)]


def body_tokens(rec, toks):
    start, end = rec.body
    out = []
    i = start
    holes = {hs: he for hs, he in rec.holes}
    while i <= end and i < len(toks):
        if i in holes:
            i = holes[i] + 1
            continue
        out.append((i, toks[i]))
        i += 1
    return out


def callees(rec, toks):
    body = body_tokens(rec, toks)
    out = set()
    for w in range(len(body)):
        _, t = body[w]
        if t[0] != IDENT:
            continue
        if w + 1 >= len(body):
            continue
        if body[w + 1][1][1] != "(":
            continue
        if w > 0 and body[w - 1][1][1] == "fn":
            continue
        out.add(t[1])
    return out


def build_callgraph(recs, toks_by_file):
    by_name = {}
    for i, r in enumerate(recs):
        if not r.is_test:
            by_name.setdefault(r.name, []).append(i)
    edges = [[] for _ in recs]
    for i, r in enumerate(recs):
        if r.is_test:
            continue
        toks = toks_by_file[r.file]
        for name in callees(r, toks):
            for t in by_name.get(name, []):
                if t != i:
                    edges[i].append(t)
    return edges


def closure(edges, seeds):
    seen = set(seeds)
    q = list(seeds)
    while q:
        i = q.pop(0)
        for j in edges[i]:
            if j not in seen:
                seen.add(j)
                q.append(j)
    return seen


def check_symmetry(rec, toks, out):
    if rec.is_test or rec.allowed(CLASS_SYMMETRY):
        return
    body = body_tokens(rec, toks)
    ctx = []  # (rank_dep, fallible)
    brace_owner = []
    saw_cond_exit = False
    w = 0
    while w < len(body):
        _, t = body[w]
        if t[0] == IDENT and t[1] in ("if", "match", "while", "for"):
            depth = 0
            j = w + 1
            rank_dep = False
            fallible = False
            while j < len(body):
                _, h = body[j]
                ht = h[1]
                if ht in ("(", "["):
                    depth += 1
                elif ht in (")", "]"):
                    depth -= 1
                elif ht == "{" and depth == 0:
                    break
                if h[0] == IDENT:
                    if ht in RANK_IDENTS:
                        rank_dep = True
                    if ht in (
                        "Ok",
                        "Err",
                        "Some",
                        "None",
                        "is_ok",
                        "is_err",
                        "is_some",
                        "is_none",
                    ):
                        fallible = True
                j += 1
            if j < len(body):
                ctx.append((rank_dep, fallible))
                brace_owner.append(True)
                w = j + 1
                continue
            w += 1
            continue
        if t[1] == "{":
            brace_owner.append(False)
            w += 1
            continue
        if t[1] == "}":
            if brace_owner:
                owned = brace_owner.pop()
                if owned:
                    popped = ctx.pop() if ctx else None
                    if w + 1 < len(body) and body[w + 1][1][1] == "else":
                        if popped is not None:
                            nxt2 = (
                                body[w + 2][1][1] if w + 2 < len(body) else None
                            )
                            if nxt2 != "if":
                                ctx.append(popped)
                                brace_owner.append(True)
                                w += 3
                                continue
            w += 1
            continue
        if t[0] == IDENT:
            name = t[1]
            nxt = body[w + 1][1][1] if w + 1 < len(body) else None
            if (name == "return" or (name == "bail" and nxt == "!")) and ctx:
                saw_cond_exit = True
            if name in COLLECTIVES and nxt == "(":
                rank_dep = any(r for r, _ in ctx)
                fallible = any(f for _, f in ctx)
                if rank_dep:
                    out.add(CLASS_SYMMETRY, rec, f"rank-branch:{name}", t[2])
                if fallible:
                    out.add(CLASS_SYMMETRY, rec, f"fallible-branch:{name}", t[2])
                if saw_cond_exit and not rank_dep and not fallible:
                    out.add(CLASS_SYMMETRY, rec, f"early-exit:{name}", t[2])
        w += 1


ALLOC_NEW_TYPES = (
    "Vec",
    "Box",
    "String",
    "VecDeque",
    "HashMap",
    "BTreeMap",
    "HashSet",
    "BTreeSet",
)


def check_hot_path(rec, toks, out):
    if rec.is_test or rec.allowed(CLASS_HOT_PATH):
        return
    body = body_tokens(rec, toks)
    for w in range(len(body)):
        _, t = body[w]
        if t[0] != IDENT:
            continue
        nxt = body[w + 1][1][1] if w + 1 < len(body) else None
        prev = body[w - 1][1][1] if w > 0 else ""
        prev2 = body[w - 2][1][1] if w > 1 else ""
        name = t[1]
        construct = None
        if name == "new" and nxt == "(" and prev == "::" and prev2 in ALLOC_NEW_TYPES:
            construct = f"{prev2}::new"
        elif name == "clone" and nxt == "(":
            if not (prev == "::" and prev2 in ("Arc", "Rc")):
                construct = "clone"
        elif name in ("to_vec", "to_string", "to_owned", "collect", "with_capacity") and nxt == "(":
            construct = name
        elif name in ("vec", "format") and nxt == "!":
            construct = f"{name}!"
        if construct is not None:
            out.add(CLASS_HOT_PATH, rec, construct, t[2])


def check_error_prop(rec, toks, out):
    if rec.is_test or rec.allowed(CLASS_ERROR_PROP):
        return
    body = body_tokens(rec, toks)
    for w in range(len(body)):
        _, t = body[w]
        if t[0] != IDENT:
            continue
        nxt = body[w + 1][1][1] if w + 1 < len(body) else None
        name = t[1]
        construct = None
        if name in ("unwrap", "expect") and nxt == "(":
            construct = name
        elif name in ("panic", "unreachable", "todo", "unimplemented") and nxt == "!":
            construct = f"{name}!"
        if construct is not None:
            out.add(CLASS_ERROR_PROP, rec, construct, t[2])


def check_pragmas(rec, out):
    for cls in sorted(rec.allows):
        justified = rec.allows[cls]
        if cls not in KNOWN_CLASSES:
            out.add("pragma", rec, f"unknown-class:{cls}", rec.line)
        elif not justified:
            out.add("pragma", rec, f"missing-justification:{cls}", rec.line)


# --- lib.rs ---------------------------------------------------------------

def load_tree(root):
    files = []
    for dirpath, _dirnames, filenames in os.walk(root):
        for fn in filenames:
            if fn.endswith(".rs"):
                files.append(os.path.join(dirpath, fn))
    files.sort()
    fns = []
    toks_by_file = {}
    for path in files:
        rel = os.path.relpath(path, root).replace(os.sep, "/")
        with open(path, encoding="utf-8") as f:
            src = f.read()
        toks, comments = lex(src)
        parse_file(rel, toks, comments, fns)
        toks_by_file[rel] = toks
    return fns, toks_by_file


def analyze(fns, toks_by_file, hot_entries):
    edges = build_callgraph(fns, toks_by_file)
    out = Findings()

    hot_seeds = []
    for i, r in enumerate(fns):
        if r.is_test:
            continue
        for e in hot_entries:
            hit = (r.qname == e) if "::" in e else (r.name == e)
            if hit:
                hot_seeds.append(i)
    hot_closure = closure(edges, hot_seeds)

    coll_seeds = [
        i for i, r in enumerate(fns) if not r.is_test and r.name in COLLECTIVES
    ]
    coll_closure = closure(edges, coll_seeds)

    for i, r in enumerate(fns):
        if r.is_test:
            continue
        toks = toks_by_file[r.file]
        check_pragmas(r, out)
        check_symmetry(r, toks, out)
        if i in hot_closure:
            check_hot_path(r, toks, out)
        if "comm/" in r.file or i in coll_closure:
            check_error_prop(r, toks, out)
    return out.into_sorted()


# --- baseline.rs ----------------------------------------------------------

def read_hot_paths(path):
    out = []
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if line.startswith("#"):
                continue
            rest = line
            while '"' in rest:
                open_q = rest.find('"')
                tail = rest[open_q + 1:]
                close_q = tail.find('"')
                if close_q < 0:
                    break
                s = tail[:close_q]
                if s:
                    out.append(s)
                rest = tail[close_q + 1:]
    return out


BASELINE_HEADER = """{
  "description": "orchlint ratchet baseline: the exact finding-key set `cargo run -p orchlint -- rust/src` must produce. CI fails on any finding absent from this list AND on any stale entry, so the list can only change deliberately. The intent is monotone shrinkage: fix a finding (or pragma-allowlist it with a justification) and delete its key here.",
  "rebaseline_procedure": "Run `cargo run -p orchlint -- rust/src --write-baseline` from the repo root and commit the diff. Additions require PR justification per key (they mean a new asymmetric collective, hot-path allocation, or panic path was introduced); deletions are always welcome.",
"""


def write_baseline(path, findings):
    s = BASELINE_HEADER
    s += '  "findings": [\n'
    for i, f in enumerate(findings):
        assert '"' not in f["key"] and "\\" not in f["key"]
        s += f'    "{f["key"]}"'
        s += ",\n" if i + 1 < len(findings) else "\n"
    s += "  ]\n}\n"
    with open(path, "w", encoding="utf-8") as fh:
        fh.write(s)


def main(argv):
    root = None
    hot_paths = "ci/hot_paths.toml"
    write_to = None
    check = None
    list_mode = False
    it = iter(argv)
    for a in it:
        if a == "--hot-paths":
            hot_paths = next(it)
        elif a == "--write-baseline":
            write_to = next(it)
        elif a == "--check":
            check = next(it)
        elif a == "--list":
            list_mode = True
        elif root is None and not a.startswith("-"):
            root = a
        else:
            print(f"mirror: unknown arg {a}", file=sys.stderr)
            return 2
    if root is None:
        print(__doc__, file=sys.stderr)
        return 2
    hot_entries = read_hot_paths(hot_paths) if os.path.exists(hot_paths) else []
    fns, toks_by_file = load_tree(root)
    findings = analyze(fns, toks_by_file, hot_entries)
    per_class = {}
    for f in findings:
        per_class[f["class"]] = per_class.get(f["class"], 0) + 1
    print(
        f"mirror: {len(findings)} findings "
        f"({', '.join(f'{c}: {n}' for c, n in sorted(per_class.items())) or 'none'})",
        file=sys.stderr,
    )
    if list_mode:
        for f in findings:
            print(f'{f["key"]}  lines={f["lines"]}')
    if write_to:
        write_baseline(write_to, findings)
        print(f"mirror: wrote {write_to} ({len(findings)} keys)", file=sys.stderr)
    if check:
        with open(check, encoding="utf-8") as fh:
            base = set(json.load(fh)["findings"])
        cur = {f["key"] for f in findings}
        new = sorted(cur - base)
        stale = sorted(base - cur)
        for k in new:
            print(f"mirror: NEW finding: {k}", file=sys.stderr)
        for k in stale:
            print(f"mirror: stale baseline entry: {k}", file=sys.stderr)
        if new or stale:
            return 1
        print("mirror: clean — findings exactly match the baseline", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
