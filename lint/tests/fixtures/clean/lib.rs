//! Clean fixture: symmetric collectives, no panics in scope, no
//! allocations in the (empty) hot-path closure. Must produce zero
//! findings.

use anyhow::Result;

pub struct World {
    rank: usize,
    d: usize,
}

impl World {
    fn barrier(&self) -> Result<()> {
        Ok(())
    }

    fn all_reduce_sum(&self, _data: &mut [f32]) -> Result<()> {
        Ok(())
    }
}

/// Every rank calls both collectives unconditionally; allocation is
/// fine because nothing here is in a hot-path closure, and `?` is the
/// sanctioned error path.
pub fn train_step(w: &World, data: &mut [f32]) -> Result<Vec<f32>> {
    w.barrier()?;
    w.all_reduce_sum(data)?;
    let out: Vec<f32> = data.to_vec();
    Ok(out)
}

/// Rank-dependent work that does NOT contain a collective is fine.
pub fn local_shard(w: &World, items: &[usize]) -> Vec<usize> {
    items
        .iter()
        .copied()
        .filter(|i| i % w.d == w.rank)
        .collect()
}
