//! Seeded hot-path allocation violations (golden fixture).
//!
//! The fixture manifest (`hot_paths.toml` beside this file) names
//! `Planner::step` as the zero-alloc entry point; `helper` is in its
//! callee closure, `unrelated` is not.

use std::sync::Arc;

pub struct Planner {
    scratch: Vec<usize>,
}

impl Planner {
    /// Entry point. Violations: collect + vec!.
    pub fn step(&mut self, lens: &[usize]) -> Vec<usize> {
        let doubled: Vec<usize> = lens.iter().map(|l| l * 2).collect();
        let padding = vec![0usize; 4];
        helper(&doubled);
        self.scratch.extend_from_slice(&padding);
        std::mem::take(&mut self.scratch)
    }
}

/// In the closure. Violations: Vec::new + to_vec + clone + format!.
/// Not a violation: Arc::clone (refcount bump, not an allocation).
fn helper(xs: &[usize]) -> usize {
    let mut acc: Vec<usize> = Vec::new();
    acc.extend_from_slice(&xs.to_vec());
    let shared = Arc::new(acc.clone());
    let twin = Arc::clone(&shared);
    let _label = format!("{} items", twin.len());
    shared.len()
}

/// Allowed: cold-path setup, pragma with justification — no findings.
// orchlint: allow(hot-path-alloc): one-time setup, runs before the loop.
pub fn warmup(n: usize) -> Planner {
    Planner {
        scratch: Vec::with_capacity(n),
    }
}

/// NOT in the closure — allocations here are fine.
pub fn unrelated() -> String {
    let v: Vec<u8> = Vec::new();
    format!("{} bytes", v.len())
}
