//! Seeded collective-asymmetry violations (golden fixture).
//!
//! This file is analyzer input, not compiled code. Each fn below seeds
//! exactly the finding its name describes; `lint/tests/golden.rs` pins
//! the full key set.

use anyhow::Result;

pub struct World {
    rank: usize,
    d: usize,
}

impl World {
    fn barrier(&self) -> Result<()> {
        Ok(())
    }

    fn all_gather_bytes(&self, bytes: Vec<u8>) -> Result<Vec<Vec<u8>>> {
        Ok(vec![bytes])
    }

    fn all_reduce_sum(&self, _data: &mut [f32]) -> Result<()> {
        Ok(())
    }
}

/// Violation: the barrier only runs on rank 0 — peers hang.
pub fn rank_gated(w: &World) -> Result<()> {
    if w.rank == 0 {
        w.barrier()?;
    }
    Ok(())
}

/// Violation: the gather sits on the Ok arm of a fallible branch.
pub fn fallible_arm(w: &World, r: Result<Vec<u8>>) -> Result<()> {
    if let Ok(bytes) = r {
        w.all_gather_bytes(bytes)?;
    }
    Ok(())
}

/// Violation: a conditional early return deserts the later reduce.
pub fn early_exit(w: &World, data: &mut [f32]) -> Result<()> {
    if data.is_empty() {
        return Ok(());
    }
    w.all_reduce_sum(data)?;
    Ok(())
}

/// Allowed: pragma with a justification — no finding.
// orchlint: allow(collective-asymmetry): fixture exercise of a justified allow.
pub fn allowed_gate(w: &World) -> Result<()> {
    if w.rank == 1 {
        w.barrier()?;
    }
    Ok(())
}

/// Pragma without a justification — `pragma` finding, and the allow
/// still suppresses the symmetry finding underneath.
// orchlint: allow(collective-asymmetry)
pub fn unjustified_gate(w: &World) -> Result<()> {
    if w.rank == 2 {
        w.barrier()?;
    }
    Ok(())
}

/// Symmetric control flow: every rank takes the same path — no finding.
pub fn symmetric(w: &World, data: &mut [f32]) -> Result<()> {
    for _round in 0..w.d {
        w.barrier()?;
    }
    w.all_reduce_sum(data)?;
    Ok(())
}
