//! Seeded error-propagation violations, `comm/`-path scope (golden
//! fixture). Everything in a file whose path contains `comm/` is in
//! scope regardless of reachability.

use anyhow::Result;

/// Violations: unwrap + expect on the decode path.
pub fn decode_header(bytes: &[u8]) -> (u32, u64) {
    let magic = u32::from_le_bytes(bytes[0..4].try_into().unwrap());
    let round = u64::from_le_bytes(
        bytes[4..12].try_into().expect("8-byte round"),
    );
    (magic, round)
}

/// Violation: panic! instead of a typed error.
pub fn check_magic(magic: u32) {
    if magic != 0x4d4c4c4d {
        panic!("bad magic {magic:#x}");
    }
}

/// Allowed: justified pragma — no finding.
// orchlint: allow(error-propagation): fixture exercise — infallible by construction.
pub fn tag_of(byte: u8) -> u8 {
    [0u8, 1, 2].get(byte as usize % 3).copied().unwrap()
}

/// Clean: propagates instead of aborting.
pub fn decode_checked(bytes: &[u8]) -> Result<u8> {
    bytes
        .first()
        .copied()
        .ok_or_else(|| anyhow::anyhow!("empty frame"))
}
