//! Reachability scope: `barrier` below is a collective implementation,
//! so its callee closure is in error-propagation scope even though this
//! file is not under `comm/`. `detached` is unreachable from any
//! collective and allocates panics freely without findings.

use anyhow::Result;

pub struct Group {
    arrived: usize,
    d: usize,
}

impl Group {
    /// A collective implementation: seeds the reachability closure.
    pub fn barrier(&mut self) -> Result<()> {
        self.arrived += 1;
        wait_all(self.arrived, self.d);
        self.arrived = 0;
        Ok(())
    }
}

/// Reachable from `barrier`. Violations: unwrap + unreachable!.
fn wait_all(arrived: usize, d: usize) {
    let remaining: Option<usize> = d.checked_sub(arrived);
    let r = remaining.unwrap();
    if r > d {
        unreachable!("arithmetic underflow already handled");
    }
}

/// NOT reachable from a collective and not under `comm/` — no finding.
pub fn detached(v: Option<usize>) -> usize {
    v.expect("caller checked")
}
