//! Golden-fixture suite: each mini-tree under `tests/fixtures/` seeds a
//! known set of violations, and the analyzer must produce exactly those
//! finding keys — no more, no fewer. Keys are line-free by design, so
//! these assertions survive fixture reformatting that doesn't change
//! structure.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

fn fixture(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(name)
}

fn keys(root: &Path, hot_entries: &[String]) -> BTreeSet<String> {
    orchlint::run(root, hot_entries)
        .expect("fixture tree loads")
        .into_iter()
        .map(|f| f.key)
        .collect()
}

fn expect_exact(got: BTreeSet<String>, want: &[&str]) {
    let want: BTreeSet<String> = want.iter().map(|s| s.to_string()).collect();
    let missing: Vec<_> = want.difference(&got).collect();
    let extra: Vec<_> = got.difference(&want).collect();
    assert!(
        missing.is_empty() && extra.is_empty(),
        "finding-key mismatch\n  missing: {missing:#?}\n  extra: {extra:#?}"
    );
}

#[test]
fn asymmetry_fixture_pins_all_three_rules_and_pragma_enforcement() {
    let got = keys(&fixture("asymmetry"), &[]);
    expect_exact(
        got,
        &[
            "collective-asymmetry::lib.rs::rank_gated::rank-branch:barrier",
            "collective-asymmetry::lib.rs::fallible_arm::fallible-branch:all_gather_bytes",
            "collective-asymmetry::lib.rs::early_exit::early-exit:all_reduce_sum",
            // `unjustified_gate` is allowlisted (no asymmetry finding) but
            // the bare pragma itself is flagged; `allowed_gate` is silent.
            "pragma::lib.rs::unjustified_gate::missing-justification:collective-asymmetry",
        ],
    );
}

#[test]
fn hotpath_fixture_flags_the_entry_closure_and_nothing_else() {
    let manifest = fixture("hotpath").join("hot_paths.toml");
    let entries = orchlint::baseline::read_hot_paths(&manifest).expect("fixture manifest");
    assert_eq!(entries, vec!["Planner::step".to_string()]);
    let got = keys(&fixture("hotpath"), &entries);
    expect_exact(
        got,
        &[
            "hot-path-alloc::lib.rs::Planner::step::collect",
            "hot-path-alloc::lib.rs::Planner::step::vec!",
            "hot-path-alloc::lib.rs::helper::Vec::new",
            "hot-path-alloc::lib.rs::helper::to_vec",
            "hot-path-alloc::lib.rs::helper::clone",
            "hot-path-alloc::lib.rs::helper::format!",
            // Absent by design: Arc::clone in `helper` (refcount bump),
            // everything in `warmup` (justified pragma) and `unrelated`
            // (outside the entry closure).
        ],
    );
}

#[test]
fn errors_fixture_covers_both_scope_rules() {
    let got = keys(&fixture("errors"), &[]);
    expect_exact(
        got,
        &[
            // Path scope: any file under comm/ is in scope outright.
            "error-propagation::comm/wire.rs::decode_header::unwrap",
            "error-propagation::comm/wire.rs::decode_header::expect",
            "error-propagation::comm/wire.rs::check_magic::panic!",
            // Reachability scope: `wait_all` is a callee of the collective
            // `Group::barrier`; `detached` is neither and stays silent.
            "error-propagation::engine.rs::wait_all::unwrap",
            "error-propagation::engine.rs::wait_all::unreachable!",
        ],
    );
}

#[test]
fn clean_fixture_is_silent() {
    let got = keys(&fixture("clean"), &[]);
    assert!(got.is_empty(), "clean fixture produced findings: {got:#?}");
}
