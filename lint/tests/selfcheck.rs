//! Self-check: analyzing the real `rust/src` tree with the committed
//! hot-path manifest must reproduce `ci/orchlint_baseline.json` exactly.
//! This is the same comparison the CI gate runs, expressed as a test so
//! `cargo test` catches ratchet drift (new findings OR stale baseline
//! entries) before the static-analysis job does.

use std::collections::BTreeSet;
use std::path::Path;

#[test]
fn real_tree_matches_committed_baseline() {
    let repo = Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
    let entries = orchlint::baseline::read_hot_paths(&repo.join("ci/hot_paths.toml"))
        .expect("ci/hot_paths.toml");
    let baseline = orchlint::baseline::read_baseline(&repo.join("ci/orchlint_baseline.json"))
        .expect("ci/orchlint_baseline.json");

    let got: BTreeSet<String> = orchlint::run(&repo.join("rust/src"), &entries)
        .expect("rust/src loads")
        .into_iter()
        .map(|f| f.key)
        .collect();

    let new: Vec<_> = got.difference(&baseline).collect();
    let stale: Vec<_> = baseline.difference(&got).collect();
    assert!(
        new.is_empty() && stale.is_empty(),
        "orchlint drift vs ci/orchlint_baseline.json\n  \
         new findings (fix or pragma-allowlist with justification): {new:#?}\n  \
         stale baseline entries (delete them — the ratchet only shrinks): {stale:#?}"
    );
}
