//! A minimal Rust lexer, scoped to what the orchlint analyses need.
//!
//! Produces a flat token stream (idents, single-char puncts plus `::`,
//! literals) with line numbers, and a separate list of line comments so
//! `// orchlint: allow(...)` pragmas survive lexing. Correctly skips
//! strings (incl. raw/byte strings), char literals vs lifetimes, nested
//! block comments, and numeric literals (incl. `0..n` range ambiguity).
//!
//! This is intentionally NOT a full Rust lexer: multi-char operators other
//! than `::` are emitted as single-char puncts, and no keyword table exists
//! (keywords are just idents). The parser and analyses only ever match on
//! ident text and the puncts `{ } ( ) [ ] < > : :: ; , . # ! ' =`.

/// Token kind. Literals carry no text (analyses never inspect them).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Punct,
    Lit,
}

#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

/// A `//` line comment (text excludes the leading slashes).
#[derive(Debug, Clone)]
pub struct Comment {
    pub line: u32,
    pub text: String,
}

pub fn lex(src: &str) -> (Vec<Tok>, Vec<Comment>) {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut toks: Vec<Tok> = Vec::new();
    let mut comments: Vec<Comment> = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;

    let is_ident_start = |c: char| c.is_alphabetic() || c == '_';
    let is_ident_cont = |c: char| c.is_alphanumeric() || c == '_';

    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment.
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            let start = i + 2;
            let mut j = start;
            while j < n && b[j] != '\n' {
                j += 1;
            }
            comments.push(Comment {
                line,
                text: b[start..j].iter().collect(),
            });
            i = j;
            continue;
        }
        // Block comment (nested).
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let mut depth = 1i32;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if b[j] == '\n' {
                    line += 1;
                    j += 1;
                } else if b[j] == '/' && j + 1 < n && b[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if b[j] == '*' && j + 1 < n && b[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            i = j;
            continue;
        }
        // Raw / byte string prefixes: r"…", r#"…"#, b"…", br#"…"#, …
        if c == 'r' || c == 'b' {
            let mut j = i + 1;
            if c == 'b' && j < n && b[j] == 'r' {
                j += 1;
            }
            let mut hashes = 0usize;
            let mut k = j;
            while k < n && b[k] == '#' {
                hashes += 1;
                k += 1;
            }
            if k < n && b[k] == '"' {
                // r"…" | r#…#"…" | b"…" | br"…" — but `r#ident` (raw ident)
                // has hashes followed by an ident char, not a quote, so it
                // falls through to the ident path below.
                {
                    let lit_line = line;
                    let mut m = k + 1;
                    'raw: while m < n {
                        if b[m] == '\n' {
                            line += 1;
                            m += 1;
                            continue;
                        }
                        if b[m] == '"' {
                            let mut h = 0usize;
                            while m + 1 + h < n && h < hashes && b[m + 1 + h] == '#' {
                                h += 1;
                            }
                            if h == hashes {
                                m += 1 + hashes;
                                break 'raw;
                            }
                        }
                        // Non-raw byte string b"…" honors escapes.
                        if hashes == 0 && b[m] == '\\' && m + 1 < n {
                            m += 2;
                            continue;
                        }
                        m += 1;
                    }
                    toks.push(Tok {
                        kind: TokKind::Lit,
                        text: String::new(),
                        line: lit_line,
                    });
                    i = m;
                    continue;
                }
            }
            // `r#ident` raw identifier: skip the `r#`, lex the ident below.
            if c == 'r' && i + 1 < n && b[i + 1] == '#' && i + 2 < n && is_ident_start(b[i + 2]) {
                i += 2;
                // fall through to ident handling with b[i] an ident start
                let start = i;
                let mut j = i;
                while j < n && is_ident_cont(b[j]) {
                    j += 1;
                }
                toks.push(Tok {
                    kind: TokKind::Ident,
                    text: b[start..j].iter().collect(),
                    line,
                });
                i = j;
                continue;
            }
        }
        // Plain string.
        if c == '"' {
            let lit_line = line;
            let mut j = i + 1;
            while j < n {
                if b[j] == '\\' && j + 1 < n {
                    j += 2;
                    continue;
                }
                if b[j] == '\n' {
                    line += 1;
                    j += 1;
                    continue;
                }
                if b[j] == '"' {
                    j += 1;
                    break;
                }
                j += 1;
            }
            toks.push(Tok {
                kind: TokKind::Lit,
                text: String::new(),
                line: lit_line,
            });
            i = j;
            continue;
        }
        // Char literal vs lifetime.
        if c == '\'' {
            if i + 1 < n && b[i + 1] == '\\' {
                // '\n', '\'', '\u{..}' …
                let mut j = i + 2;
                while j < n && b[j] != '\'' {
                    j += 1;
                }
                toks.push(Tok {
                    kind: TokKind::Lit,
                    text: String::new(),
                    line,
                });
                i = j + 1;
                continue;
            }
            if i + 2 < n && b[i + 2] == '\'' {
                // 'x'
                toks.push(Tok {
                    kind: TokKind::Lit,
                    text: String::new(),
                    line,
                });
                i += 3;
                continue;
            }
            // Lifetime: emit the quote as punct; ident lexes next round.
            toks.push(Tok {
                kind: TokKind::Punct,
                text: "'".to_string(),
                line,
            });
            i += 1;
            continue;
        }
        // Number.
        if c.is_ascii_digit() {
            let mut j = i + 1;
            while j < n {
                let d = b[j];
                if d.is_ascii_alphanumeric() || d == '_' {
                    j += 1;
                    continue;
                }
                // `1.5` yes; `0..n` and `1.max(..)` no.
                if d == '.' && j + 1 < n && b[j + 1].is_ascii_digit() {
                    j += 1;
                    continue;
                }
                // `1e-3` exponent sign.
                if (d == '+' || d == '-')
                    && matches!(b[j - 1], 'e' | 'E')
                    && j + 1 < n
                    && b[j + 1].is_ascii_digit()
                {
                    j += 1;
                    continue;
                }
                break;
            }
            toks.push(Tok {
                kind: TokKind::Lit,
                text: String::new(),
                line,
            });
            i = j;
            continue;
        }
        // Ident.
        if is_ident_start(c) {
            let start = i;
            let mut j = i;
            while j < n && is_ident_cont(b[j]) {
                j += 1;
            }
            toks.push(Tok {
                kind: TokKind::Ident,
                text: b[start..j].iter().collect(),
                line,
            });
            i = j;
            continue;
        }
        // `::` as one token (path detection); everything else single char.
        if c == ':' && i + 1 < n && b[i + 1] == ':' {
            toks.push(Tok {
                kind: TokKind::Punct,
                text: "::".to_string(),
                line,
            });
            i += 2;
            continue;
        }
        toks.push(Tok {
            kind: TokKind::Punct,
            text: c.to_string(),
            line,
        });
        i += 1;
    }
    (toks, comments)
}
