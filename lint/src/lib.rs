//! orchlint — SPMD-aware static analysis for the orchmllm source tree.
//!
//! Three project-specific analyses over an intra-crate, name-resolved call
//! graph (see DESIGN.md §Static Analysis for definitions and soundness):
//!
//! 1. **collective-asymmetry** — calls into the Transport/Collectives data
//!    plane that are control-dependent on rank identity, sit under a
//!    fallible branch, or follow a conditional early exit. The classic
//!    MPI mismatched-collective deadlock source.
//! 2. **hot-path-alloc** — allocating constructs in the callee closure of
//!    the `ci/hot_paths.toml` entry points (the PR-6 zero-alloc surfaces);
//!    the static complement to `rust/tests/plan_allocations.rs`.
//! 3. **error-propagation** — `unwrap`/`expect`/`panic!`-family constructs
//!    in `comm/` code and in anything reachable from a collective, where
//!    failures must surface as `TransportError` instead of a local abort.
//!
//! Findings are stable-keyed (`class::file::function::detail`, no line
//! numbers) and ratcheted against `ci/orchlint_baseline.json`.

pub mod analyses;
pub mod baseline;
pub mod lexer;
pub mod parse;

use analyses::{CallGraph, Finding, Findings, COLLECTIVES};
use lexer::Tok;
use parse::FnRec;
use std::collections::BTreeMap;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Lexed + parsed source tree.
pub struct Tree {
    pub root: PathBuf,
    pub fns: Vec<FnRec>,
    pub toks_by_file: BTreeMap<String, Vec<Tok>>,
}

/// Recursively collect `.rs` files under `root`, sorted by relative path so
/// analysis order (and therefore output) is deterministic across platforms.
fn rs_files(root: &Path) -> io::Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        for entry in fs::read_dir(&dir)? {
            let entry = entry?;
            let path = entry.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension().is_some_and(|e| e == "rs") {
                out.push(path);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Lex and parse every `.rs` file under `root`.
pub fn load_tree(root: &Path) -> io::Result<Tree> {
    let mut fns = Vec::new();
    let mut toks_by_file = BTreeMap::new();
    for path in rs_files(root)? {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace('\\', "/");
        let src = fs::read_to_string(&path)?;
        let (toks, comments) = lexer::lex(&src);
        parse::parse_file(&rel, &toks, &comments, &mut fns);
        toks_by_file.insert(rel, toks);
    }
    Ok(Tree {
        root: root.to_path_buf(),
        fns,
        toks_by_file,
    })
}

/// Run all analyses; `hot_entries` comes from `ci/hot_paths.toml`.
pub fn analyze(tree: &Tree, hot_entries: &[String]) -> Vec<Finding> {
    let graph = CallGraph::build(&tree.fns, &tree.toks_by_file);
    let mut out = Findings::default();

    // Seeds for the hot-path closure: exact qualified match, or bare-name
    // match for entries without a `::`.
    let mut hot_seeds = Vec::new();
    for (i, r) in tree.fns.iter().enumerate() {
        if r.is_test {
            continue;
        }
        for e in hot_entries {
            let hit = if e.contains("::") {
                r.qname == *e
            } else {
                r.name == *e
            };
            if hit {
                hot_seeds.push(i);
            }
        }
    }
    let hot_closure = graph.closure(&hot_seeds);

    // Seeds for the error-propagation closure: the collective
    // implementations themselves (any fn named like one).
    let coll_seeds: Vec<usize> = tree
        .fns
        .iter()
        .enumerate()
        .filter(|(_, r)| !r.is_test && COLLECTIVES.contains(&r.name.as_str()))
        .map(|(i, _)| i)
        .collect();
    let coll_closure = graph.closure(&coll_seeds);

    for (i, r) in tree.fns.iter().enumerate() {
        if r.is_test {
            continue;
        }
        let toks = &tree.toks_by_file[&r.file];
        analyses::check_pragmas(r, &mut out);
        analyses::check_symmetry(r, toks, &mut out);
        if hot_closure.contains(&i) {
            analyses::check_hot_path(r, toks, &mut out);
        }
        if r.file.contains("comm/") || coll_closure.contains(&i) {
            analyses::check_error_prop(r, toks, &mut out);
        }
    }
    out.into_sorted()
}

/// Convenience: load + analyze in one call.
pub fn run(root: &Path, hot_entries: &[String]) -> io::Result<Vec<Finding>> {
    let tree = load_tree(root)?;
    Ok(analyze(&tree, hot_entries))
}
