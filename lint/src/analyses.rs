//! The three orchlint analyses plus pragma validation.
//!
//! All analyses run over `FnRec` token spans from `parse.rs`. Findings are
//! deduplicated per `(function, detail)` and keyed WITHOUT line numbers so
//! the baseline stays stable across unrelated edits; line numbers ride
//! along in the report payload only.

use crate::lexer::{Tok, TokKind};
use crate::parse::FnRec;
use std::collections::{BTreeMap, BTreeSet, VecDeque};

/// The Transport/Collectives data plane: every DP rank must call these the
/// same number of times in the same order.
pub const COLLECTIVES: [&str; 6] = [
    "all_to_all_bytes",
    "all_to_all_shards",
    "all_gather_bytes",
    "all_reduce_sum",
    "barrier",
    "heartbeat",
];

pub const CLASS_SYMMETRY: &str = "collective-asymmetry";
pub const CLASS_HOT_PATH: &str = "hot-path-alloc";
pub const CLASS_ERROR_PROP: &str = "error-propagation";
const KNOWN_CLASSES: [&str; 3] = [CLASS_SYMMETRY, CLASS_HOT_PATH, CLASS_ERROR_PROP];

/// Idents treated as rank identity when they appear in a branch header.
const RANK_IDENTS: [&str; 4] = ["rank", "me", "my_rank", "rank_id"];

/// One deduplicated finding. `key` is the stable identity used by the
/// baseline; `lines` are advisory (first few sites, sorted).
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Finding {
    pub key: String,
    pub class: String,
    pub file: String,
    pub function: String,
    pub detail: String,
    pub lines: Vec<u32>,
}

fn key_of(class: &str, file: &str, qname: &str, detail: &str) -> String {
    format!("{class}::{file}::{qname}::{detail}")
}

/// Accumulates findings with per-key line lists.
#[derive(Default)]
pub struct Findings {
    map: BTreeMap<String, Finding>,
}

impl Findings {
    pub fn add(&mut self, class: &str, rec: &FnRec, detail: &str, line: u32) {
        let key = key_of(class, &rec.file, &rec.qname, detail);
        let f = self.map.entry(key.clone()).or_insert_with(|| Finding {
            key,
            class: class.to_string(),
            file: rec.file.clone(),
            function: rec.qname.clone(),
            detail: detail.to_string(),
            lines: Vec::new(),
        });
        if !f.lines.contains(&line) {
            f.lines.push(line);
            f.lines.sort_unstable();
        }
    }

    pub fn into_sorted(self) -> Vec<Finding> {
        self.map.into_values().collect()
    }
}

/// Iterate a fn's body tokens, skipping nested-fn holes.
fn body_tokens<'a>(rec: &'a FnRec, toks: &'a [Tok]) -> Vec<(usize, &'a Tok)> {
    let (start, end) = rec.body;
    let mut out = Vec::new();
    let mut i = start;
    while i <= end && i < toks.len() {
        if let Some(&(hs, he)) = rec.holes.iter().find(|&&(hs, _)| hs == i) {
            debug_assert!(he >= hs);
            i = he + 1;
            continue;
        }
        out.push((i, &toks[i]));
        i += 1;
    }
    out
}

/// Call-site names in a fn body: `name(`, `.name(`, `Path::name(`.
/// Macro invocations (`name!(`) and nested `fn` declarations are excluded.
pub fn callees(rec: &FnRec, toks: &[Tok]) -> BTreeSet<String> {
    let body = body_tokens(rec, toks);
    let mut out = BTreeSet::new();
    for w in 0..body.len() {
        let (_, t) = body[w];
        if t.kind != TokKind::Ident {
            continue;
        }
        let Some(&(_, next)) = body.get(w + 1) else {
            continue;
        };
        if next.text != "(" {
            continue;
        }
        if w > 0 && body[w - 1].1.text == "fn" {
            continue;
        }
        out.insert(t.text.clone());
    }
    out
}

/// Name-based call graph over non-test fns: an edge exists from caller to
/// every fn whose last-segment name matches a call-site name. Trait-object
/// and method calls resolve by bare method name — over-approximate by
/// design (see DESIGN.md §Static Analysis).
pub struct CallGraph {
    /// fn index -> indices of possible callees.
    pub edges: Vec<Vec<usize>>,
}

impl CallGraph {
    pub fn build(recs: &[FnRec], toks_by_file: &BTreeMap<String, Vec<Tok>>) -> CallGraph {
        let mut by_name: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
        for (i, r) in recs.iter().enumerate() {
            if !r.is_test {
                by_name.entry(&r.name).or_default().push(i);
            }
        }
        let mut edges = vec![Vec::new(); recs.len()];
        for (i, r) in recs.iter().enumerate() {
            if r.is_test {
                continue;
            }
            let toks = &toks_by_file[&r.file];
            for name in callees(r, toks) {
                if let Some(targets) = by_name.get(name.as_str()) {
                    for &t in targets {
                        if t != i {
                            edges[i].push(t);
                        }
                    }
                }
            }
        }
        CallGraph { edges }
    }

    /// Indices reachable from `seeds` (inclusive).
    pub fn closure(&self, seeds: &[usize]) -> BTreeSet<usize> {
        let mut seen: BTreeSet<usize> = seeds.iter().copied().collect();
        let mut q: VecDeque<usize> = seeds.iter().copied().collect();
        while let Some(i) = q.pop_front() {
            for &j in &self.edges[i] {
                if seen.insert(j) {
                    q.push_back(j);
                }
            }
        }
        seen
    }
}

/// Analysis 1: collective symmetry.
///
/// Flags a collective call when (a) any enclosing `if`/`match`/`while`/`for`
/// header mentions rank identity, (b) any enclosing header is fallible
/// (`if let Ok/Err/Some/None`, `.is_ok()` etc.), or (c) a `return`/`bail!`
/// occurred earlier in the fn inside a conditional — a rank that takes the
/// early exit skips the collective its peers are blocked in.
pub fn check_symmetry(rec: &FnRec, toks: &[Tok], out: &mut Findings) {
    if rec.is_test || rec.allowed(CLASS_SYMMETRY) {
        return;
    }
    let body = body_tokens(rec, toks);
    // Conditional-context stack: (rank_dep, fallible, brace_depth_at_open).
    let mut ctx: Vec<(bool, bool)> = Vec::new();
    let mut brace_owner: Vec<bool> = Vec::new(); // true = brace opened a ctx
    let mut saw_cond_exit = false;
    let mut w = 0usize;
    while w < body.len() {
        let (_, t) = body[w];
        if t.kind == TokKind::Ident
            && matches!(t.text.as_str(), "if" | "match" | "while" | "for")
        {
            // Header = tokens until `{` at paren/bracket-depth 0. Bare
            // struct literals are illegal in these headers, so the first
            // depth-0 `{` is the block opener.
            let mut depth = 0i32;
            let mut j = w + 1;
            let mut rank_dep = false;
            let mut fallible = false;
            while j < body.len() {
                let (_, h) = body[j];
                match h.text.as_str() {
                    "(" | "[" => depth += 1,
                    ")" | "]" => depth -= 1,
                    "{" if depth == 0 => break,
                    _ => {}
                }
                if h.kind == TokKind::Ident {
                    if RANK_IDENTS.contains(&h.text.as_str()) {
                        rank_dep = true;
                    }
                    if matches!(
                        h.text.as_str(),
                        "Ok" | "Err" | "Some" | "None" | "is_ok" | "is_err" | "is_some"
                            | "is_none"
                    ) {
                        fallible = true;
                    }
                }
                j += 1;
            }
            if j < body.len() {
                // Consume header and the opening brace.
                ctx.push((rank_dep, fallible));
                brace_owner.push(true);
                w = j + 1;
                continue;
            }
            w += 1;
            continue;
        }
        match t.text.as_str() {
            "{" => {
                brace_owner.push(false);
                w += 1;
                continue;
            }
            "}" => {
                let owned = brace_owner.pop().unwrap_or(false);
                let popped = if owned { ctx.pop() } else { None };
                let nxt1 = body.get(w + 1).map(|&(_, t2)| t2.text.as_str());
                let nxt2 = body.get(w + 2).map(|&(_, t2)| t2.text.as_str());
                match popped {
                    Some(p) if nxt1 == Some("else") && nxt2 != Some("if") => {
                        // Bare `else {` reuses the popped flags; `else if`
                        // pushes a fresh context when its own header's `{`
                        // is consumed on a later iteration.
                        ctx.push(p);
                        brace_owner.push(true);
                        w += 3; // skip `}` `else` `{`
                    }
                    _ => w += 1,
                }
                continue;
            }
            _ => {}
        }
        if t.kind == TokKind::Ident {
            let name = t.text.as_str();
            let next = body.get(w + 1).map(|&(_, t2)| t2.text.as_str());
            if (name == "return" || (name == "bail" && next == Some("!"))) && !ctx.is_empty() {
                saw_cond_exit = true;
            }
            if COLLECTIVES.contains(&name) && next == Some("(") {
                let rank_dep = ctx.iter().any(|&(r, _)| r);
                let fallible = ctx.iter().any(|&(_, f)| f);
                if rank_dep {
                    out.add(CLASS_SYMMETRY, rec, &format!("rank-branch:{name}"), t.line);
                }
                if fallible {
                    out.add(
                        CLASS_SYMMETRY,
                        rec,
                        &format!("fallible-branch:{name}"),
                        t.line,
                    );
                }
                if saw_cond_exit && !rank_dep && !fallible {
                    out.add(CLASS_SYMMETRY, rec, &format!("early-exit:{name}"), t.line);
                }
            }
        }
        w += 1;
    }
}

/// Analysis 2: allocating constructs in the hot-path closure.
pub fn check_hot_path(rec: &FnRec, toks: &[Tok], out: &mut Findings) {
    if rec.is_test || rec.allowed(CLASS_HOT_PATH) {
        return;
    }
    let body = body_tokens(rec, toks);
    for w in 0..body.len() {
        let (_, t) = body[w];
        if t.kind != TokKind::Ident {
            continue;
        }
        let next = body.get(w + 1).map(|&(_, t2)| t2.text.as_str());
        let prev = if w > 0 {
            body[w - 1].1.text.as_str()
        } else {
            ""
        };
        let prev2 = if w > 1 {
            body[w - 2].1.text.as_str()
        } else {
            ""
        };
        let name = t.text.as_str();
        let construct: Option<String> = match name {
            "new" if next == Some("(")
                && prev == "::"
                && matches!(prev2, "Vec" | "Box" | "String" | "VecDeque" | "HashMap"
                    | "BTreeMap" | "HashSet" | "BTreeSet") =>
            {
                Some(format!("{prev2}::new"))
            }
            "clone" if next == Some("(") => {
                // `Arc::clone` / `Rc::clone` are refcount bumps, not heap
                // allocations.
                if prev == "::" && matches!(prev2, "Arc" | "Rc") {
                    None
                } else {
                    Some("clone".to_string())
                }
            }
            "to_vec" | "to_string" | "to_owned" | "collect" | "with_capacity"
                if next == Some("(") =>
            {
                Some(name.to_string())
            }
            "vec" | "format" if next == Some("!") => Some(format!("{name}!")),
            _ => None,
        };
        if let Some(c) = construct {
            out.add(CLASS_HOT_PATH, rec, &c, t.line);
        }
    }
}

/// Analysis 3: panic-family constructs where errors must propagate as
/// `TransportError` instead.
pub fn check_error_prop(rec: &FnRec, toks: &[Tok], out: &mut Findings) {
    if rec.is_test || rec.allowed(CLASS_ERROR_PROP) {
        return;
    }
    let body = body_tokens(rec, toks);
    for w in 0..body.len() {
        let (_, t) = body[w];
        if t.kind != TokKind::Ident {
            continue;
        }
        let next = body.get(w + 1).map(|&(_, t2)| t2.text.as_str());
        let name = t.text.as_str();
        let construct: Option<String> = match name {
            "unwrap" | "expect" if next == Some("(") => Some(name.to_string()),
            "panic" | "unreachable" | "todo" | "unimplemented" if next == Some("!") => {
                Some(format!("{name}!"))
            }
            _ => None,
        };
        if let Some(c) = construct {
            out.add(CLASS_ERROR_PROP, rec, &c, t.line);
        }
    }
}

/// Pragma validation: every `orchlint: allow(...)` must name a known class
/// and carry a justification after the closing paren.
pub fn check_pragmas(rec: &FnRec, out: &mut Findings) {
    for (class, justified) in &rec.allows {
        if !KNOWN_CLASSES.contains(&class.as_str()) {
            out.add(
                "pragma",
                rec,
                &format!("unknown-class:{class}"),
                rec.line,
            );
        } else if !justified {
            out.add(
                "pragma",
                rec,
                &format!("missing-justification:{class}"),
                rec.line,
            );
        }
    }
}
