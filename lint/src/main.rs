//! orchlint CLI.
//!
//! ```text
//! cargo run -p orchlint -- rust/src                 # gate against ci/orchlint_baseline.json
//! cargo run -p orchlint -- rust/src --write-baseline  # regenerate the ratchet
//! cargo run -p orchlint -- rust/src --json report.json
//! ```
//!
//! Exit codes: 0 clean (findings exactly match the baseline), 1 drift
//! (unbaselined findings and/or stale baseline entries), 2 usage/IO error.

use orchlint::baseline;
use std::collections::BTreeSet;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

struct Opts {
    root: PathBuf,
    hot_paths: PathBuf,
    baseline: PathBuf,
    write_baseline: bool,
    json: Option<PathBuf>,
}

fn parse_args() -> Result<Opts, String> {
    let mut root: Option<PathBuf> = None;
    let mut hot_paths = PathBuf::from("ci/hot_paths.toml");
    let mut baseline = PathBuf::from("ci/orchlint_baseline.json");
    let mut write_baseline = false;
    let mut json = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--hot-paths" => {
                hot_paths = PathBuf::from(args.next().ok_or("--hot-paths needs a path")?)
            }
            "--baseline" => {
                baseline = PathBuf::from(args.next().ok_or("--baseline needs a path")?)
            }
            "--write-baseline" => write_baseline = true,
            "--json" => json = Some(PathBuf::from(args.next().ok_or("--json needs a path")?)),
            "--help" | "-h" => {
                return Err("usage: orchlint <root> [--hot-paths p] [--baseline p] \
                     [--write-baseline] [--json p]"
                    .to_string())
            }
            _ if root.is_none() && !a.starts_with('-') => root = Some(PathBuf::from(a)),
            _ => return Err(format!("unknown argument: {a}")),
        }
    }
    Ok(Opts {
        root: root.ok_or("missing <root> (e.g. rust/src)")?,
        hot_paths,
        baseline,
        write_baseline,
        json,
    })
}

fn main() -> ExitCode {
    let opts = match parse_args() {
        Ok(o) => o,
        Err(e) => {
            eprintln!("orchlint: {e}");
            return ExitCode::from(2);
        }
    };
    let hot_entries = if opts.hot_paths.exists() {
        match baseline::read_hot_paths(&opts.hot_paths) {
            Ok(v) => v,
            Err(e) => {
                eprintln!("orchlint: reading {}: {e}", opts.hot_paths.display());
                return ExitCode::from(2);
            }
        }
    } else {
        eprintln!(
            "orchlint: note: {} not found; hot-path analysis has no entry points",
            opts.hot_paths.display()
        );
        Vec::new()
    };
    let findings = match orchlint::run(&opts.root, &hot_entries) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("orchlint: analyzing {}: {e}", opts.root.display());
            return ExitCode::from(2);
        }
    };
    let mut per_class: std::collections::BTreeMap<&str, usize> = Default::default();
    for f in &findings {
        *per_class.entry(f.class.as_str()).or_default() += 1;
    }
    let summary: Vec<String> = per_class
        .iter()
        .map(|(c, n)| format!("{c}: {n}"))
        .collect();
    eprintln!(
        "orchlint: {} findings ({}) across {}",
        findings.len(),
        if summary.is_empty() {
            "none".to_string()
        } else {
            summary.join(", ")
        },
        opts.root.display()
    );

    if let Some(p) = &opts.json {
        if let Err(e) = baseline::write_report(p, &opts.root.to_string_lossy(), &findings) {
            eprintln!("orchlint: writing {}: {e}", p.display());
            return ExitCode::from(2);
        }
    }
    if opts.write_baseline {
        if let Err(e) = baseline::write_baseline(&opts.baseline, &findings) {
            eprintln!("orchlint: writing {}: {e}", opts.baseline.display());
            return ExitCode::from(2);
        }
        eprintln!(
            "orchlint: wrote {} ({} keys)",
            opts.baseline.display(),
            findings.len()
        );
        return ExitCode::SUCCESS;
    }

    gate(&opts.baseline, &findings)
}

/// Compare findings against the ratchet. Both directions are errors: new
/// findings mean a regression; stale entries mean the baseline must shrink
/// (delete the fixed keys and commit).
fn gate(baseline_path: &Path, findings: &[orchlint::analyses::Finding]) -> ExitCode {
    if !baseline_path.exists() {
        eprintln!(
            "orchlint: no baseline at {}; run with --write-baseline to create one",
            baseline_path.display()
        );
        return ExitCode::from(if findings.is_empty() { 0 } else { 1 });
    }
    let base = match baseline::read_baseline(baseline_path) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("orchlint: reading {}: {e}", baseline_path.display());
            return ExitCode::from(2);
        }
    };
    let current: BTreeSet<String> = findings.iter().map(|f| f.key.clone()).collect();
    let new: Vec<&String> = current.difference(&base).collect();
    let stale: Vec<&String> = base.difference(&current).collect();
    for k in &new {
        let lines = findings
            .iter()
            .find(|f| &&f.key == k)
            .map(|f| format!("{:?}", f.lines))
            .unwrap_or_default();
        eprintln!("orchlint: NEW finding (not in baseline): {k} at lines {lines}");
    }
    for k in &stale {
        eprintln!(
            "orchlint: stale baseline entry (finding fixed — delete it from {}): {k}",
            baseline_path.display()
        );
    }
    if new.is_empty() && stale.is_empty() {
        eprintln!("orchlint: clean — findings exactly match the baseline");
        ExitCode::SUCCESS
    } else {
        eprintln!(
            "orchlint: drift — {} new, {} stale (baseline ratchet only moves down)",
            new.len(),
            stale.len()
        );
        ExitCode::from(1)
    }
}
