//! Item-level parse: turn a token stream into function records.
//!
//! Tracks module nesting (to drop `#[cfg(test)]` modules and `mod tests`),
//! `impl`/`trait` blocks (to qualify method names as `Type::method`), and
//! function bodies as brace-matched token spans. Nested `fn`s become their
//! own records and are carved out of the parent's span ("holes") so every
//! token belongs to exactly one function.

use crate::lexer::{Comment, Tok, TokKind};
use std::collections::BTreeMap;

/// One function (free fn, method, or default trait method) in one file.
#[derive(Debug)]
pub struct FnRec {
    /// Path of the containing file, relative to the scan root, `/`-separated.
    pub file: String,
    /// `Type::name` inside an `impl`/`trait` block, else bare `name`.
    pub qname: String,
    /// Last segment of `qname`.
    pub name: String,
    /// Line of the `fn` keyword.
    pub line: u32,
    /// Line of the body's closing brace (pragma containment check).
    pub end_line: u32,
    /// True for `#[test]` fns and anything inside a test module.
    pub is_test: bool,
    /// Token index range of the body, inclusive of both braces.
    pub body: (usize, usize),
    /// Sub-ranges of `body` owned by nested fns; skip when scanning.
    pub holes: Vec<(usize, usize)>,
    /// Pragma allow-classes -> justification present?
    pub allows: BTreeMap<String, bool>,
}

impl FnRec {
    pub fn allowed(&self, class: &str) -> bool {
        self.allows.contains_key(class)
    }
}

enum Ctx {
    Mod { test: bool },
    Impl { ty: String },
    Trait { name: String },
    Fn { rec: usize },
    Other,
}

/// Parse one file's tokens into fn records (appended to `out`).
pub fn parse_file(file: &str, toks: &[Tok], comments: &[Comment], out: &mut Vec<FnRec>) {
    let first_rec = out.len();
    let mut stack: Vec<Ctx> = Vec::new();
    let mut pending_test_attr = false; // #[test] / #[cfg(test)] seen since last item
    let mut i = 0usize;
    let n = toks.len();

    let in_test_mod = |stack: &[Ctx]| stack.iter().any(|c| matches!(c, Ctx::Mod { test: true }));
    let enclosing_ty = |stack: &[Ctx]| -> Option<String> {
        // A nested fn inside another fn is a free fn, not a method.
        for c in stack.iter().rev() {
            match c {
                Ctx::Fn { .. } => return None,
                Ctx::Impl { ty } => return Some(ty.clone()),
                Ctx::Trait { name } => return Some(name.clone()),
                _ => {}
            }
        }
        None
    };

    while i < n {
        let t = &toks[i];
        match (t.kind, t.text.as_str()) {
            (TokKind::Punct, "#") => {
                // Attribute: #[...] or #![...]. Collect idents, flag tests.
                let mut j = i + 1;
                if j < n && toks[j].text == "!" {
                    j += 1;
                }
                if j < n && toks[j].text == "[" {
                    let mut depth = 1i32;
                    let mut k = j + 1;
                    let mut idents: Vec<&str> = Vec::new();
                    while k < n && depth > 0 {
                        match toks[k].text.as_str() {
                            "[" => depth += 1,
                            "]" => depth -= 1,
                            _ => {
                                if toks[k].kind == TokKind::Ident {
                                    idents.push(&toks[k].text);
                                }
                            }
                        }
                        k += 1;
                    }
                    let is_test = idents.first() == Some(&"test")
                        || (idents.first() == Some(&"cfg") && idents.contains(&"test"));
                    if is_test {
                        pending_test_attr = true;
                    }
                    i = k;
                    continue;
                }
                i += 1;
            }
            (TokKind::Ident, "mod") => {
                // `mod name;` or `mod name { ... }`
                let name = if i + 1 < n && toks[i + 1].kind == TokKind::Ident {
                    toks[i + 1].text.clone()
                } else {
                    String::new()
                };
                let mut j = i + 1;
                while j < n && toks[j].text != "{" && toks[j].text != ";" {
                    j += 1;
                }
                if j < n && toks[j].text == "{" {
                    let test = pending_test_attr || name == "tests" || name == "test";
                    stack.push(Ctx::Mod { test });
                    i = j + 1;
                } else {
                    i = j + 1;
                }
                pending_test_attr = false;
            }
            (TokKind::Ident, "impl") => {
                // impl [<G>] Type [for Type2] [where ...] { ... }
                let mut j = i + 1;
                // Skip leading generics.
                if j < n && toks[j].text == "<" {
                    let mut angle = 1i32;
                    j += 1;
                    while j < n && angle > 0 {
                        match toks[j].text.as_str() {
                            "<" => angle += 1,
                            ">" => angle -= 1,
                            _ => {}
                        }
                        j += 1;
                    }
                }
                // Read up to `{`, remembering idents at angle-depth 0 before
                // and after a top-level `for`.
                let mut before: Vec<String> = Vec::new();
                let mut after: Vec<String> = Vec::new();
                let mut saw_for = false;
                let mut angle = 0i32;
                while j < n && !(angle == 0 && toks[j].text == "{") {
                    let tt = &toks[j];
                    match tt.text.as_str() {
                        "<" => angle += 1,
                        ">" => {
                            if angle > 0 {
                                angle -= 1;
                            }
                        }
                        "for" if angle == 0 && tt.kind == TokKind::Ident => saw_for = true,
                        "where" if angle == 0 && tt.kind == TokKind::Ident => {
                            // type part is over; skip to `{`
                            while j < n && toks[j].text != "{" {
                                j += 1;
                            }
                            break;
                        }
                        _ => {
                            if tt.kind == TokKind::Ident && angle == 0 {
                                if saw_for {
                                    after.push(tt.text.clone());
                                } else {
                                    before.push(tt.text.clone());
                                }
                            }
                        }
                    }
                    j += 1;
                }
                let ty = if saw_for {
                    after.last().cloned().unwrap_or_default()
                } else {
                    before.last().cloned().unwrap_or_default()
                };
                if j < n && toks[j].text == "{" {
                    stack.push(Ctx::Impl { ty });
                    i = j + 1;
                } else {
                    i = j;
                }
                pending_test_attr = false;
            }
            (TokKind::Ident, "trait") => {
                let name = if i + 1 < n && toks[i + 1].kind == TokKind::Ident {
                    toks[i + 1].text.clone()
                } else {
                    String::new()
                };
                let mut j = i + 1;
                while j < n && toks[j].text != "{" && toks[j].text != ";" {
                    j += 1;
                }
                if j < n && toks[j].text == "{" {
                    stack.push(Ctx::Trait { name });
                    i = j + 1;
                } else {
                    i = j + 1;
                }
                pending_test_attr = false;
            }
            (TokKind::Ident, "fn") => {
                // Guard against `fn`-pointer types: require an ident next.
                if i + 1 >= n || toks[i + 1].kind != TokKind::Ident {
                    i += 1;
                    continue;
                }
                let name = toks[i + 1].text.clone();
                let line = t.line;
                // Skip to `;` (no body) or `{` (body) at bracket-depth 0.
                // `<`/`>` are ignored here: `->` return arrows and comparison
                // operators make angle counting unreliable, and generic args
                // cannot contain `{` or `;` outside a brace-matched block.
                let mut j = i + 2;
                let mut depth = 0i32;
                while j < n {
                    match toks[j].text.as_str() {
                        "(" | "[" => depth += 1,
                        ")" | "]" => depth -= 1,
                        ";" if depth == 0 => break,
                        "{" if depth == 0 => break,
                        _ => {}
                    }
                    j += 1;
                }
                if j >= n || toks[j].text == ";" {
                    // Trait method signature without default body.
                    pending_test_attr = false;
                    i = j + 1;
                    continue;
                }
                let qname = match enclosing_ty(&stack) {
                    Some(ty) if !ty.is_empty() => format!("{ty}::{name}"),
                    _ => name.clone(),
                };
                let is_test = pending_test_attr || in_test_mod(&stack);
                pending_test_attr = false;
                out.push(FnRec {
                    file: file.to_string(),
                    qname,
                    name,
                    line,
                    end_line: 0,
                    is_test,
                    body: (j, j),
                    holes: Vec::new(),
                    allows: BTreeMap::new(),
                });
                stack.push(Ctx::Fn {
                    rec: out.len() - 1,
                });
                i = j + 1;
            }
            (TokKind::Punct, "{") => {
                stack.push(Ctx::Other);
                i += 1;
            }
            (TokKind::Punct, "}") => {
                if let Some(ctx) = stack.pop() {
                    if let Ctx::Fn { rec } = ctx {
                        out[rec].body.1 = i;
                        out[rec].end_line = t.line;
                        // Carve this fn out of the nearest enclosing fn.
                        for c in stack.iter().rev() {
                            if let Ctx::Fn { rec: outer } = c {
                                let span = out[rec].body;
                                out[*outer].holes.push(span);
                                break;
                            }
                        }
                    }
                }
                i += 1;
            }
            _ => {
                i += 1;
            }
        }
    }

    attach_pragmas(&mut out[first_rec..], comments);
}

/// Parse `orchlint: allow(class[, class…])[: justification]` comments and
/// attach them to the containing fn (comment inside a body) or, failing
/// that, the nearest fn declared at or below the comment's line.
fn attach_pragmas(recs: &mut [FnRec], comments: &[Comment]) {
    for c in comments {
        let text = c.text.trim();
        let Some(rest) = text.strip_prefix("orchlint:") else {
            continue;
        };
        let rest = rest.trim_start();
        let Some(rest) = rest.strip_prefix("allow") else {
            continue;
        };
        let rest = rest.trim_start();
        let Some(rest) = rest.strip_prefix('(') else {
            continue;
        };
        let Some(close) = rest.find(')') else {
            continue;
        };
        let classes: Vec<String> = rest[..close]
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
        let tail = rest[close + 1..].trim();
        let justification = tail.strip_prefix(':').map(|s| s.trim()).unwrap_or(tail);
        let justified = !justification.is_empty();

        // Containment first, then nearest following declaration.
        let mut target: Option<usize> = None;
        for (idx, r) in recs.iter().enumerate() {
            if r.line <= c.line && c.line <= r.end_line {
                // Innermost containing fn wins (later recs with smaller
                // spans are nested or subsequent; pick the tightest).
                match target {
                    Some(prev)
                        if recs[prev].end_line - recs[prev].line
                            <= r.end_line.saturating_sub(r.line) => {}
                    _ => target = Some(idx),
                }
            }
        }
        if target.is_none() {
            let mut best: Option<usize> = None;
            for (idx, r) in recs.iter().enumerate() {
                if r.line >= c.line {
                    match best {
                        Some(prev) if recs[prev].line <= r.line => {}
                        _ => best = Some(idx),
                    }
                }
            }
            target = best;
        }
        if let Some(idx) = target {
            for class in classes {
                let e = recs[idx].allows.entry(class).or_insert(false);
                *e = *e || justified;
            }
        }
    }
}
