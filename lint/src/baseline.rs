//! Baseline ratchet + hot-path manifest I/O.
//!
//! Both file formats are parsed with deliberately tiny scanners (no serde
//! in the offline build environment): the baseline is a JSON object whose
//! `findings` member is a sorted array of key strings, and the manifest is
//! a TOML file whose only payload is the quoted strings in its `entries`
//! array. Keys never contain quotes or backslashes, so no escape handling
//! is needed beyond rejecting such keys at write time.

use crate::analyses::Finding;
use std::collections::BTreeSet;
use std::fs;
use std::io;
use std::path::Path;

/// Parse `ci/hot_paths.toml`: every quoted string on a non-comment line is
/// an entry (`Type::method` or a bare fn name).
pub fn read_hot_paths(path: &Path) -> io::Result<Vec<String>> {
    let text = fs::read_to_string(path)?;
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.starts_with('#') {
            continue;
        }
        let mut rest = line;
        while let Some(open) = rest.find('"') {
            let tail = &rest[open + 1..];
            let Some(close) = tail.find('"') else { break };
            let s = &tail[..close];
            if !s.is_empty() {
                out.push(s.to_string());
            }
            rest = &tail[close + 1..];
        }
    }
    Ok(out)
}

/// Read the `findings` array of key strings from the baseline JSON.
pub fn read_baseline(path: &Path) -> io::Result<BTreeSet<String>> {
    let text = fs::read_to_string(path)?;
    let Some(pos) = text.find("\"findings\"") else {
        return Ok(BTreeSet::new());
    };
    let tail = &text[pos..];
    let Some(open) = tail.find('[') else {
        return Ok(BTreeSet::new());
    };
    let mut out = BTreeSet::new();
    let mut rest = &tail[open + 1..];
    loop {
        // Next string or closing bracket, whichever comes first.
        let close = rest.find(']');
        let quote = rest.find('"');
        match (quote, close) {
            (Some(q), Some(c)) if q < c => {
                let t = &rest[q + 1..];
                let Some(end) = t.find('"') else { break };
                out.insert(t[..end].to_string());
                rest = &t[end + 1..];
            }
            _ => break,
        }
    }
    Ok(out)
}

const BASELINE_HEADER: &str = r#"{
  "description": "orchlint ratchet baseline: the exact finding-key set `cargo run -p orchlint -- rust/src` must produce. CI fails on any finding absent from this list AND on any stale entry, so the list can only change deliberately. The intent is monotone shrinkage: fix a finding (or pragma-allowlist it with a justification) and delete its key here.",
  "rebaseline_procedure": "Run `cargo run -p orchlint -- rust/src --write-baseline` from the repo root and commit the diff. Additions require PR justification per key (they mean a new asymmetric collective, hot-path allocation, or panic path was introduced); deletions are always welcome.",
"#;

/// Write the baseline file: fixed header + sorted key array.
pub fn write_baseline(path: &Path, findings: &[Finding]) -> io::Result<()> {
    let mut s = String::from(BASELINE_HEADER);
    s.push_str("  \"findings\": [\n");
    for (i, f) in findings.iter().enumerate() {
        assert!(
            !f.key.contains('"') && !f.key.contains('\\'),
            "finding key needs escaping: {}",
            f.key
        );
        s.push_str("    \"");
        s.push_str(&f.key);
        s.push('"');
        if i + 1 < findings.len() {
            s.push(',');
        }
        s.push('\n');
    }
    s.push_str("  ]\n}\n");
    fs::write(path, s)
}

/// Write the full findings report (keys + advisory line numbers).
pub fn write_report(path: &Path, root: &str, findings: &[Finding]) -> io::Result<()> {
    let mut s = String::new();
    s.push_str("{\n  \"version\": 1,\n");
    s.push_str(&format!("  \"root\": \"{root}\",\n"));
    s.push_str(&format!("  \"total\": {},\n", findings.len()));
    s.push_str("  \"findings\": [\n");
    for (i, f) in findings.iter().enumerate() {
        let lines: Vec<String> = f.lines.iter().map(|l| l.to_string()).collect();
        s.push_str(&format!(
            "    {{\"key\": \"{}\", \"class\": \"{}\", \"file\": \"{}\", \"function\": \"{}\", \"detail\": \"{}\", \"lines\": [{}]}}",
            f.key,
            f.class,
            f.file,
            f.function,
            f.detail,
            lines.join(", ")
        ));
        if i + 1 < findings.len() {
            s.push(',');
        }
        s.push('\n');
    }
    s.push_str("  ]\n}\n");
    fs::write(path, s)
}
