"""L2: the tiny-MLLM compute graph in JAX (build-time only).

Mirrors the paper's MLLM structure (§2.1): a vision encoder, an audio
encoder (conv front-end + transformer, the "ConvTransformer" of App. A),
MLP connectors into the LLM embedding space, and a causal LLM backbone.
Every submodule's attention runs through the L1 Pallas flash-attention
kernel so the whole stack lowers into one HLO dialect.

The model is *phase-split* exactly the way the rust orchestrator needs it:

  vision_fwd   (vis_params, patches, mask)            -> vis_tokens
  audio_fwd    (aud_params, frames, mask)             -> aud_tokens
  llm_step     (llm_params, token_ids, vis_tokens, vis_pos,
                aud_tokens, aud_pos, targets, loss_mask)
               -> (loss_sum, token_count, d_vis_tokens, d_aud_tokens,
                   *llm_grads)
  vision_bwd   (vis_params, patches, mask, d_out)     -> *vis_grads
  audio_bwd    (aud_params, frames, mask, d_out)      -> *aud_grads
  sgd_<sub>    (step_scale, *params, *grads)          -> *new_params

Subsequence assembly (§6 of the paper) is expressed as a scatter: the
rust coordinator ships encoder-output buffers between DP instances with
its All-to-All engine and hands the LLM phase per-example *position
tables* (vis_pos/aud_pos, -1 = inactive slot); the scatter into the
embedding sequence — and its transposed gather in the backward pass —
live in HLO. Losses and gradients are SUMS over valid tokens, so a later
all-reduce + global 1/token_count rescale makes training bit-for-bit
invariant under any cross-instance rearrangement Π (§3.3).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels.attention import flash_attention, fused_layernorm


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters for one tiny-MLLM variant."""

    name: str = "test"
    # LLM backbone
    vocab: int = 256
    d_llm: int = 64
    llm_layers: int = 2
    llm_heads: int = 2
    llm_ffn: int = 128
    max_seq: int = 128
    # Vision encoder (patch transformer, no-padding batching in the paper)
    patch_dim: int = 48
    d_vis: int = 32
    vis_layers: int = 1
    vis_heads: int = 2
    vis_ffn: int = 64
    vis_group: int = 2  # downsample: group r patches -> 1 LLM token
    max_vis: int = 64
    # Audio encoder (conv front-end + transformer, padded batching)
    mel_dim: int = 40
    d_aud: int = 32
    aud_layers: int = 1
    aud_heads: int = 2
    aud_ffn: int = 64
    aud_stride: int = 2  # conv downsample: r frames -> 1 LLM token
    max_aud: int = 64


CONFIGS: Dict[str, ModelConfig] = {
    # Fast config for pytest and rust integration tests.
    "test": ModelConfig(),
    # ~25M params: default for the end-to-end training example on CPU.
    "e2e-small": ModelConfig(
        name="e2e-small",
        vocab=4096,
        d_llm=384,
        llm_layers=6,
        llm_heads=6,
        llm_ffn=1536,
        max_seq=256,
        patch_dim=96,
        d_vis=128,
        vis_layers=2,
        vis_heads=4,
        vis_ffn=512,
        max_vis=128,
        mel_dim=80,
        d_aud=128,
        aud_layers=2,
        aud_heads=4,
        aud_ffn=512,
        max_aud=128,
    ),
    # ~95M params: the "~100M transformer" end-to-end validation target.
    "e2e-100m": ModelConfig(
        name="e2e-100m",
        vocab=8192,
        d_llm=768,
        llm_layers=10,
        llm_heads=12,
        llm_ffn=3072,
        max_seq=256,
        patch_dim=96,
        d_vis=256,
        vis_layers=4,
        vis_heads=8,
        vis_ffn=1024,
        max_vis=128,
        mel_dim=80,
        d_aud=256,
        aud_layers=4,
        aud_heads=8,
        aud_ffn=1024,
        max_aud=128,
    ),
}


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------


def _dense_init(key, shape, scale=0.02):
    return (scale * jax.random.normal(key, shape)).astype(jnp.float32)


def _init_block_stack(key, n_layers, d, ffn):
    """Stacked (scan-ready) transformer block params: leading axis = layer."""
    ks = jax.random.split(key, 8)

    def stack(k, shape):
        return _dense_init(k, (n_layers,) + shape)

    return {
        "ln1_g": jnp.ones((n_layers, d), jnp.float32),
        "ln1_b": jnp.zeros((n_layers, d), jnp.float32),
        "wqkv": stack(ks[0], (d, 3 * d)),
        "wo": stack(ks[1], (d, d)),
        "ln2_g": jnp.ones((n_layers, d), jnp.float32),
        "ln2_b": jnp.zeros((n_layers, d), jnp.float32),
        "w1": stack(ks[2], (d, ffn)),
        "b1": jnp.zeros((n_layers, ffn), jnp.float32),
        "w2": stack(ks[3], (ffn, d)),
        "b2": jnp.zeros((n_layers, d), jnp.float32),
    }


def init_vision_params(key, cfg: ModelConfig):
    ks = jax.random.split(key, 5)
    return {
        "proj": _dense_init(ks[0], (cfg.patch_dim, cfg.d_vis)),
        "pos": _dense_init(ks[1], (cfg.max_vis, cfg.d_vis)),
        "blocks": _init_block_stack(ks[2], cfg.vis_layers, cfg.d_vis, cfg.vis_ffn),
        "lnf_g": jnp.ones((cfg.d_vis,), jnp.float32),
        "lnf_b": jnp.zeros((cfg.d_vis,), jnp.float32),
        # connector: grouped patches -> LLM embedding space (2-layer MLP)
        "c_w1": _dense_init(ks[3], (cfg.vis_group * cfg.d_vis, cfg.d_llm)),
        "c_b1": jnp.zeros((cfg.d_llm,), jnp.float32),
        "c_w2": _dense_init(ks[4], (cfg.d_llm, cfg.d_llm)),
        "c_b2": jnp.zeros((cfg.d_llm,), jnp.float32),
    }


def init_audio_params(key, cfg: ModelConfig):
    ks = jax.random.split(key, 5)
    return {
        # conv front-end: width-3 stride-r conv over mel frames
        "conv_w": _dense_init(ks[0], (3, cfg.mel_dim, cfg.d_aud)),
        "conv_b": jnp.zeros((cfg.d_aud,), jnp.float32),
        "pos": _dense_init(ks[1], (cfg.max_aud, cfg.d_aud)),
        "blocks": _init_block_stack(ks[2], cfg.aud_layers, cfg.d_aud, cfg.aud_ffn),
        "lnf_g": jnp.ones((cfg.d_aud,), jnp.float32),
        "lnf_b": jnp.zeros((cfg.d_aud,), jnp.float32),
        "c_w1": _dense_init(ks[3], (cfg.d_aud, cfg.d_llm)),
        "c_b1": jnp.zeros((cfg.d_llm,), jnp.float32),
        "c_w2": _dense_init(ks[4], (cfg.d_llm, cfg.d_llm)),
        "c_b2": jnp.zeros((cfg.d_llm,), jnp.float32),
    }


def init_llm_params(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    return {
        "embed": _dense_init(ks[0], (cfg.vocab, cfg.d_llm)),
        "pos": _dense_init(ks[1], (cfg.max_seq, cfg.d_llm)),
        "blocks": _init_block_stack(ks[2], cfg.llm_layers, cfg.d_llm, cfg.llm_ffn),
        "lnf_g": jnp.ones((cfg.d_llm,), jnp.float32),
        "lnf_b": jnp.zeros((cfg.d_llm,), jnp.float32),
        "head": _dense_init(ks[3], (cfg.d_llm, cfg.vocab)),
    }


def init_all_params(seed: int, cfg: ModelConfig):
    key = jax.random.PRNGKey(seed)
    kv, ka, kl = jax.random.split(key, 3)
    return {
        "vision": init_vision_params(kv, cfg),
        "audio": init_audio_params(ka, cfg),
        "llm": init_llm_params(kl, cfg),
    }


def param_count(params) -> int:
    return sum(int(p.size) for p in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# Transformer trunk (shared by all submodules; scan over stacked layers)
# ---------------------------------------------------------------------------


def _transformer_trunk(blocks, x, mask, n_heads: int, causal: bool):
    """Pre-LN transformer over stacked layer params via lax.scan.

    x: [B, L, D]; mask: [B, L] key-validity; returns [B, L, D].
    """
    b, l, d = x.shape
    hd = d // n_heads

    def layer(h, lp):
        y = fused_layernorm(h, lp["ln1_g"], lp["ln1_b"])
        qkv = y @ lp["wqkv"]  # [B, L, 3D]
        q, k, v = jnp.split(qkv, 3, axis=-1)

        def heads(t):
            return t.reshape(b, l, n_heads, hd).transpose(0, 2, 1, 3)

        attn = flash_attention(
            heads(q), heads(k), heads(v), mask=mask, causal=causal
        )
        attn = attn.transpose(0, 2, 1, 3).reshape(b, l, d)
        h = h + attn @ lp["wo"]
        y = fused_layernorm(h, lp["ln2_g"], lp["ln2_b"])
        y = jax.nn.gelu(y @ lp["w1"] + lp["b1"]) @ lp["w2"] + lp["b2"]
        return h + y, None

    out, _ = jax.lax.scan(layer, x, blocks)
    return out


# ---------------------------------------------------------------------------
# Phase functions
# ---------------------------------------------------------------------------


def vision_encode(params, patches, mask, cfg: ModelConfig):
    """Vision phase: [B, Lp, patch_dim] patches -> [B, Lp/r, d_llm] tokens."""
    b, lp, _ = patches.shape
    x = patches @ params["proj"] + params["pos"][:lp][None]
    x = _transformer_trunk(
        params["blocks"], x, mask, cfg.vis_heads, causal=False
    )
    x = fused_layernorm(x, params["lnf_g"], params["lnf_b"])
    r = cfg.vis_group
    g = x.reshape(b, lp // r, r * cfg.d_vis)
    h = jax.nn.gelu(g @ params["c_w1"] + params["c_b1"])
    return h @ params["c_w2"] + params["c_b2"]


def audio_encode(params, frames, mask, cfg: ModelConfig):
    """Audio phase: [B, Lf, mel] frames -> [B, Lf/r, d_llm] tokens.

    Conv front-end forces padded batching for this phase (paper §8
    "Input preprocessing"), which is why its dispatcher uses the padded
    post-balancing algorithm.
    """
    b, lf, _ = frames.shape
    r = cfg.aud_stride
    x = jax.lax.conv_general_dilated(
        frames,
        params["conv_w"],
        window_strides=(r,),
        padding="SAME",
        dimension_numbers=("NWC", "WIO", "NWC"),
    ) + params["conv_b"]
    lt = lf // r
    dmask = mask[:, ::r]
    x = x + params["pos"][:lt][None]
    x = _transformer_trunk(
        params["blocks"], x, dmask, cfg.aud_heads, causal=False
    )
    x = fused_layernorm(x, params["lnf_g"], params["lnf_b"])
    h = jax.nn.gelu(x @ params["c_w1"] + params["c_b1"])
    return h @ params["c_w2"] + params["c_b2"]


def _scatter_tokens(base, tokens, pos):
    """Scatter encoder tokens into the embedding sequence.

    base:   [B, L, D] text-token embeddings.
    tokens: [B, T, D] encoder output tokens.
    pos:    [B, T] destination index in [0, L), or -1 for inactive slots.

    Inactive slots scatter to a dump row (index L) that is sliced off, so
    the op stays static-shaped, and its VJP is the matching gather.
    """
    b, l, d = base.shape
    padded = jnp.concatenate([base, jnp.zeros((b, 1, d), base.dtype)], axis=1)
    safe_pos = jnp.where(pos >= 0, pos, l)
    upd = jax.vmap(
        lambda buf, tok, idx: buf.at[idx].set(tok)
    )(padded, tokens, safe_pos)
    return upd[:, :l, :]


def llm_forward(
    params,
    token_ids,
    vis_tokens,
    vis_pos,
    aud_tokens,
    aud_pos,
    targets,
    loss_mask,
    cfg: ModelConfig,
):
    """LLM phase: assemble interleaved sequence, run backbone, sum CE loss.

    Returns (loss_sum, token_count); loss is a SUM over valid target
    positions so that the downstream DP all-reduce is rearrangement-
    invariant (the paper's consequence-invariance, §3.3).
    """
    b, l = token_ids.shape
    base = params["embed"][token_ids]  # [B, L, D]
    base = _scatter_tokens(base, vis_tokens, vis_pos)
    base = _scatter_tokens(base, aud_tokens, aud_pos)
    x = base + params["pos"][:l][None]
    seq_mask = (loss_mask > -1).astype(jnp.int32)  # all slots valid unless
    # the caller marks a slot as hard padding with loss_mask == -1.
    x = _transformer_trunk(
        params["blocks"], x, seq_mask, cfg.llm_heads, causal=True
    )
    x = fused_layernorm(x, params["lnf_g"], params["lnf_b"])
    logits = x @ params["head"]  # [B, L, V]
    logp = jax.nn.log_softmax(logits, axis=-1)
    tgt = jnp.clip(targets, 0, cfg.vocab - 1)
    picked = jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    lmask = (loss_mask > 0).astype(jnp.float32)
    loss_sum = -jnp.sum(picked * lmask)
    token_count = jnp.sum(lmask)
    return loss_sum, token_count


def make_llm_step(cfg: ModelConfig):
    """llm_step: loss + grads wrt (params, vis_tokens, aud_tokens)."""

    def step_fixed(params, token_ids, vis_tokens, vis_pos, aud_tokens,
                   aud_pos, targets, loss_mask):
        def loss_fn(p, vt, at):
            ls, tc = llm_forward(
                p, token_ids, vt, vis_pos, at, aud_pos, targets, loss_mask,
                cfg,
            )
            return ls, tc

        (loss_sum, token_count), (pgrads, d_vis, d_aud) = (
            jax.value_and_grad(loss_fn, argnums=(0, 1, 2), has_aux=True)(
                params, vis_tokens, aud_tokens
            )
        )
        return loss_sum, token_count, d_vis, d_aud, pgrads

    return step_fixed


def make_vision_bwd(cfg: ModelConfig):
    def bwd(params, patches, mask, d_out):
        _, vjp = jax.vjp(
            lambda p: vision_encode(p, patches, mask, cfg), params
        )
        return vjp(d_out)[0]

    return bwd


def make_audio_bwd(cfg: ModelConfig):
    def bwd(params, frames, mask, d_out):
        _, vjp = jax.vjp(
            lambda p: audio_encode(p, frames, mask, cfg), params
        )
        return vjp(d_out)[0]

    return bwd


def make_sgd():
    """SGD: p <- p - step_scale * g, step_scale = lr / global_token_count."""

    def sgd(step_scale, params, grads):
        return jax.tree_util.tree_map(
            lambda p, g: p - step_scale * g, params, grads
        )

    return sgd


# ---------------------------------------------------------------------------
# Flattening helpers (deterministic parameter ordering for the rust side)
# ---------------------------------------------------------------------------


def flatten_params(params) -> Tuple[List[Any], List[str], Any]:
    """Flatten a param pytree into (leaves, dotted-path names, treedef)."""
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(params)
    names = []
    leaves = []
    for path, leaf in leaves_with_path:
        parts = []
        for entry in path:
            if hasattr(entry, "key"):
                parts.append(str(entry.key))
            else:
                parts.append(str(entry))
        names.append(".".join(parts))
        leaves.append(leaf)
    return leaves, names, treedef


def unflatten_params(treedef, leaves):
    return jax.tree_util.tree_unflatten(treedef, leaves)
