"""AOT compile path: lower every phase function to HLO *text* artifacts.

Python runs exactly once (``make artifacts``); afterwards the rust
coordinator is self-contained. The interchange format is HLO text — NOT
a serialized ``HloModuleProto`` — because jax >= 0.5 emits protos with
64-bit instruction ids that the xla crate's xla_extension 0.5.1 rejects;
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Per model config this emits, under ``artifacts/<config>/``:

  vision_fwd_{B}x{L}.hlo.txt     (vis_params, patches, mask) -> vis_tokens
  vision_bwd_{B}x{L}.hlo.txt     (vis_params, patches, mask, d_out) -> grads
  audio_fwd_{B}x{L}.hlo.txt      (aud_params, frames, mask) -> aud_tokens
  audio_bwd_{B}x{L}.hlo.txt      (aud_params, frames, mask, d_out) -> grads
  llm_step_{B}x{L}x{Tv}x{Ta}.hlo.txt
      (llm_params, token_ids, vis_tokens, vis_pos, aud_tokens, aud_pos,
       targets, loss_mask)
      -> (loss_sum, token_count, d_vis_tokens, d_aud_tokens, *llm_grads)
  sgd_{vision,audio,llm}.hlo.txt (step_scale, *params, *grads) -> *params'
  params/{sub}/{iii}.bin         initial parameters, raw f32 LE
  manifest.json                  shapes/dtypes/ordering contract for rust

Buckets: XLA AOT requires static shapes, so each phase is lowered at a
small set of (batch, seq) buckets; the rust trainer packs rearranged
mini-batches into the smallest fitting bucket (DESIGN.md §6).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec_like(tree):
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
    )


def _dtype_name(dt) -> str:
    return {"float32": "f32", "int32": "i32"}[np.dtype(dt).name]


def _tensor_entry(role: str, arr) -> Dict:
    return {
        "role": role,
        "shape": [int(s) for s in arr.shape],
        "dtype": _dtype_name(arr.dtype),
    }


def _param_entries(sub: str, names: List[str], leaves) -> List[Dict]:
    out = []
    for i, (n, leaf) in enumerate(zip(names, leaves)):
        out.append(
            {
                "name": n,
                "shape": [int(s) for s in leaf.shape],
                "dtype": _dtype_name(leaf.dtype),
                "file": f"params/{sub}/{i:03d}.bin",
            }
        )
    return out


def _write_params(out_dir: str, sub: str, leaves) -> None:
    d = os.path.join(out_dir, "params", sub)
    os.makedirs(d, exist_ok=True)
    for i, leaf in enumerate(leaves):
        np.asarray(leaf, dtype=np.float32).tofile(
            os.path.join(d, f"{i:03d}.bin")
        )


def _lower(fn, *args) -> str:
    # keep_unused=True: the rust side feeds every manifest slot, so the
    # compiled signature must keep arguments even when a gradient graph
    # does not read them (e.g. biases whose VJP ignores the primal).
    return to_hlo_text(jax.jit(fn, keep_unused=True).lower(*args))


def parse_buckets(spec: str) -> List[Tuple[int, ...]]:
    """Parse '4x16,8x32' into [(4, 16), (8, 32)]."""
    out = []
    for part in spec.split(","):
        out.append(tuple(int(x) for x in part.strip().split("x")))
    return out


DEFAULT_BUCKETS = {
    # phase -> bucket list; llm buckets are (B, L, Tv, Ta).
    "test": {
        "vision": [(4, 16)],
        "audio": [(4, 16)],
        "llm": [(4, 48, 8, 8)],
    },
    "e2e-small": {
        "vision": [(4, 32), (8, 64)],
        "audio": [(4, 32), (8, 64)],
        "llm": [(4, 128, 24, 24), (8, 160, 32, 32)],
    },
    "e2e-100m": {
        "vision": [(4, 64)],
        "audio": [(4, 64)],
        "llm": [(4, 160, 32, 32)],
    },
}


def build(config_name: str, out_root: str, seed: int,
          buckets: Dict[str, List[Tuple[int, ...]]] | None = None) -> str:
    cfg = M.CONFIGS[config_name]
    buckets = buckets or DEFAULT_BUCKETS[config_name]
    out_dir = os.path.join(out_root, config_name)
    os.makedirs(out_dir, exist_ok=True)

    params = M.init_all_params(seed, cfg)
    manifest: Dict = {
        "config": {
            "name": cfg.name,
            "vocab": cfg.vocab,
            "d_llm": cfg.d_llm,
            "llm_layers": cfg.llm_layers,
            "llm_heads": cfg.llm_heads,
            "llm_ffn": cfg.llm_ffn,
            "max_seq": cfg.max_seq,
            "patch_dim": cfg.patch_dim,
            "d_vis": cfg.d_vis,
            "vis_layers": cfg.vis_layers,
            "vis_group": cfg.vis_group,
            "max_vis": cfg.max_vis,
            "mel_dim": cfg.mel_dim,
            "d_aud": cfg.d_aud,
            "aud_layers": cfg.aud_layers,
            "aud_stride": cfg.aud_stride,
            "max_aud": cfg.max_aud,
            "param_count": int(M.param_count(params)),
            "seed": seed,
        },
        "params": {},
        "artifacts": [],
    }

    for sub in ("vision", "audio", "llm"):
        leaves, names, _ = M.flatten_params(params[sub])
        manifest["params"][sub] = _param_entries(sub, names, leaves)
        _write_params(out_dir, sub, leaves)

    def emit(name: str, text: str, inputs: List[Dict], outputs: List[Dict],
             bucket: List[int]) -> None:
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "name": name,
                "file": fname,
                "bucket": bucket,
                "inputs": inputs,
                "outputs": outputs,
            }
        )
        print(f"  wrote {fname} ({len(text) / 1e6:.2f} MB)")

    vp_spec = spec_like(params["vision"])
    ap_spec = spec_like(params["audio"])
    lp_spec = spec_like(params["llm"])

    # ---- vision phase -----------------------------------------------------
    for (b, lp) in buckets["vision"]:
        patches = jax.ShapeDtypeStruct((b, lp, cfg.patch_dim), jnp.float32)
        mask = jax.ShapeDtypeStruct((b, lp), jnp.int32)
        tv = lp // cfg.vis_group
        d_out = jax.ShapeDtypeStruct((b, tv, cfg.d_llm), jnp.float32)

        fwd = lambda p, x, m: (M.vision_encode(p, x, m, cfg),)
        emit(
            f"vision_fwd_{b}x{lp}",
            _lower(fwd, vp_spec, patches, mask),
            [{"kind": "params", "sub": "vision"},
             _tensor_entry("patches", patches),
             _tensor_entry("mask", mask)],
            [{"role": "vis_tokens", "shape": [b, tv, cfg.d_llm],
              "dtype": "f32"}],
            [b, lp],
        )
        bwd_fn = M.make_vision_bwd(cfg)
        bwd = lambda p, x, m, d: (bwd_fn(p, x, m, d),)
        emit(
            f"vision_bwd_{b}x{lp}",
            _lower(bwd, vp_spec, patches, mask, d_out),
            [{"kind": "params", "sub": "vision"},
             _tensor_entry("patches", patches),
             _tensor_entry("mask", mask),
             _tensor_entry("d_out", d_out)],
            [{"kind": "grads", "sub": "vision"}],
            [b, lp],
        )

    # ---- audio phase ------------------------------------------------------
    for (b, lf) in buckets["audio"]:
        frames = jax.ShapeDtypeStruct((b, lf, cfg.mel_dim), jnp.float32)
        mask = jax.ShapeDtypeStruct((b, lf), jnp.int32)
        ta = lf // cfg.aud_stride
        d_out = jax.ShapeDtypeStruct((b, ta, cfg.d_llm), jnp.float32)

        fwd = lambda p, x, m: (M.audio_encode(p, x, m, cfg),)
        emit(
            f"audio_fwd_{b}x{lf}",
            _lower(fwd, ap_spec, frames, mask),
            [{"kind": "params", "sub": "audio"},
             _tensor_entry("frames", frames),
             _tensor_entry("mask", mask)],
            [{"role": "aud_tokens", "shape": [b, ta, cfg.d_llm],
              "dtype": "f32"}],
            [b, lf],
        )
        bwd_fn = M.make_audio_bwd(cfg)
        bwd = lambda p, x, m, d: (bwd_fn(p, x, m, d),)
        emit(
            f"audio_bwd_{b}x{lf}",
            _lower(bwd, ap_spec, frames, mask, d_out),
            [{"kind": "params", "sub": "audio"},
             _tensor_entry("frames", frames),
             _tensor_entry("mask", mask),
             _tensor_entry("d_out", d_out)],
            [{"kind": "grads", "sub": "audio"}],
            [b, lf],
        )

    # ---- LLM phase ----------------------------------------------------------
    step_fn = M.make_llm_step(cfg)
    for (b, l, tv, ta) in buckets["llm"]:
        token_ids = jax.ShapeDtypeStruct((b, l), jnp.int32)
        vis_tokens = jax.ShapeDtypeStruct((b, tv, cfg.d_llm), jnp.float32)
        vis_pos = jax.ShapeDtypeStruct((b, tv), jnp.int32)
        aud_tokens = jax.ShapeDtypeStruct((b, ta, cfg.d_llm), jnp.float32)
        aud_pos = jax.ShapeDtypeStruct((b, ta), jnp.int32)
        targets = jax.ShapeDtypeStruct((b, l), jnp.int32)
        loss_mask = jax.ShapeDtypeStruct((b, l), jnp.int32)

        def llm_flat(p, tok, vt, vp, at, ap, tgt, lm):
            loss, cnt, d_vis, d_aud, grads = step_fn(
                p, tok, vt, vp, at, ap, tgt, lm
            )
            return (loss, cnt, d_vis, d_aud, grads)

        emit(
            f"llm_step_{b}x{l}x{tv}x{ta}",
            _lower(llm_flat, lp_spec, token_ids, vis_tokens, vis_pos,
                   aud_tokens, aud_pos, targets, loss_mask),
            [{"kind": "params", "sub": "llm"},
             _tensor_entry("token_ids", token_ids),
             _tensor_entry("vis_tokens", vis_tokens),
             _tensor_entry("vis_pos", vis_pos),
             _tensor_entry("aud_tokens", aud_tokens),
             _tensor_entry("aud_pos", aud_pos),
             _tensor_entry("targets", targets),
             _tensor_entry("loss_mask", loss_mask)],
            [{"role": "loss_sum", "shape": [], "dtype": "f32"},
             {"role": "token_count", "shape": [], "dtype": "f32"},
             {"role": "d_vis_tokens", "shape": [b, tv, cfg.d_llm],
              "dtype": "f32"},
             {"role": "d_aud_tokens", "shape": [b, ta, cfg.d_llm],
              "dtype": "f32"},
             {"kind": "grads", "sub": "llm"}],
            [b, l, tv, ta],
        )

    # ---- optimizer ----------------------------------------------------------
    sgd = M.make_sgd()
    for sub in ("vision", "audio", "llm"):
        p_spec = spec_like(params[sub])
        scale = jax.ShapeDtypeStruct((), jnp.float32)

        def sgd_flat(s, p, g):
            return (sgd(s, p, g),)

        emit(
            f"sgd_{sub}",
            _lower(sgd_flat, scale, p_spec, p_spec),
            [_tensor_entry("step_scale", scale),
             {"kind": "params", "sub": sub},
             {"kind": "grads", "sub": sub}],
            [{"kind": "params", "sub": sub}],
            [],
        )

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  wrote manifest.json ({len(manifest['artifacts'])} artifacts)")
    return out_dir


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifact root directory")
    ap.add_argument("--config", default="test",
                    choices=sorted(M.CONFIGS.keys()))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--vision-buckets", default=None,
                    help="e.g. '4x16,8x32' (BxL)")
    ap.add_argument("--audio-buckets", default=None)
    ap.add_argument("--llm-buckets", default=None,
                    help="e.g. '4x48x8x8' (BxLxTvxTa)")
    args = ap.parse_args()

    buckets = dict(DEFAULT_BUCKETS[args.config])
    if args.vision_buckets:
        buckets["vision"] = parse_buckets(args.vision_buckets)
    if args.audio_buckets:
        buckets["audio"] = parse_buckets(args.audio_buckets)
    if args.llm_buckets:
        buckets["llm"] = parse_buckets(args.llm_buckets)

    print(f"AOT build: config={args.config} -> {args.out}/{args.config}")
    build(args.config, args.out, args.seed, buckets)


if __name__ == "__main__":
    main()
