"""L1 Pallas kernels: flash-style fused attention and fused LayerNorm.

The compute hot-spot of every OrchMLLM phase (vision encoder, audio
encoder, LLM backbone) is transformer attention. The paper's clusters run
it as a CUDA flash-attention kernel; here it is re-thought for TPU as a
Pallas kernel (see DESIGN.md §Hardware-Adaptation):

  * the CUDA threadblock tiling over queries becomes the Pallas grid over
    (batch*heads, query blocks) with a BlockSpec that stages one query
    block in VMEM;
  * the shared-memory K/V staging becomes an in-kernel ``fori_loop`` over
    key blocks (``pl.ds`` slices), i.e. the HBM->VMEM stream that Mosaic
    double-buffers on a real TPU;
  * warp-level online softmax becomes f32 (m, l, acc) carries;
  * WMMA tiles become MXU-shaped block matmuls (block x head_dim).

Kernels are lowered with ``interpret=True`` so the resulting HLO runs on
the CPU PJRT plugin (real-TPU lowering emits a Mosaic custom-call the CPU
client cannot execute). Correctness vs. ``ref.py`` is enforced by
``python/tests/test_kernel.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_INF = -1e30


def _flash_attention_kernel(
    q_ref,
    k_ref,
    v_ref,
    mask_ref,
    o_ref,
    *,
    scale: float,
    block_k: int,
    seq_len_k: int,
    block_q: int,
    causal: bool,
):
    """One grid step: one query block vs. all key blocks (online softmax).

    Ref shapes (leading grid-mapped axis already sliced away by BlockSpec):
      q_ref:    [1, block_q, d]
      k_ref:    [1, Lk, d]      (streamed block-wise below)
      v_ref:    [1, Lk, d]
      mask_ref: [1, Lk]         int32 key-validity (1 = valid)
      o_ref:    [1, block_q, d]
    """
    q = q_ref[0].astype(jnp.float32) * scale  # [bq, d]
    d = q.shape[-1]
    num_k_blocks = seq_len_k // block_k
    q_block_idx = pl.program_id(1)
    row_ids = q_block_idx * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0
    )

    def body(i, carry):
        m_prev, l_prev, acc_prev = carry
        k_blk = k_ref[0, pl.ds(i * block_k, block_k), :].astype(jnp.float32)
        v_blk = v_ref[0, pl.ds(i * block_k, block_k), :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q,
            k_blk,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # [bq, bk]
        valid = mask_ref[0, pl.ds(i * block_k, block_k)] > 0  # [bk]
        s = jnp.where(valid[None, :], s, _NEG_INF)
        if causal:
            col_ids = i * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1
            )
            s = jnp.where(col_ids <= row_ids, s, _NEG_INF)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_cur = l_prev * alpha + jnp.sum(p, axis=-1)
        acc_cur = acc_prev * alpha[:, None] + jax.lax.dot_general(
            p,
            v_blk,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return m_cur, l_cur, acc_cur

    m0 = jnp.full((block_q,), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc0 = jnp.zeros((block_q, d), jnp.float32)
    _, l, acc = jax.lax.fori_loop(0, num_k_blocks, body, (m0, l0, acc0))
    o_ref[0] = (acc / (l[:, None] + 1e-30)).astype(o_ref.dtype)


def _pad_to(x, axis, multiple, value=0.0):
    size = x.shape[axis]
    target = ((size + multiple - 1) // multiple) * multiple
    if target == size:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, target - size)
    return jnp.pad(x, widths, constant_values=value)


def _attention_bwd_math(q, k, v, mask, do, *, causal: bool, scale: float):
    """Closed-form attention backward (the flash-attention bwd recurrence
    collapsed to full matrices — exact at these model scales).

    Runs in f32 and lowers into the same HLO module as the Pallas forward,
    so the rust runtime never sees a custom-call.
    """
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    dof = do.astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * scale
    lq, lk = s.shape[-2], s.shape[-1]
    if mask is not None:
        s = jnp.where(mask[:, None, None, :] > 0, s, _NEG_INF)
    if causal:
        causal_m = jnp.tril(jnp.ones((lq, lk), jnp.bool_), k=lk - lq)
        s = jnp.where(causal_m[None, None], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    dv = jnp.einsum("bhqk,bhqd->bhkd", p, dof)
    dp = jnp.einsum("bhqd,bhkd->bhqk", dof, vf)
    delta = jnp.sum(dp * p, axis=-1, keepdims=True)
    ds = p * (dp - delta) * scale
    dq = jnp.einsum("bhqk,bhkd->bhqd", ds, kf).astype(q.dtype)
    dk = jnp.einsum("bhqk,bhqd->bhkd", ds, qf).astype(k.dtype)
    return dq, dk, dv.astype(v.dtype)


def _flash_forward_impl(q, k, v, mask, causal, scale, block_q, block_k,
                        interpret):
    """Pallas forward pass over already-validated [B, H, L, D] tensors."""
    b, h, lq, d = q.shape
    lk = k.shape[2]
    block_q = min(block_q, lq)
    block_k = min(block_k, lk)

    # Pad sequence axes to block multiples; padded keys are masked out and
    # padded query rows are sliced off below.
    qp = _pad_to(q, 2, block_q)
    kp = _pad_to(k, 2, block_k)
    vp = _pad_to(v, 2, block_k)
    maskp = _pad_to(mask, 1, block_k, value=0)
    lq_p, lk_p = qp.shape[2], kp.shape[2]

    # Collapse (B, H) into one grid-mapped axis.
    qf = qp.reshape(b * h, lq_p, d)
    kf = kp.reshape(b * h, lk_p, d)
    vf = vp.reshape(b * h, lk_p, d)

    grid = (b * h, lq_p // block_q)
    kernel = functools.partial(
        _flash_attention_kernel,
        scale=scale,
        block_k=block_k,
        seq_len_k=lk_p,
        block_q=block_q,
        causal=causal,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, iq: (bh, iq, 0)),
            pl.BlockSpec((1, lk_p, d), lambda bh, iq: (bh, 0, 0)),
            pl.BlockSpec((1, lk_p, d), lambda bh, iq: (bh, 0, 0)),
            pl.BlockSpec((1, lk_p), lambda bh, iq: (bh // h, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, iq: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, lq_p, d), q.dtype),
        interpret=interpret,
    )(qf, kf, vf, mask)
    return out.reshape(b, h, lq_p, d)[:, :, :lq, :]


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def _flash_attention_vjp(q, k, v, mask, causal, scale, block_q, block_k,
                         interpret):
    return _flash_forward_impl(q, k, v, mask, causal, scale, block_q,
                               block_k, interpret)


def _flash_vjp_fwd(q, k, v, mask, causal, scale, block_q, block_k,
                   interpret):
    out = _flash_forward_impl(q, k, v, mask, causal, scale, block_q,
                              block_k, interpret)
    return out, (q, k, v, mask)


def _flash_vjp_bwd(causal, scale, block_q, block_k, interpret, res, do):
    import numpy as np

    q, k, v, mask = res
    dq, dk, dv = _attention_bwd_math(q, k, v, mask, do, causal=causal,
                                     scale=scale)
    dmask = np.zeros(mask.shape, dtype=jax.dtypes.float0)
    return dq, dk, dv, dmask


_flash_attention_vjp.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def flash_attention(
    q,
    k,
    v,
    mask=None,
    causal: bool = False,
    scale=None,
    block_q: int = 64,
    block_k: int = 64,
    interpret: bool = True,
):
    """Flash-style fused attention via Pallas (differentiable).

    Args:
      q, k, v: [B, H, L, D] (Lq == Lk required when ``causal``).
      mask: optional [B, Lk] int key-validity mask (1 = valid). Padding
        keys contribute no attention weight.
      causal: apply a causal (lower-triangular) mask.
      scale: softmax scale, default 1/sqrt(D).
      block_q, block_k: VMEM tile sizes (clamped to the sequence length).
      interpret: must stay True for CPU-PJRT execution (see module doc).

    Returns:
      [B, H, Lq, D] attention output in q's dtype. Reverse-mode autodiff
      is provided by a custom VJP (``_attention_bwd_math``).
    """
    b, h, lq, d = q.shape
    lk = k.shape[2]
    if causal and lq != lk:
        raise ValueError("causal flash_attention requires Lq == Lk")
    if scale is None:
        scale = float(1.0 / (d**0.5))
    if mask is None:
        mask = jnp.ones((b, lk), jnp.int32)
    mask = mask.astype(jnp.int32)
    return _flash_attention_vjp(q, k, v, mask, bool(causal), float(scale),
                                int(block_q), int(block_k), bool(interpret))


def _layernorm_kernel(x_ref, gamma_ref, beta_ref, o_ref, *, eps: float):
    """Fused LayerNorm over the last axis for one row block."""
    x = x_ref[...].astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    g = gamma_ref[...].astype(jnp.float32)
    bta = beta_ref[...].astype(jnp.float32)
    o_ref[...] = (y * g[None, :] + bta[None, :]).astype(o_ref.dtype)


def _layernorm_forward_impl(x, gamma, beta, eps, block_rows, interpret):
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    xf = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    xp = _pad_to(xf, 0, block_rows)
    rows_p = xp.shape[0]
    out = pl.pallas_call(
        functools.partial(_layernorm_kernel, eps=eps),
        grid=(rows_p // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows_p, d), x.dtype),
        interpret=interpret,
    )(xp, gamma, beta)
    return out[:rows].reshape(orig_shape)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _layernorm_vjp(x, gamma, beta, eps, block_rows, interpret):
    return _layernorm_forward_impl(x, gamma, beta, eps, block_rows,
                                   interpret)


def _layernorm_vjp_fwd(x, gamma, beta, eps, block_rows, interpret):
    out = _layernorm_forward_impl(x, gamma, beta, eps, block_rows, interpret)
    return out, (x, gamma)


def _layernorm_vjp_bwd(eps, block_rows, interpret, res, do):
    x, gamma = res
    xf = x.astype(jnp.float32)
    dof = do.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    rstd = jax.lax.rsqrt(var + eps)
    xhat = (xf - mu) * rstd
    g = gamma.astype(jnp.float32)
    sum_axes = tuple(range(x.ndim - 1))
    dgamma = jnp.sum(dof * xhat, axis=sum_axes).astype(gamma.dtype)
    dbeta = jnp.sum(dof, axis=sum_axes).astype(gamma.dtype)
    dy = dof * g
    dx = rstd * (
        dy
        - jnp.mean(dy, axis=-1, keepdims=True)
        - xhat * jnp.mean(dy * xhat, axis=-1, keepdims=True)
    )
    return dx.astype(x.dtype), dgamma, dbeta


_layernorm_vjp.defvjp(_layernorm_vjp_fwd, _layernorm_vjp_bwd)


def fused_layernorm(x, gamma, beta, eps: float = 1e-5, block_rows: int = 128,
                    interpret: bool = True):
    """Fused LayerNorm via Pallas: x is [..., D]; gamma/beta are [D].

    Differentiable via a custom VJP (closed-form LayerNorm backward).
    """
    return _layernorm_vjp(x, gamma, beta, float(eps), int(block_rows),
                          bool(interpret))


def vmem_footprint_bytes(block_q: int, block_k: int, d: int,
                         dtype_bytes: int = 4) -> int:
    """Estimated VMEM bytes per grid step of the flash kernel.

    q block + one K block + one V block + score tile + (m, l, acc)
    carries. Used by DESIGN.md / EXPERIMENTS.md to pick block sizes that
    fit the ~16 MiB/core VMEM budget with double buffering.
    """
    q_blk = block_q * d
    kv_blk = 2 * block_k * d
    scores = block_q * block_k
    carries = block_q * (d + 2)
    return (q_blk + kv_blk + scores + carries) * dtype_bytes
