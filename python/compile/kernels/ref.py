"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness layer).

Every Pallas kernel in this package has a reference implementation here,
written with plain ``jax.numpy`` ops only. ``python/tests/test_kernel.py``
sweeps shapes and dtypes with hypothesis and asserts the kernel output
matches these oracles to tolerance.
"""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, mask=None, scale=None):
    """Reference scaled dot-product attention.

    Args:
      q: [B, H, Lq, D] queries.
      k: [B, H, Lk, D] keys.
      v: [B, H, Lk, D] values.
      mask: optional [B, Lk] validity mask (1 = valid, 0 = padding) or
        [B, Lq, Lk] full mask. Padding keys receive -inf scores.
      scale: softmax scale; defaults to 1/sqrt(D).

    Returns:
      [B, H, Lq, D] attention output, same dtype as q.
    """
    d = q.shape[-1]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * scale
    if mask is not None:
        if mask.ndim == 2:  # [B, Lk] key-validity mask
            m = mask[:, None, None, :]
        elif mask.ndim == 3:  # [B, Lq, Lk]
            m = mask[:, None, :, :]
        else:
            m = mask
        scores = jnp.where(m > 0, scores, jnp.float32(-1e30))
    probs = jnp.exp(scores - jnp.max(scores, axis=-1, keepdims=True))
    probs = probs / (jnp.sum(probs, axis=-1, keepdims=True) + 1e-30)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vf)
    return out.astype(q.dtype)


def causal_attention_ref(q, k, v, scale=None):
    """Reference causal (decoder) attention: query i attends to keys <= i."""
    lq, lk = q.shape[-2], k.shape[-2]
    causal = jnp.tril(jnp.ones((lq, lk), dtype=jnp.int32), k=lk - lq)
    mask = jnp.broadcast_to(causal[None, :, :], (q.shape[0], lq, lk))
    return attention_ref(q, k, v, mask=mask, scale=scale)


def layernorm_ref(x, gamma, beta, eps=1e-5):
    """Reference LayerNorm over the last axis."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) / jnp.sqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(
        x.dtype
    )
