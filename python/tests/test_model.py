"""L2 correctness: model shapes, gradients, and the invariance contracts
the rust orchestrator depends on.

The key contract (paper §3.3): loss and gradients are SUMS over valid
tokens, so any rearrangement of examples across mini-batches leaves the
all-reduced totals unchanged. These tests pin that down *inside* one
process before the rust layer distributes it.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.CONFIGS["test"]


@pytest.fixture(scope="module")
def params():
    return M.init_all_params(0, CFG)


def _example_inputs(key=0, b=4, lp=16, lf=16, l=48):
    ks = jax.random.split(jax.random.PRNGKey(key), 6)
    patches = jax.random.normal(ks[0], (b, lp, CFG.patch_dim))
    pmask = (jnp.arange(lp)[None, :] < jnp.array([lp, lp // 2, lp, 4])[:b, None]).astype(jnp.int32)
    frames = jax.random.normal(ks[1], (b, lf, CFG.mel_dim))
    fmask = (jnp.arange(lf)[None, :] < jnp.array([lf, lf, 8, lf])[:b, None]).astype(jnp.int32)
    tok = jax.random.randint(ks[2], (b, l), 0, CFG.vocab)
    tgt = jax.random.randint(ks[3], (b, l), 0, CFG.vocab)
    lm = (jax.random.uniform(ks[4], (b, l)) < 0.8).astype(jnp.int32)
    tv = lp // CFG.vis_group
    ta = lf // CFG.aud_stride
    vpos = jnp.tile(jnp.arange(tv)[None], (b, 1))
    apos = jnp.tile(jnp.arange(ta)[None] + tv, (b, 1))
    return patches, pmask, frames, fmask, tok, tgt, lm, vpos, apos


def test_param_counts_are_sane(params):
    counts = {k: M.param_count(v) for k, v in params.items()}
    assert counts["llm"] > counts["vision"]
    assert all(c > 0 for c in counts.values())
    # e2e-100m must actually be ~100M.
    big = M.init_all_params(0, M.CONFIGS["e2e-100m"])
    total = M.param_count(big)
    assert 70e6 < total < 130e6, total


def test_vision_encode_shapes(params):
    patches, pmask, *_ = _example_inputs()
    out = M.vision_encode(params["vision"], patches, pmask, CFG)
    assert out.shape == (4, 16 // CFG.vis_group, CFG.d_llm)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_audio_encode_shapes(params):
    _, _, frames, fmask, *_ = _example_inputs()
    out = M.audio_encode(params["audio"], frames, fmask, CFG)
    assert out.shape == (4, 16 // CFG.aud_stride, CFG.d_llm)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_llm_step_outputs(params):
    patches, pmask, frames, fmask, tok, tgt, lm, vpos, apos = _example_inputs()
    vt = M.vision_encode(params["vision"], patches, pmask, CFG)
    at = M.audio_encode(params["audio"], frames, fmask, CFG)
    step = M.make_llm_step(CFG)
    loss, cnt, d_vis, d_aud, grads = step(
        params["llm"], tok, vt, vpos, at, apos, tgt, lm
    )
    assert loss.shape == () and cnt.shape == ()
    assert float(cnt) == float(jnp.sum(lm))
    assert d_vis.shape == vt.shape and d_aud.shape == at.shape
    assert float(loss) > 0
    n_leaves = len(jax.tree_util.tree_leaves(grads))
    assert n_leaves == len(jax.tree_util.tree_leaves(params["llm"]))


def test_loss_sum_additive_over_batch_split(params):
    """loss_sum(batch) == loss_sum(first half) + loss_sum(second half).

    This additivity is exactly what makes post-balancing rearrangements
    consequence-invariant after the DP all-reduce.
    """
    patches, pmask, frames, fmask, tok, tgt, lm, vpos, apos = _example_inputs()
    vt = M.vision_encode(params["vision"], patches, pmask, CFG)
    at = M.audio_encode(params["audio"], frames, fmask, CFG)
    step = M.make_llm_step(CFG)

    def run(sl):
        return step(params["llm"], tok[sl], vt[sl], vpos[sl], at[sl],
                    apos[sl], tgt[sl], lm[sl])

    full = run(slice(None))
    lo = run(slice(0, 2))
    hi = run(slice(2, 4))
    np.testing.assert_allclose(
        float(full[0]), float(lo[0]) + float(hi[0]), rtol=1e-5
    )
    np.testing.assert_allclose(
        float(full[1]), float(lo[1]) + float(hi[1]), rtol=1e-6
    )
    # Parameter-gradient sums must also be additive.
    g_full = jax.tree_util.tree_leaves(full[4])
    g_sum = [
        a + b
        for a, b in zip(jax.tree_util.tree_leaves(lo[4]),
                        jax.tree_util.tree_leaves(hi[4]))
    ]
    for a, b in zip(g_full, g_sum):
        np.testing.assert_allclose(a, b, atol=1e-4, rtol=1e-4)


def test_llm_step_permutation_invariant_sum(params):
    """Permuting examples inside the batch leaves loss_sum unchanged."""
    patches, pmask, frames, fmask, tok, tgt, lm, vpos, apos = _example_inputs()
    vt = M.vision_encode(params["vision"], patches, pmask, CFG)
    at = M.audio_encode(params["audio"], frames, fmask, CFG)
    step = M.make_llm_step(CFG)
    perm = jnp.array([2, 0, 3, 1])
    a = step(params["llm"], tok, vt, vpos, at, apos, tgt, lm)
    b = step(params["llm"], tok[perm], vt[perm], vpos[perm], at[perm],
             apos[perm], tgt[perm], lm[perm])
    np.testing.assert_allclose(float(a[0]), float(b[0]), rtol=1e-5)


def test_encoder_bwd_matches_autodiff(params):
    patches, pmask, frames, fmask, *_ = _example_inputs()
    d_out = jax.random.normal(
        jax.random.PRNGKey(9), (4, 16 // CFG.vis_group, CFG.d_llm)
    )
    bwd = M.make_vision_bwd(CFG)
    got = bwd(params["vision"], patches, pmask, d_out)

    def scalar_fn(p):
        return jnp.sum(M.vision_encode(p, patches, pmask, CFG) * d_out)

    want = jax.grad(scalar_fn)(params["vision"])
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-4)


def test_scatter_respects_positions(params):
    """Injected encoder tokens must land exactly at vis_pos/aud_pos."""
    b, l, tv = 2, 16, 4
    base = jnp.zeros((b, l, CFG.d_llm))
    tokens = jnp.ones((b, tv, CFG.d_llm))
    pos = jnp.array([[1, 3, 5, -1], [0, -1, -1, -1]])
    out = M._scatter_tokens(base, tokens, pos)
    assert float(out[0, 1, 0]) == 1.0
    assert float(out[0, 3, 0]) == 1.0
    assert float(out[0, 5, 0]) == 1.0
    assert float(out[0, 0, 0]) == 0.0
    assert float(out[1, 0, 0]) == 1.0
    assert float(jnp.sum(out[0])) == 3.0 * CFG.d_llm
    assert float(jnp.sum(out[1])) == 1.0 * CFG.d_llm


def test_sgd_step_moves_params(params):
    sgd = M.make_sgd()
    grads = jax.tree_util.tree_map(jnp.ones_like, params["llm"])
    new = sgd(jnp.float32(0.1), params["llm"], grads)
    for a, b in zip(jax.tree_util.tree_leaves(new),
                    jax.tree_util.tree_leaves(params["llm"])):
        np.testing.assert_allclose(a, b - 0.1, atol=1e-6)


def test_flatten_roundtrip(params):
    leaves, names, treedef = M.flatten_params(params["llm"])
    assert len(leaves) == len(names) == len(set(names))
    rebuilt = M.unflatten_params(treedef, leaves)
    for a, b in zip(jax.tree_util.tree_leaves(rebuilt),
                    jax.tree_util.tree_leaves(params["llm"])):
        assert a is b


def test_training_reduces_loss(params):
    """A few SGD steps on a fixed batch must reduce the loss (sanity that
    the phase-split gradients actually descend)."""
    patches, pmask, frames, fmask, tok, tgt, lm, vpos, apos = _example_inputs()
    step = M.make_llm_step(CFG)
    sgd = M.make_sgd()
    vbwd = M.make_vision_bwd(CFG)
    abwd = M.make_audio_bwd(CFG)
    p = {k: v for k, v in params.items()}
    losses = []
    lr = 0.05
    for _ in range(5):
        vt = M.vision_encode(p["vision"], patches, pmask, CFG)
        at = M.audio_encode(p["audio"], frames, fmask, CFG)
        loss, cnt, d_vis, d_aud, lg = step(
            p["llm"], tok, vt, vpos, at, apos, tgt, lm
        )
        vg = vbwd(p["vision"], patches, pmask, d_vis)
        ag = abwd(p["audio"], frames, fmask, d_aud)
        scale = jnp.float32(lr / float(cnt))
        p = {
            "llm": sgd(scale, p["llm"], lg),
            "vision": sgd(scale, p["vision"], vg),
            "audio": sgd(scale, p["audio"], ag),
        }
        losses.append(float(loss) / float(cnt))
    assert losses[-1] < losses[0], losses
