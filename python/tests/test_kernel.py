"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes, dtypes, block sizes, and mask patterns; every
case asserts allclose against ``kernels/ref.py``. This is the core
correctness signal for the kernel layer — the AOT'd model is only as
right as these kernels.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import (
    flash_attention,
    fused_layernorm,
    vmem_footprint_bytes,
)
from compile.kernels.ref import (
    attention_ref,
    causal_attention_ref,
    layernorm_ref,
)

jax.config.update("jax_platform_name", "cpu")

_SETTINGS = dict(max_examples=25, deadline=None)


def _rand(key, shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    return x.astype(dtype)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------


@settings(**_SETTINGS)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    l=st.integers(1, 70),
    d=st.sampled_from([8, 16, 32]),
    block=st.sampled_from([8, 16, 64]),
)
def test_attention_unmasked_matches_ref(b, h, l, d, block):
    q = _rand(1, (b, h, l, d), jnp.float32)
    k = _rand(2, (b, h, l, d), jnp.float32)
    v = _rand(3, (b, h, l, d), jnp.float32)
    out = flash_attention(q, k, v, block_q=block, block_k=block)
    ref = attention_ref(q, k, v)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


@settings(**_SETTINGS)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 3),
    l=st.integers(2, 48),
    d=st.sampled_from([8, 16]),
    data=st.data(),
)
def test_attention_padded_keys_match_ref(b, h, l, d, data):
    """Key-validity masks (padded batching) must match the oracle."""
    valid = data.draw(
        st.lists(st.integers(1, l), min_size=b, max_size=b), label="valid"
    )
    mask = (jnp.arange(l)[None, :] < jnp.array(valid)[:, None]).astype(
        jnp.int32
    )
    q = _rand(4, (b, h, l, d), jnp.float32)
    k = _rand(5, (b, h, l, d), jnp.float32)
    v = _rand(6, (b, h, l, d), jnp.float32)
    out = flash_attention(q, k, v, mask=mask, block_q=16, block_k=16)
    ref = attention_ref(q, k, v, mask=mask)
    # Compare only valid query rows; padding rows are downstream-masked.
    for i, n in enumerate(valid):
        np.testing.assert_allclose(
            out[i, :, :n], ref[i, :, :n], atol=2e-5, rtol=2e-5
        )


@settings(**_SETTINGS)
@given(
    b=st.integers(1, 2),
    h=st.integers(1, 3),
    l=st.integers(1, 65),
    d=st.sampled_from([8, 32]),
)
def test_attention_causal_matches_ref(b, h, l, d):
    q = _rand(7, (b, h, l, d), jnp.float32)
    k = _rand(8, (b, h, l, d), jnp.float32)
    v = _rand(9, (b, h, l, d), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block_q=16, block_k=16)
    ref = causal_attention_ref(q, k, v)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


@settings(**_SETTINGS)
@given(dtype=st.sampled_from([jnp.float32, jnp.bfloat16]))
def test_attention_dtypes(dtype):
    b, h, l, d = 2, 2, 32, 16
    q = _rand(10, (b, h, l, d), dtype)
    k = _rand(11, (b, h, l, d), dtype)
    v = _rand(12, (b, h, l, d), dtype)
    out = flash_attention(q, k, v, block_q=16, block_k=16)
    ref = attention_ref(q, k, v)
    assert out.dtype == dtype
    np.testing.assert_allclose(
        out.astype(jnp.float32),
        ref.astype(jnp.float32),
        atol=_tol(dtype),
        rtol=_tol(dtype),
    )


def test_attention_custom_scale():
    b, h, l, d = 1, 2, 24, 8
    q, k, v = (_rand(i, (b, h, l, d), jnp.float32) for i in (13, 14, 15))
    out = flash_attention(q, k, v, scale=0.5, block_q=8, block_k=8)
    ref = attention_ref(q, k, v, scale=0.5)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_attention_grads_match_ref():
    """Custom VJP vs. autodiff through the reference implementation."""
    b, h, l, d = 1, 2, 20, 8
    q, k, v = (_rand(i, (b, h, l, d), jnp.float32) for i in (16, 17, 18))
    mask = (jnp.arange(l)[None, :] < 15).astype(jnp.int32)

    def f_kernel(q, k, v):
        return jnp.sum(flash_attention(q, k, v, mask=mask, block_q=8,
                                       block_k=8) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(attention_ref(q, k, v, mask=mask) ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(a, b_, atol=3e-5, rtol=3e-5)


def test_attention_causal_grads_match_ref():
    b, h, l, d = 1, 1, 16, 8
    q, k, v = (_rand(i, (b, h, l, d), jnp.float32) for i in (19, 20, 21))
    causal_m = jnp.tril(jnp.ones((l, l), jnp.int32))[None]

    def f_kernel(q):
        return jnp.sum(flash_attention(q, k, v, causal=True, block_q=8,
                                       block_k=8) ** 2)

    def f_ref(q):
        return jnp.sum(attention_ref(q, k, v, mask=causal_m) ** 2)

    np.testing.assert_allclose(
        jax.grad(f_kernel)(q), jax.grad(f_ref)(q), atol=3e-5, rtol=3e-5
    )


def test_attention_rejects_causal_cross():
    q = jnp.zeros((1, 1, 8, 8))
    k = jnp.zeros((1, 1, 16, 8))
    with pytest.raises(ValueError):
        flash_attention(q, k, v=k, causal=True)


def test_attention_fully_masked_rows_are_finite():
    """Fully-padded examples must not produce NaN/Inf (they are sliced or
    loss-masked downstream, but must stay numerically inert)."""
    b, h, l, d = 2, 1, 16, 8
    q, k, v = (_rand(i, (b, h, l, d), jnp.float32) for i in (22, 23, 24))
    mask = jnp.zeros((b, l), jnp.int32).at[0].set(1)
    out = flash_attention(q, k, v, mask=mask, block_q=8, block_k=8)
    assert bool(jnp.all(jnp.isfinite(out)))


# ---------------------------------------------------------------------------
# fused_layernorm
# ---------------------------------------------------------------------------


@settings(**_SETTINGS)
@given(
    rows=st.integers(1, 100),
    d=st.sampled_from([8, 32, 64]),
    block=st.sampled_from([8, 128]),
)
def test_layernorm_matches_ref(rows, d, block):
    x = _rand(30, (rows, d), jnp.float32)
    g = 1.0 + 0.1 * _rand(31, (d,), jnp.float32)
    b = 0.1 * _rand(32, (d,), jnp.float32)
    out = fused_layernorm(x, g, b, block_rows=block)
    ref = layernorm_ref(x, g, b)
    np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


def test_layernorm_3d_and_grads():
    x = _rand(33, (3, 17, 32), jnp.float32)
    g = jnp.ones(32)
    b = jnp.zeros(32)

    def f_kernel(x, g, b):
        return jnp.sum(fused_layernorm(x, g, b) ** 2)

    def f_ref(x, g, b):
        return jnp.sum(layernorm_ref(x, g, b) ** 2)

    out = fused_layernorm(x, g, b)
    np.testing.assert_allclose(out, layernorm_ref(x, g, b), atol=2e-5,
                               rtol=2e-5)
    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(x, g, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, g, b)
    for a, b_ in zip(gk, gr):
        np.testing.assert_allclose(a, b_, atol=5e-4, rtol=5e-4)


# ---------------------------------------------------------------------------
# VMEM estimator (the real-TPU sizing contract from DESIGN.md)
# ---------------------------------------------------------------------------


def test_vmem_footprint_monotone_and_fits_budget():
    small = vmem_footprint_bytes(64, 64, 64)
    big = vmem_footprint_bytes(128, 128, 128)
    assert small < big
    # The default production tile (128, 128, d=128) must fit a 16 MiB VMEM
    # with double buffering (x2).
    assert 2 * vmem_footprint_bytes(128, 128, 128) < 16 * 1024 * 1024


def test_attention_inside_jit():
    """The kernel must lower inside jit (the AOT path depends on it)."""
    b, h, l, d = 1, 2, 16, 8
    q, k, v = (_rand(i, (b, h, l, d), jnp.float32) for i in (40, 41, 42))

    @jax.jit
    def f(q, k, v):
        return flash_attention(q, k, v, block_q=8, block_k=8)

    np.testing.assert_allclose(f(q, k, v), attention_ref(q, k, v),
                               atol=2e-5, rtol=2e-5)
