//! Fig. 10: ablation of encoder balancing — full OrchMLLM vs balancing
//! only the LLM phase (the pre-balancing stand-in, cf. DistTrain) — on
//! 128 GPUs, mb 75/50/25.
//!
//! Expected shape (paper): OrchMLLM wins MFU and memory on every size;
//! the gap grows with model size; LLM-only OOMs at MLLM-84B (it only
//! fits at mb 18 with 24.16% MFU).
//!
//! Run: `cargo bench --bench fig10_prebalance`

use orchmllm::model::config::MllmConfig;
use orchmllm::sim::engine::{simulate_run, SystemKind};
use orchmllm::sim::report;
use orchmllm::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let gpus = args.usize("gpus", 128);
    let steps = args.usize("steps", 3);
    let seed = args.u64("seed", 42);
    let mbs = [75usize, 50, 25];

    let mut rows = Vec::new();
    for system in [SystemKind::OrchMllm, SystemKind::LlmOnly] {
        let mut row = Vec::new();
        for (mi, model) in MllmConfig::all().iter().enumerate() {
            row.push(simulate_run(
                system, model, gpus, mbs[mi], steps, seed,
            ));
        }
        rows.push(row);
    }
    println!(
        "Fig. 10 — encoder-balancing ablation ({gpus} GPUs, mb 75/50/25):\n"
    );
    print!("{}", report::render_mfu_memory(&rows));

    // If LLM-only OOMs at 84B, re-run at the paper's fallback mb 18.
    if rows[1][2].oom {
        let fallback = simulate_run(
            SystemKind::LlmOnly,
            &MllmConfig::mllm_84b(),
            gpus,
            18,
            steps,
            seed,
        );
        println!(
            "\nLLM-only at MLLM-84B OOMs at mb 25; at mb 18: \
             MFU {:.1}% mem {:.1} GB (paper: 24.16%, 62.7 GB)",
            fallback.mfu * 100.0,
            fallback.peak_mem_gb
        );
    }

    // Shape checks: full balance wins everywhere, gap grows with size.
    let mut prev_gap = 0.0;
    for mi in 0..3 {
        let orch = &rows[0][mi];
        let llm = &rows[1][mi];
        if llm.oom {
            println!("{}: LLM-only OOM (paper shape ✓)", orch.model_name);
            continue;
        }
        let gap = orch.mfu / llm.mfu.max(1e-9);
        println!(
            "{}: OrchMLLM {:.1}% vs LLM-only {:.1}% ({gap:.2}x)",
            orch.model_name,
            orch.mfu * 100.0,
            llm.mfu * 100.0
        );
        assert!(gap > 1.0, "encoder balancing gained nothing");
        assert!(gap >= prev_gap * 0.9, "gap should grow with size");
        prev_gap = gap;
    }
}
