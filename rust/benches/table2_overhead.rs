//! Table 2: dispatcher overhead (ms) and forward duration (s) as the
//! cluster scales 64 → 2560 GPUs (MLLM-10B, mb 60), plus the serial vs
//! parallel+scratch planning comparison that the step pipeline's §6
//! overlap rests on.
//!
//! Expected shape (paper): overhead stays tens of ms (16.7 → 53.9 ms),
//! <2% of the forward duration, because the All-to-All cost is
//! scale-free (Eq. 4) and the solver computation overlaps with the
//! forward pass.
//!
//! Emits `BENCH_table2_overhead.json` (overhead sweep + before/after
//! planning wall-times) so the speedup is tracked across PRs.
//!
//! Run: `cargo bench --bench table2_overhead`

use orchmllm::comm::topology::Topology;
use orchmllm::data::synth::{DatasetConfig, Example, Generator};
use orchmllm::model::config::MllmConfig;
use orchmllm::orchestrator::global::{
    Orchestrator, OrchestratorConfig, StepScratch,
};
use orchmllm::sim::engine::{simulate_run, SystemKind};
use orchmllm::sim::report;
use orchmllm::util::bench::Bencher;
use orchmllm::util::cli::Args;
use orchmllm::util::json::Json;

fn main() {
    let args = Args::from_env();
    let steps = args.usize("steps", 3);
    let seed = args.u64("seed", 42);
    let model = MllmConfig::mllm_10b();

    let sizes = [64usize, 128, 256, 512, 1024, 2560];
    let cells: Vec<_> = sizes
        .iter()
        .map(|&g| {
            let t0 = std::time::Instant::now();
            let r = simulate_run(
                SystemKind::OrchMllm, &model, g, 60, steps, seed,
            );
            eprintln!(
                "  simulated {g} GPUs in {:.1}s",
                t0.elapsed().as_secs_f64()
            );
            r
        })
        .collect();

    println!(
        "Table 2 — OrchMLLM overhead vs cluster size (MLLM-10B, mb 60):\n"
    );
    print!("{}", report::render_overhead(&cells));

    // Shape checks: overhead grows sublinearly and stays a small
    // fraction of the step.
    let first = &cells[0];
    let last = cells.last().unwrap();
    let scale = last.gpus as f64 / first.gpus as f64; // 40x
    let growth =
        last.dispatcher_overhead_ms / first.dispatcher_overhead_ms.max(1e-9);
    println!(
        "\noverhead growth {growth:.1}x over a {scale:.0}x scale-up \
         (paper: 3.2x over 40x)"
    );
    assert!(growth < scale / 2.0, "overhead scales too fast: {growth}x");
    for c in &cells {
        let frac = c.dispatcher_overhead_ms / 1e3 / c.step_secs;
        assert!(
            frac < 0.05,
            "overhead {:.1}% of step at {} GPUs",
            frac * 100.0,
            c.gpus
        );
    }

    // ---- serial vs parallel+scratch planning ---------------------------
    // The acceptance workload: 3 phases, d = 32 instances. `serial` is
    // the pre-refactor path (one phase after another, fresh allocations
    // each step); `parallel` is the shipped path (phases planned
    // concurrently on a reused StepScratch).
    let d = args.usize("plan-gpus", 32);
    let mb = args.usize("plan-mb", 60);
    let topo = Topology::h100(d);
    let orch =
        Orchestrator::new(OrchestratorConfig::orchmllm(3584.0 * 2.0));
    let mut generator = Generator::new(DatasetConfig::default(), seed);
    let minibatches: Vec<Vec<Example>> =
        (0..d).map(|_| generator.batch(mb)).collect();

    let mut bench = Bencher::new(&format!(
        "step planning (3 phases, d={d}, n={} per phase)",
        d * mb
    ));
    let (serial_ms, serial_best_ms) = {
        let r = bench.iter("serial, fresh allocations", || {
            orch.plan_step_serial(&topo, &minibatches)
        });
        (r.mean_ms(), r.min_ns / 1e6)
    };
    let mut scratch = StepScratch::default();
    let (parallel_ms, parallel_best_ms) = {
        let r = bench.iter("parallel phases + scratch", || {
            orch.plan_step_with(&topo, &minibatches, &mut scratch)
        });
        (r.mean_ms(), r.min_ns / 1e6)
    };
    bench.report();
    let speedup = serial_ms / parallel_ms.max(1e-9);
    println!(
        "\nplanning: serial {serial_ms:.3} ms -> parallel+scratch \
         {parallel_ms:.3} ms ({speedup:.2}x; best-case \
         {serial_best_ms:.3} -> {parallel_best_ms:.3} ms)"
    );
    // Compare best-case times: minima measure the intrinsic cost of
    // each path, where means on a shared/loaded runner fold scheduler
    // noise into whichever case ran during a spike. On a single-core
    // host parallel phase planning cannot win by construction, so the
    // comparison is reported but not enforced there.
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    if cores >= 2 {
        assert!(
            parallel_best_ms < serial_best_ms,
            "parallel+scratch planning ({parallel_best_ms:.3} ms best) \
             did not beat the serial path ({serial_best_ms:.3} ms best)"
        );
    } else {
        eprintln!("single-core host: speedup assertion skipped");
    }

    // ---- JSON emission (tracked across PRs) ----------------------------
    let sweep = Json::arr(cells.iter().map(|c| {
        Json::obj(vec![
            ("gpus", Json::num(c.gpus as f64)),
            ("overhead_ms", Json::num(c.dispatcher_overhead_ms)),
            ("step_secs", Json::num(c.step_secs)),
            ("plan_ms", Json::num(c.plan_ms)),
            ("plan_overlapped_pct", Json::num(c.plan_overlapped_pct)),
        ])
    }));
    let out = Json::obj(vec![
        ("bench", Json::str("table2_overhead")),
        ("model", Json::str(model.name)),
        ("mini_batch", Json::num(60.0)),
        ("steps", Json::num(steps as f64)),
        ("seed", Json::num(seed as f64)),
        ("sweep", sweep),
        (
            "planning",
            Json::obj(vec![
                ("gpus", Json::num(d as f64)),
                ("mini_batch", Json::num(mb as f64)),
                ("serial_ms", Json::num(serial_ms)),
                ("parallel_scratch_ms", Json::num(parallel_ms)),
                ("speedup", Json::num(speedup)),
            ]),
        ),
    ]);
    let path = "BENCH_table2_overhead.json";
    std::fs::write(path, out.pretty()).expect("write bench json");
    println!("wrote {path}");
}
