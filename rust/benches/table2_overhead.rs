//! Table 2: dispatcher overhead (ms) and forward duration (s) as the
//! cluster scales 64 → 2560 GPUs (MLLM-10B, mb 60), plus the planning
//! comparison the step pipeline's §6 overlap rests on: serial vs
//! parallel+scratch (PR 1) vs incremental warm-start + plan cache.
//!
//! Expected shape (paper): overhead stays tens of ms (16.7 → 53.9 ms),
//! <2% of the forward duration, because the All-to-All cost is
//! scale-free (Eq. 4) and the solver computation overlaps with the
//! forward pass.
//!
//! The incremental case measures the steady-state workload (step
//! t ≥ 2): a small set of recurring batch shapes, planned once cold,
//! then replanned through the warm-start path and the sketch-keyed plan
//! caches. Acceptance: its **median** plan time is ≥ 3× lower than the
//! from-scratch parallel path, with the cache hit rate and p99 plan
//! time reported alongside.
//!
//! Emits `BENCH_table2_overhead.json` (overhead sweep + planning
//! wall-times) so the speedup is tracked across PRs.
//!
//! Run: `cargo bench --bench table2_overhead` (`-- --smoke` runs a tiny
//! shape for CI bit-rot detection, skipping the timing assertions;
//! `-- --depth-sweep` additionally sweeps the pipeline lookahead
//! depth 1..=8 at d >= 1024 — smoke shrinks it to d = 8 — reporting
//! per-depth consumer-stall times in the JSON's `depth_sweep` array).

use orchmllm::comm::topology::Topology;
use orchmllm::data::synth::{DatasetConfig, Example, Generator};
use orchmllm::model::config::MllmConfig;
use orchmllm::orchestrator::global::OrchestratorConfig;
use orchmllm::orchestrator::pipeline::{
    PipelineConfig, StepPipeline, MAX_PIPELINE_DEPTH,
};
use orchmllm::orchestrator::session::{PlanOptions, PlanSession};
use orchmllm::sim::engine::{simulate_run, SystemKind};
use orchmllm::sim::report;
use orchmllm::util::bench::Bencher;
use orchmllm::util::cli::Args;
use orchmllm::util::json::Json;

fn main() {
    let args = Args::from_env();
    let smoke = args.flag("smoke");
    let steps = args.usize("steps", if smoke { 2 } else { 3 });
    let seed = args.u64("seed", 42);
    let model = MllmConfig::mllm_10b();

    let sizes: &[usize] = if smoke {
        &[8, 16]
    } else {
        &[64, 128, 256, 512, 1024, 2560]
    };
    let cells: Vec<_> = sizes
        .iter()
        .map(|&g| {
            let t0 = std::time::Instant::now();
            let r = simulate_run(
                SystemKind::OrchMllm, &model, g, 60, steps, seed,
            );
            eprintln!(
                "  simulated {g} GPUs in {:.1}s",
                t0.elapsed().as_secs_f64()
            );
            r
        })
        .collect();

    println!(
        "Table 2 — OrchMLLM overhead vs cluster size (MLLM-10B, mb 60):\n"
    );
    print!("{}", report::render_overhead(&cells));

    // Shape checks: overhead grows sublinearly and stays a small
    // fraction of the step (full scale only — a 2-point smoke sweep is
    // too noisy to gate on).
    if !smoke {
        let first = &cells[0];
        let last = cells.last().unwrap();
        let scale = last.gpus as f64 / first.gpus as f64; // 40x
        let growth = last.dispatcher_overhead_ms
            / first.dispatcher_overhead_ms.max(1e-9);
        println!(
            "\noverhead growth {growth:.1}x over a {scale:.0}x scale-up \
             (paper: 3.2x over 40x)"
        );
        assert!(
            growth < scale / 2.0,
            "overhead scales too fast: {growth}x"
        );
        for c in &cells {
            let frac = c.dispatcher_overhead_ms / 1e3 / c.step_secs;
            assert!(
                frac < 0.05,
                "overhead {:.1}% of step at {} GPUs",
                frac * 100.0,
                c.gpus
            );
        }
    }

    // ---- serial vs parallel vs incremental planning --------------------
    // The acceptance workload: 3 phases, d = 32 instances. `serial`
    // plans one phase after another on the calling thread; `parallel`
    // plans phases concurrently; `incremental` adds the cross-step
    // history — the steady-state (t ≥ 2) path the pipeline actually
    // runs. All three are PlanOptions on the same PlanSession entry
    // point, each on its own session's warmed scratch — so since PR 5
    // the serial→parallel delta isolates *phase parallelism* alone
    // (the pre-session serial case also paid fresh allocations each
    // step; PR-1's zero-alloc win is no longer part of this number —
    // keep that in mind when comparing `speedup` across PRs).
    let d = args.usize("plan-gpus", if smoke { 8 } else { 32 });
    let mb = args.usize("plan-mb", if smoke { 8 } else { 60 });
    let cache_size = args.usize("plan-cache-size", 32);
    let topo = Topology::h100(d);
    let cfg = OrchestratorConfig::orchmllm(3584.0 * 2.0);
    let pipe_cfg =
        PipelineConfig { plan_cache_size: cache_size, ..Default::default() };
    let mut generator = Generator::new(DatasetConfig::default(), seed);
    let minibatches: Vec<Vec<Example>> =
        (0..d).map(|_| generator.batch(mb)).collect();

    // One session per strategy: each strategy is a PlanOptions value on
    // the same entry point, so the comparison measures the solve
    // strategy, not the API path.
    let mut serial_session =
        PlanSession::new(cfg.clone(), pipe_cfg, topo);
    let mut parallel_session =
        PlanSession::new(cfg.clone(), pipe_cfg, topo);
    let mut inc_session = PlanSession::new(cfg, pipe_cfg, topo);

    let mut bench = Bencher::new(&format!(
        "step planning (3 phases, d={d}, n={} per phase)",
        d * mb
    ));
    let (serial_ms, serial_best_ms) = {
        let r = bench.iter("serial phases", || {
            serial_session.plan(&minibatches, PlanOptions::serial())
        });
        (r.mean_ms(), r.min_ns / 1e6)
    };
    let (parallel_ms, parallel_p50_ms, parallel_best_ms) = {
        let r = bench.iter("parallel phases + scratch", || {
            parallel_session.plan(&minibatches, PlanOptions::from_scratch())
        });
        (r.mean_ms(), r.p50_ns / 1e6, r.min_ns / 1e6)
    };

    // Steady-state workload: a recurring cycle of distinct batch
    // shapes. One cold pass populates the history and caches (the
    // t < 2 steps); the timed loop is then pure steady state.
    let shapes: Vec<Vec<Vec<Example>>> = (0..4)
        .map(|_| (0..d).map(|_| generator.batch(mb)).collect())
        .collect();
    for s in &shapes {
        inc_session.plan(s, PlanOptions::auto());
    }
    let mut idx = 0usize;
    let (incr_ms, incr_p50_ms, incr_p99_ms) = {
        let r = bench.iter("incremental (warm + plan cache)", || {
            let plan = inc_session.plan(
                &shapes[idx % shapes.len()],
                PlanOptions::auto(),
            );
            idx += 1;
            plan
        });
        (r.mean_ms(), r.p50_ns / 1e6, r.p99_ns / 1e6)
    };
    bench.report();

    let cache_hit_rate = inc_session.cache_hit_rate();
    let speedup = serial_ms / parallel_ms.max(1e-9);
    let steady_speedup = parallel_p50_ms / incr_p50_ms.max(1e-9);
    println!(
        "\nplanning: serial {serial_ms:.3} ms -> parallel+scratch \
         {parallel_ms:.3} ms ({speedup:.2}x; best-case \
         {serial_best_ms:.3} -> {parallel_best_ms:.3} ms)"
    );
    println!(
        "steady state: parallel p50 {parallel_p50_ms:.3} ms -> \
         incremental p50 {incr_p50_ms:.3} ms ({steady_speedup:.2}x), \
         p99 {incr_p99_ms:.3} ms, cache hit rate {:.0}%",
        cache_hit_rate * 100.0
    );

    // Compare best-case times: minima measure the intrinsic cost of
    // each path, where means on a shared/loaded runner fold scheduler
    // noise into whichever case ran during a spike. On a single-core
    // host parallel phase planning cannot win by construction, so the
    // comparison is reported but not enforced there.
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    if !smoke {
        if cores >= 2 {
            assert!(
                parallel_best_ms < serial_best_ms,
                "parallel+scratch planning ({parallel_best_ms:.3} ms \
                 best) did not beat the serial path \
                 ({serial_best_ms:.3} ms best)"
            );
        } else {
            eprintln!("single-core host: speedup assertion skipped");
        }
        // The headline acceptance: steady-state median plan time must
        // be >= 3x lower than the from-scratch parallel path.
        assert!(
            steady_speedup >= 3.0,
            "incremental planning only {steady_speedup:.2}x faster \
             (p50 {incr_p50_ms:.3} ms vs parallel {parallel_p50_ms:.3} \
             ms); acceptance requires >= 3x"
        );
        assert!(
            cache_hit_rate > 0.0,
            "recurring shapes produced no cache hits"
        );
    }

    // ---- depth sweep (--depth-sweep): lookahead 1..=8 at d >= 1024 -----
    // The pipeline's lookahead depth is the knob that hides planning
    // spikes (a cold solve at large d) from the executor. Sweep every
    // legal depth on one shape and report how long the consumer
    // stalled in `next()` against a fixed stand-in execute cost:
    // depth 1 eats every spike, deeper buffers absorb them.
    let depth_sweep = if args.flag("depth-sweep") {
        let sweep_d =
            args.usize("sweep-gpus", if smoke { 8 } else { 1024 });
        let sweep_mb = args.usize("sweep-mb", if smoke { 4 } else { 8 });
        let sweep_steps =
            args.usize("sweep-steps", if smoke { 6 } else { 24 });
        let execute_ms =
            args.u64("sweep-execute-ms", if smoke { 1 } else { 10 });
        eprintln!(
            "\ndepth sweep (d={sweep_d}, mb {sweep_mb}, \
             {sweep_steps} steps, execute {execute_ms} ms):"
        );
        let mut rows = Vec::new();
        for depth in 1..=MAX_PIPELINE_DEPTH {
            let session = PlanSession::new(
                OrchestratorConfig::orchmllm(3584.0 * 2.0),
                PipelineConfig { depth, plan_cache_size: cache_size },
                Topology::h100(sweep_d),
            );
            let pipe = StepPipeline::new(
                session,
                DatasetConfig::default(),
                seed,
                sweep_mb,
                sweep_steps,
            );
            let t0 = std::time::Instant::now();
            let mut stalls_ms: Vec<f64> =
                Vec::with_capacity(sweep_steps);
            let mut plan_ns_total: u128 = 0;
            loop {
                let t = std::time::Instant::now();
                let Some(step) = pipe.next() else { break };
                stalls_ms.push(t.elapsed().as_secs_f64() * 1e3);
                plan_ns_total += step.plan_nanos;
                // The window the background planner runs ahead in.
                std::thread::sleep(std::time::Duration::from_millis(
                    execute_ms,
                ));
            }
            assert_eq!(stalls_ms.len(), sweep_steps);
            let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
            stalls_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let stall_p50_ms = stalls_ms[stalls_ms.len() / 2];
            let stall_max_ms = *stalls_ms.last().unwrap();
            let mean_plan_ms =
                plan_ns_total as f64 / 1e6 / sweep_steps as f64;
            eprintln!(
                "  depth {depth}: wall {wall_ms:>8.1} ms  stall p50 \
                 {stall_p50_ms:>7.3} ms  max {stall_max_ms:>8.2} ms  \
                 plan mean {mean_plan_ms:>7.3} ms"
            );
            rows.push(Json::obj(vec![
                ("depth", Json::num(depth as f64)),
                ("gpus", Json::num(sweep_d as f64)),
                ("mini_batch", Json::num(sweep_mb as f64)),
                ("steps", Json::num(sweep_steps as f64)),
                ("execute_ms", Json::num(execute_ms as f64)),
                ("wall_ms", Json::num(wall_ms)),
                ("stall_p50_ms", Json::num(stall_p50_ms)),
                ("stall_max_ms", Json::num(stall_max_ms)),
                ("mean_plan_ms", Json::num(mean_plan_ms)),
            ]));
        }
        rows
    } else {
        Vec::new()
    };

    // ---- JSON emission (tracked across PRs) ----------------------------
    let sweep = Json::arr(cells.iter().map(|c| {
        Json::obj(vec![
            ("gpus", Json::num(c.gpus as f64)),
            ("overhead_ms", Json::num(c.dispatcher_overhead_ms)),
            ("step_secs", Json::num(c.step_secs)),
            ("plan_ms", Json::num(c.plan_ms)),
            ("plan_ms_p50", Json::num(c.plan_stats.p50_ms)),
            ("plan_ms_p95", Json::num(c.plan_stats.p95_ms)),
            ("plan_ms_p99", Json::num(c.plan_stats.p99_ms)),
            ("plan_warm_ms", Json::num(c.plan_stats.warm_ms)),
            ("plan_cold_ms", Json::num(c.plan_stats.cold_ms)),
            ("warm_rate", Json::num(c.plan_stats.warm_rate)),
            (
                "cache_hit_rate",
                Json::num(c.plan_stats.cache_hit_rate),
            ),
            ("plan_overlapped_pct", Json::num(c.plan_overlapped_pct)),
        ])
    }));
    let out = Json::obj(vec![
        ("bench", Json::str("table2_overhead")),
        ("model", Json::str(model.name)),
        ("mini_batch", Json::num(60.0)),
        ("steps", Json::num(steps as f64)),
        ("seed", Json::num(seed as f64)),
        ("smoke", Json::Bool(smoke)),
        ("sweep", sweep),
        (
            "planning",
            Json::obj(vec![
                ("gpus", Json::num(d as f64)),
                ("mini_batch", Json::num(mb as f64)),
                ("serial_ms", Json::num(serial_ms)),
                ("parallel_scratch_ms", Json::num(parallel_ms)),
                ("parallel_p50_ms", Json::num(parallel_p50_ms)),
                ("speedup", Json::num(speedup)),
                ("incremental_ms", Json::num(incr_ms)),
                ("incremental_p50_ms", Json::num(incr_p50_ms)),
                ("incremental_p99_ms", Json::num(incr_p99_ms)),
                ("steady_state_speedup", Json::num(steady_speedup)),
                ("cache_hit_rate", Json::num(cache_hit_rate)),
                ("plan_cache_size", Json::num(cache_size as f64)),
            ]),
        ),
        ("depth_sweep", Json::arr(depth_sweep)),
    ]);
    let path = "BENCH_table2_overhead.json";
    std::fs::write(path, out.pretty()).expect("write bench json");
    println!("wrote {path}");
}
