//! Table 2: dispatcher overhead (ms) and forward duration (s) as the
//! cluster scales 64 → 2560 GPUs (MLLM-10B, mb 60).
//!
//! Expected shape (paper): overhead stays tens of ms (16.7 → 53.9 ms),
//! <2% of the forward duration, because the All-to-All cost is
//! scale-free (Eq. 4) and the solver computation overlaps with the
//! forward pass.
//!
//! Run: `cargo bench --bench table2_overhead`

use orchmllm::model::config::MllmConfig;
use orchmllm::sim::engine::{simulate_run, SystemKind};
use orchmllm::sim::report;
use orchmllm::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let steps = args.usize("steps", 3);
    let seed = args.u64("seed", 42);
    let model = MllmConfig::mllm_10b();

    let sizes = [64usize, 128, 256, 512, 1024, 2560];
    let cells: Vec<_> = sizes
        .iter()
        .map(|&g| {
            let t0 = std::time::Instant::now();
            let r = simulate_run(
                SystemKind::OrchMllm, &model, g, 60, steps, seed,
            );
            eprintln!(
                "  simulated {g} GPUs in {:.1}s",
                t0.elapsed().as_secs_f64()
            );
            r
        })
        .collect();

    println!(
        "Table 2 — OrchMLLM overhead vs cluster size (MLLM-10B, mb 60):\n"
    );
    print!("{}", report::render_overhead(&cells));

    // Shape checks: overhead grows sublinearly and stays a small
    // fraction of the step.
    let first = &cells[0];
    let last = cells.last().unwrap();
    let scale = last.gpus as f64 / first.gpus as f64; // 40x
    let growth =
        last.dispatcher_overhead_ms / first.dispatcher_overhead_ms.max(1e-9);
    println!(
        "\noverhead growth {growth:.1}x over a {scale:.0}x scale-up \
         (paper: 3.2x over 40x)"
    );
    assert!(growth < scale / 2.0, "overhead scales too fast: {growth}x");
    for c in &cells {
        let frac = c.dispatcher_overhead_ms / 1e3 / c.step_secs;
        assert!(
            frac < 0.05,
            "overhead {:.1}% of step at {} GPUs",
            frac * 100.0,
            c.gpus
        );
    }
}
