//! Fig. 12: communicator comparison — the paper's Node-wise All-to-All
//! vs the All-Gather strawman (§5.2.1) — on 128 GPUs, MFU + memory.
//!
//! Expected shape (paper): All-to-All wins both metrics on every size;
//! All-Gather OOMs at MLLM-84B (fits only at mb 20: 25.51%, 61.8 GB).
//!
//! Run: `cargo bench --bench fig12_allgather`

use orchmllm::model::config::MllmConfig;
use orchmllm::sim::engine::{simulate_run, SystemKind};
use orchmllm::sim::report;
use orchmllm::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let gpus = args.usize("gpus", 128);
    let steps = args.usize("steps", 3);
    let seed = args.u64("seed", 42);
    let mbs = [75usize, 50, 25];

    let mut rows = Vec::new();
    for system in [SystemKind::OrchMllm, SystemKind::AllGatherComm] {
        let mut row = Vec::new();
        for (mi, model) in MllmConfig::all().iter().enumerate() {
            row.push(simulate_run(
                system, model, gpus, mbs[mi], steps, seed,
            ));
        }
        rows.push(row);
    }
    println!("Fig. 12 — All-to-All vs All-Gather ({gpus} GPUs):\n");
    print!("{}", report::render_mfu_memory(&rows));

    if rows[1][2].oom {
        let fallback = simulate_run(
            SystemKind::AllGatherComm,
            &MllmConfig::mllm_84b(),
            gpus,
            20,
            steps,
            seed,
        );
        println!(
            "\nAll-Gather at MLLM-84B OOMs at mb 25; at mb 20: \
             MFU {:.1}% mem {:.1} GB (paper: 25.51%, 61.8 GB)",
            fallback.mfu * 100.0,
            fallback.peak_mem_gb
        );
    }

    for mi in 0..3 {
        let a2a = &rows[0][mi];
        let ag = &rows[1][mi];
        assert!(
            ag.peak_mem_gb > a2a.peak_mem_gb,
            "{}: All-Gather must stage more memory",
            a2a.model_name
        );
        if !ag.oom {
            assert!(
                a2a.mfu >= ag.mfu,
                "{}: All-to-All must not lose MFU",
                a2a.model_name
            );
        }
        println!(
            "{}: A2A {:.1}% / {:.1} GB   AG {} / {:.1} GB",
            a2a.model_name,
            a2a.mfu * 100.0,
            a2a.peak_mem_gb,
            if ag.oom {
                "OOM".to_string()
            } else {
                format!("{:.1}%", ag.mfu * 100.0)
            },
            ag.peak_mem_gb
        );
    }
}
