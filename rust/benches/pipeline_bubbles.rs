//! Bubble-occupancy sweep: the co-scheduler vs the unscheduled 1F1B
//! baseline across pp ∈ {2,4,8} × microbatches ∈ {4,8,16} × the four
//! modality-incoherence profiles (cells with microbatches < pp are
//! skipped — no full steady state, and the CLI rejects the shape).
//!
//! Every cell must *strictly* improve bubble occupancy over the
//! baseline (whose occupancy is identically 0) and strictly shrink the
//! projected step. The sweep emits `BENCH_pipeline_bubbles.json`, and
//! `--baseline ci/bubble_baseline.json` additionally gates every cell
//! against its committed minimum occupancy-improvement floor.
//!
//! Run: `cargo bench --bench pipeline_bubbles`
//!   `-- --smoke`            the small CI grid (what the baseline gates)
//!   `-- --baseline <path>`  fail on regressions vs the checked-in file

use orchmllm::sim::pipeline::run_bubble_sweep;
use orchmllm::util::cli::Args;
use orchmllm::util::json::Json;

/// `cargo bench` runs with CWD at the package root (`rust/`), while
/// developers run from the workspace root — accept both.
fn read_either(path: &str) -> Option<String> {
    std::fs::read_to_string(path)
        .or_else(|_| std::fs::read_to_string(format!("../{path}")))
        .ok()
}

fn main() {
    let args = Args::from_env();
    let smoke = args.flag("smoke");

    let t0 = std::time::Instant::now();
    let sweep = run_bubble_sweep(smoke);
    eprintln!(
        "  swept {} cells in {:.1}s",
        sweep.cells.len(),
        t0.elapsed().as_secs_f64()
    );

    println!(
        "{:<28}{:>9}{:>10}{:>11}{:>9}",
        "cell", "bubble%", "analytic%", "occupancy%", "speedup"
    );
    for c in &sweep.cells {
        println!(
            "{:<28}{:>9.2}{:>10.2}{:>11.2}{:>9.3}",
            c.key,
            c.bubble_fraction * 100.0,
            c.analytic_bubble_fraction * 100.0,
            c.occupancy * 100.0,
            c.speedup
        );
    }

    // The tentpole's acceptance invariant, baseline file or not: every
    // swept cell strictly improves on the unscheduled pipeline.
    for c in &sweep.cells {
        assert!(
            c.improvement > 0.0,
            "cell {}: no occupancy improvement over the unscheduled \
             baseline",
            c.key
        );
        assert!(
            c.cosched_step_secs < c.baseline_step_secs,
            "cell {}: projected step did not shrink ({} !< {})",
            c.key,
            c.cosched_step_secs,
            c.baseline_step_secs
        );
    }
    println!(
        "\nall {} cells strictly improve occupancy and step time",
        sweep.cells.len()
    );

    // ---- JSON emission (tracked across PRs, uploaded by CI) ------------
    let out = sweep.to_json();
    let path = "BENCH_pipeline_bubbles.json";
    std::fs::write(path, out.pretty()).expect("write bench json");
    println!("wrote {path}");

    // ---- baseline gate -------------------------------------------------
    if let Some(baseline_path) = args.get("baseline") {
        let text = read_either(baseline_path).unwrap_or_else(|| {
            panic!("baseline '{baseline_path}' not found")
        });
        let baseline = Json::parse(&text)
            .unwrap_or_else(|e| panic!("{baseline_path}: {e}"));
        let regressions = sweep.check_baseline(&baseline);
        println!("\nbaseline gate ({baseline_path}):");
        assert!(
            regressions.is_empty(),
            "bubble-occupancy regressions:\n  {}",
            regressions.join("\n  ")
        );
        println!(
            "  PASS: every cell cleared its occupancy-improvement floor"
        );
    }
}
