//! Fig. 13: Node-wise Rearrangement Algorithm ablation — average
//! inter-node communication volume of the dispatchers, per modality,
//! with and without the node-wise step — on 128 GPUs.
//!
//! Expected shape (paper): node-wise reduces inter-node volume to
//! 0.436–0.722 of the baseline, with per-modality variation (it's
//! effective for every tailored algorithm).
//!
//! Run: `cargo bench --bench fig13_nodewise`

use orchmllm::model::config::MllmConfig;
use orchmllm::sim::engine::{simulate_run, SystemKind};
use orchmllm::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let gpus = args.usize("gpus", 128);
    let steps = args.usize("steps", 5);
    let seed = args.u64("seed", 42);
    let mbs = [75usize, 50, 25];

    println!(
        "Fig. 13 — inter-node comm volume per dispatcher, MB/iter \
         ({gpus} GPUs):\n"
    );
    println!(
        "{:<10} {:>14} {:>14} {:>14} {:>8}",
        "model", "vision", "audio", "text", "ratio"
    );
    for (mi, model) in MllmConfig::all().iter().enumerate() {
        let with = simulate_run(
            SystemKind::OrchMllm, model, gpus, mbs[mi], steps, seed,
        );
        let without = simulate_run(
            SystemKind::NoNodewise, model, gpus, mbs[mi], steps, seed,
        );
        let total_with: f64 = with.inter_node_mb.iter().sum();
        let total_without: f64 = without.inter_node_mb.iter().sum();
        let ratio = total_with / total_without.max(1e-9);
        println!(
            "{:<10} {:>6.0} /{:>6.0} {:>6.0} /{:>6.0} {:>6.0} /{:>6.0} {:>8.3}",
            model.name,
            with.inter_node_mb[0],
            without.inter_node_mb[0],
            with.inter_node_mb[1],
            without.inter_node_mb[1],
            with.inter_node_mb[2],
            without.inter_node_mb[2],
            ratio,
        );
        assert!(
            ratio < 0.95,
            "{}: node-wise rearrangement saved nothing ({ratio:.3})",
            model.name
        );
        // Paper band is 0.436..0.722; allow generous margins for the
        // synthetic data but require the same order of magnitude.
        assert!(
            ratio > 0.2,
            "{}: ratio {ratio:.3} implausibly low",
            model.name
        );
    }
    println!(
        "\n(paper: per-modality reduction ratios in 0.436–0.722; cells \
         are with/without node-wise)"
    );
}
