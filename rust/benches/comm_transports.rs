//! Transport comparison bench: per-backend All-to-All / All-Gather
//! latency vs payload size, plus the calibrated α/β each backend fits
//! (`comm::calibrate`) next to the hard-coded `costmodel` constants
//! the dispatcher would otherwise assume.
//!
//! Expected shape: `inproc` moves ownership, so its latency is flat in
//! payload size (β saturates the fit cap); `tcp` pays a real bandwidth
//! term, so its latency grows with size and its fitted β is the
//! loopback throughput. The gap between fitted and hard-coded
//! constants is exactly what `--calibrate-comm` closes for the
//! planner.
//!
//! Emits `BENCH_comm_transports.json` so the numbers are tracked
//! across PRs.
//!
//! Run: `cargo bench --bench comm_transports` (`-- --smoke` runs a
//! tiny shape for CI bit-rot detection, skipping timing assertions).

use std::time::Instant;

use orchmllm::comm::calibrate::{fit_line, Calibration, BETA_CAP};
use orchmllm::comm::costmodel::pairwise_alltoall_cost;
use orchmllm::comm::transport::{registry, run_world, Transport};
use orchmllm::orchestrator::rearrangement::Rearrangement;
use orchmllm::sim::report;
use orchmllm::trainer;
use orchmllm::util::cli::Args;
use orchmllm::util::json::Json;

struct SizeSample {
    bytes: usize,
    a2a_min_us: f64,
    a2a_mean_us: f64,
    ag_min_us: f64,
    ag_mean_us: f64,
}

fn stats_us(samples: &[f64]) -> (f64, f64) {
    let min = samples.iter().copied().fold(f64::INFINITY, f64::min);
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    (min * 1e6, mean * 1e6)
}

/// One rank's SPMD measurement loop. The All-to-All realizes a shift
/// rearrangement (every rank ships one payload to its successor) built
/// through `Rearrangement::sends_from` — the same bridge the trainer
/// uses between a planned Π and a transport round.
fn worker_loop(
    t: Box<dyn Transport>,
    sizes: &[usize],
    reps: usize,
) -> Vec<SizeSample> {
    let d = t.world_size();
    let rank = t.rank();
    let shift = Rearrangement::new(
        (0..d).collect(),
        (0..d).map(|g| (g + 1) % d).collect(),
    );
    let my_sends = shift.sends_from(rank);
    let mut out = Vec::new();
    for &size in sizes {
        let payload = vec![0x5Au8; size];
        let mut a2a = Vec::with_capacity(reps);
        let mut ag = Vec::with_capacity(reps);
        for _ in 0..reps {
            // Clones hoisted out of the timed window.
            let sends: Vec<(usize, Vec<u8>)> = my_sends
                .iter()
                .map(|&(_g, dst)| (dst, payload.clone()))
                .collect();
            t.barrier().unwrap();
            let t0 = Instant::now();
            let recv = t.all_to_all_bytes(sends).unwrap();
            a2a.push(t0.elapsed().as_secs_f64());
            assert_eq!(recv.len(), 1, "shift must deliver one payload");
            assert_eq!(recv[0].1.len(), size);

            let contrib = payload.clone();
            t.barrier().unwrap();
            let t0 = Instant::now();
            let all = t.all_gather_bytes(contrib).unwrap();
            ag.push(t0.elapsed().as_secs_f64());
            assert_eq!(all.len(), d);
        }
        let (a2a_min_us, a2a_mean_us) = stats_us(&a2a);
        let (ag_min_us, ag_mean_us) = stats_us(&ag);
        out.push(SizeSample {
            bytes: size,
            a2a_min_us,
            a2a_mean_us,
            ag_min_us,
            ag_mean_us,
        });
    }
    out
}

fn measure_backend(
    name: &str,
    d: usize,
    sizes: &[usize],
    reps: usize,
) -> Vec<SizeSample> {
    let factory = registry::must(name);
    let out = run_world(factory.as_ref(), d, |t| worker_loop(t, sizes, reps))
        .unwrap_or_else(|e| panic!("{name}: bench world failed: {e:#}"));
    out.into_iter().next().expect("world had at least one rank")
}

fn main() {
    let args = Args::from_env();
    let smoke = args.flag("smoke");
    let d = args.usize("workers", 4);
    let reps = args.usize("reps", if smoke { 3 } else { 15 }).max(1);
    let sizes: Vec<usize> = if smoke {
        vec![1 << 10, 16 << 10]
    } else {
        vec![1 << 10, 8 << 10, 64 << 10, 256 << 10, 1 << 20]
    };

    println!(
        "== comm transports: d = {d}, {} payload sizes, {reps} reps ==",
        sizes.len()
    );
    let mut backends_json = Vec::new();
    let mut measured: Vec<(&str, Vec<SizeSample>)> = Vec::new();
    for name in registry::NAMES {
        let samples = measure_backend(name, d, &sizes, reps);
        println!("\n-- {name} --");
        println!(
            "{:<12}{:>14}{:>14}{:>14}{:>14}",
            "bytes", "a2a min us", "a2a mean us", "ag min us", "ag mean us"
        );
        for s in &samples {
            println!(
                "{:<12}{:>14.1}{:>14.1}{:>14.1}{:>14.1}",
                s.bytes, s.a2a_min_us, s.a2a_mean_us, s.ag_min_us,
                s.ag_mean_us
            );
        }
        measured.push((*name, samples));
    }

    // ---- calibration: fitted α/β vs the hard-coded constants -----------
    // Fit directly over the per-size minima measured above (the same
    // estimator `comm::calibrate` uses) instead of paying a second
    // sweep per backend; `calibrate()` itself is exercised end-to-end
    // by its unit tests and the `transports --calibrate` CLI.
    let analytic = trainer::worker_topology(d);
    let mut calibrations = Vec::new();
    for (name, samples) in &measured {
        let a2a_points: Vec<(f64, f64)> = samples
            .iter()
            .map(|s| (s.bytes as f64, s.a2a_min_us / 1e6))
            .collect();
        let ag_points: Vec<(f64, f64)> = samples
            .iter()
            .map(|s| (s.bytes as f64, s.ag_min_us / 1e6))
            .collect();
        let cal = Calibration {
            transport: name.to_string(),
            d,
            all_to_all: fit_line(&a2a_points),
            all_gather: fit_line(&ag_points),
            all_to_all_points: a2a_points,
            all_gather_points: ag_points,
        };
        print!("{}", report::render_calibration(&cal, &analytic));
        calibrations.push(cal);
    }

    // Schedule-aware prediction from the calibrated constants, at the
    // largest swept payload.
    let probe_bytes = *sizes.last().unwrap() as f64;
    for cal in &calibrations {
        let topo = cal.to_topology(trainer::WORKERS_PER_NODE.min(d));
        let pred = pairwise_alltoall_cost(&topo, probe_bytes);
        println!(
            "{}: pairwise-schedule prediction at {probe_bytes:.0} B: \
             {:.1} us",
            cal.transport,
            pred.seconds * 1e6
        );
    }

    // ---- shape checks (full scale only) --------------------------------
    if !smoke {
        // TCP must pay a real bandwidth term: the largest payload is
        // orders of magnitude bigger than the smallest, so even a noisy
        // run separates the minima.
        let tcp = measured
            .iter()
            .find(|(n, _)| *n == "tcp")
            .expect("tcp measured");
        let first = tcp.1.first().unwrap();
        let last = tcp.1.last().unwrap();
        assert!(
            last.a2a_min_us > first.a2a_min_us,
            "tcp all_to_all at {} B ({:.1} us) not slower than {} B \
             ({:.1} us)",
            last.bytes,
            last.a2a_min_us,
            first.bytes,
            first.a2a_min_us
        );
        let tcp_cal = calibrations
            .iter()
            .find(|c| c.transport == "tcp")
            .unwrap();
        // A clamped (degenerate) fit returns exactly BETA_CAP, so the
        // real check is "the slope was not clamped".
        assert!(
            tcp_cal.all_to_all.beta_bytes_per_s < BETA_CAP,
            "tcp fit produced no bandwidth slope (clamped to cap)"
        );
    }

    // ---- JSON emission (tracked across PRs) ----------------------------
    for ((name, samples), cal) in measured.iter().zip(&calibrations) {
        let points = Json::arr(samples.iter().map(|s| {
            Json::obj(vec![
                ("bytes", Json::num(s.bytes as f64)),
                ("a2a_min_us", Json::num(s.a2a_min_us)),
                ("a2a_mean_us", Json::num(s.a2a_mean_us)),
                ("ag_min_us", Json::num(s.ag_min_us)),
                ("ag_mean_us", Json::num(s.ag_mean_us)),
            ])
        }));
        backends_json.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("points", points),
            (
                "fit",
                Json::obj(vec![
                    (
                        "a2a_alpha_us",
                        Json::num(cal.all_to_all.alpha_s * 1e6),
                    ),
                    (
                        "a2a_beta_gbps",
                        Json::num(cal.all_to_all.beta_bytes_per_s / 1e9),
                    ),
                    (
                        "ag_alpha_us",
                        Json::num(cal.all_gather.alpha_s * 1e6),
                    ),
                    (
                        "ag_beta_gbps",
                        Json::num(cal.all_gather.beta_bytes_per_s / 1e9),
                    ),
                ]),
            ),
        ]));
    }
    let out = Json::obj(vec![
        ("bench", Json::str("comm_transports")),
        ("workers", Json::num(d as f64)),
        ("reps", Json::num(reps as f64)),
        ("smoke", Json::Bool(smoke)),
        ("backends", Json::arr(backends_json.into_iter())),
        (
            "costmodel_constants",
            Json::obj(vec![
                (
                    "worker_topology_base_latency_us",
                    Json::num(analytic.base_latency * 1e6),
                ),
                (
                    "worker_topology_intra_gbps",
                    Json::num(analytic.intra_bw / 1e9),
                ),
                (
                    "worker_topology_inter_gbps",
                    Json::num(analytic.inter_bw / 1e9),
                ),
            ]),
        ),
    ]);
    let path = "BENCH_comm_transports.json";
    std::fs::write(path, out.pretty()).expect("write bench json");
    println!("wrote {path}");
}
