//! Million-sequence planning throughput: the gate on the zero-copy +
//! SIMD hot-path work.
//!
//! For every registered balancer and d ∈ {64, 512, 2048}, plan a
//! ~10⁶-sequence step (n split evenly across the d instances) and
//! report the **cold** cost (a fresh session's first plan — the full
//! from-scratch solve, history and caches empty) against the **warm**
//! cost (the same recurring step replayed through the session's
//! step-level plan cache via [`PlanSession::plan_shared`] — a sketch +
//! key comparison and an `Arc` refcount bump, no `StepPlan` clone).
//! Each row carries the sequences-per-second both medians imply and
//! the process peak RSS (`VmHWM`) observed by the end of the cell.
//!
//! Acceptance (full scale only): the warm median must be ≥ 2× below
//! the cold median for the headline `greedy` balancer at d = 512.
//!
//! Emits `BENCH_plan_throughput.json` (tracked across PRs, uploaded by
//! the `plan-throughput` CI job).
//!
//! Run: `cargo bench --bench plan_throughput`
//!   `-- --smoke`            tiny CI shape (d = 8, n = 4096), no
//!                           acceptance assertions
//!   `-- --baseline <path>`  fail on warm-median regressions past the
//!                           checked-in per-(d, balancer) ceilings
//!   `-- --n <n>`            override the per-step sequence count
//!   `-- --cold-iters <k>` / `-- --warm-iters <k>`  sample counts

use std::time::Instant;

use orchmllm::balance::registry;
use orchmllm::comm::topology::Topology;
use orchmllm::data::synth::{DatasetConfig, Example, Generator};
use orchmllm::orchestrator::global::OrchestratorConfig;
use orchmllm::orchestrator::pipeline::PipelineConfig;
use orchmllm::orchestrator::session::{PlanOptions, PlanSession};
use orchmllm::util::cli::Args;
use orchmllm::util::json::Json;

/// `quadratic`'s comparator is O(n·d); past this work bound a single
/// cold solve takes minutes and stops measuring the hot paths this
/// bench exists for. Skipped cells are logged and listed in the JSON —
/// no silent truncation.
const QUADRATIC_MAX_WORK: usize = 1 << 30;

/// `cargo bench` runs with CWD at the package root (`rust/`), while
/// developers run from the workspace root — accept both.
fn read_either(path: &str) -> Option<String> {
    std::fs::read_to_string(path)
        .or_else(|_| std::fs::read_to_string(format!("../{path}")))
        .ok()
}

/// Process peak resident set (kB) from `/proc/self/status`. `None` on
/// platforms without procfs. VmHWM is a process-lifetime high-water
/// mark, so per-row values are cumulative, not per-cell.
fn peak_rss_kb() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    line.trim_start_matches("VmHWM:")
        .trim()
        .trim_end_matches("kB")
        .trim()
        .parse()
        .ok()
}

fn median(samples: &[f64]) -> f64 {
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("finite timings"));
    v[v.len() / 2]
}

fn min(samples: &[f64]) -> f64 {
    samples.iter().copied().fold(f64::INFINITY, f64::min)
}

struct Row {
    d: usize,
    n: usize,
    balancer: &'static str,
    cold_median_ms: f64,
    cold_min_ms: f64,
    warm_median_ms: f64,
    warm_min_ms: f64,
    step_cache_hits: u64,
    peak_rss_kb: Option<u64>,
}

fn main() {
    let args = Args::from_env();
    let smoke = args.flag("smoke");
    let seed = args.u64("seed", 7);
    let n_target = args.usize("n", if smoke { 4096 } else { 1_000_000 });
    let cold_iters = args.usize("cold-iters", if smoke { 2 } else { 3 });
    let warm_iters = args.usize("warm-iters", if smoke { 6 } else { 9 });
    let ds: &[usize] = if smoke { &[8] } else { &[64, 512, 2048] };

    let mut rows: Vec<Row> = Vec::new();
    let mut skipped: Vec<(usize, &str, String)> = Vec::new();

    for &d in ds {
        let mb = (n_target / d).max(1);
        let n = mb * d;
        let t0 = Instant::now();
        let mut generator = Generator::new(DatasetConfig::default(), seed);
        let minibatches: Vec<Vec<Example>> =
            (0..d).map(|_| generator.batch(mb)).collect();
        eprintln!(
            "d={d}: generated {n} sequences in {:.1}s",
            t0.elapsed().as_secs_f64()
        );

        for &name in registry::NAMES {
            if name == "quadratic"
                && n.saturating_mul(d) > QUADRATIC_MAX_WORK
            {
                let why = format!(
                    "O(n*d) comparator: n*d = {} > {QUADRATIC_MAX_WORK}",
                    n.saturating_mul(d)
                );
                eprintln!("  skip {name} at d={d}: {why}");
                skipped.push((d, name, why));
                continue;
            }
            let cfg = OrchestratorConfig::orchmllm(3584.0 * 2.0)
                .with_balancer(registry::must(name));
            let pipe = PipelineConfig::default();
            let topo = Topology::h100(d);

            // Cold: a fresh session's first plan — history empty, every
            // phase pays the from-scratch solve (plus the one-time
            // cache population the steady state amortizes).
            let mut cold = Vec::with_capacity(cold_iters);
            for _ in 0..cold_iters {
                let mut s = PlanSession::new(cfg.clone(), pipe, topo);
                let t = Instant::now();
                let plan =
                    s.plan_shared(&minibatches, PlanOptions::auto());
                cold.push(t.elapsed().as_secs_f64() * 1e3);
                std::hint::black_box(&plan);
            }

            // Warm: one session, one untimed populating pass, then
            // timed replays of the identical step — the plan_shared
            // zero-copy path (step-cache hit, Arc-shared plan).
            let mut s = PlanSession::new(cfg.clone(), pipe, topo);
            s.plan_shared(&minibatches, PlanOptions::auto());
            let mut warm = Vec::with_capacity(warm_iters);
            for _ in 0..warm_iters {
                let t = Instant::now();
                let plan =
                    s.plan_shared(&minibatches, PlanOptions::auto());
                warm.push(t.elapsed().as_secs_f64() * 1e3);
                std::hint::black_box(&plan);
            }
            let hits = s.stats().step_cache_hits();
            assert_eq!(
                hits as usize, warm_iters,
                "warm replays must hit the step cache (d={d}, {name})"
            );

            let cold_median_ms = median(&cold);
            let warm_median_ms = median(&warm);
            let hwm = peak_rss_kb();
            eprintln!(
                "  {name:<20} cold {cold_median_ms:>10.2} ms  warm \
                 {warm_median_ms:>9.3} ms  ({:>6.1}x)  rss {} MiB",
                cold_median_ms / warm_median_ms.max(1e-9),
                hwm.map(|kb| (kb / 1024).to_string())
                    .unwrap_or_else(|| "?".into())
            );
            rows.push(Row {
                d,
                n,
                balancer: name,
                cold_median_ms,
                cold_min_ms: min(&cold),
                warm_median_ms,
                warm_min_ms: min(&warm),
                step_cache_hits: hits,
                peak_rss_kb: hwm,
            });
        }
    }

    // ---- JSON emission (tracked across PRs, uploaded by CI) ------------
    let rows_json = Json::arr(rows.iter().map(|r| {
        let cold_sps = r.n as f64 / (r.cold_median_ms / 1e3).max(1e-12);
        let warm_sps = r.n as f64 / (r.warm_median_ms / 1e3).max(1e-12);
        Json::obj(vec![
            ("d", Json::num(r.d as f64)),
            ("n", Json::num(r.n as f64)),
            ("balancer", Json::str(r.balancer)),
            ("cold_median_ms", Json::num(r.cold_median_ms)),
            ("cold_min_ms", Json::num(r.cold_min_ms)),
            ("cold_seqs_per_sec", Json::num(cold_sps)),
            ("warm_median_ms", Json::num(r.warm_median_ms)),
            ("warm_min_ms", Json::num(r.warm_min_ms)),
            ("warm_seqs_per_sec", Json::num(warm_sps)),
            (
                "warm_over_cold_speedup",
                Json::num(
                    r.cold_median_ms / r.warm_median_ms.max(1e-9),
                ),
            ),
            ("step_cache_hits", Json::num(r.step_cache_hits as f64)),
            (
                "peak_rss_kb",
                r.peak_rss_kb
                    .map(|kb| Json::num(kb as f64))
                    .unwrap_or(Json::Null),
            ),
        ])
    }));
    let skipped_json = Json::arr(skipped.iter().map(|(d, name, why)| {
        Json::obj(vec![
            ("d", Json::num(*d as f64)),
            ("balancer", Json::str(name)),
            ("reason", Json::str(why)),
        ])
    }));
    let out = Json::obj(vec![
        ("bench", Json::str("plan_throughput")),
        ("smoke", Json::Bool(smoke)),
        ("seed", Json::num(seed as f64)),
        ("n_target", Json::num(n_target as f64)),
        ("cold_iters", Json::num(cold_iters as f64)),
        ("warm_iters", Json::num(warm_iters as f64)),
        ("rows", rows_json),
        ("skipped", skipped_json),
    ]);
    let path = "BENCH_plan_throughput.json";
    std::fs::write(path, out.pretty()).expect("write bench json");
    println!("wrote {path}");

    // ---- acceptance (full scale only) ----------------------------------
    if !smoke {
        for r in &rows {
            if r.balancer == "greedy" && r.d == 512 {
                let ratio =
                    r.cold_median_ms / r.warm_median_ms.max(1e-9);
                assert!(
                    ratio >= 2.0,
                    "acceptance: warm median must be >= 2x below cold \
                     at d=512 (cold {:.2} ms, warm {:.3} ms, only \
                     {ratio:.2}x)",
                    r.cold_median_ms,
                    r.warm_median_ms
                );
                println!(
                    "acceptance: d=512 greedy warm/cold = {ratio:.1}x \
                     (>= 2x required)"
                );
            }
        }
    }

    // ---- baseline gate -------------------------------------------------
    if let Some(baseline_path) = args.get("baseline") {
        let text = read_either(baseline_path).unwrap_or_else(|| {
            panic!("baseline '{baseline_path}' not found")
        });
        let baseline = Json::parse(&text)
            .unwrap_or_else(|e| panic!("{baseline_path}: {e}"));
        let slack = baseline.get("slack").as_f64().unwrap_or(1.0);
        let mut regressions = Vec::new();
        println!("\nbaseline gate ({baseline_path}, slack {slack}x):");
        for r in &rows {
            let ceiling = baseline
                .get("warm_median_ms")
                .get(&r.d.to_string())
                .get(r.balancer)
                .as_f64();
            let Some(c) = ceiling else {
                println!(
                    "  d={:<5} {:<20} warm {:>9.3} ms  (no ceiling — \
                     skipped)",
                    r.d, r.balancer, r.warm_median_ms
                );
                continue;
            };
            let limit = c * slack;
            let ok = r.warm_median_ms <= limit;
            println!(
                "  d={:<5} {:<20} warm {:>9.3} ms  (limit {:>9.3} ms) {}",
                r.d,
                r.balancer,
                r.warm_median_ms,
                limit,
                if ok { "ok" } else { "REGRESSED" }
            );
            if !ok {
                regressions.push(format!(
                    "d={} {}: warm median {:.3} ms > {:.3} ms \
                     ({:.1} ms ceiling x {:.1} slack)",
                    r.d,
                    r.balancer,
                    r.warm_median_ms,
                    limit,
                    c,
                    slack
                ));
            }
        }
        assert!(
            regressions.is_empty(),
            "plan-throughput regressions:\n  {}",
            regressions.join("\n  ")
        );
        println!("  PASS: no (d, balancer) cell regressed past its ceiling");
    }
}
