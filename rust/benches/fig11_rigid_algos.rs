//! Fig. 11: rigid post-balancing algorithms — forcing one algorithm on
//! every encoder phase (*all pad* / *all rmpad*) vs OrchMLLM's tailored
//! per-phase selection (no-padding for vision patches, padded for the
//! conv audio encoder) — on 128 GPUs.
//!
//! Expected shape (paper): both rigid variants lose MFU vs tailored on
//! every model size, demonstrating why §5.1 ships multiple algorithms.
//!
//! Run: `cargo bench --bench fig11_rigid_algos`

use orchmllm::model::config::MllmConfig;
use orchmllm::sim::engine::{simulate_run, SystemKind};
use orchmllm::sim::report;
use orchmllm::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let gpus = args.usize("gpus", 128);
    let steps = args.usize("steps", 3);
    let seed = args.u64("seed", 42);
    let mbs = [75usize, 50, 25];

    let systems = [
        SystemKind::OrchMllm,
        SystemKind::AllRmpad,
        SystemKind::AllPad,
    ];
    let mut rows = Vec::new();
    for system in systems {
        let mut row = Vec::new();
        for (mi, model) in MllmConfig::all().iter().enumerate() {
            row.push(simulate_run(
                system, model, gpus, mbs[mi], steps, seed,
            ));
        }
        rows.push(row);
    }
    println!(
        "Fig. 11 — rigid vs tailored algorithms ({gpus} GPUs):\n"
    );
    print!("{}", report::render_mfu_memory(&rows));

    for mi in 0..3 {
        let orch = rows[0][mi].mfu;
        let rmpad = rows[1][mi].mfu;
        let pad = rows[2][mi].mfu;
        println!(
            "{}: tailored {:.1}% | all-rmpad {:.1}% | all-pad {:.1}%",
            rows[0][mi].model_name,
            orch * 100.0,
            rmpad * 100.0,
            pad * 100.0
        );
        assert!(
            orch >= rmpad - 1e-9 && orch >= pad - 1e-9,
            "tailored selection lost to a rigid algorithm"
        );
    }
    // At least one size must show a real (>1%) gap for each rigid mode —
    // otherwise the ablation shows nothing.
    let gap_rmpad = (0..3)
        .map(|mi| rows[0][mi].mfu - rows[1][mi].mfu)
        .fold(0.0f64, f64::max);
    let gap_pad = (0..3)
        .map(|mi| rows[0][mi].mfu - rows[2][mi].mfu)
        .fold(0.0f64, f64::max);
    println!(
        "max MFU gap: vs all-rmpad {:.2}pp, vs all-pad {:.2}pp",
        gap_rmpad * 100.0,
        gap_pad * 100.0
    );
    // all-rmpad mis-balances the padded audio phase — a large, robust
    // effect. all-pad's penalty (padding waste on the vision phase) is
    // mild on our synthetic length distributions because the padded
    // algorithm packs length-runs with little waste; require the sign,
    // not the paper's magnitude.
    assert!(
        gap_rmpad > 0.01,
        "all-rmpad should clearly lose (audio phase mis-balanced)"
    );
    assert!(gap_pad > 0.0001, "all-pad should lose at least slightly");
}
