//! Micro-latency of the Batch Post-Balancing algorithms and the
//! node-wise rearrangement solvers — the "computation" share of the
//! Table-2 overhead (which the orchestrator overlaps with the forward
//! pass, §6). Sizes go up to the paper's production scale: d = 2560
//! instances × mb 80 ≈ 200k sequences.
//!
//! Run: `cargo bench --bench balance_algorithms`

use orchmllm::balance::{self, types::Policy};
use orchmllm::comm::topology::Topology;
use orchmllm::nodewise;
use orchmllm::util::bench::Bencher;
use orchmllm::util::rng::Pcg64;

fn main() {
    let mut rng = Pcg64::new(1);

    let mut b = Bencher::new("post-balancing algorithms");
    for (d, mb) in [(64usize, 60usize), (320, 60), (2560, 80)] {
        let n = d * mb;
        let lens = balance::synth_lengths(&mut rng, n, 5.5, 1.0);
        b.iter(&format!("alg1 greedy        d={d} n={n}"), || {
            balance::balance(Policy::GreedyUnpadded, &lens, d)
        });
        b.iter(&format!("alg2 padded        d={d} n={n}"), || {
            balance::balance(Policy::BinaryPadded, &lens, d)
        });
        if d <= 320 {
            b.iter(&format!("alg3 quadratic     d={d} n={n}"), || {
                balance::balance(
                    Policy::QuadraticUnpadded { lambda: 0.01, tolerance: 32.0 },
                    &lens,
                    d,
                )
            });
        }
        b.iter(&format!("alg4 convpad       d={d} n={n}"), || {
            balance::balance(Policy::ConvPadded { lambda: 0.001 }, &lens, d)
        });
    }
    b.report();

    let mut b2 = Bencher::new("node-wise rearrangement");
    for d in [16usize, 64, 128, 320] {
        let topo = Topology::h100(d);
        let mut v = orchmllm::comm::volume::VolumeMatrix::zeros(d);
        for i in 0..d {
            for j in 0..d {
                if rng.f64() > 0.6 {
                    v.add(i, j, rng.f64() * 1e6);
                }
            }
        }
        b2.iter(&format!("local search       d={d}"), || {
            nodewise::greedy::solve_local(&topo, &v)
        });
        if d <= 16 {
            b2.iter(&format!("exact B&B          d={d}"), || {
                nodewise::ilp::solve_exact(&topo, &v)
            });
        }
    }
    b2.report();

    // The paper's claim: dispatcher computation is tens of ms at 2560
    // GPUs and fully overlappable. Assert the algorithms stay in budget.
    let lens = balance::synth_lengths(&mut rng, 2560 * 80, 5.5, 1.0);
    let t0 = std::time::Instant::now();
    let _ = balance::balance(Policy::GreedyUnpadded, &lens, 2560);
    let alg1 = t0.elapsed();
    println!(
        "\nalg1 at paper scale (2560x80): {:.1} ms (budget: tens of ms)",
        alg1.as_secs_f64() * 1e3
    );
    assert!(alg1.as_millis() < 500, "alg1 too slow: {alg1:?}");
}
