//! Micro-latency of the Batch Post-Balancing algorithms and the
//! node-wise rearrangement solvers — the "computation" share of the
//! Table-2 overhead (which the orchestrator overlaps with the forward
//! pass, §6). Sizes go up to the paper's production scale: d = 2560
//! instances × mb 80 ≈ 200k sequences.
//!
//! Every algorithm is driven through the [`Balancer`] registry on a
//! reused [`PlanScratch`], i.e. exactly the dispatcher's hot path; a
//! fresh-allocation case is timed alongside so the scratch win is
//! visible.
//!
//! Run: `cargo bench --bench balance_algorithms`

use orchmllm::balance::incremental::BatchStat;
use orchmllm::balance::{self, registry, PlanScratch, Sketch};
use orchmllm::comm::topology::Topology;
use orchmllm::nodewise;
use orchmllm::util::bench::Bencher;
use orchmllm::util::rng::Pcg64;

fn main() {
    let mut rng = Pcg64::new(1);

    let mut b = Bencher::new("post-balancing algorithms (scratch reuse)");
    let mut scratch = PlanScratch::new();
    for (d, mb) in [(64usize, 60usize), (320, 60), (2560, 80)] {
        let n = d * mb;
        let lens = balance::synth_lengths(&mut rng, n, 5.5, 1.0);
        for name in ["greedy", "padded", "quadratic", "convpad", "kk"] {
            // The O(n·d) comparator stays at paper ablation scale, and
            // the kk row is only timed where it actually runs LDM
            // rather than its LPT fallback.
            if name == "quadratic" && d > 320 {
                continue;
            }
            if name == "kk"
                && n.saturating_mul(d) > orchmllm::balance::kk::KK_MAX_WORK
            {
                continue;
            }
            let balancer = registry::must(name);
            b.iter(&format!("{name:<10} d={d} n={n}"), || {
                balancer.balance(&lens, d, &mut scratch)
            });
        }
    }
    b.report();

    // Scratch reuse vs per-call allocation, at ablation scale.
    let mut b_alloc = Bencher::new("scratch reuse vs fresh allocation");
    let lens = balance::synth_lengths(&mut rng, 320 * 60, 5.5, 1.0);
    let greedy = registry::must("greedy");
    let reused = b_alloc
        .iter("greedy d=320 reused scratch", || {
            greedy.balance(&lens, 320, &mut scratch)
        })
        .mean_ns;
    let fresh = b_alloc
        .iter("greedy d=320 fresh scratch", || {
            greedy.balance(&lens, 320, &mut PlanScratch::new())
        })
        .mean_ns;
    b_alloc.report();
    println!(
        "\nscratch reuse saves {:.1}% on greedy d=320\n",
        100.0 * (fresh - reused) / fresh
    );

    // Oracle latency at gap-harness scale: `ilp` is not a per-step
    // solver, but its certify time bounds what the gap suite costs.
    let mut b_ilp = Bencher::new("exact oracle (balance/ilp)");
    for (n, d) in [(12usize, 3usize), (16, 4), (20, 4)] {
        let lens = balance::synth_lengths(&mut rng, n, 3.4, 1.1);
        b_ilp.iter(&format!("ilp::solve   n={n} d={d}"), || {
            orchmllm::balance::ilp::solve(
                &orchmllm::balance::CostModel::Linear { alpha: 1.0 },
                &lens,
                d,
                200_000,
            )
        });
    }
    b_ilp.report();

    // Planning-kernel microbenches: the SIMD-friendly inner loops the
    // incremental path leans on (DESIGN.md §Hot Paths). Each flat
    // kernel is timed against its scalar/streaming twin — the pairs are
    // pinned result-identical by unit tests, so the delta here is pure
    // instruction-level parallelism.
    let mut b_kernel = Bencher::new("planning kernels (SoA / multi-lane)");
    for n in [4_096usize, 200_000] {
        let lens = balance::synth_lengths(&mut rng, n, 5.5, 1.0);
        b_kernel.iter(&format!("sketch of_slice    n={n}"), || {
            Sketch::of(&lens, 64)
        });
        b_kernel.iter(&format!("sketch of_iter     n={n}"), || {
            Sketch::of_iter(lens.iter().copied(), 64)
        });
        b_kernel.iter(&format!("batchstat of_slice n={n}"), || {
            BatchStat::of_slice(&lens)
        });
        b_kernel.iter(&format!("batchstat fold-add n={n}"), || {
            let mut s = BatchStat::default();
            for &l in &lens {
                s.add(l);
            }
            s
        });
    }
    b_kernel.report();

    let mut b2 = Bencher::new("node-wise rearrangement");
    for d in [16usize, 64, 128, 320] {
        let topo = Topology::h100(d);
        let mut v = orchmllm::comm::volume::VolumeMatrix::zeros(d);
        for i in 0..d {
            for j in 0..d {
                if rng.f64() > 0.6 {
                    v.add(i, j, rng.f64() * 1e6);
                }
            }
        }
        b2.iter(&format!("local search       d={d}"), || {
            nodewise::greedy::solve_local(&topo, &v)
        });
        if d <= 16 {
            b2.iter(&format!("exact B&B          d={d}"), || {
                nodewise::ilp::solve_exact(&topo, &v)
            });
        }
    }
    b2.report();

    // The paper's claim: dispatcher computation is tens of ms at 2560
    // GPUs and fully overlappable. Assert the algorithms stay in budget.
    let lens = balance::synth_lengths(&mut rng, 2560 * 80, 5.5, 1.0);
    let greedy = registry::must("greedy");
    let t0 = std::time::Instant::now();
    let _ = greedy.balance(&lens, 2560, &mut scratch);
    let alg1 = t0.elapsed();
    println!(
        "\nalg1 at paper scale (2560x80): {:.1} ms (budget: tens of ms)",
        alg1.as_secs_f64() * 1e3
    );
    assert!(alg1.as_millis() < 500, "alg1 too slow: {alg1:?}");
}
