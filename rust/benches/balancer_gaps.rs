//! Approximation-gap suite: every registered heuristic vs the exact
//! `ilp` oracle across a grid of modality-incoherence profiles.
//!
//! Every future balancer PR becomes a measurable gap delta: the sweep
//! emits `BENCH_balancer_gaps.json` (per-heuristic, per-profile
//! mean/max gaps over oracle-certified cases), and `--baseline
//! ci/gap_baseline.json` gates the run against the checked-in
//! per-heuristic max-gap ceilings — CI fails on any regression past
//! the ceiling + slack.
//!
//! Run: `cargo bench --bench balancer_gaps`
//!   `-- --smoke`            the small CI grid (what the baseline gates)
//!   `-- --baseline <path>`  fail on regressions vs the checked-in file
//!   `-- --node-budget <n>`  override the oracle budget

use orchmllm::balance::gaps::{run_gap_suite, GapConfig};
use orchmllm::sim::report;
use orchmllm::util::cli::Args;
use orchmllm::util::json::Json;

/// `cargo bench` runs with CWD at the package root (`rust/`), while
/// developers run from the workspace root — accept both.
fn read_either(path: &str) -> Option<String> {
    std::fs::read_to_string(path)
        .or_else(|_| std::fs::read_to_string(format!("../{path}")))
        .ok()
}

fn main() {
    let args = Args::from_env();
    let smoke = args.flag("smoke");
    let mut cfg = if smoke { GapConfig::smoke() } else { GapConfig::full() };
    cfg.node_budget = args.usize("node-budget", cfg.node_budget);
    cfg.seed = args.u64("seed", cfg.seed);

    let t0 = std::time::Instant::now();
    let gaps = run_gap_suite(&cfg);
    eprintln!(
        "  swept {} rows in {:.1}s",
        gaps.rows.len(),
        t0.elapsed().as_secs_f64()
    );
    print!("{}", report::render_balancer_gaps(&gaps));

    // The oracle must actually be an oracle on this grid: a sweep where
    // it stopped certifying is a gap report against nothing. The gated
    // smoke grid must certify nearly everywhere; the larger full grid
    // is allowed more best-effort cells.
    let min_certified = if smoke { 0.8 } else { 0.5 };
    assert!(
        gaps.certified_fraction() >= min_certified,
        "oracle certified only {:.0}% of cases — shrink the grid or \
         raise --node-budget",
        gaps.certified_fraction() * 100.0
    );
    // Per heuristic too: certification varies by cost model (the
    // padded regimes have the loosest bounds), and a heuristic with no
    // certified cases would otherwise report a vacuous 0.0 gap.
    let min_certified_each = min_certified * 0.5;
    for &h in orchmllm::balance::gaps::GAP_HEURISTICS {
        assert!(
            gaps.certified_fraction_of(h) >= min_certified_each,
            "oracle certified only {:.0}% of {h}'s cases — its gap \
             ceiling would gate nothing",
            gaps.certified_fraction_of(h) * 100.0
        );
    }

    // ---- JSON emission (tracked across PRs, uploaded by CI) ------------
    let mut out = gaps.to_json();
    if let Json::Obj(m) = &mut out {
        m.insert("smoke".into(), Json::Bool(smoke));
    }
    let path = "BENCH_balancer_gaps.json";
    std::fs::write(path, out.pretty()).expect("write bench json");
    println!("wrote {path}");

    // ---- baseline gate -------------------------------------------------
    if let Some(baseline_path) = args.get("baseline") {
        let text = read_either(baseline_path).unwrap_or_else(|| {
            panic!("baseline '{baseline_path}' not found")
        });
        let baseline = Json::parse(&text)
            .unwrap_or_else(|e| panic!("{baseline_path}: {e}"));
        let regressions = gaps.check_baseline(&baseline);
        println!("\nbaseline gate ({baseline_path}):");
        for &h in orchmllm::balance::gaps::GAP_HEURISTICS {
            println!(
                "  {h:<12} max gap {:>7.4}  (ceiling {})",
                gaps.overall_max_gap(h),
                baseline
                    .get("max_gap")
                    .get(h)
                    .as_f64()
                    .map(|c| format!("{c:.4}"))
                    .unwrap_or_else(|| "missing".into())
            );
        }
        assert!(
            regressions.is_empty(),
            "approximation-gap regressions:\n  {}",
            regressions.join("\n  ")
        );
        println!("  PASS: no heuristic regressed past its ceiling");
    }
}
