//! Fig. 8 + Fig. 9: overall MFU and training throughput of OrchMLLM vs
//! Megatron-LM vs OrchMLLM-without-balance, for MLLM-10B/18B/84B on the
//! modelled 2560-H100 cluster (paper §8.1 settings: mb 80/60/30
//! balanced, 65/40/15 unbalanced; Megatron PP 2/4/10, TP 8, same GPUs).
//!
//! Expected shape (paper): OrchMLLM ≈ 41.6% MFU at 84B; 3.1–4.1x
//! Megatron's MFU; 1.5–2.0x the no-balance MFU, ratio growing with
//! model size.
//!
//! Run: `cargo bench --bench fig8_fig9_overall`

use orchmllm::model::config::MllmConfig;
use orchmllm::sim::engine::{simulate_run, SystemKind};
use orchmllm::sim::report;
use orchmllm::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let gpus = args.usize("gpus", 2560);
    let steps = args.usize("steps", 3);
    let seed = args.u64("seed", 42);
    let mb_orch = [80, 60, 30];
    let mb_none = [65, 40, 15];

    let mut rows = Vec::new();
    for system in
        [SystemKind::OrchMllm, SystemKind::Megatron, SystemKind::NoBalance]
    {
        let mut row = Vec::new();
        for (mi, model) in MllmConfig::all().iter().enumerate() {
            let mb = if system == SystemKind::NoBalance {
                mb_none[mi]
            } else {
                mb_orch[mi]
            };
            let t0 = std::time::Instant::now();
            let r = simulate_run(system, model, gpus, mb, steps, seed);
            eprintln!(
                "  simulated {} / {} in {:.1}s",
                system.name(),
                model.name,
                t0.elapsed().as_secs_f64()
            );
            row.push(r);
        }
        rows.push(row);
    }

    println!("Fig. 8/9 — overall results ({gpus} GPUs, {steps} steps):\n");
    print!("{}", report::render_overall(&rows));

    // Shape checks (who wins, by roughly what factor).
    for mi in 0..3 {
        let orch = &rows[0][mi];
        let mega = &rows[1][mi];
        let none = &rows[2][mi];
        let vs_mega = orch.mfu / mega.mfu.max(1e-9);
        let vs_none = orch.mfu / none.mfu.max(1e-9);
        println!(
            "{}: vs Megatron {vs_mega:.1}x (paper 3.1-4.1x), \
             vs no-balance {vs_none:.2}x (paper 1.5-2.0x)",
            orch.model_name
        );
        assert!(vs_mega > 2.0, "Megatron gap collapsed at {}", orch.model_name);
        assert!(vs_none > 1.2, "balance gain collapsed at {}", orch.model_name);
    }
    // The advantage over no-balance must grow with model size.
    let g10 = rows[0][0].mfu / rows[2][0].mfu;
    let g84 = rows[0][2].mfu / rows[2][2].mfu;
    assert!(
        g84 > g10,
        "balance advantage should grow with size: {g10:.2} vs {g84:.2}"
    );
}
