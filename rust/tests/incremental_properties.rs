//! Incremental-planning properties, registry-wide: for EVERY registered
//! balancer, `plan_incremental` must
//!
//! 1. produce a valid assignment — every example id exactly once,
//!    exactly `d` mini-batches — from any history (warm, diverged, or
//!    empty);
//! 2. stay within the documented repair tolerance of the from-scratch
//!    plan: `makespan(incremental) <= makespan(balance) ×
//!    (1 + REPAIR_TOLERANCE)` under the balancer's own cost model;
//! 3. never lose to the identity dealing (the `NoBalance` floor — the
//!    `Guarded` invariant extended to the incremental path);
//! 4. be a deterministic pure function of `(lens, d, prev)` (§5.2.1);
//! 5. fall back to the bit-exact from-scratch plan on divergence (empty
//!    phase, single-example batch, empty history, d mismatch);
//!
//! and the sketch-keyed caches must replay plans **bit-identically**:
//! a cache hit equals the miss that populated it, at the phase level
//! (dispatcher) and the step level (orchestrator).

use orchmllm::balance::incremental::{PlanSource, REPAIR_TOLERANCE};
use orchmllm::balance::types::{
    assert_valid_assignment, identity_with_lens,
};
use orchmllm::balance::{registry, PlanScratch};
use orchmllm::comm::topology::Topology;
use orchmllm::orchestrator::dispatcher::{
    Communicator, DispatchOptions, Dispatcher, PhaseHistory,
};
use orchmllm::orchestrator::global::OrchestratorConfig;
use orchmllm::orchestrator::pipeline::PipelineConfig;
use orchmllm::orchestrator::session::{PlanOptions, PlanSession};
use orchmllm::util::prop::{check, Gen};
use orchmllm::util::rng::Pcg64;

#[test]
fn every_balancer_warm_plan_is_valid_and_within_tolerance() {
    check("incremental tolerance", 60, |g| {
        let d = g.usize(1, 10);
        let n = g.usize(0, 120);
        // Two draws from the same distribution: consecutive steps.
        let lens_prev = g.seq_lengths(n, 3.3, 1.2);
        let lens_now = g.seq_lengths(n, 3.3, 1.2);
        let mut scratch = PlanScratch::new();
        for name in registry::NAMES {
            let b = registry::must(name);
            let prev = b.balance(&lens_prev, d, &mut scratch);
            let inc =
                b.plan_incremental(&lens_now, d, &prev, &mut scratch);
            assert_valid_assignment(&inc.assignment, n, d);

            let cm = b.cost_model();
            let from_scratch = b.balance(&lens_now, d, &mut scratch);
            assert!(
                cm.makespan(&inc.assignment)
                    <= cm.makespan(&from_scratch)
                        * (1.0 + REPAIR_TOLERANCE)
                        + 1e-6,
                "{name}: incremental {} exceeds tolerance over \
                 from-scratch {}",
                cm.makespan(&inc.assignment),
                cm.makespan(&from_scratch)
            );
            // The NoBalance floor holds on the incremental path too.
            let identity = identity_with_lens(&lens_now, d);
            assert!(
                cm.makespan(&inc.assignment)
                    <= cm.makespan(&identity) + 1e-6,
                "{name}: incremental {} worse than NoBalance {}",
                cm.makespan(&inc.assignment),
                cm.makespan(&identity)
            );
        }
    });
}

#[test]
fn every_balancer_is_deterministic_incrementally() {
    check("incremental determinism", 30, |g| {
        let d = g.usize(1, 8);
        let n = g.usize(0, 100);
        let lens_prev = g.seq_lengths(n, 3.2, 1.1);
        let lens_now = g.seq_lengths(n, 3.2, 1.1);
        let mut scratch = PlanScratch::new();
        for name in registry::NAMES {
            let b = registry::must(name);
            let prev = b.balance(&lens_prev, d, &mut scratch);
            let a =
                b.plan_incremental(&lens_now, d, &prev, &mut scratch);
            let b2 = b.plan_incremental(
                &lens_now,
                d,
                &prev,
                &mut PlanScratch::new(),
            );
            assert_eq!(
                a.assignment, b2.assignment,
                "{name}: incremental plan nondeterministic"
            );
            assert_eq!(a.source, b2.source, "{name}: source flapped");
        }
    });
}

#[test]
fn divergence_falls_back_to_the_bit_exact_cold_plan() {
    let mut scratch = PlanScratch::new();
    let mut g = Gen::new(17);
    let lens_prev = g.seq_lengths(64, 3.4, 1.0);
    for name in registry::NAMES {
        let b = registry::must(name);
        let prev = b.balance(&lens_prev, 4, &mut scratch);

        // Empty phase: nothing to plan, but the result must be valid
        // and exactly the cold plan.
        let inc = b.plan_incremental(&[], 4, &prev, &mut scratch);
        assert_valid_assignment(&inc.assignment, 0, 4);
        assert_eq!(inc.assignment, b.balance(&[], 4, &mut scratch));
        assert_eq!(inc.source, PlanSource::Cold, "{name}: empty phase");

        // Single-example batch against a 64-example history: diverged.
        let inc = b.plan_incremental(&[37], 4, &prev, &mut scratch);
        assert_valid_assignment(&inc.assignment, 1, 4);
        assert_eq!(inc.assignment, b.balance(&[37], 4, &mut scratch));
        assert_eq!(inc.source, PlanSource::Cold, "{name}: single ex");

        // Empty history: the very first step is always cold.
        let inc = b.plan_incremental(
            &lens_prev,
            4,
            &Vec::new(),
            &mut scratch,
        );
        assert_eq!(
            inc.assignment,
            b.balance(&lens_prev, 4, &mut scratch),
            "{name}: empty history must plan cold"
        );

        // d changed between steps (elastic resize): diverged.
        let inc =
            b.plan_incremental(&lens_prev, 6, &prev, &mut scratch);
        assert_valid_assignment(&inc.assignment, lens_prev.len(), 6);
        assert_eq!(
            inc.assignment,
            b.balance(&lens_prev, 6, &mut scratch),
            "{name}: d mismatch must plan cold"
        );
    }
}

fn dispatch_setup(
    d: usize,
    n_per: usize,
    seed: u64,
) -> (Topology, Vec<usize>, Vec<usize>, Vec<f64>) {
    let topo = Topology::h100(d);
    let mut rng = Pcg64::new(seed);
    let n = d * n_per;
    let placement: Vec<usize> = (0..n).map(|g| g / n_per).collect();
    let lens: Vec<usize> = (0..n).map(|_| rng.range(1, 2048)).collect();
    let payload: Vec<f64> =
        lens.iter().map(|&l| (l * 4) as f64).collect();
    (topo, placement, lens, payload)
}

#[test]
fn phase_cache_hits_are_bit_identical_for_every_balancer() {
    let (topo, placement, lens, payload) = dispatch_setup(6, 12, 23);
    let mut scratch = PlanScratch::new();
    for name in registry::NAMES {
        let dp = Dispatcher::by_name(
            name,
            Communicator::AllToAll { nodewise: true },
        )
        .expect("registered name");
        let mut history = PhaseHistory::new(8);
        let miss = dp.dispatch(
            &topo,
            &placement,
            &lens,
            &payload,
            &mut scratch,
            DispatchOptions::incremental(&mut history),
        );
        let hit = dp.dispatch(
            &topo,
            &placement,
            &lens,
            &payload,
            &mut scratch,
            DispatchOptions::incremental(&mut history),
        );
        if dp.balancer.is_identity() {
            continue; // identity path never consults the cache
        }
        assert_eq!(
            hit.source,
            PlanSource::Cached,
            "{name}: second identical dispatch must hit the cache"
        );
        assert_eq!(hit.assignment, miss.assignment, "{name}");
        assert_eq!(hit.route, miss.route, "{name}");
        assert_eq!(hit.nodewise_perm, miss.nodewise_perm, "{name}");
        assert_eq!(hit.comm, miss.comm, "{name}");
    }
}

#[test]
fn step_cache_hit_equals_the_plan_that_populated_it() {
    let topo = Topology::h100(6);
    let mut g = orchmllm::data::synth::Generator::new(
        orchmllm::data::synth::DatasetConfig::default(),
        31,
    );
    let mbs: Vec<Vec<orchmllm::data::synth::Example>> =
        (0..6).map(|_| g.batch(10)).collect();
    let mut session = PlanSession::new(
        OrchestratorConfig::orchmllm(7168.0),
        PipelineConfig { plan_cache_size: 8, ..Default::default() },
        topo,
    );
    let miss = session.plan(&mbs, PlanOptions::auto());
    let hit = session.plan(&mbs, PlanOptions::auto());
    assert_eq!(hit.plan_sources(), [PlanSource::Cached; 3]);
    assert_eq!(hit.llm.assignment, miss.llm.assignment);
    assert_eq!(hit.llm.route, miss.llm.route);
    assert_eq!(hit.vision.plan.assignment, miss.vision.plan.assignment);
    assert_eq!(hit.vision.out_route, miss.vision.out_route);
    assert_eq!(hit.audio.out_route, miss.audio.out_route);
    assert_eq!(hit.examples, miss.examples);
    assert_eq!(hit.home, miss.home);
}

#[test]
fn warm_steps_keep_the_guarded_floor_under_drift() {
    // Simulate a drifting workload: each step's lengths shift scale a
    // little. Every step's incremental plan must stay valid and keep
    // the NoBalance floor, whether it planned warm or cold.
    let mut scratch = PlanScratch::new();
    let mut g = Gen::new(41);
    for name in registry::NAMES {
        let b = registry::must(name);
        let cm = b.cost_model();
        let d = 5;
        let mut prev = Vec::new();
        for step in 0..6 {
            let mu = 3.0 + 0.15 * step as f64;
            let lens = g.seq_lengths(60, mu, 1.0);
            let inc = b.plan_incremental(&lens, d, &prev, &mut scratch);
            assert_valid_assignment(&inc.assignment, lens.len(), d);
            let identity = identity_with_lens(&lens, d);
            assert!(
                cm.makespan(&inc.assignment)
                    <= cm.makespan(&identity) + 1e-6,
                "{name} step {step}: floor broken"
            );
            prev = inc.assignment;
        }
    }
}
