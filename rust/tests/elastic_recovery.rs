//! End-to-end elastic recovery: kill one rank mid-run, survivors
//! re-rendezvous at a bumped epoch, re-plan over the shrunk world, and
//! finish with a loss trajectory that *bit-matches* the reference.
//!
//! The reference for a hard death at step N is a *resignation* run in
//! which the same rank leaves cleanly before step N: parameters are
//! only mutated by completed steps, the interrupted step applied no
//! update on any rank, and the per-step global batch is sampled at a
//! fixed stream width — so both runs share the identical world-4
//! prefix and the identical world-3 suffix, down to the bit.

use orchmllm::config::TrainRunConfig;
use orchmllm::trainer::elastic::{
    run_elastic_collect, run_elastic_collect_with, run_multiproc,
    FaultPlan, WorldTransition,
};

fn cfg(workers: usize, steps: usize) -> TrainRunConfig {
    TrainRunConfig {
        workers,
        mini_batch: 3,
        steps,
        lr: 0.05,
        seed: 9,
        min_world: 2,
        transport: "inproc".into(),
        ..TrainRunConfig::default()
    }
}

#[test]
fn inproc_hard_death_bit_matches_the_resignation_reference() {
    // Rank 2 of 4 is hard-killed immediately before step 3's planned
    // all-to-all (collective 1) — survivors detect a typed peer death
    // mid-step, shrink, and re-execute step 3 at world 3.
    let hard = run_elastic_collect(
        &cfg(4, 6),
        FaultPlan::kill(2, 3).at_collective(1),
    )
    .expect("hard-death run");
    // Reference: the same rank resigns cleanly before step 3.
    let reference =
        run_elastic_collect(&cfg(4, 6), FaultPlan::resignation(2, 3))
            .expect("resignation run");

    assert_eq!(hard.losses.len(), 6);
    assert_eq!(hard.losses, reference.losses, "recovery must bit-match");
    let expected_transitions = vec![WorldTransition {
        step: 3,
        epoch: 1,
        from: 4,
        to: 3,
        dead: vec![2],
    }];
    assert_eq!(hard.transitions, expected_transitions);
    assert_eq!(reference.transitions, expected_transitions);

    // The pre-fault prefix is exactly the fault-free world-4 run.
    let healthy = run_elastic_collect(&cfg(4, 6), FaultPlan::none())
        .expect("fault-free run");
    assert!(healthy.transitions.is_empty());
    assert_eq!(healthy.losses[..3], hard.losses[..3]);

    // A from-scratch run at the shrunk world over the *same* data
    // stream (stream width pinned to 4) agrees closely after the
    // fault point — not bitwise, because its pre-fault steps reduced
    // gradients with a different rank grouping.
    let scratch3 =
        run_elastic_collect_with(&cfg(3, 6), FaultPlan::none(), 4)
            .expect("shrunk-world reference");
    for (i, (a, b)) in
        hard.losses[3..].iter().zip(&scratch3.losses[3..]).enumerate()
    {
        assert!(
            (a - b).abs() < 1e-3,
            "post-fault step {}: elastic {a} vs from-scratch {b}",
            i + 3
        );
    }
}

#[test]
fn min_world_floor_refuses_to_shrink_below() {
    // A 4-rank run floored at 4 cannot survive losing a rank: the
    // survivors must abort with the floor error, not limp on at 3.
    let mut c = cfg(4, 6);
    c.min_world = 4;
    let err = run_elastic_collect(&c, FaultPlan::kill(2, 3))
        .expect_err("shrinking below the floor must fail");
    let msg = format!("{err:#}");
    assert!(msg.contains("--min-world"), "{msg}");

    // And validate() rejects a floor above the launch world outright.
    c.min_world = 5;
    let err = c.validate().expect_err("floor above world");
    assert!(err.to_string().contains("--min-world"), "{err}");
}

#[test]
fn tcp_multiproc_processes_survive_a_mid_run_death() {
    // Same fault, but every member is a real OS process over loopback
    // sockets and the file rendezvous — spawned from this crate's own
    // binary. The rank-order all-reduce is bit-stable across backends,
    // so the surviving processes' trajectory bit-matches the inproc
    // resignation reference.
    let bin = std::path::Path::new(env!("CARGO_BIN_EXE_orchmllm"));
    let mut c = cfg(4, 6);
    c.transport = "tcp-multiproc".into();
    let report = run_multiproc(&c, FaultPlan::kill(2, 3), bin)
        .expect("multi-process hard-death run");

    let reference =
        run_elastic_collect(&cfg(4, 6), FaultPlan::resignation(2, 3))
            .expect("resignation run");
    assert_eq!(report.losses, reference.losses);
    assert_eq!(
        report.transitions,
        vec![WorldTransition {
            step: 3,
            epoch: 1,
            from: 4,
            to: 3,
            dead: vec![2],
        }]
    );
}
