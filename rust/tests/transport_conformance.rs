//! Transport conformance suite: one parameterized battery run against
//! every backend in `comm::transport::registry`, pinning the SPMD
//! contract the trainer depends on — routing, rank order, multi-payload
//! pairs, round isolation, empty sends, the d = 1 degenerate, and the
//! bit-stable rank-order all-reduce. Plus the trainer-invariance check:
//! a full training step must be bit-identical whether the bytes move
//! over in-process channels or loopback TCP sockets.
//!
//! CI runs this file with `--test-threads=1`; the TCP backend binds
//! ephemeral ports by default (`ORCHMLLM_TCP_BASE_PORT` overrides), so
//! parallel local runs are safe too.

use std::time::Duration;

use orchmllm::comm::transport::inproc::InProcFactory;
use orchmllm::comm::transport::mesh::TcpMeshFactory;
use orchmllm::comm::transport::tcp::TcpLoopbackFactory;
use orchmllm::comm::transport::{
    self, peer_dead, registry, Transport, TransportExt, TransportFactory,
};

/// Run `f` on every rank of a `d`-rank world of the named backend and
/// collect the per-rank results in rank order (thin wrapper over the
/// shared `transport::run_world` harness, adding the backend name to
/// failures).
fn run_world<R, F>(backend: &str, d: usize, f: F) -> Vec<R>
where
    F: Fn(Box<dyn Transport>) -> R + Send + Sync,
    R: Send,
{
    let factory = registry::must(backend);
    let out = transport::run_world(factory.as_ref(), d, f)
        .unwrap_or_else(|e| panic!("{backend}: world of {d} failed: {e:#}"));
    assert_eq!(out.len(), d, "{backend}: wrong rank count");
    out
}

/// Run `test` against every registered backend, so a new transport
/// inherits the whole battery by registering itself.
fn for_every_backend(test: fn(&'static str)) {
    for name in registry::NAMES {
        test(name);
    }
}

#[test]
fn handles_are_rank_scoped() {
    for_every_backend(|backend| {
        let out = run_world(backend, 3, |t| (t.rank(), t.world_size()));
        assert_eq!(out, vec![(0, 3), (1, 3), (2, 3)], "{backend}");
    });
}

#[test]
fn all_to_all_routes_every_pair() {
    for_every_backend(|backend| {
        let d = 4;
        let out = run_world(backend, d, move |t| {
            let rank = t.rank();
            // Everyone sends one tagged payload to every rank,
            // including itself (loopback).
            let sends: Vec<(usize, Vec<u8>)> = (0..d)
                .map(|dst| (dst, vec![rank as u8, dst as u8]))
                .collect();
            t.all_to_all_bytes(sends).unwrap()
        });
        for (rank, got) in out.into_iter().enumerate() {
            let want: Vec<(usize, Vec<u8>)> = (0..d)
                .map(|src| (src, vec![src as u8, rank as u8]))
                .collect();
            assert_eq!(got, want, "{backend} rank {rank}");
        }
    });
}

#[test]
fn all_to_all_preserves_per_source_send_order() {
    for_every_backend(|backend| {
        let out = run_world(backend, 2, |t| {
            let rank = t.rank();
            let sends = if rank == 0 {
                vec![
                    (1, vec![7u8]),
                    (1, vec![8u8]),
                    (0, vec![1u8]),
                    (1, vec![9u8]),
                ]
            } else {
                vec![]
            };
            t.all_to_all_bytes(sends).unwrap()
        });
        // Rank 0 keeps its self-send; rank 1 sees 7, 8, 9 in order.
        assert_eq!(out[0], vec![(0, vec![1u8])], "{backend}");
        assert_eq!(
            out[1],
            vec![(0, vec![7u8]), (0, vec![8u8]), (0, vec![9u8])],
            "{backend}"
        );
    });
}

#[test]
fn all_gather_returns_rank_order() {
    for_every_backend(|backend| {
        let d = 4;
        let out = run_world(backend, d, move |t| {
            t.all_gather_bytes(vec![t.rank() as u8; 3]).unwrap()
        });
        for got in out {
            assert_eq!(
                got,
                (0..d).map(|r| vec![r as u8; 3]).collect::<Vec<_>>(),
                "{backend}"
            );
        }
    });
}

#[test]
fn rounds_are_isolated() {
    // Interleave every collective kind for several rounds; each round
    // must deliver exactly its own payloads (no leaks, no replays).
    for_every_backend(|backend| {
        let d = 3;
        let out = run_world(backend, d, move |t| {
            let rank = t.rank();
            let mut log = Vec::new();
            for round in 0..5u8 {
                let recv = t
                    .all_to_all_bytes(vec![(
                        (rank + 1) % d,
                        vec![round, rank as u8],
                    )])
                    .unwrap();
                assert_eq!(recv.len(), 1, "{backend} round {round} leaked");
                assert_eq!(
                    recv[0],
                    ((rank + d - 1) % d, vec![round, ((rank + d - 1) % d) as u8]),
                    "{backend} round {round}"
                );
                let all =
                    t.all_gather_bytes(vec![round, rank as u8]).unwrap();
                assert_eq!(
                    all,
                    (0..d)
                        .map(|r| vec![round, r as u8])
                        .collect::<Vec<_>>(),
                    "{backend} round {round} stale gather"
                );
                t.barrier().unwrap();
                log.push(recv[0].1[0]);
            }
            log
        });
        for got in out {
            assert_eq!(got, vec![0, 1, 2, 3, 4], "{backend}");
        }
    });
}

#[test]
fn empty_sends_are_valid_rounds() {
    for_every_backend(|backend| {
        let d = 3;
        let out = run_world(backend, d, move |t| {
            // A round where nobody sends anything…
            let recv = t.all_to_all_bytes(vec![]).unwrap();
            assert!(recv.is_empty(), "{backend}");
            // …and one where payloads are zero-length but present.
            let recv = t
                .all_to_all_bytes(vec![((t.rank() + 1) % d, Vec::new())])
                .unwrap();
            assert_eq!(recv.len(), 1, "{backend}");
            assert!(recv[0].1.is_empty(), "{backend}");
            // Empty gather contribution.
            let all = t.all_gather_bytes(Vec::new()).unwrap();
            assert_eq!(all, vec![Vec::<u8>::new(); d], "{backend}");
        });
        assert_eq!(out.len(), d);
    });
}

#[test]
fn single_rank_world_degenerates() {
    for_every_backend(|backend| {
        let out = run_world(backend, 1, |t| {
            assert_eq!(t.world_size(), 1);
            let recv = t
                .all_to_all_bytes(vec![(0, vec![1u8]), (0, vec![2u8])])
                .unwrap();
            assert_eq!(recv, vec![(0, vec![1u8]), (0, vec![2u8])]);
            assert_eq!(
                t.all_gather_bytes(vec![9u8]).unwrap(),
                vec![vec![9u8]]
            );
            t.barrier().unwrap();
            let mut data = vec![1.5f32, -2.0];
            t.all_reduce_sum(&mut data).unwrap();
            assert_eq!(data, vec![1.5, -2.0]);
        });
        assert_eq!(out.len(), 1);
    });
}

#[test]
fn out_of_range_destination_errors_before_traffic() {
    for_every_backend(|backend| {
        let d = 2;
        let out = run_world(backend, d, move |t| {
            // SPMD-consistent bad call: every rank attempts it, every
            // rank must get a local error without touching the group…
            let err = t
                .all_to_all_bytes(vec![(d, vec![0u8])])
                .unwrap_err()
                .to_string();
            assert!(err.contains("out of range"), "{backend}: {err}");
            // …so a following good round still lines up.
            let rank = t.rank();
            let recv = t
                .all_to_all_bytes(vec![(1 - rank, vec![rank as u8])])
                .unwrap();
            assert_eq!(recv, vec![(1 - rank, vec![(1 - rank) as u8])]);
        });
        assert_eq!(out.len(), d);
    });
}

#[test]
fn all_reduce_is_bit_stable_rank_order() {
    // Values chosen so floating-point addition order is observable:
    // summing big + small + small in a different order changes the
    // result. The contract is "accumulate in increasing rank order".
    for_every_backend(|backend| {
        let d = 4;
        // Lengths exercise uneven chunking (n % d != 0) and n < d.
        for n in [1usize, 3, 10, 17] {
            let out = run_world(backend, d, move |t| {
                let rank = t.rank();
                let mut data: Vec<f32> = (0..n)
                    .map(|i| {
                        if rank == 0 {
                            1.0e8 + i as f32
                        } else {
                            0.25 + (rank * n + i) as f32 * 1e-3
                        }
                    })
                    .collect();
                t.all_reduce_sum(&mut data).unwrap();
                data
            });
            // Reference: strict rank-order accumulation.
            let mut want = vec![0.0f32; n];
            for rank in 0..d {
                for (i, w) in want.iter_mut().enumerate() {
                    let x = if rank == 0 {
                        1.0e8 + i as f32
                    } else {
                        0.25 + (rank * n + i) as f32 * 1e-3
                    };
                    *w += x;
                }
            }
            for (rank, got) in out.into_iter().enumerate() {
                assert_eq!(got, want, "{backend} rank {rank} n {n}");
            }
        }
    });
}

#[test]
fn typed_payloads_cross_every_backend() {
    // The trainer's actual Wire payloads (batch shards) through the
    // typed extension layer.
    for_every_backend(|backend| {
        let d = 3;
        let out = run_world(backend, d, move |t| {
            let rank = t.rank();
            let sends: Vec<(usize, (usize, Vec<f32>))> = (0..d)
                .map(|dst| (dst, (rank * 100 + dst, vec![rank as f32; 4])))
                .collect();
            let recv = t.all_to_all::<(usize, Vec<f32>)>(sends).unwrap();
            for (src, (id, rows)) in &recv {
                assert_eq!(*id, src * 100 + rank, "{backend}");
                assert_eq!(rows, &vec![*src as f32; 4], "{backend}");
            }
            let texts =
                t.all_gather(&(rank, vec![rank as i32; 2])).unwrap();
            texts
        });
        for got in out {
            let want: Vec<(usize, Vec<i32>)> =
                (0..d).map(|r| (r, vec![r as i32; 2])).collect();
            assert_eq!(got, want, "{backend}");
        }
    });
}

#[test]
fn shard_fast_path_agrees_with_the_wire_path() {
    // `inproc` overrides `all_to_all_shards` with an Arc-moving fast
    // path; `tcp` takes the Wire-encoding default. Both must deliver
    // the same logical shards in the same order — and mixed dtypes in
    // one round must survive every backend.
    use orchmllm::comm::transport::Shard;
    let d = 3;
    let program = move |t: Box<dyn Transport>| -> Vec<(usize, Shard)> {
        let rank = t.rank();
        let mut sends: Vec<(usize, Shard)> = Vec::new();
        for dst in 0..d {
            sends.push((
                dst,
                Shard::f32(rank * 10 + dst, vec![rank as f32 + 0.5; 3]),
            ));
            sends.push((
                dst,
                Shard::i32(rank * 10 + dst, vec![-(rank as i32); 2]),
            ));
        }
        t.all_to_all_shards(sends).unwrap()
    };
    let mut reference: Option<Vec<Vec<(usize, Shard)>>> = None;
    for name in registry::NAMES {
        let out = run_world(name, d, program);
        for (rank, recv) in out.iter().enumerate() {
            assert_eq!(recv.len(), 2 * d, "{name} rank {rank}");
            for (src, shard) in recv {
                assert_eq!(shard.id(), src * 10 + rank, "{name}");
            }
        }
        match &reference {
            None => reference = Some(out),
            Some(r) => {
                assert_eq!(&out, r, "{name} shard routing diverges");
            }
        }
    }
}

#[test]
fn backends_agree_bit_for_bit() {
    // The same deterministic SPMD program must produce identical bytes
    // on every backend — the cheap cross-backend invariance check that
    // does not need trainer artifacts.
    let d = 3;
    let program = move |t: Box<dyn Transport>| -> (Vec<(usize, Vec<u8>)>, Vec<Vec<u8>>, Vec<f32>) {
        let rank = t.rank();
        let a2a = t
            .all_to_all_bytes(
                (0..d)
                    .map(|dst| (dst, vec![(rank * 7 + dst) as u8; 5]))
                    .collect(),
            )
            .unwrap();
        let ag = t.all_gather_bytes(vec![rank as u8; 9]).unwrap();
        let mut grads: Vec<f32> =
            (0..13).map(|i| (rank + 1) as f32 * 0.1 + i as f32).collect();
        t.all_reduce_sum(&mut grads).unwrap();
        (a2a, ag, grads)
    };
    let mut reference: Option<Vec<_>> = None;
    for name in registry::NAMES {
        let out = run_world(name, d, program);
        match &reference {
            None => reference = Some(out),
            Some(r) => assert_eq!(&out, r, "{name} diverges from {:?}", registry::NAMES[0]),
        }
    }
}

// ---------------------------------------------------------------------------
// Trainer invariance across transports (the TCP trainer smoke test)
// ---------------------------------------------------------------------------

/// Full trainer step over real PJRT artifacts: bit-identical metrics
/// in-proc vs TCP-loopback. Skips (like every trainer test) when
/// `make artifacts` has not produced `artifacts/test`.
#[test]
fn trainer_step_bit_identical_across_transports() {
    use orchmllm::config::TrainRunConfig;
    use orchmllm::trainer;

    if !std::path::Path::new("artifacts/test/manifest.json").exists() {
        eprintln!("skipping: artifacts/test not built");
        return;
    }
    let base = TrainRunConfig {
        artifacts: "artifacts/test".into(),
        workers: 2,
        mini_batch: 3,
        steps: 3,
        lr: 2.0,
        seed: 11,
        ..TrainRunConfig::default()
    };
    let inproc = trainer::run_collect(&TrainRunConfig {
        transport: "inproc".into(),
        ..base.clone()
    })
    .unwrap();
    let tcp = trainer::run_collect(&TrainRunConfig {
        transport: "tcp".into(),
        ..base
    })
    .unwrap();
    // Bit-identical, not approximately equal: the transports carry the
    // same bytes and the reduction order is fixed.
    assert_eq!(inproc.losses, tcp.losses);
    assert_eq!(inproc.tokens_per_step, tcp.tokens_per_step);
}

// ---------------------------------------------------------------------------
// Unified failure semantics: a dead rank surfaces as a typed PeerDead
// ---------------------------------------------------------------------------

/// Every backend must turn a rank that dies *before* a collective into
/// a typed `TransportError::PeerDead` on the survivors — within the
/// backend's timeout, never a hang, never a panic — for every
/// collective kind. This is the contract the elastic trainer's
/// recovery path is built on.
#[test]
fn dead_rank_surfaces_typed_peer_death_for_every_collective() {
    // Short timeouts keep detection latency test-sized; semantics are
    // identical at the production defaults.
    let factories: Vec<(&str, Box<dyn TransportFactory>)> = vec![
        (
            "inproc",
            Box::new(InProcFactory {
                watchdog: Some(Duration::from_millis(300)),
            }),
        ),
        (
            "tcp",
            Box::new(TcpLoopbackFactory {
                base_port: 0,
                timeout: Some(Duration::from_secs(2)),
            }),
        ),
        (
            "tcp-multiproc",
            Box::new(TcpMeshFactory {
                timeout: Some(Duration::from_secs(2)),
            }),
        ),
    ];
    for (name, factory) in &factories {
        for kind in ["barrier", "all_to_all", "all_gather", "all_reduce"] {
            let out = transport::run_world(factory.as_ref(), 3, |t| {
                if t.rank() == 1 {
                    // Rank 1 dies before the collective: dropping the
                    // handle closes sockets / abandons the barrier.
                    drop(t);
                    return None;
                }
                let err = match kind {
                    "barrier" => t.barrier().unwrap_err(),
                    "all_to_all" => {
                        let sends = (0..3)
                            .map(|dst| (dst, vec![t.rank() as u8]))
                            .collect();
                        t.all_to_all_bytes(sends).unwrap_err()
                    }
                    "all_gather" => t
                        .all_gather_bytes(vec![t.rank() as u8])
                        .unwrap_err(),
                    _ => {
                        let mut x = [1.0f32; 4];
                        t.all_reduce_sum(&mut x).unwrap_err()
                    }
                };
                Some(peer_dead(&err))
            })
            .unwrap_or_else(|e| {
                panic!("{name}/{kind}: world failed: {e:#}")
            });
            for (rank, blamed) in out.into_iter().enumerate() {
                let Some(blamed) = blamed else {
                    assert_eq!(rank, 1, "{name}/{kind}");
                    continue;
                };
                // Survivors must hold a typed peer death. The inproc
                // barrier attributes the exact missing rank; socket
                // backends may cascade blame onto another survivor
                // whose streams collapsed first, which recovery treats
                // as a hint only.
                assert!(
                    blamed.is_some(),
                    "{name}/{kind} rank {rank}: error was not a typed \
                     peer death"
                );
                if *name == "inproc" {
                    assert_eq!(blamed, Some(1), "{name}/{kind}");
                }
            }
        }
    }
}
