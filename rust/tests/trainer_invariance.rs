//! End-to-end consequence-invariance (paper §3.3) over the REAL stack:
//! PJRT executables, worker threads, all-to-all payload movement,
//! gradient all-reduce. Training with post-balancing must produce the
//! same loss trajectory as training without it, from the same sampled
//! batches — the rearrangement only relocates examples.
//!
//! Requires `make artifacts` (skipped silently otherwise, so plain
//! `cargo test` works in a fresh checkout).

use std::path::Path;

use orchmllm::config::TrainRunConfig;
use orchmllm::trainer;

fn artifacts_ready() -> bool {
    Path::new("artifacts/test/manifest.json").exists()
}

fn base_cfg() -> TrainRunConfig {
    TrainRunConfig {
        artifacts: "artifacts/test".into(),
        workers: 2,
        mini_batch: 3,
        steps: 3,
        lr: 2.0,
        seed: 7,
        balance: true,
        balancer: None,
        ..TrainRunConfig::default()
    }
}

#[test]
fn balanced_and_unbalanced_runs_agree() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts/test not built");
        return;
    }
    let balanced = trainer::run_collect(&base_cfg()).unwrap();
    let unbalanced = trainer::run_collect(&TrainRunConfig {
        balance: false,
        ..base_cfg()
    })
    .unwrap();
    assert_eq!(balanced.losses.len(), unbalanced.losses.len());
    for (i, (a, b)) in
        balanced.losses.iter().zip(&unbalanced.losses).enumerate()
    {
        let rel = (a - b).abs() / a.abs().max(1e-9);
        assert!(
            rel < 1e-3,
            "step {i}: balanced {a} vs unbalanced {b} (rel {rel})"
        );
    }
    // Token counts must match exactly (same sampled batches).
    assert!(
        (balanced.tokens_per_step - unbalanced.tokens_per_step).abs()
            < 1e-6
    );
}

#[test]
fn training_is_deterministic_per_seed() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts/test not built");
        return;
    }
    let a = trainer::run_collect(&base_cfg()).unwrap();
    let b = trainer::run_collect(&base_cfg()).unwrap();
    assert_eq!(a.losses, b.losses);
}

#[test]
fn different_worker_counts_see_the_same_global_batch_size() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts/test not built");
        return;
    }
    // With the same per-worker mini-batch, doubling workers doubles the
    // tokens per step (sanity of the data path, not an invariance).
    let two = trainer::run_collect(&TrainRunConfig {
        workers: 2,
        steps: 2,
        ..base_cfg()
    })
    .unwrap();
    let four = trainer::run_collect(&TrainRunConfig {
        workers: 4,
        steps: 2,
        ..base_cfg()
    })
    .unwrap();
    let ratio = four.tokens_per_step / two.tokens_per_step;
    assert!(
        (1.3..3.0).contains(&ratio),
        "token scaling ratio {ratio} implausible"
    );
}

#[test]
fn loss_descends_on_fixedish_corpus() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts/test not built");
        return;
    }
    let report = trainer::run_collect(&TrainRunConfig {
        workers: 2,
        mini_batch: 4,
        steps: 40,
        lr: 3.0,
        ..base_cfg()
    })
    .unwrap();
    let first5: f64 =
        report.losses.iter().take(5).sum::<f64>() / 5.0;
    let last5: f64 =
        report.losses.iter().rev().take(5).sum::<f64>() / 5.0;
    assert!(
        last5 < first5,
        "no descent: {first5:.4} -> {last5:.4} ({:?})",
        report.losses
    );
}
