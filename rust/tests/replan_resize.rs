//! Registry-wide elastic re-planning property: after a session is
//! resized from `d` to `d − 1` instances (one DP rank died), every
//! registered balancer must produce a *valid* plan over the surviving
//! minibatches — full example coverage, correct width — whose LLM
//! makespan stays within the natural shrink bound
//! `ms(d−1) ≤ ms(d) · d/(d−1) · slack + 2·max_item`: losing one of
//! `d` ranks raises the ideal per-rank load by `d/(d−1)`, and no
//! balancer is allowed to do materially worse than that after
//! [`PlanSession::resize`] dropped its warm state.

use orchmllm::balance::{registry, ExampleRef};
use orchmllm::data::synth::{DatasetConfig, Example, Generator};
use orchmllm::orchestrator::global::OrchestratorConfig;
use orchmllm::orchestrator::session::{PlanOptions, PlanSession};
use orchmllm::trainer::{worker_topology, worker_topology_with_floor};

const D: usize = 4;
const PER_RANK: usize = 6;

fn minibatches(seed: u64) -> Vec<Vec<Example>> {
    let mut g = Generator::new(DatasetConfig::default(), seed);
    (0..D).map(|_| g.batch(PER_RANK)).collect()
}

#[test]
fn every_balancer_replans_validly_after_losing_a_rank() {
    for name in registry::NAMES {
        let b = registry::must(name);
        let cm = b.cost_model();
        let mbs = minibatches(11);
        for k in 0..D {
            let mut s = PlanSession::with_defaults(
                OrchestratorConfig::orchmllm(512.0)
                    .with_balancer(b.clone()),
                worker_topology(D),
            );
            let plan_d = s.plan(&mbs, PlanOptions::auto());
            assert_eq!(plan_d.d, D, "{name}");
            let ms_d = cm.makespan(&plan_d.llm.assignment);
            // Cost of the single most expensive example — re-planning
            // over fewer ranks can at worst misplace one item at each
            // of the two affected batch boundaries.
            let max_item = plan_d
                .llm
                .assignment
                .iter()
                .flatten()
                .map(|e| {
                    cm.makespan(&[vec![ExampleRef {
                        id: e.id,
                        len: e.len,
                    }]])
                })
                .fold(0.0, f64::max);

            // Rank k dies: resize the same session and re-plan over
            // the survivors' minibatches.
            s.resize(worker_topology_with_floor(D - 1, 1).unwrap());
            let survivors: Vec<Vec<Example>> = mbs
                .iter()
                .enumerate()
                .filter(|(i, _)| *i != k)
                .map(|(_, m)| m.clone())
                .collect();
            let plan = s.plan(&survivors, PlanOptions::auto());
            assert_eq!(plan.d, D - 1, "{name} dropping rank {k}");

            // Validity: every surviving example exactly once.
            let n = plan.examples.len();
            assert_eq!(n, (D - 1) * PER_RANK, "{name}");
            let mut seen = vec![false; n];
            for batch in &plan.llm.assignment {
                for e in batch {
                    assert!(
                        !seen[e.id],
                        "{name} dropping rank {k}: example {} assigned \
                         twice",
                        e.id
                    );
                    seen[e.id] = true;
                }
            }
            assert!(
                seen.iter().all(|&x| x),
                "{name} dropping rank {k}: example lost after resize"
            );

            // Quality: within the natural d/(d−1) shrink bound (skip
            // the identity dealer — it makes no balancing promise).
            if b.is_identity() {
                continue;
            }
            let ms = cm.makespan(&plan.llm.assignment);
            let bound = ms_d * D as f64 / (D - 1) as f64 * 1.25
                + 2.0 * max_item
                + 1e-6;
            assert!(
                ms <= bound,
                "{name} dropping rank {k}: shrunk makespan {ms} \
                 exceeds bound {bound} (d-rank makespan {ms_d})"
            );
        }
    }
}
