//! Integration tests across balance + comm + nodewise + orchestrator:
//! the properties the paper's design depends on, exercised through the
//! public API on realistic synthetic workloads.

use orchmllm::balance::cost::CostModel;
use orchmllm::balance::PlanScratch;
use orchmllm::comm::topology::Topology;
use orchmllm::data::incoherence::IncoherenceReport;
use orchmllm::data::synth::{DatasetConfig, Example, Generator};
use orchmllm::model::flops::PhaseKind;
use orchmllm::orchestrator::dispatcher::{
    Communicator, DispatchOptions, Dispatcher,
};
use orchmllm::orchestrator::global::{OrchestratorConfig, StepPlan};
use orchmllm::orchestrator::session::{PlanOptions, PlanSession};

fn sample(d: usize, b: usize, seed: u64) -> Vec<Vec<Example>> {
    let mut g = Generator::new(DatasetConfig::default(), seed);
    (0..d).map(|_| g.batch(b)).collect()
}

/// One step through the public planning surface.
fn plan(cfg: OrchestratorConfig, d: usize, mbs: &[Vec<Example>]) -> StepPlan {
    PlanSession::with_defaults(cfg, Topology::h100(d))
        .plan(mbs, PlanOptions::auto())
}

#[test]
fn incoherent_data_defeats_llm_only_balance_consistently() {
    // Over many seeds, LLM-only balancing must leave encoder phases
    // imbalanced — the paper's core motivation (§3.1).
    let lin = CostModel::Linear { alpha: 1.0 };
    let mut worse = 0;
    for seed in 0..10 {
        let mbs = sample(32, 40, seed);
        let plan = plan(OrchestratorConfig::llm_only(7168.0), 32, &mbs);
        let enc_imb = lin
            .imbalance(plan.assignment(PhaseKind::Vision))
            .max(lin.imbalance(plan.assignment(PhaseKind::Audio)));
        if enc_imb > 1.15 {
            worse += 1;
        }
    }
    assert!(worse >= 9, "encoder imbalance vanished in {}/10 seeds", 10 - worse);
}

#[test]
fn full_balance_fixes_all_phases_across_seeds() {
    let lin = CostModel::Linear { alpha: 1.0 };
    for seed in 0..10 {
        let mbs = sample(32, 40, seed);
        let plan = plan(OrchestratorConfig::orchmllm(7168.0), 32, &mbs);
        for phase in PhaseKind::ALL {
            let imb = lin.imbalance(plan.assignment(phase));
            assert!(
                imb < 1.30,
                "seed {seed} phase {} imbalance {imb}",
                phase.name()
            );
        }
    }
}

#[test]
fn every_example_is_conserved_through_the_full_pipeline() {
    // No example may be lost or duplicated by any phase's dispatch,
    // including the composed encoder-output routes.
    let mbs = sample(16, 25, 3);
    let plan = plan(OrchestratorConfig::orchmllm(7168.0), 16, &mbs);
    let n = plan.examples.len();
    assert_eq!(n, 16 * 25);

    for phase in PhaseKind::ALL {
        let mut seen = vec![false; n];
        for (i, batch) in plan.assignment(phase).iter().enumerate() {
            assert!(i < 16);
            for e in batch {
                assert!(!seen[e.id], "{}: dup {}", phase.name(), e.id);
                seen[e.id] = true;
            }
        }
        let expect = |e: &Example| match phase {
            PhaseKind::Vision => e.vis_len > 0,
            PhaseKind::Audio => e.aud_len > 0,
            PhaseKind::Llm => true,
        };
        for (g, e) in plan.examples.iter().enumerate() {
            assert_eq!(
                seen[g],
                expect(e),
                "{}: example {g} participation wrong",
                phase.name()
            );
        }
    }

    // Composed routes: encoder-output start = encoder placement,
    // end = LLM placement, for every participating example.
    for g in 0..n {
        if plan.examples[g].vis_len > 0 {
            assert_eq!(
                plan.vision.out_route.from[g],
                plan.vision.plan.route.to[g]
            );
            assert_eq!(plan.vision.out_route.to[g], plan.llm.route.to[g]);
        }
        if plan.examples[g].aud_len > 0 {
            assert_eq!(
                plan.audio.out_route.from[g],
                plan.audio.plan.route.to[g]
            );
            assert_eq!(plan.audio.out_route.to[g], plan.llm.route.to[g]);
        }
    }
}

#[test]
fn nodewise_dispatch_never_increases_max_inter_node_send() {
    let topo = Topology::h100(32);
    let mut gen = Generator::new(DatasetConfig::default(), 11);
    for _ in 0..5 {
        let examples = gen.batch(32 * 20);
        let placement: Vec<usize> = (0..examples.len())
            .map(|g| g / 20)
            .collect();
        let lens: Vec<usize> =
            examples.iter().map(|e| e.vis_len).collect();
        let payload: Vec<f64> =
            lens.iter().map(|&l| l as f64 * 1176.0).collect();
        let mk = |nodewise| {
            Dispatcher::by_name(
                "greedy",
                Communicator::AllToAll { nodewise },
            )
            .expect("greedy is registered")
        };
        let run = |dp: &Dispatcher| {
            dp.dispatch(
                &topo,
                &placement,
                &lens,
                &payload,
                &mut PlanScratch::new(),
                DispatchOptions::default(),
            )
        };
        let with = run(&mk(true));
        let without = run(&mk(false));
        let m_with = with.route.max_inter_node_bytes(&topo, &payload);
        let m_without =
            without.route.max_inter_node_bytes(&topo, &payload);
        assert!(
            m_with <= m_without + 1e-6,
            "nodewise regressed: {m_with} > {m_without}"
        );
    }
}

#[test]
fn generated_corpus_is_incoherent_at_scale() {
    let ex = Generator::new(DatasetConfig::default(), 99).batch(50_000);
    let rep = IncoherenceReport::from_examples(&ex, 20);
    assert!(rep.is_incoherent(), "{}", rep.render());
}

#[test]
fn balancing_is_a_pure_permutation_of_lengths() {
    // The multiset of (id, len) pairs must be identical before and
    // after — the data-level statement of consequence-invariance.
    let mbs = sample(8, 30, 21);
    let plan = plan(OrchestratorConfig::orchmllm(7168.0), 8, &mbs);
    let mut before: Vec<(usize, usize)> = plan
        .examples
        .iter()
        .enumerate()
        .map(|(g, e)| (g, e.llm_len()))
        .collect();
    let mut after: Vec<(usize, usize)> = plan
        .assignment(PhaseKind::Llm)
        .iter()
        .flatten()
        .map(|e| (e.id, e.len))
        .collect();
    before.sort_unstable();
    after.sort_unstable();
    assert_eq!(before, after);
}
