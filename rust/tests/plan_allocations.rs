//! Counting-allocator integration test: steady-state planning is
//! allocation-free.
//!
//! The hot-path claim (DESIGN.md §Hot Paths): once a session's arenas
//! are warm, a recurring step replayed through
//! [`PlanSession::plan_shared`] performs **zero** heap allocations —
//! the flatten pass reuses the `StepScratch` arenas, the step-cache key
//! is rebuilt in a retained buffer, the sketch is computed on the
//! stack, and the hit hands back an `Arc` refcount bump. A counting
//! `#[global_allocator]` wrapped around `System` makes that claim a
//! test instead of a comment.
//!
//! This file intentionally holds a **single** `#[test]`: the counter
//! is process-global, and libtest runs sibling tests on concurrent
//! threads, which would pollute the measured windows.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use orchmllm::balance::registry;
use orchmllm::comm::topology::Topology;
use orchmllm::data::synth::{DatasetConfig, Example, Generator};
use orchmllm::orchestrator::global::OrchestratorConfig;
use orchmllm::orchestrator::session::{PlanOptions, PlanSession};

/// `System` plus a process-global allocation counter. Frees are not
/// counted: the claim under test is "no allocation", and counting
/// `dealloc` would only blur the windows with drops of pre-window
/// allocations.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

#[test]
fn steady_state_planning_is_allocation_free() {
    // n = 192 stays under the parallel-solve threshold (256), so even
    // the solving paths run on this thread and every count below is
    // exact — no thread-spawn allocations, no cross-thread noise.
    let d = 6;
    let mb = 32;
    let mut g = Generator::new(DatasetConfig::default(), 11);
    let mbs: Vec<Vec<Example>> = (0..d).map(|_| g.batch(mb)).collect();

    // ---- cache-hit replays: exactly zero allocations -----------------
    //
    // Window arithmetic: the session's telemetry Summaries are plain
    // `Vec<f64>`s that double 4 → 8 → … → 256. 170 warm-up steps grow
    // them to capacity 256; the 60 measured steps push to at most 230,
    // so no Summary reallocation can land inside the counted window.
    for name in ["greedy", "kk"] {
        let cfg = OrchestratorConfig::orchmllm(7168.0)
            .with_balancer(registry::must(name));
        let mut s =
            PlanSession::with_defaults(cfg, Topology::h100(d));
        for _ in 0..170 {
            let p = s.plan_shared(&mbs, PlanOptions::auto());
            assert_eq!(p.examples.len(), d * mb);
        }
        assert!(
            s.stats().step_cache_hits() >= 169,
            "{name}: recurring step must replay from the step cache"
        );
        let before = allocs();
        for _ in 0..60 {
            let p = s.plan_shared(&mbs, PlanOptions::auto());
            std::hint::black_box(&p);
        }
        let counted = allocs() - before;
        assert_eq!(
            counted, 0,
            "{name}: {counted} heap allocations across 60 warm \
             plan_shared calls (expected 0)"
        );
    }

    // ---- warm solves (cache off): steady, bounded allocations --------
    //
    // With the plan caches off, every step re-runs the warm-start
    // transfer + repair and materializes a fresh `StepPlan`
    // (examples/home clones, per-batch vectors, rearrangement tables)
    // — allocation-free is impossible by design, but the count must be
    // *flat*: identical recurring input at a converged history must
    // allocate an identical amount every step, or the arenas are
    // leaking work. Warm-up (33 steps) parks the Summaries at capacity
    // 64 so the 24 measured steps (pushes 34..=57) cross no doubling
    // boundary.
    let mut s = PlanSession::with_defaults(
        OrchestratorConfig::orchmllm(7168.0),
        Topology::h100(d),
    );
    for _ in 0..33 {
        s.plan_shared(&mbs, PlanOptions::auto().cache(false));
    }
    let mut counts: Vec<u64> = Vec::with_capacity(24);
    for _ in 0..24 {
        let before = allocs();
        let p = s.plan_shared(&mbs, PlanOptions::auto().cache(false));
        std::hint::black_box(&p);
        counts.push(allocs() - before);
    }
    let per_step = counts[0];
    assert!(
        counts.iter().all(|&c| c == per_step),
        "warm-solve allocation count drifts across steps: {counts:?}"
    );
    assert!(per_step > 0, "a warm solve must build a fresh plan");
    // Documented budget: ~200–600 allocations per warm solve at this
    // shape today. 5000 is the regression ceiling, not the target —
    // tighten it if the solve paths ever adopt plan-level arenas.
    assert!(
        per_step < 5_000,
        "warm solve allocated {per_step} times per step (budget 5000)"
    );
}
