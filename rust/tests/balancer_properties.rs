//! Registry-wide property tests: EVERY registered balancer, over
//! log-normal and adversarial length distributions, must
//!
//! 1. produce a valid assignment — every example id exactly once,
//!    exactly `d` mini-batches;
//! 2. achieve a makespan (under the balancer's own cost model) no worse
//!    than `NoBalance` (the identity dealing);
//! 3. be a deterministic pure function of `(lens, d)` — replicas solve
//!    independently and must agree (§5.2.1);
//! 4. behave on edge shapes: empty input, n < d, all-equal lengths.
//!
//! These are the invariants that make post-balancing safe to plug into
//! any phase: consequence-invariance needs (1) and (3); "never slower
//! than not balancing" needs (2).

use orchmllm::balance::types::{
    assert_valid_assignment, identity_with_lens,
};
use orchmllm::balance::{registry, Balancer, PlanScratch};
use orchmllm::util::prop::{check, Gen};

fn lognormal_lens(g: &mut Gen) -> Vec<usize> {
    let n = g.usize(0, 150);
    g.seq_lengths(n, 3.2, 1.3)
}

/// Adversarial shape: one giant example among many tiny ones — the
/// worst case for padded batching and greedy commitment.
fn one_giant_lens(g: &mut Gen) -> Vec<usize> {
    let n = g.usize(1, 120);
    let mut lens = vec![2usize; n];
    let giant = g.usize(0, n);
    lens[giant] = 50_000;
    lens
}

fn check_balancer_on(
    b: &dyn Balancer,
    lens: &[usize],
    d: usize,
    scratch: &mut PlanScratch,
) {
    let a = b.balance(lens, d, scratch);
    assert_valid_assignment(&a, lens.len(), d);

    let cm = b.cost_model();
    let identity = identity_with_lens(lens, d);
    assert!(
        cm.makespan(&a) <= cm.makespan(&identity) + 1e-9,
        "{}: makespan {} worse than NoBalance {}",
        b.name(),
        cm.makespan(&a),
        cm.makespan(&identity)
    );
}

#[test]
fn every_balancer_valid_and_no_worse_than_nobalance_lognormal() {
    check("registry lognormal", 120, |g| {
        let d = g.usize(1, 12);
        let lens = lognormal_lens(g);
        let mut scratch = PlanScratch::new();
        for name in registry::NAMES {
            check_balancer_on(&*registry::must(name), &lens, d, &mut scratch);
        }
    });
}

#[test]
fn every_balancer_valid_and_no_worse_than_nobalance_adversarial() {
    check("registry one-giant", 120, |g| {
        let d = g.usize(1, 10);
        let lens = one_giant_lens(g);
        let mut scratch = PlanScratch::new();
        for name in registry::NAMES {
            check_balancer_on(&*registry::must(name), &lens, d, &mut scratch);
        }
    });
}

#[test]
fn every_balancer_is_deterministic() {
    check("registry determinism", 40, |g| {
        let d = g.usize(1, 8);
        let lens = lognormal_lens(g);
        for name in registry::NAMES {
            let b = registry::must(name);
            let a1 = b.balance(&lens, d, &mut PlanScratch::new());
            let a2 = b.balance(&lens, d, &mut PlanScratch::new());
            assert_eq!(a1, a2, "{name} is nondeterministic");
        }
    });
}

#[test]
fn every_balancer_handles_edge_shapes() {
    let mut scratch = PlanScratch::new();
    for name in registry::NAMES {
        let b = registry::must(name);
        // Empty input.
        let a = b.balance(&[], 5, &mut scratch);
        assert_valid_assignment(&a, 0, 5);
        // Fewer examples than instances.
        let a = b.balance(&[7, 3], 6, &mut scratch);
        assert_valid_assignment(&a, 2, 6);
        // All equal: every instance gets an equal share.
        let lens = vec![10usize; 24];
        let a = b.balance(&lens, 4, &mut scratch);
        assert_valid_assignment(&a, 24, 4);
        let sizes: Vec<usize> = a.iter().map(|batch| batch.len()).collect();
        assert!(
            sizes.iter().all(|&s| s == 6),
            "{name}: uneven split {sizes:?} on uniform lengths"
        );
        // Single instance takes everything.
        let a = b.balance(&[4, 9, 1], 1, &mut scratch);
        assert_valid_assignment(&a, 3, 1);
    }
}

#[test]
fn metadata_is_consistent() {
    for name in registry::NAMES {
        let b = registry::must(name);
        // The declared cost model must match the declared batching mode
        // except for regimes that imply their own (documented) mode.
        let cm = b.cost_model();
        let a = b.balance(&[5, 5], 2, &mut PlanScratch::new());
        // Smoke: the cost model evaluates on this balancer's output.
        assert!(cm.makespan(&a).is_finite(), "{name}: NaN makespan");
    }
}
