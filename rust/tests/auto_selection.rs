//! Balancer auto-selection (`--balancer auto`), end to end: the
//! documented trait→algorithm rules, the Table-1 model resolutions,
//! safe fallback on missing registry metadata, determinism, and the
//! orchestrator/simulator wiring.

use orchmllm::balance::select::{
    select_for_phase, select_for_phase_from, PhaseTraits,
    QUADRATIC_ATTENTION_RATIO,
};
use orchmllm::model::config::MllmConfig;
use orchmllm::model::flops::PhaseKind;
use orchmllm::orchestrator::global::OrchestratorConfig;
use orchmllm::sim::engine::{simulate_run_named, SystemKind};

#[test]
fn trait_table_resolves_to_the_documented_algorithms() {
    struct Case {
        label: &'static str,
        traits: PhaseTraits,
        expect: &'static str,
    }
    let cases = [
        Case {
            label: "conv front-end",
            traits: PhaseTraits::conv_encoder(),
            expect: "convpad",
        },
        Case {
            label: "conv outranks quadratic",
            traits: PhaseTraits {
                conv_frontend: true,
                padded: true,
                beta_len_over_alpha: 5.0,
            },
            expect: "convpad",
        },
        Case {
            label: "padded without conv",
            traits: PhaseTraits {
                conv_frontend: false,
                padded: true,
                beta_len_over_alpha: 0.0,
            },
            expect: "padded",
        },
        Case {
            label: "attention-heavy unpadded",
            traits: PhaseTraits {
                conv_frontend: false,
                padded: false,
                beta_len_over_alpha: QUADRATIC_ATTENTION_RATIO + 0.05,
            },
            expect: "quadratic",
        },
        Case {
            label: "attention-light unpadded",
            traits: PhaseTraits {
                conv_frontend: false,
                padded: false,
                beta_len_over_alpha: QUADRATIC_ATTENTION_RATIO - 0.05,
            },
            expect: "greedy",
        },
        Case {
            label: "exactly at the threshold",
            traits: PhaseTraits {
                conv_frontend: false,
                padded: false,
                beta_len_over_alpha: QUADRATIC_ATTENTION_RATIO,
            },
            expect: "quadratic",
        },
    ];
    for c in cases {
        let sel = select_for_phase(&c.traits);
        assert_eq!(
            sel.balancer.name(),
            c.expect,
            "{}: rule was '{}'",
            c.label,
            sel.rule
        );
    }
}

#[test]
fn table1_models_resolve_per_the_documented_rules() {
    // (model, [vision, audio, llm]) — audio is always the conv
    // front-end; vision/llm flip between greedy and quadratic as the
    // attention share β·L/α crosses the threshold at each scale.
    let expect: [(&str, [&str; 3]); 3] = [
        ("MLLM-10B", ["greedy", "convpad", "quadratic"]),
        ("MLLM-18B", ["quadratic", "convpad", "quadratic"]),
        ("MLLM-84B", ["quadratic", "convpad", "greedy"]),
    ];
    for (model, phases) in expect {
        let m = MllmConfig::by_name(model).unwrap();
        for (phase, want) in PhaseKind::ALL.iter().zip(phases) {
            let traits = m.phase_traits(*phase);
            let sel = select_for_phase(&traits);
            assert_eq!(
                sel.balancer.name(),
                want,
                "{model} {}: β·L/α = {:.3}, rule '{}'",
                phase.name(),
                traits.beta_len_over_alpha,
                sel.rule
            );
        }
    }
}

#[test]
fn auto_is_deterministic_per_model() {
    for m in MllmConfig::all() {
        for phase in PhaseKind::ALL {
            let a = select_for_phase(&m.phase_traits(phase));
            let b = select_for_phase(&m.phase_traits(phase));
            assert_eq!(a.balancer.name(), b.balancer.name());
            assert_eq!(a.rule, b.rule);
        }
    }
}

#[test]
fn missing_registry_metadata_degrades_not_fails() {
    // conv phase, registry without any padded algorithm: linear
    // fallback, never a panic and never the hard-coded default.
    let conv = PhaseTraits::conv_encoder();
    let sel = select_for_phase_from(&["greedy", "kk"], &conv);
    assert_eq!(sel.balancer.name(), "greedy");

    // Nothing usable at all: identity, balancing degrades to off.
    let sel = select_for_phase_from(&[], &conv);
    assert!(sel.balancer.is_identity());
}

#[test]
fn orchestrator_auto_config_wires_all_three_phases() {
    let m = MllmConfig::mllm_10b();
    let cfg = OrchestratorConfig::auto(&m, 3584.0 * 2.0);
    assert_eq!(cfg.vision_balancer.name(), "greedy");
    assert_eq!(cfg.audio_balancer.name(), "convpad");
    assert_eq!(cfg.llm_balancer.name(), "quadratic");

    let m84 = MllmConfig::mllm_84b();
    let cfg = OrchestratorConfig::auto(&m84, 8192.0 * 2.0);
    assert_eq!(cfg.vision_balancer.name(), "quadratic");
    assert_eq!(cfg.llm_balancer.name(), "greedy");
}

#[test]
fn simulated_auto_run_balances_like_the_tailored_config() {
    // `--balancer auto` end to end through the simulator: the
    // auto-selected configuration must land in the same MFU band as the
    // hand-tailored default and far above no-balance.
    let m = MllmConfig::mllm_10b();
    let auto = simulate_run_named(
        SystemKind::OrchMllm, &m, 16, 16, 2, 42, Some("auto"),
    );
    let tailored = simulate_run_named(
        SystemKind::OrchMllm, &m, 16, 16, 2, 42, None,
    );
    let none = simulate_run_named(
        SystemKind::OrchMllm, &m, 16, 16, 2, 42, Some("none"),
    );
    assert!(
        auto.mfu > 1.15 * none.mfu,
        "auto {} vs none {}",
        auto.mfu,
        none.mfu
    );
    assert!(
        auto.mfu > 0.85 * tailored.mfu,
        "auto {} fell far below tailored {}",
        auto.mfu,
        tailored.mfu
    );
}
