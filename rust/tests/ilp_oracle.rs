//! Oracle properties of the exact `ilp` balancer:
//!
//! 1. on instances small enough to brute-force, the branch-and-bound
//!    makespan equals the true optimum under every Eq.-2 cost regime;
//! 2. registry-wide: on certified instances NO registered heuristic
//!    beats the oracle under that heuristic's own cost model — the
//!    property the gap harness rests on;
//! 3. the registered `ilp` balancer is a first-class citizen: valid
//!    assignments, deterministic, never worse than `greedy` or the
//!    identity dealing, total at any scale (best-effort past the work
//!    guard).

use orchmllm::balance::cost::CostModel;
use orchmllm::balance::ilp::{self, IlpStatus};
use orchmllm::balance::types::{
    assert_valid_assignment, ExampleRef,
};
use orchmllm::balance::{registry, PlanScratch};
use orchmllm::util::prop::check;

/// All Eq.-2 regimes at test coefficients.
const MODELS: [CostModel; 4] = [
    CostModel::Linear { alpha: 1.0 },
    CostModel::TransformerUnpadded { alpha: 1.0, beta: 0.02 },
    CostModel::TransformerPadded { alpha: 1.0, beta: 0.0 },
    CostModel::ConvPadded { alpha: 1.0, lambda: 0.002 },
];

/// True optimum by enumerating all d^n assignments.
fn brute_force_opt(cm: &CostModel, lens: &[usize], d: usize) -> f64 {
    let n = lens.len();
    let mut assign = vec![0usize; n];
    let mut best = f64::INFINITY;
    loop {
        let mut batches: Vec<Vec<ExampleRef>> = vec![Vec::new(); d];
        for (id, &b) in assign.iter().enumerate() {
            batches[b].push(ExampleRef { id, len: lens[id] });
        }
        best = best.min(cm.makespan(&batches));
        // Increment the base-d counter.
        let mut k = 0;
        loop {
            if k == n {
                return best;
            }
            assign[k] += 1;
            if assign[k] < d {
                break;
            }
            assign[k] = 0;
            k += 1;
        }
    }
}

#[test]
fn bnb_matches_brute_force_on_tiny_instances() {
    check("ilp == brute force", 40, |g| {
        let d = g.usize(2, 4); // 2..=3
        let n = g.usize(1, 8); // 1..=7  => at most 3^7 = 2187 states
        let lens = g.seq_lengths(n, 3.0, 1.2);
        for cm in MODELS {
            let s = ilp::solve(&cm, &lens, d, 1_000_000);
            assert_eq!(
                s.status,
                IlpStatus::Optimal,
                "{cm:?}: tiny instance must certify"
            );
            let opt = brute_force_opt(&cm, &lens, d);
            assert!(
                (s.makespan - opt).abs() <= 1e-9 * opt.max(1.0),
                "{cm:?}: B&B {} != brute-force optimum {opt} \
                 (lens {lens:?}, d {d})",
                s.makespan
            );
        }
    });
}

#[test]
fn dominance_pruning_matches_brute_force_on_duplicate_heavy_batches() {
    // Duplicate-heavy instances are where the twin-batch dominance
    // rule fires hardest (many batches share aggregates mid-search) —
    // and where an unsound rule would be likeliest to prune the
    // optimum away. Draw lengths from a 3-value alphabet so twins are
    // everywhere, then check the pruned search against an exhaustive
    // enumeration under every Eq.-2 regime.
    check("dominance == brute force", 30, |g| {
        let d = g.usize(2, 4); // 2..=3
        let n = g.usize(2, 10); // 2..=9 => at most 3^9 = 19683 states
        let alphabet = [
            g.usize(1, 8) * 3,
            g.usize(1, 8) * 3 + 1,
            g.usize(1, 8) * 3 + 2,
        ];
        let lens: Vec<usize> =
            (0..n).map(|_| alphabet[g.usize(0, 3)]).collect();
        for cm in MODELS {
            let s = ilp::solve(&cm, &lens, d, 1_000_000);
            assert_eq!(
                s.status,
                IlpStatus::Optimal,
                "{cm:?}: duplicate-heavy tiny instance must certify"
            );
            assert_valid_assignment(&s.assignment, n, d);
            let opt = brute_force_opt(&cm, &lens, d);
            assert!(
                (s.makespan - opt).abs() <= 1e-9 * opt.max(1.0),
                "{cm:?}: pruned B&B {} != brute-force optimum {opt} \
                 (lens {lens:?}, d {d})",
                s.makespan
            );
        }
    });
}

#[test]
fn no_registered_heuristic_beats_a_certified_oracle() {
    check("oracle dominance", 24, |g| {
        let d = g.usize(2, 5);
        let n = g.usize(d, 13);
        let lens = g.seq_lengths(n, 3.4, 1.1);
        let mut scratch = PlanScratch::new();
        for name in registry::NAMES {
            let b = registry::must(name);
            let cm = b.cost_model();
            let oracle = ilp::solve(&cm, &lens, d, 120_000);
            if oracle.status != IlpStatus::Optimal {
                continue; // only certified optima are binding
            }
            let heur = b.balance(&lens, d, &mut scratch);
            assert!(
                oracle.makespan <= cm.makespan(&heur) + 1e-9,
                "{name} beat the certified oracle: {} < {} \
                 (lens {lens:?}, d {d})",
                cm.makespan(&heur),
                oracle.makespan
            );
        }
    });
}

#[test]
fn certified_solutions_match_their_own_lower_bound_contract() {
    // Certification must be honest: status Optimal with a makespan
    // strictly above the from-scratch re-solve would be a soundness
    // bug. Re-solving with a bigger budget can never improve on a
    // certified optimum.
    check("certificate stability", 20, |g| {
        let d = g.usize(2, 4);
        let n = g.usize(1, 12);
        let lens = g.seq_lengths(n, 3.2, 1.0);
        for cm in MODELS {
            let small = ilp::solve(&cm, &lens, d, 100_000);
            if small.status != IlpStatus::Optimal {
                continue;
            }
            let big = ilp::solve(&cm, &lens, d, 2_000_000);
            assert!(
                (small.makespan - big.makespan).abs() <= 1e-9,
                "{cm:?}: certified {} but larger budget found {}",
                small.makespan,
                big.makespan
            );
        }
    });
}

#[test]
fn registered_ilp_is_a_first_class_balancer() {
    assert!(
        registry::NAMES.contains(&"ilp"),
        "ilp missing from the registry"
    );
    let b = registry::must("ilp");
    assert_eq!(b.name(), "ilp");
    assert!(!b.is_identity());

    // Valid + deterministic + self-guarded across shapes, including
    // past the work guard where it degrades to best-effort.
    let mut scratch = PlanScratch::new();
    let mut g = orchmllm::util::prop::Gen::new(19);
    for &(n, d) in &[(0usize, 3usize), (5, 8), (40, 4), (600, 128)] {
        let lens = g.seq_lengths(n, 3.3, 1.1);
        let a1 = b.balance(&lens, d, &mut scratch);
        let a2 = b.balance(&lens, d, &mut PlanScratch::new());
        assert_valid_assignment(&a1, n, d);
        assert_eq!(a1, a2, "ilp nondeterministic at n={n} d={d}");
        let cm = b.cost_model();
        let greedy = registry::must("greedy");
        let g_plan = greedy.balance(&lens, d, &mut scratch);
        assert!(
            cm.makespan(&a1) <= cm.makespan(&g_plan) + 1e-9,
            "ilp worse than greedy at n={n} d={d}"
        );
    }
}

#[test]
fn oracle_improves_on_lpt_where_lpt_is_suboptimal() {
    // The classic LPT trap: 8,7,6,5,4 on two batches (LPT 17, OPT 15)
    // — through the *registered* balancer, not just the solver API.
    let b = registry::must("ilp");
    let cm = b.cost_model();
    let a = b.balance(&[8, 7, 6, 5, 4], 2, &mut PlanScratch::new());
    assert!(
        (cm.makespan(&a) - 15.0).abs() < 1e-9,
        "registered ilp returned {}",
        cm.makespan(&a)
    );
}
