//! Fast integration checks that the simulator reproduces each paper
//! experiment's *shape* at reduced scale — the full-scale versions live
//! in `rust/benches/`. These guard the conclusions against regressions
//! in the balancing/orchestration/pricing stack.

use orchmllm::model::config::MllmConfig;
use orchmllm::sim::engine::{simulate_run, SystemKind};

const GPUS: usize = 64;
const STEPS: usize = 2;
const SEED: u64 = 42;

fn run(system: SystemKind, model: &MllmConfig, mb: usize)
    -> orchmllm::sim::engine::RunSummary {
    simulate_run(system, model, GPUS, mb, STEPS, SEED)
}

#[test]
fn fig8_ordering_holds_at_small_scale() {
    let model = MllmConfig::mllm_10b();
    let orch = run(SystemKind::OrchMllm, &model, 40);
    let mega = run(SystemKind::Megatron, &model, 40);
    let none = run(SystemKind::NoBalance, &model, 32);
    assert!(orch.mfu > none.mfu && none.mfu > mega.mfu,
        "ordering broken: {} {} {}", orch.mfu, none.mfu, mega.mfu);
    assert!(orch.tpt > none.tpt && none.tpt > mega.tpt);
}

#[test]
fn fig8_gain_grows_with_model_size() {
    let g10 = {
        let m = MllmConfig::mllm_10b();
        run(SystemKind::OrchMllm, &m, 40).mfu
            / run(SystemKind::NoBalance, &m, 32).mfu
    };
    let g84 = {
        let m = MllmConfig::mllm_84b();
        run(SystemKind::OrchMllm, &m, 20).mfu
            / run(SystemKind::NoBalance, &m, 10).mfu
    };
    assert!(g84 > g10, "gain must grow with size: {g10:.2} vs {g84:.2}");
}

#[test]
fn table2_overhead_is_scale_free() {
    let model = MllmConfig::mllm_10b();
    let small = simulate_run(SystemKind::OrchMllm, &model, 32, 30, STEPS, SEED);
    let large = simulate_run(SystemKind::OrchMllm, &model, 256, 30, STEPS, SEED);
    // All-to-All overhead must not scale with d (Eq. 4).
    assert!(
        large.dispatcher_overhead_ms
            < small.dispatcher_overhead_ms * 3.0,
        "{} vs {}",
        large.dispatcher_overhead_ms,
        small.dispatcher_overhead_ms
    );
    // And it stays a small fraction of the step.
    assert!(large.dispatcher_overhead_ms / 1e3 / large.step_secs < 0.05);
}

#[test]
fn fig10_llm_only_loses_and_uses_more_memory() {
    let model = MllmConfig::mllm_18b();
    let orch = run(SystemKind::OrchMllm, &model, 30);
    let llm = run(SystemKind::LlmOnly, &model, 30);
    assert!(orch.mfu > llm.mfu);
    assert!(orch.peak_mem_gb < llm.peak_mem_gb);
}

#[test]
fn fig11_rigid_algorithms_lose() {
    let model = MllmConfig::mllm_18b();
    let orch = run(SystemKind::OrchMllm, &model, 30);
    let rmpad = run(SystemKind::AllRmpad, &model, 30);
    let pad = run(SystemKind::AllPad, &model, 30);
    assert!(orch.mfu >= rmpad.mfu);
    assert!(orch.mfu >= pad.mfu);
    assert!(orch.mfu - rmpad.mfu > 0.01, "rmpad gap vanished");
}

#[test]
fn fig12_allgather_pays_memory_and_mfu() {
    let model = MllmConfig::mllm_10b();
    let a2a = run(SystemKind::OrchMllm, &model, 40);
    let ag = run(SystemKind::AllGatherComm, &model, 40);
    assert!(ag.peak_mem_gb > a2a.peak_mem_gb);
    assert!(a2a.mfu >= ag.mfu);
}

#[test]
fn fig13_nodewise_reduces_max_inter_node_volume() {
    let model = MllmConfig::mllm_10b();
    let with = run(SystemKind::OrchMllm, &model, 40);
    let without = run(SystemKind::NoNodewise, &model, 40);
    let s_with: f64 = with.inter_node_mb.iter().sum();
    let s_without: f64 = without.inter_node_mb.iter().sum();
    let ratio = s_with / s_without.max(1e-9);
    assert!(
        ratio < 0.95,
        "node-wise saved nothing: ratio {ratio:.3}"
    );
}

#[test]
fn composition_ablation_only_changes_comm() {
    let model = MllmConfig::mllm_10b();
    let with = run(SystemKind::OrchMllm, &model, 40);
    let without = run(SystemKind::NoComposition, &model, 40);
    assert!(with.comm_secs < without.comm_secs);
    // Balance quality itself is unchanged.
    assert!((with.mfu - without.mfu).abs() / with.mfu < 0.05);
}
