//! Persistent plan archive, end to end in-process: bit-identical
//! warm starts via the simulator and the elastic trainer, golden
//! fixture format pinning, decode-never-panics corruption handling,
//! and the elastic × archive world-fingerprint invariants.

use std::fs;
use std::path::{Path, PathBuf};

use orchmllm::comm::topology::Topology;
use orchmllm::config::TrainRunConfig;
use orchmllm::model::config::MllmConfig;
use orchmllm::orchestrator::archive::{self, Archive, ArchiveError};
use orchmllm::orchestrator::global::OrchestratorConfig;
use orchmllm::orchestrator::pipeline::PipelineConfig;
use orchmllm::orchestrator::session::PlanSession;
use orchmllm::orchestrator::WarmStart;
use orchmllm::sim::engine::{simulate_run_archived, SystemKind};
use orchmllm::trainer::elastic::{run_elastic_collect, FaultPlan};

/// Unique scratch directory per test (parallel test threads must not
/// share archive directories).
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "orchmllm-plan-archive-{}-{tag}",
        std::process::id()
    ));
    let _ = fs::remove_dir_all(&dir);
    dir
}

fn fixture_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("../ci/plan_archive_fixture")
}

fn copy_fixture(tag: &str) -> PathBuf {
    let dst = scratch(tag);
    fs::create_dir_all(&dst).unwrap();
    for name in
        ["manifest.json", "caches.bin", "plans.bin", "profiles.bin"]
    {
        fs::copy(fixture_dir().join(name), dst.join(name)).unwrap();
    }
    dst
}

// ---------------------------------------------------------------------------
// Warm start via the simulator
// ---------------------------------------------------------------------------

#[test]
fn sim_warm_start_replays_every_step_bit_identically() {
    let dir = scratch("sim-roundtrip");
    let model = MllmConfig::mllm_10b();
    let run = |archive_in: Option<&Path>, archive_out: Option<&Path>| {
        simulate_run_archived(
            SystemKind::OrchMllm,
            &model,
            8,
            6,
            4,
            42,
            None,
            archive_in,
            archive_out,
        )
        .expect("sim with archive endpoints")
    };

    // Run A: cold, records and exports.
    let a = run(None, Some(&dir));
    let ainfo = a.archive.expect("archive info present");
    assert!(!ainfo.loaded);
    assert!(ainfo.exported);
    assert!(!ainfo.first_step_cache_hit, "run A's first step is cold");
    let exported_id = ainfo.first_plan_id.expect("plan id recorded");

    // Run B: fresh session, same configuration and seed — every step
    // must replay whole from the restored step cache, and the first
    // step's plan must be the archived plan, bit for bit.
    let b = run(Some(&dir), None);
    let binfo = b.archive.expect("archive info present");
    assert!(binfo.loaded, "fingerprints match: warm start expected");
    assert_eq!(binfo.cold_reason, None);
    assert!(binfo.first_step_cache_hit, "first step must replay");
    assert_eq!(
        binfo.first_plan_id.as_deref(),
        Some(exported_id.as_str()),
        "replayed plan must hash to the archived content id"
    );
    assert_eq!(
        binfo.warm_start_hit_rate, 1.0,
        "a same-seed re-run replays every step"
    );

    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn sim_archive_gc_prunes_and_reseals() {
    let dir = scratch("sim-gc");
    let model = MllmConfig::mllm_10b();
    simulate_run_archived(
        SystemKind::OrchMllm,
        &model,
        8,
        6,
        4,
        7,
        None,
        None,
        Some(&dir),
    )
    .expect("sim export");
    let before = archive::verify(&dir).expect("fresh export verifies");
    assert_eq!(before.chain_len, 4, "one chain entry per planned step");

    let gc = archive::gc(&dir, Some(2), None).expect("gc");
    assert_eq!(gc.kept, 2);
    assert_eq!(gc.pruned, 2);

    // The rewritten plans.bin and patched manifest still verify.
    let after = archive::verify(&dir).expect("gc keeps archive valid");
    assert_eq!(after.chain_len, 2);
    let _ = fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// Golden fixture: format pinning
// ---------------------------------------------------------------------------

#[test]
fn golden_fixture_opens_and_fully_decodes() {
    let archive = Archive::open(&fixture_dir())
        .expect("fixture manifest parses and self-verifies")
        .expect("fixture manifest exists");
    assert_eq!(archive.manifest.schema_version, "1.0.0");
    assert_eq!(archive.manifest.topology.instances, 4);
    assert_eq!(archive.manifest.payloads.len(), 3);

    let state = archive
        .load_state(None)
        .expect("fixture payloads decode with archived capacities");
    assert_eq!(state.history.step_cache.len(), 0);
    assert_eq!(state.history.step_cache.capacity(), 32);
    assert!(state.plan_log.is_empty());
    assert!(state.profiles.is_empty());

    let report = archive::verify(&fixture_dir())
        .expect("fixture passes the full integrity check");
    assert_eq!(report.payloads, 3);
    assert_eq!(report.chain_len, 0);
}

#[test]
fn truncated_payload_prefixes_never_panic() {
    // Every proper prefix of a valid payload must produce a typed
    // error — a truncation can cut anywhere.
    let bytes = fs::read(fixture_dir().join("caches.bin")).unwrap();
    for cut in 0..bytes.len() {
        let err = archive::decode_caches(&bytes[..cut], None)
            .expect_err("prefix decode must fail");
        assert!(
            matches!(
                err,
                ArchiveError::Truncated { .. }
                    | ArchiveError::Malformed { .. }
            ),
            "cut at {cut}: unexpected error {err}"
        );
    }
}

#[test]
fn flipped_payload_byte_is_a_checksum_mismatch() {
    let dir = copy_fixture("flip");
    let path = dir.join("caches.bin");
    let mut bytes = fs::read(&path).unwrap();
    let last = bytes.len() - 1;
    bytes[last] ^= 0x01;
    fs::write(&path, &bytes).unwrap();

    let archive = Archive::open(&dir).unwrap().unwrap();
    let err = archive.load_state(None).expect_err("flip must fail");
    assert!(
        matches!(err, ArchiveError::ChecksumMismatch { .. }),
        "unexpected error {err}"
    );
    assert!(archive::verify(&dir).is_err());
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn future_schema_version_is_a_typed_error() {
    let dir = copy_fixture("schema");
    let path = dir.join("manifest.json");
    let text = fs::read_to_string(&path).unwrap();
    fs::write(&path, text.replace("1.0.0", "2.0.0")).unwrap();

    let err = Archive::open(&dir).expect_err("major skew must fail");
    match err {
        ArchiveError::SchemaVersion { found, .. } => {
            assert_eq!(found, "2.0.0")
        }
        other => panic!("unexpected error {other}"),
    }
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn same_major_manifest_loads() {
    // Compat policy: same-major archives load (unknown minor additions
    // are ignored by the JSON walk); only a major bump is a hard stop.
    let archive =
        Archive::open(&fixture_dir()).unwrap().expect("fixture");
    assert_eq!(archive.manifest.major(), Some(1));
}

// ---------------------------------------------------------------------------
// Elastic × archive
// ---------------------------------------------------------------------------

fn elastic_cfg(workers: usize, steps: usize) -> TrainRunConfig {
    TrainRunConfig {
        workers,
        mini_batch: 3,
        steps,
        lr: 0.05,
        seed: 9,
        min_world: 2,
        transport: "inproc".into(),
        ..TrainRunConfig::default()
    }
}

#[test]
fn elastic_warm_start_round_trips_the_first_plan() {
    let dir = scratch("elastic-warm");
    let mut cfg = elastic_cfg(4, 5);
    cfg.archive_out = Some(dir.to_string_lossy().into_owned());
    let first = run_elastic_collect(&cfg, FaultPlan::none())
        .expect("recording run");
    assert_eq!(first.archive_warm, None, "no archive was loaded");
    assert!(!first.first_step_cache_hit);
    let exported_id = first.first_plan_id.clone().expect("id recorded");

    let mut cfg2 = elastic_cfg(4, 5);
    cfg2.archive_in = Some(dir.to_string_lossy().into_owned());
    let second = run_elastic_collect(&cfg2, FaultPlan::none())
        .expect("warm run");
    assert_eq!(second.archive_warm, Some(true));
    assert!(
        second.first_step_cache_hit,
        "first step must replay from the archived cache"
    );
    assert_eq!(
        second.first_plan_id,
        Some(exported_id),
        "bit-identical replay across sessions"
    );
    // Plans are SPMD-deterministic, so the warm run's losses bit-match
    // the recording run's.
    assert_eq!(second.losses, first.losses);

    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn shrunk_world_export_carries_the_new_topology() {
    let dir = scratch("elastic-shrink");
    let mut cfg = elastic_cfg(4, 6);
    cfg.archive_out = Some(dir.to_string_lossy().into_owned());
    // Rank 2 resigns before step 3: the world shrinks 4 -> 3 and the
    // surviving minimum-id member re-exports after the transition and
    // again at clean exit.
    let report =
        run_elastic_collect(&cfg, FaultPlan::resignation(2, 3))
            .expect("shrinking run");
    assert_eq!(report.transitions.len(), 1);

    let archive = Archive::open(&dir).unwrap().expect("export exists");
    assert_eq!(
        archive.manifest.topology.instances, 3,
        "the exported fingerprint must describe the shrunk world"
    );

    // Loading that archive into a launch-world (4-member) run degrades
    // to a cold start — wrong-world plans are never reused.
    let mut cfg2 = elastic_cfg(4, 5);
    cfg2.archive_in = Some(dir.to_string_lossy().into_owned());
    let cold = run_elastic_collect(&cfg2, FaultPlan::none())
        .expect("mismatched-world run still succeeds");
    assert_eq!(
        cold.archive_warm,
        Some(false),
        "topology mismatch must degrade to cold start"
    );
    assert!(!cold.first_step_cache_hit);

    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn mismatched_world_cold_start_reports_a_reason() {
    let dir = scratch("session-mismatch");
    let cfg = OrchestratorConfig::orchmllm(7168.0);
    let session = PlanSession::new(
        cfg.clone(),
        PipelineConfig::default(),
        Topology::h100(8),
    );
    session.export_archive(&dir).expect("export empty session");

    let (_session, warm) = PlanSession::with_archive(
        cfg,
        PipelineConfig::default(),
        Topology::h100(16),
        &dir,
    )
    .expect("mismatch is a degrade, not an error");
    match warm {
        WarmStart::Cold { reason } => assert!(
            reason.contains("topology fingerprint mismatch"),
            "reason must name the mismatch: {reason}"
        ),
        WarmStart::Warm { .. } => {
            panic!("wrong-world archive must not warm-start")
        }
    }
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn missing_archive_directory_is_a_cold_start() {
    let dir = scratch("absent");
    let cfg = OrchestratorConfig::orchmllm(7168.0);
    let (_session, warm) = PlanSession::with_archive(
        cfg,
        PipelineConfig::default(),
        Topology::h100(8),
        &dir,
    )
    .expect("missing archive is not an error");
    match warm {
        WarmStart::Cold { reason } => {
            assert!(reason.contains("no archive"), "{reason}")
        }
        WarmStart::Warm { .. } => panic!("nothing to warm-start from"),
    }
}
