//! Two-process plan-archive round trip over `tcp-multiproc`: a real
//! elastic run exports an archive, a second OS process loads it and
//! must replay the first step bit-identically (pinned by the plan's
//! content id crossing the process boundary through the archive). Also
//! pins the `orchmllm archive verify` CLI contract: exit 0 on a clean
//! archive, exit 2 on a corrupted payload.

use std::fs;
use std::path::{Path, PathBuf};
use std::process::Command;

use orchmllm::util::json::Json;

fn bin() -> &'static Path {
    Path::new(env!("CARGO_BIN_EXE_orchmllm"))
}

fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "orchmllm-archive-proc-{}-{tag}",
        std::process::id()
    ));
    let _ = fs::remove_dir_all(&dir);
    fs::create_dir_all(&dir).unwrap();
    dir
}

fn run_elastic(archive_flag: &str, archive_dir: &Path, out: &Path) {
    let status = Command::new(bin())
        .args([
            "elastic",
            "--workers",
            "2",
            "--mini-batch",
            "3",
            "--steps",
            "4",
            "--seed",
            "11",
            "--min-world",
            "1",
            "--transport",
            "tcp-multiproc",
            archive_flag,
        ])
        .arg(archive_dir)
        .arg("--out")
        .arg(out)
        .status()
        .expect("spawn orchmllm elastic");
    assert!(status.success(), "elastic run failed: {status}");
}

fn read_report(path: &Path) -> Json {
    let text = fs::read_to_string(path).expect("report file");
    Json::parse(&text).expect("report parses")
}

#[test]
fn two_process_round_trip_replays_bit_identically() {
    let root = scratch("roundtrip");
    let archive_dir = root.join("archive");
    let r1 = root.join("r1.json");
    let r2 = root.join("r2.json");

    // Process tree 1: record and export.
    run_elastic("--archive-out", &archive_dir, &r1);
    let first = read_report(&r1);
    assert_eq!(first.get("archive_warm").as_bool(), None);
    let exported_id = first
        .get("first_plan_id")
        .as_str()
        .expect("recording run logs its first plan id")
        .to_string();

    // Process tree 2: a fresh process loads the archive and must
    // warm-start — same configuration, so the first step replays the
    // archived plan, hashing to the same content id.
    run_elastic("--archive-in", &archive_dir, &r2);
    let second = read_report(&r2);
    assert_eq!(second.get("archive_warm").as_bool(), Some(true));
    assert_eq!(
        second.get("first_step_cache_hit").as_bool(),
        Some(true),
        "first step must replay from the restored cache"
    );
    assert_eq!(
        second.get("first_plan_id").as_str(),
        Some(exported_id.as_str()),
        "plan content id must survive the process boundary"
    );
    // SPMD determinism: the warm run's loss trajectory bit-matches.
    assert_eq!(
        second.get("losses").pretty(),
        first.get("losses").pretty()
    );

    // CLI contract: a clean archive verifies with exit 0.
    let out = Command::new(bin())
        .args(["archive", "verify"])
        .arg(&archive_dir)
        .output()
        .expect("spawn archive verify");
    assert!(out.status.success(), "verify must pass: {out:?}");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("archive OK"), "got: {stdout}");

    // ...and a flipped payload byte makes it exit 2.
    let payload = archive_dir.join("caches.bin");
    let mut bytes = fs::read(&payload).unwrap();
    let last = bytes.len() - 1;
    bytes[last] ^= 0x01;
    fs::write(&payload, &bytes).unwrap();
    let out = Command::new(bin())
        .args(["archive", "verify"])
        .arg(&archive_dir)
        .output()
        .expect("spawn archive verify (corrupted)");
    assert_eq!(
        out.status.code(),
        Some(2),
        "corruption is the documented exit-2 path: {out:?}"
    );

    let _ = fs::remove_dir_all(&root);
}
