//! Session-parity suite: `PlanSession::plan` must be **bit-identical**
//! to every legacy `plan_step_*` path it replaced, for every registered
//! balancer, before the legacy methods can be removed for good.
//!
//! The legacy methods survive only as `#[doc(hidden)]` `#[deprecated]`
//! shims on `Orchestrator` — this suite is their sole sanctioned
//! caller (hence the file-wide `allow(deprecated)`). Each test drives
//! the same sampled mini-batches through a session strategy and the
//! corresponding shim and asserts equality of everything a plan
//! determines: per-phase assignments, physical routes, node-wise
//! permutations, priced communication, composed encoder-output routes,
//! and solve provenance.

#![allow(deprecated)]

use orchmllm::balance::registry;
use orchmllm::comm::topology::Topology;
use orchmllm::data::synth::{DatasetConfig, Example, Generator};
use orchmllm::orchestrator::global::{
    Orchestrator, OrchestratorConfig, StepHistory, StepPlan, StepScratch,
};
use orchmllm::orchestrator::pipeline::PipelineConfig;
use orchmllm::orchestrator::session::{PlanOptions, PlanSession};

fn sample(d: usize, b: usize, seed: u64) -> Vec<Vec<Example>> {
    let mut g = Generator::new(DatasetConfig::default(), seed);
    (0..d).map(|_| g.batch(b)).collect()
}

/// Orchestrator config with one registered balancer on every phase.
fn cfg_for(name: &str) -> OrchestratorConfig {
    OrchestratorConfig::orchmllm(7168.0)
        .with_balancer(registry::must(name))
}

/// Everything a step plan determines must match, bit for bit.
fn assert_plans_identical(name: &str, a: &StepPlan, b: &StepPlan) {
    assert_eq!(a.d, b.d, "{name}: d");
    assert_eq!(a.examples, b.examples, "{name}: examples");
    assert_eq!(a.home, b.home, "{name}: home placement");
    for (phase, pa, pb) in [
        ("vision", &a.vision.plan, &b.vision.plan),
        ("audio", &a.audio.plan, &b.audio.plan),
    ] {
        assert_eq!(pa.assignment, pb.assignment, "{name}/{phase}");
        assert_eq!(pa.route, pb.route, "{name}/{phase} route");
        assert_eq!(pa.nodewise_perm, pb.nodewise_perm, "{name}/{phase}");
        assert_eq!(pa.comm, pb.comm, "{name}/{phase} comm");
        assert_eq!(pa.source, pb.source, "{name}/{phase} source");
    }
    assert_eq!(a.llm.assignment, b.llm.assignment, "{name}/llm");
    assert_eq!(a.llm.route, b.llm.route, "{name}/llm route");
    assert_eq!(a.llm.nodewise_perm, b.llm.nodewise_perm, "{name}/llm");
    assert_eq!(a.llm.comm, b.llm.comm, "{name}/llm comm");
    assert_eq!(a.llm.source, b.llm.source, "{name}/llm source");
    assert_eq!(a.vision.out_route, b.vision.out_route, "{name}/vis out");
    assert_eq!(a.audio.out_route, b.audio.out_route, "{name}/aud out");
    assert_eq!(a.vision.out_comm, b.vision.out_comm, "{name}/vis out");
    assert_eq!(a.audio.out_comm, b.audio.out_comm, "{name}/aud out");
}

#[test]
fn from_scratch_parallel_matches_legacy_plan_step_with() {
    for name in registry::NAMES {
        let topo = Topology::h100(6);
        let mbs = sample(6, 10, 7);
        let orch = Orchestrator::new(cfg_for(name));
        let mut scratch = StepScratch::default();
        let mut session = PlanSession::with_defaults(cfg_for(name), topo);
        // Repeated calls: scratch/session reuse must not drift.
        for _ in 0..3 {
            let legacy = orch.plan_step_with(&topo, &mbs, &mut scratch);
            let new = session.plan(&mbs, PlanOptions::from_scratch());
            assert_plans_identical(name, &new, &legacy);
        }
    }
}

#[test]
fn serial_matches_legacy_plan_step_serial() {
    for name in registry::NAMES {
        let topo = Topology::h100(6);
        let mbs = sample(6, 10, 11);
        let orch = Orchestrator::new(cfg_for(name));
        let legacy = orch.plan_step_serial(&topo, &mbs);
        let mut session = PlanSession::with_defaults(cfg_for(name), topo);
        let new = session.plan(&mbs, PlanOptions::serial());
        assert_plans_identical(name, &new, &legacy);
    }
}

#[test]
fn incremental_matches_legacy_over_evolving_steps() {
    // The steady-state path: both sides carry their own evolving
    // history across steps; every step must agree bit for bit,
    // including the provenance (warm vs cold vs cached per phase).
    for name in registry::NAMES {
        let topo = Topology::h100(6);
        let orch = Orchestrator::new(cfg_for(name));
        let mut scratch = StepScratch::default();
        let mut history = StepHistory::default();
        let mut session = PlanSession::with_defaults(cfg_for(name), topo);
        let mut g = Generator::new(DatasetConfig::default(), 31);
        for step in 0..4 {
            let mbs: Vec<Vec<Example>> =
                (0..6).map(|_| g.batch(12)).collect();
            let legacy = orch.plan_step_incremental(
                &topo,
                &mbs,
                &mut scratch,
                &mut history,
            );
            let new = session.plan(&mbs, PlanOptions::auto());
            assert_plans_identical(name, &new, &legacy);
            assert_eq!(
                new.plan_sources(),
                legacy.plan_sources(),
                "{name}: provenance diverged at step {step}"
            );
        }
    }
}

#[test]
fn cached_replay_matches_legacy_cached_replay() {
    // A recurring step must replay from the step cache on both paths,
    // and the replays must equal each other and the original solve.
    for name in registry::NAMES {
        let topo = Topology::h100(6);
        let mbs = sample(6, 10, 17);
        let orch = Orchestrator::new(cfg_for(name));
        let mut scratch = StepScratch::default();
        let mut history = StepHistory::new(8);
        let mut session = PlanSession::new(
            cfg_for(name),
            PipelineConfig { plan_cache_size: 8, ..Default::default() },
            topo,
        );
        let legacy_first = orch.plan_step_incremental(
            &topo,
            &mbs,
            &mut scratch,
            &mut history,
        );
        let new_first = session.plan(&mbs, PlanOptions::auto());
        assert_plans_identical(name, &new_first, &legacy_first);
        let legacy_hit = orch.plan_step_incremental(
            &topo,
            &mbs,
            &mut scratch,
            &mut history,
        );
        let new_hit = session.plan(&mbs, PlanOptions::auto());
        assert_plans_identical(name, &new_hit, &legacy_hit);
        assert_eq!(new_hit.plan_sources(), legacy_hit.plan_sources());
        assert_eq!(
            session.report().unwrap().step_cache_hit,
            history.step_cache.hits > 0,
            "{name}: step-cache provenance disagrees with the history"
        );
    }
}

#[test]
fn cache_off_matches_a_zero_capacity_history() {
    // PlanOptions::cache(false) must behave exactly like the legacy
    // trick of threading a zero-capacity StepHistory: warm-starting
    // still applies, caching never does.
    for name in registry::NAMES {
        let topo = Topology::h100(6);
        let orch = Orchestrator::new(cfg_for(name));
        let mut scratch = StepScratch::default();
        let mut history = StepHistory::new(0);
        let mut session = PlanSession::with_defaults(cfg_for(name), topo);
        let mut g = Generator::new(DatasetConfig::default(), 23);
        for _ in 0..3 {
            let mbs: Vec<Vec<Example>> =
                (0..6).map(|_| g.batch(10)).collect();
            let legacy = orch.plan_step_incremental(
                &topo,
                &mbs,
                &mut scratch,
                &mut history,
            );
            let new = session.plan(&mbs, PlanOptions::auto().cache(false));
            assert_plans_identical(name, &new, &legacy);
        }
        assert_eq!(session.cache_hit_rate(), 0.0, "{name}");
    }
}

#[test]
fn threaded_parallel_path_matches_legacy_at_scale() {
    // 8 × 40 = 320 examples clears PARALLEL_MIN_EXAMPLES, so the
    // scoped-thread planning path really runs on both sides.
    let topo = Topology::h100(8);
    let mbs = sample(8, 40, 9);
    let orch =
        Orchestrator::new(OrchestratorConfig::orchmllm(7168.0));
    let legacy_serial = orch.plan_step_serial(&topo, &mbs);
    let mut scratch = StepScratch::default();
    let legacy_parallel = orch.plan_step_with(&topo, &mbs, &mut scratch);
    let mut session = PlanSession::with_defaults(
        OrchestratorConfig::orchmllm(7168.0),
        topo,
    );
    let new_parallel = session.plan(&mbs, PlanOptions::from_scratch());
    let new_serial = session.plan(&mbs, PlanOptions::serial());
    assert_plans_identical("orchmllm", &new_parallel, &legacy_parallel);
    assert_plans_identical("orchmllm", &new_serial, &legacy_serial);
    // The §6 overlap is an execution strategy, not an algorithm change.
    assert_plans_identical("orchmllm", &new_parallel, &new_serial);
}

#[test]
fn auto_selected_configs_run_through_the_session() {
    // `--balancer auto` resolves per phase from model metadata; the
    // resulting mixed-balancer config must plan identically through
    // the session and the legacy incremental path.
    let model = orchmllm::model::config::MllmConfig::mllm_10b();
    let cfg = OrchestratorConfig::auto(&model, 7168.0);
    let topo = Topology::h100(6);
    let mbs = sample(6, 14, 41);
    let orch = Orchestrator::new(cfg.clone());
    let mut scratch = StepScratch::default();
    let mut history = StepHistory::default();
    let legacy = orch.plan_step_incremental(
        &topo,
        &mbs,
        &mut scratch,
        &mut history,
    );
    let mut session = PlanSession::with_defaults(cfg, topo);
    let new = session.plan(&mbs, PlanOptions::auto());
    assert_plans_identical("auto", &new, &legacy);
}
