//! MLLM architecture descriptions and analytic FLOPs/memory models.
//!
//! [`config`] carries the paper's Table-1 submodule configurations
//! (MLLM-10B / 18B / 84B); [`flops`] converts them into the Eq.-2 cost
//! coefficients (α, β per phase) and absolute FLOPs/bytes that the
//! cluster simulator prices steps with.

pub mod config;
pub mod flops;

pub use config::{MllmConfig, SubmoduleConfig};
pub use flops::{PhaseKind, SubmoduleCost};
