//! Table-1 model configurations.
//!
//! Each MLLM = LLM backbone + vision encoder (ViT) + audio encoder
//! (Whisper-style ConvTransformer), with MLP connectors and per-modality
//! downsample rates (paper §8, "Models" / "Input preprocessing").

/// Which transformer flavour a submodule uses (affects parameter and
/// FLOP accounting).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BlockStyle {
    /// Qwen2-style LLM trunk: GQA attention (~3.4 h² with the Table-1
    /// head configs) + SwiGLU MLP (3 h·ffn).
    Gqa,
    /// ViT/Whisper-style encoder: MHA (4 h²) + 2-matmul MLP (2 h·ffn).
    Encoder,
}

/// One submodule's transformer shape.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SubmoduleConfig {
    pub layers: usize,
    pub hidden: usize,
    pub ffn_hidden: usize,
    pub style: BlockStyle,
    /// Convolutional front-end before the transformer stack (the
    /// Whisper-style audio encoder): attention must pad, which drives
    /// balancer auto-selection toward the conv-attention regime.
    pub conv_frontend: bool,
}

impl SubmoduleConfig {
    /// Approximate parameter count per the block style (embeddings and
    /// connectors excluded — small at Table-1 scales and identical
    /// across the systems under comparison).
    pub fn params(&self) -> f64 {
        let h = self.hidden as f64;
        let f = self.ffn_hidden as f64;
        let (attn, mlp) = match self.style {
            BlockStyle::Gqa => (3.4 * h * h, 3.0 * h * f),
            BlockStyle::Encoder => (4.0 * h * h, 2.0 * h * f),
        };
        self.layers as f64 * (attn + mlp)
    }

    /// Whether this submodule exists at all. Two-modality models (e.g.
    /// text+image-only) zero out a submodule's shape; cost/trait
    /// derivations must check this before dividing by α.
    pub fn is_present(&self) -> bool {
        self.layers > 0 && self.hidden > 0
    }
}

/// A full MLLM (Table 1 row) plus preprocessing parameters.
#[derive(Clone, Copy, Debug)]
pub struct MllmConfig {
    pub name: &'static str,
    pub llm: SubmoduleConfig,
    pub vision: SubmoduleConfig,
    pub audio: SubmoduleConfig,
    /// Encoder-output downsample before the connector (paper: 1/4/4 for
    /// vision, 2/2/4 for audio across the three sizes).
    pub vis_downsample: usize,
    pub aud_downsample: usize,
    /// Upper bound on image resolution (patch grid side comes from this
    /// and patch size 14).
    pub max_image_res: usize,
}

impl MllmConfig {
    pub fn mllm_10b() -> MllmConfig {
        MllmConfig {
            name: "MLLM-10B",
            llm: SubmoduleConfig { layers: 28, hidden: 3584, ffn_hidden: 18944, style: BlockStyle::Gqa, conv_frontend: false },
            vision: SubmoduleConfig { layers: 36, hidden: 2048, ffn_hidden: 8192, style: BlockStyle::Encoder, conv_frontend: false },
            audio: SubmoduleConfig { layers: 32, hidden: 1280, ffn_hidden: 5120, style: BlockStyle::Encoder, conv_frontend: true },
            vis_downsample: 1,
            aud_downsample: 2,
            max_image_res: 448,
        }
    }

    pub fn mllm_18b() -> MllmConfig {
        MllmConfig {
            name: "MLLM-18B",
            llm: SubmoduleConfig { layers: 48, hidden: 5120, ffn_hidden: 13824, style: BlockStyle::Gqa, conv_frontend: false },
            vision: SubmoduleConfig { layers: 40, hidden: 2400, ffn_hidden: 9600, style: BlockStyle::Encoder, conv_frontend: false },
            audio: SubmoduleConfig { layers: 32, hidden: 1280, ffn_hidden: 5120, style: BlockStyle::Encoder, conv_frontend: true },
            vis_downsample: 4,
            aud_downsample: 2,
            max_image_res: 672,
        }
    }

    pub fn mllm_84b() -> MllmConfig {
        MllmConfig {
            name: "MLLM-84B",
            llm: SubmoduleConfig { layers: 80, hidden: 8192, ffn_hidden: 29568, style: BlockStyle::Gqa, conv_frontend: false },
            vision: SubmoduleConfig { layers: 45, hidden: 3200, ffn_hidden: 12800, style: BlockStyle::Encoder, conv_frontend: false },
            audio: SubmoduleConfig { layers: 48, hidden: 3072, ffn_hidden: 12288, style: BlockStyle::Encoder, conv_frontend: true },
            vis_downsample: 4,
            aud_downsample: 4,
            max_image_res: 896,
        }
    }

    pub fn by_name(name: &str) -> Option<MllmConfig> {
        match name.to_ascii_lowercase().as_str() {
            "mllm-10b" | "10b" => Some(Self::mllm_10b()),
            "mllm-18b" | "18b" => Some(Self::mllm_18b()),
            "mllm-84b" | "84b" => Some(Self::mllm_84b()),
            _ => None,
        }
    }

    pub fn all() -> [MllmConfig; 3] {
        [Self::mllm_10b(), Self::mllm_18b(), Self::mllm_84b()]
    }

    pub fn total_params(&self) -> f64 {
        self.llm.params() + self.vision.params() + self.audio.params()
    }

    /// Max vision patches per image: (res/14)² at the configured cap.
    pub fn max_patches(&self) -> usize {
        let side = self.max_image_res / 14;
        side * side
    }

    /// The per-phase facts balancer auto-selection decides on
    /// (`--balancer auto`, DESIGN.md §Exact Balancer & Auto-Selection):
    /// the submodule's front-end + batching constraints, and the
    /// attention share `β·L/α` at the phase's *maximum* sequence length
    /// — the straggler length post-balancing exists to fix. Length caps
    /// come from this config (vision) and the dataset defaults
    /// (audio frames, text tokens), matching what `sim::engine` feeds
    /// the generator.
    pub fn phase_traits(
        &self,
        phase: crate::model::flops::PhaseKind,
    ) -> crate::balance::select::PhaseTraits {
        use crate::data::synth::DatasetConfig;
        use crate::model::flops::{PhaseKind, SubmoduleCost};
        let data = DatasetConfig::default();
        let (sub, max_len) = match phase {
            PhaseKind::Vision => (&self.vision, self.max_patches()),
            PhaseKind::Audio => (&self.audio, data.max_aud),
            PhaseKind::Llm => (
                &self.llm,
                data.max_text
                    + self.max_patches() / self.vis_downsample
                    + data.max_aud / self.aud_downsample,
            ),
        };
        let cost = SubmoduleCost::from_config(sub, 0.0);
        crate::balance::select::PhaseTraits {
            conv_frontend: sub.conv_frontend,
            // A conv front-end is the only thing forcing padding in the
            // Table-1 architectures (paper §8 "Input preprocessing").
            padded: sub.conv_frontend,
            // Absent submodule (two-modality model): α = 0 would make
            // this NaN and poison auto-selection comparisons; an absent
            // phase has no attention share.
            beta_len_over_alpha: if sub.is_present() {
                cost.beta_flops * max_len as f64 / cost.alpha_flops
            } else {
                0.0
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_param_counts_are_close() {
        // Paper designations: 7B/2B/0.6B, 14B/3B/0.6B, 72B/6B/6B.
        let m10 = MllmConfig::mllm_10b();
        assert!((m10.llm.params() / 1e9 - 7.0).abs() < 1.5, "{}", m10.llm.params() / 1e9);
        assert!((m10.vision.params() / 1e9 - 2.0).abs() < 0.7);
        assert!((m10.audio.params() / 1e9 - 0.6).abs() < 0.3);

        let m84 = MllmConfig::mllm_84b();
        assert!((m84.llm.params() / 1e9 - 72.0).abs() < 10.0);
        assert!((m84.total_params() / 1e9 - 84.0).abs() < 12.0);
    }

    #[test]
    fn sizes_are_ordered() {
        let [a, b, c] = MllmConfig::all();
        assert!(a.total_params() < b.total_params());
        assert!(b.total_params() < c.total_params());
    }

    #[test]
    fn by_name_resolves() {
        assert_eq!(MllmConfig::by_name("mllm-18b").unwrap().name, "MLLM-18B");
        assert_eq!(MllmConfig::by_name("84B").unwrap().name, "MLLM-84B");
        assert!(MllmConfig::by_name("nope").is_none());
    }

    #[test]
    fn max_patches_scale_with_resolution() {
        assert_eq!(MllmConfig::mllm_10b().max_patches(), 32 * 32);
        assert_eq!(MllmConfig::mllm_84b().max_patches(), 64 * 64);
    }

    /// A text+image-only model: audio zeroed out entirely.
    fn two_modality() -> MllmConfig {
        MllmConfig {
            audio: SubmoduleConfig {
                layers: 0,
                hidden: 0,
                ffn_hidden: 0,
                style: BlockStyle::Encoder,
                conv_frontend: false,
            },
            ..MllmConfig::mllm_10b()
        }
    }

    #[test]
    fn two_modality_traits_are_finite() {
        use crate::model::flops::PhaseKind;
        let m = two_modality();
        assert!(!m.audio.is_present());
        assert!(m.vision.is_present() && m.llm.is_present());
        // Regression: α = 0 used to make β·L/α NaN, which poisons every
        // auto-selection comparison downstream.
        for phase in PhaseKind::ALL {
            let t = m.phase_traits(phase);
            assert!(
                t.beta_len_over_alpha.is_finite(),
                "{phase:?}: β·L/α = {}",
                t.beta_len_over_alpha
            );
        }
        assert_eq!(
            m.phase_traits(PhaseKind::Audio).beta_len_over_alpha,
            0.0
        );
        assert!(m.total_params() > 0.0);
    }

    #[test]
    fn phase_traits_reflect_the_architecture() {
        use crate::model::flops::PhaseKind;
        for m in MllmConfig::all() {
            let vis = m.phase_traits(PhaseKind::Vision);
            let aud = m.phase_traits(PhaseKind::Audio);
            let llm = m.phase_traits(PhaseKind::Llm);
            // Only the Whisper-style audio encoder has a conv
            // front-end, and conv is what forces padding.
            assert!(!vis.conv_frontend && !vis.padded, "{}", m.name);
            assert!(aud.conv_frontend && aud.padded, "{}", m.name);
            assert!(!llm.conv_frontend && !llm.padded, "{}", m.name);
            // Attention share is a sane fraction at every Table-1 scale.
            for t in [vis, llm] {
                assert!(
                    t.beta_len_over_alpha > 0.0
                        && t.beta_len_over_alpha < 1.0,
                    "{}: β·L/α = {}",
                    m.name,
                    t.beta_len_over_alpha
                );
            }
        }
    }
}
