//! FLOPs and memory models for MLLM phases.
//!
//! Converts a [`SubmoduleConfig`] into:
//! * the Eq.-2 coefficients (α = FLOPs per token from the token-linear
//!   matmuls, β = FLOPs per token² from attention) used by the balancing
//!   algorithms and priced by the simulator;
//! * activation-memory bytes per token (for the OOM analysis of the
//!   Fig. 10/12 ablations);
//! * payload bytes per token for communicator volume accounting.

use super::config::SubmoduleConfig;
use crate::balance::cost::CostModel;
use crate::balance::types::ExampleRef;

/// Which phase of an iteration a cost belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PhaseKind {
    Vision,
    Audio,
    Llm,
}

impl PhaseKind {
    pub const ALL: [PhaseKind; 3] =
        [PhaseKind::Vision, PhaseKind::Audio, PhaseKind::Llm];

    pub fn name(&self) -> &'static str {
        match self {
            PhaseKind::Vision => "vision",
            PhaseKind::Audio => "audio",
            PhaseKind::Llm => "llm",
        }
    }
}

/// Analytic cost description of one submodule.
#[derive(Clone, Copy, Debug)]
pub struct SubmoduleCost {
    /// FLOPs per token, forward pass (token-linear matmul work).
    pub alpha_flops: f64,
    /// FLOPs per token-pair, forward pass (attention score+value work).
    pub beta_flops: f64,
    /// bwd/fwd FLOP multiplier (classic 2x for matmul-dominated nets).
    pub bwd_mult: f64,
    /// Activation bytes held per token during fwd (for recompute-free
    /// training; drives the OOM analysis).
    pub act_bytes_per_token: f64,
    /// Payload bytes per token when this phase's inputs move in an
    /// All-to-All / All-Gather (metadata for encoders, embeddings for
    /// the LLM phase).
    pub payload_bytes_per_token: f64,
}

impl SubmoduleCost {
    /// Derive from a submodule shape.
    ///
    /// * α: 2 FLOPs/MAC × matmul params per token (the classic
    ///   "fwd FLOPs ≈ 2·N·tokens"), style-aware via
    ///   [`SubmoduleConfig::params`].
    /// * β: 2 FLOPs/MAC × 2 matmuls (QKᵀ, PV) × h per layer.
    /// * activations: ~4·h floats/layer/token — activation
    ///   checkpointing keeps layer inputs + flash-attention working set
    ///   (calibrated so Table-1 models at the paper's mini-batch sizes
    ///   land near the H100's 80 GB, reproducing the Fig. 10/12 OOM
    ///   crossovers).
    pub fn from_config(cfg: &SubmoduleConfig, payload_bytes_per_token: f64)
        -> SubmoduleCost {
        let h = cfg.hidden as f64;
        let l = cfg.layers as f64;
        SubmoduleCost {
            alpha_flops: 2.0 * cfg.params(),
            beta_flops: 2.0 * l * 2.0 * h,
            bwd_mult: 2.0,
            act_bytes_per_token: l * 4.0 * h,
            payload_bytes_per_token,
        }
    }

    /// The Eq.-2 [`CostModel`] in FLOP units (fwd+bwd).
    pub fn cost_model(&self, padded: bool) -> CostModel {
        let mult = 1.0 + self.bwd_mult;
        let alpha = self.alpha_flops * mult;
        let beta = self.beta_flops * mult;
        if padded {
            CostModel::TransformerPadded { alpha, beta }
        } else {
            CostModel::TransformerUnpadded { alpha, beta }
        }
    }

    /// Total fwd+bwd FLOPs for a mini-batch (the simulator's price).
    pub fn flops(&self, batch: &[ExampleRef], padded: bool) -> f64 {
        self.cost_model(padded).eval(batch)
    }

    /// *Effective* FLOPs: computed over true lengths (no padding),
    /// matching the paper's MFU definition ("effective GPU FLOPs
    /// without paddings").
    pub fn effective_flops(&self, batch: &[ExampleRef]) -> f64 {
        self.cost_model(false).eval(batch)
    }

    /// Peak activation bytes for a mini-batch.
    pub fn act_bytes(&self, batch: &[ExampleRef], padded: bool) -> f64 {
        let tokens = if padded {
            batch.len() as f64
                * batch.iter().map(|e| e.len).max().unwrap_or(0) as f64
        } else {
            batch.iter().map(|e| e.len).sum::<usize>() as f64
        };
        tokens * self.act_bytes_per_token
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balance::types::make_refs;
    use crate::model::config::MllmConfig;

    #[test]
    fn alpha_matches_6nd_rule() {
        // fwd+bwd FLOPs per token ≈ 6 × params is the standard estimate;
        // our α(1+bwd_mult) should be within 10% of it.
        let cfg = MllmConfig::mllm_10b().llm;
        let c = SubmoduleCost::from_config(&cfg, 2.0 * cfg.hidden as f64);
        let per_token = c.alpha_flops * (1.0 + c.bwd_mult);
        let rule = 6.0 * cfg.params();
        assert!(
            (per_token / rule - 1.0).abs() < 0.1,
            "{per_token} vs {rule}"
        );
    }

    #[test]
    fn beta_is_much_smaller_than_alpha() {
        // The paper's β ≪ α assumption must hold at Table-1 scales for
        // typical sequence lengths.
        let cfg = MllmConfig::mllm_10b().llm;
        let c = SubmoduleCost::from_config(&cfg, 0.0);
        // attention work equals linear work only at l ≈ α/β tokens:
        let crossover = c.alpha_flops / c.beta_flops;
        assert!(crossover > 8_000.0, "crossover at {crossover} tokens");
    }

    #[test]
    fn flops_scale_with_tokens() {
        let cfg = MllmConfig::mllm_10b().vision;
        let c = SubmoduleCost::from_config(&cfg, 0.0);
        let small = c.flops(&make_refs(&[128]), false);
        let large = c.flops(&make_refs(&[256]), false);
        assert!(large > 1.9 * small && large < 2.2 * small);
    }

    #[test]
    fn padded_flops_exceed_effective() {
        let cfg = MllmConfig::mllm_10b().audio;
        let c = SubmoduleCost::from_config(&cfg, 0.0);
        let batch = make_refs(&[100, 10, 10, 10]);
        assert!(c.flops(&batch, true) > c.effective_flops(&batch));
    }

    #[test]
    fn act_bytes_padded_vs_not() {
        let cfg = MllmConfig::mllm_10b().audio;
        let c = SubmoduleCost::from_config(&cfg, 0.0);
        let batch = make_refs(&[100, 10]);
        assert_eq!(c.act_bytes(&batch, true), 200.0 * c.act_bytes_per_token);
        assert_eq!(c.act_bytes(&batch, false), 110.0 * c.act_bytes_per_token);
    }
}
