//! Mini property-testing runner (proptest is unavailable offline).
//!
//! A property is a closure over a [`Gen`] (a seeded value source). The
//! runner executes it for `cases` random seeds; on failure it reports the
//! failing seed so the case can be replayed deterministically:
//!
//! ```no_run
//! use orchmllm::util::prop::{check, Gen};
//! check("sort is idempotent", 200, |g: &mut Gen| {
//!     let mut v = g.vec_usize(0..50, 0, 100);
//!     v.sort_unstable();
//!     let w = { let mut w = v.clone(); w.sort_unstable(); w };
//!     assert_eq!(v, w);
//! });
//! ```

use super::rng::Pcg64;

/// Seeded generator handed to each property case.
pub struct Gen {
    pub rng: Pcg64,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen {
            rng: Pcg64::new(seed),
            seed,
        }
    }

    /// usize in [lo, hi).
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo, hi)
    }

    /// f64 in [lo, hi).
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.f64() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bool(0.5)
    }

    /// Vec of usizes with random length in `len_range` and values in
    /// [vlo, vhi).
    pub fn vec_usize(
        &mut self,
        len_range: std::ops::Range<usize>,
        vlo: usize,
        vhi: usize,
    ) -> Vec<usize> {
        let n = self.usize(len_range.start, len_range.end.max(len_range.start + 1));
        (0..n).map(|_| self.usize(vlo, vhi)).collect()
    }

    /// Heavy-tailed positive lengths (log-normal), the shape real sequence
    /// data exhibits (§2.3 of the paper).
    pub fn seq_lengths(&mut self, n: usize, mu: f64, sigma: f64) -> Vec<usize> {
        (0..n)
            .map(|_| (self.rng.lognormal(mu, sigma).round() as usize).max(1))
            .collect()
    }

    /// Choose one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize(0, xs.len())]
    }
}

/// Run `prop` for `cases` seeds; panics (with the seed) on first failure.
pub fn check<F: FnMut(&mut Gen)>(name: &str, cases: u64, mut prop: F) {
    // A fixed base offset keeps suites reproducible while still varying
    // per case.
    for case in 0..cases {
        let seed = 0x5EED_0000 + case;
        let mut g = Gen::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || prop(&mut g),
        ));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("count", 50, |_g| {
            count += 1;
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_reports_seed() {
        check("fails", 10, |g| {
            assert!(g.usize(0, 10) > 100, "always false");
        });
    }

    #[test]
    fn gen_is_deterministic_per_seed() {
        let mut a = Gen::new(5);
        let mut b = Gen::new(5);
        assert_eq!(a.vec_usize(1..20, 0, 50), b.vec_usize(1..20, 0, 50));
    }

    #[test]
    fn seq_lengths_positive() {
        let mut g = Gen::new(3);
        let ls = g.seq_lengths(100, 3.0, 1.0);
        assert_eq!(ls.len(), 100);
        assert!(ls.iter().all(|&l| l >= 1));
    }
}
