//! Minimal JSON parser and writer.
//!
//! Used for run configs, artifact manifests (`artifacts/*/manifest.json`
//! written by `python/compile/aot.py`), and experiment reports. Supports
//! the full JSON grammar (objects, arrays, strings with escapes, numbers,
//! bools, null); numbers are parsed as `f64` with an `i64` fast path.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 {
                Some(f as usize)
            } else {
                None
            }
        })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// `obj["key"]`-style access; returns `Json::Null` when missing.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }

    /// Array indexing; returns `Json::Null` when out of range.
    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        self.as_arr().and_then(|a| a.get(i)).unwrap_or(&NULL)
    }

    // -- constructors --------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<N: Into<f64>>(n: N) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    /// Serialize compactly.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 1-space indentation (matches aot.py's output).
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(1), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    it.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let cp = self.hex4()?;
                            // Surrogate pairs.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                self.pos += 1; // consume 'u' position below
                                if self.peek() != Some(b'\\') {
                                    return Err(
                                        self.err("lone high surrogate")
                                    );
                                }
                                self.pos += 1;
                                if self.peek() != Some(b'u') {
                                    return Err(
                                        self.err("lone high surrogate")
                                    );
                                }
                                let lo = self.hex4()?;
                                let combined = 0x10000
                                    + ((cp - 0xD800) << 10)
                                    + (lo - 0xDC00);
                                char::from_u32(combined)
                            } else {
                                char::from_u32(cp)
                            };
                            match c {
                                Some(c) => s.push(c),
                                None => {
                                    return Err(
                                        self.err("invalid unicode escape")
                                    )
                                }
                            }
                            // hex4 leaves pos at last hex digit; advance in
                            // the common path below.
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = &self.bytes[self.pos..];
                    let ch_len = utf8_len(rest[0]);
                    let chunk = rest
                        .get(..ch_len)
                        .ok_or_else(|| self.err("truncated utf-8"))?;
                    let st = std::str::from_utf8(chunk)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(st);
                    self.pos += ch_len;
                }
            }
        }
    }

    /// Parse the 4 hex digits after a `\u`; leaves pos on the last digit.
    fn hex4(&mut self) -> Result<u32, JsonError> {
        // self.pos currently at 'u'
        let start = self.pos + 1;
        let end = start + 4;
        let chunk = self
            .bytes
            .get(start..end)
            .ok_or_else(|| self.err("truncated \\u escape"))?;
        let s = std::str::from_utf8(chunk)
            .map_err(|_| self.err("invalid \\u escape"))?;
        let v = u32::from_str_radix(s, 16)
            .map_err(|_| self.err("invalid \\u escape"))?;
        self.pos = end - 1;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"hi\\n\"").unwrap(),
            Json::Str("hi\n".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(
            r#"{"a": [1, 2, {"b": "x"}], "c": null, "d": true}"#,
        )
        .unwrap();
        assert_eq!(j.get("a").idx(2).get("b").as_str(), Some("x"));
        assert_eq!(j.get("c"), &Json::Null);
        assert_eq!(j.get("d").as_bool(), Some(true));
        assert_eq!(j.get("a").idx(0).as_usize(), Some(1));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"k":[1,2.5,"s\"x",false,null],"m":{"n":-7}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.dump()).unwrap();
        assert_eq!(j, j2);
        let j3 = Json::parse(&j.pretty()).unwrap();
        assert_eq!(j, j3);
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(j.as_str(), Some("é😀"));
    }

    #[test]
    fn utf8_passthrough() {
        let j = Json::parse("\"héllo — ok\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo — ok"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"open").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn missing_keys_yield_null() {
        let j = Json::parse(r#"{"a": 1}"#).unwrap();
        assert_eq!(j.get("nope"), &Json::Null);
        assert_eq!(j.get("nope").as_usize(), None);
        assert_eq!(j.idx(3), &Json::Null);
    }

    #[test]
    fn integers_survive_roundtrip_exactly() {
        let j = Json::parse("123456789012").unwrap();
        assert_eq!(j.dump(), "123456789012");
    }
}
