//! From-scratch substrates the crate needs in a no-network environment:
//! a seedable PRNG, a JSON parser/writer (configs + artifact manifests),
//! a tiny CLI argument parser, a criterion-style micro-bench harness, a
//! property-testing runner, summary statistics, and a SHA-256
//! implementation (content addressing + payload checksums for the plan
//! archive).

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod sha256;
pub mod stats;
