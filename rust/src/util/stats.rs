//! Summary statistics for metrics, benchmarks, and the incoherence report.

/// Online and batch summary statistics over f64 samples.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    xs: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn from_slice(xs: &[f64]) -> Self {
        Summary { xs: xs.to_vec() }
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
    }

    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    pub fn sum(&self) -> f64 {
        self.xs.iter().sum()
    }

    pub fn mean(&self) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        self.sum() / self.xs.len() as f64
    }

    pub fn var(&self) -> f64 {
        if self.xs.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        self.xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / (self.xs.len() - 1) as f64
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    /// Coefficient of variation (std / mean); 0 when mean is 0.
    pub fn cv(&self) -> f64 {
        let m = self.mean();
        if m == 0.0 {
            0.0
        } else {
            self.std() / m
        }
    }

    pub fn min(&self) -> f64 {
        self.xs.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Percentile by linear interpolation, `p` in [0, 100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.xs.is_empty() {
            return 0.0;
        }
        let mut s = self.xs.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (p / 100.0) * (s.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            let w = rank - lo as f64;
            s[lo] * (1.0 - w) + s[hi] * w
        }
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    /// Normalized histogram over `bins` equal-width buckets in [lo, hi].
    pub fn histogram(&self, lo: f64, hi: f64, bins: usize) -> Vec<f64> {
        let mut h = vec![0.0; bins];
        if self.xs.is_empty() || hi <= lo {
            return h;
        }
        for &x in &self.xs {
            let t = ((x - lo) / (hi - lo)).clamp(0.0, 1.0);
            let mut b = (t * bins as f64) as usize;
            if b == bins {
                b -= 1;
            }
            h[b] += 1.0;
        }
        let n = self.xs.len() as f64;
        for v in &mut h {
            *v /= n;
        }
        h
    }
}

/// Render a one-line unicode sparkline histogram (for terminal reports).
pub fn sparkline(values: &[f64]) -> String {
    const TICKS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let max = values.iter().copied().fold(f64::MIN, f64::max);
    if max <= 0.0 {
        return " ".repeat(values.len());
    }
    values
        .iter()
        .map(|&v| {
            let idx = ((v / max) * 7.0).round().clamp(0.0, 7.0) as usize;
            TICKS[idx]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let s = Summary::from_slice(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.mean(), 2.5);
        assert!((s.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert_eq!(s.median(), 2.5);
    }

    #[test]
    fn percentiles_interpolate() {
        let s = Summary::from_slice(&[0.0, 10.0]);
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(50.0), 5.0);
        assert_eq!(s.percentile(100.0), 10.0);
    }

    #[test]
    fn histogram_sums_to_one() {
        let s = Summary::from_slice(&(0..100).map(|i| i as f64).collect::<Vec<_>>());
        let h = s.histogram(0.0, 100.0, 10);
        assert!((h.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(h.iter().all(|&v| (v - 0.1).abs() < 0.011));
    }

    #[test]
    fn empty_summary_is_safe() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(50.0), 0.0);
        assert!(s.is_empty());
    }

    #[test]
    fn cv_of_constant_is_zero() {
        let s = Summary::from_slice(&[5.0; 10]);
        assert_eq!(s.cv(), 0.0);
    }

    #[test]
    fn sparkline_has_expected_len() {
        assert_eq!(sparkline(&[0.0, 0.5, 1.0]).chars().count(), 3);
    }
}
