//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.
//! Subcommand dispatch is handled by the caller (see `main.rs`).

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.options.insert(rest.to_string(), v);
                } else {
                    args.flags.push(rest.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    panic!("--{name} expects an integer, got '{v}'")
                })
            })
            .unwrap_or(default)
    }

    pub fn u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    panic!("--{name} expects an integer, got '{v}'")
                })
            })
            .unwrap_or(default)
    }

    pub fn f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    panic!("--{name} expects a number, got '{v}'")
                })
            })
            .unwrap_or(default)
    }

    /// First positional argument (the subcommand), if any.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()))
    }

    #[test]
    fn parses_mixed_forms() {
        // NOTE: `--key value` binds greedily, so boolean flags must come
        // last or use no trailing positional (documented semantics).
        let a = parse(&[
            "train", "extra", "--steps", "100", "--lr=0.5", "--verbose",
        ]);
        assert_eq!(a.subcommand(), Some("train"));
        assert_eq!(a.usize("steps", 0), 100);
        assert_eq!(a.f64("lr", 0.0), 0.5);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["train", "extra"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["sim"]);
        assert_eq!(a.usize("gpus", 64), 64);
        assert_eq!(a.get_or("model", "mllm-10b"), "mllm-10b");
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn trailing_flag_is_flag() {
        let a = parse(&["x", "--dry-run"]);
        assert!(a.flag("dry-run"));
    }

    #[test]
    #[should_panic(expected = "expects an integer")]
    fn bad_integer_panics() {
        let a = parse(&["--steps", "ten"]);
        a.usize("steps", 0);
    }
}
