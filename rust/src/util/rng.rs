//! Deterministic, seedable pseudo-random number generation.
//!
//! Implements PCG64 (O'Neill's permuted congruential generator, XSL-RR
//! 128/64 variant) plus SplitMix64 for seeding. No external crates; the
//! whole reproduction — data synthesis, property tests, simulator — is
//! reproducible from a single `u64` seed.

/// SplitMix64: used to expand a single seed into stream state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG XSL-RR 128/64: high-quality 64-bit output, 128-bit state.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Pcg64 {
    /// Create a generator from a seed; distinct seeds give independent
    /// streams (the increment is derived from the seed as well).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s0 = splitmix64(&mut sm) as u128;
        let s1 = splitmix64(&mut sm) as u128;
        let i0 = splitmix64(&mut sm) as u128;
        let i1 = splitmix64(&mut sm) as u128;
        let mut rng = Pcg64 {
            state: (s0 << 64) | s1,
            inc: (((i0 << 64) | i1) << 1) | 1,
        };
        rng.next_u64();
        rng
    }

    /// Derive an independent child stream (for per-worker determinism).
    pub fn fork(&mut self, tag: u64) -> Pcg64 {
        let a = self.next_u64() ^ tag.rotate_left(17);
        Pcg64::new(a)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, n)` without modulo bias (Lemire's method).
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        assert!(n > 0, "gen_range(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform `usize` in `[lo, hi)` (panics if `lo >= hi`).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "range({lo}, {hi})");
        lo + self.gen_range((hi - lo) as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with the given log-space mean and stddev.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted() needs positive total weight");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if x < *w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// `true` with probability `p`.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn range_bounds_hold() {
        let mut r = Pcg64::new(7);
        for _ in 0..10_000 {
            let x = r.range(3, 17);
            assert!((3..17).contains(&x));
        }
    }

    #[test]
    fn gen_range_covers_support() {
        let mut r = Pcg64::new(9);
        let mut seen = [false; 8];
        for _ in 0..10_000 {
            seen[r.gen_range(8) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Pcg64::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(13);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Pcg64::new(17);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.weighted(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let frac = counts[2] as f64 / 30_000.0;
        assert!((frac - 0.7).abs() < 0.03, "frac={frac}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(19);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Pcg64::new(23);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
