//! Criterion-style micro-benchmark harness (criterion is unavailable in
//! this offline environment, so the crate ships its own).
//!
//! Usage inside a `[[bench]] harness = false` target:
//!
//! ```no_run
//! use orchmllm::util::bench::Bencher;
//! let mut b = Bencher::new("alg1_greedy");
//! b.iter("n=1k", || { /* workload */ });
//! b.report();
//! ```
//!
//! Each case is warmed up, then timed for a fixed wall budget or minimum
//! iteration count, and reported as mean / p50 / p99 with throughput-
//! friendly nanosecond resolution.

use std::time::{Duration, Instant};

use super::stats::Summary;

/// Per-case timing configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub budget: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 10_000,
            budget: Duration::from_millis(700),
        }
    }
}

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct CaseResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
}

impl CaseResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }
}

/// A named group of benchmark cases.
pub struct Bencher {
    group: String,
    config: BenchConfig,
    results: Vec<CaseResult>,
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:8.1} ns")
    } else if ns < 1e6 {
        format!("{:8.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:8.3} ms", ns / 1e6)
    } else {
        format!("{:8.3} s ", ns / 1e9)
    }
}

impl Bencher {
    pub fn new(group: &str) -> Self {
        Bencher {
            group: group.to_string(),
            config: BenchConfig::default(),
            results: Vec::new(),
        }
    }

    pub fn with_config(group: &str, config: BenchConfig) -> Self {
        Bencher {
            group: group.to_string(),
            config,
            results: Vec::new(),
        }
    }

    /// Time `f`, preventing the compiler from eliding its result.
    pub fn iter<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F)
        -> &CaseResult {
        for _ in 0..self.config.warmup_iters {
            std::hint::black_box(f());
        }
        let mut samples = Summary::new();
        let start = Instant::now();
        let mut iters = 0;
        while iters < self.config.min_iters
            || (start.elapsed() < self.config.budget
                && iters < self.config.max_iters)
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64);
            iters += 1;
        }
        let res = CaseResult {
            name: name.to_string(),
            iters,
            mean_ns: samples.mean(),
            p50_ns: samples.percentile(50.0),
            p99_ns: samples.percentile(99.0),
            min_ns: samples.min(),
        };
        self.results.push(res);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[CaseResult] {
        &self.results
    }

    /// Print a criterion-like table for this group.
    pub fn report(&self) {
        println!("\n== bench group: {} ==", self.group);
        println!(
            "{:<38} {:>10} {:>11} {:>11} {:>11}",
            "case", "iters", "mean", "p50", "p99"
        );
        for r in &self.results {
            println!(
                "{:<38} {:>10} {} {} {}",
                r.name,
                r.iters,
                fmt_ns(r.mean_ns),
                fmt_ns(r.p50_ns),
                fmt_ns(r.p99_ns)
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bencher::with_config(
            "t",
            BenchConfig {
                warmup_iters: 1,
                min_iters: 5,
                max_iters: 5,
                budget: Duration::from_millis(1),
            },
        );
        let r = b.iter("spin", || {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(r.mean_ns > 0.0);
        assert_eq!(r.iters, 5);
    }

    #[test]
    fn collects_multiple_cases() {
        let cfg = BenchConfig {
            warmup_iters: 0,
            min_iters: 2,
            max_iters: 2,
            budget: Duration::from_millis(1),
        };
        let mut b = Bencher::with_config("t", cfg);
        b.iter("a", || 1 + 1);
        b.iter("b", || 2 + 2);
        assert_eq!(b.results().len(), 2);
    }
}
