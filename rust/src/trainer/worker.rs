//! One DP worker: executes the orchestrator's [`StepPlan`] against real
//! PJRT executables, moving example payloads through a pluggable
//! [`Transport`] exactly as the paper's communicator would over NCCL.
//! The worker is generic over `dyn Transport`, so the identical SPMD
//! code runs over in-process channels (`--transport inproc`) or
//! loopback TCP sockets (`--transport tcp`) — see
//! `crate::comm::transport`.
//!
//! Per step (SPMD across workers):
//!   1. vision/audio phase inputs All-to-All (metadata moves home →
//!      encoder-phase instance);
//!   2. encoder forward per bucket chunk;
//!   3. encoder outputs All-to-All along the *composed* route
//!      `Π_M ∘ Π_E⁻¹` (one hop, §6), text along the LLM route;
//!   4. LLM phase fwd+bwd; gradients w.r.t. injected encoder tokens
//!      come back;
//!   5. d(tokens) All-to-All along the inverse composed route;
//!   6. encoder backward per chunk;
//!   7. gradient all-reduce + global-token-count SGD rescale (the sum
//!      formulation that makes everything rearrangement-invariant).

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::comm::topology::Topology;
use crate::comm::transport::{Shard, Transport};
use crate::runtime::xla_stub as xla;
use crate::data::synth::Example;
use crate::orchestrator::global::StepPlan;
use crate::runtime::engine::Runtime;
use crate::runtime::manifest::ArtifactSpec;
use crate::runtime::tensor::HostTensor;

use super::content::ContentGen;

/// Legacy wire-tuple aliases (same byte layout as [`Shard`]'s f32/i32
/// variants). The step path now moves [`Shard`]s — `Arc`-shared
/// payloads that in-process backends pass without copying — but the
/// tuples remain the canonical byte manifests for external tooling.
pub type F32Msg = (usize, Vec<f32>);
pub type I32Msg = (usize, Vec<i32>);

/// One worker's state.
pub struct Worker {
    pub rank: usize,
    pub topo: Topology,
    pub runtime: Runtime,
    /// Rank-scoped handle into the collective group; every payload the
    /// step moves goes through this, so swapping the backend swaps the
    /// whole comm substrate.
    pub transport: Box<dyn Transport>,
    pub content: ContentGen,
    /// Parameters cached as device-ready literals: converted once at
    /// init and refreshed once per optimizer step, instead of per bucket
    /// chunk (EXPERIMENTS.md §Perf L3-2).
    pub params: HashMap<String, Vec<xla::Literal>>,
    pub lr: f64,
}

/// Outcome of one step on one worker (identical on all ranks for the
/// reduced fields).
#[derive(Clone, Debug)]
pub struct StepOutcome {
    pub loss: f64,
    pub tokens: f64,
    pub comm_seconds: f64,
    pub compute_seconds: f64,
}

struct EncoderState {
    /// Cached chunk inputs for the backward pass:
    /// (chunk example ids, input tensor, mask tensor).
    chunks: Vec<(Vec<usize>, HostTensor, HostTensor)>,
    /// Encoder output rows per example id: `[tokens, d_llm]` flattened.
    /// `Arc`-shared so routing them onward is a refcount bump on the
    /// in-process fast path, never a buffer clone.
    out_rows: HashMap<usize, Arc<Vec<f32>>>,
}

/// Unpack an f32-shard all-to-all result into an id-keyed row map.
fn f32_rows(
    received: Vec<(usize, Shard)>,
) -> Result<HashMap<usize, Arc<Vec<f32>>>> {
    received
        .into_iter()
        .map(|(_src, shard)| shard.into_f32())
        .collect()
}

impl Worker {
    pub fn new(
        rank: usize,
        topo: Topology,
        artifacts: &Path,
        transport: Box<dyn Transport>,
        content: ContentGen,
        lr: f64,
    ) -> Result<Worker> {
        if transport.rank() != rank {
            bail!(
                "transport handle is scoped to rank {} but worker is \
                 rank {rank}",
                transport.rank()
            );
        }
        if transport.world_size() != topo.instances {
            bail!(
                "transport world size {} != topology instances {}",
                transport.world_size(),
                topo.instances
            );
        }
        let runtime = Runtime::load(artifacts, &[])?;
        let mut params = HashMap::new();
        for sub in ["vision", "audio", "llm"] {
            let lits = runtime
                .load_params(sub)?
                .iter()
                .map(|t| t.to_literal())
                .collect::<Result<Vec<_>>>()?;
            params.insert(sub.to_string(), lits);
        }
        Ok(Worker { rank, topo, runtime, transport, content, params, lr })
    }

    fn cfg(&self) -> &crate::runtime::manifest::ModelInfo {
        &self.runtime.manifest.config
    }

    /// Execute one planned training step. `plan` is identical on every
    /// rank (deterministic planning from shared lengths).
    pub fn step(&mut self, plan: &StepPlan) -> Result<StepOutcome> {
        let t_all = std::time::Instant::now();
        let mut comm_s = 0.0f64;

        // ---- 1+2: encoder phases ------------------------------------------
        let vision = self.encoder_phase(plan, Phase::Vision, &mut comm_s)?;
        let audio = self.encoder_phase(plan, Phase::Audio, &mut comm_s)?;

        // ---- 3: composed routes to the LLM phase -----------------------
        let vis_tokens =
            self.route_tokens(plan, &plan.vision.out_route, &vision, &mut comm_s)?;
        let aud_tokens =
            self.route_tokens(plan, &plan.audio.out_route, &audio, &mut comm_s)?;
        let texts = self.route_text(plan, &mut comm_s)?;

        // ---- 4: LLM phase ----------------------------------------------------
        let (loss_sum, token_count, d_vis_rows, d_aud_rows, llm_grads) =
            self.llm_phase(plan, &vis_tokens, &aud_tokens, &texts)?;

        // ---- 5: gradient routes back to encoder instances -----------------
        let inv_v = plan.vision.out_route.inverse();
        let inv_a = plan.audio.out_route.inverse();
        let d_vis =
            self.route_rows_back(plan, &inv_v, d_vis_rows, &mut comm_s)?;
        let d_aud =
            self.route_rows_back(plan, &inv_a, d_aud_rows, &mut comm_s)?;

        // ---- 6: encoder backward ------------------------------------------
        let vis_grads =
            self.encoder_bwd(plan, Phase::Vision, &vision, &d_vis)?;
        let aud_grads =
            self.encoder_bwd(plan, Phase::Audio, &audio, &d_aud)?;

        // ---- 7: all-reduce + SGD ----------------------------------------------
        let t0 = std::time::Instant::now();
        let (loss_g, tokens_g) = self.reduce_and_update(
            loss_sum,
            token_count,
            vis_grads,
            aud_grads,
            llm_grads,
        )?;
        comm_s += t0.elapsed().as_secs_f64();

        Ok(StepOutcome {
            loss: loss_g / tokens_g.max(1.0),
            tokens: tokens_g,
            comm_seconds: comm_s,
            compute_seconds: t_all.elapsed().as_secs_f64() - comm_s,
        })
    }

    // -- encoder forward -----------------------------------------------------

    fn encoder_phase(
        &mut self,
        plan: &StepPlan,
        phase: Phase,
        comm_s: &mut f64,
    ) -> Result<EncoderState> {
        let route = match phase {
            Phase::Vision => &plan.vision.plan.route,
            Phase::Audio => &plan.audio.plan.route,
        };
        // Ship my home examples' metadata to their encoder instances.
        let mut sends: Vec<(usize, Shard)> = Vec::new();
        for (g, e) in plan.examples.iter().enumerate() {
            if plan.home[g] != self.rank || phase.meta_len(e) == 0 {
                continue;
            }
            let payload = match phase {
                Phase::Vision => {
                    self.content.patches(e, self.cfg().patch_dim)
                }
                Phase::Audio => self.content.frames(e, self.cfg().mel_dim),
            };
            sends.push((route.to[g], Shard::f32(g, payload)));
        }
        let t0 = std::time::Instant::now();
        let received = self
            .transport
            .all_to_all_shards(sends)
            .context("encoder metadata all-to-all")?;
        *comm_s += t0.elapsed().as_secs_f64();
        let mut by_id = f32_rows(received)
            .context("encoder metadata all-to-all")?;

        // My encoder mini-batch, chunked into the compiled bucket.
        let my_batch: Vec<usize> = match phase {
            Phase::Vision => &plan.vision.plan.assignment[self.rank],
            Phase::Audio => &plan.audio.plan.assignment[self.rank],
        }
        .iter()
        .map(|e| e.id)
        .collect();

        let (fwd, b, l) = self.encoder_artifacts(phase, Dir::Fwd)?;
        let feat = phase.feat_dim(self.cfg());
        let mut state = EncoderState {
            chunks: Vec::new(),
            out_rows: HashMap::new(),
        };
        for chunk in my_batch.chunks(b) {
            let mut input = HostTensor::zeros_f32(&[b, l, feat]);
            let mut mask = HostTensor::zeros_i32(&[b, l]);
            for (row, &g) in chunk.iter().enumerate() {
                let e = &plan.examples[g];
                let data = by_id
                    .remove(&g)
                    .ok_or_else(|| anyhow!("payload for example {g} missing"))?;
                let n = phase.meta_len(e);
                if n > l {
                    bail!("example {g} length {n} exceeds bucket {l}");
                }
                input.f32s_mut()[row * l * feat..row * l * feat + n * feat]
                    .copy_from_slice(&data);
                for p in 0..n {
                    mask.i32s_mut()[row * l + p] = 1;
                }
            }
            let in_lits =
                [input.to_literal()?, mask.to_literal()?];
            let mut refs: Vec<&xla::Literal> =
                self.params[phase.sub()].iter().collect();
            refs.extend(in_lits.iter());
            let spec = fwd.clone();
            let out = self.runtime.execute_literals(&spec, &refs)?;
            // Single output: [b, l/r, d_llm] token buffer.
            let tokens = &out[0];
            let tok_l = tokens.shape[1];
            let d_llm = tokens.shape[2];
            for (row, &g) in chunk.iter().enumerate() {
                let e = &plan.examples[g];
                let nt = phase.token_len(e);
                let start = row * tok_l * d_llm;
                state.out_rows.insert(
                    g,
                    Arc::new(
                        tokens.f32s()[start..start + nt * d_llm].to_vec(),
                    ),
                );
            }
            state.chunks.push((chunk.to_vec(), input, mask));
        }
        Ok(state)
    }

    // -- encoder backward ------------------------------------------------------

    fn encoder_bwd(
        &mut self,
        plan: &StepPlan,
        phase: Phase,
        state: &EncoderState,
        d_out_rows: &HashMap<usize, Arc<Vec<f32>>>,
    ) -> Result<Vec<HostTensor>> {
        let (bwd, b, l) = self.encoder_artifacts(phase, Dir::Bwd)?;
        let d_llm = self.cfg().d_llm;
        let r = phase.downsample(self.cfg());
        let tok_l = l / r;
        let mut acc: Option<Vec<HostTensor>> = None;
        for (chunk, input, mask) in &state.chunks {
            let mut d_out = HostTensor::zeros_f32(&[b, tok_l, d_llm]);
            for (row, &g) in chunk.iter().enumerate() {
                let e = &plan.examples[g];
                let nt = phase.token_len(e);
                let rows = d_out_rows.get(&g).ok_or_else(|| {
                    anyhow!("d_out for example {g} missing")
                })?;
                let start = row * tok_l * d_llm;
                d_out.f32s_mut()[start..start + nt * d_llm]
                    .copy_from_slice(rows);
            }
            let in_lits = [
                input.to_literal()?,
                mask.to_literal()?,
                d_out.to_literal()?,
            ];
            let mut refs: Vec<&xla::Literal> =
                self.params[phase.sub()].iter().collect();
            refs.extend(in_lits.iter());
            let spec = bwd.clone();
            let grads = self.runtime.execute_literals(&spec, &refs)?;
            match &mut acc {
                None => acc = Some(grads),
                Some(acc) => {
                    for (a, g) in acc.iter_mut().zip(&grads) {
                        a.add_assign(g);
                    }
                }
            }
        }
        Ok(acc.unwrap_or_else(|| {
            // No chunk on this worker: zero grads of the right shapes.
            self.runtime.manifest.params[phase.sub()]
                .iter()
                .map(|p| HostTensor::zeros_f32(&p.shape))
                .collect()
        }))
    }

    // -- routing helpers -----------------------------------------------------

    /// Route encoder output rows along a rearrangement; returns rows for
    /// examples this rank hosts in the LLM phase. Each send shares the
    /// encoder's output buffer (`Arc` clone) — the in-process fast path
    /// moves it to the destination rank without ever copying the rows.
    fn route_tokens(
        &self,
        plan: &StepPlan,
        route: &crate::orchestrator::rearrangement::Rearrangement,
        state: &EncoderState,
        comm_s: &mut f64,
    ) -> Result<HashMap<usize, Arc<Vec<f32>>>> {
        let mut sends: Vec<(usize, Shard)> = Vec::new();
        for (&g, rows) in &state.out_rows {
            debug_assert_eq!(route.from[g], self.rank);
            sends.push((
                route.to[g],
                Shard::f32_shared(g, Arc::clone(rows)),
            ));
        }
        let _ = plan;
        let t0 = std::time::Instant::now();
        let received = self
            .transport
            .all_to_all_shards(sends)
            .context("encoder output all-to-all (composed route)")?;
        *comm_s += t0.elapsed().as_secs_f64();
        f32_rows(received)
            .context("encoder output all-to-all (composed route)")
    }

    /// Route gradient rows back along the inverse composed route.
    fn route_rows_back(
        &self,
        _plan: &StepPlan,
        inv_route: &crate::orchestrator::rearrangement::Rearrangement,
        rows: HashMap<usize, Arc<Vec<f32>>>,
        comm_s: &mut f64,
    ) -> Result<HashMap<usize, Arc<Vec<f32>>>> {
        let mut sends: Vec<(usize, Shard)> = Vec::new();
        for (g, data) in rows {
            debug_assert_eq!(inv_route.from[g], self.rank);
            sends.push((inv_route.to[g], Shard::f32_shared(g, data)));
        }
        let t0 = std::time::Instant::now();
        let received = self
            .transport
            .all_to_all_shards(sends)
            .context("token-gradient all-to-all (inverse route)")?;
        *comm_s += t0.elapsed().as_secs_f64();
        f32_rows(received)
            .context("token-gradient all-to-all (inverse route)")
    }

    /// Route text tokens home → LLM instance.
    fn route_text(
        &self,
        plan: &StepPlan,
        comm_s: &mut f64,
    ) -> Result<HashMap<usize, Arc<Vec<i32>>>> {
        let mut sends: Vec<(usize, Shard)> = Vec::new();
        for (g, e) in plan.examples.iter().enumerate() {
            if plan.home[g] != self.rank {
                continue;
            }
            sends.push((
                plan.llm.route.to[g],
                Shard::i32(g, self.content.text(e)),
            ));
        }
        let t0 = std::time::Instant::now();
        let received = self
            .transport
            .all_to_all_shards(sends)
            .context("text-token all-to-all")?;
        *comm_s += t0.elapsed().as_secs_f64();
        received
            .into_iter()
            .map(|(_src, shard)| shard.into_i32())
            .collect::<Result<HashMap<_, _>>>()
            .context("text-token all-to-all")
    }

    // -- LLM phase -------------------------------------------------------------

    #[allow(clippy::type_complexity)]
    fn llm_phase(
        &mut self,
        plan: &StepPlan,
        vis_tokens: &HashMap<usize, Arc<Vec<f32>>>,
        aud_tokens: &HashMap<usize, Arc<Vec<f32>>>,
        texts: &HashMap<usize, Arc<Vec<i32>>>,
    ) -> Result<(
        f64,
        f64,
        HashMap<usize, Arc<Vec<f32>>>,
        HashMap<usize, Arc<Vec<f32>>>,
        Vec<HostTensor>,
    )> {
        let spec = self
            .runtime
            .manifest
            .artifact_with_prefix("llm_step")?
            .clone();
        let (b, l, tv, ta) = (
            spec.bucket[0],
            spec.bucket[1],
            spec.bucket[2],
            spec.bucket[3],
        );
        let d_llm = self.cfg().d_llm;
        let my_batch: Vec<usize> = plan.llm.assignment[self.rank]
            .iter()
            .map(|e| e.id)
            .collect();

        let mut loss_sum = 0.0f64;
        let mut token_count = 0.0f64;
        let mut d_vis_rows = HashMap::new();
        let mut d_aud_rows = HashMap::new();
        let mut grads_acc: Option<Vec<HostTensor>> = None;

        for chunk in my_batch.chunks(b) {
            let mut token_ids = HostTensor::zeros_i32(&[b, l]);
            let mut vis_buf = HostTensor::zeros_f32(&[b, tv, d_llm]);
            let mut vis_pos = HostTensor::from_i32(&[b, tv], vec![-1; b * tv]);
            let mut aud_buf = HostTensor::zeros_f32(&[b, ta, d_llm]);
            let mut aud_pos = HostTensor::from_i32(&[b, ta], vec![-1; b * ta]);
            let mut targets = HostTensor::zeros_i32(&[b, l]);
            let mut loss_mask =
                HostTensor::from_i32(&[b, l], vec![-1; b * l]);

            for (row, &g) in chunk.iter().enumerate() {
                let e = &plan.examples[g];
                let (nv, na, nt) =
                    (e.vis_tokens, e.aud_tokens, e.text_len);
                let total = nv + na + nt;
                if total > l || nv > tv || na > ta {
                    bail!(
                        "example {g} ({nv}+{na}+{nt}) exceeds bucket \
                         ({b},{l},{tv},{ta})"
                    );
                }
                // Layout: [vision tokens][audio tokens][text].
                if nv > 0 {
                    let rows = vis_tokens.get(&g).ok_or_else(|| {
                        anyhow!("vis tokens for {g} missing")
                    })?;
                    vis_buf.f32s_mut()
                        [row * tv * d_llm..row * tv * d_llm + nv * d_llm]
                        .copy_from_slice(rows);
                    for k in 0..nv {
                        vis_pos.i32s_mut()[row * tv + k] = k as i32;
                    }
                }
                if na > 0 {
                    let rows = aud_tokens.get(&g).ok_or_else(|| {
                        anyhow!("aud tokens for {g} missing")
                    })?;
                    aud_buf.f32s_mut()
                        [row * ta * d_llm..row * ta * d_llm + na * d_llm]
                        .copy_from_slice(rows);
                    for k in 0..na {
                        aud_pos.i32s_mut()[row * ta + k] = (nv + k) as i32;
                    }
                }
                let text = texts
                    .get(&g)
                    .ok_or_else(|| anyhow!("text for {g} missing"))?;
                for (k, &tok) in text.iter().enumerate() {
                    token_ids.i32s_mut()[row * l + nv + na + k] = tok;
                }
                // Valid positions: loss_mask > -1 gates attention; 1
                // marks positions whose *next* token is a text target.
                for p in 0..total {
                    loss_mask.i32s_mut()[row * l + p] = 0;
                }
                for p in (nv + na)..(total - 1) {
                    targets.i32s_mut()[row * l + p] = text[p - nv - na + 1];
                    loss_mask.i32s_mut()[row * l + p] = 1;
                }
            }

            let in_lits = [
                token_ids.to_literal()?,
                vis_buf.to_literal()?,
                vis_pos.to_literal()?,
                aud_buf.to_literal()?,
                aud_pos.to_literal()?,
                targets.to_literal()?,
                loss_mask.to_literal()?,
            ];
            let mut refs: Vec<&xla::Literal> =
                self.params["llm"].iter().collect();
            refs.extend(in_lits.iter());
            let out = self.runtime.execute_literals(&spec, &refs)?;
            loss_sum += out[0].f32s()[0] as f64;
            token_count += out[1].f32s()[0] as f64;
            let d_vis = &out[2];
            let d_aud = &out[3];
            for (row, &g) in chunk.iter().enumerate() {
                let e = &plan.examples[g];
                if e.vis_tokens > 0 {
                    let s = row * tv * d_llm;
                    d_vis_rows.insert(
                        g,
                        Arc::new(
                            d_vis.f32s()[s..s + e.vis_tokens * d_llm]
                                .to_vec(),
                        ),
                    );
                }
                if e.aud_tokens > 0 {
                    let s = row * ta * d_llm;
                    d_aud_rows.insert(
                        g,
                        Arc::new(
                            d_aud.f32s()[s..s + e.aud_tokens * d_llm]
                                .to_vec(),
                        ),
                    );
                }
            }
            let grads = out[4..].to_vec();
            match &mut grads_acc {
                None => grads_acc = Some(grads),
                Some(acc) => {
                    for (a, g) in acc.iter_mut().zip(&grads) {
                        a.add_assign(g);
                    }
                }
            }
        }

        let llm_grads = grads_acc.unwrap_or_else(|| {
            self.runtime.manifest.params["llm"]
                .iter()
                .map(|p| HostTensor::zeros_f32(&p.shape))
                .collect()
        });
        Ok((loss_sum, token_count, d_vis_rows, d_aud_rows, llm_grads))
    }

    // -- reduction + update ---------------------------------------------------

    fn reduce_and_update(
        &mut self,
        loss_sum: f64,
        token_count: f64,
        vis_grads: Vec<HostTensor>,
        aud_grads: Vec<HostTensor>,
        llm_grads: Vec<HostTensor>,
    ) -> Result<(f64, f64)> {
        // Concatenate everything (+ loss, tokens) into one flat buffer
        // and sum-all-reduce it.
        let groups = [
            ("vision", vis_grads),
            ("audio", aud_grads),
            ("llm", llm_grads),
        ];
        let mut flat = vec![loss_sum as f32, token_count as f32];
        for (_, grads) in &groups {
            for g in grads {
                flat.extend_from_slice(g.f32s());
            }
        }
        self.transport
            .all_reduce_sum(&mut flat)
            .context("gradient all-reduce")?;
        let loss_g = flat[0] as f64;
        let tokens_g = flat[1] as f64;

        // SGD per submodule: p <- p - (lr / global_tokens) * g_sum.
        let step_scale = (self.lr / tokens_g.max(1.0)) as f32;
        let mut offset = 2;
        for (sub, grads) in groups {
            let spec = self
                .runtime
                .manifest
                .artifact(&format!("sgd_{sub}"))?
                .clone();
            let scale_lit =
                HostTensor::scalar_f32(step_scale).to_literal()?;
            let mut grad_lits = Vec::with_capacity(grads.len());
            for g in &grads {
                let n = g.len();
                grad_lits.push(
                    HostTensor::from_f32(
                        &g.shape,
                        flat[offset..offset + n].to_vec(),
                    )
                    .to_literal()?,
                );
                offset += n;
            }
            let mut refs: Vec<&xla::Literal> = vec![&scale_lit];
            refs.extend(self.params[sub].iter());
            refs.extend(grad_lits.iter());
            let new_params =
                self.runtime.execute_literals(&spec, &refs)?;
            // Refresh the literal cache once per step.
            let new_lits = new_params
                .iter()
                .map(|t| t.to_literal())
                .collect::<Result<Vec<_>>>()?;
            self.params.insert(sub.to_string(), new_lits);
        }
        Ok((loss_g, tokens_g))
    }

    // -- plumbing ---------------------------------------------------------------

    fn encoder_artifacts(&self, phase: Phase, dir: Dir)
        -> Result<(ArtifactSpec, usize, usize)> {
        let prefix = match (phase, dir) {
            (Phase::Vision, Dir::Fwd) => "vision_fwd",
            (Phase::Vision, Dir::Bwd) => "vision_bwd",
            (Phase::Audio, Dir::Fwd) => "audio_fwd",
            (Phase::Audio, Dir::Bwd) => "audio_bwd",
        };
        let spec = self
            .runtime
            .manifest
            .artifact_with_prefix(prefix)
            .with_context(|| format!("{prefix} artifact"))?
            .clone();
        let (b, l) = (spec.bucket[0], spec.bucket[1]);
        Ok((spec, b, l))
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Phase {
    Vision,
    Audio,
}

#[derive(Clone, Copy)]
enum Dir {
    Fwd,
    Bwd,
}

impl Phase {
    fn sub(&self) -> &'static str {
        match self {
            Phase::Vision => "vision",
            Phase::Audio => "audio",
        }
    }

    fn meta_len(&self, e: &Example) -> usize {
        match self {
            Phase::Vision => e.vis_len,
            Phase::Audio => e.aud_len,
        }
    }

    fn token_len(&self, e: &Example) -> usize {
        match self {
            Phase::Vision => e.vis_tokens,
            Phase::Audio => e.aud_tokens,
        }
    }

    fn feat_dim(&self, cfg: &crate::runtime::manifest::ModelInfo) -> usize {
        match self {
            Phase::Vision => cfg.patch_dim,
            Phase::Audio => cfg.mel_dim,
        }
    }

    fn downsample(&self, cfg: &crate::runtime::manifest::ModelInfo)
        -> usize {
        match self {
            Phase::Vision => cfg.vis_group,
            Phase::Audio => cfg.aud_stride,
        }
    }
}
