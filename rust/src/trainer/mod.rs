//! Real end-to-end DP training of the tiny MLLM over PJRT artifacts.
//!
//! `run` spawns one thread per DP worker. Every worker owns a
//! [`StepPipeline`]: a background thread that samples the same example
//! stream (seeded) and plans step *t+1* with the deterministic
//! [`Orchestrator`] — on reusable scratch, phases in parallel — while
//! the worker executes step *t*. That is the paper's §6 computation
//! overhead overlapping realized on the execution path, mirroring the
//! lengths-only All-Gather + replicated solve: every rank's pipeline
//! sees the identical stream, so all plans agree without extra traffic.
//! Losses and gradients are *sums*, rescaled by the global token count
//! after the all-reduce, so any rearrangement is bit-for-bit
//! consequence-invariant (validated by `rust/tests/trainer_invariance`).

pub mod content;
pub mod elastic;
pub mod worker;

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::balance::{registry, select};
use crate::comm::calibrate::{self, CalibrationSpec};
use crate::comm::topology::Topology;
use crate::comm::transport::registry as transport_registry;
use crate::config::TrainRunConfig;
use crate::data::synth::{DatasetConfig, TaskMix};
use crate::orchestrator::global::OrchestratorConfig;
use crate::orchestrator::pipeline::StepPipeline;
use crate::orchestrator::session::{PlanSession, SessionStats};
use crate::runtime::manifest::Manifest;

use content::ContentGen;
use worker::{StepOutcome, Worker};

/// Aggregated result of a training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub losses: Vec<f64>,
    pub tokens_per_step: f64,
    pub secs_per_step: f64,
    pub comm_secs_per_step: f64,
    /// Mean planning wall-time per step — spent on the pipeline thread,
    /// overlapped with execution (§6), not on the critical path.
    pub plan_secs_per_step: f64,
    /// Fraction of phase solves warm-started or replayed from a plan
    /// cache (from the session's `PlanReport`s — steady-state steps
    /// should push this toward 1.0).
    pub plan_warm_rate: f64,
    /// Fraction of phase solves replayed bit-identically from a sketch
    /// cache.
    pub plan_cache_hit_rate: f64,
    pub workers: usize,
    pub steps: usize,
    /// Which comm backend carried the run (`--transport`).
    pub transport: String,
    /// World-size transitions an elastic run survived (empty for the
    /// fixed-world trainer).
    pub transitions: Vec<elastic::WorldTransition>,
    /// Whether an `--archive-in` load actually warm-started the
    /// session (`None` when no archive was requested, `Some(false)`
    /// when the load degraded to a cold start).
    pub archive_warm: Option<bool>,
    /// Whether the first planned step replayed whole from the
    /// (possibly archive-restored) step cache.
    pub first_step_cache_hit: bool,
    /// Content id (sha256 of the canonical encoding) of the first
    /// step's plan — equal across processes when the first step
    /// replays the archived plan bit-identically. `None` when no
    /// archive endpoint was requested.
    pub first_plan_id: Option<String>,
}

impl TrainReport {
    pub fn render(&self) -> String {
        let first = self.losses.first().copied().unwrap_or(0.0);
        let last = self.losses.last().copied().unwrap_or(0.0);
        let mut curve = String::new();
        for (i, l) in self.losses.iter().enumerate() {
            if i % (self.losses.len() / 10).max(1) == 0
                || i + 1 == self.losses.len()
            {
                curve.push_str(&format!("  step {i:>4}  loss {l:.4}\n"));
            }
        }
        if !self.transitions.is_empty() {
            curve.push_str(&crate::sim::report::render_transitions(
                &self.transitions,
            ));
        }
        format!(
            "train: {} workers over '{}' transport, {} steps\n\
             {curve}loss {first:.4} -> {last:.4}\n\
             {:.0} tokens/step, {:.3}s/step ({:.1}ms comm, \
             {:.2}ms plan overlapped; {:.0}% warm solves, \
             {:.0}% cache hits)",
            self.workers,
            self.transport,
            self.steps,
            self.tokens_per_step,
            self.secs_per_step,
            self.comm_secs_per_step * 1e3,
            self.plan_secs_per_step * 1e3,
            self.plan_warm_rate * 100.0,
            self.plan_cache_hit_rate * 100.0,
        )
    }
}

/// Derive a dataset config whose lengths always fit the compiled
/// buckets (the trainer packs one example per bucket row).
pub fn dataset_for_manifest(manifest: &Manifest) -> Result<DatasetConfig> {
    let c = &manifest.config;
    let vis = manifest.artifact_with_prefix("vision_fwd")?;
    let aud = manifest.artifact_with_prefix("audio_fwd")?;
    let llm = manifest.artifact_with_prefix("llm_step")?;
    let (l, tv, ta) = (llm.bucket[1], llm.bucket[2], llm.bucket[3]);
    let max_vis = vis.bucket[1].min(tv * c.vis_group);
    let max_aud = aud.bucket[1].min(ta * c.aud_stride);
    let max_text = l
        .saturating_sub(tv + ta + 2)
        .min(c.max_seq.saturating_sub(tv + ta + 2));
    Ok(DatasetConfig {
        mix: TaskMix::default(),
        vis_downsample: c.vis_group,
        aud_downsample: c.aud_stride,
        max_vis,
        max_aud,
        max_text,
        // Scale medians down so lengths are varied but under the caps.
        scale: (max_text as f64 / 500.0).min(1.0),
    })
}

/// Workers grouped per pretend "node" — shared by [`worker_topology`]
/// and the calibrated-topology path so both agree on node shape.
pub const WORKERS_PER_NODE: usize = 2;

/// The trainer's worker topology: pretend two workers share a "node" so
/// the node-wise rearrangement path is exercised end to end.
pub fn worker_topology(workers: usize) -> Topology {
    Topology {
        instances: workers,
        per_node: WORKERS_PER_NODE.min(workers),
        intra_bw: 10e9,
        inter_bw: 1e9,
        base_latency: 0.0,
    }
}

/// [`worker_topology`] guarded by an elastic floor: refuse to build a
/// world smaller than `min_world` (the `--min-world` knob), so a
/// shrinking run stops with a clear error instead of limping on with
/// too little data parallelism.
pub fn worker_topology_with_floor(
    workers: usize,
    min_world: usize,
) -> Result<Topology> {
    if workers < min_world.max(1) {
        bail!(
            "world of {workers} worker(s) is below the configured \
             --min-world floor of {min_world}"
        );
    }
    Ok(worker_topology(workers))
}

/// Resolve the orchestrator configuration a training run uses.
pub(crate) fn orchestrator_config(
    cfg: &TrainRunConfig,
    embed_bytes: f64,
) -> Result<OrchestratorConfig> {
    let mut orch_cfg = if cfg.balance {
        OrchestratorConfig::orchmllm(embed_bytes)
    } else {
        OrchestratorConfig::no_balance(embed_bytes)
    };
    if cfg.balance {
        if let Some(name) = &cfg.balancer {
            if name == select::AUTO {
                // The tiny trainer model mirrors the paper architecture
                // (conv audio front-end, negligible attention share
                // elsewhere) — resolve each phase from that metadata.
                orch_cfg = orch_cfg.with_selected_balancers(
                    &select::trainer_phase_traits(),
                );
            } else {
                let b = registry::create(name).ok_or_else(|| {
                    anyhow!(
                        "unknown balancer '{name}' (registered: {:?})",
                        registry::NAMES
                    )
                })?;
                orch_cfg = orch_cfg.with_balancer(b);
            }
        }
    }
    Ok(orch_cfg)
}

/// Run a training job, returning the aggregated report.
pub fn run_collect(cfg: &TrainRunConfig) -> Result<TrainReport> {
    cfg.validate()?;
    let dir = Path::new(&cfg.artifacts);
    let manifest = Manifest::load(dir).with_context(|| {
        format!(
            "loading {} — run `make artifacts` first",
            dir.join("manifest.json").display()
        )
    })?;
    let data_cfg = dataset_for_manifest(&manifest)?;
    let factory =
        transport_registry::create(&cfg.transport).ok_or_else(|| {
            anyhow!(
                "unknown transport '{}' (registered: {:?})",
                cfg.transport,
                transport_registry::NAMES
            )
        })?;
    // The planner's topology: hard-coded worker constants by default,
    // or measured α/β from a calibration pass over the live backend
    // (`--calibrate-comm`) so cost estimates track the real substrate.
    let topo = if cfg.calibrate_comm {
        let cal = calibrate::calibrate(
            factory.as_ref(),
            cfg.workers,
            &CalibrationSpec::quick(),
        )
        .context("calibrating comm transport")?;
        cal.to_topology(WORKERS_PER_NODE.min(cfg.workers))
    } else {
        worker_topology(cfg.workers)
    };
    let embed_bytes = manifest.config.d_llm as f64 * 4.0;
    let orch_cfg = orchestrator_config(cfg, embed_bytes)?;
    let content =
        ContentGen { seed: cfg.seed ^ 0xC0FFEE, vocab: manifest.config.vocab };
    let transports = factory.connect(cfg.workers).with_context(|| {
        format!("connecting '{}' transport world", cfg.transport)
    })?;

    let mut handles = Vec::new();
    for (rank, transport) in transports.into_iter().enumerate() {
        let cfg = cfg.clone();
        let orch_cfg = orch_cfg.clone();
        let data_cfg = data_cfg;
        let dir = dir.to_path_buf();
        handles.push(std::thread::spawn(
            move || -> Result<(Vec<StepOutcome>, u128, SessionStats)> {
                let mut w = Worker::new(
                    rank,
                    topo,
                    &dir,
                    transport,
                    content,
                    cfg.lr,
                )?;
                // Identical stream + deterministic session on every
                // rank: the lengths "all-gather". The session owns the
                // planning state; depth and cache capacity come from
                // --pipeline-depth / --plan-cache-size (depth 1 = plan
                // t+1 while t executes; deeper absorbs planning
                // spikes).
                let pipeline = StepPipeline::new(
                    PlanSession::new(
                        orch_cfg,
                        cfg.pipeline_config(),
                        topo,
                    ),
                    data_cfg,
                    cfg.seed,
                    cfg.mini_batch,
                    cfg.steps,
                );
                let mut outcomes = Vec::new();
                let mut plan_nanos: u128 = 0;
                // Session-style provenance rebuilt from the reports
                // (the session itself lives on the pipeline thread).
                let mut stats = SessionStats::default();
                while let Some(step) = pipeline.next() {
                    plan_nanos += step.plan_nanos;
                    stats.record(&step.report);
                    outcomes.push(w.step(&step.plan)?);
                }
                Ok((outcomes, plan_nanos, stats))
            },
        ));
    }

    let mut per_rank = Vec::new();
    let mut plan_nanos_rank0 = 0u128;
    let mut stats_rank0 = SessionStats::default();
    for (rank, h) in handles.into_iter().enumerate() {
        let (outcomes, plan_nanos, stats) =
            h.join().expect("worker panicked")?;
        if rank == 0 {
            plan_nanos_rank0 = plan_nanos;
            stats_rank0 = stats;
        }
        per_rank.push(outcomes);
    }
    let r0 = &per_rank[0];
    // Reduced quantities must agree across ranks.
    for other in &per_rank[1..] {
        for (a, b) in r0.iter().zip(other) {
            debug_assert!((a.loss - b.loss).abs() < 1e-5);
        }
    }
    let steps = r0.len();
    Ok(TrainReport {
        losses: r0.iter().map(|o| o.loss).collect(),
        tokens_per_step: r0.iter().map(|o| o.tokens).sum::<f64>()
            / steps as f64,
        secs_per_step: r0
            .iter()
            .map(|o| o.compute_seconds + o.comm_seconds)
            .sum::<f64>()
            / steps as f64,
        comm_secs_per_step: r0.iter().map(|o| o.comm_seconds).sum::<f64>()
            / steps as f64,
        plan_secs_per_step: plan_nanos_rank0 as f64
            / 1e9
            / steps.max(1) as f64,
        plan_warm_rate: stats_rank0.warm_rate(),
        plan_cache_hit_rate: stats_rank0.cache_hit_rate(),
        workers: cfg.workers,
        steps,
        transport: cfg.transport.clone(),
        transitions: Vec::new(),
        // The fixed-world pipeline trainer moves its session onto a
        // background thread; archive endpoints are elastic-only.
        archive_warm: None,
        first_step_cache_hit: false,
        first_plan_id: None,
    })
}

/// CLI entry: run and render.
pub fn run(cfg: &TrainRunConfig) -> Result<String> {
    Ok(run_collect(cfg)?.render())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::Generator;

    #[test]
    fn dataset_caps_respect_buckets() {
        let dir = Path::new("artifacts/test");
        if !dir.join("manifest.json").exists() {
            return; // artifacts not built in this environment
        }
        let m = Manifest::load(dir).unwrap();
        let d = dataset_for_manifest(&m).unwrap();
        assert!(d.max_vis <= 16);
        assert!(d.max_aud <= 16);
        assert!(d.max_text + 16 + 2 <= 48);
        let ex = Generator::new(d, 1).batch(500);
        for e in ex {
            assert!(e.vis_tokens <= 8 && e.aud_tokens <= 8);
            assert!(e.llm_len() <= 48);
        }
    }

    #[test]
    fn worker_topology_has_nodes() {
        let t = worker_topology(4);
        assert_eq!(t.nodes(), 2);
        assert!(t.same_node(0, 1));
        assert!(!t.same_node(1, 2));
    }

    #[test]
    fn topology_floor_refuses_small_worlds() {
        assert_eq!(
            worker_topology_with_floor(4, 2).unwrap().instances,
            4
        );
        let err = worker_topology_with_floor(1, 2).unwrap_err();
        assert!(err.to_string().contains("--min-world"));
        // A floor of 0 behaves like 1: an empty world is never valid.
        assert!(worker_topology_with_floor(0, 0).is_err());
    }

    #[test]
    fn orchestrator_config_resolves_balancer_names() {
        let mut cfg = TrainRunConfig {
            balancer: Some("kk".into()),
            ..TrainRunConfig::default()
        };
        let oc = orchestrator_config(&cfg, 128.0).unwrap();
        assert_eq!(oc.llm_balancer.name(), "kk");

        cfg.balancer = Some("not-an-algorithm".into());
        assert!(orchestrator_config(&cfg, 128.0).is_err());

        // `auto` resolves per phase from the trainer's architecture:
        // conv audio front-end → convpad, everything else linear.
        cfg.balancer = Some("auto".into());
        let oc = orchestrator_config(&cfg, 128.0).unwrap();
        assert_eq!(oc.vision_balancer.name(), "greedy");
        assert_eq!(oc.audio_balancer.name(), "convpad");
        assert_eq!(oc.llm_balancer.name(), "greedy");

        cfg.balance = false;
        // --no-balance wins over --balancer.
        let oc = orchestrator_config(&cfg, 128.0).unwrap();
        assert!(oc.llm_balancer.is_identity());
    }
}
