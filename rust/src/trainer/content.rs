//! Deterministic synthetic example *content* (the metadata payloads the
//! dispatchers move): patch grids, mel frames, and text token chains.
//!
//! Content is a pure function of (corpus seed, example id), generated at
//! the example's *home* instance and physically routed by the collective
//! engine — so the trainer's All-to-All moves real bytes, never
//! regenerates remotely.
//!
//! Text is a learnable affine chain `t_{k+1} = (a·t_k + b) mod V`, so the
//! end-to-end loss curve demonstrably descends (EXPERIMENTS.md §E2E).

use crate::data::synth::Example;
use crate::util::rng::Pcg64;

/// Per-example content generator.
#[derive(Clone, Copy, Debug)]
pub struct ContentGen {
    pub seed: u64,
    pub vocab: usize,
}

impl ContentGen {
    fn rng(&self, id: usize, tag: u64) -> Pcg64 {
        Pcg64::new(
            self.seed ^ (id as u64).wrapping_mul(0x9E37_79B9) ^ (tag << 56),
        )
    }

    /// Vision patches, flattened `[vis_len, patch_dim]`.
    pub fn patches(&self, e: &Example, patch_dim: usize) -> Vec<f32> {
        let mut r = self.rng(e.id, 1);
        (0..e.vis_len * patch_dim)
            .map(|_| 0.3 * r.normal() as f32)
            .collect()
    }

    /// Audio mel frames, flattened `[aud_len, mel_dim]`.
    pub fn frames(&self, e: &Example, mel_dim: usize) -> Vec<f32> {
        let mut r = self.rng(e.id, 2);
        (0..e.aud_len * mel_dim)
            .map(|_| 0.3 * r.normal() as f32)
            .collect()
    }

    /// Text tokens: a learnable affine chain seeded by the example id.
    /// Tokens live in [1, vocab) — 0 is reserved for injected slots.
    pub fn text(&self, e: &Example) -> Vec<i32> {
        let v = (self.vocab - 1) as u64;
        let mut t = (e.id as u64 * 13 + 5) % v;
        (0..e.text_len)
            .map(|_| {
                t = (t * 31 + 7) % v;
                (t + 1) as i32
            })
            .collect()
    }
}

/// One example's routed payload bundle (what actually crosses the
/// collective engine for the LLM phase).
#[derive(Clone, Debug, PartialEq)]
pub struct TextBundle {
    pub tokens: Vec<i32>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::Task;

    fn example(id: usize) -> Example {
        Example {
            id,
            task: Task::AvDialogue,
            vis_len: 8,
            aud_len: 6,
            text_len: 10,
            vis_tokens: 4,
            aud_tokens: 3,
        }
    }

    #[test]
    fn content_is_deterministic() {
        let g = ContentGen { seed: 7, vocab: 256 };
        let e = example(3);
        assert_eq!(g.patches(&e, 48), g.patches(&e, 48));
        assert_eq!(g.frames(&e, 40), g.frames(&e, 40));
        assert_eq!(g.text(&e), g.text(&e));
    }

    #[test]
    fn content_differs_by_example() {
        let g = ContentGen { seed: 7, vocab: 256 };
        assert_ne!(g.text(&example(1)), g.text(&example(2)));
        assert_ne!(g.patches(&example(1), 48), g.patches(&example(2), 48));
    }

    #[test]
    fn text_chain_is_learnable_and_in_range() {
        let g = ContentGen { seed: 1, vocab: 256 };
        let t = g.text(&example(5));
        assert_eq!(t.len(), 10);
        assert!(t.iter().all(|&x| (1..256).contains(&x)));
        // The affine recurrence: next token is a function of current.
        let v = 255i64;
        for w in t.windows(2) {
            let want = ((w[0] as i64 - 1) * 31 + 7).rem_euclid(v) + 1;
            assert_eq!(w[1] as i64, want);
        }
    }

    #[test]
    fn shapes_match_lengths() {
        let g = ContentGen { seed: 2, vocab: 128 };
        let e = example(9);
        assert_eq!(g.patches(&e, 48).len(), 8 * 48);
        assert_eq!(g.frames(&e, 40).len(), 6 * 40);
    }
}
