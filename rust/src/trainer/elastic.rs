//! Elastic training runtime: rank-death detection and shrink-the-world
//! recovery over the [`crate::comm::transport::ElasticFactory`]
//! rendezvous layer.
//!
//! The paper's dispatcher re-plans every step from nothing but the
//! sampled lengths and the topology, which makes *elasticity* almost
//! free: when a DP rank dies, the survivors only need to agree on the
//! new world and hand [`PlanSession`] a shrunk [`Topology`] — the next
//! `plan` call re-deals the same global batch over `d − 1` instances.
//! This module supplies the missing runtime pieces:
//!
//! * a **deterministic fault-injection harness** — [`FaultPlan`] picks
//!   one rank, one step, and one collective (env:
//!   `ORCHMLLM_FAULT_RANK` / `ORCHMLLM_FAULT_STEP` /
//!   `ORCHMLLM_FAULT_COLLECTIVE`, `ORCHMLLM_FAULT_RESIGN`);
//! * a **synthetic SPMD worker** — a pure-Rust training step (planned
//!   all-to-all payload routing, per-example loss/gradient, rank-order
//!   all-reduce, SGD) that needs no PJRT artifacts, so the elastic
//!   path is exercised end to end in CI. Parameters are updated only
//!   *after* a successful all-reduce, so a step interrupted by a death
//!   mutates nothing and re-executes safely at the shrunk world;
//! * the **recovery protocol** — on a typed
//!   [`TransportError::PeerDead`](crate::comm::transport::TransportError)
//!   every survivor abandons its collective group, re-rendezvouses at
//!   a bumped epoch (the locally blamed rank is only a *hint*: the
//!   sealed membership is whoever actually re-registers), resizes the
//!   session, records a [`WorldTransition`], and re-executes the
//!   interrupted step.
//!
//! Determinism argument (pinned by `rust/tests/elastic_recovery.rs`):
//! the global batch of step *t* is sampled from a fresh generator
//! seeded by `(seed, t)` over a fixed `stream_width` (the *launch*
//! world size) and regrouped `stream j → dense rank j mod w`, so the
//! batch is identical at every world size; parameters are only mutated
//! by completed steps; an interrupted step applied no update on any
//! rank (all survivors fail the same collective). Hence a hard death
//! at step *N* replays step *N* at the shrunk world bit-identically to
//! a *resignation* reference run in which the same rank leaves cleanly
//! before step *N* — and, because the all-reduce is rank-order
//! bit-stable on every backend, the equality holds across `inproc`
//! threads and `tcp-multiproc` OS processes.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::comm::rendezvous::{cleanup, scratch_dir, FileRendezvous};
use crate::comm::transport::inproc::InProcElastic;
use crate::comm::transport::mesh::TcpElastic;
use crate::comm::transport::{
    peer_dead, ElasticFactory, Shard, Transport,
};
use crate::config::TrainRunConfig;
use crate::data::synth::{DatasetConfig, Example, Generator};
use crate::orchestrator::archive;
use crate::orchestrator::global::StepPlan;
use crate::orchestrator::session::{PlanOptions, PlanSession};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::sha256;

use super::{orchestrator_config, worker_topology_with_floor, TrainReport};

/// Exit code a process-mode worker uses when its planned fault fires,
/// so the parent can tell an injected death from a real failure.
pub const FAULT_EXIT: i32 = 17;

/// Parameter count of the synthetic model (one weight per feature).
pub const PARAM_COUNT: usize = 6;

/// Detection-latency knob for elastic runs: barrier watchdog (inproc)
/// and per-stream socket timeout (tcp mesh). Overrides
/// `ORCHMLLM_ELASTIC_TIMEOUT_SECS`; the default keeps CI fault tests
/// snappy without tripping on healthy scheduling jitter.
fn detect_timeout(default_secs: u64) -> Duration {
    let secs = std::env::var("ORCHMLLM_ELASTIC_TIMEOUT_SECS")
        .ok()
        .and_then(|s| s.trim().parse::<u64>().ok())
        .unwrap_or(default_secs);
    Duration::from_secs(secs.max(1))
}

// ---------------------------------------------------------------------------
// Fault injection
// ---------------------------------------------------------------------------

/// One injected fault: member `rank` (a *stable* rendezvous id, not a
/// dense rank) stops participating at step `step`, immediately before
/// collective `collective` of that step (0 = heartbeat, 1 = the
/// plan-routed all-to-all, 2 = the gradient all-reduce).
///
/// `resign == false` is a hard death: survivors discover it through a
/// typed `PeerDead` failure. `resign == true` is a clean departure the
/// whole world knows about in advance — survivors proactively
/// re-rendezvous at the same step, which makes the resignation run the
/// bit-exact reference for the hard-death run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    pub rank: Option<usize>,
    pub step: usize,
    pub collective: usize,
    pub resign: bool,
}

impl FaultPlan {
    /// No fault: every rank runs to completion.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// Hard-kill `rank` immediately before step `step`'s heartbeat.
    pub fn kill(rank: usize, step: usize) -> FaultPlan {
        FaultPlan { rank: Some(rank), step, collective: 0, resign: false }
    }

    /// `rank` leaves cleanly before step `step`; survivors shrink
    /// proactively. This is the reference run for [`FaultPlan::kill`].
    pub fn resignation(rank: usize, step: usize) -> FaultPlan {
        FaultPlan { rank: Some(rank), step, collective: 0, resign: true }
    }

    /// Die before a specific collective of the step instead of the
    /// heartbeat.
    pub fn at_collective(mut self, collective: usize) -> FaultPlan {
        self.collective = collective;
        self
    }

    /// Read the fault from `ORCHMLLM_FAULT_RANK` /
    /// `ORCHMLLM_FAULT_STEP` / `ORCHMLLM_FAULT_COLLECTIVE` /
    /// `ORCHMLLM_FAULT_RESIGN` (unset rank = no fault).
    pub fn from_env() -> FaultPlan {
        let num = |key: &str| {
            std::env::var(key)
                .ok()
                .and_then(|s| s.trim().parse::<usize>().ok())
        };
        FaultPlan {
            rank: num("ORCHMLLM_FAULT_RANK"),
            step: num("ORCHMLLM_FAULT_STEP").unwrap_or(0),
            collective: num("ORCHMLLM_FAULT_COLLECTIVE").unwrap_or(0),
            resign: std::env::var("ORCHMLLM_FAULT_RESIGN")
                .map(|s| s == "1" || s == "true")
                .unwrap_or(false),
        }
    }

    /// CLI flags (`--fault-rank` / `--fault-step` /
    /// `--fault-collective` / `--fault-resign`), falling back to the
    /// environment when no flag names a rank. A malformed flag is a
    /// typed error for the CLI layer to report, not a panic.
    pub fn from_args(args: &Args) -> Result<FaultPlan> {
        Ok(match args.get("fault-rank") {
            None => FaultPlan::from_env(),
            Some(r) => FaultPlan {
                rank: Some(r.parse().map_err(|_| {
                    anyhow!("--fault-rank expects an integer, got '{r}'")
                })?),
                step: args.usize("fault-step", 0),
                collective: args.usize("fault-collective", 0),
                resign: args.flag("fault-resign"),
            },
        })
    }
}

// ---------------------------------------------------------------------------
// World transitions
// ---------------------------------------------------------------------------

/// One recorded shrink (or, in principle, growth) of the training
/// world, kept in the final [`TrainReport`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WorldTransition {
    /// Step at which the transition happened; the step was (re-)run at
    /// the *new* world size.
    pub step: usize,
    /// Rendezvous epoch the survivors sealed.
    pub epoch: u64,
    /// World size before / after.
    pub from: usize,
    pub to: usize,
    /// Stable member ids that left the world at this transition.
    pub dead: Vec<usize>,
}

// ---------------------------------------------------------------------------
// Synthetic model + world-invariant sampling
// ---------------------------------------------------------------------------

fn init_params() -> Vec<f32> {
    (0..PARAM_COUNT).map(|k| 0.05 * (k as f32 + 1.0)).collect()
}

/// Per-example feature vector — the payload the planned all-to-all
/// actually routes, so mis-routing is a hard test failure, not a
/// silent wrong number.
fn features(e: &Example) -> [f32; PARAM_COUNT] {
    [
        1.0,
        e.vis_tokens as f32 * 0.1,
        e.aud_tokens as f32 * 0.1,
        e.text_len as f32 * 0.05,
        e.vis_len as f32 * 0.02,
        e.aud_len as f32 * 0.02,
    ]
}

fn target(e: &Example) -> f32 {
    ((e.text_len * 7 + e.vis_tokens * 3 + e.aud_tokens) % 13) as f32 * 0.1
}

/// splitmix64 finalizer — decorrelates per-step generator seeds.
fn mix_seed(seed: u64, step: usize) -> u64 {
    let mut z = seed
        ^ (step as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ 0x243F_6A88_85A3_08D3;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Sample step `step`'s global batch and group it for a `world`-rank
/// run. The batch is a function of `(seed, step, stream_width)` only —
/// `stream_width` is pinned to the *launch* world size — so shrinking
/// the world regroups the identical examples (`stream j → dense rank
/// j mod world`) instead of changing what is trained on.
fn global_minibatches(
    seed: u64,
    step: usize,
    stream_width: usize,
    mini_batch: usize,
    world: usize,
) -> Vec<Vec<Example>> {
    let mut g = Generator::new(DatasetConfig::tiny(2, 2), mix_seed(seed, step));
    let all = g.batch(stream_width * mini_batch);
    let mut mbs = vec![Vec::new(); world];
    for (j, chunk) in all.chunks(mini_batch).enumerate() {
        mbs[j % world].extend_from_slice(chunk);
    }
    mbs
}

// ---------------------------------------------------------------------------
// One synthetic SPMD step
// ---------------------------------------------------------------------------

enum StepSignal {
    Done { loss_g: f64, tokens_g: f64, comm_s: f64, params: Vec<f32> },
    /// This rank's injected fault fired mid-step: stop participating.
    Died,
}

/// Execute one planned step: heartbeat → plan-routed feature payloads
/// → local loss/grad → rank-order all-reduce → SGD. `die_at` is the
/// injected fault point for this rank (collective index), if any.
/// Parameters are returned, not mutated — the caller commits them only
/// when the step completed, so an interrupted step leaves rank state
/// untouched for safe re-execution.
// orchlint: allow(collective-asymmetry): deterministic fault injection —
// the `die_at` early returns exist precisely to desert the collective
// schedule on one rank and exercise shrink-the-world recovery; survivors
// detect the desertion via PeerDead/watchdog, which is the behavior under
// test.
fn synthetic_step(
    t: &dyn Transport,
    plan: &StepPlan,
    params: &[f32],
    lr: f64,
    die_at: Option<usize>,
) -> Result<StepSignal> {
    let rank = t.rank();
    let mut comm_s = 0.0f64;

    // Collective 0: heartbeat — the failure-detection round.
    if die_at == Some(0) {
        return Ok(StepSignal::Died);
    }
    let t0 = Instant::now();
    t.heartbeat().context("step heartbeat")?;
    comm_s += t0.elapsed().as_secs_f64();

    // Collective 1: every example's feature payload moves home → LLM
    // instance along the planned route.
    if die_at == Some(1) {
        return Ok(StepSignal::Died);
    }
    let mut sends: Vec<(usize, Shard)> = Vec::new();
    for (g, e) in plan.examples.iter().enumerate() {
        if plan.home[g] != rank || e.llm_len() == 0 {
            continue;
        }
        sends.push((
            plan.llm.route.to[g],
            Shard::f32(g, features(e).to_vec()),
        ));
    }
    let t0 = Instant::now();
    let received = t
        .all_to_all_shards(sends)
        .context("planned feature all-to-all")?;
    comm_s += t0.elapsed().as_secs_f64();
    let mut by_id = BTreeMap::new();
    for (_src, shard) in received {
        let (g, rows) = shard
            .into_f32()
            .context("planned feature all-to-all")?;
        by_id.insert(g, rows);
    }

    // Local loss/grad over my planned mini-batch, from *routed* bytes.
    let mut flat = vec![0.0f32; 2 + PARAM_COUNT];
    for eref in &plan.llm.assignment[rank] {
        let e = &plan.examples[eref.id];
        let phi = by_id.get(&eref.id).ok_or_else(|| {
            anyhow!(
                "example {} assigned to rank {rank} but its payload \
                 was not routed here",
                eref.id
            )
        })?;
        let pred: f32 =
            params.iter().zip(phi.iter()).map(|(p, x)| p * x).sum();
        let err = pred - target(e);
        flat[0] += err * err;
        flat[1] += e.llm_len() as f32;
        for (k, x) in phi.iter().enumerate() {
            flat[2 + k] += 2.0 * err * x;
        }
    }

    // Collective 2: rank-order (bit-stable) gradient all-reduce.
    if die_at == Some(2) {
        return Ok(StepSignal::Died);
    }
    let t0 = Instant::now();
    t.all_reduce_sum(&mut flat).context("gradient all-reduce")?;
    comm_s += t0.elapsed().as_secs_f64();

    // SGD only after the reduce succeeded (rescaled by global tokens,
    // like the real worker) — a failed step commits nothing.
    let scale = lr as f32 / flat[1].max(1.0);
    let params = params
        .iter()
        .zip(&flat[2..])
        .map(|(p, g)| p - scale * g)
        .collect();
    Ok(StepSignal::Done {
        loss_g: flat[0] as f64,
        tokens_g: flat[1] as f64,
        comm_s,
        params,
    })
}

// ---------------------------------------------------------------------------
// Recovery: re-rendezvous at a bumped epoch
// ---------------------------------------------------------------------------

/// Abandon the current collective group and agree on the shrunk world.
/// `dead_hint` is the locally blamed member — only a *hint*: it is
/// excluded from the seal-immediately set, but membership is whoever
/// re-registers before the seal (a mis-blamed live rank re-registers
/// and stays in the world; see DESIGN.md §Elastic Runtime).
#[allow(clippy::too_many_arguments)]
fn rejoin(
    elastic: &dyn ElasticFactory,
    id: usize,
    step: usize,
    dead_hint: Option<usize>,
    min_world: usize,
    epoch: &mut u64,
    members: &mut Vec<usize>,
    transport: &mut Option<Box<dyn Transport>>,
    session: &mut PlanSession,
    transitions: &mut Vec<WorldTransition>,
) -> Result<()> {
    let from = members.len();
    // Drop first: closes sockets / abandons barriers so peers still
    // blocked on the old group fail over promptly too.
    drop(transport.take());
    *epoch += 1;
    let expected: Vec<usize> = members
        .iter()
        .copied()
        .filter(|m| Some(*m) != dead_hint)
        .collect();
    let (new_members, t) = elastic
        .join(*epoch, id, &expected)
        .with_context(|| {
            format!("member {id} re-rendezvousing at epoch {epoch}")
        })?;
    let dead: Vec<usize> = members
        .iter()
        .copied()
        .filter(|m| !new_members.contains(m))
        .collect();
    if new_members.len() < min_world.max(1) {
        bail!(
            "epoch {epoch}: world shrank to {} member(s) \
             ({new_members:?}; dead: {dead:?}) — below the --min-world \
             floor of {min_world}; refusing to continue",
            new_members.len()
        );
    }
    // Shrunk topology + fresh planning state: histories and caches are
    // keyed to the old world size and must not warm-start across it.
    session.resize(worker_topology_with_floor(
        new_members.len(),
        min_world,
    )?);
    transitions.push(WorldTransition {
        step,
        epoch: *epoch,
        from,
        to: new_members.len(),
        dead,
    });
    *members = new_members;
    *transport = Some(t);
    Ok(())
}

// ---------------------------------------------------------------------------
// The per-member elastic training loop
// ---------------------------------------------------------------------------

/// Run one member (stable id `id`) of an elastic world to completion.
/// Returns `Ok(None)` when this member's injected fault fired (it
/// stopped participating on purpose), `Ok(Some(report))` for a
/// survivor. `stream_width` pins the sampling width to the launch
/// world size so recovery never changes the data stream.
pub fn run_member(
    cfg: &TrainRunConfig,
    fault: FaultPlan,
    elastic: &dyn ElasticFactory,
    id: usize,
    stream_width: usize,
) -> Result<Option<TrainReport>> {
    let expected: Vec<usize> = (0..cfg.workers).collect();
    let mut epoch = 0u64;
    let (mut members, t) = elastic
        .join(epoch, id, &expected)
        .with_context(|| format!("member {id} joining epoch 0"))?;
    let mut transport = Some(t);
    let embed_bytes = (PARAM_COUNT * 4) as f64;
    let orch_cfg = orchestrator_config(cfg, embed_bytes)?;
    let topo =
        worker_topology_with_floor(members.len(), cfg.min_world)?;
    let mut archive_warm: Option<bool> = None;
    let mut session = match &cfg.archive_in {
        Some(dir) => {
            // A fingerprint mismatch (different world, different
            // orchestrator config) degrades to a cold start inside
            // `with_archive`; only corruption or schema skew errors.
            let (s, warm) = PlanSession::with_archive(
                orch_cfg,
                cfg.pipeline_config(),
                topo,
                Path::new(dir),
            )
            .with_context(|| {
                format!("member {id} loading plan archive {dir}")
            })?;
            archive_warm = Some(warm.is_warm());
            s
        }
        None => PlanSession::new(orch_cfg, cfg.pipeline_config(), topo),
    };
    if cfg.archive_out.is_some() {
        session.set_archive_log(true);
    }
    let archive_on =
        cfg.archive_in.is_some() || cfg.archive_out.is_some();
    let mut first_plan: Option<(bool, String)> = None;
    let mut params = init_params();
    let mut losses: Vec<f64> = Vec::new();
    let mut transitions: Vec<WorldTransition> = Vec::new();
    let mut tokens_sum = 0.0f64;
    let mut comm_sum = 0.0f64;
    let mut plan_nanos: u128 = 0;
    let t_run = Instant::now();

    let mut step = 0usize;
    while step < cfg.steps {
        let fault_due =
            fault.step == step && fault.rank.is_some_and(|r| members.contains(&r));
        if fault_due && fault.rank == Some(id) && fault.resign {
            // Clean departure before the step; survivors shrink below.
            drop(transport.take());
            return Ok(None);
        }
        if fault_due && fault.resign {
            // Announced resignation: shrink proactively, then run this
            // step at the new world (the hard-death reference path).
            rejoin(
                elastic,
                id,
                step,
                fault.rank,
                cfg.min_world,
                &mut epoch,
                &mut members,
                &mut transport,
                &mut session,
                &mut transitions,
            )?;
            // Satellite invariant: an export after shrink-the-world
            // carries the *shrunk* world's topology fingerprint, so a
            // later `with_archive` on the old world degrades to a cold
            // start instead of reusing wrong-world plans.
            maybe_export_archive(cfg, &session, id, &members)?;
            continue;
        }
        let die_at = (fault.rank == Some(id) && fault.step == step)
            .then_some(fault.collective);

        let minibatches = global_minibatches(
            cfg.seed,
            step,
            stream_width,
            cfg.mini_batch,
            members.len(),
        );
        let t0 = Instant::now();
        // `plan_shared`, not `plan`: a step-cache replay returns the
        // archived `Arc` untouched, so the content hash below matches
        // the archived plan id bit for bit.
        let plan = session.plan_shared(&minibatches, PlanOptions::auto());
        plan_nanos += t0.elapsed().as_nanos();
        if archive_on && first_plan.is_none() {
            let r = session.report().expect("plan records a report");
            first_plan = Some((
                r.step_cache_hit,
                sha256::hex(&sha256::sha256(&archive::encode_step_plan(
                    &plan,
                ))),
            ));
        }
        let t = transport.as_deref().expect("transport is live");
        match synthetic_step(t, &plan, &params, cfg.lr, die_at) {
            Ok(StepSignal::Done { loss_g, tokens_g, comm_s, params: p }) => {
                params = p;
                losses.push(loss_g / tokens_g.max(1.0));
                tokens_sum += tokens_g;
                comm_sum += comm_s;
                step += 1;
            }
            Ok(StepSignal::Died) => {
                // Injected hard death: vanish mid-collective-sequence.
                drop(transport.take());
                return Ok(None);
            }
            Err(err) => {
                let Some(blamed) = peer_dead(&err) else {
                    return Err(err.context(format!(
                        "member {id} failed step {step} (not a peer \
                         death — not recoverable)"
                    )));
                };
                let dead_hint = members.get(blamed).copied();
                rejoin(
                    elastic,
                    id,
                    step,
                    dead_hint,
                    cfg.min_world,
                    &mut epoch,
                    &mut members,
                    &mut transport,
                    &mut session,
                    &mut transitions,
                )?;
                maybe_export_archive(cfg, &session, id, &members)?;
                // Re-execute the interrupted step at the shrunk world;
                // no rank applied its update, so this is safe.
            }
        }
    }

    // Clean exit: the surviving minimum-id member seals the session
    // into the archive (caches, profiles, plan log, final topology).
    maybe_export_archive(cfg, &session, id, &members)?;

    let steps = losses.len().max(1);
    let stats = session.stats();
    let (first_step_cache_hit, first_plan_id) = match first_plan {
        Some((hit, plan_id)) => (hit, Some(plan_id)),
        None => (false, None),
    };
    Ok(Some(TrainReport {
        losses,
        tokens_per_step: tokens_sum / steps as f64,
        secs_per_step: t_run.elapsed().as_secs_f64() / steps as f64,
        comm_secs_per_step: comm_sum / steps as f64,
        plan_secs_per_step: plan_nanos as f64 / 1e9 / steps as f64,
        plan_warm_rate: stats.warm_rate(),
        plan_cache_hit_rate: stats.cache_hit_rate(),
        workers: cfg.workers,
        steps: cfg.steps,
        transport: cfg.transport.clone(),
        transitions,
        archive_warm,
        first_step_cache_hit,
        first_plan_id,
    }))
}

/// Export the session's plan archive to `cfg.archive_out`, but only
/// from the minimum-id surviving member — one writer per directory,
/// and every survivor's session is bit-identical anyway (SPMD).
fn maybe_export_archive(
    cfg: &TrainRunConfig,
    session: &PlanSession,
    id: usize,
    members: &[usize],
) -> Result<()> {
    let Some(dir) = &cfg.archive_out else {
        return Ok(());
    };
    if members.iter().min() != Some(&id) {
        return Ok(());
    }
    session
        .export_archive(Path::new(dir))
        .map(|_manifest| ())
        .with_context(|| {
            format!("member {id} exporting plan archive to {dir}")
        })
}

// ---------------------------------------------------------------------------
// In-process harness (threads)
// ---------------------------------------------------------------------------

fn run_threaded(
    cfg: &TrainRunConfig,
    fault: FaultPlan,
    elastic: &dyn ElasticFactory,
    stream_width: usize,
) -> Result<TrainReport> {
    let reports = std::thread::scope(
        |scope| -> Result<Vec<(usize, TrainReport)>> {
            let handles: Vec<_> = (0..cfg.workers)
                .map(|id| {
                    scope.spawn(move || {
                        run_member(cfg, fault, elastic, id, stream_width)
                    })
                })
                .collect();
            let mut reports = Vec::new();
            for (id, h) in handles.into_iter().enumerate() {
                match h.join() {
                    Ok(Ok(Some(r))) => reports.push((id, r)),
                    Ok(Ok(None)) => {} // planned fault fired
                    Ok(Err(e)) => {
                        return Err(e.context(format!(
                            "elastic member {id} failed"
                        )))
                    }
                    Err(_) => bail!("elastic member {id} panicked"),
                }
            }
            Ok(reports)
        },
    )?;
    let (first_id, first) =
        reports.first().ok_or_else(|| anyhow!("no survivors"))?;
    for (id, r) in &reports[1..] {
        if r.losses != first.losses || r.transitions != first.transitions {
            bail!(
                "survivor {id} diverged from survivor {first_id}: \
                 losses/transitions disagree"
            );
        }
    }
    Ok(first.clone())
}

/// Run an elastic training job in one process (one thread per member),
/// with the sampling stream pinned to `stream_width` instead of
/// `cfg.workers` — the knob the shrunk-world reference runs use.
pub fn run_elastic_collect_with(
    cfg: &TrainRunConfig,
    fault: FaultPlan,
    stream_width: usize,
) -> Result<TrainReport> {
    cfg.validate()?;
    let detect = detect_timeout(2);
    match cfg.transport.as_str() {
        "inproc" => {
            let elastic =
                InProcElastic::new(Some(detect), Duration::from_secs(2));
            run_threaded(cfg, fault, &elastic, stream_width)
        }
        _ => {
            // Real sockets + file rendezvous, members as threads: the
            // same wire path the multi-process runner uses.
            let dir = scratch_dir("elastic");
            let elastic = TcpElastic {
                rdzv: FileRendezvous::new(&dir),
                timeout: Some(detect),
            };
            let out = run_threaded(cfg, fault, &elastic, stream_width);
            cleanup(&dir);
            out
        }
    }
}

/// [`run_elastic_collect_with`] at the natural stream width
/// (`cfg.workers`, the launch world size).
pub fn run_elastic_collect(
    cfg: &TrainRunConfig,
    fault: FaultPlan,
) -> Result<TrainReport> {
    run_elastic_collect_with(cfg, fault, cfg.workers)
}

// ---------------------------------------------------------------------------
// Multi-process runner (real OS processes over `orchmllm worker`)
// ---------------------------------------------------------------------------

fn report_path(dir: &Path, id: usize) -> PathBuf {
    dir.join(format!("report.m{id}.json"))
}

/// Spawn `cfg.workers` real OS processes (`<bin> worker …`) over a
/// shared file-rendezvous directory, wait for them, tolerate
/// [`FAULT_EXIT`] from the planned fault rank only, and return the
/// survivors' (agreeing) report.
pub fn run_multiproc(
    cfg: &TrainRunConfig,
    fault: FaultPlan,
    bin: &Path,
) -> Result<TrainReport> {
    cfg.validate()?;
    let dir = scratch_dir("elastic-proc");
    std::fs::create_dir_all(&dir)
        .with_context(|| format!("creating {}", dir.display()))?;
    let mut children = Vec::new();
    for id in 0..cfg.workers {
        let mut cmd = std::process::Command::new(bin);
        cmd.arg("worker")
            .arg("--rank")
            .arg(id.to_string())
            .arg("--rdzv-dir")
            .arg(&dir)
            .arg("--workers")
            .arg(cfg.workers.to_string())
            .arg("--mini-batch")
            .arg(cfg.mini_batch.to_string())
            .arg("--steps")
            .arg(cfg.steps.to_string())
            .arg("--lr")
            .arg(cfg.lr.to_string())
            .arg("--seed")
            .arg(cfg.seed.to_string())
            .arg("--min-world")
            .arg(cfg.min_world.to_string());
        if let Some(dir) = &cfg.archive_in {
            cmd.arg("--archive-in").arg(dir);
        }
        if let Some(dir) = &cfg.archive_out {
            cmd.arg("--archive-out").arg(dir);
        }
        if let Some(rank) = fault.rank {
            cmd.arg("--fault-rank")
                .arg(rank.to_string())
                .arg("--fault-step")
                .arg(fault.step.to_string())
                .arg("--fault-collective")
                .arg(fault.collective.to_string());
            if fault.resign {
                // Boolean flags must trail `--key value` pairs.
                cmd.arg("--fault-resign");
            }
        }
        let child = cmd
            .spawn()
            .with_context(|| format!("spawning worker {id}"))?;
        children.push((id, child));
    }

    let mut failures = Vec::new();
    for (id, mut child) in children {
        let status = child
            .wait()
            .with_context(|| format!("waiting for worker {id}"))?;
        let planned_fault = fault.rank == Some(id);
        let ok = status.success()
            || (planned_fault && status.code() == Some(FAULT_EXIT));
        if !ok {
            failures.push(format!("worker {id} exited with {status}"));
        }
    }
    if !failures.is_empty() {
        bail!("elastic run failed: {}", failures.join("; "));
    }

    let mut reports: Vec<(usize, TrainReport)> = Vec::new();
    for id in 0..cfg.workers {
        if fault.rank == Some(id) {
            continue;
        }
        let path = report_path(&dir, id);
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("reading survivor report {}", path.display())
        })?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow!("{}: {e}", path.display()))?;
        reports.push((id, report_from_json(&j)?));
    }
    let (first_id, first) =
        reports.first().ok_or_else(|| anyhow!("no survivors"))?;
    for (id, r) in &reports[1..] {
        if r.losses != first.losses || r.transitions != first.transitions {
            bail!(
                "survivor {id} diverged from survivor {first_id}: \
                 losses/transitions disagree"
            );
        }
    }
    let out = first.clone();
    cleanup(&dir);
    Ok(out)
}

/// Entry point of the `orchmllm worker` subcommand: join the file
/// rendezvous as one member, train, write the report JSON next to the
/// rendezvous files, and return the process exit code.
pub fn worker_main(args: &Args) -> i32 {
    let id = args.usize("rank", 0);
    let dir = match args.get("rdzv-dir") {
        Some(d) => PathBuf::from(d),
        None => {
            eprintln!("worker: --rdzv-dir is required");
            return 2;
        }
    };
    let cfg = TrainRunConfig {
        workers: args.usize("workers", 4),
        mini_batch: args.usize("mini-batch", 4),
        steps: args.usize("steps", 8),
        lr: args.f64("lr", 0.05),
        seed: args.u64("seed", 0),
        min_world: args.usize("min-world", 1),
        transport: "tcp-multiproc".into(),
        archive_in: args.get("archive-in").map(str::to_string),
        archive_out: args.get("archive-out").map(str::to_string),
        ..TrainRunConfig::default()
    };
    if let Err(e) = cfg.validate() {
        eprintln!("worker {id}: invalid configuration: {e:#}");
        return 2;
    }
    let fault = match FaultPlan::from_args(args) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("worker {id}: {e:#}");
            return 2;
        }
    };
    let elastic = TcpElastic {
        rdzv: FileRendezvous::new(&dir),
        timeout: Some(detect_timeout(5)),
    };
    match run_member(&cfg, fault, &elastic, id, cfg.workers) {
        Ok(Some(report)) => {
            let path = report_path(&dir, id);
            if let Err(e) =
                std::fs::write(&path, report_to_json(&report).pretty())
            {
                eprintln!(
                    "worker {id}: writing {}: {e}",
                    path.display()
                );
                return 1;
            }
            0
        }
        Ok(None) => FAULT_EXIT,
        Err(e) => {
            eprintln!("worker {id} failed: {e:#}");
            1
        }
    }
}

// ---------------------------------------------------------------------------
// Report (de)serialization — crosses the process boundary losslessly:
// Json prints f64 via Rust's shortest-roundtrip formatting.
// ---------------------------------------------------------------------------

fn transition_to_json(t: &WorldTransition) -> Json {
    Json::obj(vec![
        ("step", Json::num(t.step as f64)),
        ("epoch", Json::num(t.epoch as f64)),
        ("from", Json::num(t.from as f64)),
        ("to", Json::num(t.to as f64)),
        (
            "dead",
            Json::arr(t.dead.iter().map(|&d| Json::num(d as f64))),
        ),
    ])
}

fn transition_from_json(j: &Json) -> Result<WorldTransition> {
    let field = |k: &str| {
        j.get(k)
            .as_usize()
            .ok_or_else(|| anyhow!("transition field '{k}' missing"))
    };
    Ok(WorldTransition {
        step: field("step")?,
        epoch: field("epoch")? as u64,
        from: field("from")?,
        to: field("to")?,
        dead: j
            .get("dead")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|d| {
                d.as_usize()
                    .ok_or_else(|| anyhow!("bad dead-member entry"))
            })
            .collect::<Result<Vec<_>>>()?,
    })
}

pub fn report_to_json(r: &TrainReport) -> Json {
    Json::obj(vec![
        ("losses", Json::arr(r.losses.iter().map(|&l| Json::num(l)))),
        ("tokens_per_step", Json::num(r.tokens_per_step)),
        ("secs_per_step", Json::num(r.secs_per_step)),
        ("comm_secs_per_step", Json::num(r.comm_secs_per_step)),
        ("plan_secs_per_step", Json::num(r.plan_secs_per_step)),
        ("plan_warm_rate", Json::num(r.plan_warm_rate)),
        ("plan_cache_hit_rate", Json::num(r.plan_cache_hit_rate)),
        ("workers", Json::num(r.workers as f64)),
        ("steps", Json::num(r.steps as f64)),
        ("transport", Json::str(&r.transport)),
        (
            "transitions",
            Json::arr(r.transitions.iter().map(transition_to_json)),
        ),
        (
            "archive_warm",
            match r.archive_warm {
                Some(b) => Json::Bool(b),
                None => Json::Null,
            },
        ),
        (
            "first_step_cache_hit",
            Json::Bool(r.first_step_cache_hit),
        ),
        (
            "first_plan_id",
            match &r.first_plan_id {
                Some(plan_id) => Json::str(plan_id),
                None => Json::Null,
            },
        ),
    ])
}

pub fn report_from_json(j: &Json) -> Result<TrainReport> {
    let num = |k: &str| {
        j.get(k)
            .as_f64()
            .ok_or_else(|| anyhow!("report field '{k}' missing"))
    };
    Ok(TrainReport {
        losses: j
            .get("losses")
            .as_arr()
            .ok_or_else(|| anyhow!("report field 'losses' missing"))?
            .iter()
            .map(|l| l.as_f64().ok_or_else(|| anyhow!("bad loss entry")))
            .collect::<Result<Vec<_>>>()?,
        tokens_per_step: num("tokens_per_step")?,
        secs_per_step: num("secs_per_step")?,
        comm_secs_per_step: num("comm_secs_per_step")?,
        plan_secs_per_step: num("plan_secs_per_step")?,
        plan_warm_rate: num("plan_warm_rate")?,
        plan_cache_hit_rate: num("plan_cache_hit_rate")?,
        workers: num("workers")? as usize,
        steps: num("steps")? as usize,
        transport: j
            .get("transport")
            .as_str()
            .unwrap_or("tcp-multiproc")
            .to_string(),
        transitions: j
            .get("transitions")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(transition_from_json)
            .collect::<Result<Vec<_>>>()?,
        archive_warm: j.get("archive_warm").as_bool(),
        first_step_cache_hit: j
            .get("first_step_cache_hit")
            .as_bool()
            .unwrap_or(false),
        first_plan_id: j
            .get("first_plan_id")
            .as_str()
            .map(str::to_string),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_plan_env_and_args_round_trip() {
        // No flags, no env → no fault.
        let args = Args::parse(Vec::<String>::new());
        assert_eq!(FaultPlan::from_args(&args).unwrap(), FaultPlan::none());

        let args = Args::parse(
            [
                "worker",
                "--fault-rank",
                "2",
                "--fault-step",
                "3",
                "--fault-collective",
                "1",
                "--fault-resign",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        let f = FaultPlan::from_args(&args).unwrap();
        assert_eq!(
            f,
            FaultPlan::resignation(2, 3).at_collective(1)
        );
    }

    #[test]
    fn global_batch_is_world_invariant() {
        // The same (seed, step) global batch regroups across world
        // sizes without changing the example multiset or order within
        // a stream.
        let at4 = global_minibatches(9, 5, 4, 3, 4);
        let at3 = global_minibatches(9, 5, 4, 3, 3);
        let flat4: Vec<_> =
            at4.iter().flatten().map(|e| e.llm_len()).collect();
        assert_eq!(flat4.len(), 12);
        let total3: usize = at3.iter().map(Vec::len).sum();
        assert_eq!(total3, 12);
        // Stream 3 (examples 9..12 of the flat batch) lands on dense
        // rank 0 at world 3.
        assert_eq!(at3[0].len(), 6);
        let tail: Vec<_> =
            at3[0][3..].iter().map(|e| e.llm_len()).collect();
        assert_eq!(tail, flat4[9..12].to_vec());
    }

    #[test]
    fn report_json_round_trips_bit_exactly() {
        let r = TrainReport {
            losses: vec![0.1 + 0.2, 1.0 / 3.0, 2.5e-7],
            tokens_per_step: 123.456,
            secs_per_step: 0.01,
            comm_secs_per_step: 0.001,
            plan_secs_per_step: 0.0001,
            plan_warm_rate: 0.75,
            plan_cache_hit_rate: 0.5,
            workers: 4,
            steps: 6,
            transport: "tcp-multiproc".into(),
            transitions: vec![WorldTransition {
                step: 3,
                epoch: 1,
                from: 4,
                to: 3,
                dead: vec![2],
            }],
            archive_warm: Some(true),
            first_step_cache_hit: true,
            first_plan_id: Some("ab12".repeat(16)),
        };
        let text = report_to_json(&r).pretty();
        let back =
            report_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.losses, r.losses); // bit-exact f64 round trip
        assert_eq!(back.transitions, r.transitions);
        assert_eq!(back.workers, 4);
        assert_eq!(back.archive_warm, Some(true));
        assert!(back.first_step_cache_hit);
        assert_eq!(back.first_plan_id, r.first_plan_id);
    }
}
