//! Batch Post-Balancing Dispatcher (paper §5).
//!
//! One dispatcher serves one phase. Per training step it:
//!
//! 1. All-Gathers the sequence *lengths* only (negligible volume — the
//!    §5.2.1 insight);
//! 2. runs the configured Post-Balancing [`Balancer`] on every instance
//!    (deterministic, so all instances agree without extra traffic);
//! 3. runs the Node-wise Rearrangement Algorithm to permute the
//!    destination batch order for the hierarchical topology (§5.2.2);
//! 4. prices (simulator) / executes (trainer) the payload rearrangement
//!    on the chosen communicator: the paper's All-to-All or the
//!    All-Gather strawman it is compared against (Fig. 12).
//!
//! Steps 1–3 are "computation" in the paper's taxonomy and run inside
//! the prefetch overlap; step 4 is the only on-critical-path work. The
//! hot path is [`Dispatcher::dispatch_with`], which threads a
//! [`PlanScratch`] so a warmed-up dispatcher performs no allocation in
//! its sort/heap/volume loops.

use std::sync::Arc;
use std::time::Instant;

use crate::balance::balancer::{registry, Balancer};
use crate::balance::scratch::PlanScratch;
use crate::balance::types::{Assignment, ExampleRef};
use crate::comm::costmodel::{allgather_cost, alltoall_cost, CollectiveCost};
use crate::comm::topology::Topology;
use crate::comm::volume::VolumeMatrix;
use crate::nodewise;

use super::rearrangement::Rearrangement;

/// Which payload communicator realizes the rearrangement.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Communicator {
    /// The paper's Node-wise All-to-All (node-wise step optional).
    AllToAll { nodewise: bool },
    /// Strawman: All-Gather everything everywhere (§5.2.1).
    AllGather,
}

/// A dispatcher for one phase: a pluggable balancing algorithm plus a
/// payload communicator.
#[derive(Clone)]
pub struct Dispatcher {
    pub balancer: Arc<dyn Balancer>,
    pub communicator: Communicator,
}

impl std::fmt::Debug for Dispatcher {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Dispatcher")
            .field("balancer", &self.balancer.name())
            .field("communicator", &self.communicator)
            .finish()
    }
}

/// The dispatcher's output for one step of one phase.
#[derive(Clone, Debug)]
pub struct DispatchPlan {
    /// New mini-batches: `assignment[i]` = examples instance i computes.
    /// Examples with zero length in this phase are omitted.
    pub assignment: Assignment,
    /// Physical routing for the phase *inputs* (after the node-wise
    /// permutation).
    pub route: Rearrangement,
    /// Node-wise permutation applied (identity when disabled).
    pub nodewise_perm: Vec<usize>,
    /// Priced communication of the input rearrangement.
    pub comm: CollectiveCost,
    /// Peak staging bytes on any instance (AllGather inflates this).
    pub peak_bytes: f64,
    /// Dispatcher *computation* time (overlappable, §6).
    pub compute_nanos: u128,
}

impl DispatchPlan {
    /// Per-instance destination for every participating example id.
    pub fn destination_of(&self, n: usize) -> Vec<Option<usize>> {
        let mut dst = vec![None; n];
        for (i, batch) in self.assignment.iter().enumerate() {
            for e in batch {
                dst[e.id] = Some(i);
            }
        }
        dst
    }
}

impl Dispatcher {
    pub fn new(
        balancer: Arc<dyn Balancer>,
        communicator: Communicator,
    ) -> Dispatcher {
        Dispatcher { balancer, communicator }
    }

    /// Build a dispatcher from a registry name (`None` if unknown).
    pub fn by_name(
        name: &str,
        communicator: Communicator,
    ) -> Option<Dispatcher> {
        Some(Dispatcher::new(registry::create(name)?, communicator))
    }

    /// Plan this phase's rearrangement with a fresh scratch
    /// (convenience path for tests and one-shot callers).
    ///
    /// * `placement[g]` — instance currently holding example g.
    /// * `lens[g]` — example g's sequence length in this phase (0 =
    ///   does not participate, stays put).
    /// * `payload[g]` — bytes that must move if g changes instance.
    pub fn dispatch(
        &self,
        topo: &Topology,
        placement: &[usize],
        lens: &[usize],
        payload: &[f64],
    ) -> DispatchPlan {
        self.dispatch_with(
            topo,
            placement,
            lens,
            payload,
            &mut PlanScratch::new(),
        )
    }

    /// Plan this phase's rearrangement, reusing `scratch` buffers — the
    /// allocation-free hot path the step pipeline runs every iteration.
    pub fn dispatch_with(
        &self,
        topo: &Topology,
        placement: &[usize],
        lens: &[usize],
        payload: &[f64],
        scratch: &mut PlanScratch,
    ) -> DispatchPlan {
        let t0 = Instant::now();
        let d = topo.instances;
        let n = lens.len();
        assert_eq!(placement.len(), n);
        assert_eq!(payload.len(), n);

        // Participating examples only.
        scratch.active.clear();
        scratch.active_lens.clear();
        for (g, &len) in lens.iter().enumerate() {
            if len > 0 {
                scratch.active.push(g);
                scratch.active_lens.push(len);
            }
        }

        // Step 2: post-balancing over the active set. The identity
        // balancer keeps the sampled placement (the "OrchMLLM w/o
        // balance" baseline) rather than re-dealing.
        let assignment: Assignment = if self.balancer.is_identity() {
            let mut a: Assignment = vec![Vec::new(); d];
            for &g in &scratch.active {
                a[placement[g]].push(ExampleRef { id: g, len: lens[g] });
            }
            a
        } else {
            // The balancer receives the whole scratch; temporarily move
            // the lens slice out so the borrows stay disjoint.
            let active_lens = std::mem::take(&mut scratch.active_lens);
            let mut local = self.balancer.balance(&active_lens, d, scratch);
            scratch.active_lens = active_lens;
            // Map algorithm-local ids back to global example ids.
            for batch in &mut local {
                for e in batch.iter_mut() {
                    e.id = scratch.active[e.id];
                }
            }
            local
        };

        // Logical destination per active example.
        scratch.logical_to.clear();
        scratch.logical_to.resize(n, usize::MAX);
        for (i, batch) in assignment.iter().enumerate() {
            for e in batch {
                scratch.logical_to[e.id] = i;
            }
        }

        // Step 3: node-wise permutation of destination batches.
        scratch.volume.reset(d);
        for &g in &scratch.active {
            scratch.volume.add(
                placement[g],
                scratch.logical_to[g],
                payload[g],
            );
        }
        let nodewise_perm = match self.communicator {
            Communicator::AllToAll { nodewise: true } => {
                nodewise::rearrange(topo, &scratch.volume).perm
            }
            _ => VolumeMatrix::identity_perm(d),
        };

        // Physical route (inactive examples stay put).
        let from: Vec<usize> = placement.to_vec();
        let to: Vec<usize> = (0..n)
            .map(|g| {
                if scratch.logical_to[g] == usize::MAX {
                    placement[g]
                } else {
                    nodewise_perm[scratch.logical_to[g]]
                }
            })
            .collect();
        let route = Rearrangement::new(from, to);

        // Remap the assignment to physical instances.
        let mut physical: Assignment = vec![Vec::new(); d];
        for (logical, batch) in assignment.into_iter().enumerate() {
            physical[nodewise_perm[logical]] = batch;
        }

        // Step 4 pricing.
        let (comm, peak_bytes) = match self.communicator {
            Communicator::AllToAll { .. } => {
                route.volume_into(d, payload, &mut scratch.volume2);
                let c = alltoall_cost(
                    topo,
                    &scratch.volume2,
                    &VolumeMatrix::identity_perm(d),
                );
                (c, c.peak_bytes)
            }
            Communicator::AllGather => {
                // Everyone receives every instance's whole payload.
                let per_instance: Vec<usize> = (0..d)
                    .map(|i| {
                        (0..n)
                            .filter(|&g| placement[g] == i)
                            .map(|g| payload[g] as usize)
                            .sum()
                    })
                    .collect();
                let c = allgather_cost(topo, &per_instance);
                (c, c.peak_bytes)
            }
        };

        DispatchPlan {
            assignment: physical,
            route,
            nodewise_perm,
            comm,
            peak_bytes,
            compute_nanos: t0.elapsed().as_nanos(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balance::cost::CostModel;
    use crate::util::rng::Pcg64;

    fn setup(d: usize, n_per: usize, seed: u64)
        -> (Topology, Vec<usize>, Vec<usize>, Vec<f64>) {
        let topo = Topology::h100(d);
        let mut rng = Pcg64::new(seed);
        let n = d * n_per;
        let placement: Vec<usize> = (0..n).map(|g| g / n_per).collect();
        let lens: Vec<usize> =
            (0..n).map(|_| rng.range(1, 2048)).collect();
        let payload: Vec<f64> =
            lens.iter().map(|&l| (l * 4) as f64).collect();
        (topo, placement, lens, payload)
    }

    fn disp(name: &str, communicator: Communicator) -> Dispatcher {
        Dispatcher::by_name(name, communicator).expect("registered name")
    }

    #[test]
    fn balanced_dispatch_reduces_imbalance() {
        let (topo, placement, lens, payload) = setup(8, 16, 1);
        let plan = disp("greedy", Communicator::AllToAll { nodewise: true })
            .dispatch(&topo, &placement, &lens, &payload);
        let cm = CostModel::Linear { alpha: 1.0 };
        // Identity (no balance) batches.
        let base = disp("none", Communicator::AllToAll { nodewise: false })
            .dispatch(&topo, &placement, &lens, &payload);
        assert!(
            cm.imbalance(&plan.assignment) < cm.imbalance(&base.assignment),
            "{} !< {}",
            cm.imbalance(&plan.assignment),
            cm.imbalance(&base.assignment)
        );
        assert!(cm.imbalance(&plan.assignment) < 1.05);
    }

    #[test]
    fn no_balance_plan_never_moves() {
        let (topo, placement, lens, payload) = setup(4, 8, 2);
        let plan = disp("none", Communicator::AllToAll { nodewise: false })
            .dispatch(&topo, &placement, &lens, &payload);
        assert_eq!(plan.route.moved(), 0);
        assert!(plan.comm.seconds <= topo.base_latency + 1e-12);
    }

    #[test]
    fn zero_length_examples_stay_home() {
        let topo = Topology::h100(2);
        let placement = vec![0, 0, 1, 1];
        let lens = vec![10, 0, 7, 0];
        let payload = vec![40.0, 0.0, 28.0, 0.0];
        let plan = disp("greedy", Communicator::AllToAll { nodewise: false })
            .dispatch(&topo, &placement, &lens, &payload);
        assert_eq!(plan.route.to[1], 0);
        assert_eq!(plan.route.to[3], 1);
        let assigned: usize =
            plan.assignment.iter().map(|b| b.len()).sum();
        assert_eq!(assigned, 2); // only the active examples
    }

    #[test]
    fn allgather_costs_more_than_alltoall() {
        let (topo, placement, lens, payload) = setup(16, 8, 3);
        let a2a = disp("greedy", Communicator::AllToAll { nodewise: true })
            .dispatch(&topo, &placement, &lens, &payload);
        let ag = disp("greedy", Communicator::AllGather)
            .dispatch(&topo, &placement, &lens, &payload);
        assert!(ag.comm.seconds > a2a.comm.seconds);
        assert!(ag.peak_bytes > a2a.peak_bytes);
    }

    #[test]
    fn nodewise_reduces_inter_node_traffic() {
        let (topo, placement, lens, payload) = setup(32, 8, 4);
        let with = disp("greedy", Communicator::AllToAll { nodewise: true })
            .dispatch(&topo, &placement, &lens, &payload);
        let without =
            disp("greedy", Communicator::AllToAll { nodewise: false })
                .dispatch(&topo, &placement, &lens, &payload);
        let inter_with = with.route.inter_node_bytes(&topo, &payload);
        let inter_without =
            without.route.inter_node_bytes(&topo, &payload);
        assert!(
            inter_with <= inter_without,
            "{inter_with} > {inter_without}"
        );
    }

    #[test]
    fn destinations_cover_active_examples() {
        let (topo, placement, lens, payload) = setup(4, 4, 5);
        let plan = disp("padded", Communicator::AllToAll { nodewise: false })
            .dispatch(&topo, &placement, &lens, &payload);
        let dst = plan.destination_of(lens.len());
        for (g, d) in dst.iter().enumerate() {
            assert_eq!(d.is_some(), lens[g] > 0);
        }
    }

    #[test]
    fn scratch_reuse_matches_fresh_dispatch() {
        let (topo, placement, lens, payload) = setup(8, 12, 6);
        let dp = disp("kk", Communicator::AllToAll { nodewise: true });
        let fresh = dp.dispatch(&topo, &placement, &lens, &payload);
        let mut scratch = PlanScratch::new();
        for _ in 0..3 {
            let reused = dp.dispatch_with(
                &topo, &placement, &lens, &payload, &mut scratch,
            );
            assert_eq!(reused.assignment, fresh.assignment);
            assert_eq!(reused.route, fresh.route);
            assert_eq!(reused.nodewise_perm, fresh.nodewise_perm);
        }
    }

    #[test]
    fn every_registered_balancer_dispatches_validly() {
        let (topo, placement, lens, payload) = setup(6, 10, 7);
        let mut scratch = PlanScratch::new();
        for name in crate::balance::registry::NAMES {
            let plan = disp(name, Communicator::AllToAll { nodewise: true })
                .dispatch_with(
                    &topo, &placement, &lens, &payload, &mut scratch,
                );
            let assigned: usize =
                plan.assignment.iter().map(|b| b.len()).sum();
            assert_eq!(assigned, lens.len(), "{name} lost examples");
        }
    }
}
