//! Batch Post-Balancing Dispatcher (paper §5).
//!
//! One dispatcher serves one phase. Per training step it:
//!
//! 1. All-Gathers the sequence *lengths* only (negligible volume — the
//!    §5.2.1 insight);
//! 2. runs the configured Post-Balancing algorithm on every instance
//!    (deterministic, so all instances agree without extra traffic);
//! 3. runs the Node-wise Rearrangement Algorithm to permute the
//!    destination batch order for the hierarchical topology (§5.2.2);
//! 4. prices (simulator) / executes (trainer) the payload rearrangement
//!    on the chosen communicator: the paper's All-to-All or the
//!    All-Gather strawman it is compared against (Fig. 12).
//!
//! Steps 1–3 are "computation" in the paper's taxonomy and run inside
//! the prefetch overlap; step 4 is the only on-critical-path work.

use std::time::Instant;

use crate::balance::types::{Assignment, ExampleRef, Policy};
use crate::balance::{self};
use crate::comm::costmodel::{allgather_cost, alltoall_cost, CollectiveCost};
use crate::comm::topology::Topology;
use crate::comm::volume::VolumeMatrix;
use crate::nodewise;

use super::rearrangement::Rearrangement;

/// Which payload communicator realizes the rearrangement.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Communicator {
    /// The paper's Node-wise All-to-All (node-wise step optional).
    AllToAll { nodewise: bool },
    /// Strawman: All-Gather everything everywhere (§5.2.1).
    AllGather,
}

/// A dispatcher for one phase.
#[derive(Clone, Copy, Debug)]
pub struct Dispatcher {
    pub policy: Policy,
    pub communicator: Communicator,
}

/// The dispatcher's output for one step of one phase.
#[derive(Clone, Debug)]
pub struct DispatchPlan {
    /// New mini-batches: `assignment[i]` = examples instance i computes.
    /// Examples with zero length in this phase are omitted.
    pub assignment: Assignment,
    /// Physical routing for the phase *inputs* (after the node-wise
    /// permutation).
    pub route: Rearrangement,
    /// Node-wise permutation applied (identity when disabled).
    pub nodewise_perm: Vec<usize>,
    /// Priced communication of the input rearrangement.
    pub comm: CollectiveCost,
    /// Peak staging bytes on any instance (AllGather inflates this).
    pub peak_bytes: f64,
    /// Dispatcher *computation* time (overlappable, §6).
    pub compute_nanos: u128,
}

impl DispatchPlan {
    /// Per-instance destination for every participating example id.
    pub fn destination_of(&self, n: usize) -> Vec<Option<usize>> {
        let mut dst = vec![None; n];
        for (i, batch) in self.assignment.iter().enumerate() {
            for e in batch {
                dst[e.id] = Some(i);
            }
        }
        dst
    }
}

impl Dispatcher {
    /// Plan this phase's rearrangement.
    ///
    /// * `placement[g]` — instance currently holding example g.
    /// * `lens[g]` — example g's sequence length in this phase (0 =
    ///   does not participate, stays put).
    /// * `payload[g]` — bytes that must move if g changes instance.
    pub fn dispatch(
        &self,
        topo: &Topology,
        placement: &[usize],
        lens: &[usize],
        payload: &[f64],
    ) -> DispatchPlan {
        let t0 = Instant::now();
        let d = topo.instances;
        let n = lens.len();
        assert_eq!(placement.len(), n);
        assert_eq!(payload.len(), n);

        // Participating examples only.
        let active: Vec<usize> =
            (0..n).filter(|&g| lens[g] > 0).collect();
        let active_lens: Vec<usize> =
            active.iter().map(|&g| lens[g]).collect();

        // Step 2: post-balancing over the active set. NoBalance keeps
        // the sampled placement (the "OrchMLLM w/o balance" baseline).
        let assignment: Assignment = if self.policy == Policy::NoBalance {
            let mut a: Assignment = vec![Vec::new(); d];
            for &g in &active {
                a[placement[g]].push(ExampleRef { id: g, len: lens[g] });
            }
            a
        } else {
            let local = balance::balance(self.policy, &active_lens, d);
            // Map algorithm-local ids back to global example ids.
            local
                .into_iter()
                .map(|batch| {
                    batch
                        .into_iter()
                        .map(|e| ExampleRef {
                            id: active[e.id],
                            len: e.len,
                        })
                        .collect()
                })
                .collect()
        };

        // Logical destination per active example.
        let mut logical_to = vec![usize::MAX; n];
        for (i, batch) in assignment.iter().enumerate() {
            for e in batch {
                logical_to[e.id] = i;
            }
        }

        // Step 3: node-wise permutation of destination batches.
        let mut volume = VolumeMatrix::zeros(d);
        for &g in &active {
            volume.add(placement[g], logical_to[g], payload[g]);
        }
        let nodewise_perm = match self.communicator {
            Communicator::AllToAll { nodewise: true } => {
                nodewise::rearrange(topo, &volume).perm
            }
            _ => VolumeMatrix::identity_perm(d),
        };

        // Physical route (inactive examples stay put).
        let from: Vec<usize> = placement.to_vec();
        let to: Vec<usize> = (0..n)
            .map(|g| {
                if logical_to[g] == usize::MAX {
                    placement[g]
                } else {
                    nodewise_perm[logical_to[g]]
                }
            })
            .collect();
        let route = Rearrangement::new(from, to);

        // Remap the assignment to physical instances.
        let mut physical: Assignment = vec![Vec::new(); d];
        for (logical, batch) in assignment.into_iter().enumerate() {
            physical[nodewise_perm[logical]] = batch;
        }

        // Step 4 pricing.
        let (comm, peak_bytes) = match self.communicator {
            Communicator::AllToAll { .. } => {
                let v = route.volume(d, payload);
                let c =
                    alltoall_cost(topo, &v, &VolumeMatrix::identity_perm(d));
                (c, c.peak_bytes)
            }
            Communicator::AllGather => {
                // Everyone receives every instance's whole payload.
                let per_instance: Vec<usize> = (0..d)
                    .map(|i| {
                        (0..n)
                            .filter(|&g| placement[g] == i)
                            .map(|g| payload[g] as usize)
                            .sum()
                    })
                    .collect();
                let c = allgather_cost(topo, &per_instance);
                (c, c.peak_bytes)
            }
        };

        DispatchPlan {
            assignment: physical,
            route,
            nodewise_perm,
            comm,
            peak_bytes,
            compute_nanos: t0.elapsed().as_nanos(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balance::cost::CostModel;
    use crate::util::rng::Pcg64;

    fn setup(d: usize, n_per: usize, seed: u64)
        -> (Topology, Vec<usize>, Vec<usize>, Vec<f64>) {
        let topo = Topology::h100(d);
        let mut rng = Pcg64::new(seed);
        let n = d * n_per;
        let placement: Vec<usize> = (0..n).map(|g| g / n_per).collect();
        let lens: Vec<usize> =
            (0..n).map(|_| rng.range(1, 2048)).collect();
        let payload: Vec<f64> =
            lens.iter().map(|&l| (l * 4) as f64).collect();
        (topo, placement, lens, payload)
    }

    #[test]
    fn balanced_dispatch_reduces_imbalance() {
        let (topo, placement, lens, payload) = setup(8, 16, 1);
        let disp = Dispatcher {
            policy: Policy::GreedyUnpadded,
            communicator: Communicator::AllToAll { nodewise: true },
        };
        let plan = disp.dispatch(&topo, &placement, &lens, &payload);
        let cm = CostModel::Linear { alpha: 1.0 };
        // Identity (no balance) batches.
        let none = Dispatcher {
            policy: Policy::NoBalance,
            communicator: Communicator::AllToAll { nodewise: false },
        };
        let base = none.dispatch(&topo, &placement, &lens, &payload);
        assert!(
            cm.imbalance(&plan.assignment) < cm.imbalance(&base.assignment),
            "{} !< {}",
            cm.imbalance(&plan.assignment),
            cm.imbalance(&base.assignment)
        );
        assert!(cm.imbalance(&plan.assignment) < 1.05);
    }

    #[test]
    fn no_balance_plan_never_moves() {
        let (topo, placement, lens, payload) = setup(4, 8, 2);
        let disp = Dispatcher {
            policy: Policy::NoBalance,
            communicator: Communicator::AllToAll { nodewise: false },
        };
        let plan = disp.dispatch(&topo, &placement, &lens, &payload);
        assert_eq!(plan.route.moved(), 0);
        assert!(plan.comm.seconds <= topo.base_latency + 1e-12);
    }

    #[test]
    fn zero_length_examples_stay_home() {
        let topo = Topology::h100(2);
        let placement = vec![0, 0, 1, 1];
        let lens = vec![10, 0, 7, 0];
        let payload = vec![40.0, 0.0, 28.0, 0.0];
        let disp = Dispatcher {
            policy: Policy::GreedyUnpadded,
            communicator: Communicator::AllToAll { nodewise: false },
        };
        let plan = disp.dispatch(&topo, &placement, &lens, &payload);
        assert_eq!(plan.route.to[1], 0);
        assert_eq!(plan.route.to[3], 1);
        let assigned: usize =
            plan.assignment.iter().map(|b| b.len()).sum();
        assert_eq!(assigned, 2); // only the active examples
    }

    #[test]
    fn allgather_costs_more_than_alltoall() {
        let (topo, placement, lens, payload) = setup(16, 8, 3);
        let a2a = Dispatcher {
            policy: Policy::GreedyUnpadded,
            communicator: Communicator::AllToAll { nodewise: true },
        }
        .dispatch(&topo, &placement, &lens, &payload);
        let ag = Dispatcher {
            policy: Policy::GreedyUnpadded,
            communicator: Communicator::AllGather,
        }
        .dispatch(&topo, &placement, &lens, &payload);
        assert!(ag.comm.seconds > a2a.comm.seconds);
        assert!(ag.peak_bytes > a2a.peak_bytes);
    }

    #[test]
    fn nodewise_reduces_inter_node_traffic() {
        let (topo, placement, lens, payload) = setup(32, 8, 4);
        let with = Dispatcher {
            policy: Policy::GreedyUnpadded,
            communicator: Communicator::AllToAll { nodewise: true },
        }
        .dispatch(&topo, &placement, &lens, &payload);
        let without = Dispatcher {
            policy: Policy::GreedyUnpadded,
            communicator: Communicator::AllToAll { nodewise: false },
        }
        .dispatch(&topo, &placement, &lens, &payload);
        let inter_with = with.route.inter_node_bytes(&topo, &payload);
        let inter_without =
            without.route.inter_node_bytes(&topo, &payload);
        assert!(
            inter_with <= inter_without,
            "{inter_with} > {inter_without}"
        );
    }

    #[test]
    fn destinations_cover_active_examples() {
        let (topo, placement, lens, payload) = setup(4, 4, 5);
        let plan = Dispatcher {
            policy: Policy::BinaryPadded,
            communicator: Communicator::AllToAll { nodewise: false },
        }
        .dispatch(&topo, &placement, &lens, &payload);
        let dst = plan.destination_of(lens.len());
        for (g, d) in dst.iter().enumerate() {
            assert_eq!(d.is_some(), lens[g] > 0);
        }
    }
}
