//! Batch Post-Balancing Dispatcher (paper §5).
//!
//! One dispatcher serves one phase. Per training step it:
//!
//! 1. All-Gathers the sequence *lengths* only (negligible volume — the
//!    §5.2.1 insight);
//! 2. runs the configured Post-Balancing [`Balancer`] on every instance
//!    (deterministic, so all instances agree without extra traffic);
//! 3. runs the Node-wise Rearrangement Algorithm to permute the
//!    destination batch order for the hierarchical topology (§5.2.2);
//! 4. prices (simulator) / executes (trainer) the payload rearrangement
//!    on the chosen communicator: the paper's All-to-All or the
//!    All-Gather strawman it is compared against (Fig. 12).
//!
//! Steps 1–3 are "computation" in the paper's taxonomy and run inside
//! the prefetch overlap; step 4 is the only on-critical-path work.
//! [`Dispatcher::dispatch`] is the *single* planning entry point: it
//! threads a [`PlanScratch`] (no allocation in the sort/heap/volume
//! loops) and a [`DispatchOptions`] — attach a [`PhaseHistory`] and
//! recurring batch shapes replay a cached solve bit-identically,
//! similar shapes warm-start from the previous step's assignment
//! within the options' tolerance band, and only diverged batches pay
//! the from-scratch solve; omit the history for the cold baseline.
//! Callers above the phase level should not drive dispatchers directly
//! — the stateful [`crate::orchestrator::session::PlanSession`] owns
//! scratches and histories for all three phases.

use std::sync::Arc;
use std::time::Instant;

use crate::balance::balancer::{registry, Balancer};
use crate::balance::cache::{PlanCache, Sketch, DEFAULT_PLAN_CACHE_SIZE};
use crate::balance::incremental::{PlanSource, REPAIR_TOLERANCE};
use crate::balance::scratch::PlanScratch;
use crate::balance::types::{Assignment, ExampleRef};
use crate::comm::costmodel::{allgather_cost, alltoall_cost, CollectiveCost};
use crate::comm::topology::Topology;
use crate::comm::volume::VolumeMatrix;
use crate::nodewise;

use super::rearrangement::Rearrangement;

/// Per-phase planning history carried across steps: the previous
/// accepted balancer-local assignment (the warm-start donor) plus the
/// sketch-keyed solve cache. One per phase — histories, like scratches,
/// are never shared between the concurrently-planning dispatchers.
#[derive(Clone, Debug)]
pub struct PhaseHistory {
    /// Previous step's balancer-local assignment. Ids index into *that*
    /// step's active set; only the rank structure is reused, so the two
    /// steps' id spaces never mix.
    pub prev_local: Assignment,
    /// Cache of balancer-local solves keyed by the exact `(d,
    /// active_lens)` input, bucketed by the quantized histogram sketch.
    /// Hits are bit-identical replays of an earlier solve.
    pub cache: PlanCache<Assignment>,
    /// Reusable exact-key buffer (d ‖ active lens).
    key_buf: Vec<u64>,
}

impl PhaseHistory {
    pub fn new(cache_capacity: usize) -> PhaseHistory {
        PhaseHistory {
            prev_local: Vec::new(),
            cache: PlanCache::new(cache_capacity),
            key_buf: Vec::new(),
        }
    }
}

impl Default for PhaseHistory {
    fn default() -> PhaseHistory {
        PhaseHistory::new(DEFAULT_PLAN_CACHE_SIZE)
    }
}

/// Per-call knobs of [`Dispatcher::dispatch`] — the phase-level mirror
/// of `PlanOptions` (`crate::orchestrator::session`). The default is
/// the history-free cold solve; attach a [`PhaseHistory`] for the
/// incremental path.
#[derive(Debug)]
pub struct DispatchOptions<'h> {
    /// Cross-step planning state. `None` = solve from scratch.
    pub history: Option<&'h mut PhaseHistory>,
    /// Warm-acceptance tolerance band (see
    /// [`crate::balance::incremental::warm_start_with`]).
    pub tolerance: f64,
    /// Consult/populate the sketch-keyed solve cache. `false` skips the
    /// key build and insert clone entirely; warm-starting still applies
    /// when a history is attached.
    pub cache: bool,
}

impl Default for DispatchOptions<'_> {
    fn default() -> Self {
        DispatchOptions {
            history: None,
            tolerance: REPAIR_TOLERANCE,
            cache: true,
        }
    }
}

impl<'h> DispatchOptions<'h> {
    /// The steady-state path: warm-start + cache through `history`.
    pub fn incremental(history: &'h mut PhaseHistory) -> Self {
        DispatchOptions {
            history: Some(history),
            tolerance: REPAIR_TOLERANCE,
            cache: true,
        }
    }

    /// Override the warm-acceptance tolerance band.
    pub fn tolerance(mut self, tolerance: f64) -> Self {
        self.tolerance = tolerance;
        self
    }

    /// Enable or disable the sketch-keyed solve cache.
    pub fn cache(mut self, cache: bool) -> Self {
        self.cache = cache;
        self
    }
}

/// Which payload communicator realizes the rearrangement.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Communicator {
    /// The paper's Node-wise All-to-All (node-wise step optional).
    AllToAll { nodewise: bool },
    /// Strawman: All-Gather everything everywhere (§5.2.1).
    AllGather,
}

/// A dispatcher for one phase: a pluggable balancing algorithm plus a
/// payload communicator.
#[derive(Clone)]
pub struct Dispatcher {
    pub balancer: Arc<dyn Balancer>,
    pub communicator: Communicator,
}

impl std::fmt::Debug for Dispatcher {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Dispatcher")
            .field("balancer", &self.balancer.name())
            .field("communicator", &self.communicator)
            .finish()
    }
}

/// The dispatcher's output for one step of one phase.
#[derive(Clone, Debug)]
pub struct DispatchPlan {
    /// New mini-batches: `assignment[i]` = examples instance i computes.
    /// Examples with zero length in this phase are omitted.
    pub assignment: Assignment,
    /// Physical routing for the phase *inputs* (after the node-wise
    /// permutation).
    pub route: Rearrangement,
    /// Node-wise permutation applied (identity when disabled).
    pub nodewise_perm: Vec<usize>,
    /// Priced communication of the input rearrangement.
    pub comm: CollectiveCost,
    /// Peak staging bytes on any instance (AllGather inflates this).
    pub peak_bytes: f64,
    /// Dispatcher *computation* time (overlappable, §6).
    pub compute_nanos: u128,
    /// How the balancer-local solve was produced (identity dispatches
    /// and history-free calls are `Cold`).
    pub source: PlanSource,
    /// Local repair moves applied on the warm path (0 otherwise).
    pub repair_moves: usize,
}

impl DispatchPlan {
    /// Per-instance destination for every participating example id.
    pub fn destination_of(&self, n: usize) -> Vec<Option<usize>> {
        let mut dst = vec![None; n];
        for (i, batch) in self.assignment.iter().enumerate() {
            for e in batch {
                dst[e.id] = Some(i);
            }
        }
        dst
    }
}

impl Dispatcher {
    pub fn new(
        balancer: Arc<dyn Balancer>,
        communicator: Communicator,
    ) -> Dispatcher {
        Dispatcher { balancer, communicator }
    }

    /// Build a dispatcher from a registry name (`None` if unknown).
    pub fn by_name(
        name: &str,
        communicator: Communicator,
    ) -> Option<Dispatcher> {
        Some(Dispatcher::new(registry::create(name)?, communicator))
    }

    /// Plan this phase's rearrangement — the one planning entry point.
    ///
    /// * `placement[g]` — instance currently holding example g.
    /// * `lens[g]` — example g's sequence length in this phase (0 =
    ///   does not participate, stays put).
    /// * `payload[g]` — bytes that must move if g changes instance.
    /// * `scratch` — reusable sort/heap/volume buffers; warmed-up calls
    ///   are allocation-free.
    /// * `opts` — history / tolerance / cache knobs
    ///   ([`DispatchOptions::default`] is the cold, history-free
    ///   solve; [`DispatchOptions::incremental`] the steady-state
    ///   path, updating the history in place).
    pub fn dispatch(
        &self,
        topo: &Topology,
        placement: &[usize],
        lens: &[usize],
        payload: &[f64],
        scratch: &mut PlanScratch,
        opts: DispatchOptions<'_>,
    ) -> DispatchPlan {
        let DispatchOptions { mut history, tolerance, cache } = opts;
        let t0 = Instant::now();
        let d = topo.instances;
        let n = lens.len();
        assert_eq!(placement.len(), n);
        assert_eq!(payload.len(), n);

        // Participating examples only.
        scratch.active.clear();
        scratch.active_lens.clear();
        for (g, &len) in lens.iter().enumerate() {
            if len > 0 {
                scratch.active.push(g);
                scratch.active_lens.push(len);
            }
        }

        // Step 2: post-balancing over the active set. The identity
        // balancer keeps the sampled placement (the "OrchMLLM w/o
        // balance" baseline) rather than re-dealing.
        let mut source = PlanSource::Cold;
        let mut repair_moves = 0usize;
        let assignment: Assignment = if self.balancer.is_identity() {
            let mut a: Assignment = vec![Vec::new(); d];
            for &g in &scratch.active {
                a[placement[g]].push(ExampleRef { id: g, len: lens[g] });
            }
            a
        } else {
            // The balancer receives the whole scratch; temporarily move
            // the lens slice out so the borrows stay disjoint.
            let active_lens = std::mem::take(&mut scratch.active_lens);
            let mut local = match history.as_deref_mut() {
                Some(h) if cache && h.cache.capacity() > 0 => {
                    // The solve is a pure function of (active lens, d):
                    // sketch-bucketed exact lookup first, then
                    // warm-start, then cold solve.
                    let sketch = Sketch::of(&active_lens, d);
                    h.key_buf.clear();
                    h.key_buf.push(d as u64);
                    h.key_buf
                        .extend(active_lens.iter().map(|&l| l as u64));
                    if let Some(cached) =
                        h.cache.lookup(sketch, &h.key_buf)
                    {
                        source = PlanSource::Cached;
                        h.prev_local.clone_from(&cached);
                        cached
                    } else {
                        let inc = self.balancer.plan_incremental_with(
                            &active_lens,
                            d,
                            &h.prev_local,
                            scratch,
                            tolerance,
                        );
                        source = inc.source;
                        repair_moves = inc.repair_moves;
                        h.prev_local.clone_from(&inc.assignment);
                        h.cache.insert(
                            sketch,
                            &h.key_buf,
                            inc.assignment.clone(),
                        );
                        inc.assignment
                    }
                }
                Some(h) => {
                    // Caching disabled (opts or capacity 0): skip the
                    // sketch, key build, and insert clone entirely — the
                    // warm start from the previous assignment still
                    // applies.
                    let inc = self.balancer.plan_incremental_with(
                        &active_lens,
                        d,
                        &h.prev_local,
                        scratch,
                        tolerance,
                    );
                    source = inc.source;
                    repair_moves = inc.repair_moves;
                    h.prev_local.clone_from(&inc.assignment);
                    inc.assignment
                }
                None => self.balancer.balance(&active_lens, d, scratch),
            };
            scratch.active_lens = active_lens;
            // Map algorithm-local ids back to global example ids.
            for batch in &mut local {
                for e in batch.iter_mut() {
                    e.id = scratch.active[e.id];
                }
            }
            local
        };

        // Logical destination per active example.
        scratch.logical_to.clear();
        scratch.logical_to.resize(n, usize::MAX);
        for (i, batch) in assignment.iter().enumerate() {
            for e in batch {
                scratch.logical_to[e.id] = i;
            }
        }

        // Step 3: node-wise permutation of destination batches.
        scratch.volume.reset(d);
        for &g in &scratch.active {
            scratch.volume.add(
                placement[g],
                scratch.logical_to[g],
                payload[g],
            );
        }
        let nodewise_perm = match self.communicator {
            Communicator::AllToAll { nodewise: true } => {
                nodewise::rearrange(topo, &scratch.volume).perm
            }
            _ => VolumeMatrix::identity_perm(d),
        };

        // Physical route (inactive examples stay put).
        let from: Vec<usize> = placement.to_vec();
        let to: Vec<usize> = (0..n)
            .map(|g| {
                if scratch.logical_to[g] == usize::MAX {
                    placement[g]
                } else {
                    nodewise_perm[scratch.logical_to[g]]
                }
            })
            .collect();
        let route = Rearrangement::new(from, to);

        // Remap the assignment to physical instances.
        let mut physical: Assignment = vec![Vec::new(); d];
        for (logical, batch) in assignment.into_iter().enumerate() {
            physical[nodewise_perm[logical]] = batch;
        }

        // Step 4 pricing.
        let (comm, peak_bytes) = match self.communicator {
            Communicator::AllToAll { .. } => {
                route.volume_into(d, payload, &mut scratch.volume2);
                let c = alltoall_cost(
                    topo,
                    &scratch.volume2,
                    &VolumeMatrix::identity_perm(d),
                );
                (c, c.peak_bytes)
            }
            Communicator::AllGather => {
                // Everyone receives every instance's whole payload.
                let per_instance: Vec<usize> = (0..d)
                    .map(|i| {
                        (0..n)
                            .filter(|&g| placement[g] == i)
                            .map(|g| payload[g] as usize)
                            .sum()
                    })
                    .collect();
                let c = allgather_cost(topo, &per_instance);
                (c, c.peak_bytes)
            }
        };

        DispatchPlan {
            assignment: physical,
            route,
            nodewise_perm,
            comm,
            peak_bytes,
            compute_nanos: t0.elapsed().as_nanos(),
            source,
            repair_moves,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balance::cost::CostModel;
    use crate::util::rng::Pcg64;

    fn setup(d: usize, n_per: usize, seed: u64)
        -> (Topology, Vec<usize>, Vec<usize>, Vec<f64>) {
        let topo = Topology::h100(d);
        let mut rng = Pcg64::new(seed);
        let n = d * n_per;
        let placement: Vec<usize> = (0..n).map(|g| g / n_per).collect();
        let lens: Vec<usize> =
            (0..n).map(|_| rng.range(1, 2048)).collect();
        let payload: Vec<f64> =
            lens.iter().map(|&l| (l * 4) as f64).collect();
        (topo, placement, lens, payload)
    }

    fn disp(name: &str, communicator: Communicator) -> Dispatcher {
        Dispatcher::by_name(name, communicator).expect("registered name")
    }

    /// One-shot cold dispatch on a fresh scratch (test convenience).
    fn cold(
        dp: &Dispatcher,
        topo: &Topology,
        placement: &[usize],
        lens: &[usize],
        payload: &[f64],
    ) -> DispatchPlan {
        dp.dispatch(
            topo,
            placement,
            lens,
            payload,
            &mut PlanScratch::new(),
            DispatchOptions::default(),
        )
    }

    #[test]
    fn balanced_dispatch_reduces_imbalance() {
        let (topo, placement, lens, payload) = setup(8, 16, 1);
        let dp = disp("greedy", Communicator::AllToAll { nodewise: true });
        let plan = cold(&dp, &topo, &placement, &lens, &payload);
        let cm = CostModel::Linear { alpha: 1.0 };
        // Identity (no balance) batches.
        let base_dp = disp("none", Communicator::AllToAll { nodewise: false });
        let base = cold(&base_dp, &topo, &placement, &lens, &payload);
        assert!(
            cm.imbalance(&plan.assignment) < cm.imbalance(&base.assignment),
            "{} !< {}",
            cm.imbalance(&plan.assignment),
            cm.imbalance(&base.assignment)
        );
        assert!(cm.imbalance(&plan.assignment) < 1.05);
    }

    #[test]
    fn no_balance_plan_never_moves() {
        let (topo, placement, lens, payload) = setup(4, 8, 2);
        let dp = disp("none", Communicator::AllToAll { nodewise: false });
        let plan = cold(&dp, &topo, &placement, &lens, &payload);
        assert_eq!(plan.route.moved(), 0);
        assert!(plan.comm.seconds <= topo.base_latency + 1e-12);
    }

    #[test]
    fn zero_length_examples_stay_home() {
        let topo = Topology::h100(2);
        let placement = vec![0, 0, 1, 1];
        let lens = vec![10, 0, 7, 0];
        let payload = vec![40.0, 0.0, 28.0, 0.0];
        let dp = disp("greedy", Communicator::AllToAll { nodewise: false });
        let plan = cold(&dp, &topo, &placement, &lens, &payload);
        assert_eq!(plan.route.to[1], 0);
        assert_eq!(plan.route.to[3], 1);
        let assigned: usize =
            plan.assignment.iter().map(|b| b.len()).sum();
        assert_eq!(assigned, 2); // only the active examples
    }

    #[test]
    fn allgather_costs_more_than_alltoall() {
        let (topo, placement, lens, payload) = setup(16, 8, 3);
        let a2a_dp = disp("greedy", Communicator::AllToAll { nodewise: true });
        let a2a = cold(&a2a_dp, &topo, &placement, &lens, &payload);
        let ag_dp = disp("greedy", Communicator::AllGather);
        let ag = cold(&ag_dp, &topo, &placement, &lens, &payload);
        assert!(ag.comm.seconds > a2a.comm.seconds);
        assert!(ag.peak_bytes > a2a.peak_bytes);
    }

    #[test]
    fn nodewise_reduces_inter_node_traffic() {
        let (topo, placement, lens, payload) = setup(32, 8, 4);
        let with_dp = disp("greedy", Communicator::AllToAll { nodewise: true });
        let with = cold(&with_dp, &topo, &placement, &lens, &payload);
        let without_dp =
            disp("greedy", Communicator::AllToAll { nodewise: false });
        let without = cold(&without_dp, &topo, &placement, &lens, &payload);
        let inter_with = with.route.inter_node_bytes(&topo, &payload);
        let inter_without =
            without.route.inter_node_bytes(&topo, &payload);
        assert!(
            inter_with <= inter_without,
            "{inter_with} > {inter_without}"
        );
    }

    #[test]
    fn destinations_cover_active_examples() {
        let (topo, placement, lens, payload) = setup(4, 4, 5);
        let dp = disp("padded", Communicator::AllToAll { nodewise: false });
        let plan = cold(&dp, &topo, &placement, &lens, &payload);
        let dst = plan.destination_of(lens.len());
        for (g, d) in dst.iter().enumerate() {
            assert_eq!(d.is_some(), lens[g] > 0);
        }
    }

    #[test]
    fn scratch_reuse_matches_fresh_dispatch() {
        let (topo, placement, lens, payload) = setup(8, 12, 6);
        let dp = disp("kk", Communicator::AllToAll { nodewise: true });
        let fresh = cold(&dp, &topo, &placement, &lens, &payload);
        let mut scratch = PlanScratch::new();
        for _ in 0..3 {
            let reused = dp.dispatch(
                &topo,
                &placement,
                &lens,
                &payload,
                &mut scratch,
                DispatchOptions::default(),
            );
            assert_eq!(reused.assignment, fresh.assignment);
            assert_eq!(reused.route, fresh.route);
            assert_eq!(reused.nodewise_perm, fresh.nodewise_perm);
        }
    }

    #[test]
    fn incremental_first_call_matches_from_scratch() {
        // With an empty history the incremental path must plan cold and
        // agree with the history-free dispatch exactly.
        let (topo, placement, lens, payload) = setup(8, 12, 8);
        let dp = disp("greedy", Communicator::AllToAll { nodewise: true });
        let mut scratch = PlanScratch::new();
        let mut history = PhaseHistory::new(8);
        let cold_plan = dp.dispatch(
            &topo,
            &placement,
            &lens,
            &payload,
            &mut scratch,
            DispatchOptions::default(),
        );
        let inc = dp.dispatch(
            &topo,
            &placement,
            &lens,
            &payload,
            &mut scratch,
            DispatchOptions::incremental(&mut history),
        );
        assert_eq!(inc.source, crate::balance::PlanSource::Cold);
        assert_eq!(inc.assignment, cold_plan.assignment);
        assert_eq!(inc.route, cold_plan.route);
        assert_eq!(inc.nodewise_perm, cold_plan.nodewise_perm);
    }

    #[test]
    fn repeated_dispatch_hits_the_cache_bit_identically() {
        let (topo, placement, lens, payload) = setup(6, 10, 9);
        let dp = disp("kk", Communicator::AllToAll { nodewise: true });
        let mut scratch = PlanScratch::new();
        let mut history = PhaseHistory::new(8);
        let first = dp.dispatch(
            &topo,
            &placement,
            &lens,
            &payload,
            &mut scratch,
            DispatchOptions::incremental(&mut history),
        );
        let second = dp.dispatch(
            &topo,
            &placement,
            &lens,
            &payload,
            &mut scratch,
            DispatchOptions::incremental(&mut history),
        );
        assert_eq!(second.source, crate::balance::PlanSource::Cached);
        assert_eq!(second.assignment, first.assignment);
        assert_eq!(second.route, first.route);
        assert_eq!(second.nodewise_perm, first.nodewise_perm);
        assert_eq!(second.comm, first.comm);
        assert_eq!(history.cache.hits, 1);
    }

    #[test]
    fn warm_dispatch_on_similar_batch_stays_valid() {
        let (topo, placement, lens, payload) = setup(8, 20, 10);
        let dp = disp("greedy", Communicator::AllToAll { nodewise: true });
        let mut scratch = PlanScratch::new();
        let mut history = PhaseHistory::new(8);
        dp.dispatch(
            &topo,
            &placement,
            &lens,
            &payload,
            &mut scratch,
            DispatchOptions::incremental(&mut history),
        );
        // Perturb one example's length: same shape, different key.
        let mut lens2 = lens.clone();
        lens2[3] += 1;
        let plan = dp.dispatch(
            &topo,
            &placement,
            &lens2,
            &payload,
            &mut scratch,
            DispatchOptions::incremental(&mut history),
        );
        let assigned: usize =
            plan.assignment.iter().map(|b| b.len()).sum();
        assert_eq!(assigned, lens2.len());
        assert_ne!(plan.source, crate::balance::PlanSource::Cached);
    }

    #[test]
    fn every_registered_balancer_dispatches_validly() {
        let (topo, placement, lens, payload) = setup(6, 10, 7);
        let mut scratch = PlanScratch::new();
        for name in crate::balance::registry::NAMES {
            let plan = disp(name, Communicator::AllToAll { nodewise: true })
                .dispatch(
                    &topo,
                    &placement,
                    &lens,
                    &payload,
                    &mut scratch,
                    DispatchOptions::default(),
                );
            let assigned: usize =
                plan.assignment.iter().map(|b| b.len()).sum();
            assert_eq!(assigned, lens.len(), "{name} lost examples");
        }
    }
}
