//! Double-buffered step planning: the §6 overlap on the execution path.
//!
//! The paper prices dispatcher computation as free because it "overlaps
//! with the forward pass via prefetch" — this module is where that
//! actually happens. A [`StepPipeline`] owns a background planning
//! thread that samples the next step's mini-batches and runs the full
//! [`Orchestrator`] plan (post-balancing, node-wise rearrangement,
//! composition) while the caller executes the current step. The channel
//! is bounded at `depth` planned-but-unconsumed steps (depth 1 =
//! classic double buffering: plan t+1 while t executes), so planning
//! can never run unboundedly ahead of the consumer.
//!
//! The planning thread reuses one [`StepScratch`] across steps and
//! plans the three phases concurrently, so the planning latency that
//! must hide under one step's compute is the slowest single phase, not
//! the sum — measured per step in [`PlannedStep::plan_nanos`] and
//! reported by the trainer and the Table-2 bench.

use crate::comm::topology::Topology;
use crate::data::loader::Prefetcher;
use crate::data::synth::{DatasetConfig, Example};

use super::global::{Orchestrator, StepPlan, StepScratch};

/// One planned step, handed to the executor.
pub struct PlannedStep {
    /// The sampled per-instance mini-batches the plan was built from.
    pub minibatches: Vec<Vec<Example>>,
    /// The full step plan (same object the simulator prices).
    pub plan: StepPlan,
    /// Planning wall-time — time spent *off* the critical path.
    pub plan_nanos: u128,
}

/// Background sampler + planner with bounded lookahead.
pub struct StepPipeline {
    inner: Prefetcher<StepPlan>,
}

impl StepPipeline {
    /// Start planning: `d` instances × `batch_size` examples per step
    /// for `steps` steps, at most `depth` planned steps in flight.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        orch: Orchestrator,
        topo: Topology,
        data_cfg: DatasetConfig,
        seed: u64,
        d: usize,
        batch_size: usize,
        steps: usize,
        depth: usize,
    ) -> StepPipeline {
        let mut scratch = StepScratch::default();
        let inner = Prefetcher::new(
            data_cfg,
            seed,
            d,
            batch_size,
            steps,
            depth.max(1),
            move |mbs| orch.plan_step_with(&topo, mbs, &mut scratch),
        );
        StepPipeline { inner }
    }

    /// Blocking fetch of the next planned step; `None` when the
    /// configured number of steps is exhausted.
    pub fn next(&self) -> Option<PlannedStep> {
        self.inner.next().map(|s| PlannedStep {
            minibatches: s.minibatches,
            plan: s.plan,
            plan_nanos: s.plan_nanos,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::flops::PhaseKind;
    use crate::orchestrator::global::OrchestratorConfig;

    fn pipeline(steps: usize, seed: u64) -> StepPipeline {
        StepPipeline::new(
            Orchestrator::new(OrchestratorConfig::orchmllm(7168.0)),
            Topology::h100(4),
            DatasetConfig::tiny(2, 2),
            seed,
            4,
            6,
            steps,
            1,
        )
    }

    #[test]
    fn yields_the_configured_number_of_planned_steps() {
        let p = pipeline(5, 3);
        let mut n = 0;
        while let Some(step) = p.next() {
            assert_eq!(step.minibatches.len(), 4);
            assert_eq!(step.plan.d, 4);
            assert_eq!(step.plan.examples.len(), 4 * 6);
            n += 1;
        }
        assert_eq!(n, 5);
    }

    #[test]
    fn pipelined_plans_match_inline_planning() {
        // Same seed → the pipeline must produce exactly the plans the
        // trainer would have computed inline (SPMD determinism).
        let p = pipeline(3, 7);
        let orch = Orchestrator::new(OrchestratorConfig::orchmllm(7168.0));
        let topo = Topology::h100(4);
        while let Some(step) = p.next() {
            let inline = orch.plan_step(&topo, &step.minibatches);
            assert_eq!(step.plan.llm.route, inline.llm.route);
            assert_eq!(
                step.plan.assignment(PhaseKind::Llm),
                inline.assignment(PhaseKind::Llm)
            );
            assert_eq!(step.plan.vision.out_route, inline.vision.out_route);
        }
    }

    #[test]
    fn early_drop_shuts_down_cleanly() {
        let p = pipeline(100, 9);
        let _ = p.next();
        drop(p); // must join the planning thread without consuming all
    }

    #[test]
    fn records_planning_time() {
        let p = pipeline(1, 11);
        let step = p.next().unwrap();
        assert!(step.plan_nanos > 0);
        assert!(step.plan_nanos >= step.plan.compute_nanos);
    }
}
