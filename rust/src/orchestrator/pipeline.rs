//! Deep-buffered step planning: the §6 overlap on the execution path.
//!
//! The paper prices dispatcher computation as free because it "overlaps
//! with the forward pass via prefetch" — this module is where that
//! actually happens. A [`StepPipeline`] moves a [`PlanSession`] onto a
//! background planning thread that samples the next steps' mini-batches
//! and runs the full plan (post-balancing, node-wise rearrangement,
//! composition) while the caller executes the current step. The channel
//! is bounded at `depth` planned-but-unconsumed steps — a *session*
//! property ([`PlanSession::depth`], from its [`PipelineConfig`]; depth
//! 1 = classic double buffering; depth 2–3 absorb planning spikes — a
//! cold solve at d ≥ 1024, an allocator hiccup — without ever stalling
//! the consumer), so planning can never run unboundedly ahead.
//!
//! The session owns all cross-step state, so steady-state steps go
//! through the incremental path ([`PlanOptions::auto`]): warm-started
//! solves and sketch-cache replays instead of from-scratch planning.
//! Every rank runs an identical pipeline over the identical sampled
//! stream, and the session is a deterministic function of that stream,
//! so all ranks still agree on every plan without communication
//! (§5.2.1). Each planned step carries its [`PlanReport`], so consumers
//! (trainer, benches) read provenance instead of reconstructing it.

use crate::balance::cache::DEFAULT_PLAN_CACHE_SIZE;
use crate::data::loader::Prefetcher;
use crate::data::synth::{DatasetConfig, Example};

use super::global::StepPlan;
use super::session::{PlanOptions, PlanReport, PlanSession};

/// Upper bound on the pipeline depth: lookahead beyond a few steps only
/// costs memory (every in-flight step retains its mini-batches + plan).
pub const MAX_PIPELINE_DEPTH: usize = 8;

/// Lookahead + caching configuration for a [`PlanSession`] (and hence
/// the [`StepPipeline`] it drives).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PipelineConfig {
    /// Planned-but-unconsumed steps in flight (1 = double buffering;
    /// 2–3 absorb planning spikes at large d).
    pub depth: usize,
    /// Capacity of each planning cache — per phase and per step — in
    /// the session's history (0 disables caching; warm-starting still
    /// applies).
    pub plan_cache_size: usize,
}

impl Default for PipelineConfig {
    fn default() -> PipelineConfig {
        PipelineConfig {
            depth: 1,
            plan_cache_size: DEFAULT_PLAN_CACHE_SIZE,
        }
    }
}

impl PipelineConfig {
    /// Validate CLI/config-supplied values, returning a printable error
    /// instead of clamping silently.
    pub fn validate(&self) -> Result<(), String> {
        if self.depth == 0 || self.depth > MAX_PIPELINE_DEPTH {
            return Err(format!(
                "pipeline depth must be in 1..={MAX_PIPELINE_DEPTH}, \
                 got {}",
                self.depth
            ));
        }
        if self.plan_cache_size > 65_536 {
            return Err(format!(
                "plan cache size {} is unreasonably large (max 65536)",
                self.plan_cache_size
            ));
        }
        Ok(())
    }
}

/// One planned step, handed to the executor.
pub struct PlannedStep {
    /// The sampled per-instance mini-batches the plan was built from.
    pub minibatches: Vec<Vec<Example>>,
    /// The full step plan (same object the simulator prices).
    pub plan: StepPlan,
    /// Provenance of this plan (per-phase sources, cache hit, timing).
    pub report: PlanReport,
    /// Planning wall-time — time spent *off* the critical path.
    pub plan_nanos: u128,
}

/// Background sampler + planner with bounded lookahead, driving one
/// [`PlanSession`].
pub struct StepPipeline {
    inner: Prefetcher<(StepPlan, PlanReport)>,
}

impl StepPipeline {
    /// Start planning: move `session` onto a background thread that
    /// samples `batch_size` examples per instance per step for `steps`
    /// steps and plans each with [`PlanOptions::auto`]. The instance
    /// count comes from the session's topology and the lookahead depth
    /// from its [`PipelineConfig`] (out-of-range depths are clamped
    /// into the documented bounds; use [`PipelineConfig::validate`] on
    /// user-supplied input first to surface an error instead — the
    /// CLI/config layers do).
    pub fn new(
        mut session: PlanSession,
        data_cfg: DatasetConfig,
        seed: u64,
        batch_size: usize,
        steps: usize,
    ) -> StepPipeline {
        let d = session.topology().instances;
        let depth = session.depth().clamp(1, MAX_PIPELINE_DEPTH);
        let inner = Prefetcher::new(
            data_cfg,
            seed,
            d,
            batch_size,
            steps,
            depth,
            move |mbs| {
                let plan = session.plan(mbs, PlanOptions::auto());
                let report = session
                    .report()
                    .cloned()
                    .expect("plan() always leaves a report");
                (plan, report)
            },
        );
        StepPipeline { inner }
    }

    /// Blocking fetch of the next planned step; `None` when the
    /// configured number of steps is exhausted.
    pub fn next(&self) -> Option<PlannedStep> {
        self.inner.next().map(|s| {
            let (plan, report) = s.plan;
            PlannedStep {
                minibatches: s.minibatches,
                plan,
                report,
                plan_nanos: s.plan_nanos,
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::topology::Topology;
    use crate::model::flops::PhaseKind;
    use crate::orchestrator::global::OrchestratorConfig;

    fn pipeline_with(
        steps: usize,
        seed: u64,
        config: PipelineConfig,
    ) -> StepPipeline {
        StepPipeline::new(
            PlanSession::new(
                OrchestratorConfig::orchmllm(7168.0),
                config,
                Topology::h100(4),
            ),
            DatasetConfig::tiny(2, 2),
            seed,
            6,
            steps,
        )
    }

    fn pipeline(steps: usize, seed: u64) -> StepPipeline {
        pipeline_with(steps, seed, PipelineConfig::default())
    }

    #[test]
    fn yields_the_configured_number_of_planned_steps() {
        let p = pipeline(5, 3);
        let mut n = 0;
        while let Some(step) = p.next() {
            assert_eq!(step.minibatches.len(), 4);
            assert_eq!(step.plan.d, 4);
            assert_eq!(step.plan.examples.len(), 4 * 6);
            n += 1;
        }
        assert_eq!(n, 5);
    }

    #[test]
    fn pipelined_plans_match_inline_session_planning() {
        // Same seed → the pipeline must produce exactly the plans an
        // inline session (same evolving history) would have computed —
        // the SPMD determinism every rank relies on.
        let p = pipeline(3, 7);
        let mut inline_session = PlanSession::with_defaults(
            OrchestratorConfig::orchmllm(7168.0),
            Topology::h100(4),
        );
        while let Some(step) = p.next() {
            let inline = inline_session
                .plan(&step.minibatches, PlanOptions::auto());
            assert_eq!(step.plan.llm.route, inline.llm.route);
            assert_eq!(
                step.plan.assignment(PhaseKind::Llm),
                inline.assignment(PhaseKind::Llm)
            );
            assert_eq!(step.plan.vision.out_route, inline.vision.out_route);
            assert_eq!(
                step.report.sources,
                inline_session.report().unwrap().sources,
                "pipelined provenance must match inline provenance"
            );
        }
    }

    #[test]
    fn deeper_pipelines_produce_the_same_plans() {
        // Depth is an execution knob, not an algorithm change: depths 1
        // and 3 must yield identical plan sequences for the same seed.
        let shallow = pipeline_with(
            4,
            11,
            PipelineConfig { depth: 1, ..PipelineConfig::default() },
        );
        let deep = pipeline_with(
            4,
            11,
            PipelineConfig { depth: 3, ..PipelineConfig::default() },
        );
        loop {
            match (shallow.next(), deep.next()) {
                (Some(a), Some(b)) => {
                    assert_eq!(a.minibatches, b.minibatches);
                    assert_eq!(a.plan.llm.route, b.plan.llm.route);
                    assert_eq!(
                        a.plan.assignment(PhaseKind::Llm),
                        b.plan.assignment(PhaseKind::Llm)
                    );
                }
                (None, None) => break,
                _ => panic!("pipelines yielded different step counts"),
            }
        }
    }

    #[test]
    fn early_drop_shuts_down_cleanly() {
        let p = pipeline_with(
            100,
            9,
            PipelineConfig { depth: 3, ..PipelineConfig::default() },
        );
        let _ = p.next();
        drop(p); // must join the planning thread without consuming all
    }

    #[test]
    fn records_planning_time_and_provenance() {
        let p = pipeline(2, 11);
        let step = p.next().unwrap();
        assert!(step.plan_nanos > 0);
        assert!(step.plan_nanos >= step.plan.compute_nanos);
        assert_eq!(step.report.step, 1);
        assert!(step.report.plan_nanos > 0);
        // The first planned step can never be warm.
        assert!(step.report.cold(), "{:?}", step.report);
    }

    #[test]
    fn config_validation_rejects_bad_depths() {
        let bad = PipelineConfig { depth: 0, plan_cache_size: 8 };
        assert!(bad.validate().is_err());
        let bad = PipelineConfig {
            depth: MAX_PIPELINE_DEPTH + 1,
            plan_cache_size: 8,
        };
        assert!(bad.validate().is_err());
        let ok = PipelineConfig { depth: 3, plan_cache_size: 0 };
        assert!(ok.validate().is_ok());
        let huge = PipelineConfig { depth: 2, plan_cache_size: 1 << 20 };
        assert!(huge.validate().is_err());
    }
}
