//! Deep-buffered step planning: the §6 overlap on the execution path.
//!
//! The paper prices dispatcher computation as free because it "overlaps
//! with the forward pass via prefetch" — this module is where that
//! actually happens. A [`StepPipeline`] owns a background planning
//! thread that samples the next steps' mini-batches and runs the full
//! [`Orchestrator`] plan (post-balancing, node-wise rearrangement,
//! composition) while the caller executes the current step. The channel
//! is bounded at `depth` planned-but-unconsumed steps (depth 1 =
//! classic double buffering; depth 2–3 absorb planning spikes — a cold
//! solve at d ≥ 1024, an allocator hiccup — without ever stalling the
//! consumer), so planning can never run unboundedly ahead.
//!
//! The planning thread reuses one [`StepScratch`] across steps, plans
//! the three phases concurrently, and carries a [`StepHistory`] so
//! steady-state steps go through the incremental path: warm-started
//! solves and sketch-cache replays instead of from-scratch planning.
//! Every rank runs an identical pipeline over the identical sampled
//! stream, and the incremental planner is a deterministic function of
//! that stream, so all ranks still agree on every plan without
//! communication (§5.2.1). Per-step planning latency is measured in
//! [`PlannedStep::plan_nanos`] and reported by the trainer and the
//! Table-2 bench.

use crate::balance::cache::DEFAULT_PLAN_CACHE_SIZE;
use crate::comm::topology::Topology;
use crate::data::loader::Prefetcher;
use crate::data::synth::{DatasetConfig, Example};

use super::global::{Orchestrator, StepHistory, StepPlan, StepScratch};

/// Upper bound on the pipeline depth: lookahead beyond a few steps only
/// costs memory (every in-flight step retains its mini-batches + plan).
pub const MAX_PIPELINE_DEPTH: usize = 8;

/// Lookahead + caching configuration for a [`StepPipeline`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PipelineConfig {
    /// Planned-but-unconsumed steps in flight (1 = double buffering;
    /// 2–3 absorb planning spikes at large d).
    pub depth: usize,
    /// Capacity of each planning cache — per phase and per step — in
    /// the pipeline's [`StepHistory`] (0 disables caching; warm-
    /// starting still applies).
    pub plan_cache_size: usize,
}

impl Default for PipelineConfig {
    fn default() -> PipelineConfig {
        PipelineConfig {
            depth: 1,
            plan_cache_size: DEFAULT_PLAN_CACHE_SIZE,
        }
    }
}

impl PipelineConfig {
    /// Validate CLI/config-supplied values, returning a printable error
    /// instead of clamping silently.
    pub fn validate(&self) -> Result<(), String> {
        if self.depth == 0 || self.depth > MAX_PIPELINE_DEPTH {
            return Err(format!(
                "pipeline depth must be in 1..={MAX_PIPELINE_DEPTH}, \
                 got {}",
                self.depth
            ));
        }
        if self.plan_cache_size > 65_536 {
            return Err(format!(
                "plan cache size {} is unreasonably large (max 65536)",
                self.plan_cache_size
            ));
        }
        Ok(())
    }
}

/// One planned step, handed to the executor.
pub struct PlannedStep {
    /// The sampled per-instance mini-batches the plan was built from.
    pub minibatches: Vec<Vec<Example>>,
    /// The full step plan (same object the simulator prices).
    pub plan: StepPlan,
    /// Planning wall-time — time spent *off* the critical path.
    pub plan_nanos: u128,
}

/// Background sampler + planner with bounded lookahead.
pub struct StepPipeline {
    inner: Prefetcher<StepPlan>,
}

impl StepPipeline {
    /// Start planning: `d` instances × `batch_size` examples per step
    /// for `steps` steps, at most `depth` planned steps in flight
    /// (caching at the default capacity).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        orch: Orchestrator,
        topo: Topology,
        data_cfg: DatasetConfig,
        seed: u64,
        d: usize,
        batch_size: usize,
        steps: usize,
        depth: usize,
    ) -> StepPipeline {
        StepPipeline::with_config(
            orch,
            topo,
            data_cfg,
            seed,
            d,
            batch_size,
            steps,
            PipelineConfig { depth, ..PipelineConfig::default() },
        )
    }

    /// Start planning with an explicit lookahead/caching configuration.
    /// Out-of-range values are clamped into the documented bounds; use
    /// [`PipelineConfig::validate`] on user-supplied input first to
    /// surface an error instead (the CLI/config layers do).
    #[allow(clippy::too_many_arguments)]
    pub fn with_config(
        orch: Orchestrator,
        topo: Topology,
        data_cfg: DatasetConfig,
        seed: u64,
        d: usize,
        batch_size: usize,
        steps: usize,
        config: PipelineConfig,
    ) -> StepPipeline {
        let mut scratch = StepScratch::default();
        let mut history =
            StepHistory::new(config.plan_cache_size.min(65_536));
        let inner = Prefetcher::new(
            data_cfg,
            seed,
            d,
            batch_size,
            steps,
            config.depth.clamp(1, MAX_PIPELINE_DEPTH),
            move |mbs| {
                orch.plan_step_incremental(
                    &topo,
                    mbs,
                    &mut scratch,
                    &mut history,
                )
            },
        );
        StepPipeline { inner }
    }

    /// Blocking fetch of the next planned step; `None` when the
    /// configured number of steps is exhausted.
    pub fn next(&self) -> Option<PlannedStep> {
        self.inner.next().map(|s| PlannedStep {
            minibatches: s.minibatches,
            plan: s.plan,
            plan_nanos: s.plan_nanos,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::flops::PhaseKind;
    use crate::orchestrator::global::OrchestratorConfig;

    fn pipeline_with(
        steps: usize,
        seed: u64,
        config: PipelineConfig,
    ) -> StepPipeline {
        StepPipeline::with_config(
            Orchestrator::new(OrchestratorConfig::orchmllm(7168.0)),
            Topology::h100(4),
            DatasetConfig::tiny(2, 2),
            seed,
            4,
            6,
            steps,
            config,
        )
    }

    fn pipeline(steps: usize, seed: u64) -> StepPipeline {
        pipeline_with(steps, seed, PipelineConfig::default())
    }

    #[test]
    fn yields_the_configured_number_of_planned_steps() {
        let p = pipeline(5, 3);
        let mut n = 0;
        while let Some(step) = p.next() {
            assert_eq!(step.minibatches.len(), 4);
            assert_eq!(step.plan.d, 4);
            assert_eq!(step.plan.examples.len(), 4 * 6);
            n += 1;
        }
        assert_eq!(n, 5);
    }

    #[test]
    fn pipelined_plans_match_inline_incremental_planning() {
        // Same seed → the pipeline must produce exactly the plans an
        // inline incremental planner (same evolving history) would have
        // computed — the SPMD determinism every rank relies on.
        let p = pipeline(3, 7);
        let orch = Orchestrator::new(OrchestratorConfig::orchmllm(7168.0));
        let topo = Topology::h100(4);
        let mut scratch = StepScratch::default();
        let mut history = StepHistory::default();
        while let Some(step) = p.next() {
            let inline = orch.plan_step_incremental(
                &topo,
                &step.minibatches,
                &mut scratch,
                &mut history,
            );
            assert_eq!(step.plan.llm.route, inline.llm.route);
            assert_eq!(
                step.plan.assignment(PhaseKind::Llm),
                inline.assignment(PhaseKind::Llm)
            );
            assert_eq!(step.plan.vision.out_route, inline.vision.out_route);
        }
    }

    #[test]
    fn deeper_pipelines_produce_the_same_plans() {
        // Depth is an execution knob, not an algorithm change: depths 1
        // and 3 must yield identical plan sequences for the same seed.
        let shallow = pipeline_with(
            4,
            11,
            PipelineConfig { depth: 1, ..PipelineConfig::default() },
        );
        let deep = pipeline_with(
            4,
            11,
            PipelineConfig { depth: 3, ..PipelineConfig::default() },
        );
        loop {
            match (shallow.next(), deep.next()) {
                (Some(a), Some(b)) => {
                    assert_eq!(a.minibatches, b.minibatches);
                    assert_eq!(a.plan.llm.route, b.plan.llm.route);
                    assert_eq!(
                        a.plan.assignment(PhaseKind::Llm),
                        b.plan.assignment(PhaseKind::Llm)
                    );
                }
                (None, None) => break,
                _ => panic!("pipelines yielded different step counts"),
            }
        }
    }

    #[test]
    fn early_drop_shuts_down_cleanly() {
        let p = pipeline_with(
            100,
            9,
            PipelineConfig { depth: 3, ..PipelineConfig::default() },
        );
        let _ = p.next();
        drop(p); // must join the planning thread without consuming all
    }

    #[test]
    fn records_planning_time() {
        let p = pipeline(1, 11);
        let step = p.next().unwrap();
        assert!(step.plan_nanos > 0);
        assert!(step.plan_nanos >= step.plan.compute_nanos);
    }

    #[test]
    fn config_validation_rejects_bad_depths() {
        let bad = PipelineConfig { depth: 0, plan_cache_size: 8 };
        assert!(bad.validate().is_err());
        let bad = PipelineConfig {
            depth: MAX_PIPELINE_DEPTH + 1,
            plan_cache_size: 8,
        };
        assert!(bad.validate().is_err());
        let ok = PipelineConfig { depth: 3, plan_cache_size: 0 };
        assert!(ok.validate().is_ok());
        let huge = PipelineConfig { depth: 2, plan_cache_size: 1 << 20 };
        assert!(huge.validate().is_err());
    }
}
