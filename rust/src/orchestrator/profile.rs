//! Shape-profile store: observed `Sketch` → length-histogram
//! distributions per phase.
//!
//! The plan caches answer "have I seen *exactly* this batch before?";
//! the profile store answers the softer question "what does this job's
//! shape distribution look like?" — which sketches recur, how often,
//! and what length histogram each one carries. Profiles ride along in
//! the plan archive (orchestrator/archive.rs), so a warm-started
//! process inherits not just cached plans but a durable picture of the
//! workload that produced them: auto-selection heuristics, capacity
//! tuning, and post-hoc audits can all read it without replaying the
//! run.
//!
//! Observation is **opt-in** (sessions record only when archiving is
//! enabled): the steady-state planning path is gated at zero heap
//! allocations per warm step (rust/tests/plan_allocations.rs), and
//! first-sighting a sketch inserts into a `Vec`.

use crate::balance::cache::{Sketch, SKETCH_BUCKETS};
use crate::data::synth::Example;
use crate::model::flops::PhaseKind;

/// Aggregated shape statistics for one recurring sketch.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShapeProfile {
    /// Steps on which this sketch was observed.
    pub count: u64,
    /// log₂ length histogram, same bucketing as [`Sketch`]
    /// ([`SKETCH_BUCKETS`] buckets), summed over observations.
    pub hist: [u64; SKETCH_BUCKETS],
    /// Sum of all observed lengths (for mean length).
    pub total_len: u64,
    /// Shortest length ever observed under this sketch.
    pub min_len: u64,
    /// Longest length ever observed under this sketch.
    pub max_len: u64,
}

impl ShapeProfile {
    fn new() -> ShapeProfile {
        ShapeProfile {
            count: 0,
            hist: [0; SKETCH_BUCKETS],
            total_len: 0,
            min_len: u64::MAX,
            max_len: 0,
        }
    }

    fn observe(&mut self, lens: impl Iterator<Item = usize>) {
        self.count += 1;
        for l in lens {
            self.hist[bucket(l)] += 1;
            self.total_len += l as u64;
            self.min_len = self.min_len.min(l as u64);
            self.max_len = self.max_len.max(l as u64);
        }
    }

    /// Total sequences across all observations.
    pub fn sequences(&self) -> u64 {
        self.hist.iter().sum()
    }

    /// Mean observed length (0.0 before any observation).
    pub fn mean_len(&self) -> f64 {
        let n = self.sequences();
        if n == 0 {
            0.0
        } else {
            self.total_len as f64 / n as f64
        }
    }
}

/// Same bucketing rule as `balance::cache::bucket` (private there):
/// bucket 0 for zero lengths, floor(log2) + 1 otherwise, last bucket
/// absorbs over-range. A unit test pins the agreement via `Sketch`.
#[inline]
fn bucket(l: usize) -> usize {
    ((usize::BITS - l.leading_zeros()) as usize).min(SKETCH_BUCKETS - 1)
}

/// Per-phase map of observed sketches to their shape profiles.
///
/// Backed by small sorted-insertion `Vec`s — a training job recurs over
/// a handful of shapes (that is the premise of the plan cache), so the
/// store stays tiny and scan-friendly.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ShapeProfileStore {
    /// Indexed by phase: 0 = vision, 1 = audio, 2 = llm (the
    /// [`PhaseKind`] order used throughout the orchestrator).
    phases: [Vec<(u64, ShapeProfile)>; 3],
    /// Steps observed (each step touches all three phases).
    steps: u64,
}

impl ShapeProfileStore {
    pub fn new() -> ShapeProfileStore {
        ShapeProfileStore::default()
    }

    /// Record one planned step: derive each phase's active lengths from
    /// the plan's examples (the same derivation the planner sketches
    /// with) and fold them into that phase's profile.
    pub fn observe_step(&mut self, examples: &[Example], d: usize) {
        self.steps += 1;
        self.observe_phase(
            PhaseKind::Vision,
            examples.iter().map(|e| e.vis_len),
            d,
        );
        self.observe_phase(
            PhaseKind::Audio,
            examples.iter().map(|e| e.aud_len),
            d,
        );
        self.observe_phase(
            PhaseKind::Llm,
            examples.iter().map(|e| e.llm_len()),
            d,
        );
    }

    /// Fold one phase's length stream into its sketch-keyed profile.
    pub fn observe_phase(
        &mut self,
        phase: PhaseKind,
        lens: impl Iterator<Item = usize> + Clone,
        d: usize,
    ) {
        let sketch = Sketch::of_iter(lens.clone(), d);
        let v = &mut self.phases[phase_index(phase)];
        let profile = match v.iter_mut().find(|(s, _)| *s == sketch.0) {
            Some((_, p)) => p,
            None => {
                v.push((sketch.0, ShapeProfile::new()));
                &mut v.last_mut().expect("just pushed").1
            }
        };
        profile.observe(lens);
    }

    /// Steps observed so far.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Distinct sketches observed for a phase.
    pub fn distinct(&self, phase: PhaseKind) -> usize {
        self.phases[phase_index(phase)].len()
    }

    /// Iterate one phase's `(sketch, profile)` pairs in observation
    /// order (serialization + reporting).
    pub fn phase_profiles(
        &self,
        phase: PhaseKind,
    ) -> impl Iterator<Item = (Sketch, &ShapeProfile)> {
        self.phases[phase_index(phase)]
            .iter()
            .map(|(s, p)| (Sketch(*s), p))
    }

    /// Total profile entries across phases.
    pub fn len(&self) -> usize {
        self.phases.iter().map(Vec::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Rebuild from serialized parts (archive load).
    pub fn restore(
        steps: u64,
        phases: [Vec<(u64, ShapeProfile)>; 3],
    ) -> ShapeProfileStore {
        ShapeProfileStore { phases, steps }
    }

    /// Merge another store into this one (a rejoined world folding a
    /// peer's archive into its own observations).
    pub fn merge(&mut self, other: &ShapeProfileStore) {
        self.steps += other.steps;
        for (mine, theirs) in self.phases.iter_mut().zip(other.phases.iter()) {
            for (sketch, profile) in theirs {
                match mine.iter_mut().find(|(s, _)| s == sketch) {
                    Some((_, p)) => {
                        p.count += profile.count;
                        p.total_len += profile.total_len;
                        p.min_len = p.min_len.min(profile.min_len);
                        p.max_len = p.max_len.max(profile.max_len);
                        for (a, b) in p.hist.iter_mut().zip(profile.hist.iter())
                        {
                            *a += b;
                        }
                    }
                    None => mine.push((*sketch, profile.clone())),
                }
            }
        }
    }
}

/// Stable phase indexing for the store (and its archive payload).
pub fn phase_index(phase: PhaseKind) -> usize {
    match phase {
        PhaseKind::Vision => 0,
        PhaseKind::Audio => 1,
        PhaseKind::Llm => 2,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::Task;

    fn ex(id: usize, vis: usize, aud: usize, text: usize) -> Example {
        Example {
            id,
            task: Task::AvDialogue,
            vis_len: vis,
            aud_len: aud,
            text_len: text,
            vis_tokens: vis / 2,
            aud_tokens: aud / 2,
        }
    }

    #[test]
    fn bucket_agrees_with_sketch_bucketing() {
        // Same lengths → same sketch means the private bucket fn in
        // cache.rs and ours agree; probe the boundary values.
        for l in [0usize, 1, 2, 3, 4, 65_535, 65_536, 1 << 20] {
            let a = Sketch::of(&[l], 1);
            let b = Sketch::of_iter(std::iter::once(l), 1);
            assert_eq!(a, b);
            assert!(bucket(l) < SKETCH_BUCKETS);
        }
    }

    #[test]
    fn recurring_shapes_aggregate_under_one_sketch() {
        let mut store = ShapeProfileStore::new();
        let batch = vec![ex(0, 8, 4, 100), ex(1, 16, 0, 50)];
        store.observe_step(&batch, 2);
        store.observe_step(&batch, 2);
        assert_eq!(store.steps(), 2);
        assert_eq!(store.distinct(PhaseKind::Llm), 1);
        let (_, p) = store.phase_profiles(PhaseKind::Llm).next().unwrap();
        assert_eq!(p.count, 2);
        assert_eq!(p.sequences(), 4);
        let llm0 = (100 + 8 / 2 + 4 / 2) as u64;
        let llm1 = (50 + 16 / 2) as u64;
        assert_eq!(p.total_len, 2 * (llm0 + llm1));
        assert_eq!(p.min_len, llm1.min(llm0));
        assert_eq!(p.max_len, llm1.max(llm0));
    }

    #[test]
    fn different_shapes_get_distinct_profiles() {
        let mut store = ShapeProfileStore::new();
        store.observe_step(&[ex(0, 8, 4, 100)], 1);
        store.observe_step(&[ex(0, 8, 4, 100), ex(1, 8, 4, 100)], 1);
        assert_eq!(store.distinct(PhaseKind::Vision), 2);
    }

    #[test]
    fn merge_folds_counts() {
        let mut a = ShapeProfileStore::new();
        let mut b = ShapeProfileStore::new();
        let batch = vec![ex(0, 8, 4, 100)];
        a.observe_step(&batch, 1);
        b.observe_step(&batch, 1);
        b.observe_step(&[ex(0, 32, 4, 100)], 1);
        a.merge(&b);
        assert_eq!(a.steps(), 3);
        assert_eq!(a.distinct(PhaseKind::Vision), 2);
        let (_, p) = a.phase_profiles(PhaseKind::Vision).next().unwrap();
        assert_eq!(p.count, 2, "shared sketch merges counts");
    }

    #[test]
    fn mean_len_is_sane() {
        let p = ShapeProfile::new();
        assert_eq!(p.mean_len(), 0.0);
        let mut store = ShapeProfileStore::new();
        store.observe_step(&[ex(0, 10, 10, 10)], 1);
        let (_, p) = store.phase_profiles(PhaseKind::Audio).next().unwrap();
        assert_eq!(p.mean_len(), 10.0);
    }
}
