//! The paper's system layer: Batch Post-Balancing Dispatcher (§5) and
//! MLLM Global Orchestrator (§6).
//!
//! * [`rearrangement`] — the rearrangement Π as explicit data, with
//!   inverse and composition (the algebra behind Rearrangement
//!   Composition);
//! * [`dispatcher`] — one phase's dispatcher: a pluggable
//!   [`crate::balance::Balancer`] + node-wise rearrangement +
//!   communicator choice;
//! * [`global`] — the MLLM Global Orchestrator: per-phase dispatchers
//!   planned concurrently on reusable scratch, subsequence assembly
//!   bookkeeping, rearrangement composition, and the full
//!   [`global::StepPlan`] shared by the simulator and trainer;
//! * [`pipeline`] — the double-buffered [`pipeline::StepPipeline`] that
//!   plans step *t+1* while step *t* executes (the §6 overlap on the
//!   execution path).

pub mod dispatcher;
pub mod global;
pub mod pipeline;
pub mod rearrangement;

pub use dispatcher::{Communicator, Dispatcher, DispatchPlan, PhaseHistory};
pub use global::{
    Orchestrator, OrchestratorConfig, StepHistory, StepPlan, StepScratch,
};
pub use pipeline::{PipelineConfig, PlannedStep, StepPipeline};
pub use rearrangement::Rearrangement;
