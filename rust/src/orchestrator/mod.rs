//! The paper's system layer: Batch Post-Balancing Dispatcher (§5) and
//! MLLM Global Orchestrator (§6).
//!
//! * [`rearrangement`] — the rearrangement Π as explicit data, with
//!   inverse and composition (the algebra behind Rearrangement
//!   Composition);
//! * [`dispatcher`] — one phase's dispatcher: post-balancing algorithm +
//!   node-wise rearrangement + communicator choice;
//! * [`global`] — the MLLM Global Orchestrator: per-phase dispatchers,
//!   subsequence assembly bookkeeping, rearrangement composition, and
//!   the full [`global::StepPlan`] shared by the simulator and trainer.

pub mod dispatcher;
pub mod global;
pub mod rearrangement;

pub use dispatcher::{Communicator, Dispatcher, DispatchPlan};
pub use global::{Orchestrator, OrchestratorConfig, StepPlan};
pub use rearrangement::Rearrangement;
