//! The paper's system layer: Batch Post-Balancing Dispatcher (§5) and
//! MLLM Global Orchestrator (§6).
//!
//! * [`rearrangement`] — the rearrangement Π as explicit data, with
//!   inverse and composition (the algebra behind Rearrangement
//!   Composition);
//! * [`dispatcher`] — one phase's dispatcher: a pluggable
//!   [`crate::balance::Balancer`] + node-wise rearrangement +
//!   communicator choice;
//! * [`global`] — the MLLM Global Orchestrator: per-phase dispatchers
//!   planned concurrently on reusable scratch, subsequence assembly
//!   bookkeeping, rearrangement composition, and the full
//!   [`global::StepPlan`] shared by the simulator and trainer;
//! * [`session`] — the **public planning surface**: a stateful
//!   [`session::PlanSession`] owning scratches, histories, and plan
//!   caches, with one entry point ([`session::PlanSession::plan`] +
//!   [`session::PlanOptions`]) and provenance-rich
//!   [`session::PlanReport`]s;
//! * [`pipeline`] — the deep-buffered [`pipeline::StepPipeline`] that
//!   drives a session on a background thread, planning step *t+1*
//!   while step *t* executes (the §6 overlap on the execution path);
//! * [`archive`] — the persistent plan archive: versioned, checksummed
//!   serialization of a session's caches, shape profiles, and a
//!   content-addressed causal log of emitted plans, so a fresh process
//!   warm-starts bit-identically from a prior run;
//! * [`profile`] — the shape-profile store archived alongside the
//!   caches: observed [`crate::balance::cache::Sketch`] →
//!   length-histogram distributions per phase.

pub mod archive;
pub mod dispatcher;
pub mod global;
pub mod pipeline;
pub mod profile;
pub mod rearrangement;
pub mod session;

pub use archive::{
    Archive, ArchiveError, ExportInputs, Manifest, PlanLog, StatsSummary,
    WarmStart,
};
pub use dispatcher::{
    Communicator, DispatchOptions, Dispatcher, DispatchPlan, PhaseHistory,
};
pub use global::{
    Orchestrator, OrchestratorConfig, StepHistory, StepPlan, StepScratch,
};
pub use pipeline::{PipelineConfig, PlannedStep, StepPipeline};
pub use rearrangement::Rearrangement;
pub use session::{
    PlanMode, PlanOptions, PlanReport, PlanSession, PlanTimeStats,
    ResolvedMode, SessionStats, SolveStrategy,
};
