//! Persistent plan archive: planning state that outlives the process.
//!
//! Everything a [`crate::orchestrator::session::PlanSession`] learns —
//! the three phase-level solve caches, the step-level plan cache, the
//! shape-profile store ([`super::profile`]), and a content-addressed
//! log of every emitted [`StepPlan`] — serializes to a directory:
//!
//! ```text
//! <archive>/
//!   manifest.json    versioned, self-hashed provenance + payload sha256s
//!   caches.bin       phase + step PlanCache contents (LRU state intact)
//!   plans.bin        causal chain of content-addressed StepPlans
//!   profiles.bin     Sketch → length-histogram distributions per phase
//! ```
//!
//! A fresh process that loads the archive warm-starts **bit-identically**:
//! a recurring step hits the restored step cache and replays the
//! archived plan object itself, so the first warm step's plan hashes to
//! the same content id the exporting process archived (pinned by a
//! two-process test). Every plan entry carries a causal `prev` link —
//! the CCOS-style immutable chain — so any training step is replayable
//! and auditable after the fact.
//!
//! Format rules (see DESIGN.md §Plan Archive):
//!
//! * payloads are hand-rolled, length-prefixed, little-endian codecs
//!   (no crates.io), each with an 8-byte magic + kind + format version;
//! * `manifest.json` carries a semver `schema_version`; loaders accept
//!   the same **major** and ignore unknown fields (minor additions are
//!   compatible by construction);
//! * `manifest_sha256` is the digest of the manifest's canonical JSON
//!   (sorted keys, 1-space pretty form) with the `manifest_sha256`
//!   field itself removed;
//! * decode never panics: corruption, truncation, and version skew all
//!   surface as a typed [`ArchiveError`].

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{SystemTime, UNIX_EPOCH};

use crate::balance::cache::{PlanCache, SKETCH_BUCKETS};
use crate::balance::incremental::PlanSource;
use crate::balance::types::{Assignment, ExampleRef};
use crate::comm::costmodel::CollectiveCost;
use crate::comm::topology::Topology;
use crate::data::synth::{Example, Task};
use crate::model::flops::PhaseKind;
use crate::util::json::Json;
use crate::util::sha256;

use super::dispatcher::{Communicator, DispatchPlan, PhaseHistory};
use super::global::{EncoderPlan, OrchestratorConfig, StepHistory, StepPlan};
use super::profile::{ShapeProfile, ShapeProfileStore};
use super::rearrangement::Rearrangement;

/// Archive schema version (semver). Compat policy: loaders accept the
/// same major, any minor/patch; unknown manifest fields are ignored.
pub const SCHEMA_VERSION: &str = "1.0.0";
const SUPPORTED_MAJOR: u64 = 1;

const MANIFEST: &str = "manifest.json";
const PAYLOAD_CACHES: &str = "caches.bin";
const PAYLOAD_PLANS: &str = "plans.bin";
const PAYLOAD_PROFILES: &str = "profiles.bin";

/// 8-byte payload magic, shared by all binary payloads.
const MAGIC: [u8; 8] = *b"OMLLMAR1";
/// Per-payload kind tags (after the magic).
const KIND_CACHES: u16 = 1;
const KIND_PLANS: u16 = 2;
const KIND_PROFILES: u16 = 3;
/// Binary payload format version (independent of the manifest semver).
const PAYLOAD_VERSION: u16 = 1;

// ---------------------------------------------------------------------------
// Errors
// ---------------------------------------------------------------------------

/// Typed archive failure. Decode paths return these — never panic — so
/// a corrupt or future-versioned archive degrades loudly but safely.
#[derive(Debug)]
pub enum ArchiveError {
    /// Filesystem failure reading or writing an archive member.
    Io { path: PathBuf, err: io::Error },
    /// A payload ended before its declared contents did.
    Truncated { section: &'static str },
    /// Structurally invalid bytes (bad magic, unknown tag, bad JSON…).
    Malformed { section: &'static str, detail: String },
    /// Payload or plan-blob bytes do not hash to their recorded digest.
    ChecksumMismatch {
        name: String,
        expected: String,
        actual: String,
    },
    /// Manifest written by an incompatible (different-major) schema.
    SchemaVersion { found: String, supported: &'static str },
    /// Manifest references a payload file that is missing on disk.
    MissingPayload { name: String },
}

impl fmt::Display for ArchiveError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ArchiveError::Io { path, err } => {
                write!(f, "archive io error at {}: {err}", path.display())
            }
            ArchiveError::Truncated { section } => {
                write!(f, "archive payload truncated in {section}")
            }
            ArchiveError::Malformed { section, detail } => {
                write!(f, "malformed archive {section}: {detail}")
            }
            ArchiveError::ChecksumMismatch { name, expected, actual } => {
                write!(
                    f,
                    "checksum mismatch for {name}: recorded {expected}, \
                     bytes hash to {actual}"
                )
            }
            ArchiveError::SchemaVersion { found, supported } => {
                write!(
                    f,
                    "archive schema version {found} is not supported \
                     (this build reads major {supported})"
                )
            }
            ArchiveError::MissingPayload { name } => {
                write!(f, "archive payload {name} is missing")
            }
        }
    }
}

impl std::error::Error for ArchiveError {}

fn io_err(path: &Path, err: io::Error) -> ArchiveError {
    ArchiveError::Io { path: path.to_path_buf(), err }
}

// ---------------------------------------------------------------------------
// Wire codec (length-prefixed, little-endian, versioned)
// ---------------------------------------------------------------------------

/// Byte-stream encoder for archive payloads.
#[derive(Default)]
pub(crate) struct Enc {
    pub buf: Vec<u8>,
}

impl Enc {
    fn new() -> Enc {
        Enc::default()
    }

    fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn put_u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn put_u128(&mut self, v: u128) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn put_f64(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }

    fn put_usize(&mut self, v: usize) {
        self.put_u64(v as u64);
    }

    fn put_raw(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    fn put_bytes(&mut self, bytes: &[u8]) {
        self.put_u64(bytes.len() as u64);
        self.put_raw(bytes);
    }
}

/// Bounds-checked decoder: every read is fallible, and declared lengths
/// are validated against the remaining bytes *before* any allocation,
/// so a corrupt length word cannot OOM or panic.
pub(crate) struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
    section: &'static str,
}

impl<'a> Dec<'a> {
    fn new(buf: &'a [u8], section: &'static str) -> Dec<'a> {
        Dec { buf, pos: 0, section }
    }

    fn truncated(&self) -> ArchiveError {
        ArchiveError::Truncated { section: self.section }
    }

    fn malformed(&self, detail: String) -> ArchiveError {
        ArchiveError::Malformed { section: self.section, detail }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], ArchiveError> {
        if self.remaining() < n {
            return Err(self.truncated());
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn take_u8(&mut self) -> Result<u8, ArchiveError> {
        Ok(self.take(1)?[0])
    }

    fn take_u16(&mut self) -> Result<u16, ArchiveError> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn take_u64(&mut self) -> Result<u64, ArchiveError> {
        let b = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_le_bytes(a))
    }

    fn take_u128(&mut self) -> Result<u128, ArchiveError> {
        let b = self.take(16)?;
        let mut a = [0u8; 16];
        a.copy_from_slice(b);
        Ok(u128::from_le_bytes(a))
    }

    fn take_f64(&mut self) -> Result<f64, ArchiveError> {
        Ok(f64::from_bits(self.take_u64()?))
    }

    fn take_usize(&mut self) -> Result<usize, ArchiveError> {
        let v = self.take_u64()?;
        usize::try_from(v).map_err(|_| {
            self.malformed(format!("value {v} overflows usize"))
        })
    }

    /// Read an element count whose elements occupy at least `elem_min`
    /// bytes each; rejects counts the remaining bytes cannot hold.
    fn take_len(&mut self, elem_min: usize) -> Result<usize, ArchiveError> {
        let n = self.take_usize()?;
        if elem_min > 0 && n > self.remaining() / elem_min {
            return Err(self.truncated());
        }
        Ok(n)
    }

    fn take_bytes(&mut self) -> Result<&'a [u8], ArchiveError> {
        let n = self.take_len(1)?;
        self.take(n)
    }

    fn take_digest(&mut self) -> Result<[u8; 32], ArchiveError> {
        let b = self.take(32)?;
        let mut a = [0u8; 32];
        a.copy_from_slice(b);
        Ok(a)
    }

    /// Every payload decoder ends with this: trailing garbage is as
    /// malformed as missing bytes.
    fn finish(&self) -> Result<(), ArchiveError> {
        if self.remaining() != 0 {
            return Err(self.malformed(format!(
                "{} trailing bytes after payload contents",
                self.remaining()
            )));
        }
        Ok(())
    }
}

fn put_header(e: &mut Enc, kind: u16) {
    e.put_raw(&MAGIC);
    e.put_u16(kind);
    e.put_u16(PAYLOAD_VERSION);
}

fn check_header(d: &mut Dec<'_>, kind: u16) -> Result<(), ArchiveError> {
    let magic = d.take(8)?;
    if magic != MAGIC {
        return Err(d.malformed("bad payload magic".to_string()));
    }
    let got_kind = d.take_u16()?;
    if got_kind != kind {
        return Err(d.malformed(format!(
            "payload kind {got_kind} where {kind} was expected"
        )));
    }
    let version = d.take_u16()?;
    if version != PAYLOAD_VERSION {
        return Err(d.malformed(format!(
            "payload format version {version} (this build reads \
             {PAYLOAD_VERSION})"
        )));
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Plan codecs
// ---------------------------------------------------------------------------

fn task_code(t: Task) -> u8 {
    match t {
        Task::Asr => 0,
        Task::SpokenQa => 1,
        Task::Caption => 2,
        Task::Vqa => 3,
        Task::TextOnly => 4,
        Task::AvDialogue => 5,
    }
}

fn task_from(d: &Dec<'_>, code: u8) -> Result<Task, ArchiveError> {
    Ok(match code {
        0 => Task::Asr,
        1 => Task::SpokenQa,
        2 => Task::Caption,
        3 => Task::Vqa,
        4 => Task::TextOnly,
        5 => Task::AvDialogue,
        _ => return Err(d.malformed(format!("unknown task code {code}"))),
    })
}

fn source_code(s: PlanSource) -> u8 {
    match s {
        PlanSource::Cold => 0,
        PlanSource::Warm => 1,
        PlanSource::Cached => 2,
    }
}

fn source_from(d: &Dec<'_>, code: u8) -> Result<PlanSource, ArchiveError> {
    Ok(match code {
        0 => PlanSource::Cold,
        1 => PlanSource::Warm,
        2 => PlanSource::Cached,
        _ => {
            return Err(d.malformed(format!("unknown plan source {code}")))
        }
    })
}

fn put_example(e: &mut Enc, x: &Example) {
    e.put_usize(x.id);
    e.put_u8(task_code(x.task));
    e.put_usize(x.vis_len);
    e.put_usize(x.aud_len);
    e.put_usize(x.text_len);
    e.put_usize(x.vis_tokens);
    e.put_usize(x.aud_tokens);
}

fn take_example(d: &mut Dec<'_>) -> Result<Example, ArchiveError> {
    let id = d.take_usize()?;
    let code = d.take_u8()?;
    let task = task_from(d, code)?;
    Ok(Example {
        id,
        task,
        vis_len: d.take_usize()?,
        aud_len: d.take_usize()?,
        text_len: d.take_usize()?,
        vis_tokens: d.take_usize()?,
        aud_tokens: d.take_usize()?,
    })
}

fn put_usizes(e: &mut Enc, v: &[usize]) {
    e.put_usize(v.len());
    for &x in v {
        e.put_usize(x);
    }
}

fn take_usizes(d: &mut Dec<'_>) -> Result<Vec<usize>, ArchiveError> {
    let n = d.take_len(8)?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(d.take_usize()?);
    }
    Ok(out)
}

fn put_assignment(e: &mut Enc, a: &Assignment) {
    e.put_usize(a.len());
    for batch in a {
        e.put_usize(batch.len());
        for r in batch {
            e.put_usize(r.id);
            e.put_usize(r.len);
        }
    }
}

fn take_assignment(d: &mut Dec<'_>) -> Result<Assignment, ArchiveError> {
    let n = d.take_len(8)?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let m = d.take_len(16)?;
        let mut batch = Vec::with_capacity(m);
        for _ in 0..m {
            batch.push(ExampleRef {
                id: d.take_usize()?,
                len: d.take_usize()?,
            });
        }
        out.push(batch);
    }
    Ok(out)
}

fn put_rearrangement(e: &mut Enc, r: &Rearrangement) {
    put_usizes(e, &r.from);
    put_usizes(e, &r.to);
}

fn take_rearrangement(d: &mut Dec<'_>) -> Result<Rearrangement, ArchiveError> {
    Ok(Rearrangement { from: take_usizes(d)?, to: take_usizes(d)? })
}

fn put_cost(e: &mut Enc, c: &CollectiveCost) {
    e.put_f64(c.seconds);
    e.put_f64(c.peak_bytes);
}

fn take_cost(d: &mut Dec<'_>) -> Result<CollectiveCost, ArchiveError> {
    Ok(CollectiveCost { seconds: d.take_f64()?, peak_bytes: d.take_f64()? })
}

fn put_dispatch(e: &mut Enc, p: &DispatchPlan) {
    put_assignment(e, &p.assignment);
    put_rearrangement(e, &p.route);
    put_usizes(e, &p.nodewise_perm);
    put_cost(e, &p.comm);
    e.put_f64(p.peak_bytes);
    e.put_u128(p.compute_nanos);
    e.put_u8(source_code(p.source));
    e.put_usize(p.repair_moves);
}

fn take_dispatch(d: &mut Dec<'_>) -> Result<DispatchPlan, ArchiveError> {
    let assignment = take_assignment(d)?;
    let route = take_rearrangement(d)?;
    let nodewise_perm = take_usizes(d)?;
    let comm = take_cost(d)?;
    let peak_bytes = d.take_f64()?;
    let compute_nanos = d.take_u128()?;
    let code = d.take_u8()?;
    let source = source_from(d, code)?;
    Ok(DispatchPlan {
        assignment,
        route,
        nodewise_perm,
        comm,
        peak_bytes,
        compute_nanos,
        source,
        repair_moves: d.take_usize()?,
    })
}

fn put_encoder(e: &mut Enc, p: &EncoderPlan) {
    put_dispatch(e, &p.plan);
    put_rearrangement(e, &p.out_route);
    put_cost(e, &p.out_comm);
    e.put_f64(p.out_inter_node_bytes);
}

fn take_encoder(d: &mut Dec<'_>) -> Result<EncoderPlan, ArchiveError> {
    Ok(EncoderPlan {
        plan: take_dispatch(d)?,
        out_route: take_rearrangement(d)?,
        out_comm: take_cost(d)?,
        out_inter_node_bytes: d.take_f64()?,
    })
}

/// Canonical byte serialization of a [`StepPlan`] — the content that
/// the plan log's sha256 ids address. Deterministic: a bit-identical
/// plan always encodes to the same bytes.
pub fn encode_step_plan(p: &StepPlan) -> Vec<u8> {
    let mut e = Enc::new();
    e.put_usize(p.d);
    e.put_usize(p.examples.len());
    for x in &p.examples {
        put_example(&mut e, x);
    }
    put_usizes(&mut e, &p.home);
    put_encoder(&mut e, &p.vision);
    put_encoder(&mut e, &p.audio);
    put_dispatch(&mut e, &p.llm);
    e.put_u128(p.compute_nanos);
    e.buf
}

fn take_step_plan(d: &mut Dec<'_>) -> Result<StepPlan, ArchiveError> {
    let dd = d.take_usize()?;
    let n = d.take_len(8)?;
    let mut examples = Vec::with_capacity(n);
    for _ in 0..n {
        examples.push(take_example(d)?);
    }
    Ok(StepPlan {
        d: dd,
        examples,
        home: take_usizes(d)?,
        vision: take_encoder(d)?,
        audio: take_encoder(d)?,
        llm: take_dispatch(d)?,
        compute_nanos: d.take_u128()?,
    })
}

/// Decode a standalone plan blob (as stored in `plans.bin`).
pub fn decode_step_plan(bytes: &[u8]) -> Result<StepPlan, ArchiveError> {
    let mut d = Dec::new(bytes, "plan blob");
    let plan = take_step_plan(&mut d)?;
    d.finish()?;
    Ok(plan)
}

// ---------------------------------------------------------------------------
// Content-addressed plan log (CCOS-style causal chain)
// ---------------------------------------------------------------------------

/// One archived plan emission: which step, when, and the causal link to
/// the plan emitted just before it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlanLogEntry {
    /// Session step number the plan was emitted for.
    pub step: u64,
    /// Unix seconds at record time (drives `archive gc` age pruning).
    pub unix_secs: u64,
    /// Content id: sha256 of the plan's canonical encoding.
    pub id: [u8; 32],
    /// Content id of the previously emitted plan (`None` for the first
    /// entry of a cold-started chain).
    pub prev: Option<[u8; 32]>,
}

/// Append-only log of emitted plans, content-addressed and causally
/// chained. Blobs are deduplicated by id, so a step-cache hit that
/// replays an earlier plan costs one entry but zero new blob bytes.
#[derive(Clone, Debug, Default)]
pub struct PlanLog {
    entries: Vec<PlanLogEntry>,
    blobs: Vec<([u8; 32], Arc<Vec<u8>>)>,
    head: Option<[u8; 32]>,
}

impl PlanLog {
    pub fn new() -> PlanLog {
        PlanLog::default()
    }

    /// Record one emitted plan; returns its content id.
    pub fn record(&mut self, step: u64, plan: &StepPlan) -> [u8; 32] {
        let bytes = encode_step_plan(plan);
        let id = sha256::sha256(&bytes);
        if !self.blobs.iter().any(|(b, _)| *b == id) {
            self.blobs.push((id, Arc::new(bytes)));
        }
        let entry = PlanLogEntry {
            step,
            unix_secs: unix_now(),
            id,
            prev: self.head,
        };
        self.entries.push(entry);
        self.head = Some(id);
        id
    }

    pub fn entries(&self) -> &[PlanLogEntry] {
        &self.entries
    }

    pub fn head(&self) -> Option<[u8; 32]> {
        self.head
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn blob_count(&self) -> usize {
        self.blobs.len()
    }

    /// Fetch an archived plan's canonical bytes by content id.
    pub fn blob(&self, id: &[u8; 32]) -> Option<&[u8]> {
        self.blobs
            .iter()
            .find(|(b, _)| b == id)
            .map(|(_, bytes)| bytes.as_slice())
    }

    /// Prune the chain: keep entries that are within the newest
    /// `keep_last` (when set) *and* no older than `max_age_secs` (when
    /// set). Orphaned blobs are dropped and `prev` links re-threaded so
    /// the surviving entries still form one causal chain.
    pub fn prune(
        &mut self,
        keep_last: Option<usize>,
        max_age_secs: Option<u64>,
        now_unix: u64,
    ) -> usize {
        let cutoff_index =
            keep_last.map_or(0, |k| self.entries.len().saturating_sub(k));
        let cutoff_time =
            max_age_secs.map_or(0, |a| now_unix.saturating_sub(a));
        let before = self.entries.len();
        let mut kept = Vec::with_capacity(before - cutoff_index);
        for (i, e) in self.entries.drain(..).enumerate() {
            if i >= cutoff_index && e.unix_secs >= cutoff_time {
                kept.push(e);
            }
        }
        let mut prev = None;
        for e in &mut kept {
            e.prev = prev;
            prev = Some(e.id);
        }
        self.head = prev;
        self.entries = kept;
        let live: Vec<[u8; 32]> =
            self.entries.iter().map(|e| e.id).collect();
        self.blobs.retain(|(id, _)| live.contains(id));
        before - self.entries.len()
    }
}

fn unix_now() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

// ---------------------------------------------------------------------------
// Fingerprints
// ---------------------------------------------------------------------------

/// Digest of the exact topology bit patterns: any world change — size,
/// node shape, calibrated bandwidths — changes the fingerprint, which
/// is what keeps a shrunk world from silently reusing pre-shrink plans.
pub fn topology_fingerprint(t: &Topology) -> String {
    let mut e = Enc::new();
    e.put_usize(t.instances);
    e.put_usize(t.per_node);
    e.put_f64(t.intra_bw);
    e.put_f64(t.inter_bw);
    e.put_f64(t.base_latency);
    sha256::hex(&sha256::sha256(&e.buf))
}

/// Digest of everything in the orchestrator config that shapes a plan:
/// balancer names, communicator, composition, and the byte-cost
/// parameters (exact f64 bit patterns).
pub fn config_fingerprint(cfg: &OrchestratorConfig) -> String {
    let comm = match cfg.communicator {
        Communicator::AllToAll { nodewise } => {
            format!("all-to-all(nodewise={nodewise})")
        }
        Communicator::AllGather => "all-gather".to_string(),
    };
    let text = format!(
        "vision={};audio={};llm={};comm={};composition={};embed={:016x};\
         vis={:016x};aud={:016x};text={:016x}",
        cfg.vision_balancer.name(),
        cfg.audio_balancer.name(),
        cfg.llm_balancer.name(),
        comm,
        cfg.composition,
        cfg.embed_bytes_per_token.to_bits(),
        cfg.vis_bytes_per_unit.to_bits(),
        cfg.aud_bytes_per_unit.to_bits(),
        cfg.text_bytes_per_token.to_bits(),
    );
    sha256::hex(&sha256::sha256(text.as_bytes()))
}

// ---------------------------------------------------------------------------
// Payload encode/decode
// ---------------------------------------------------------------------------

fn put_cache<V>(
    e: &mut Enc,
    cache: &PlanCache<V>,
    mut put_value: impl FnMut(&mut Enc, &V),
) where
    V: Clone,
{
    e.put_usize(cache.capacity());
    e.put_u64(cache.clock());
    e.put_usize(cache.len());
    for (sketch, key, value, stamp) in cache.entries() {
        e.put_u64(sketch.0);
        e.put_usize(key.len());
        for &w in key {
            e.put_u64(w);
        }
        e.put_u64(stamp);
        put_value(e, value);
    }
}

fn take_cache<'a, V>(
    d: &mut Dec<'a>,
    capacity_override: Option<usize>,
    mut take_value: impl FnMut(&mut Dec<'a>) -> Result<V, ArchiveError>,
) -> Result<PlanCache<V>, ArchiveError>
where
    V: Clone,
{
    let capacity = d.take_usize()?;
    let clock = d.take_u64()?;
    let n = d.take_len(24)?;
    let mut entries = Vec::with_capacity(n);
    for _ in 0..n {
        let sketch = d.take_u64()?;
        let klen = d.take_len(8)?;
        let mut key = Vec::with_capacity(klen);
        for _ in 0..klen {
            key.push(d.take_u64()?);
        }
        let stamp = d.take_u64()?;
        let value = take_value(d)?;
        entries.push((sketch, key, value, stamp));
    }
    Ok(PlanCache::restore(
        capacity_override.unwrap_or(capacity),
        clock,
        entries,
    ))
}

/// Serialize a session's full [`StepHistory`] (three phase histories +
/// the step cache) into the `caches.bin` payload.
pub fn encode_caches(history: &StepHistory) -> Vec<u8> {
    let mut e = Enc::new();
    put_header(&mut e, KIND_CACHES);
    for phase in [&history.vision, &history.audio, &history.llm] {
        put_assignment(&mut e, &phase.prev_local);
        put_cache(&mut e, &phase.cache, put_assignment);
    }
    put_cache(&mut e, &history.step_cache, |e, plan: &Arc<StepPlan>| {
        let bytes = encode_step_plan(plan);
        e.put_bytes(&bytes);
    });
    e.buf
}

/// Rebuild a [`StepHistory`] from `caches.bin`. `capacity_override`
/// installs the *loader's* configured cache capacity (None keeps the
/// archived capacities — used by `archive verify`).
pub fn decode_caches(
    bytes: &[u8],
    capacity_override: Option<usize>,
) -> Result<StepHistory, ArchiveError> {
    let mut d = Dec::new(bytes, "caches.bin");
    check_header(&mut d, KIND_CACHES)?;
    // Start from capacity 0 and overwrite every field that matters; the
    // restored caches carry their own capacities.
    let mut history = StepHistory::new(0);
    let phases: [&mut PhaseHistory; 3] =
        [&mut history.vision, &mut history.audio, &mut history.llm];
    for phase in phases {
        phase.prev_local = take_assignment(&mut d)?;
        phase.cache =
            take_cache(&mut d, capacity_override, take_assignment)?;
    }
    history.step_cache = take_cache(&mut d, capacity_override, |d| {
        let blob = d.take_bytes()?;
        decode_step_plan(blob).map(Arc::new)
    })?;
    d.finish()?;
    Ok(history)
}

/// Serialize the plan log into the `plans.bin` payload.
pub fn encode_plans(log: &PlanLog) -> Vec<u8> {
    let mut e = Enc::new();
    put_header(&mut e, KIND_PLANS);
    e.put_usize(log.entries.len());
    for entry in &log.entries {
        e.put_u64(entry.step);
        e.put_u64(entry.unix_secs);
        e.put_raw(&entry.id);
        match entry.prev {
            Some(prev) => {
                e.put_u8(1);
                e.put_raw(&prev);
            }
            None => e.put_u8(0),
        }
    }
    e.put_usize(log.blobs.len());
    for (id, bytes) in &log.blobs {
        e.put_raw(id);
        e.put_bytes(bytes);
    }
    e.buf
}

/// Rebuild the plan log from `plans.bin`, verifying every blob hashes
/// to its content id (blobs are the audit record — a silent bit-flip
/// here would defeat the whole point).
pub fn decode_plans(bytes: &[u8]) -> Result<PlanLog, ArchiveError> {
    let mut d = Dec::new(bytes, "plans.bin");
    check_header(&mut d, KIND_PLANS)?;
    let n = d.take_len(49)?;
    let mut entries = Vec::with_capacity(n);
    for _ in 0..n {
        let step = d.take_u64()?;
        let unix_secs = d.take_u64()?;
        let id = d.take_digest()?;
        let prev = match d.take_u8()? {
            0 => None,
            1 => Some(d.take_digest()?),
            x => {
                return Err(d.malformed(format!("bad prev-link flag {x}")))
            }
        };
        entries.push(PlanLogEntry { step, unix_secs, id, prev });
    }
    let m = d.take_len(40)?;
    let mut blobs = Vec::with_capacity(m);
    for _ in 0..m {
        let id = d.take_digest()?;
        let bytes = d.take_bytes()?;
        let actual = sha256::sha256(bytes);
        if actual != id {
            return Err(ArchiveError::ChecksumMismatch {
                name: format!("plan blob {}", sha256::hex(&id)),
                expected: sha256::hex(&id),
                actual: sha256::hex(&actual),
            });
        }
        blobs.push((id, Arc::new(bytes.to_vec())));
    }
    d.finish()?;
    let head = entries.last().map(|e: &PlanLogEntry| e.id);
    Ok(PlanLog { entries, blobs, head })
}

/// Serialize the shape-profile store into the `profiles.bin` payload.
pub fn encode_profiles(store: &ShapeProfileStore) -> Vec<u8> {
    let mut e = Enc::new();
    put_header(&mut e, KIND_PROFILES);
    e.put_u64(store.steps());
    for phase in PhaseKind::ALL {
        let profiles: Vec<_> = store.phase_profiles(phase).collect();
        e.put_usize(profiles.len());
        for (sketch, p) in profiles {
            e.put_u64(sketch.0);
            e.put_u64(p.count);
            e.put_u64(p.total_len);
            e.put_u64(p.min_len);
            e.put_u64(p.max_len);
            for &h in &p.hist {
                e.put_u64(h);
            }
        }
    }
    e.buf
}

/// Rebuild the shape-profile store from `profiles.bin`.
pub fn decode_profiles(
    bytes: &[u8],
) -> Result<ShapeProfileStore, ArchiveError> {
    let mut d = Dec::new(bytes, "profiles.bin");
    check_header(&mut d, KIND_PROFILES)?;
    let steps = d.take_u64()?;
    let mut phases: [Vec<(u64, ShapeProfile)>; 3] = Default::default();
    for slot in phases.iter_mut() {
        let n = d.take_len(8 * (5 + SKETCH_BUCKETS))?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            let sketch = d.take_u64()?;
            let count = d.take_u64()?;
            let total_len = d.take_u64()?;
            let min_len = d.take_u64()?;
            let max_len = d.take_u64()?;
            let mut hist = [0u64; SKETCH_BUCKETS];
            for h in hist.iter_mut() {
                *h = d.take_u64()?;
            }
            v.push((
                sketch,
                ShapeProfile { count, hist, total_len, min_len, max_len },
            ));
        }
        *slot = v;
    }
    d.finish()?;
    Ok(ShapeProfileStore::restore(steps, phases))
}

// ---------------------------------------------------------------------------
// Manifest
// ---------------------------------------------------------------------------

/// Summary of the exporting session's [`super::session::SessionStats`],
/// embedded in the manifest as provenance.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StatsSummary {
    pub steps: u64,
    pub step_cache_hits: u64,
    pub warm_rate: f64,
    pub cache_hit_rate: f64,
    pub mean_plan_ms: f64,
}

/// One payload's manifest record.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PayloadMeta {
    pub name: String,
    pub bytes: u64,
    pub sha256: String,
}

/// The parsed `manifest.json`: schema + provenance + payload digests.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub schema_version: String,
    pub created_unix: u64,
    pub git_describe: String,
    pub topology: Topology,
    pub topology_fingerprint: String,
    pub config_fingerprint: String,
    pub stats: StatsSummary,
    pub plan_chain_len: u64,
    pub plan_chain_head: Option<String>,
    pub payloads: Vec<PayloadMeta>,
    /// Self-hash: sha256 of the canonical JSON with this field removed.
    pub manifest_sha256: String,
}

impl Manifest {
    /// Parsed semver major of `schema_version` (None if unparseable).
    pub fn major(&self) -> Option<u64> {
        self.schema_version.split('.').next()?.parse().ok()
    }

    pub fn payload(&self, name: &str) -> Option<&PayloadMeta> {
        self.payloads.iter().find(|p| p.name == name)
    }

    fn to_json_without_hash(&self) -> Json {
        Json::obj(vec![
            ("schema_version", Json::str(&self.schema_version)),
            ("created_unix", Json::num(self.created_unix as f64)),
            ("generator", Json::str("orchmllm plan archive")),
            ("git_describe", Json::str(&self.git_describe)),
            (
                "topology",
                Json::obj(vec![
                    (
                        "instances",
                        Json::num(self.topology.instances as f64),
                    ),
                    ("per_node", Json::num(self.topology.per_node as f64)),
                    ("intra_bw", Json::num(self.topology.intra_bw)),
                    ("inter_bw", Json::num(self.topology.inter_bw)),
                    (
                        "base_latency",
                        Json::num(self.topology.base_latency),
                    ),
                ]),
            ),
            (
                "topology_fingerprint",
                Json::str(&self.topology_fingerprint),
            ),
            ("config_fingerprint", Json::str(&self.config_fingerprint)),
            (
                "stats",
                Json::obj(vec![
                    ("steps", Json::num(self.stats.steps as f64)),
                    (
                        "step_cache_hits",
                        Json::num(self.stats.step_cache_hits as f64),
                    ),
                    ("warm_rate", Json::num(finite(self.stats.warm_rate))),
                    (
                        "cache_hit_rate",
                        Json::num(finite(self.stats.cache_hit_rate)),
                    ),
                    (
                        "mean_plan_ms",
                        Json::num(finite(self.stats.mean_plan_ms)),
                    ),
                ]),
            ),
            (
                "plan_chain",
                Json::obj(vec![
                    ("len", Json::num(self.plan_chain_len as f64)),
                    (
                        "head",
                        match &self.plan_chain_head {
                            Some(h) => Json::str(h),
                            None => Json::Null,
                        },
                    ),
                ]),
            ),
            (
                "payloads",
                Json::arr(self.payloads.iter().map(|p| {
                    Json::obj(vec![
                        ("name", Json::str(&p.name)),
                        ("bytes", Json::num(p.bytes as f64)),
                        ("sha256", Json::str(&p.sha256)),
                    ])
                })),
            ),
        ])
    }

    /// Serialize to canonical JSON text (sorted keys, 1-space pretty),
    /// computing the self-hash.
    pub fn to_text(&mut self) -> String {
        let canonical = self.to_json_without_hash().pretty();
        self.manifest_sha256 =
            sha256::hex(&sha256::sha256(canonical.as_bytes()));
        let mut j = self.to_json_without_hash();
        if let Json::Obj(map) = &mut j {
            map.insert(
                "manifest_sha256".to_string(),
                Json::str(&self.manifest_sha256),
            );
        }
        let mut text = j.pretty();
        text.push('\n');
        text
    }

    /// Parse and self-verify a manifest. Unknown fields are ignored
    /// (minor-version additions stay loadable); a bad self-hash or a
    /// different major is a typed error.
    pub fn parse(text: &str) -> Result<Manifest, ArchiveError> {
        let malformed = |detail: String| ArchiveError::Malformed {
            section: "manifest.json",
            detail,
        };
        let j = Json::parse(text).map_err(|e| malformed(e.to_string()))?;
        let schema_version = j
            .get("schema_version")
            .as_str()
            .ok_or_else(|| malformed("missing schema_version".into()))?
            .to_string();
        let major: Option<u64> =
            schema_version.split('.').next().and_then(|m| m.parse().ok());
        if major != Some(SUPPORTED_MAJOR) {
            return Err(ArchiveError::SchemaVersion {
                found: schema_version,
                supported: "1",
            });
        }
        let recorded = j
            .get("manifest_sha256")
            .as_str()
            .ok_or_else(|| malformed("missing manifest_sha256".into()))?
            .to_string();
        // Canonical re-serialization minus the hash field must hash to
        // the recorded value. BTreeMap-backed objects make the sorted
        // pretty form deterministic; f64s round-trip via shortest form.
        let mut without = j.clone();
        if let Json::Obj(map) = &mut without {
            map.remove("manifest_sha256");
        }
        let actual =
            sha256::hex(&sha256::sha256(without.pretty().as_bytes()));
        if actual != recorded {
            return Err(ArchiveError::ChecksumMismatch {
                name: "manifest.json".to_string(),
                expected: recorded,
                actual,
            });
        }
        let topo = j.get("topology");
        let need_num = |v: &Json, what: &str| {
            v.as_f64()
                .ok_or_else(|| malformed(format!("missing {what}")))
        };
        let topology = Topology {
            instances: need_num(topo.get("instances"), "topology.instances")?
                as usize,
            per_node: need_num(topo.get("per_node"), "topology.per_node")?
                as usize,
            intra_bw: need_num(topo.get("intra_bw"), "topology.intra_bw")?,
            inter_bw: need_num(topo.get("inter_bw"), "topology.inter_bw")?,
            base_latency: need_num(
                topo.get("base_latency"),
                "topology.base_latency",
            )?,
        };
        let stats = j.get("stats");
        let stats = StatsSummary {
            steps: stats.get("steps").as_f64().unwrap_or(0.0) as u64,
            step_cache_hits: stats
                .get("step_cache_hits")
                .as_f64()
                .unwrap_or(0.0) as u64,
            warm_rate: stats.get("warm_rate").as_f64().unwrap_or(0.0),
            cache_hit_rate: stats
                .get("cache_hit_rate")
                .as_f64()
                .unwrap_or(0.0),
            mean_plan_ms: stats.get("mean_plan_ms").as_f64().unwrap_or(0.0),
        };
        let payloads = j
            .get("payloads")
            .as_arr()
            .ok_or_else(|| malformed("missing payloads".into()))?
            .iter()
            .map(|p| {
                Ok(PayloadMeta {
                    name: p
                        .get("name")
                        .as_str()
                        .ok_or_else(|| {
                            malformed("payload missing name".into())
                        })?
                        .to_string(),
                    bytes: p.get("bytes").as_f64().unwrap_or(0.0) as u64,
                    sha256: p
                        .get("sha256")
                        .as_str()
                        .ok_or_else(|| {
                            malformed("payload missing sha256".into())
                        })?
                        .to_string(),
                })
            })
            .collect::<Result<Vec<_>, ArchiveError>>()?;
        Ok(Manifest {
            schema_version,
            created_unix: j.get("created_unix").as_f64().unwrap_or(0.0)
                as u64,
            git_describe: j
                .get("git_describe")
                .as_str()
                .unwrap_or("unknown")
                .to_string(),
            topology,
            topology_fingerprint: j
                .get("topology_fingerprint")
                .as_str()
                .unwrap_or_default()
                .to_string(),
            config_fingerprint: j
                .get("config_fingerprint")
                .as_str()
                .unwrap_or_default()
                .to_string(),
            stats,
            plan_chain_len: j
                .get("plan_chain")
                .get("len")
                .as_f64()
                .unwrap_or(0.0) as u64,
            plan_chain_head: j
                .get("plan_chain")
                .get("head")
                .as_str()
                .map(str::to_string),
            payloads,
            manifest_sha256: recorded,
        })
    }
}

fn finite(x: f64) -> f64 {
    if x.is_finite() {
        x
    } else {
        0.0
    }
}

fn git_describe() -> String {
    std::process::Command::new("git")
        .args(["describe", "--always", "--dirty"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

// ---------------------------------------------------------------------------
// Export / open / load
// ---------------------------------------------------------------------------

/// Borrowed view of everything a session exports.
pub struct ExportInputs<'a> {
    pub cfg: &'a OrchestratorConfig,
    pub topo: &'a Topology,
    pub history: &'a StepHistory,
    pub profiles: &'a ShapeProfileStore,
    pub plan_log: &'a PlanLog,
    pub stats: StatsSummary,
}

/// Write a complete archive into `dir` (created if needed, existing
/// payloads overwritten). Returns the manifest that was written.
pub fn export(
    dir: &Path,
    inputs: &ExportInputs<'_>,
) -> Result<Manifest, ArchiveError> {
    fs::create_dir_all(dir).map_err(|e| io_err(dir, e))?;
    let payload_bytes = [
        (PAYLOAD_CACHES, encode_caches(inputs.history)),
        (PAYLOAD_PLANS, encode_plans(inputs.plan_log)),
        (PAYLOAD_PROFILES, encode_profiles(inputs.profiles)),
    ];
    let mut payloads = Vec::with_capacity(payload_bytes.len());
    for (name, bytes) in &payload_bytes {
        let path = dir.join(name);
        fs::write(&path, bytes).map_err(|e| io_err(&path, e))?;
        payloads.push(PayloadMeta {
            name: name.to_string(),
            bytes: bytes.len() as u64,
            sha256: sha256::hex(&sha256::sha256(bytes)),
        });
    }
    let mut manifest = Manifest {
        schema_version: SCHEMA_VERSION.to_string(),
        created_unix: unix_now(),
        git_describe: git_describe(),
        topology: *inputs.topo,
        topology_fingerprint: topology_fingerprint(inputs.topo),
        config_fingerprint: config_fingerprint(inputs.cfg),
        stats: inputs.stats,
        plan_chain_len: inputs.plan_log.len() as u64,
        plan_chain_head: inputs
            .plan_log
            .head()
            .map(|h| sha256::hex(&h)),
        payloads,
        manifest_sha256: String::new(),
    };
    let text = manifest.to_text();
    let path = dir.join(MANIFEST);
    fs::write(&path, text).map_err(|e| io_err(&path, e))?;
    Ok(manifest)
}

/// An opened archive: manifest parsed and self-verified, payloads not
/// yet read. Fingerprint checks are cheap at this stage.
pub struct Archive {
    pub dir: PathBuf,
    pub manifest: Manifest,
}

/// Fully decoded archive contents.
pub struct LoadedState {
    pub history: StepHistory,
    pub profiles: ShapeProfileStore,
    pub plan_log: PlanLog,
}

impl Archive {
    /// Open an archive directory. `Ok(None)` when no manifest exists
    /// there (callers degrade to cold start); schema/self-hash problems
    /// are typed errors.
    pub fn open(dir: &Path) -> Result<Option<Archive>, ArchiveError> {
        let path = dir.join(MANIFEST);
        let text = match fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == io::ErrorKind::NotFound => {
                return Ok(None)
            }
            Err(e) => return Err(io_err(&path, e)),
        };
        let manifest = Manifest::parse(&text)?;
        Ok(Some(Archive { dir: dir.to_path_buf(), manifest }))
    }

    /// Read and checksum-verify one payload's raw bytes.
    fn payload_bytes(&self, name: &str) -> Result<Vec<u8>, ArchiveError> {
        let meta = self.manifest.payload(name).ok_or_else(|| {
            ArchiveError::MissingPayload { name: name.to_string() }
        })?;
        let path = self.dir.join(name);
        let bytes = match fs::read(&path) {
            Ok(b) => b,
            Err(e) if e.kind() == io::ErrorKind::NotFound => {
                return Err(ArchiveError::MissingPayload {
                    name: name.to_string(),
                })
            }
            Err(e) => return Err(io_err(&path, e)),
        };
        let actual = sha256::hex(&sha256::sha256(&bytes));
        if actual != meta.sha256 {
            return Err(ArchiveError::ChecksumMismatch {
                name: name.to_string(),
                expected: meta.sha256.clone(),
                actual,
            });
        }
        Ok(bytes)
    }

    /// Decode the full archive state. `capacity_override` installs the
    /// loader's plan-cache capacity (None keeps archived capacities).
    pub fn load_state(
        &self,
        capacity_override: Option<usize>,
    ) -> Result<LoadedState, ArchiveError> {
        let history = decode_caches(
            &self.payload_bytes(PAYLOAD_CACHES)?,
            capacity_override,
        )?;
        let plan_log = decode_plans(&self.payload_bytes(PAYLOAD_PLANS)?)?;
        let profiles =
            decode_profiles(&self.payload_bytes(PAYLOAD_PROFILES)?)?;
        Ok(LoadedState { history, profiles, plan_log })
    }
}

// ---------------------------------------------------------------------------
// Warm-start outcome
// ---------------------------------------------------------------------------

/// What `PlanSession::with_archive` found.
#[derive(Clone, Debug)]
pub enum WarmStart {
    /// Archive loaded: caches, profiles, and plan chain installed.
    Warm {
        /// Step-cache entries restored.
        cached_plans: usize,
        /// Phase-cache entries restored (all three phases).
        cached_solves: usize,
        /// Plan-chain length carried forward.
        chain_len: usize,
        /// Shape-profile entries restored.
        profile_entries: usize,
    },
    /// No usable archive: reason says why (missing, wrong world, wrong
    /// config). Never an error — cold start is always safe.
    Cold { reason: String },
}

impl WarmStart {
    pub fn is_warm(&self) -> bool {
        matches!(self, WarmStart::Warm { .. })
    }

    /// Human-readable one-liner for logs and reports.
    pub fn describe(&self) -> String {
        match self {
            WarmStart::Warm {
                cached_plans,
                cached_solves,
                chain_len,
                profile_entries,
            } => format!(
                "warm start: {cached_plans} step plans, {cached_solves} \
                 phase solves, {profile_entries} shape profiles, chain \
                 len {chain_len}"
            ),
            WarmStart::Cold { reason } => format!("cold start: {reason}"),
        }
    }
}

// ---------------------------------------------------------------------------
// Verify / inspect / gc
// ---------------------------------------------------------------------------

/// Result of a full integrity check.
#[derive(Clone, Debug)]
pub struct VerifyReport {
    pub payloads: usize,
    pub cached_plans: usize,
    pub chain_len: usize,
    pub blobs: usize,
}

/// Full integrity check: manifest self-hash + schema, payload sha256s,
/// complete decode of every payload, blob content ids, and the causal
/// chain's link structure. Any failure is a typed [`ArchiveError`].
pub fn verify(dir: &Path) -> Result<VerifyReport, ArchiveError> {
    let archive = Archive::open(dir)?.ok_or_else(|| {
        ArchiveError::MissingPayload { name: MANIFEST.to_string() }
    })?;
    let state = archive.load_state(None)?;
    let entries = state.plan_log.entries();
    let mut prev: Option<[u8; 32]> = None;
    for (i, e) in entries.iter().enumerate() {
        if i > 0 && e.prev != prev {
            return Err(ArchiveError::Malformed {
                section: "plans.bin",
                detail: format!(
                    "causal chain broken at entry {i} (step {})",
                    e.step
                ),
            });
        }
        if state.plan_log.blob(&e.id).is_none() {
            return Err(ArchiveError::Malformed {
                section: "plans.bin",
                detail: format!(
                    "entry {i} references missing blob {}",
                    sha256::hex(&e.id)
                ),
            });
        }
        prev = Some(e.id);
    }
    if archive.manifest.plan_chain_len != entries.len() as u64 {
        return Err(ArchiveError::Malformed {
            section: "manifest.json",
            detail: format!(
                "manifest says chain len {}, plans.bin holds {}",
                archive.manifest.plan_chain_len,
                entries.len()
            ),
        });
    }
    Ok(VerifyReport {
        payloads: archive.manifest.payloads.len(),
        cached_plans: state.history.step_cache.len(),
        chain_len: entries.len(),
        blobs: state.plan_log.blob_count(),
    })
}

/// Human-readable archive summary (the `archive inspect` output).
pub fn inspect(dir: &Path) -> Result<String, ArchiveError> {
    let archive = Archive::open(dir)?.ok_or_else(|| {
        ArchiveError::MissingPayload { name: MANIFEST.to_string() }
    })?;
    let m = &archive.manifest;
    let mut out = String::new();
    out.push_str(&format!("plan archive at {}\n", dir.display()));
    out.push_str(&format!(
        "  schema {} · created {} · git {}\n",
        m.schema_version, m.created_unix, m.git_describe
    ));
    out.push_str(&format!(
        "  topology d={} per_node={} (fingerprint {})\n",
        m.topology.instances,
        m.topology.per_node,
        &m.topology_fingerprint[..16.min(m.topology_fingerprint.len())]
    ));
    out.push_str(&format!(
        "  config fingerprint {}\n",
        &m.config_fingerprint[..16.min(m.config_fingerprint.len())]
    ));
    out.push_str(&format!(
        "  session: {} steps, {} step-cache hits, warm rate {:.3}, \
         cache hit rate {:.3}\n",
        m.stats.steps,
        m.stats.step_cache_hits,
        m.stats.warm_rate,
        m.stats.cache_hit_rate
    ));
    out.push_str(&format!(
        "  plan chain: {} entries, head {}\n",
        m.plan_chain_len,
        m.plan_chain_head
            .as_deref()
            .map(|h| &h[..16.min(h.len())])
            .unwrap_or("-")
    ));
    for p in &m.payloads {
        out.push_str(&format!(
            "  payload {:<13} {:>8} bytes  sha256 {}\n",
            p.name,
            p.bytes,
            &p.sha256[..16.min(p.sha256.len())]
        ));
    }
    Ok(out)
}

/// Result of a gc pass.
#[derive(Clone, Debug)]
pub struct GcReport {
    pub kept: usize,
    pub pruned: usize,
    pub blobs_before: usize,
    pub blobs_after: usize,
}

/// Prune the plan chain by count and/or age, rewrite `plans.bin`, and
/// re-seal the manifest. Caches and profiles are untouched.
pub fn gc(
    dir: &Path,
    keep_last: Option<usize>,
    max_age_secs: Option<u64>,
) -> Result<GcReport, ArchiveError> {
    let archive = Archive::open(dir)?.ok_or_else(|| {
        ArchiveError::MissingPayload { name: MANIFEST.to_string() }
    })?;
    let mut log = decode_plans(&archive.payload_bytes(PAYLOAD_PLANS)?)?;
    let blobs_before = log.blob_count();
    let pruned = log.prune(keep_last, max_age_secs, unix_now());
    let bytes = encode_plans(&log);
    let path = dir.join(PAYLOAD_PLANS);
    fs::write(&path, &bytes).map_err(|e| io_err(&path, e))?;
    let mut manifest = archive.manifest;
    if let Some(meta) =
        manifest.payloads.iter_mut().find(|p| p.name == PAYLOAD_PLANS)
    {
        meta.bytes = bytes.len() as u64;
        meta.sha256 = sha256::hex(&sha256::sha256(&bytes));
    }
    manifest.plan_chain_len = log.len() as u64;
    manifest.plan_chain_head = log.head().map(|h| sha256::hex(&h));
    let text = manifest.to_text();
    let mpath = dir.join(MANIFEST);
    fs::write(&mpath, text).map_err(|e| io_err(&mpath, e))?;
    Ok(GcReport {
        kept: log.len(),
        pruned,
        blobs_before,
        blobs_after: log.blob_count(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balance::cache::Sketch;

    fn dispatch(seed: usize) -> DispatchPlan {
        DispatchPlan {
            assignment: vec![
                vec![ExampleRef { id: seed, len: 5 + seed }],
                vec![ExampleRef { id: seed + 1, len: 9 }],
            ],
            route: Rearrangement { from: vec![0, 1], to: vec![1, 0] },
            nodewise_perm: vec![0, 1],
            comm: CollectiveCost { seconds: 0.25, peak_bytes: 1e6 },
            peak_bytes: 2e6,
            compute_nanos: 12_345 + seed as u128,
            source: PlanSource::Warm,
            repair_moves: seed % 3,
        }
    }

    fn encoder(seed: usize) -> EncoderPlan {
        EncoderPlan {
            plan: dispatch(seed),
            out_route: Rearrangement { from: vec![1, 0], to: vec![0, 1] },
            out_comm: CollectiveCost { seconds: 0.5, peak_bytes: 3e5 },
            out_inter_node_bytes: 4.5e7,
        }
    }

    fn step_plan() -> StepPlan {
        StepPlan {
            d: 2,
            examples: vec![
                Example {
                    id: 0,
                    task: Task::Vqa,
                    vis_len: 16,
                    aud_len: 0,
                    text_len: 40,
                    vis_tokens: 8,
                    aud_tokens: 0,
                },
                Example {
                    id: 1,
                    task: Task::Asr,
                    vis_len: 0,
                    aud_len: 100,
                    text_len: 20,
                    vis_tokens: 0,
                    aud_tokens: 25,
                },
            ],
            home: vec![0, 1],
            vision: encoder(0),
            audio: encoder(7),
            llm: dispatch(3),
            compute_nanos: 999_999,
        }
    }

    #[test]
    fn step_plan_roundtrips_bit_identically() {
        let plan = step_plan();
        let bytes = encode_step_plan(&plan);
        let back = decode_step_plan(&bytes).unwrap();
        assert_eq!(encode_step_plan(&back), bytes);
        assert_eq!(back.d, plan.d);
        assert_eq!(back.examples, plan.examples);
        assert_eq!(back.home, plan.home);
        assert_eq!(back.llm.assignment, plan.llm.assignment);
        assert_eq!(back.compute_nanos, plan.compute_nanos);
    }

    #[test]
    fn truncated_plan_is_a_typed_error_not_a_panic() {
        let bytes = encode_step_plan(&step_plan());
        for cut in [0, 1, 7, bytes.len() / 2, bytes.len() - 1] {
            match decode_step_plan(&bytes[..cut]) {
                Err(ArchiveError::Truncated { .. }) => {}
                other => panic!("cut at {cut}: {other:?}"),
            }
        }
    }

    #[test]
    fn trailing_garbage_is_malformed() {
        let mut bytes = encode_step_plan(&step_plan());
        bytes.extend_from_slice(&[0xde, 0xad]);
        assert!(matches!(
            decode_step_plan(&bytes),
            Err(ArchiveError::Malformed { .. })
        ));
    }

    #[test]
    fn corrupt_length_word_cannot_allocate_unbounded() {
        // Flip a length prefix to u64::MAX: take_len must reject it
        // before any Vec::with_capacity sees it.
        let mut bytes = encode_step_plan(&step_plan());
        bytes[8..16].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(decode_step_plan(&bytes).is_err());
    }

    #[test]
    fn plan_log_chains_and_dedupes() {
        let mut log = PlanLog::new();
        let plan = step_plan();
        let id1 = log.record(1, &plan);
        let id2 = log.record(2, &plan); // identical plan → same id
        assert_eq!(id1, id2);
        assert_eq!(log.len(), 2);
        assert_eq!(log.blob_count(), 1, "identical plans share one blob");
        assert_eq!(log.entries()[0].prev, None);
        assert_eq!(log.entries()[1].prev, Some(id1));
        assert_eq!(log.head(), Some(id2));
        let mut other = plan.clone();
        other.compute_nanos += 1;
        let id3 = log.record(3, &other);
        assert_ne!(id3, id1);
        assert_eq!(log.blob_count(), 2);
    }

    #[test]
    fn plans_payload_roundtrips_and_verifies_blob_ids() {
        let mut log = PlanLog::new();
        log.record(1, &step_plan());
        let bytes = encode_plans(&log);
        let back = decode_plans(&bytes).unwrap();
        assert_eq!(back.entries(), log.entries());
        assert_eq!(back.head(), log.head());
        // Flip one byte inside the blob region: content id must catch it.
        let mut bad = bytes.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0xff;
        assert!(matches!(
            decode_plans(&bad),
            Err(ArchiveError::ChecksumMismatch { .. })
                | Err(ArchiveError::Truncated { .. })
                | Err(ArchiveError::Malformed { .. })
        ));
    }

    #[test]
    fn plan_log_prune_rethreads_the_chain() {
        let mut log = PlanLog::new();
        for step in 1..=5u64 {
            let mut p = step_plan();
            p.compute_nanos = step as u128;
            log.record(step, &p);
        }
        assert_eq!(log.blob_count(), 5);
        let pruned = log.prune(Some(2), None, unix_now());
        assert_eq!(pruned, 3);
        assert_eq!(log.len(), 2);
        assert_eq!(log.blob_count(), 2, "orphan blobs dropped");
        assert_eq!(log.entries()[0].prev, None, "chain re-threaded");
        assert_eq!(
            log.entries()[1].prev,
            Some(log.entries()[0].id)
        );
        assert_eq!(log.head(), Some(log.entries()[1].id));
    }

    #[test]
    fn caches_payload_roundtrips_history() {
        let mut h = StepHistory::new(4);
        h.llm.prev_local = vec![vec![ExampleRef { id: 0, len: 3 }]];
        h.llm.cache.insert(
            Sketch(42),
            &[1, 2, 3],
            vec![vec![ExampleRef { id: 9, len: 8 }]],
        );
        h.step_cache
            .insert(Sketch(7), &[4, 5], Arc::new(step_plan()));
        let bytes = encode_caches(&h);
        let mut back = decode_caches(&bytes, None).unwrap();
        assert_eq!(back.llm.prev_local, h.llm.prev_local);
        assert_eq!(back.llm.cache.len(), 1);
        assert_eq!(
            back.llm.cache.lookup(Sketch(42), &[1, 2, 3]),
            Some(vec![vec![ExampleRef { id: 9, len: 8 }]])
        );
        let got = back.step_cache.lookup(Sketch(7), &[4, 5]).unwrap();
        assert_eq!(
            encode_step_plan(&got),
            encode_step_plan(&step_plan()),
            "restored step plan is bit-identical"
        );
        // Capacity override respects the loader's config.
        let small = decode_caches(&bytes, Some(0)).unwrap();
        assert!(small.step_cache.is_empty());
    }

    #[test]
    fn profiles_payload_roundtrips() {
        let mut store = ShapeProfileStore::new();
        store.observe_step(&step_plan().examples, 2);
        store.observe_step(&step_plan().examples, 2);
        let bytes = encode_profiles(&store);
        let back = decode_profiles(&bytes).unwrap();
        assert_eq!(back, store);
    }

    #[test]
    fn payload_header_is_checked() {
        let h = StepHistory::new(2);
        let mut bytes = encode_caches(&h);
        // Wrong magic.
        bytes[0] ^= 0xff;
        assert!(matches!(
            decode_caches(&bytes, None),
            Err(ArchiveError::Malformed { .. })
        ));
        // Wrong kind: a profiles payload fed to the caches decoder.
        let p = encode_profiles(&ShapeProfileStore::new());
        assert!(matches!(
            decode_caches(&p, None),
            Err(ArchiveError::Malformed { .. })
        ));
    }

    #[test]
    fn fingerprints_react_to_any_field() {
        let t = Topology::h100(8);
        let base = topology_fingerprint(&t);
        assert_eq!(base, topology_fingerprint(&t), "deterministic");
        let mut t2 = t;
        t2.instances = 7;
        assert_ne!(base, topology_fingerprint(&t2));
        let mut t3 = t;
        t3.inter_bw += 1.0;
        assert_ne!(base, topology_fingerprint(&t3));

        let cfg = OrchestratorConfig::orchmllm(7168.0);
        let cbase = config_fingerprint(&cfg);
        assert_eq!(cbase, config_fingerprint(&cfg));
        let mut cfg2 = cfg.clone();
        cfg2.composition = !cfg2.composition;
        assert_ne!(cbase, config_fingerprint(&cfg2));
    }

    #[test]
    fn manifest_roundtrips_and_self_verifies() {
        let mut m = Manifest {
            schema_version: SCHEMA_VERSION.to_string(),
            created_unix: 1_700_000_000,
            git_describe: "abc123-dirty".to_string(),
            topology: Topology::h100(16),
            topology_fingerprint: topology_fingerprint(&Topology::h100(16)),
            config_fingerprint: "deadbeef".to_string(),
            stats: StatsSummary {
                steps: 10,
                step_cache_hits: 9,
                warm_rate: 0.9,
                cache_hit_rate: 0.45,
                mean_plan_ms: 1.25,
            },
            plan_chain_len: 10,
            plan_chain_head: Some("aa".repeat(32)),
            payloads: vec![PayloadMeta {
                name: "caches.bin".to_string(),
                bytes: 128,
                sha256: "bb".repeat(32),
            }],
            manifest_sha256: String::new(),
        };
        let text = m.to_text();
        let back = Manifest::parse(&text).unwrap();
        assert_eq!(back.schema_version, m.schema_version);
        assert_eq!(back.topology, m.topology);
        assert_eq!(back.stats, m.stats);
        assert_eq!(back.plan_chain_head, m.plan_chain_head);
        assert_eq!(back.manifest_sha256, m.manifest_sha256);
    }

    #[test]
    fn manifest_tamper_is_a_checksum_error() {
        let mut m = Manifest {
            schema_version: SCHEMA_VERSION.to_string(),
            created_unix: 1,
            git_describe: "x".to_string(),
            topology: Topology::h100(4),
            topology_fingerprint: "t".to_string(),
            config_fingerprint: "c".to_string(),
            stats: StatsSummary::default(),
            plan_chain_len: 0,
            plan_chain_head: None,
            payloads: vec![],
            manifest_sha256: String::new(),
        };
        let text = m.to_text();
        let tampered = text.replace("\"created_unix\": 1", "\"created_unix\": 2");
        assert_ne!(text, tampered, "test premise");
        assert!(matches!(
            Manifest::parse(&tampered),
            Err(ArchiveError::ChecksumMismatch { .. })
        ));
    }

    #[test]
    fn future_major_schema_is_a_typed_error() {
        let mut m = Manifest {
            schema_version: "2.0.0".to_string(),
            created_unix: 1,
            git_describe: "x".to_string(),
            topology: Topology::h100(4),
            topology_fingerprint: "t".to_string(),
            config_fingerprint: "c".to_string(),
            stats: StatsSummary::default(),
            plan_chain_len: 0,
            plan_chain_head: None,
            payloads: vec![],
            manifest_sha256: String::new(),
        };
        let text = m.to_text();
        match Manifest::parse(&text) {
            Err(ArchiveError::SchemaVersion { found, .. }) => {
                assert_eq!(found, "2.0.0")
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn future_minor_schema_still_loads() {
        // Same major, newer minor: must parse (unknown fields ignored
        // by construction; the minor bump alone is not a rejection).
        let mut m = Manifest {
            schema_version: "1.9.0".to_string(),
            created_unix: 1,
            git_describe: "x".to_string(),
            topology: Topology::h100(4),
            topology_fingerprint: "t".to_string(),
            config_fingerprint: "c".to_string(),
            stats: StatsSummary::default(),
            plan_chain_len: 0,
            plan_chain_head: None,
            payloads: vec![],
            manifest_sha256: String::new(),
        };
        let text = m.to_text();
        assert!(Manifest::parse(&text).is_ok());
    }

    #[test]
    fn export_verify_gc_roundtrip_on_disk() {
        let dir = std::env::temp_dir().join(format!(
            "orchmllm-archive-test-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        let cfg = OrchestratorConfig::orchmllm(7168.0);
        let topo = Topology::h100(2);
        let mut history = StepHistory::new(8);
        history
            .step_cache
            .insert(Sketch(1), &[1], Arc::new(step_plan()));
        let mut profiles = ShapeProfileStore::new();
        profiles.observe_step(&step_plan().examples, 2);
        let mut log = PlanLog::new();
        for step in 1..=4 {
            let mut p = step_plan();
            p.compute_nanos = step as u128;
            log.record(step, &p);
        }
        let inputs = ExportInputs {
            cfg: &cfg,
            topo: &topo,
            history: &history,
            profiles: &profiles,
            plan_log: &log,
            stats: StatsSummary {
                steps: 4,
                step_cache_hits: 3,
                warm_rate: 0.75,
                cache_hit_rate: 0.5,
                mean_plan_ms: 0.1,
            },
        };
        let manifest = export(&dir, &inputs).unwrap();
        assert_eq!(manifest.plan_chain_len, 4);

        let report = verify(&dir).unwrap();
        assert_eq!(report.payloads, 3);
        assert_eq!(report.chain_len, 4);
        assert_eq!(report.cached_plans, 1);

        let opened = Archive::open(&dir).unwrap().unwrap();
        let state = opened.load_state(Some(8)).unwrap();
        assert_eq!(state.plan_log.len(), 4);
        assert_eq!(state.profiles, profiles);
        assert_eq!(state.history.step_cache.len(), 1);

        // gc down to the last 2 entries, then verify again.
        let gc_report = gc(&dir, Some(2), None).unwrap();
        assert_eq!(gc_report.kept, 2);
        assert_eq!(gc_report.pruned, 2);
        let report = verify(&dir).unwrap();
        assert_eq!(report.chain_len, 2);

        // Corrupt a payload byte: verify must fail with a checksum error.
        let path = dir.join(PAYLOAD_CACHES);
        let mut bytes = fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x01;
        fs::write(&path, &bytes).unwrap();
        assert!(matches!(
            verify(&dir),
            Err(ArchiveError::ChecksumMismatch { .. })
        ));

        // Missing archive opens as None.
        let _ = fs::remove_dir_all(&dir);
        assert!(Archive::open(&dir).unwrap().is_none());
    }
}
