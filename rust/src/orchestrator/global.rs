//! MLLM Global Orchestrator (paper §6).
//!
//! Coordinates the per-phase dispatchers across one training step:
//!
//! * **Subsequences assembly** — the LLM dispatcher balances on the
//!   *interleaved* sequence length (text + all encoder subsequences),
//!   not the text length;
//! * **Rearrangement composition** — encoder outputs route directly
//!   from their encoder-phase instance to their LLM-phase instance
//!   (`Π_M ∘ Π_Eₖ⁻¹`), one All-to-All per encoder instead of two;
//! * **Computation overhead overlapping** — planning is pure
//!   computation over sequence lengths, designed to run inside the
//!   dataloader prefetch (see [`super::pipeline::StepPipeline`]); only
//!   the All-to-All operations land on the critical path. The three
//!   phase dispatchers are independent (§6), so the parallel solve
//!   strategy plans them concurrently under `std::thread::scope`, each
//!   phase on its own [`PlanScratch`] — the serial strategy exists as
//!   the before/after baseline for `benches/table2_overhead`;
//! * **Incremental rebalancing** — the steady-state path threads a
//!   [`StepHistory`]: each phase warm-starts its solve from the
//!   previous step's assignment and caches solves under a length-
//!   histogram sketch, and exactly-recurring steps replay the whole
//!   [`StepPlan`] from the step-level cache (DESIGN.md §Incremental
//!   Planning).
//!
//! This module holds the *stateless* planning machinery: the
//! [`Orchestrator`] is a pure function of its configuration, and every
//! solve strategy funnels through one crate-internal `plan_inner`. The
//! public planning surface is [`super::session::PlanSession::plan`],
//! which owns the scratch/history state and picks the strategy from a
//! `PlanOptions`; the old `plan_step_*` method family survives only as
//! `#[doc(hidden)]` deprecated shims pinned by the session-parity
//! suite (`rust/tests/session_parity.rs`) — see DESIGN.md §Planning
//! Session for the migration map.
//!
//! The resulting [`StepPlan`] is consumed by both the discrete-event
//! simulator (pricing) and the real trainer (execution) — the same plan
//! object, so benchmarks measure the logic that ships.

use std::sync::Arc;

use crate::balance::balancer::{registry, Balancer};
use crate::balance::cache::{PlanCache, Sketch, DEFAULT_PLAN_CACHE_SIZE};
use crate::balance::incremental::{PlanSource, REPAIR_TOLERANCE};
use crate::balance::scratch::PlanScratch;
use crate::comm::costmodel::{alltoall_cost, CollectiveCost};
use crate::comm::topology::Topology;
use crate::comm::volume::VolumeMatrix;
use crate::data::synth::Example;
use crate::model::flops::PhaseKind;

use super::dispatcher::{
    Communicator, DispatchOptions, DispatchPlan, Dispatcher, PhaseHistory,
};
use super::rearrangement::Rearrangement;

/// Orchestrator configuration: which phases balance, with what
/// algorithm, over which communicator. Balancers resolve through the
/// [`registry`], so any registered algorithm plugs into any phase.
#[derive(Clone)]
pub struct OrchestratorConfig {
    pub vision_balancer: Arc<dyn Balancer>,
    pub audio_balancer: Arc<dyn Balancer>,
    pub llm_balancer: Arc<dyn Balancer>,
    pub communicator: Communicator,
    /// Rearrangement Composition on (off = reset-to-origin two-hop).
    pub composition: bool,
    /// Bytes per element of encoder-output embeddings (LLM hidden ·
    /// dtype size) — the payload of the composed routes.
    pub embed_bytes_per_token: f64,
    /// Bytes per metadata unit for each encoder input.
    pub vis_bytes_per_unit: f64,
    pub aud_bytes_per_unit: f64,
    /// Bytes per text token moved in the LLM-phase rearrangement (ids +
    /// targets + masks).
    pub text_bytes_per_token: f64,
}

impl std::fmt::Debug for OrchestratorConfig {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("OrchestratorConfig")
            .field("vision_balancer", &self.vision_balancer.name())
            .field("audio_balancer", &self.audio_balancer.name())
            .field("llm_balancer", &self.llm_balancer.name())
            .field("communicator", &self.communicator)
            .field("composition", &self.composition)
            .finish_non_exhaustive()
    }
}

impl OrchestratorConfig {
    /// The paper's full system: tailored algorithms per phase
    /// (no-padding for vision patches, padded for the conv audio
    /// encoder, no-padding for the LLM — §8 "Input preprocessing"),
    /// node-wise All-to-All, composition on.
    pub fn orchmllm(embed_bytes: f64) -> OrchestratorConfig {
        OrchestratorConfig {
            vision_balancer: registry::must("greedy"),
            audio_balancer: registry::must("padded"),
            llm_balancer: registry::must("greedy"),
            communicator: Communicator::AllToAll { nodewise: true },
            composition: true,
            embed_bytes_per_token: embed_bytes,
            vis_bytes_per_unit: 588.0 * 2.0, // 14x14x3 patch, bf16
            aud_bytes_per_unit: 128.0 * 2.0, // mel frame, bf16
            text_bytes_per_token: 16.0,      // id + target + masks
        }
    }

    /// Baseline: no balancing anywhere ("OrchMLLM w/o balance").
    pub fn no_balance(embed_bytes: f64) -> OrchestratorConfig {
        OrchestratorConfig {
            vision_balancer: registry::must("none"),
            audio_balancer: registry::must("none"),
            llm_balancer: registry::must("none"),
            ..Self::orchmllm(embed_bytes)
        }
    }

    /// Pre-balancing stand-in (Fig. 10): balance only the LLM phase.
    pub fn llm_only(embed_bytes: f64) -> OrchestratorConfig {
        OrchestratorConfig {
            vision_balancer: registry::must("none"),
            audio_balancer: registry::must("none"),
            ..Self::orchmllm(embed_bytes)
        }
    }

    /// Force one registered algorithm onto every phase (the `--balancer`
    /// CLI override).
    pub fn with_balancer(mut self, b: Arc<dyn Balancer>)
        -> OrchestratorConfig {
        self.vision_balancer = b.clone();
        self.audio_balancer = b.clone();
        self.llm_balancer = b;
        self
    }

    /// Apply metadata-driven selections to the three phases from the
    /// given `(vision, audio, llm)` traits — the shared core of
    /// [`OrchestratorConfig::with_auto_balancers`] and the trainer's
    /// `--balancer auto` path.
    pub fn with_selected_balancers(
        mut self,
        traits: &[crate::balance::select::PhaseTraits; 3],
    ) -> OrchestratorConfig {
        use crate::balance::select::select_for_phase;
        self.vision_balancer = select_for_phase(&traits[0]).balancer;
        self.audio_balancer = select_for_phase(&traits[1]).balancer;
        self.llm_balancer = select_for_phase(&traits[2]).balancer;
        self
    }

    /// Auto-select each phase's balancer from the registry's metadata
    /// and the model configuration (`--balancer auto`): conv front-end
    /// → conv-attention regime, large β·L/α → quadratic regime, else
    /// linear — see `balance::select` and DESIGN.md §Exact Balancer &
    /// Auto-Selection.
    pub fn with_auto_balancers(
        self,
        model: &crate::model::config::MllmConfig,
    ) -> OrchestratorConfig {
        self.with_selected_balancers(&[
            model.phase_traits(PhaseKind::Vision),
            model.phase_traits(PhaseKind::Audio),
            model.phase_traits(PhaseKind::Llm),
        ])
    }

    /// The auto-selected configuration for a model: `orchmllm` defaults
    /// with every phase's balancer resolved by metadata.
    pub fn auto(
        model: &crate::model::config::MllmConfig,
        embed_bytes: f64,
    ) -> OrchestratorConfig {
        Self::orchmllm(embed_bytes).with_auto_balancers(model)
    }
}

/// One phase's plan plus the composed output route (encoders only).
#[derive(Clone, Debug)]
pub struct EncoderPlan {
    pub plan: DispatchPlan,
    /// Encoder outputs: encoder-phase instance → LLM-phase instance
    /// (composed), or the two-hop pair when composition is off.
    pub out_route: Rearrangement,
    /// Priced communication of the output rearrangement (composed: one
    /// All-to-All; uncomposed: two).
    pub out_comm: CollectiveCost,
    /// Inter-node bytes of the output route (Fig.-13 metric).
    pub out_inter_node_bytes: f64,
}

/// The full step plan the simulator prices and the trainer executes.
#[derive(Clone, Debug)]
pub struct StepPlan {
    pub d: usize,
    pub examples: Vec<Example>,
    /// Where each example was sampled (home instance).
    pub home: Vec<usize>,
    pub vision: EncoderPlan,
    pub audio: EncoderPlan,
    pub llm: DispatchPlan,
    /// Wall-clock planning time (overlappable; with parallel phase
    /// planning this is the slowest phase, not the sum).
    pub compute_nanos: u128,
}

impl StepPlan {
    /// How each phase's solve was produced this step
    /// (vision, audio, llm).
    pub fn plan_sources(&self) -> [PlanSource; 3] {
        [
            self.vision.plan.source,
            self.audio.plan.source,
            self.llm.source,
        ]
    }

    /// Sum of on-critical-path communication seconds.
    pub fn comm_seconds(&self) -> f64 {
        self.vision.plan.comm.seconds
            + self.audio.plan.comm.seconds
            + self.vision.out_comm.seconds
            + self.audio.out_comm.seconds
            + self.llm.comm.seconds
    }

    /// Phase mini-batches for a given phase kind.
    pub fn assignment(&self, phase: PhaseKind)
        -> &crate::balance::types::Assignment {
        match phase {
            PhaseKind::Vision => &self.vision.plan.assignment,
            PhaseKind::Audio => &self.audio.plan.assignment,
            PhaseKind::Llm => &self.llm.assignment,
        }
    }
}

/// Per-phase reusable buffers for one planning stream: lens + payload
/// staging plus the balancer/dispatcher [`PlanScratch`]. One per phase
/// so the three dispatchers can plan concurrently without sharing.
#[derive(Clone, Debug, Default)]
pub struct PhaseScratch {
    pub lens: Vec<usize>,
    pub payload: Vec<f64>,
    pub plan: PlanScratch,
}

/// The orchestrator's full per-step workspace (all three phases), plus
/// the step-level staging arenas: the flattened global example list and
/// home placement are staged here (`clear()` + `push`, capacity
/// retained) and only cloned into a [`StepPlan`] when a step actually
/// builds one — a step-cache replay touches no heap at all.
#[derive(Clone, Debug, Default)]
pub struct StepScratch {
    pub vision: PhaseScratch,
    pub audio: PhaseScratch,
    pub llm: PhaseScratch,
    /// Arena for the flattened global example list.
    pub examples: Vec<Example>,
    /// Arena for the per-example home instance.
    pub home: Vec<usize>,
}

/// Cross-step planning state: each modality phase carries its own
/// [`PhaseHistory`] (previous assignment + solve cache), and the step
/// level adds a full-[`StepPlan`] cache so exactly-recurring steps skip
/// dispatch *and* composition entirely.
#[derive(Clone, Debug)]
pub struct StepHistory {
    pub vision: PhaseHistory,
    pub audio: PhaseHistory,
    pub llm: PhaseHistory,
    /// Full-step plan cache, keyed by the sketch of the interleaved LLM
    /// lengths and verified against every example's fields + placement.
    /// Entries are [`Arc`]-shared with the plans handed back to
    /// callers: an insert is a refcount bump, and a hit replays the
    /// cached step without cloning it.
    pub step_cache: PlanCache<Arc<StepPlan>>,
    /// Reusable exact-key buffer for the step cache.
    key_buf: Vec<u64>,
}

impl StepHistory {
    /// Histories with every cache capped at `plan_cache_size` entries
    /// (0 disables caching; warm-starting still applies).
    pub fn new(plan_cache_size: usize) -> StepHistory {
        StepHistory {
            vision: PhaseHistory::new(plan_cache_size),
            audio: PhaseHistory::new(plan_cache_size),
            llm: PhaseHistory::new(plan_cache_size),
            step_cache: PlanCache::new(plan_cache_size),
            key_buf: Vec::new(),
        }
    }

    /// Aggregate hit rate across the step cache and the three per-phase
    /// solve caches.
    pub fn cache_hit_rate(&self) -> f64 {
        let hits = self.step_cache.hits
            + self.vision.cache.hits
            + self.audio.cache.hits
            + self.llm.cache.hits;
        let misses = self.step_cache.misses
            + self.vision.cache.misses
            + self.audio.cache.misses
            + self.llm.cache.misses;
        if hits + misses == 0 {
            0.0
        } else {
            hits as f64 / (hits + misses) as f64
        }
    }
}

impl Default for StepHistory {
    fn default() -> StepHistory {
        StepHistory::new(DEFAULT_PLAN_CACHE_SIZE)
    }
}

/// Below this many global examples the per-step cost of two scoped
/// thread spawns exceeds the phase solves being parallelized (tiny
/// trainer workloads), so planning stays on the calling thread.
const PARALLEL_MIN_EXAMPLES: usize = 256;

/// Above this many global examples the step-level plan cache is
/// bypassed (per-phase solve caches and warm-starting still apply).
/// Since cached plans became [`Arc`]-shared, an insert no longer deep-
/// clones the `StepPlan`, so the per-step cost of a never-hitting
/// stream is just the O(n) exact key — cheap next to a solve at the
/// same n — and the bound now exists only to cap resident key memory:
/// each entry's key holds 8 words per example (≈ 64 MiB per cached
/// million-sequence step, times the LRU capacity). Streams that large
/// and known to be non-recurring should plan with the cache off.
const STEP_CACHE_MAX_EXAMPLES: usize = 1 << 20;

/// The MLLM Global Orchestrator.
#[derive(Clone, Debug)]
pub struct Orchestrator {
    pub cfg: OrchestratorConfig,
}

impl Orchestrator {
    pub fn new(cfg: OrchestratorConfig) -> Orchestrator {
        Orchestrator { cfg }
    }

    /// Legacy shim: history-free parallel planning on a caller-owned
    /// scratch. Kept (hidden) only so the session-parity suite can pin
    /// `PlanSession::plan` bit-identical to the pre-session path.
    #[doc(hidden)]
    #[deprecated(note = "use orchestrator::session::PlanSession::plan \
                         with PlanOptions::from_scratch()")]
    pub fn plan_step_with(
        &self,
        topo: &Topology,
        minibatches: &[Vec<Example>],
        scratch: &mut StepScratch,
    ) -> StepPlan {
        let (plan, outcome) = self.plan_inner(
            topo,
            minibatches,
            scratch,
            true,
            None,
            REPAIR_TOLERANCE,
            true,
        );
        materialize(plan, &outcome)
    }

    /// Legacy shim: parallel phases + cross-step history. Kept (hidden)
    /// only for the session-parity suite.
    #[doc(hidden)]
    #[deprecated(note = "use orchestrator::session::PlanSession::plan \
                         (PlanOptions default is the incremental path)")]
    pub fn plan_step_incremental(
        &self,
        topo: &Topology,
        minibatches: &[Vec<Example>],
        scratch: &mut StepScratch,
        history: &mut StepHistory,
    ) -> StepPlan {
        let (plan, outcome) = self.plan_inner(
            topo,
            minibatches,
            scratch,
            true,
            Some(history),
            REPAIR_TOLERANCE,
            true,
        );
        materialize(plan, &outcome)
    }

    /// Legacy shim: one phase after another, fresh allocations. Kept
    /// (hidden) only for the session-parity suite.
    #[doc(hidden)]
    #[deprecated(note = "use orchestrator::session::PlanSession::plan \
                         with PlanOptions::serial()")]
    pub fn plan_step_serial(
        &self,
        topo: &Topology,
        minibatches: &[Vec<Example>],
    ) -> StepPlan {
        let (plan, outcome) = self.plan_inner(
            topo,
            minibatches,
            &mut StepScratch::default(),
            false,
            None,
            REPAIR_TOLERANCE,
            true,
        );
        materialize(plan, &outcome)
    }

    /// The one planning engine every strategy funnels through. Not a
    /// public API: callers go through
    /// [`super::session::PlanSession::plan`] /
    /// [`super::session::PlanSession::plan_shared`], which own the
    /// scratch and history and map `PlanOptions` onto these knobs.
    ///
    /// Returns the plan behind an [`Arc`] (shared with the step cache
    /// when the cache retains it) plus this call's [`StepOutcome`]: a
    /// cached replay cannot stamp provenance onto the shared plan, so
    /// who-solved-what travels beside it instead of inside it.
    ///
    /// * `parallel` — plan the three phases on scoped threads (subject
    ///   to [`PARALLEL_MIN_EXAMPLES`]);
    /// * `history` — cross-step state: warm-starts + solve caches +
    ///   the step-level plan cache;
    /// * `tolerance` — warm-acceptance band
    ///   ([`crate::balance::incremental::warm_start_with`]);
    /// * `use_cache` — consult/populate the sketch-keyed caches (off:
    ///   warm-starting still applies).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn plan_inner(
        &self,
        topo: &Topology,
        minibatches: &[Vec<Example>],
        scratch: &mut StepScratch,
        parallel: bool,
        mut history: Option<&mut StepHistory>,
        tolerance: f64,
        use_cache: bool,
    ) -> (Arc<StepPlan>, StepOutcome) {
        let t0 = std::time::Instant::now();
        let d = topo.instances;
        assert_eq!(minibatches.len(), d, "one mini-batch per instance");

        // Flatten to the global example list with home placement —
        // staged in the scratch arenas, cloned into the plan only when
        // a step actually builds one.
        let StepScratch { vision, audio, llm, examples, home } = scratch;
        examples.clear();
        home.clear();
        for (i, mb) in minibatches.iter().enumerate() {
            for &e in mb {
                examples.push(e);
                home.push(i);
            }
        }

        // Step-level cache: an exactly-recurring step (same examples on
        // the same homes, same topology) replays the full plan —
        // dispatch, node-wise permutation, and composition included —
        // bit-identically, as a refcount bump on the cached Arc.
        let mut step_sketch: Option<Sketch> = None;
        if let Some(h) = history.as_deref_mut() {
            if use_cache
                && h.step_cache.capacity() > 0
                && examples.len() <= STEP_CACHE_MAX_EXAMPLES
            {
                let sketch =
                    Sketch::of_iter(examples.iter().map(|e| e.llm_len()), d);
                h.key_buf.clear();
                h.key_buf.push(d as u64);
                // The cached plan embeds topology-dependent routes,
                // node-wise permutations, and comm prices, so the
                // topology's identifying parameters are part of the key.
                h.key_buf.push(topo.per_node as u64);
                h.key_buf.push(topo.intra_bw.to_bits());
                h.key_buf.push(topo.inter_bw.to_bits());
                h.key_buf.push(topo.base_latency.to_bits());
                for (e, &hm) in examples.iter().zip(home.iter()) {
                    h.key_buf.push(hm as u64);
                    h.key_buf.push(e.id as u64);
                    h.key_buf.push(e.task as u64);
                    h.key_buf.push(e.vis_len as u64);
                    h.key_buf.push(e.aud_len as u64);
                    h.key_buf.push(e.text_len as u64);
                    h.key_buf.push(e.vis_tokens as u64);
                    h.key_buf.push(e.aud_tokens as u64);
                }
                if let Some(plan) = h.step_cache.lookup(sketch, &h.key_buf)
                {
                    let outcome = StepOutcome {
                        sources: [PlanSource::Cached; 3],
                        repair_moves: [
                            plan.vision.plan.repair_moves,
                            plan.audio.plan.repair_moves,
                            plan.llm.repair_moves,
                        ],
                        step_cache_hit: true,
                        compute_nanos: t0.elapsed().as_nanos(),
                    };
                    return (plan, outcome);
                }
                step_sketch = Some(sketch);
            }
        }
        let cfg = &self.cfg;

        // Stage per-phase lengths and payload bytes into the scratch.
        fill_phase(vision, examples, |e| e.vis_len, |e| {
            e.vis_len as f64 * cfg.vis_bytes_per_unit
        });
        fill_phase(audio, examples, |e| e.aud_len, |e| {
            e.aud_len as f64 * cfg.aud_bytes_per_unit
        });
        fill_phase(llm, examples, |e| e.llm_len(), |e| {
            e.text_len as f64 * cfg.text_bytes_per_token
        });

        let vd = Dispatcher::new(
            cfg.vision_balancer.clone(),
            cfg.communicator,
        );
        let ad =
            Dispatcher::new(cfg.audio_balancer.clone(), cfg.communicator);
        let ld = Dispatcher::new(cfg.llm_balancer.clone(), cfg.communicator);

        // ---- per-phase dispatchers (independent, §6) -------------------
        let home_ref: &[usize] = home;
        let parallel = parallel && examples.len() >= PARALLEL_MIN_EXAMPLES;
        let (vision_plan, audio_plan, llm_plan) = {
            // Like the scratches, each phase's history is private to its
            // dispatcher, so the three planning streams stay disjoint.
            let (vh, ah, lh) = match history.as_deref_mut() {
                Some(h) => {
                    let StepHistory {
                        vision: hist_v,
                        audio: hist_a,
                        llm: hist_l,
                        ..
                    } = h;
                    (Some(hist_v), Some(hist_a), Some(hist_l))
                }
                None => (None, None, None),
            };
            if parallel {
                // The dispatchers share nothing mutable: each phase
                // plans on its own scratch + history. The LLM phase
                // (usually the largest) runs on the calling thread;
                // encoders on scoped threads.
                std::thread::scope(|s| {
                    let hv = s.spawn(move || {
                        dispatch_phase(
                            &vd, topo, home_ref, vision, vh, tolerance,
                            use_cache,
                        )
                    });
                    let ha = s.spawn(move || {
                        dispatch_phase(
                            &ad, topo, home_ref, audio, ah, tolerance,
                            use_cache,
                        )
                    });
                    let lp = dispatch_phase(
                        &ld, topo, home_ref, llm, lh, tolerance, use_cache,
                    );
                    (
                        hv.join().expect("vision planner panicked"),
                        ha.join().expect("audio planner panicked"),
                        lp,
                    )
                })
            } else {
                (
                    dispatch_phase(
                        &vd, topo, home_ref, vision, vh, tolerance,
                        use_cache,
                    ),
                    dispatch_phase(
                        &ad, topo, home_ref, audio, ah, tolerance,
                        use_cache,
                    ),
                    dispatch_phase(
                        &ld, topo, home_ref, llm, lh, tolerance, use_cache,
                    ),
                )
            }
        };

        // ---- rearrangement composition ---------------------------------
        let vision = self.encoder_out(
            topo, &vision_plan, &llm_plan, examples, home,
            |e| e.vis_tokens,
        );
        let audio = self.encoder_out(
            topo, &audio_plan, &llm_plan, examples, home,
            |e| e.aud_tokens,
        );

        let plan = Arc::new(StepPlan {
            d,
            examples: examples.clone(),
            home: home.clone(),
            vision: EncoderPlan { plan: vision_plan, ..vision },
            audio: EncoderPlan { plan: audio_plan, ..audio },
            llm: llm_plan,
            compute_nanos: t0.elapsed().as_nanos(),
        });
        if let (Some(h), Some(sketch)) =
            (history.as_deref_mut(), step_sketch)
        {
            h.step_cache.insert(sketch, &h.key_buf, Arc::clone(&plan));
        }
        let outcome = StepOutcome {
            sources: plan.plan_sources(),
            repair_moves: [
                plan.vision.plan.repair_moves,
                plan.audio.plan.repair_moves,
                plan.llm.repair_moves,
            ],
            step_cache_hit: false,
            compute_nanos: plan.compute_nanos,
        };
        (plan, outcome)
    }

    /// Build the encoder-output route `Π_M ∘ Π_Eₖ⁻¹` (or its two-hop
    /// expansion when composition is disabled) and price it.
    fn encoder_out(
        &self,
        topo: &Topology,
        enc: &DispatchPlan,
        llm: &DispatchPlan,
        examples: &[Example],
        home: &[usize],
        tokens: impl Fn(&Example) -> usize,
    ) -> EncoderPlan {
        let d = topo.instances;
        let payload: Vec<f64> = examples
            .iter()
            .map(|e| tokens(e) as f64 * self.cfg.embed_bytes_per_token)
            .collect();

        // Encoder outputs currently live at enc.route.to; the LLM phase
        // needs them at llm.route.to.
        let enc_inv = Rearrangement::new(
            enc.route.to.clone(),
            home.to_vec(),
        );
        let to_llm =
            Rearrangement::new(home.to_vec(), llm.route.to.clone());
        let composed = enc_inv.compose(&to_llm);

        let identity = VolumeMatrix::identity_perm(d);
        let (out_comm, out_route) = if self.cfg.composition {
            let v = composed.volume(d, &payload);
            (alltoall_cost(topo, &v, &identity), composed.clone())
        } else {
            // Two hops: reset to origin, then re-dispatch (what §6 calls
            // the trivial approach).
            let c1 =
                alltoall_cost(topo, &enc_inv.volume(d, &payload), &identity);
            let c2 =
                alltoall_cost(topo, &to_llm.volume(d, &payload), &identity);
            (
                CollectiveCost {
                    seconds: c1.seconds + c2.seconds,
                    peak_bytes: c1.peak_bytes.max(c2.peak_bytes),
                },
                composed.clone(),
            )
        };
        EncoderPlan {
            plan: enc.clone(), // replaced by struct-update at call site
            out_inter_node_bytes: composed
                .inter_node_bytes(topo, &payload),
            out_route,
            out_comm,
        }
    }
}

/// What one `plan_inner` call did — provenance that travels beside the
/// (possibly cache-shared) [`Arc<StepPlan>`] instead of inside it. A
/// cached replay returns the same `StepPlan` the original build
/// produced, whose embedded `source`/`compute_nanos` fields describe
/// that build; this struct describes *this* call.
#[derive(Clone, Copy, Debug)]
pub(crate) struct StepOutcome {
    /// Per-phase solve provenance for this call (vision, audio, llm).
    pub(crate) sources: [PlanSource; 3],
    /// Per-phase repair moves applied on the warm path.
    pub(crate) repair_moves: [usize; 3],
    /// Whether the full-step plan cache replayed this step.
    pub(crate) step_cache_hit: bool,
    /// Wall-clock planning time of this call.
    pub(crate) compute_nanos: u128,
}

/// Unshare a planned step for by-value callers: unwrap the [`Arc`]
/// when this call holds the only reference, deep-clone when the step
/// cache retained it, then stamp the call's own provenance onto the
/// plan so by-value consumers see exactly what the pre-`Arc` API
/// reported (`Cached` sources on a replay, this call's timing).
pub(crate) fn materialize(
    plan: Arc<StepPlan>,
    outcome: &StepOutcome,
) -> StepPlan {
    let mut p = Arc::try_unwrap(plan).unwrap_or_else(|a| (*a).clone());
    p.vision.plan.source = outcome.sources[0];
    p.audio.plan.source = outcome.sources[1];
    p.llm.source = outcome.sources[2];
    p.compute_nanos = outcome.compute_nanos;
    p
}

/// Dispatch one phase, incrementally when a history stream is present.
fn dispatch_phase(
    dispatcher: &Dispatcher,
    topo: &Topology,
    home: &[usize],
    ph: &mut PhaseScratch,
    history: Option<&mut PhaseHistory>,
    tolerance: f64,
    use_cache: bool,
) -> DispatchPlan {
    dispatcher.dispatch(
        topo,
        home,
        &ph.lens,
        &ph.payload,
        &mut ph.plan,
        DispatchOptions { history, tolerance, cache: use_cache },
    )
}

/// Stage one phase's lengths and payload bytes into its scratch.
fn fill_phase(
    ph: &mut PhaseScratch,
    examples: &[Example],
    len_of: impl Fn(&Example) -> usize,
    bytes_of: impl Fn(&Example) -> f64,
) {
    ph.lens.clear();
    ph.lens.extend(examples.iter().map(&len_of));
    ph.payload.clear();
    ph.payload.extend(examples.iter().map(&bytes_of));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balance::cost::CostModel;
    use crate::data::synth::{DatasetConfig, Generator};
    use crate::orchestrator::session::{PlanOptions, PlanSession};

    fn sample(d: usize, b: usize, seed: u64) -> Vec<Vec<Example>> {
        let mut g = Generator::new(DatasetConfig::default(), seed);
        (0..d).map(|_| g.batch(b)).collect()
    }

    fn plan_once(
        cfg: OrchestratorConfig,
        d: usize,
        mbs: &[Vec<Example>],
    ) -> StepPlan {
        PlanSession::with_defaults(
            cfg,
            crate::comm::topology::Topology::h100(d),
        )
        .plan(mbs, PlanOptions::auto())
    }

    #[test]
    fn llm_only_balances_llm_but_not_encoders() {
        let mbs = sample(16, 30, 3);
        let plan = plan_once(OrchestratorConfig::llm_only(7168.0), 16, &mbs);
        let lin = CostModel::Linear { alpha: 1.0 };
        let llm_imb = lin.imbalance(plan.assignment(PhaseKind::Llm));
        let vis_imb = lin.imbalance(plan.assignment(PhaseKind::Vision));
        assert!(llm_imb < 1.1, "llm {llm_imb}");
        // Modality Composition Incoherence: encoder stays imbalanced.
        assert!(vis_imb > llm_imb + 0.1, "vis {vis_imb} llm {llm_imb}");
    }

    #[test]
    fn composition_halves_encoder_output_comm() {
        let mbs = sample(16, 30, 4);
        let with =
            plan_once(OrchestratorConfig::orchmllm(7168.0), 16, &mbs);
        let mut cfg = OrchestratorConfig::orchmllm(7168.0);
        cfg.composition = false;
        let without = plan_once(cfg, 16, &mbs);
        assert!(
            with.vision.out_comm.seconds
                < without.vision.out_comm.seconds,
            "{} !< {}",
            with.vision.out_comm.seconds,
            without.vision.out_comm.seconds
        );
        // Routes themselves are identical — only hop count differs.
        assert_eq!(with.vision.out_route, without.vision.out_route);
    }

    #[test]
    fn step_history_tracks_an_aggregate_hit_rate() {
        let mut h = StepHistory::new(4);
        assert_eq!(h.cache_hit_rate(), 0.0);
        h.vision.cache.hits = 3;
        h.vision.cache.misses = 1;
        h.step_cache.misses = 4;
        assert!((h.cache_hit_rate() - 3.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn with_balancer_overrides_every_phase() {
        let cfg = OrchestratorConfig::orchmllm(7168.0)
            .with_balancer(registry::must("kk"));
        assert_eq!(cfg.vision_balancer.name(), "kk");
        assert_eq!(cfg.audio_balancer.name(), "kk");
        assert_eq!(cfg.llm_balancer.name(), "kk");
        let mbs = sample(4, 10, 11);
        let plan = plan_once(cfg, 4, &mbs);
        assert_eq!(
            plan.assignment(PhaseKind::Llm)
                .iter()
                .map(|b| b.len())
                .sum::<usize>(),
            plan.examples.len()
        );
    }
}
