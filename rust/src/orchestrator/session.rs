//! The planning session — the one public entry point into step
//! planning.
//!
//! The Batch Post-Balancing Dispatcher (§5) and the MLLM Global
//! Orchestrator (§6) are one logical pipeline, but the pre-session API
//! exposed them as a method family (`plan_step`, `plan_step_with`,
//! `plan_step_serial`, `plan_step_incremental`) that forced every
//! caller — trainer, simulator, pipeline, benches, examples — to thread
//! its own [`StepScratch`], [`StepHistory`], and plan caches. A
//! [`PlanSession`] collapses that surface:
//!
//! * **one constructor** — [`PlanSession::new`] from an
//!   [`OrchestratorConfig`] (which phases balance, with what algorithm)
//!   plus a [`PipelineConfig`] (lookahead depth + plan-cache capacity;
//!   depth is a *session* property consumed by
//!   [`super::pipeline::StepPipeline`]) and the [`Topology`] being
//!   planned against;
//! * **owned state** — the session owns the per-phase scratches, the
//!   per-phase solve histories/caches, and the step-level plan cache;
//!   callers never see them;
//! * **one entry point** — [`PlanSession::plan`] takes the sampled
//!   mini-batches and a [`PlanOptions`], and every solve strategy is a
//!   `PlanOptions` value instead of a method: new scenarios (elastic
//!   world size, persisted shape profiles, failure injection) are one
//!   options variant big, not a new method family;
//! * **provenance** — each plan produces a [`PlanReport`] (per-phase
//!   [`PlanSource`], warm/cold timing, cache-hit and tolerance-gate
//!   outcome) retrievable via [`PlanSession::report`], and the session
//!   accumulates [`SessionStats`] so the sim report, the Table-2 JSON,
//!   and the `TrainReport` read provenance instead of recomputing it
//!   from scraps.
//!
//! Determinism is unchanged: `plan` is a pure function of the session's
//! construction arguments and the sequence of `(minibatches, options)`
//! calls, so every SPMD rank running an identical session over the
//! identical sampled stream replays identical plans without
//! communication (§5.2.1). The session-parity suite
//! (`rust/tests/session_parity.rs`) pins each strategy bit-identical to
//! the legacy `plan_step_*` path it replaced.

use std::path::Path;
use std::time::Instant;

use crate::balance::incremental::{PlanSource, REPAIR_TOLERANCE};
use crate::comm::topology::Topology;
use crate::data::synth::Example;
use crate::sim::pipeline::{CoschedReport, PipelineParallelConfig};
use crate::util::stats::Summary;

use std::sync::Arc;

use super::archive::{
    self, Archive, ArchiveError, ExportInputs, Manifest, PlanLog,
    StatsSummary, WarmStart,
};
use super::global::{
    materialize, Orchestrator, OrchestratorConfig, StepHistory,
    StepOutcome, StepPlan, StepScratch,
};
use super::pipeline::PipelineConfig;
use super::profile::ShapeProfileStore;

/// How the from-scratch phase solves execute.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolveStrategy {
    /// One phase after another on the calling thread (the pre-PR-1
    /// baseline `benches/table2_overhead` still measures).
    Serial,
    /// The three phase dispatchers on scoped threads (§6 overlap).
    Parallel,
}

/// Which planning strategy [`PlanSession::plan`] runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanMode {
    /// Pick for the caller: incremental-with-cache when history exists;
    /// phases that diverged (or a first step's empty history) fall back
    /// to the cold solve exactly like `Guarded` does — per phase,
    /// inside the warm-start gate — so `Auto` is always safe to use.
    Auto,
    /// Ignore history: every phase solves from scratch.
    FromScratch(SolveStrategy),
    /// Force the steady-state path: warm-starts + caches through the
    /// session's history (behaviourally what `Auto` resolves to today).
    Incremental,
}

/// Builder-style per-call options for [`PlanSession::plan`] — the
/// replacement for the `plan_step_*` method-per-strategy spread.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PlanOptions {
    pub mode: PlanMode,
    /// Warm-acceptance tolerance band: an accepted warm-started plan is
    /// certified within `1 + tolerance` of the sound lower bound (see
    /// `balance::incremental::warm_start_with`). `0.0` accepts only
    /// provably-optimal warm plans.
    pub tolerance: f64,
    /// Consult/populate the sketch-keyed plan caches (per-phase solves
    /// and the full-step plan). Off: warm-starting still applies.
    pub cache: bool,
    /// Opt-in pipeline-parallel co-scheduling: when set, every plan
    /// call runs the bubble packer over the planned step and attaches a
    /// [`CoschedReport`] to the [`PlanReport`]. Off by default — the
    /// packer allocates, and default sessions are pinned to zero heap
    /// allocations per warm step (rust/tests/plan_allocations.rs).
    /// `Copy` is preserved: the config is a fixed-size value type.
    pub pipeline: Option<PipelineParallelConfig>,
}

impl Default for PlanOptions {
    fn default() -> Self {
        PlanOptions {
            mode: PlanMode::Auto,
            tolerance: REPAIR_TOLERANCE,
            cache: true,
            pipeline: None,
        }
    }
}

impl PlanOptions {
    /// The shipped steady-state configuration ([`PlanMode::Auto`]).
    pub fn auto() -> Self {
        PlanOptions::default()
    }

    /// Force the incremental path explicitly.
    pub fn incremental() -> Self {
        PlanOptions { mode: PlanMode::Incremental, ..Self::default() }
    }

    /// History-free parallel solve (the cold baseline).
    pub fn from_scratch() -> Self {
        PlanOptions {
            mode: PlanMode::FromScratch(SolveStrategy::Parallel),
            ..Self::default()
        }
    }

    /// History-free serial solve (the pre-refactor bench baseline).
    pub fn serial() -> Self {
        PlanOptions {
            mode: PlanMode::FromScratch(SolveStrategy::Serial),
            ..Self::default()
        }
    }

    /// Override the warm-acceptance tolerance band.
    pub fn tolerance(mut self, tolerance: f64) -> Self {
        self.tolerance = tolerance;
        self
    }

    /// Enable or disable the plan caches for this call.
    pub fn cache(mut self, cache: bool) -> Self {
        self.cache = cache;
        self
    }

    /// Attach pipeline-parallel co-scheduling: every plan's
    /// [`PlanReport`] will carry a [`CoschedReport`] packing the step's
    /// encoder phases into the LLM 1F1B bubbles described by `cfg`.
    /// Validate user-supplied configs with
    /// [`PipelineParallelConfig::validate`] first.
    pub fn pipeline(mut self, cfg: PipelineParallelConfig) -> Self {
        self.pipeline = Some(cfg);
        self
    }
}

/// What [`PlanMode`] resolved to for one `plan` call.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ResolvedMode {
    Serial,
    Parallel,
    Incremental,
}

/// Provenance of one planned step — who solved what, how, and how fast.
/// The per-phase [`PlanSource`] *is* the tolerance-gate outcome:
/// `Warm` means the gate certified the warm-started plan within the
/// call's tolerance band, `Cold` means it was rejected (or there was no
/// usable history), `Cached` means the gate was bypassed by a
/// bit-identical sketch-cache replay.
#[derive(Clone, Debug)]
pub struct PlanReport {
    /// 1-based index of this plan within its session.
    pub step: u64,
    /// The strategy the options resolved to.
    pub mode: ResolvedMode,
    /// Per-phase solve provenance (vision, audio, llm).
    pub sources: [PlanSource; 3],
    /// Per-phase repair moves applied on the warm path.
    pub repair_moves: [usize; 3],
    /// Whether the full-step plan cache replayed this step.
    pub step_cache_hit: bool,
    /// The tolerance band the warm gate ran under.
    pub tolerance: f64,
    /// Wall-clock time of the `plan` call (overlappable work).
    pub plan_nanos: u128,
    /// Bubble co-scheduling outcome — present iff the call's
    /// [`PlanOptions::pipeline`] was set.
    pub cosched: Option<CoschedReport>,
}

impl PlanReport {
    /// At least one phase avoided the from-scratch solve.
    pub fn warm(&self) -> bool {
        self.sources.iter().any(|s| *s != PlanSource::Cold)
    }

    /// Every phase paid the from-scratch solve.
    pub fn cold(&self) -> bool {
        !self.warm()
    }

    /// Phase solves replayed from a sketch cache.
    pub fn cached_phases(&self) -> usize {
        self.sources
            .iter()
            .filter(|s| **s == PlanSource::Cached)
            .count()
    }
}

/// Per-step plan-time distribution and warm/cold breakdown for one
/// session (§6 telemetry; zeroed for baselines that never run the
/// dispatcher). Steady-state (t ≥ 2) steps plan warm or cached; only
/// step 1 — or a diverged batch — pays the cold from-scratch solve.
#[derive(Clone, Copy, Debug, Default)]
pub struct PlanTimeStats {
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// Mean plan time over steps with at least one warm/cached phase.
    pub warm_ms: f64,
    /// Mean plan time over fully cold (from-scratch) steps.
    pub cold_ms: f64,
    /// Fraction of phase solves replayed from a sketch cache.
    pub cache_hit_rate: f64,
    /// Fraction of phase solves warm-started or cached.
    pub warm_rate: f64,
}

/// Cumulative provenance over a session's lifetime, updated on every
/// [`PlanSession::plan`] call. This is the single source the sim
/// report, the Table-2 JSON, and the `TrainReport` read instead of
/// re-classifying plans themselves.
#[derive(Clone, Debug, Default)]
pub struct SessionStats {
    plan_ms: Summary,
    warm_plan_ms: Summary,
    cold_plan_ms: Summary,
    phase_solves: u64,
    warm_solves: u64,
    cached_solves: u64,
    step_cache_hits: u64,
    steps: u64,
}

impl SessionStats {
    /// Fold one report into the aggregate. Public so consumers that
    /// only see a stream of [`PlanReport`]s (e.g. the trainer reading
    /// `PlannedStep`s off a pipeline whose session lives on the
    /// background thread) can build session-style stats without
    /// re-deriving the warm/cached classification by hand.
    pub fn record(&mut self, report: &PlanReport) {
        let ms = report.plan_nanos as f64 / 1e6;
        self.plan_ms.push(ms);
        if report.cold() {
            self.cold_plan_ms.push(ms);
        } else {
            self.warm_plan_ms.push(ms);
        }
        for s in report.sources {
            self.phase_solves += 1;
            match s {
                PlanSource::Warm => self.warm_solves += 1,
                PlanSource::Cached => self.cached_solves += 1,
                PlanSource::Cold => {}
            }
        }
        if report.step_cache_hit {
            self.step_cache_hits += 1;
        }
        self.steps += 1;
    }

    /// Steps planned so far.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Steps replayed whole from the step-level plan cache.
    pub fn step_cache_hits(&self) -> u64 {
        self.step_cache_hits
    }

    /// Mean planning wall-time per step (ms).
    pub fn mean_plan_ms(&self) -> f64 {
        self.plan_ms.mean()
    }

    /// Phase solves warm-started or replayed (out of all phase solves).
    pub fn warm_rate(&self) -> f64 {
        if self.phase_solves == 0 {
            0.0
        } else {
            (self.warm_solves + self.cached_solves) as f64
                / self.phase_solves as f64
        }
    }

    /// Phase solves replayed bit-identically from a sketch cache.
    pub fn cache_hit_rate(&self) -> f64 {
        if self.phase_solves == 0 {
            0.0
        } else {
            self.cached_solves as f64 / self.phase_solves as f64
        }
    }

    /// The distribution summary consumed by the sim report and the
    /// Table-2 JSON.
    pub fn plan_time_stats(&self) -> PlanTimeStats {
        PlanTimeStats {
            p50_ms: self.plan_ms.percentile(50.0),
            p95_ms: self.plan_ms.percentile(95.0),
            p99_ms: self.plan_ms.percentile(99.0),
            warm_ms: self.warm_plan_ms.mean(),
            cold_ms: self.cold_plan_ms.mean(),
            cache_hit_rate: self.cache_hit_rate(),
            warm_rate: self.warm_rate(),
        }
    }
}

/// A stateful planning session: one per planning stream (one per DP
/// rank in the trainer; one per simulated run). See the module docs.
#[derive(Clone, Debug)]
pub struct PlanSession {
    orch: Orchestrator,
    topo: Topology,
    pipeline: PipelineConfig,
    scratch: StepScratch,
    history: StepHistory,
    last: Option<PlanReport>,
    stats: SessionStats,
    /// Shape-profile store, populated only while `archive_log` is on.
    profiles: ShapeProfileStore,
    /// Content-addressed causal log of emitted plans, populated only
    /// while `archive_log` is on.
    plan_log: PlanLog,
    /// Opt-in archive recording. Off by default: the steady-state
    /// planning path is gated at zero heap allocations per warm step
    /// (rust/tests/plan_allocations.rs), and recording allocates.
    archive_log: bool,
}

impl PlanSession {
    /// Construct a session from the orchestrator configuration, the
    /// pipeline configuration (depth + plan-cache capacity — validate
    /// user-supplied values with [`PipelineConfig::validate`] first),
    /// and the topology being planned against.
    pub fn new(
        cfg: OrchestratorConfig,
        pipeline: PipelineConfig,
        topo: Topology,
    ) -> PlanSession {
        PlanSession {
            orch: Orchestrator::new(cfg),
            topo,
            pipeline,
            scratch: StepScratch::default(),
            history: StepHistory::new(pipeline.plan_cache_size.min(65_536)),
            last: None,
            stats: SessionStats::default(),
            profiles: ShapeProfileStore::new(),
            plan_log: PlanLog::new(),
            archive_log: false,
        }
    }

    /// Construct a session and warm-start it from a plan archive at
    /// `dir` (written by [`PlanSession::export_archive`]).
    ///
    /// The load is guarded: a missing archive, a topology-fingerprint
    /// mismatch (elastic shrink/grow since the export), or a
    /// config-fingerprint mismatch all degrade to a **cold start with a
    /// logged reason** — an archived plan is never reused against a
    /// world it was not planned for. Archive corruption and schema-major
    /// skew are typed [`ArchiveError`]s, not silent cold starts.
    ///
    /// On a warm start the restored step cache replays recurring steps
    /// **bit-identically**: a hit hands back the archived [`StepPlan`]
    /// object itself (provenance: `step_cache_hit` in the
    /// [`PlanReport`]).
    pub fn with_archive(
        cfg: OrchestratorConfig,
        pipeline: PipelineConfig,
        topo: Topology,
        dir: &Path,
    ) -> Result<(PlanSession, WarmStart), ArchiveError> {
        let mut session = PlanSession::new(cfg, pipeline, topo);
        let archive = match Archive::open(dir)? {
            Some(a) => a,
            None => {
                let start = WarmStart::Cold {
                    reason: format!(
                        "no archive at {}",
                        dir.display()
                    ),
                };
                eprintln!("[archive] {}", start.describe());
                return Ok((session, start));
            }
        };
        let want_topo = archive::topology_fingerprint(&session.topo);
        let want_cfg = archive::config_fingerprint(session.config());
        let m = &archive.manifest;
        if m.topology_fingerprint != want_topo {
            let start = WarmStart::Cold {
                reason: format!(
                    "topology fingerprint mismatch (archive {} for d={}, \
                     this world {} for d={})",
                    &m.topology_fingerprint[..16.min(m.topology_fingerprint.len())],
                    m.topology.instances,
                    &want_topo[..16],
                    session.topo.instances,
                ),
            };
            eprintln!("[archive] {}", start.describe());
            return Ok((session, start));
        }
        if m.config_fingerprint != want_cfg {
            let start = WarmStart::Cold {
                reason: format!(
                    "orchestrator config fingerprint mismatch (archive \
                     {}, this session {})",
                    &m.config_fingerprint[..16.min(m.config_fingerprint.len())],
                    &want_cfg[..16],
                ),
            };
            eprintln!("[archive] {}", start.describe());
            return Ok((session, start));
        }
        let state = archive
            .load_state(Some(pipeline.plan_cache_size.min(65_536)))?;
        let cached_solves = state.history.vision.cache.len()
            + state.history.audio.cache.len()
            + state.history.llm.cache.len();
        let start = WarmStart::Warm {
            cached_plans: state.history.step_cache.len(),
            cached_solves,
            chain_len: state.plan_log.len(),
            profile_entries: state.profiles.len(),
        };
        session.history = state.history;
        session.profiles = state.profiles;
        session.plan_log = state.plan_log;
        Ok((session, start))
    }

    /// [`PlanSession::new`] with the default [`PipelineConfig`].
    pub fn with_defaults(
        cfg: OrchestratorConfig,
        topo: Topology,
    ) -> PlanSession {
        PlanSession::new(cfg, PipelineConfig::default(), topo)
    }

    /// The orchestrator configuration this session plans with.
    pub fn config(&self) -> &OrchestratorConfig {
        &self.orch.cfg
    }

    /// The topology this session plans against.
    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// The session's pipeline configuration (depth is consumed by
    /// [`super::pipeline::StepPipeline`]).
    pub fn pipeline_config(&self) -> PipelineConfig {
        self.pipeline
    }

    /// Lookahead depth — planned-but-unconsumed steps in flight when
    /// this session drives a [`super::pipeline::StepPipeline`].
    pub fn depth(&self) -> usize {
        self.pipeline.depth
    }

    /// Steps planned so far.
    pub fn steps_planned(&self) -> u64 {
        self.stats.steps
    }

    /// Plan one training step from the sampled per-instance
    /// mini-batches. Pure computation — no communication happens here;
    /// the returned [`StepPlan`] is what the simulator prices and the
    /// trainer executes. Provenance for this call is available from
    /// [`PlanSession::report`] immediately afterwards.
    ///
    /// By-value convenience over [`PlanSession::plan_shared`]: a
    /// step-cache replay pays a deep clone here to unshare the cached
    /// plan. Hot-path callers (the throughput bench, steady-state
    /// recurring streams) should use `plan_shared` instead.
    pub fn plan(
        &mut self,
        minibatches: &[Vec<Example>],
        opts: PlanOptions,
    ) -> StepPlan {
        let plan = self.plan_shared(minibatches, opts);
        let r = self.last.as_ref().expect("plan_shared records a report");
        let outcome = StepOutcome {
            sources: r.sources,
            repair_moves: r.repair_moves,
            step_cache_hit: r.step_cache_hit,
            compute_nanos: r.plan_nanos,
        };
        materialize(plan, &outcome)
    }

    /// The zero-copy planning fast path: plan one step and hand the
    /// result back behind an [`Arc`]. On a step-cache replay the `Arc`
    /// is shared with the cache entry — the call is a key comparison
    /// plus a refcount bump, no `StepPlan` is cloned and (once the
    /// session arenas are warm) no heap allocation happens at all.
    ///
    /// Because replays share the originally-built plan, the plan's
    /// embedded `source`/`compute_nanos` fields describe the build that
    /// produced it; per-call provenance (including `Cached` sources) is
    /// what [`PlanSession::report`] returns.
    pub fn plan_shared(
        &mut self,
        minibatches: &[Vec<Example>],
        opts: PlanOptions,
    ) -> Arc<StepPlan> {
        let t0 = Instant::now();
        let mode = match opts.mode {
            PlanMode::Auto | PlanMode::Incremental => {
                ResolvedMode::Incremental
            }
            PlanMode::FromScratch(SolveStrategy::Parallel) => {
                ResolvedMode::Parallel
            }
            PlanMode::FromScratch(SolveStrategy::Serial) => {
                ResolvedMode::Serial
            }
        };
        let (parallel, history) = match mode {
            ResolvedMode::Incremental => (true, Some(&mut self.history)),
            ResolvedMode::Parallel => (true, None),
            ResolvedMode::Serial => (false, None),
        };
        let (plan, outcome) = self.orch.plan_inner(
            &self.topo,
            minibatches,
            &mut self.scratch,
            parallel,
            history,
            opts.tolerance,
            opts.cache,
        );
        // Opt-in like archive recording: the packer allocates, and the
        // default (pipeline: None) path stays on the zero-alloc gate.
        let cosched = opts
            .pipeline
            .as_ref()
            .map(|cfg| crate::sim::pipeline::coschedule(&plan, cfg).summarize());
        let report = PlanReport {
            step: self.stats.steps + 1,
            mode,
            sources: outcome.sources,
            repair_moves: outcome.repair_moves,
            step_cache_hit: outcome.step_cache_hit,
            tolerance: opts.tolerance,
            plan_nanos: t0.elapsed().as_nanos(),
            cosched,
        };
        if self.archive_log {
            // Opt-in by design: recording allocates (profile entries,
            // plan-log blobs), and default sessions are pinned to zero
            // allocations per warm step.
            self.profiles.observe_step(&plan.examples, plan.d);
            self.plan_log.record(report.step, &plan);
        }
        self.stats.record(&report);
        self.last = Some(report);
        plan
    }

    /// Provenance of the most recent [`PlanSession::plan`] call (`None`
    /// before the first).
    pub fn report(&self) -> Option<&PlanReport> {
        self.last.as_ref()
    }

    /// Cumulative provenance over the session's lifetime.
    pub fn stats(&self) -> &SessionStats {
        &self.stats
    }

    /// Shorthand for `stats().plan_time_stats()`.
    pub fn plan_time_stats(&self) -> PlanTimeStats {
        self.stats.plan_time_stats()
    }

    /// Aggregate hit rate across the step-level and per-phase plan
    /// caches (lookups, not solves — see
    /// [`SessionStats::cache_hit_rate`] for the solve-level rate).
    pub fn cache_hit_rate(&self) -> f64 {
        self.history.cache_hit_rate()
    }

    /// Turn archive recording on or off (off by default). While on,
    /// every planned step feeds the shape-profile store and appends to
    /// the content-addressed plan log exported by
    /// [`PlanSession::export_archive`].
    pub fn set_archive_log(&mut self, on: bool) {
        self.archive_log = on;
    }

    /// Whether archive recording is currently on.
    pub fn archive_log(&self) -> bool {
        self.archive_log
    }

    /// The session's shape-profile store (empty unless archive
    /// recording is on or an archive was loaded).
    pub fn profiles(&self) -> &ShapeProfileStore {
        &self.profiles
    }

    /// The session's plan log (empty unless archive recording is on or
    /// an archive was loaded).
    pub fn plan_log(&self) -> &PlanLog {
        &self.plan_log
    }

    /// Snapshot of the session's cumulative stats in the manifest's
    /// provenance form.
    pub fn stats_summary(&self) -> StatsSummary {
        StatsSummary {
            steps: self.stats.steps(),
            step_cache_hits: self.stats.step_cache_hits(),
            warm_rate: self.stats.warm_rate(),
            cache_hit_rate: self.stats.cache_hit_rate(),
            mean_plan_ms: self.stats.mean_plan_ms(),
        }
    }

    /// Export the session's full planning state — phase + step caches,
    /// shape profiles, and the causal plan log — as a versioned,
    /// checksummed archive at `dir`. A fresh process can warm-start
    /// from it via [`PlanSession::with_archive`].
    pub fn export_archive(
        &self,
        dir: &Path,
    ) -> Result<Manifest, ArchiveError> {
        archive::export(
            dir,
            &ExportInputs {
                cfg: self.config(),
                topo: &self.topo,
                history: &self.history,
                profiles: &self.profiles,
                plan_log: &self.plan_log,
                stats: self.stats_summary(),
            },
        )
    }

    /// Re-target the session at a new topology (elastic shrink/grow):
    /// swap the topology and drop the per-topology planning state —
    /// history, plan caches, and scratch are keyed to the old world
    /// size and must not warm-start across a resize. Cumulative
    /// provenance ([`PlanSession::stats`]) keeps counting across the
    /// transition, and so do the archive shape profiles and the causal
    /// plan log — an export after a resize carries the *new* world's
    /// topology fingerprint over the whole recorded chain.
    pub fn resize(&mut self, topo: Topology) {
        self.topo = topo;
        self.scratch = StepScratch::default();
        self.history =
            StepHistory::new(self.pipeline.plan_cache_size.min(65_536));
        self.last = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balance::balancer::registry;
    use crate::balance::cost::CostModel;
    use crate::data::synth::{DatasetConfig, Generator};
    use crate::model::flops::PhaseKind;

    fn sample(d: usize, b: usize, seed: u64) -> Vec<Vec<Example>> {
        let mut g = Generator::new(DatasetConfig::default(), seed);
        (0..d).map(|_| g.batch(b)).collect()
    }

    fn session(cfg: OrchestratorConfig, d: usize) -> PlanSession {
        PlanSession::with_defaults(cfg, Topology::h100(d))
    }

    #[test]
    fn one_entry_point_serves_every_strategy() {
        let topo = Topology::h100(8);
        let mbs = sample(8, 16, 5);
        let mut s = PlanSession::with_defaults(
            OrchestratorConfig::orchmllm(7168.0),
            topo,
        );
        for opts in [
            PlanOptions::serial(),
            PlanOptions::from_scratch(),
            PlanOptions::incremental(),
            PlanOptions::auto(),
            PlanOptions::auto().cache(false),
            PlanOptions::auto().tolerance(0.2),
        ] {
            let plan = s.plan(&mbs, opts);
            assert_eq!(plan.d, 8);
            assert_eq!(plan.examples.len(), 8 * 16);
            let n = plan.examples.len();
            let mut seen = vec![false; n];
            for batch in plan.assignment(PhaseKind::Llm) {
                for e in batch {
                    assert!(!seen[e.id]);
                    seen[e.id] = true;
                }
            }
            assert!(seen.iter().all(|&x| x), "example lost ({opts:?})");
        }
        assert_eq!(s.steps_planned(), 6);
    }

    #[test]
    fn resize_replans_the_shrunk_world() {
        // Elastic shrink: the session keeps its cumulative stats but
        // plans the next step over the new (smaller) topology with no
        // stale warm-start from the old world size.
        let mut s = session(OrchestratorConfig::orchmllm(7168.0), 8);
        let plan = s.plan(&sample(8, 16, 31), PlanOptions::auto());
        assert_eq!(plan.d, 8);
        s.resize(Topology::h100(7));
        let plan = s.plan(&sample(7, 16, 32), PlanOptions::auto());
        assert_eq!(plan.d, 7);
        let n = plan.examples.len();
        assert_eq!(n, 7 * 16);
        let mut seen = vec![false; n];
        for batch in plan.assignment(PhaseKind::Llm) {
            for e in batch {
                assert!(!seen[e.id]);
                seen[e.id] = true;
            }
        }
        assert!(seen.iter().all(|&x| x), "example lost after resize");
        // Both steps count toward the session's lifetime provenance.
        assert_eq!(s.stats().steps(), 2);
    }

    #[test]
    fn strategies_agree_on_the_same_batch() {
        // Solve strategy is an execution knob, not an algorithm change.
        let mbs = sample(8, 20, 9);
        let mut s = session(OrchestratorConfig::orchmllm(7168.0), 8);
        let serial = s.plan(&mbs, PlanOptions::serial());
        let parallel = s.plan(&mbs, PlanOptions::from_scratch());
        let incremental = s.plan(&mbs, PlanOptions::incremental());
        assert_eq!(serial.llm.route, parallel.llm.route);
        assert_eq!(serial.llm.assignment, parallel.llm.assignment);
        assert_eq!(serial.llm.route, incremental.llm.route);
        assert_eq!(
            serial.vision.plan.assignment,
            incremental.vision.plan.assignment
        );
        assert_eq!(serial.vision.out_route, incremental.vision.out_route);
    }

    #[test]
    fn auto_goes_warm_then_cached_and_reports_provenance() {
        let mbs = sample(8, 16, 14);
        let mut s = session(OrchestratorConfig::orchmllm(7168.0), 8);
        let first = s.plan(&mbs, PlanOptions::auto());
        let r1 = s.report().expect("report after plan").clone();
        assert_eq!(r1.step, 1);
        assert_eq!(r1.mode, ResolvedMode::Incremental);
        assert!(r1.cold(), "first step must plan cold: {r1:?}");
        assert!(!r1.step_cache_hit);
        assert!(r1.plan_nanos > 0);

        let second = s.plan(&mbs, PlanOptions::auto());
        let r2 = s.report().unwrap().clone();
        assert_eq!(r2.step, 2);
        assert!(r2.step_cache_hit, "recurring step must replay");
        assert_eq!(r2.sources, [PlanSource::Cached; 3]);
        assert_eq!(r2.cached_phases(), 3);
        assert_eq!(second.llm.route, first.llm.route);
        assert_eq!(second.llm.assignment, first.llm.assignment);

        let stats = s.stats();
        assert_eq!(stats.steps(), 2);
        assert!(stats.cache_hit_rate() > 0.0);
        assert!(stats.warm_rate() >= stats.cache_hit_rate());
        let ts = stats.plan_time_stats();
        assert!(ts.p50_ms > 0.0);
        assert!(ts.p99_ms >= ts.p50_ms);
        assert!(ts.cold_ms > 0.0, "step 1 classifies as cold");
    }

    #[test]
    fn cache_off_never_replays() {
        let mbs = sample(6, 12, 23);
        let mut s = session(OrchestratorConfig::orchmllm(7168.0), 6);
        let first = s.plan(&mbs, PlanOptions::auto().cache(false));
        let second = s.plan(&mbs, PlanOptions::auto().cache(false));
        let r = s.report().unwrap();
        assert!(!r.step_cache_hit);
        assert!(
            r.sources.iter().all(|s| *s != PlanSource::Cached),
            "cache off must not replay: {r:?}"
        );
        assert_eq!(s.cache_hit_rate(), 0.0);
        // Determinism still holds: a twin session fed the same two
        // calls produces the same two plans (the second may differ
        // from the first — warm repair is allowed to improve it).
        let mut twin = session(OrchestratorConfig::orchmllm(7168.0), 6);
        let tfirst = twin.plan(&mbs, PlanOptions::auto().cache(false));
        let tsecond = twin.plan(&mbs, PlanOptions::auto().cache(false));
        assert_eq!(first.llm.assignment, tfirst.llm.assignment);
        assert_eq!(second.llm.assignment, tsecond.llm.assignment);
    }

    #[test]
    fn sessions_are_deterministic_replicas() {
        // Two sessions fed the identical stream produce identical plans
        // — the SPMD property every DP rank relies on.
        let mut a = session(OrchestratorConfig::orchmllm(7168.0), 8);
        let mut b = session(OrchestratorConfig::orchmllm(7168.0), 8);
        let mut g = Generator::new(DatasetConfig::default(), 21);
        for _ in 0..4 {
            let mbs: Vec<Vec<Example>> =
                (0..8).map(|_| g.batch(24)).collect();
            let pa = a.plan(&mbs, PlanOptions::auto());
            let pb = b.plan(&mbs, PlanOptions::auto());
            assert_eq!(pa.llm.route, pb.llm.route);
            assert_eq!(pa.llm.assignment, pb.llm.assignment);
            assert_eq!(pa.vision.out_route, pb.vision.out_route);
            assert_eq!(
                a.report().unwrap().sources,
                b.report().unwrap().sources
            );
        }
    }

    #[test]
    fn no_balance_session_keeps_everything_home() {
        let mbs = sample(8, 20, 2);
        let mut s = session(OrchestratorConfig::no_balance(7168.0), 8);
        let plan = s.plan(&mbs, PlanOptions::auto());
        assert_eq!(plan.llm.route.moved(), 0);
        assert_eq!(plan.vision.plan.route.moved(), 0);
        assert_eq!(plan.vision.out_route.moved(), 0);
        assert_eq!(plan.audio.out_route.moved(), 0);
    }

    #[test]
    fn balanced_session_fixes_every_phase() {
        let mbs = sample(16, 30, 1);
        let mut s = session(OrchestratorConfig::orchmllm(3584.0 * 2.0), 16);
        let plan = s.plan(&mbs, PlanOptions::auto());
        let lin = CostModel::Linear { alpha: 1.0 };
        for phase in PhaseKind::ALL {
            let imb = lin.imbalance(plan.assignment(phase));
            assert!(imb < 1.25, "{}: imbalance {imb}", phase.name());
        }
    }

    #[test]
    fn balancer_override_flows_through_the_session() {
        let cfg = OrchestratorConfig::orchmllm(7168.0)
            .with_balancer(registry::must("kk"));
        assert_eq!(cfg.llm_balancer.name(), "kk");
        let mbs = sample(4, 10, 11);
        let mut s = session(cfg, 4);
        let plan = s.plan(&mbs, PlanOptions::auto());
        assert_eq!(
            plan.assignment(PhaseKind::Llm)
                .iter()
                .map(|b| b.len())
                .sum::<usize>(),
            plan.examples.len()
        );
    }

    #[test]
    fn depth_is_a_session_property() {
        let cfg = PipelineConfig { depth: 3, plan_cache_size: 16 };
        let s = PlanSession::new(
            OrchestratorConfig::orchmllm(7168.0),
            cfg,
            Topology::h100(4),
        );
        assert_eq!(s.depth(), 3);
        assert_eq!(s.pipeline_config(), cfg);
        assert_eq!(s.topology().instances, 4);
    }

    #[test]
    fn tolerance_gate_is_monotone_in_the_band() {
        // Identical cold first step → identical histories; the second
        // step's warm acceptance is then a pure function of
        // (lens, d, prev, tolerance): the transfer + repair result is
        // tolerance-independent, only the certification gate moves, so
        // a phase the 0-band warm-accepts is always warm-accepted by a
        // wider band too.
        let mut wide = session(OrchestratorConfig::orchmllm(7168.0), 6);
        let mut zero = session(OrchestratorConfig::orchmllm(7168.0), 6);
        let mut g = Generator::new(DatasetConfig::default(), 33);
        let step1: Vec<Vec<Example>> =
            (0..6).map(|_| g.batch(20)).collect();
        let step2: Vec<Vec<Example>> =
            (0..6).map(|_| g.batch(20)).collect();
        wide.plan(&step1, PlanOptions::auto().tolerance(1e6));
        zero.plan(&step1, PlanOptions::auto().tolerance(0.0));
        assert!(wide.report().unwrap().cold());
        assert!(zero.report().unwrap().cold());
        wide.plan(&step2, PlanOptions::auto().tolerance(1e6));
        zero.plan(&step2, PlanOptions::auto().tolerance(0.0));
        let wr = wide.report().unwrap();
        let zr = zero.report().unwrap();
        for (phase, (w, z)) in
            wr.sources.iter().zip(zr.sources.iter()).enumerate()
        {
            if *z == PlanSource::Warm {
                assert_eq!(
                    *w,
                    PlanSource::Warm,
                    "phase {phase}: 0-band accepted but wide band did not"
                );
            }
        }
        assert!(
            wide.stats().warm_rate() >= zero.stats().warm_rate(),
            "wide {} < zero {}",
            wide.stats().warm_rate(),
            zero.stats().warm_rate()
        );
        assert_eq!(wr.tolerance, 1e6);
        assert_eq!(zr.tolerance, 0.0);
    }
}
