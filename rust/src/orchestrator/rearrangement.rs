//! The rearrangement Π as explicit, composable data.
//!
//! A `Rearrangement` records, per global example id, which DP instance
//! holds the example before and after the All-to-All. Because the maps
//! are stored explicitly, the inverse `Π⁻¹` and the composition
//! `Π_M ∘ Π_Eₖ⁻¹` of paper §6 are cheap array operations — and the
//! composed map is exactly one All-to-All instead of two, which is the
//! communication-halving claim of Rearrangement Composition.

use crate::comm::topology::Topology;
use crate::comm::volume::VolumeMatrix;

/// An example-level relocation plan between two placements.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Rearrangement {
    /// `from[g]` = instance currently holding example g.
    pub from: Vec<usize>,
    /// `to[g]` = instance that must hold example g afterwards.
    pub to: Vec<usize>,
}

impl Rearrangement {
    pub fn new(from: Vec<usize>, to: Vec<usize>) -> Rearrangement {
        assert_eq!(from.len(), to.len());
        Rearrangement { from, to }
    }

    pub fn len(&self) -> usize {
        self.from.len()
    }

    pub fn is_empty(&self) -> bool {
        self.from.is_empty()
    }

    /// The identity rearrangement over a placement.
    pub fn identity(placement: Vec<usize>) -> Rearrangement {
        Rearrangement { from: placement.clone(), to: placement }
    }

    /// Π⁻¹: route every example back where it came from.
    pub fn inverse(&self) -> Rearrangement {
        Rearrangement { from: self.to.clone(), to: self.from.clone() }
    }

    /// Composition `other ∘ self⁻¹`-style chaining as used in §6:
    /// `self` placed examples at `self.to`; `next` expects them at
    /// `next.from` and delivers to `next.to`. Composing skips the
    /// intermediate hop: route directly `self.to → next.to`.
    ///
    /// Panics if the two plans disagree about the intermediate
    /// placement (`self.to` vs `next.from`) — that would be a logic bug
    /// in the orchestrator.
    pub fn compose(&self, next: &Rearrangement) -> Rearrangement {
        assert_eq!(self.len(), next.len(), "composition arity mismatch");
        assert_eq!(
            self.to, next.from,
            "intermediate placements disagree"
        );
        Rearrangement { from: self.from.clone(), to: next.to.clone() }
    }

    /// Number of examples that actually move.
    pub fn moved(&self) -> usize {
        self.from
            .iter()
            .zip(&self.to)
            .filter(|(f, t)| f != t)
            .count()
    }

    /// Send-volume matrix given per-example payload sizes.
    pub fn volume(&self, d: usize, payload: &[f64]) -> VolumeMatrix {
        let mut v = VolumeMatrix::zeros(d);
        self.volume_into(d, payload, &mut v);
        v
    }

    /// Allocation-free variant: accumulate into a reused matrix.
    pub fn volume_into(
        &self,
        d: usize,
        payload: &[f64],
        v: &mut VolumeMatrix,
    ) {
        assert_eq!(payload.len(), self.len());
        v.reset(d);
        for g in 0..self.len() {
            v.add(self.from[g], self.to[g], payload[g]);
        }
    }

    /// Total bytes crossing node boundaries (Fig.-13 metric) under the
    /// *physical* placement (no logical-batch indirection here: `to`
    /// already names physical instances).
    pub fn inter_node_bytes(&self, topo: &Topology, payload: &[f64]) -> f64 {
        let mut total = 0.0;
        for g in 0..self.len() {
            if !topo.same_node(self.from[g], self.to[g]) {
                total += payload[g];
            }
        }
        total
    }

    /// Max over instances of bytes sent off-node — the Eq.-5 quantity
    /// that dominates All-to-All latency and the Fig.-13 metric.
    pub fn max_inter_node_bytes(&self, topo: &Topology, payload: &[f64])
        -> f64 {
        let mut per_inst = vec![0.0f64; topo.instances];
        for g in 0..self.len() {
            if !topo.same_node(self.from[g], self.to[g]) {
                per_inst[self.from[g]] += payload[g];
            }
        }
        per_inst.into_iter().fold(0.0, f64::max)
    }

    /// Remap destinations through a node-wise permutation
    /// (`perm[logical_batch]` = physical instance).
    pub fn permuted(&self, perm: &[usize]) -> Rearrangement {
        Rearrangement {
            from: self.from.clone(),
            to: self.to.iter().map(|&b| perm[b]).collect(),
        }
    }

    /// The `(example, dst)` pairs instance `rank` must submit to an
    /// All-to-All transport round to realize this rearrangement —
    /// loopback (stay-on-rank) moves included, since the transport
    /// short-circuits them. This is the bridge between a planned Π and
    /// a `Transport::all_to_all` call (see the conformance suite and
    /// `benches/comm_transports.rs`).
    pub fn sends_from(&self, rank: usize) -> Vec<(usize, usize)> {
        (0..self.len())
            .filter(|&g| self.from[g] == rank)
            .map(|g| (g, self.to[g]))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn inverse_roundtrips() {
        let r = Rearrangement::new(vec![0, 0, 1, 2], vec![1, 2, 0, 2]);
        let inv = r.inverse();
        assert_eq!(inv.from, r.to);
        assert_eq!(inv.to, r.from);
        assert_eq!(r.inverse().inverse(), r);
    }

    #[test]
    fn compose_skips_intermediate_hop() {
        // Encoder dispatch: examples at [0,0,1] balanced to [1,0,0];
        // LLM dispatch expects them back at origin then sends to [0,1,1].
        let enc = Rearrangement::new(vec![0, 0, 1], vec![1, 0, 0]);
        let back = enc.inverse();
        let llm = Rearrangement::new(vec![0, 0, 1], vec![0, 1, 1]);
        let naive_hops = back.moved() + llm.moved();
        let composed = back.compose(&llm);
        assert_eq!(composed.from, vec![1, 0, 0]);
        assert_eq!(composed.to, vec![0, 1, 1]);
        assert!(composed.moved() <= naive_hops);
    }

    #[test]
    #[should_panic(expected = "intermediate placements disagree")]
    fn compose_checks_placements() {
        let a = Rearrangement::new(vec![0], vec![1]);
        let b = Rearrangement::new(vec![0], vec![1]);
        let _ = a.compose(&b);
    }

    #[test]
    fn sends_partition_the_examples() {
        let r = Rearrangement::new(vec![0, 0, 1, 2], vec![1, 0, 2, 2]);
        assert_eq!(r.sends_from(0), vec![(0, 1), (1, 0)]);
        assert_eq!(r.sends_from(1), vec![(2, 2)]);
        assert_eq!(r.sends_from(2), vec![(3, 2)]);
        // Every example appears exactly once across ranks.
        let total: usize = (0..3).map(|k| r.sends_from(k).len()).sum();
        assert_eq!(total, r.len());
    }

    #[test]
    fn volume_accumulates_payloads() {
        let r = Rearrangement::new(vec![0, 0, 1], vec![1, 1, 1]);
        let v = r.volume(2, &[10.0, 5.0, 3.0]);
        assert_eq!(v.get(0, 1), 15.0);
        assert_eq!(v.get(1, 1), 3.0);
    }

    #[test]
    fn prop_compose_is_associative_on_placements() {
        check("compose associativity", 100, |g| {
            let d = g.usize(2, 6);
            let n = g.usize(1, 30);
            let p0: Vec<usize> = (0..n).map(|_| g.usize(0, d)).collect();
            let p1: Vec<usize> = (0..n).map(|_| g.usize(0, d)).collect();
            let p2: Vec<usize> = (0..n).map(|_| g.usize(0, d)).collect();
            let p3: Vec<usize> = (0..n).map(|_| g.usize(0, d)).collect();
            let a = Rearrangement::new(p0.clone(), p1.clone());
            let b = Rearrangement::new(p1, p2.clone());
            let c = Rearrangement::new(p2, p3);
            let left = a.compose(&b).compose(&c);
            let right = a.compose(&b.compose(&c));
            assert_eq!(left, right);
        });
    }

    #[test]
    fn prop_inverse_cancels_moves() {
        check("inverse cancels", 100, |g| {
            let d = g.usize(2, 5);
            let n = g.usize(1, 20);
            let p0: Vec<usize> = (0..n).map(|_| g.usize(0, d)).collect();
            let p1: Vec<usize> = (0..n).map(|_| g.usize(0, d)).collect();
            let r = Rearrangement::new(p0.clone(), p1);
            let round = r.compose(&r.inverse());
            assert_eq!(round.from, p0);
            assert_eq!(round.to, p0);
            assert_eq!(round.moved(), 0);
        });
    }
}
