//! # OrchMLLM — batch post-balancing orchestration for MLLM training
//!
//! A production-shaped reproduction of *OrchMLLM: Orchestrate Multimodal
//! Data with Batch Post-Balancing to Accelerate Multimodal Large Language
//! Model Training* (CS.DC 2025) as a three-layer Rust + JAX + Pallas
//! stack:
//!
//! * **Layer 3 (this crate)** — the paper's system contribution: the
//!   [`balance`] post-balancing algorithms behind the pluggable
//!   [`balance::Balancer`] trait + registry, the [`comm`] node-wise
//!   all-to-all communicator behind the pluggable
//!   [`comm::transport::Transport`] trait + registry (in-process
//!   channels or loopback-TCP sockets, with per-backend α/β
//!   calibration in [`comm::calibrate`]), the [`nodewise`]
//!   rearrangement ILP, and the
//!   [`orchestrator`] that wires them into the multimodal training
//!   workflow — planning phases in parallel on reusable scratch,
//!   replanning incrementally from each step's predecessor
//!   ([`balance::Balancer::plan_incremental`] + the sketch-keyed
//!   [`balance::cache::PlanCache`]), and deep-buffering steps through
//!   the [`orchestrator::pipeline::StepPipeline`]. The [`sim`]
//!   discrete-event cluster simulator regenerates every table and
//!   figure of the paper's evaluation; the [`trainer`] runs a real
//!   tiny-MLLM end to end over the [`runtime`] PJRT client.
//! * **Layer 2** — `python/compile/model.py`: the multimodal model
//!   (vision encoder, audio encoder, LLM backbone) in JAX, AOT-lowered to
//!   HLO text artifacts once at build time.
//! * **Layer 1** — `python/compile/kernels/`: Pallas flash-attention and
//!   fused-layernorm kernels called by every submodule.
//!
//! Python never runs on the training path: after `make artifacts` the
//! rust binary is self-contained.
//!
//! See `DESIGN.md` for the full system inventory and the experiment index
//! mapping each paper table/figure to a bench target.

pub mod balance;
pub mod comm;
pub mod config;
pub mod data;
pub mod metrics;
pub mod model;
pub mod nodewise;
pub mod orchestrator;
pub mod sim;
pub mod trainer;
pub mod util;

pub mod runtime;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
