//! Per-worker PJRT runtime: compile HLO-text artifacts once, execute
//! many times.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::runtime::xla_stub as xla;

use super::manifest::{ArtifactSpec, Manifest, Slot};
use super::tensor::{DType, HostTensor};

/// A loaded runtime: PJRT CPU client + compiled executables. One per
/// worker thread (the client is not `Send`).
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Load the manifest and compile the named artifacts (all when
    /// `names` is empty).
    pub fn load(dir: &Path, names: &[&str]) -> Result<Runtime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PjRtClient::cpu: {e:?}"))?;
        let mut exes = HashMap::new();
        for spec in &manifest.artifacts {
            if !names.is_empty() && !names.contains(&spec.name.as_str()) {
                continue;
            }
            let proto = xla::HloModuleProto::from_text_file(
                spec.file
                    .to_str()
                    .ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| {
                anyhow!("parsing {}: {e:?}", spec.file.display())
            })?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e:?}", spec.name))?;
            exes.insert(spec.name.clone(), exe);
        }
        Ok(Runtime { manifest, client, exes })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn has(&self, name: &str) -> bool {
        self.exes.contains_key(name)
    }

    /// Execute an artifact. Inputs are given as host tensors in the
    /// manifest's slot order, with parameter lists already flattened by
    /// the caller. Outputs come back as host tensors in slot order
    /// (parameter/gradient lists flattened likewise).
    pub fn execute(
        &self,
        spec: &ArtifactSpec,
        inputs: &[HostTensor],
    ) -> Result<Vec<HostTensor>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        self.execute_literals(spec, &literals.iter().collect::<Vec<_>>())
    }

    /// Execute with pre-built literals (hot path: the trainer caches
    /// parameter literals across bucket chunks and refreshes them only
    /// after the optimizer step — see EXPERIMENTS.md §Perf).
    pub fn execute_literals(
        &self,
        spec: &ArtifactSpec,
        literals: &[&xla::Literal],
    ) -> Result<Vec<HostTensor>> {
        let exe = self
            .exes
            .get(&spec.name)
            .ok_or_else(|| anyhow!("artifact '{}' not compiled", spec.name))?;
        let result = exe
            .execute::<&xla::Literal>(literals)
            .map_err(|e| anyhow!("executing {}: {e:?}", spec.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {}: {e:?}", spec.name))?;
        // aot.py lowers with return_tuple=True: unpack the tuple into
        // the manifest's output slots.
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow!("untuple {}: {e:?}", spec.name))?;
        let expected = self.output_arity(spec);
        if parts.len() != expected {
            anyhow::bail!(
                "{}: expected {expected} outputs, got {}",
                spec.name,
                parts.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        let mut idx = 0;
        for slot in &spec.outputs {
            match slot {
                Slot::Tensor { shape, dtype, .. } => {
                    out.push(HostTensor::from_literal(
                        &parts[idx], shape, *dtype,
                    )?);
                    idx += 1;
                }
                Slot::Params { sub } => {
                    for p in &self.manifest.params[sub] {
                        out.push(HostTensor::from_literal(
                            &parts[idx],
                            &p.shape,
                            DType::F32,
                        )?);
                        idx += 1;
                    }
                }
            }
        }
        Ok(out)
    }

    /// Number of flattened outputs an artifact produces.
    pub fn output_arity(&self, spec: &ArtifactSpec) -> usize {
        spec.outputs
            .iter()
            .map(|s| match s {
                Slot::Tensor { .. } => 1,
                Slot::Params { sub } => self.manifest.params[sub].len(),
            })
            .sum()
    }

    /// Load a submodule's initial parameters from the AOT blobs.
    pub fn load_params(&self, sub: &str) -> Result<Vec<HostTensor>> {
        self.manifest
            .params
            .get(sub)
            .ok_or_else(|| anyhow!("unknown submodule '{sub}'"))?
            .iter()
            .map(|p| {
                HostTensor::read_f32_file(&p.file, &p.shape)
                    .with_context(|| format!("param {}", p.name))
            })
            .collect()
    }
}
