//! PJRT runtime: load `artifacts/*.hlo.txt` (AOT-lowered by
//! `python/compile/aot.py`) and execute them on the CPU PJRT client.
//!
//! Python never runs here — the interchange is HLO *text* plus a JSON
//! manifest describing parameter ordering, artifact signatures, and
//! bucket shapes (see `/opt/xla-example/README.md` for why text, not
//! serialized protos).
//!
//! `PjRtClient` is thread-local (`Rc` inside the xla crate), so each DP
//! worker owns a full [`engine::Runtime`] — matching the
//! process-per-GPU layout of real clusters.

pub mod engine;
pub mod manifest;
pub mod tensor;
pub mod xla_stub;

pub use engine::Runtime;
pub use manifest::Manifest;
pub use tensor::{DType, HostTensor};
