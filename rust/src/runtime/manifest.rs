//! The AOT manifest: the shape/ordering contract between
//! `python/compile/aot.py` and the rust trainer.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

use super::tensor::DType;

/// Model hyper-parameters mirrored from `model.py`'s `ModelConfig`.
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub name: String,
    pub vocab: usize,
    pub d_llm: usize,
    pub max_seq: usize,
    pub patch_dim: usize,
    pub vis_group: usize,
    pub max_vis: usize,
    pub mel_dim: usize,
    pub aud_stride: usize,
    pub max_aud: usize,
    pub param_count: usize,
    pub seed: u64,
}

/// One parameter tensor's spec.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub file: PathBuf,
}

/// One input/output slot of an artifact.
#[derive(Clone, Debug)]
pub enum Slot {
    /// The flattened parameter (or gradient) list of a submodule.
    Params { sub: String },
    /// A named tensor with static shape.
    Tensor { role: String, shape: Vec<usize>, dtype: DType },
}

/// One compiled artifact's signature.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    /// Bucket dims (phase-specific meaning; empty for optimizers).
    pub bucket: Vec<usize>,
    pub inputs: Vec<Slot>,
    pub outputs: Vec<Slot>,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config: ModelInfo,
    pub params: BTreeMap<String, Vec<ParamSpec>>,
    pub artifacts: Vec<ArtifactSpec>,
}

fn parse_slot(j: &Json) -> Result<Slot> {
    if let Some(sub) = j.get("sub").as_str() {
        return Ok(Slot::Params { sub: sub.to_string() });
    }
    let role = j
        .get("role")
        .as_str()
        .ok_or_else(|| anyhow!("slot missing role/sub: {j:?}"))?
        .to_string();
    let shape = j
        .get("shape")
        .as_arr()
        .ok_or_else(|| anyhow!("slot '{role}' missing shape"))?
        .iter()
        .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad dim")))
        .collect::<Result<Vec<_>>>()?;
    let dtype = DType::parse(j.get("dtype").as_str().unwrap_or("f32"))?;
    Ok(Slot::Tensor { role, shape, dtype })
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow!("{}: {e}", path.display()))?;

        let c = j.get("config");
        let need = |k: &str| -> Result<usize> {
            c.get(k)
                .as_usize()
                .ok_or_else(|| anyhow!("manifest config missing '{k}'"))
        };
        let config = ModelInfo {
            name: c.get("name").as_str().unwrap_or("?").to_string(),
            vocab: need("vocab")?,
            d_llm: need("d_llm")?,
            max_seq: need("max_seq")?,
            patch_dim: need("patch_dim")?,
            vis_group: need("vis_group")?,
            max_vis: need("max_vis")?,
            mel_dim: need("mel_dim")?,
            aud_stride: need("aud_stride")?,
            max_aud: need("max_aud")?,
            param_count: need("param_count")?,
            seed: c.get("seed").as_i64().unwrap_or(0) as u64,
        };

        let mut params = BTreeMap::new();
        let pobj = j
            .get("params")
            .as_obj()
            .ok_or_else(|| anyhow!("manifest missing params"))?;
        for (sub, list) in pobj {
            let specs = list
                .as_arr()
                .ok_or_else(|| anyhow!("params[{sub}] not a list"))?
                .iter()
                .map(|p| {
                    Ok(ParamSpec {
                        name: p
                            .get("name")
                            .as_str()
                            .unwrap_or("?")
                            .to_string(),
                        shape: p
                            .get("shape")
                            .as_arr()
                            .ok_or_else(|| anyhow!("param missing shape"))?
                            .iter()
                            .map(|v| {
                                v.as_usize()
                                    .ok_or_else(|| anyhow!("bad dim"))
                            })
                            .collect::<Result<Vec<_>>>()?,
                        file: dir.join(
                            p.get("file")
                                .as_str()
                                .ok_or_else(|| anyhow!("param missing file"))?,
                        ),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            params.insert(sub.clone(), specs);
        }

        let artifacts = j
            .get("artifacts")
            .as_arr()
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
            .iter()
            .map(|a| {
                Ok(ArtifactSpec {
                    name: a
                        .get("name")
                        .as_str()
                        .ok_or_else(|| anyhow!("artifact missing name"))?
                        .to_string(),
                    file: dir.join(
                        a.get("file")
                            .as_str()
                            .ok_or_else(|| anyhow!("artifact missing file"))?,
                    ),
                    bucket: a
                        .get("bucket")
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|v| v.as_usize())
                        .collect(),
                    inputs: a
                        .get("inputs")
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .map(parse_slot)
                        .collect::<Result<Vec<_>>>()?,
                    outputs: a
                        .get("outputs")
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .map(parse_slot)
                        .collect::<Result<Vec<_>>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        if artifacts.is_empty() {
            bail!("manifest has no artifacts — rerun `make artifacts`");
        }
        Ok(Manifest { dir: dir.to_path_buf(), config, params, artifacts })
    }

    /// Find an artifact by exact name.
    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))
    }

    /// Find the (unique, for the test config) artifact whose name starts
    /// with a prefix, e.g. `vision_fwd`.
    pub fn artifact_with_prefix(&self, prefix: &str)
        -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name.starts_with(prefix))
            .ok_or_else(|| anyhow!("no artifact with prefix '{prefix}'"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a miniature manifest.json in a temp dir.
    fn write_fixture() -> PathBuf {
        let dir = std::env::temp_dir().join("orchmllm_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let text = r#"{
          "config": {"name":"t","vocab":16,"d_llm":8,"max_seq":32,
                     "patch_dim":4,"vis_group":2,"max_vis":8,
                     "mel_dim":4,"aud_stride":2,"max_aud":8,
                     "param_count":100,"seed":0},
          "params": {"llm": [{"name":"w","shape":[2,2],"file":"params/llm/000.bin"}]},
          "artifacts": [
            {"name":"llm_step_1x8x2x2","file":"llm.hlo.txt","bucket":[1,8,2,2],
             "inputs":[{"kind":"params","sub":"llm"},
                       {"role":"token_ids","shape":[1,8],"dtype":"i32"}],
             "outputs":[{"role":"loss_sum","shape":[],"dtype":"f32"},
                        {"kind":"grads","sub":"llm"}]}
          ]
        }"#;
        std::fs::write(dir.join("manifest.json"), text).unwrap();
        dir
    }

    #[test]
    fn parses_fixture() {
        let m = Manifest::load(&write_fixture()).unwrap();
        assert_eq!(m.config.vocab, 16);
        assert_eq!(m.params["llm"].len(), 1);
        assert_eq!(m.params["llm"][0].shape, vec![2, 2]);
        let a = m.artifact_with_prefix("llm_step").unwrap();
        assert_eq!(a.bucket, vec![1, 8, 2, 2]);
        assert_eq!(a.inputs.len(), 2);
        match &a.inputs[0] {
            Slot::Params { sub } => assert_eq!(sub, "llm"),
            _ => panic!("expected params slot"),
        }
        match &a.inputs[1] {
            Slot::Tensor { role, shape, dtype } => {
                assert_eq!(role, "token_ids");
                assert_eq!(shape, &[1, 8]);
                assert_eq!(*dtype, DType::I32);
            }
            _ => panic!("expected tensor slot"),
        }
    }

    #[test]
    fn missing_artifact_errors() {
        let m = Manifest::load(&write_fixture()).unwrap();
        assert!(m.artifact("nope").is_err());
        assert!(m.artifact_with_prefix("nope").is_err());
    }

    #[test]
    fn real_manifest_parses_if_built() {
        // Exercised against the checked-out artifacts when present.
        let dir = Path::new("artifacts/test");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(dir).unwrap();
            assert!(m.artifacts.len() >= 8);
            assert!(m.params.contains_key("vision"));
            assert!(m.params.contains_key("audio"));
            assert!(m.params.contains_key("llm"));
        }
    }
}
