//! Offline stand-in for the `xla` PJRT binding crate.
//!
//! The runtime layer was written against the `xla` crate's API
//! (`PjRtClient`, `Literal`, `HloModuleProto`, …), which is not
//! available in offline build environments. This module mirrors exactly
//! the API surface the crate uses so everything compiles and the
//! planning/orchestration stack — the paper's contribution — runs and
//! tests fully. Host-side data marshalling (`Literal` construction,
//! reshape, readback) is implemented for real; only the PJRT
//! client/compile/execute entry points fail, with a clear error, so
//! `Runtime::load` degrades gracefully and every trainer test that
//! needs compiled artifacts skips exactly as it does when
//! `make artifacts` has not run.
//!
//! Swapping in the real binding: add the `xla` crate to
//! `rust/Cargo.toml` and replace the `use crate::runtime::xla_stub as
//! xla;` alias in `runtime/engine.rs`, `runtime/tensor.rs`, and
//! `trainer/worker.rs`. No other code changes — the signatures match.

use std::fmt;

/// Error type matching the binding's `Result<_, E: Debug>` shape.
#[derive(Clone, Debug)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable<T>(what: &str) -> Result<T, XlaError> {
    Err(XlaError(format!(
        "{what} unavailable: built against the bundled xla stub (no PJRT \
         binding in this environment); see DESIGN.md §Runtime"
    )))
}

/// Element storage for [`Literal`].
#[derive(Clone, Debug, PartialEq)]
pub enum Elems {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Elems {
    fn len(&self) -> usize {
        match self {
            Elems::F32(v) => v.len(),
            Elems::I32(v) => v.len(),
        }
    }
}

/// Native element types a [`Literal`] can hold.
pub trait NativeElem: Copy {
    fn wrap(v: Vec<Self>) -> Elems;
    fn slice(e: &Elems) -> Option<&[Self]>;
}

impl NativeElem for f32 {
    fn wrap(v: Vec<Self>) -> Elems {
        Elems::F32(v)
    }
    fn slice(e: &Elems) -> Option<&[Self]> {
        match e {
            Elems::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeElem for i32 {
    fn wrap(v: Vec<Self>) -> Elems {
        Elems::I32(v)
    }
    fn slice(e: &Elems) -> Option<&[Self]> {
        match e {
            Elems::I32(v) => Some(v),
            _ => None,
        }
    }
}

/// Host literal: dense data + dims. Fully functional.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    elems: Elems,
    dims: Vec<i64>,
}

impl Literal {
    pub fn scalar<T: NativeElem>(v: T) -> Literal {
        Literal { elems: T::wrap(vec![v]), dims: Vec::new() }
    }

    pub fn vec1<T: NativeElem>(v: &[T]) -> Literal {
        Literal { elems: T::wrap(v.to_vec()), dims: vec![v.len() as i64] }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, XlaError> {
        let want: i64 = dims.iter().product();
        if want as usize != self.elems.len() {
            return Err(XlaError(format!(
                "reshape {:?} -> {:?}: element count mismatch",
                self.dims, dims
            )));
        }
        Ok(Literal { elems: self.elems.clone(), dims: dims.to_vec() })
    }

    pub fn to_vec<T: NativeElem>(&self) -> Result<Vec<T>, XlaError> {
        T::slice(&self.elems)
            .map(|s| s.to_vec())
            .ok_or_else(|| XlaError("literal dtype mismatch".into()))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, XlaError> {
        unavailable("tuple literals")
    }
}

/// Parsed HLO module (stub: parsing requires the real binding).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        unavailable("HLO text parsing")
    }
}

/// An XLA computation handle.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client (stub: construction always fails, so callers degrade at
/// load time with a clear message rather than deep in execution).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, XlaError> {
        unavailable("compilation")
    }
}

/// A compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        unavailable("execution")
    }
}

/// A device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        unavailable("device readback")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrips_host_data() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3, 2]).is_err());
        let s = Literal::scalar(7i32);
        assert_eq!(s.to_vec::<i32>().unwrap(), vec![7]);
    }

    #[test]
    fn pjrt_entry_points_fail_with_clear_errors() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("stub"), "{err}");
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }
}
