//! Host-side tensors: the currency between the trainer's worker threads
//! (which exchange raw `Vec<f32>`/`Vec<i32>` over the collective
//! engine) and PJRT literals.

use anyhow::{bail, Context, Result};

use crate::runtime::xla_stub as xla;

/// Element type (the AOT manifest uses "f32"/"i32").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "f32" => DType::F32,
            "i32" => DType::I32,
            other => bail!("unsupported dtype '{other}'"),
        })
    }

    pub fn size(&self) -> usize {
        4
    }
}

/// A dense host tensor in row-major order.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn zeros_f32(shape: &[usize]) -> HostTensor {
        HostTensor {
            shape: shape.to_vec(),
            data: TensorData::F32(vec![0.0; shape.iter().product()]),
        }
    }

    pub fn zeros_i32(shape: &[usize]) -> HostTensor {
        HostTensor {
            shape: shape.to_vec(),
            data: TensorData::I32(vec![0; shape.iter().product()]),
        }
    }

    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { shape: shape.to_vec(), data: TensorData::F32(data) }
    }

    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { shape: shape.to_vec(), data: TensorData::I32(data) }
    }

    pub fn scalar_f32(v: f32) -> HostTensor {
        HostTensor { shape: vec![], data: TensorData::F32(vec![v]) }
    }

    pub fn dtype(&self) -> DType {
        match &self.data {
            TensorData::F32(_) => DType::F32,
            TensorData::I32(_) => DType::I32,
        }
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn f32s(&self) -> &[f32] {
        match &self.data {
            TensorData::F32(v) => v,
            _ => panic!("expected f32 tensor"),
        }
    }

    pub fn f32s_mut(&mut self) -> &mut [f32] {
        match &mut self.data {
            TensorData::F32(v) => v,
            _ => panic!("expected f32 tensor"),
        }
    }

    pub fn i32s(&self) -> &[i32] {
        match &self.data {
            TensorData::I32(v) => v,
            _ => panic!("expected i32 tensor"),
        }
    }

    pub fn i32s_mut(&mut self) -> &mut [i32] {
        match &mut self.data {
            TensorData::I32(v) => v,
            _ => panic!("expected i32 tensor"),
        }
    }

    /// Elementwise add (gradient accumulation).
    pub fn add_assign(&mut self, other: &HostTensor) {
        assert_eq!(self.shape, other.shape);
        match (&mut self.data, &other.data) {
            (TensorData::F32(a), TensorData::F32(b)) => {
                for (x, y) in a.iter_mut().zip(b) {
                    *x += *y;
                }
            }
            _ => panic!("add_assign expects f32 tensors"),
        }
    }

    /// Read a raw little-endian f32 blob (an AOT param file).
    pub fn read_f32_file(path: &std::path::Path, shape: &[usize])
        -> Result<HostTensor> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let expect: usize = shape.iter().product::<usize>() * 4;
        if bytes.len() != expect {
            bail!(
                "{}: expected {expect} bytes for shape {shape:?}, got {}",
                path.display(),
                bytes.len()
            );
        }
        let data = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(HostTensor::from_f32(shape, data))
    }

    /// Convert to a PJRT literal.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            TensorData::F32(v) => {
                if self.shape.is_empty() {
                    xla::Literal::scalar(v[0])
                } else {
                    xla::Literal::vec1(v).reshape(&dims)?
                }
            }
            TensorData::I32(v) => {
                if self.shape.is_empty() {
                    xla::Literal::scalar(v[0])
                } else {
                    xla::Literal::vec1(v).reshape(&dims)?
                }
            }
        };
        Ok(lit)
    }

    /// Convert back from a PJRT literal.
    pub fn from_literal(lit: &xla::Literal, shape: &[usize], dtype: DType)
        -> Result<HostTensor> {
        Ok(match dtype {
            DType::F32 => HostTensor::from_f32(shape, lit.to_vec::<f32>()?),
            DType::I32 => HostTensor::from_i32(shape, lit.to_vec::<i32>()?),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let t = HostTensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.dtype(), DType::F32);
        assert_eq!(t.f32s()[4], 5.0);
    }

    #[test]
    fn add_assign_accumulates() {
        let mut a = HostTensor::from_f32(&[3], vec![1., 2., 3.]);
        let b = HostTensor::from_f32(&[3], vec![10., 20., 30.]);
        a.add_assign(&b);
        assert_eq!(a.f32s(), &[11., 22., 33.]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        HostTensor::from_f32(&[2, 2], vec![1.0]);
    }

    #[test]
    fn read_f32_file_roundtrip() {
        let dir = std::env::temp_dir().join("orchmllm_tensor_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bin");
        let vals = [1.5f32, -2.0, 3.25];
        let bytes: Vec<u8> =
            vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        std::fs::write(&path, bytes).unwrap();
        let t = HostTensor::read_f32_file(&path, &[3]).unwrap();
        assert_eq!(t.f32s(), &vals);
        assert!(HostTensor::read_f32_file(&path, &[4]).is_err());
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::parse("f32").unwrap(), DType::F32);
        assert_eq!(DType::parse("i32").unwrap(), DType::I32);
        assert!(DType::parse("f64").is_err());
    }
}
