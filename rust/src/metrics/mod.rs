//! Lightweight metrics: named timers, counters, and rolling step logs
//! used by the trainer and the bench harnesses.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::util::stats::Summary;

/// A registry of named duration samples and counters.
#[derive(Default)]
pub struct Metrics {
    timers: BTreeMap<String, Summary>,
    counters: BTreeMap<String, f64>,
}

/// RAII timer guard: records on drop.
pub struct TimerGuard<'a> {
    metrics: &'a mut Metrics,
    name: String,
    start: Instant,
}

impl Drop for TimerGuard<'_> {
    fn drop(&mut self) {
        let secs = self.start.elapsed().as_secs_f64();
        self.metrics
            .timers
            .entry(std::mem::take(&mut self.name))
            .or_default()
            .push(secs);
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Record an externally measured duration (seconds).
    pub fn record(&mut self, name: &str, secs: f64) {
        self.timers.entry(name.to_string()).or_default().push(secs);
    }

    /// Time a closure.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record(name, t0.elapsed().as_secs_f64());
        out
    }

    pub fn count(&mut self, name: &str, by: f64) {
        *self.counters.entry(name.to_string()).or_insert(0.0) += by;
    }

    pub fn counter(&self, name: &str) -> f64 {
        self.counters.get(name).copied().unwrap_or(0.0)
    }

    pub fn timer(&self, name: &str) -> Option<&Summary> {
        self.timers.get(name)
    }

    /// Human-readable dump, sorted by name.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for (name, s) in &self.timers {
            out.push_str(&format!(
                "{name:<32} n={:<6} mean={:>10.3}ms p99={:>10.3}ms\n",
                s.len(),
                s.mean() * 1e3,
                s.percentile(99.0) * 1e3,
            ));
        }
        for (name, v) in &self.counters {
            out.push_str(&format!("{name:<32} total={v}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let mut m = Metrics::new();
        m.record("step", 0.010);
        m.record("step", 0.020);
        m.count("tokens", 128.0);
        m.count("tokens", 64.0);
        assert_eq!(m.timer("step").unwrap().len(), 2);
        assert!((m.timer("step").unwrap().mean() - 0.015).abs() < 1e-12);
        assert_eq!(m.counter("tokens"), 192.0);
    }

    #[test]
    fn time_wraps_closures() {
        let mut m = Metrics::new();
        let v = m.time("work", || 42);
        assert_eq!(v, 42);
        assert_eq!(m.timer("work").unwrap().len(), 1);
    }

    #[test]
    fn render_contains_names() {
        let mut m = Metrics::new();
        m.record("abc", 1.0);
        m.count("xyz", 2.0);
        let s = m.render();
        assert!(s.contains("abc") && s.contains("xyz"));
    }
}
