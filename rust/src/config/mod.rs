//! Run configuration: JSON-loadable descriptions of simulator and
//! trainer runs (the crate's "config system").
//!
//! Example (see `examples/cluster_sim.rs` and the `orchmllm` CLI):
//!
//! ```json
//! {
//!   "kind": "sim",
//!   "system": "orchmllm",
//!   "model": "mllm-10b",
//!   "gpus": 128,
//!   "mini_batch": 60,
//!   "steps": 5,
//!   "seed": 42
//! }
//! ```

use crate::sim::engine::SystemKind;
use crate::util::json::Json;

/// A simulator run description.
#[derive(Clone, Debug, PartialEq)]
pub struct SimRunConfig {
    pub system: SystemKind,
    pub model: String,
    pub gpus: usize,
    pub mini_batch: usize,
    pub steps: usize,
    pub seed: u64,
    /// Registry balancer name overriding every phase (None = the
    /// system's tailored per-phase selection).
    pub balancer: Option<String>,
    /// Accelerator to price against (`--gpu`), a
    /// [`GpuSpec::NAMES`](crate::sim::GpuSpec::NAMES) entry.
    pub gpu: String,
    /// Bubble co-scheduling: model the LLM phase as a 1F1B pipeline
    /// with this many stages (`--pp-stages`) and pack encoder work into
    /// its bubbles. `None` = the flat (no-PP) pricing model.
    pub pp_stages: Option<usize>,
    /// Microbatches in flight per pipeline (`--microbatches`); only
    /// meaningful with `pp_stages`. `None` = the default of 8.
    pub microbatches: Option<usize>,
}

/// Microbatch count `--pp-stages` implies when `--microbatches` is
/// left unset.
pub const DEFAULT_MICROBATCHES: usize = 8;

impl Default for SimRunConfig {
    fn default() -> Self {
        SimRunConfig {
            system: SystemKind::OrchMllm,
            model: "mllm-10b".into(),
            gpus: 128,
            mini_batch: 60,
            steps: 5,
            seed: 42,
            balancer: None,
            gpu: "h100".into(),
            pp_stages: None,
            microbatches: None,
        }
    }
}

impl SimRunConfig {
    pub fn from_json(j: &Json) -> anyhow::Result<SimRunConfig> {
        let d = SimRunConfig::default();
        let system = match j.get("system").as_str() {
            Some(s) => SystemKind::parse(s)
                .ok_or_else(|| anyhow::anyhow!("unknown system '{s}'"))?,
            None => d.system,
        };
        Ok(SimRunConfig {
            system,
            model: j
                .get("model")
                .as_str()
                .unwrap_or(&d.model)
                .to_string(),
            gpus: j.get("gpus").as_usize().unwrap_or(d.gpus),
            mini_batch: j
                .get("mini_batch")
                .as_usize()
                .unwrap_or(d.mini_batch),
            steps: j.get("steps").as_usize().unwrap_or(d.steps),
            seed: j.get("seed").as_i64().unwrap_or(d.seed as i64) as u64,
            balancer: j.get("balancer").as_str().map(str::to_string),
            gpu: j.get("gpu").as_str().unwrap_or(&d.gpu).to_string(),
            pp_stages: j.get("pp_stages").as_usize(),
            microbatches: j.get("microbatches").as_usize(),
        })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::str("sim")),
            ("system", Json::str(match self.system {
                SystemKind::OrchMllm => "orchmllm",
                SystemKind::NoBalance => "no-balance",
                SystemKind::LlmOnly => "llm-only",
                SystemKind::AllGatherComm => "allgather",
                SystemKind::AllPad => "all-pad",
                SystemKind::AllRmpad => "all-rmpad",
                SystemKind::NoNodewise => "no-nodewise",
                SystemKind::NoComposition => "no-composition",
                SystemKind::Megatron => "megatron",
            })),
            ("model", Json::str(&self.model)),
            ("gpus", Json::num(self.gpus as f64)),
            ("mini_batch", Json::num(self.mini_batch as f64)),
            ("steps", Json::num(self.steps as f64)),
            ("seed", Json::num(self.seed as f64)),
            (
                "balancer",
                match &self.balancer {
                    Some(b) => Json::str(b),
                    None => Json::Null,
                },
            ),
            ("gpu", Json::str(&self.gpu)),
            (
                "pp_stages",
                match self.pp_stages {
                    Some(p) => Json::num(p as f64),
                    None => Json::Null,
                },
            ),
            (
                "microbatches",
                match self.microbatches {
                    Some(m) => Json::num(m as f64),
                    None => Json::Null,
                },
            ),
        ])
    }

    pub fn load(path: &str) -> anyhow::Result<SimRunConfig> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        Self::from_json(&j)
    }

    /// Validate user-supplied knobs (GPU name, pipeline shape) with a
    /// printable error — the same contract as
    /// [`TrainRunConfig::validate`].
    pub fn validate(&self) -> anyhow::Result<()> {
        if crate::sim::GpuSpec::by_name(&self.gpu).is_none() {
            anyhow::bail!(
                "unknown gpu '{}' (available: {:?})",
                self.gpu,
                crate::sim::GpuSpec::NAMES
            );
        }
        match (self.pp_stages, self.microbatches) {
            (Some(pp), m) => {
                crate::sim::pipeline::PipelineParallelConfig::uniform(
                    pp,
                    m.unwrap_or(DEFAULT_MICROBATCHES),
                )
                .validate()
                .map_err(|e| anyhow::anyhow!(e))?;
            }
            (None, Some(_)) => {
                anyhow::bail!(
                    "--microbatches requires --pp-stages (the flat \
                     pricing model has no microbatch schedule)"
                );
            }
            (None, None) => {}
        }
        Ok(())
    }

    /// The pipeline configuration this run requests, priced against
    /// `model` on `gpu` — `None` unless `pp_stages` was set.
    pub fn pipeline(
        &self,
        model: &crate::model::config::MllmConfig,
        gpu: &crate::sim::GpuSpec,
    ) -> Option<crate::sim::pipeline::PipelineParallelConfig> {
        self.pp_stages.map(|pp| {
            crate::sim::pipeline::PipelineParallelConfig::from_model(
                model,
                gpu,
                pp,
                self.microbatches.unwrap_or(DEFAULT_MICROBATCHES),
            )
        })
    }
}

/// A real-trainer run description (consumed by `trainer::TrainConfig`).
#[derive(Clone, Debug, PartialEq)]
pub struct TrainRunConfig {
    /// Artifact directory (e.g. `artifacts/test`).
    pub artifacts: String,
    pub workers: usize,
    pub mini_batch: usize,
    pub steps: usize,
    pub lr: f64,
    pub seed: u64,
    pub balance: bool,
    /// Registry balancer name overriding every phase (None = the
    /// default tailored selection; ignored when `balance` is false).
    pub balancer: Option<String>,
    /// Planned-but-unconsumed steps the pipeline keeps in flight
    /// (`--pipeline-depth`; 1 = double buffering, 2–3 absorb planning
    /// spikes).
    pub pipeline_depth: usize,
    /// Capacity of each planning cache in the pipeline's step history
    /// (`--plan-cache-size`; 0 disables caching).
    pub plan_cache_size: usize,
    /// Comm backend carrying the run (`--transport`): a name from
    /// `comm::transport::registry` (`inproc`, `tcp`, …).
    pub transport: String,
    /// Calibrate α/β on the live transport before training and plan
    /// against the measured topology (`--calibrate-comm`).
    pub calibrate_comm: bool,
    /// Smallest world size an elastic run may shrink to
    /// (`--min-world`): losing ranks below this floor aborts the run
    /// with a clear error instead of continuing under-parallel.
    pub min_world: usize,
    /// Plan-archive directory to warm-start the planning session from
    /// (`--archive-in`, elastic runs). A fingerprint mismatch degrades
    /// to a cold start with a logged reason; it never fails the run.
    pub archive_in: Option<String>,
    /// Plan-archive directory the (minimum-id surviving) member exports
    /// to on clean exit and after a world transition (`--archive-out`,
    /// elastic runs).
    pub archive_out: Option<String>,
}

impl Default for TrainRunConfig {
    fn default() -> Self {
        TrainRunConfig {
            artifacts: "artifacts/test".into(),
            workers: 4,
            mini_batch: 4,
            steps: 20,
            lr: 0.05,
            seed: 0,
            balance: true,
            balancer: None,
            pipeline_depth: 2,
            plan_cache_size:
                crate::balance::cache::DEFAULT_PLAN_CACHE_SIZE,
            transport: "inproc".into(),
            calibrate_comm: false,
            min_world: 1,
            archive_in: None,
            archive_out: None,
        }
    }
}

impl TrainRunConfig {
    pub fn from_json(j: &Json) -> TrainRunConfig {
        let d = TrainRunConfig::default();
        TrainRunConfig {
            artifacts: j
                .get("artifacts")
                .as_str()
                .unwrap_or(&d.artifacts)
                .to_string(),
            workers: j.get("workers").as_usize().unwrap_or(d.workers),
            mini_batch: j
                .get("mini_batch")
                .as_usize()
                .unwrap_or(d.mini_batch),
            steps: j.get("steps").as_usize().unwrap_or(d.steps),
            lr: j.get("lr").as_f64().unwrap_or(d.lr),
            seed: j.get("seed").as_i64().unwrap_or(0) as u64,
            balance: j.get("balance").as_bool().unwrap_or(d.balance),
            balancer: j.get("balancer").as_str().map(str::to_string),
            pipeline_depth: j
                .get("pipeline_depth")
                .as_usize()
                .unwrap_or(d.pipeline_depth),
            plan_cache_size: j
                .get("plan_cache_size")
                .as_usize()
                .unwrap_or(d.plan_cache_size),
            transport: j
                .get("transport")
                .as_str()
                .unwrap_or(&d.transport)
                .to_string(),
            calibrate_comm: j
                .get("calibrate_comm")
                .as_bool()
                .unwrap_or(d.calibrate_comm),
            min_world: j
                .get("min_world")
                .as_usize()
                .unwrap_or(d.min_world),
            archive_in: j
                .get("archive_in")
                .as_str()
                .map(str::to_string),
            archive_out: j
                .get("archive_out")
                .as_str()
                .map(str::to_string),
        }
    }

    /// The pipeline configuration this run requests.
    pub fn pipeline_config(
        &self,
    ) -> crate::orchestrator::pipeline::PipelineConfig {
        crate::orchestrator::pipeline::PipelineConfig {
            depth: self.pipeline_depth,
            plan_cache_size: self.plan_cache_size,
        }
    }

    /// Validate user-supplied knobs (depth bounds, cache size,
    /// transport and balancer names) with a printable error.
    pub fn validate(&self) -> anyhow::Result<()> {
        self.pipeline_config()
            .validate()
            .map_err(|e| anyhow::anyhow!(e))?;
        if crate::comm::transport::registry::create(&self.transport)
            .is_none()
        {
            anyhow::bail!(
                "unknown transport '{}' (registered: {:?})",
                self.transport,
                crate::comm::transport::registry::NAMES
            );
        }
        if let Some(name) = &self.balancer {
            if !crate::balance::select::is_valid_spec(name) {
                anyhow::bail!(
                    "unknown balancer '{name}' (registered: {:?}, plus \
                     'auto')",
                    crate::balance::registry::NAMES
                );
            }
        }
        if self.min_world < 1 || self.min_world > self.workers {
            anyhow::bail!(
                "--min-world must satisfy 1 <= min_world <= workers \
                 (got min_world {} with {} workers)",
                self.min_world,
                self.workers
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_config_roundtrips() {
        let c = SimRunConfig {
            system: SystemKind::Megatron,
            model: "mllm-84b".into(),
            gpus: 2560,
            mini_batch: 30,
            steps: 10,
            seed: 7,
            balancer: Some("kk".into()),
            gpu: "a100".into(),
            pp_stages: Some(4),
            microbatches: Some(16),
        };
        let j = c.to_json();
        let back = SimRunConfig::from_json(&j).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn defaults_fill_missing_fields() {
        let j = Json::parse(r#"{"gpus": 64}"#).unwrap();
        let c = SimRunConfig::from_json(&j).unwrap();
        assert_eq!(c.gpus, 64);
        assert_eq!(c.model, "mllm-10b");
        assert_eq!(c.system, SystemKind::OrchMllm);
        assert_eq!(c.gpu, "h100");
        assert_eq!(c.pp_stages, None);
        assert_eq!(c.microbatches, None);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn sim_config_validates_gpu_and_pipeline_shape() {
        let ok = SimRunConfig {
            gpu: "a100".into(),
            pp_stages: Some(4),
            microbatches: Some(8),
            ..SimRunConfig::default()
        };
        assert!(ok.validate().is_ok());
        // --pp-stages alone implies the default microbatch count.
        let implied = SimRunConfig {
            pp_stages: Some(2),
            ..SimRunConfig::default()
        };
        assert!(implied.validate().is_ok());

        let bad_gpu = SimRunConfig {
            gpu: "tpu-v5".into(),
            ..SimRunConfig::default()
        };
        let err = bad_gpu.validate().unwrap_err().to_string();
        assert!(err.contains("unknown gpu"), "{err}");
        assert!(err.contains("h100"), "{err}");

        let zero_pp = SimRunConfig {
            pp_stages: Some(0),
            ..SimRunConfig::default()
        };
        let err = zero_pp.validate().unwrap_err().to_string();
        assert!(err.contains("--pp-stages"), "{err}");

        let too_few_micro = SimRunConfig {
            pp_stages: Some(8),
            microbatches: Some(4),
            ..SimRunConfig::default()
        };
        let err = too_few_micro.validate().unwrap_err().to_string();
        assert!(err.contains("--microbatches"), "{err}");

        let orphan_micro = SimRunConfig {
            microbatches: Some(16),
            ..SimRunConfig::default()
        };
        let err = orphan_micro.validate().unwrap_err().to_string();
        assert!(err.contains("requires --pp-stages"), "{err}");
    }

    #[test]
    fn sim_config_builds_a_priced_pipeline() {
        use crate::model::config::MllmConfig;
        use crate::sim::GpuSpec;
        let model = MllmConfig::mllm_10b();
        let gpu = GpuSpec::h100();
        let none = SimRunConfig::default();
        assert!(none.pipeline(&model, &gpu).is_none());
        let c = SimRunConfig {
            pp_stages: Some(4),
            ..SimRunConfig::default()
        };
        let p = c.pipeline(&model, &gpu).unwrap();
        assert_eq!(p.pp_stages, 4);
        assert_eq!(p.microbatches, DEFAULT_MICROBATCHES);
        assert!(p.validate().is_ok());
    }

    #[test]
    fn bad_system_errors() {
        let j = Json::parse(r#"{"system": "zzz"}"#).unwrap();
        assert!(SimRunConfig::from_json(&j).is_err());
    }

    #[test]
    fn train_config_parses() {
        let j = Json::parse(
            r#"{"workers": 2, "balance": false, "lr": 0.1}"#,
        )
        .unwrap();
        let c = TrainRunConfig::from_json(&j);
        assert_eq!(c.workers, 2);
        assert!(!c.balance);
        assert_eq!(c.lr, 0.1);
        // New knobs default sensibly and validate.
        assert_eq!(c.pipeline_depth, 2);
        assert!(c.plan_cache_size > 0);
        assert_eq!(c.transport, "inproc");
        assert!(!c.calibrate_comm);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn train_config_validates_transport_names() {
        let j = Json::parse(
            r#"{"transport": "tcp", "calibrate_comm": true}"#,
        )
        .unwrap();
        let c = TrainRunConfig::from_json(&j);
        assert_eq!(c.transport, "tcp");
        assert!(c.calibrate_comm);
        assert!(c.validate().is_ok());

        let bad = TrainRunConfig {
            transport: "nccl".into(),
            ..TrainRunConfig::default()
        };
        let err = bad.validate().unwrap_err().to_string();
        assert!(err.contains("unknown transport"), "{err}");
        assert!(err.contains("inproc"), "{err}");
    }

    #[test]
    fn train_config_validates_balancer_specs() {
        for name in ["auto", "greedy", "ilp", "none"] {
            let c = TrainRunConfig {
                balancer: Some(name.into()),
                ..TrainRunConfig::default()
            };
            assert!(c.validate().is_ok(), "{name} rejected");
        }
        let bad = TrainRunConfig {
            balancer: Some("not-an-algorithm".into()),
            ..TrainRunConfig::default()
        };
        let err = bad.validate().unwrap_err().to_string();
        assert!(err.contains("unknown balancer"), "{err}");
        assert!(err.contains("auto"), "{err}");
    }

    #[test]
    fn train_config_bounds_the_min_world_floor() {
        let j = Json::parse(r#"{"workers": 4, "min_world": 2}"#).unwrap();
        let c = TrainRunConfig::from_json(&j);
        assert_eq!(c.min_world, 2);
        assert!(c.validate().is_ok());

        // Default floor is 1 (any world is acceptable).
        assert_eq!(TrainRunConfig::default().min_world, 1);

        for bad_floor in [0, 5] {
            let bad = TrainRunConfig {
                workers: 4,
                min_world: bad_floor,
                ..TrainRunConfig::default()
            };
            let err = bad.validate().unwrap_err().to_string();
            assert!(err.contains("--min-world"), "{err}");
        }
    }

    #[test]
    fn train_config_parses_and_validates_pipeline_knobs() {
        let j = Json::parse(
            r#"{"pipeline_depth": 3, "plan_cache_size": 16}"#,
        )
        .unwrap();
        let c = TrainRunConfig::from_json(&j);
        assert_eq!(c.pipeline_depth, 3);
        assert_eq!(c.plan_cache_size, 16);
        assert!(c.validate().is_ok());

        let bad = TrainRunConfig {
            pipeline_depth: 0,
            ..TrainRunConfig::default()
        };
        assert!(bad.validate().is_err());
        let bad = TrainRunConfig {
            pipeline_depth: 99,
            ..TrainRunConfig::default()
        };
        assert!(bad.validate().is_err());
    }
}
