//! Communication-volume accounting for rearrangements.
//!
//! `V[i][j]` = bytes (or tokens) instance `i` must send to instance `j`
//! to realize a rearrangement Π (paper §5.2.2). The Node-wise
//! Rearrangement Algorithm permutes *columns* of V (destination batch
//! order) to push volume intra-node.

use super::topology::Topology;

/// Dense d×d send-volume matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct VolumeMatrix {
    pub d: usize,
    /// Row-major: `v[i * d + j]` = volume from instance i to instance j.
    v: Vec<f64>,
}

impl VolumeMatrix {
    pub fn zeros(d: usize) -> VolumeMatrix {
        VolumeMatrix { d, v: vec![0.0; d * d] }
    }

    /// Re-dimension and zero in place, keeping the allocation — the
    /// planner's per-step reuse path (see
    /// [`crate::balance::scratch::PlanScratch`]).
    pub fn reset(&mut self, d: usize) {
        self.d = d;
        self.v.clear();
        self.v.resize(d * d, 0.0);
    }

    #[inline]
    pub fn get(&self, from: usize, to: usize) -> f64 {
        self.v[from * self.d + to]
    }

    #[inline]
    pub fn add(&mut self, from: usize, to: usize, vol: f64) {
        self.v[from * self.d + to] += vol;
    }

    /// Total volume an instance sends off-node under a given destination
    /// column order (`perm[j]` = which physical instance hosts logical
    /// destination batch j). Diagonal (self) traffic is free.
    pub fn inter_node_send(
        &self,
        topo: &Topology,
        perm: &[usize],
        from: usize,
    ) -> f64 {
        let mut total = 0.0;
        for j in 0..self.d {
            let dst = perm[j];
            if !topo.same_node(from, dst) {
                total += self.get(from, j);
            }
        }
        total
    }

    /// Max over instances of inter-node send volume — the Eq. (5)
    /// quantity that dominates All-to-All latency.
    pub fn max_inter_node(&self, topo: &Topology, perm: &[usize]) -> f64 {
        (0..self.d)
            .map(|i| self.inter_node_send(topo, perm, i))
            .fold(0.0, f64::max)
    }

    /// Total (sum) inter-node volume — the Fig. 13 metric.
    pub fn total_inter_node(&self, topo: &Topology, perm: &[usize]) -> f64 {
        (0..self.d)
            .map(|i| self.inter_node_send(topo, perm, i))
            .sum()
    }

    /// Max single send volume of any instance (diagonal excluded): the
    /// Eq. (4) ceiling `max_i L_i` when built from batch lengths.
    pub fn max_send(&self) -> f64 {
        (0..self.d)
            .map(|i| {
                (0..self.d)
                    .filter(|&j| j != i)
                    .map(|j| self.get(i, j))
                    .sum::<f64>()
            })
            .fold(0.0, f64::max)
    }

    /// Identity column order.
    pub fn identity_perm(d: usize) -> Vec<usize> {
        (0..d).collect()
    }
}

/// Build the volume matrix of a rearrangement: `placements[g]` gives
/// (source instance, dest batch) per example and `lens[g]` its payload.
pub fn volume_of_rearrangement(
    d: usize,
    moves: impl Iterator<Item = (usize, usize, f64)>,
) -> VolumeMatrix {
    let mut v = VolumeMatrix::zeros(d);
    for (from, to_batch, vol) in moves {
        v.add(from, to_batch, vol);
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_and_query() {
        let mut v = VolumeMatrix::zeros(4);
        v.add(0, 1, 10.0);
        v.add(0, 1, 5.0);
        v.add(2, 3, 7.0);
        assert_eq!(v.get(0, 1), 15.0);
        assert_eq!(v.get(1, 0), 0.0);
        assert_eq!(v.max_send(), 15.0);
    }

    #[test]
    fn inter_node_respects_permutation() {
        // 4 instances, 2 per node. Volume only from 0 to logical batch 1.
        let topo = Topology {
            instances: 4,
            per_node: 2,
            intra_bw: 100.0,
            inter_bw: 10.0,
            base_latency: 0.0,
        };
        let mut v = VolumeMatrix::zeros(4);
        v.add(0, 1, 42.0);
        // Identity: batch 1 lives on instance 1 (same node as 0) => 0.
        let id = VolumeMatrix::identity_perm(4);
        assert_eq!(v.max_inter_node(&topo, &id), 0.0);
        // Swap batches 1 and 2: batch 1 now on instance 2 (other node).
        let perm = vec![0, 2, 1, 3];
        assert_eq!(v.max_inter_node(&topo, &perm), 42.0);
        assert_eq!(v.total_inter_node(&topo, &perm), 42.0);
    }

    #[test]
    fn self_traffic_is_free() {
        let topo = Topology {
            instances: 2,
            per_node: 1,
            intra_bw: 1.0,
            inter_bw: 1.0,
            base_latency: 0.0,
        };
        let mut v = VolumeMatrix::zeros(2);
        v.add(0, 0, 99.0);
        let id = VolumeMatrix::identity_perm(2);
        assert_eq!(v.max_inter_node(&topo, &id), 0.0);
    }
}
