//! Hierarchical cluster topology (paper Fig. 6).
//!
//! DP instances are grouped onto nodes; instances on one node talk over
//! NVLink-class bandwidth, instances on different nodes share the node's
//! NIC allocation (InfiniBand/Ethernet-class). The disparity between the
//! two is what the Node-wise Rearrangement Algorithm (§5.2.2) exploits.

/// Cluster shape and link bandwidths.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Topology {
    /// Total DP instances (d).
    pub instances: usize,
    /// Instances per node (c).
    pub per_node: usize,
    /// Intra-node point-to-point bandwidth, bytes/s (NVLink class).
    pub intra_bw: f64,
    /// Inter-node bandwidth allocated per instance, bytes/s (IB class).
    pub inter_bw: f64,
    /// Per-collective launch latency in seconds (NCCL-ish overhead).
    pub base_latency: f64,
}

impl Topology {
    /// The paper's testbed: H100 nodes, 900 GB/s bidirectional NVLink,
    /// 8×400 Gbps IB per node (≈50 GB/s per instance).
    pub fn h100(instances: usize) -> Topology {
        Topology {
            instances,
            per_node: 8,
            intra_bw: 450.0e9, // unidirectional NVLink
            inter_bw: 50.0e9,  // 400 Gbps per GPU
            base_latency: 20e-6,
        }
    }

    /// Node index of an instance.
    pub fn node_of(&self, instance: usize) -> usize {
        instance / self.per_node
    }

    /// Whether two instances share a node.
    pub fn same_node(&self, a: usize, b: usize) -> bool {
        self.node_of(a) == self.node_of(b)
    }

    pub fn nodes(&self) -> usize {
        (self.instances + self.per_node - 1) / self.per_node
    }

    /// Point-to-point bandwidth between two instances.
    pub fn p2p_bw(&self, a: usize, b: usize) -> f64 {
        if self.same_node(a, b) {
            self.intra_bw
        } else {
            self.inter_bw
        }
    }

    /// The minimum p2p bandwidth in the system (the Eq.-4 bound's B_min):
    /// inter-node unless the whole cluster is one node.
    pub fn min_bw(&self) -> f64 {
        if self.nodes() > 1 {
            self.inter_bw
        } else {
            self.intra_bw
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn node_grouping() {
        let t = Topology::h100(32);
        assert_eq!(t.nodes(), 4);
        assert_eq!(t.node_of(0), 0);
        assert_eq!(t.node_of(7), 0);
        assert_eq!(t.node_of(8), 1);
        assert!(t.same_node(0, 7));
        assert!(!t.same_node(7, 8));
    }

    #[test]
    fn bandwidth_hierarchy() {
        let t = Topology::h100(16);
        assert!(t.p2p_bw(0, 1) > t.p2p_bw(0, 8));
        assert_eq!(t.min_bw(), t.inter_bw);
        let single = Topology::h100(8);
        assert_eq!(single.min_bw(), single.intra_bw);
    }

    #[test]
    fn partial_last_node_counts() {
        let t = Topology::h100(20);
        assert_eq!(t.nodes(), 3);
    }
}
