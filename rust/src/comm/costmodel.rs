//! Analytic collective cost models — paper Eq. (3), (4), (5) and
//! Appendix B.
//!
//! These price the two communicator designs the paper compares:
//!
//! * **All-Gather of payloads** (the strawman, §5.2.1): every instance
//!   receives every mini-batch — `O ∝ (d-1)·max_i(L_i)` with ring
//!   scheduling, and each instance must hold the whole global batch.
//! * **All-to-All of payloads** (the paper's communicator): lengths-only
//!   All-Gather (negligible) + point-to-point moves of exactly the
//!   examples that change instance — bounded by `max_i(L_i) / B_min`
//!   regardless of d (Eq. 4), and refined by Eq. (5) to the max
//!   *inter-node* send volume under hierarchical bandwidth.

use super::topology::Topology;
use super::volume::VolumeMatrix;

/// A priced collective operation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CollectiveCost {
    /// Wall-clock seconds.
    pub seconds: f64,
    /// Peak extra bytes a single instance must buffer.
    pub peak_bytes: f64,
}

/// Ring All-Gather of per-instance payloads `bytes[i]` (Eq. 3 / App. B).
///
/// Each of the (d-1) ring steps forwards a chunk whose size is bounded by
/// the largest payload, over the slowest link in the ring (inter-node
/// when the ring spans nodes). Every instance ends up buffering the sum
/// of all payloads.
pub fn allgather_cost(topo: &Topology, bytes: &[usize]) -> CollectiveCost {
    let d = bytes.len();
    if d <= 1 {
        return CollectiveCost { seconds: 0.0, peak_bytes: 0.0 };
    }
    let max_bytes = *bytes.iter().max().unwrap() as f64;
    let bw = topo.min_bw();
    let seconds =
        topo.base_latency + (d as f64 - 1.0) * max_bytes / bw;
    let peak_bytes: f64 = bytes.iter().map(|&b| b as f64).sum();
    CollectiveCost { seconds, peak_bytes }
}

/// All-to-All realizing a rearrangement with send-volume matrix `v`
/// (bytes), under destination batch order `perm` (Eq. 5 / App. B).
///
/// Intra-node and inter-node traffic proceed in parallel; each class is
/// dominated by the busiest sender in that class. Peak extra memory is
/// the largest receive volume (staging buffers for incoming examples).
pub fn alltoall_cost(
    topo: &Topology,
    v: &VolumeMatrix,
    perm: &[usize],
) -> CollectiveCost {
    let d = v.d;
    let mut max_inter_send = 0.0f64;
    let mut max_intra_send = 0.0f64;
    let mut recv = vec![0.0f64; d];
    for i in 0..d {
        let mut inter = 0.0;
        let mut intra = 0.0;
        for j in 0..d {
            let dst = perm[j];
            let vol = v.get(i, j);
            if dst == i {
                continue; // stays local
            }
            if topo.same_node(i, dst) {
                intra += vol;
            } else {
                inter += vol;
            }
            recv[dst] += vol;
        }
        max_inter_send = max_inter_send.max(inter);
        max_intra_send = max_intra_send.max(intra);
    }
    let seconds = topo.base_latency
        + (max_inter_send / topo.inter_bw)
            .max(max_intra_send / topo.intra_bw);
    let peak_bytes = recv.iter().copied().fold(0.0, f64::max);
    CollectiveCost { seconds, peak_bytes }
}

/// Pairwise-exchange All-to-All as the `tcp` loopback transport
/// schedules it: `d-1` sequential steps, each moving `bytes_per_peer`
/// to one peer while receiving the same from another (full duplex).
///
/// `base_latency` is the *per-collective* launch term (see
/// [`Topology::base_latency`]) and is charged once — a calibrated
/// topology ([`crate::comm::calibrate::Calibration::to_topology`])
/// fits α over whole timed collectives at a fixed `d`, so the per-step
/// latencies are already folded into it. This is the schedule-aware
/// prediction the comm bench compares against measured transport
/// latency.
pub fn pairwise_alltoall_cost(
    topo: &Topology,
    bytes_per_peer: f64,
) -> CollectiveCost {
    let d = topo.instances as f64;
    if d <= 1.0 {
        return CollectiveCost { seconds: 0.0, peak_bytes: 0.0 };
    }
    let bw = topo.min_bw();
    let seconds = topo.base_latency + (d - 1.0) * bytes_per_peer / bw;
    CollectiveCost {
        seconds,
        peak_bytes: (d - 1.0) * bytes_per_peer,
    }
}

/// Ring All-Reduce of `bytes` gradient bytes across `d` instances
/// (2(d-1)/d · bytes over the slowest link) — used by the simulator to
/// price the DP gradient synchronization.
pub fn allreduce_cost(topo: &Topology, bytes: f64) -> CollectiveCost {
    let d = topo.instances as f64;
    if d <= 1.0 {
        return CollectiveCost { seconds: 0.0, peak_bytes: 0.0 };
    }
    let bw = topo.min_bw();
    let seconds = topo.base_latency + 2.0 * (d - 1.0) / d * bytes / bw;
    CollectiveCost { seconds, peak_bytes: bytes }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo(d: usize) -> Topology {
        Topology::h100(d)
    }

    #[test]
    fn allgather_scales_with_d() {
        let b16 = vec![1_000_000usize; 16];
        let b64 = vec![1_000_000usize; 64];
        let c16 = allgather_cost(&topo(16), &b16);
        let c64 = allgather_cost(&topo(64), &b64);
        // (d-1) scaling: 63/15 ≈ 4.2x.
        assert!(c64.seconds / c16.seconds > 3.5);
        assert!(c64.peak_bytes > c16.peak_bytes);
    }

    #[test]
    fn alltoall_beats_allgather_at_scale() {
        // The §5.2.1 comparison: All-to-All must not scale with d.
        let d = 64;
        let t = topo(d);
        let payload = 1_000_000usize;
        let ag = allgather_cost(&t, &vec![payload; d]);
        // Worst-case rearrangement: everyone ships its whole batch to
        // one other instance.
        let mut v = VolumeMatrix::zeros(d);
        for i in 0..d {
            v.add(i, (i + 1) % d, payload as f64);
        }
        let a2a = alltoall_cost(&t, &v, &VolumeMatrix::identity_perm(d));
        assert!(
            a2a.seconds < ag.seconds / 10.0,
            "a2a {} vs ag {}",
            a2a.seconds,
            ag.seconds
        );
        assert!(a2a.peak_bytes < ag.peak_bytes / 10.0);
    }

    #[test]
    fn alltoall_intra_node_is_cheap() {
        let t = topo(16);
        let mut v = VolumeMatrix::zeros(16);
        // 0 -> 1 (same node) vs 0 -> 8 (cross node), same volume.
        v.add(0, 1, 1e9);
        let intra =
            alltoall_cost(&t, &v, &VolumeMatrix::identity_perm(16));
        let mut v2 = VolumeMatrix::zeros(16);
        v2.add(0, 8, 1e9);
        let inter =
            alltoall_cost(&t, &v2, &VolumeMatrix::identity_perm(16));
        assert!(inter.seconds > 5.0 * intra.seconds);
    }

    #[test]
    fn local_traffic_is_free() {
        let t = topo(8);
        let mut v = VolumeMatrix::zeros(8);
        for i in 0..8 {
            v.add(i, i, 1e12);
        }
        let c = alltoall_cost(&t, &v, &VolumeMatrix::identity_perm(8));
        assert!(c.seconds <= t.base_latency + 1e-12);
        assert_eq!(c.peak_bytes, 0.0);
    }

    #[test]
    fn allreduce_asymptote() {
        let t = topo(256);
        let c = allreduce_cost(&t, 1e9);
        // ~2 * bytes / bw for large d.
        let expect = 2.0 * 1e9 / t.inter_bw;
        assert!((c.seconds - expect).abs() / expect < 0.05);
    }

    #[test]
    fn degenerate_single_instance() {
        let t = topo(1);
        assert_eq!(allgather_cost(&t, &[123]).seconds, 0.0);
        assert_eq!(allreduce_cost(&t, 1e9).seconds, 0.0);
        assert_eq!(pairwise_alltoall_cost(&t, 1e9).seconds, 0.0);
    }

    #[test]
    fn pairwise_schedule_scales_with_steps() {
        let t4 = topo(4);
        let c4 = pairwise_alltoall_cost(&t4, 1e6);
        let c8 = pairwise_alltoall_cost(&topo(8), 1e6);
        // Launch latency charged once; bandwidth term scales with the
        // (d-1) sequential steps: 4 extra steps of 1 MB each.
        let extra = c8.seconds - c4.seconds;
        let want = 4.0 * 1e6 / t4.min_bw();
        assert!((extra - want).abs() / want < 1e-9, "extra {extra}");
        assert!(c8.peak_bytes > c4.peak_bytes);
    }
}
