//! File-based rendezvous: rank discovery and membership agreement for
//! multi-process worlds (the `tcp-multiproc` backend) and for the
//! elastic recovery protocol's epoch bumps.
//!
//! # Protocol
//!
//! All members share one directory (the trainer passes `--rdzv-dir` to
//! every `worker` process). Time is divided into **epochs**: epoch 0
//! is the launch rendezvous, and every recovery after a rank death
//! bumps the epoch. Within an epoch:
//!
//! 1. **Register.** Member `m` writes `ep{e}.m{m}` containing its
//!    listener address. The write is tmp-file + rename, so a scan
//!    never observes a half-written registration.
//! 2. **Seal.** Each joiner polls the directory until either every
//!    *expected* member has registered or the grace window expires,
//!    then attempts to write `ep{e}.commit` listing the members it
//!    observed. The commit is published with tmp-file + `hard_link`,
//!    so exactly one writer wins and every reader sees a complete
//!    file — the sealed membership is a single atomic decision no
//!    matter how many members race to make it.
//! 3. **Agree.** Everyone reads the commit. A member listed in it
//!    proceeds with the sealed world; a member that registered too
//!    late is **evicted** (error) — the world moved on without it,
//!    and rejoining at a later epoch is a policy decision for the
//!    layer above, not the rendezvous.
//!
//! Dense transport ranks are *positions in the sorted member list*;
//! the stable ids in the files survive shrinks so logs stay traceable
//! to launch-time ranks.
//!
//! Knobs: `ORCHMLLM_RDZV_TIMEOUT_SECS` bounds the whole join (default
//! 30, `0` disables the bound); the grace window (how long to wait for
//! missing expected members before sealing a shrunk world) defaults to
//! 2 s and is a struct field for tests.

use std::fs;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

/// Default total join timeout when `ORCHMLLM_RDZV_TIMEOUT_SECS` is
/// unset.
pub const DEFAULT_TIMEOUT_SECS: u64 = 30;
/// Default grace window before sealing without missing members.
pub const DEFAULT_GRACE_MILLIS: u64 = 2_000;

/// One member's sealed-world entry: stable id + listener address.
pub type Member = (usize, String);

/// File-based rendezvous over a shared directory.
#[derive(Clone, Debug)]
pub struct FileRendezvous {
    /// The shared directory; created on first use.
    pub dir: PathBuf,
    /// Total join deadline (`None` = unbounded).
    pub timeout: Option<Duration>,
    /// How long to wait for missing *expected* members before sealing
    /// the epoch with whoever registered.
    pub grace: Duration,
    /// Directory poll interval.
    pub poll: Duration,
}

impl FileRendezvous {
    /// Rendezvous rooted at `dir`, with `ORCHMLLM_RDZV_TIMEOUT_SECS`
    /// honored for the join bound (default 30 s, `0` = unbounded) —
    /// env parsing warns loudly on garbage, like the other comm knobs.
    pub fn new(dir: impl Into<PathBuf>) -> FileRendezvous {
        let parsed = std::env::var("ORCHMLLM_RDZV_TIMEOUT_SECS")
            .ok()
            .and_then(|raw| match raw.trim().parse::<u64>() {
                Ok(v) => Some(v),
                Err(_) => {
                    eprintln!(
                        "warning: ignoring unparsable \
                         ORCHMLLM_RDZV_TIMEOUT_SECS='{raw}', using the \
                         default ({DEFAULT_TIMEOUT_SECS}s)"
                    );
                    None
                }
            });
        let timeout = match parsed {
            Some(0) => None,
            Some(n) => Some(Duration::from_secs(n)),
            None => Some(Duration::from_secs(DEFAULT_TIMEOUT_SECS)),
        };
        FileRendezvous {
            dir: dir.into(),
            timeout,
            grace: Duration::from_millis(DEFAULT_GRACE_MILLIS),
            poll: Duration::from_millis(10),
        }
    }

    /// Join `epoch` as stable member `me`, advertising `addr`, and
    /// block until membership seals. Returns the sealed member list
    /// sorted by stable id.
    pub fn join(
        &self,
        epoch: u64,
        me: usize,
        addr: &str,
        expected: &[usize],
    ) -> Result<Vec<Member>> {
        fs::create_dir_all(&self.dir).with_context(|| {
            format!("creating rendezvous dir {}", self.dir.display())
        })?;
        self.write_atomic(
            &format!("ep{epoch}.m{me}"),
            &format!("ep{epoch}.m{me}.tmp"),
            addr,
        )
        .context("registering with the rendezvous")?;

        let start = Instant::now();
        let grace_deadline = start + self.grace;
        loop {
            if let Some(members) = self.read_commit(epoch)? {
                if !members.iter().any(|&(id, _)| id == me) {
                    bail!(
                        "rendezvous epoch {epoch}: member {me} arrived \
                         after membership sealed (evicted); sealed \
                         world: {:?}",
                        members.iter().map(|&(id, _)| id).collect::<Vec<_>>()
                    );
                }
                return Ok(members);
            }
            let registered = self.scan_registered(epoch)?;
            let complete = expected
                .iter()
                .all(|m| registered.iter().any(|&(id, _)| id == *m));
            if complete || Instant::now() >= grace_deadline {
                self.try_commit(epoch, me, &registered)?;
                continue; // next iteration reads the winning commit
            }
            if let Some(t) = self.timeout {
                if start.elapsed() > t {
                    bail!(
                        "rendezvous epoch {epoch}: timed out after {t:?} \
                         waiting for members {expected:?} \
                         (registered: {:?})",
                        registered
                            .iter()
                            .map(|&(id, _)| id)
                            .collect::<Vec<_>>()
                    );
                }
            }
            std::thread::sleep(self.poll);
        }
    }

    /// Write `name` atomically: full content to `tmp_name`, then
    /// rename into place (same directory, so the rename is atomic).
    fn write_atomic(
        &self,
        name: &str,
        tmp_name: &str,
        content: &str,
    ) -> Result<()> {
        let tmp = self.dir.join(tmp_name);
        fs::write(&tmp, content)
            .with_context(|| format!("writing {}", tmp.display()))?;
        let dst = self.dir.join(name);
        fs::rename(&tmp, &dst)
            .with_context(|| format!("publishing {}", dst.display()))?;
        Ok(())
    }

    /// All `ep{epoch}.m{id}` registrations currently visible, sorted
    /// by id. Filenames that do not parse (tmp files mid-rename on
    /// non-atomic filesystems, stray editor droppings) are skipped.
    fn scan_registered(&self, epoch: u64) -> Result<Vec<Member>> {
        let prefix = format!("ep{epoch}.m");
        let mut out: Vec<Member> = Vec::new();
        let entries = fs::read_dir(&self.dir).with_context(|| {
            format!("scanning rendezvous dir {}", self.dir.display())
        })?;
        for entry in entries {
            let entry = entry.context("reading rendezvous dir entry")?;
            let name = entry.file_name();
            let name = name.to_string_lossy();
            let Some(rest) = name.strip_prefix(&prefix) else {
                continue;
            };
            let Ok(id) = rest.parse::<usize>() else {
                continue; // tmp files and other suffixes
            };
            // A registration published by rename is complete; an empty
            // read means a foreign writer — skip it, the poll loop
            // will see the real content or time out.
            match fs::read_to_string(entry.path()) {
                Ok(addr) if !addr.trim().is_empty() => {
                    out.push((id, addr.trim().to_string()));
                }
                _ => continue,
            }
        }
        out.sort_by_key(|&(id, _)| id);
        Ok(out)
    }

    /// Publish the commit for `epoch` if nobody has yet: first writer
    /// wins via `hard_link` (fails with `AlreadyExists` if the commit
    /// is already published), and the linked file is complete before
    /// it becomes visible.
    fn try_commit(
        &self,
        epoch: u64,
        me: usize,
        members: &[Member],
    ) -> Result<()> {
        let commit = self.dir.join(format!("ep{epoch}.commit"));
        if commit.exists() {
            return Ok(());
        }
        let body: String = members
            .iter()
            .map(|(id, addr)| format!("{id} {addr}\n"))
            .collect();
        let tmp = self.dir.join(format!("ep{epoch}.commit.tmp{me}"));
        fs::write(&tmp, body)
            .with_context(|| format!("writing {}", tmp.display()))?;
        match fs::hard_link(&tmp, &commit) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {}
            Err(e) => {
                let _ = fs::remove_file(&tmp);
                return Err(anyhow!(e)).with_context(|| {
                    format!("publishing {}", commit.display())
                });
            }
        }
        let _ = fs::remove_file(&tmp);
        Ok(())
    }

    /// Read the sealed membership for `epoch`, if published.
    fn read_commit(&self, epoch: u64) -> Result<Option<Vec<Member>>> {
        let commit = self.dir.join(format!("ep{epoch}.commit"));
        let body = match fs::read_to_string(&commit) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(None)
            }
            Err(e) => {
                return Err(anyhow!(e)).with_context(|| {
                    format!("reading {}", commit.display())
                })
            }
        };
        let mut members = Vec::new();
        for line in body.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let (id, addr) = line.split_once(' ').ok_or_else(|| {
                anyhow!("corrupt rendezvous commit line: '{line}'")
            })?;
            let id: usize = id.parse().with_context(|| {
                format!("corrupt rendezvous commit id: '{line}'")
            })?;
            members.push((id, addr.to_string()));
        }
        members.sort_by_key(|&(id, _)| id);
        Ok(Some(members))
    }
}

/// A unique scratch directory for tests and spawned worlds:
/// `{temp}/orchmllm-rdzv-{pid}-{seq}`. Uniqueness comes from the pid
/// plus a process-wide counter, so parallel tests in one process and
/// across processes never collide.
pub fn scratch_dir(label: &str) -> PathBuf {
    use std::sync::atomic::{AtomicU64, Ordering};
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!(
        "orchmllm-rdzv-{label}-{}-{seq}",
        std::process::id()
    ))
}

/// Best-effort cleanup of a rendezvous directory (ignores errors: a
/// leaked scratch dir in `/tmp` must never fail a run).
pub fn cleanup(dir: &Path) {
    let _ = fs::remove_dir_all(dir);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::thread;

    fn quick(dir: PathBuf) -> FileRendezvous {
        FileRendezvous {
            dir,
            timeout: Some(Duration::from_secs(10)),
            grace: Duration::from_secs(5),
            poll: Duration::from_millis(2),
        }
    }

    #[test]
    fn concurrent_members_agree_on_the_sealed_world() {
        let dir = scratch_dir("agree");
        let rdzv = Arc::new(quick(dir.clone()));
        let joins: Vec<_> = (0..4)
            .map(|me| {
                let rdzv = Arc::clone(&rdzv);
                thread::spawn(move || {
                    rdzv.join(
                        0,
                        me,
                        &format!("127.0.0.1:{}", 9000 + me),
                        &[0, 1, 2, 3],
                    )
                })
            })
            .collect();
        let worlds: Vec<_> =
            joins.into_iter().map(|j| j.join().unwrap().unwrap()).collect();
        for w in &worlds {
            assert_eq!(w, &worlds[0], "members disagree on the world");
            assert_eq!(
                w.iter().map(|&(id, _)| id).collect::<Vec<_>>(),
                vec![0, 1, 2, 3]
            );
        }
        cleanup(&dir);
    }

    #[test]
    fn shrunk_epoch_seals_without_the_dead_member() {
        let dir = scratch_dir("shrink");
        let rdzv = Arc::new(quick(dir.clone()));
        // Epoch 3 recovery: survivors 0 and 2 expect only each other.
        let joins: Vec<_> = [0usize, 2]
            .into_iter()
            .map(|me| {
                let rdzv = Arc::clone(&rdzv);
                thread::spawn(move || {
                    rdzv.join(3, me, &format!("a{me}"), &[0, 2])
                })
            })
            .collect();
        for j in joins {
            let members = j.join().unwrap().unwrap();
            assert_eq!(
                members,
                vec![(0, "a0".to_string()), (2, "a2".to_string())]
            );
        }
        cleanup(&dir);
    }

    #[test]
    fn latecomers_are_evicted_after_the_grace_window() {
        let dir = scratch_dir("evict");
        let mut rdzv = quick(dir.clone());
        rdzv.grace = Duration::from_millis(30);
        // Member 0 expects member 9, which never shows: the grace
        // window expires and the epoch seals solo.
        let members = rdzv.join(1, 0, "a0", &[0, 9]).unwrap();
        assert_eq!(members, vec![(0, "a0".to_string())]);
        // Member 9 finally arrives: evicted, loudly.
        let err = rdzv.join(1, 9, "a9", &[0, 9]).unwrap_err().to_string();
        assert!(err.contains("evicted"), "{err}");
        cleanup(&dir);
    }

    #[test]
    fn join_times_out_instead_of_spinning_forever() {
        let dir = scratch_dir("timeout");
        let rdzv = FileRendezvous {
            dir: dir.clone(),
            timeout: Some(Duration::from_millis(60)),
            // Grace beyond the timeout: the seal path never triggers,
            // so the total deadline must.
            grace: Duration::from_secs(60),
            poll: Duration::from_millis(2),
        };
        let err = rdzv.join(0, 0, "a0", &[0, 1]).unwrap_err().to_string();
        assert!(err.contains("timed out"), "{err}");
        cleanup(&dir);
    }
}
