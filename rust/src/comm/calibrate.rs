//! Transport calibration: measure a live [`Transport`] backend with
//! synthetic collectives and fit the α/β cost-model line, so the
//! dispatcher's analytic estimates ([`super::costmodel`]) become
//! per-backend calibration targets instead of hard-coded constants.
//!
//! The α–β model prices one collective as `seconds = α + bytes / β`
//! (launch latency + bandwidth term) — exactly the shape of the
//! paper's Eq. 3/4 once the topology constants are substituted. This
//! module times `all_to_all` / `all_gather` rounds over a sweep of
//! payload sizes, takes the per-size minimum across repetitions (the
//! noise-robust estimator of intrinsic cost), and least-squares fits
//! the line. [`Calibration::to_topology`] then packages the fit as a
//! [`Topology`] the existing cost functions and the dispatcher consume
//! unchanged.

use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use super::topology::Topology;
use super::transport::{Transport, TransportFactory};

/// A fitted `seconds = α + bytes / β` line.
#[derive(Clone, Copy, Debug)]
pub struct FittedLine {
    /// Launch latency in seconds (the α of Eq. 3/4).
    pub alpha_s: f64,
    /// Effective bandwidth in bytes/second (the β).
    pub beta_bytes_per_s: f64,
}

/// Cap applied when the sweep shows no measurable bandwidth term
/// (payloads too small, or a backend faster than the clock): 1 TB/s.
/// Public so consumers can tell a real fitted slope from a clamped
/// degenerate one (`beta_bytes_per_s < BETA_CAP`).
pub const BETA_CAP: f64 = 1e12;

impl FittedLine {
    /// Predicted wall-clock for one collective moving `bytes`.
    pub fn seconds(&self, bytes: f64) -> f64 {
        self.alpha_s + bytes / self.beta_bytes_per_s
    }
}

/// Ordinary least squares over `(bytes, seconds)` samples. Degenerate
/// sweeps (one point, zero variance, negative slope from noise) clamp
/// to `β = BETA_CAP` rather than emitting a nonsensical negative
/// bandwidth; α is clamped non-negative.
pub fn fit_line(points: &[(f64, f64)]) -> FittedLine {
    let n = points.len() as f64;
    if points.is_empty() {
        return FittedLine { alpha_s: 0.0, beta_bytes_per_s: BETA_CAP };
    }
    let mean_x = points.iter().map(|p| p.0).sum::<f64>() / n;
    let mean_y = points.iter().map(|p| p.1).sum::<f64>() / n;
    let sxx: f64 =
        points.iter().map(|p| (p.0 - mean_x).powi(2)).sum();
    let sxy: f64 = points
        .iter()
        .map(|p| (p.0 - mean_x) * (p.1 - mean_y))
        .sum();
    let slope = if sxx > 0.0 { sxy / sxx } else { 0.0 };
    // When the slope degenerates (flat sweep or noise-negative), the
    // clamped slope must also be the one alpha is computed against —
    // otherwise a raw negative slope inflates the intercept.
    let (beta, alpha) = if slope > 1.0 / BETA_CAP {
        (1.0 / slope, (mean_y - slope * mean_x).max(0.0))
    } else {
        (BETA_CAP, (mean_y - mean_x / BETA_CAP).max(0.0))
    };
    FittedLine { alpha_s: alpha, beta_bytes_per_s: beta }
}

/// What to measure: payload sizes swept and repetitions per size.
#[derive(Clone, Debug)]
pub struct CalibrationSpec {
    pub payload_sizes: Vec<usize>,
    pub reps: usize,
}

impl Default for CalibrationSpec {
    fn default() -> Self {
        CalibrationSpec {
            payload_sizes: vec![1 << 10, 8 << 10, 64 << 10, 256 << 10],
            reps: 5,
        }
    }
}

impl CalibrationSpec {
    /// A cheap sweep for startup-time calibration (`--calibrate-comm`).
    pub fn quick() -> Self {
        CalibrationSpec {
            payload_sizes: vec![1 << 10, 16 << 10, 128 << 10],
            reps: 3,
        }
    }
}

/// Fitted α/β per collective for one backend at one world size.
#[derive(Clone, Debug)]
pub struct Calibration {
    pub transport: String,
    pub d: usize,
    pub all_to_all: FittedLine,
    pub all_gather: FittedLine,
    /// Raw `(bytes, seconds)` samples behind the fits (per-size minima),
    /// kept for reporting.
    pub all_to_all_points: Vec<(f64, f64)>,
    pub all_gather_points: Vec<(f64, f64)>,
}

impl Calibration {
    /// Package the fit as a [`Topology`] for the existing cost models
    /// and the dispatcher: measured α as the launch latency, measured
    /// all-to-all β as the link bandwidth. Single-host backends are
    /// flat, so intra- and inter-node bandwidth coincide.
    pub fn to_topology(&self, per_node: usize) -> Topology {
        let alpha =
            0.5 * (self.all_to_all.alpha_s + self.all_gather.alpha_s);
        Topology {
            instances: self.d,
            per_node: per_node.clamp(1, self.d.max(1)),
            intra_bw: self.all_to_all.beta_bytes_per_s,
            inter_bw: self.all_to_all.beta_bytes_per_s,
            base_latency: alpha,
        }
    }
}

/// One rank's measurement loop (SPMD: every rank runs it; rank 0's
/// samples are the ones fitted).
// orchlint: allow(collective-asymmetry): the early returns validate the
// shape of payloads the whole group just exchanged — every rank sees the
// same frames, so all ranks take the same exit; a genuinely wedged peer
// surfaces as Err from the collective itself.
fn measure(
    t: &dyn Transport,
    spec: &CalibrationSpec,
) -> Result<(Vec<(f64, f64)>, Vec<(f64, f64)>)> {
    let d = t.world_size();
    let rank = t.rank();
    let mut a2a = Vec::with_capacity(spec.payload_sizes.len());
    let mut ag = Vec::with_capacity(spec.payload_sizes.len());
    for &size in &spec.payload_sizes {
        let payload = vec![0xA5u8; size];
        let mut best_a2a = f64::INFINITY;
        let mut best_ag = f64::INFINITY;
        for _ in 0..spec.reps.max(1) {
            // The canonical post-balancing move: each rank ships one
            // payload to its successor (a shift rearrangement). Clones
            // happen outside the timed window.
            let sends = vec![((rank + 1) % d, payload.clone())];
            t.barrier()?;
            let t0 = Instant::now();
            let got = t
                .all_to_all_bytes(sends)
                .context("calibration all_to_all")?;
            best_a2a = best_a2a.min(t0.elapsed().as_secs_f64());
            if got.len() != 1 || got[0].1.len() != size {
                return Err(anyhow!(
                    "calibration all_to_all returned wrong payload"
                ));
            }
            let contrib = payload.clone();
            t.barrier()?;
            let t0 = Instant::now();
            let all = t
                .all_gather_bytes(contrib)
                .context("calibration all_gather")?;
            best_ag = best_ag.min(t0.elapsed().as_secs_f64());
            if all.len() != d {
                return Err(anyhow!(
                    "calibration all_gather returned {} contributions",
                    all.len()
                ));
            }
        }
        a2a.push((size as f64, best_a2a));
        ag.push((size as f64, best_ag));
    }
    Ok((a2a, ag))
}

/// Time synthetic collectives on a freshly connected world of `d`
/// ranks and fit α/β for each collective. Runs one thread per rank
/// through [`super::transport::run_world`] (the world is SPMD); rank
/// 0's timings feed the fit.
pub fn calibrate(
    factory: &dyn TransportFactory,
    d: usize,
    spec: &CalibrationSpec,
) -> Result<Calibration> {
    let name = factory.name().to_string();
    let results =
        super::transport::run_world(factory, d, |t| measure(t.as_ref(), spec))
            .with_context(|| format!("calibrating '{name}' world"))?;
    let mut rank0: Option<(Vec<(f64, f64)>, Vec<(f64, f64)>)> = None;
    for (rank, result) in results.into_iter().enumerate() {
        let samples = result
            .with_context(|| format!("calibration rank {rank} failed"))?;
        if rank == 0 {
            rank0 = Some(samples);
        }
    }
    let (a2a_points, ag_points) = rank0.ok_or_else(|| {
        anyhow!("calibration produced no rank-0 samples (d = {d})")
    })?;
    Ok(Calibration {
        transport: name,
        d,
        all_to_all: fit_line(&a2a_points),
        all_gather: fit_line(&ag_points),
        all_to_all_points: a2a_points,
        all_gather_points: ag_points,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::transport::registry;

    #[test]
    fn fit_recovers_exact_line() {
        // seconds = 10µs + bytes / 1 GB/s.
        let points: Vec<(f64, f64)> = [1e3, 1e4, 1e5, 1e6]
            .iter()
            .map(|&b| (b, 10e-6 + b / 1e9))
            .collect();
        let fit = fit_line(&points);
        assert!((fit.alpha_s - 10e-6).abs() < 1e-9, "{fit:?}");
        let rel = (fit.beta_bytes_per_s - 1e9).abs() / 1e9;
        assert!(rel < 1e-6, "{fit:?}");
        assert!((fit.seconds(1e6) - (10e-6 + 1e-3)).abs() < 1e-9);
    }

    #[test]
    fn fit_degenerate_sweeps_are_clamped() {
        let flat = fit_line(&[(1e3, 5e-6), (1e6, 5e-6)]);
        assert_eq!(flat.beta_bytes_per_s, BETA_CAP);
        assert!(flat.alpha_s > 0.0);
        let empty = fit_line(&[]);
        assert_eq!(empty.alpha_s, 0.0);
        // Noise-negative slope must not produce negative bandwidth,
        // and alpha must come from the *clamped* slope — roughly the
        // mean latency, not the inflated raw-slope intercept (9 µs).
        let noisy = fit_line(&[(1e3, 9e-6), (1e6, 2e-6)]);
        assert!(noisy.beta_bytes_per_s > 0.0);
        assert!(noisy.alpha_s >= 0.0);
        assert!(
            (noisy.alpha_s - 5.5e-6).abs() < 1e-6,
            "alpha {} should track the mean of a degenerate sweep",
            noisy.alpha_s
        );
    }

    #[test]
    fn calibrates_registered_backends() {
        let spec = CalibrationSpec {
            payload_sizes: vec![256, 4096],
            reps: 2,
        };
        for name in registry::NAMES {
            let factory = registry::must(name);
            let cal = calibrate(factory.as_ref(), 2, &spec).unwrap();
            assert_eq!(cal.transport, *name);
            assert_eq!(cal.d, 2);
            assert!(cal.all_to_all.alpha_s.is_finite());
            assert!(cal.all_to_all.beta_bytes_per_s > 0.0);
            assert_eq!(cal.all_to_all_points.len(), 2);
            let topo = cal.to_topology(2);
            assert_eq!(topo.instances, 2);
            assert!(topo.intra_bw > 0.0);
            assert!(topo.base_latency >= 0.0);
        }
    }
}
