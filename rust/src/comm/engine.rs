//! In-process collective engine: real data movement between DP worker
//! threads (the trainer's NCCL stand-in).
//!
//! The engine is SPMD: all `d` participants must call the same sequence
//! of collectives. Each collective is two barrier rounds (deposit, then
//! read), so the cyclic `std::sync::Barrier` keeps rounds from
//! overlapping. Payloads are moved (not copied) for All-to-All, which
//! mirrors the zero-redundancy memory behaviour the paper claims for its
//! communicator versus the All-Gather strawman.

use std::sync::{Arc, Barrier, Mutex};

/// A collective group over `d` in-process participants exchanging `T`.
pub struct Collectives<T> {
    d: usize,
    /// All-to-All cells: `cells[src * d + dst]` holds in-flight payloads.
    cells: Mutex<Vec<Vec<T>>>,
    /// All-Gather slots, one per rank.
    slots: Mutex<Vec<Option<T>>>,
    barrier: Barrier,
}

impl<T: Send + Clone> Collectives<T> {
    pub fn new(d: usize) -> Arc<Self> {
        Arc::new(Collectives {
            d,
            cells: Mutex::new((0..d * d).map(|_| Vec::new()).collect()),
            slots: Mutex::new(vec![None; d]),
            barrier: Barrier::new(d),
        })
    }

    pub fn world_size(&self) -> usize {
        self.d
    }

    /// Point-to-point rearrangement: each rank submits (dst, payload)
    /// pairs and receives the (src, payload) pairs addressed to it.
    /// Payloads that stay on-rank take the same path (loopback).
    pub fn all_to_all(&self, rank: usize, sends: Vec<(usize, T)>)
        -> Vec<(usize, T)> {
        {
            let mut cells = self.cells.lock().unwrap();
            for (dst, item) in sends {
                assert!(dst < self.d, "all_to_all dst {dst} out of range");
                cells[rank * self.d + dst].push(item);
            }
        }
        self.barrier.wait();
        let received = {
            let mut cells = self.cells.lock().unwrap();
            let mut out = Vec::new();
            for src in 0..self.d {
                for item in cells[src * self.d + rank].drain(..) {
                    out.push((src, item));
                }
            }
            out
        };
        self.barrier.wait();
        received
    }

    /// Every rank contributes one value; all ranks receive all values in
    /// rank order.
    pub fn all_gather(&self, rank: usize, item: T) -> Vec<T> {
        {
            let mut slots = self.slots.lock().unwrap();
            slots[rank] = Some(item);
        }
        self.barrier.wait();
        let all = {
            let slots = self.slots.lock().unwrap();
            slots
                .iter()
                .map(|s| s.as_ref().expect("missing contribution").clone())
                .collect()
        };
        self.barrier.wait();
        all
    }

    /// Synchronization point with no data.
    pub fn barrier(&self) {
        self.barrier.wait();
    }
}

impl Collectives<Vec<f32>> {
    /// Sum-all-reduce of equally-shaped f32 buffers (gradient sync).
    /// Every rank receives the elementwise sum.
    pub fn all_reduce_sum(&self, rank: usize, data: &mut [f32]) {
        let contributions = self.all_gather(rank, data.to_vec());
        for (i, x) in data.iter_mut().enumerate() {
            *x = contributions.iter().map(|c| c[i]).sum();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn spawn_world<F, R>(d: usize, f: F) -> Vec<R>
    where
        F: Fn(usize) -> R + Send + Sync + 'static,
        R: Send + 'static,
    {
        let f = Arc::new(f);
        let handles: Vec<_> = (0..d)
            .map(|rank| {
                let f = Arc::clone(&f);
                thread::spawn(move || f(rank))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn all_gather_collects_in_rank_order() {
        let c = Collectives::<usize>::new(4);
        let out = spawn_world(4, move |rank| {
            let c = Arc::clone(&c);
            c.all_gather(rank, rank * 10)
        });
        for got in out {
            assert_eq!(got, vec![0, 10, 20, 30]);
        }
    }

    #[test]
    fn all_to_all_routes_payloads() {
        let c = Collectives::<String>::new(3);
        let out = spawn_world(3, move |rank| {
            let c = Arc::clone(&c);
            // Everyone sends one message to every rank (incl. itself).
            let sends = (0..3)
                .map(|dst| (dst, format!("{rank}->{dst}")))
                .collect();
            let mut recv = c.all_to_all(rank, sends);
            recv.sort();
            recv
        });
        for (rank, got) in out.into_iter().enumerate() {
            let want: Vec<(usize, String)> = (0..3)
                .map(|src| (src, format!("{src}->{rank}")))
                .collect();
            assert_eq!(got, want);
        }
    }

    #[test]
    fn all_to_all_supports_multiple_payloads_per_pair() {
        let c = Collectives::<u32>::new(2);
        let out = spawn_world(2, move |rank| {
            let c = Arc::clone(&c);
            let sends = if rank == 0 {
                vec![(1, 7), (1, 8), (1, 9)]
            } else {
                vec![]
            };
            c.all_to_all(rank, sends)
        });
        assert!(out[0].is_empty());
        let vals: Vec<u32> = out[1].iter().map(|&(_, v)| v).collect();
        assert_eq!(vals, vec![7, 8, 9]);
    }

    #[test]
    fn all_reduce_sums() {
        let c = Collectives::<Vec<f32>>::new(4);
        let out = spawn_world(4, move |rank| {
            let c = Arc::clone(&c);
            let mut data = vec![rank as f32, 1.0];
            c.all_reduce_sum(rank, &mut data);
            data
        });
        for got in out {
            assert_eq!(got, vec![6.0, 4.0]); // 0+1+2+3, 4*1
        }
    }

    #[test]
    fn repeated_rounds_do_not_leak() {
        let c = Collectives::<usize>::new(2);
        let out = spawn_world(2, move |rank| {
            let c = Arc::clone(&c);
            let mut sums = Vec::new();
            for round in 0..5 {
                let recv =
                    c.all_to_all(rank, vec![(1 - rank, round * 10 + rank)]);
                assert_eq!(recv.len(), 1, "round {round} leaked payloads");
                sums.push(recv[0].1);
            }
            sums
        });
        assert_eq!(out[0], vec![1, 11, 21, 31, 41]);
        assert_eq!(out[1], vec![0, 10, 20, 30, 40]);
    }
}
