//! Communication layer: cluster topology, collective cost models
//! (paper Eq. 3/4/5 and Appendix B), communication-volume accounting,
//! and the pluggable [`transport`] backends that actually move bytes
//! for the [`crate::trainer`].
//!
//! Three consumers share this module:
//! * the **simulator** prices All-Gather / All-to-All operations with the
//!   analytic models in [`costmodel`];
//! * the **trainer** moves real bytes between DP workers through a
//!   [`transport::Transport`] — `inproc` shared-memory channels or
//!   `tcp` loopback sockets, resolved by name through
//!   [`transport::registry`] exactly like the balancer registry;
//! * [`calibrate`] closes the loop between the two: it times synthetic
//!   collectives on a live transport and fits the α/β line, so the
//!   analytic models can be fed measured per-backend constants
//!   ([`calibrate::Calibration::to_topology`]) instead of the
//!   hard-coded testbed numbers.

pub mod calibrate;
pub mod costmodel;
pub mod rendezvous;
pub mod topology;
pub mod transport;
pub mod volume;

pub use calibrate::{calibrate, Calibration, CalibrationSpec, FittedLine};
pub use costmodel::{allgather_cost, alltoall_cost, CollectiveCost};
pub use topology::Topology;
pub use transport::{Shard, Transport, TransportExt, TransportFactory, Wire};
pub use volume::VolumeMatrix;
