//! Communication layer: cluster topology, collective cost models
//! (paper Eq. 3/4/5 and Appendix B), communication-volume accounting,
//! and a real in-process collective engine used by the [`crate::trainer`].
//!
//! Two consumers share this module:
//! * the **simulator** prices All-Gather / All-to-All operations with the
//!   analytic models in [`costmodel`];
//! * the **trainer** actually moves bytes between DP worker threads with
//!   the engine in [`engine`] — the same dispatch plans drive both.

pub mod costmodel;
pub mod engine;
pub mod topology;
pub mod volume;

pub use costmodel::{allgather_cost, alltoall_cost, CollectiveCost};
pub use topology::Topology;
pub use volume::VolumeMatrix;
