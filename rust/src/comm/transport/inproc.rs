//! In-process transport: real data movement between DP worker threads
//! over shared memory (the trainer's NCCL stand-in), packaged as the
//! `inproc` [`Transport`] backend.
//!
//! The engine is SPMD: all `d` participants must call the same sequence
//! of collectives. Each collective is two barrier rounds (deposit, then
//! read), so the cyclic [`MonitoredBarrier`] keeps rounds from
//! overlapping. Payloads are moved (not copied) for All-to-All, which
//! mirrors the zero-redundancy memory behaviour the paper claims for its
//! communicator versus the All-Gather strawman.
//!
//! **Barrier watchdog.** A rank that dies asymmetrically (panics in its
//! own step code, returns early, deadlocks elsewhere) never reaches the
//! next barrier — with a plain `std::sync::Barrier` its peers would
//! block forever. The monitored barrier waits with a deadline instead:
//! when the group fails to assemble within the watchdog timeout
//! (`ORCHMLLM_INPROC_TIMEOUT_SECS`, default 60, `0` disables —
//! mirroring the TCP backend's read-timeout escape), every waiter marks
//! the group broken and errors out, and all subsequent collectives on
//! the group fail fast with the original reason. Failure semantics are
//! the transport contract's: "error within the timeout", never a hang.
//!
//! Since the elastic runtime landed, the watchdog also *attributes*:
//! the barrier tracks which ranks arrived in the current generation, so
//! the timeout error carries [`TransportError::PeerDead`] naming the
//! first missing rank — the same typed signal the `tcp` backends
//! attach to broken sockets. [`InProcElastic`] is the thread-world
//! rendezvous that lets survivors rebuild a shrunk group after such a
//! death (see `trainer/elastic.rs`).
//!
//! [`Collectives`] is the private engine behind [`InProcTransport`];
//! nothing outside this module touches it directly anymore — the
//! trainer goes through `dyn Transport`.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::{
    ElasticFactory, Shard, Transport, TransportError, TransportFactory,
};

/// Default watchdog timeout when `ORCHMLLM_INPROC_TIMEOUT_SECS` is not
/// set. Generous: a healthy group assembles in microseconds; only a
/// dead peer keeps a barrier open for a minute.
pub const DEFAULT_WATCHDOG_SECS: u64 = 60;

/// Read the watchdog timeout from the environment (`None` = disabled).
/// Unparsable values warn loudly before falling back — mirroring the
/// TCP backend's env handling: a silently ignored timeout override
/// would defeat the watchdog it configures.
fn watchdog_from_env() -> Option<Duration> {
    let parsed = std::env::var("ORCHMLLM_INPROC_TIMEOUT_SECS")
        .ok()
        .and_then(|raw| match raw.trim().parse::<u64>() {
            Ok(v) => Some(v),
            Err(_) => {
                eprintln!(
                    "warning: ignoring unparsable \
                     ORCHMLLM_INPROC_TIMEOUT_SECS='{raw}', using the \
                     default ({DEFAULT_WATCHDOG_SECS}s)"
                );
                None
            }
        });
    match parsed {
        Some(0) => None,
        Some(n) => Some(Duration::from_secs(n)),
        None => Some(Duration::from_secs(DEFAULT_WATCHDOG_SECS)),
    }
}

/// Why a group broke: the human-readable reason plus the rank the
/// evidence points at (the first rank that never reached the barrier
/// generation), when one is attributable.
#[derive(Clone)]
struct Broken {
    why: String,
    dead: Option<usize>,
}

impl Broken {
    /// Materialize the sticky reason as an error chain: the typed
    /// [`TransportError::PeerDead`] as the root (when attributable) so
    /// `peer_dead()` finds it, the human message as the outer context
    /// so logs keep reading the same as before.
    fn to_error(&self, prefix: &str) -> anyhow::Error {
        let msg = format!("{prefix}: {}", self.why);
        match self.dead {
            Some(rank) => {
                anyhow::Error::from(TransportError::PeerDead { rank })
                    .context(msg)
            }
            None => anyhow!("{msg}"),
        }
    }
}

struct BarrierState {
    arrived: usize,
    generation: u64,
    /// Which ranks have arrived in the current generation — the
    /// watchdog's attribution evidence. Reset when a round releases.
    present: Vec<bool>,
    /// Why the group broke, if it did. Sticky: once broken, every
    /// current and future waiter errors out with this reason.
    broken: Option<Broken>,
}

/// A cyclic barrier whose waiters time out instead of blocking forever
/// when a peer never arrives.
struct MonitoredBarrier {
    state: Mutex<BarrierState>,
    cv: Condvar,
    d: usize,
    timeout: Option<Duration>,
}

impl MonitoredBarrier {
    fn new(d: usize, timeout: Option<Duration>) -> MonitoredBarrier {
        MonitoredBarrier {
            state: Mutex::new(BarrierState {
                arrived: 0,
                generation: 0,
                present: vec![false; d],
                broken: None,
            }),
            cv: Condvar::new(),
            d,
            timeout,
        }
    }

    /// Ride through poisoning: a peer that panicked while holding the
    /// lock must surface as a broken group, not a panic cascade.
    fn lock(&self) -> MutexGuard<'_, BarrierState> {
        self.state.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn wait(&self, rank: usize) -> Result<()> {
        let mut s = self.lock();
        if let Some(b) = &s.broken {
            return Err(b.to_error("inproc barrier: group already broken"));
        }
        s.arrived += 1;
        s.present[rank] = true;
        if s.arrived == self.d {
            s.arrived = 0;
            s.present.iter_mut().for_each(|p| *p = false);
            s.generation = s.generation.wrapping_add(1);
            self.cv.notify_all();
            return Ok(());
        }
        let generation = s.generation;
        let deadline = self.timeout.map(|t| (Instant::now() + t, t));
        loop {
            match deadline {
                None => {
                    s = self
                        .cv
                        .wait(s)
                        .unwrap_or_else(|p| p.into_inner());
                }
                Some((deadline, timeout)) => {
                    let now = Instant::now();
                    if now >= deadline {
                        // Attribution: the first rank with no arrival
                        // in this generation is the prime suspect. A
                        // hint, not a verdict — recovery re-verifies
                        // membership by rendezvous, never by blame.
                        let dead = s.present.iter().position(|&p| !p);
                        let why = format!(
                            "watchdog: {} of {} ranks arrived within \
                             {:?} — a peer died or skipped a round \
                             (first missing rank: {})",
                            s.arrived,
                            self.d,
                            timeout,
                            dead.map_or("?".to_string(), |r| r.to_string()),
                        );
                        let broken = Broken { why, dead };
                        let err = broken.to_error("inproc barrier");
                        s.broken = Some(broken);
                        self.cv.notify_all();
                        return Err(err);
                    }
                    let (guard, _) = self
                        .cv
                        .wait_timeout(s, deadline - now)
                        .unwrap_or_else(|p| p.into_inner());
                    s = guard;
                }
            }
            // Success check FIRST: if this round's generation already
            // advanced, the round completed — a breakage observed now
            // belongs to a *later* round and must not retroactively
            // fail this one (a descheduled waiter can wake after its
            // peers have moved on and broken a subsequent barrier).
            if s.generation != generation {
                return Ok(());
            }
            if let Some(b) = &s.broken {
                return Err(b.to_error("inproc barrier: group broken"));
            }
        }
    }
}

/// A collective group over `d` in-process participants exchanging `T`.
pub(crate) struct Collectives<T> {
    d: usize,
    /// All-to-All cells: `cells[src * d + dst]` holds in-flight payloads.
    cells: Mutex<Vec<Vec<T>>>,
    /// All-Gather slots, one per rank.
    slots: Mutex<Vec<Option<T>>>,
    barrier: MonitoredBarrier,
}

impl<T: Send + Clone> Collectives<T> {
    pub(crate) fn new(d: usize) -> Arc<Self> {
        Self::with_timeout(d, watchdog_from_env())
    }

    /// Group with an explicit watchdog timeout (`None` = wait forever).
    pub(crate) fn with_timeout(
        d: usize,
        timeout: Option<Duration>,
    ) -> Arc<Self> {
        Arc::new(Collectives {
            d,
            cells: Mutex::new((0..d * d).map(|_| Vec::new()).collect()),
            slots: Mutex::new(vec![None; d]),
            barrier: MonitoredBarrier::new(d, timeout),
        })
    }

    pub(crate) fn world_size(&self) -> usize {
        self.d
    }

    /// Ride through poisoning, same rationale as [`MonitoredBarrier::lock`]:
    /// a peer that panicked while holding a cell lock never reaches its
    /// next barrier, so the watchdog breaks the group and every survivor
    /// errors out — panicking here would cascade the abort instead.
    fn lock_cells(&self) -> MutexGuard<'_, Vec<Vec<T>>> {
        self.cells.lock().unwrap_or_else(|p| p.into_inner())
    }

    fn lock_slots(&self) -> MutexGuard<'_, Vec<Option<T>>> {
        self.slots.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Point-to-point rearrangement: each rank submits (dst, payload)
    /// pairs and receives the (src, payload) pairs addressed to it.
    /// Payloads that stay on-rank take the same path (loopback).
    pub(crate) fn all_to_all(
        &self,
        rank: usize,
        sends: Vec<(usize, T)>,
    ) -> Result<Vec<(usize, T)>> {
        {
            let mut cells = self.lock_cells();
            for (dst, item) in sends {
                if dst >= self.d {
                    bail!("all_to_all dst {dst} out of range (d = {})", self.d);
                }
                cells[rank * self.d + dst].push(item);
            }
        }
        self.barrier.wait(rank)?;
        let received = {
            let mut cells = self.lock_cells();
            let mut out = Vec::new();
            for src in 0..self.d {
                for item in cells[src * self.d + rank].drain(..) {
                    out.push((src, item));
                }
            }
            out
        };
        self.barrier.wait(rank)?;
        Ok(received)
    }

    /// Every rank contributes one value; all ranks receive all values in
    /// rank order.
    pub(crate) fn all_gather(&self, rank: usize, item: T) -> Result<Vec<T>> {
        {
            let mut slots = self.lock_slots();
            slots[rank] = Some(item);
        }
        self.barrier.wait(rank)?;
        let all: Vec<T> = {
            let slots = self.lock_slots();
            let mut all = Vec::with_capacity(self.d);
            for (src, s) in slots.iter().enumerate() {
                match s {
                    Some(v) => all.push(v.clone()),
                    None => bail!(
                        "all_gather: missing contribution from rank {src}"
                    ),
                }
            }
            all
        };
        self.barrier.wait(rank)?;
        // Stale-slot guard: clear my own slot so a rank that skips a
        // future round trips the "missing contribution" error instead
        // of silently replaying this round's value. Each rank clears
        // its own slot strictly after every rank's read (the second
        // barrier) and redeposits before the next round's read barrier,
        // so no reader ever observes the gap.
        self.lock_slots()[rank] = None;
        Ok(all)
    }

    /// Synchronization point with no data.
    pub(crate) fn barrier(&self, rank: usize) -> Result<()> {
        self.barrier.wait(rank)
    }
}

impl Collectives<Vec<f32>> {
    /// Sum-all-reduce of equally-shaped f32 buffers (gradient sync):
    /// reduce-scatter + all-gather. Rank `k` owns elements
    /// `[k·n/d, (k+1)·n/d)`: every rank ships slice `k` of its buffer
    /// to rank `k` (one All-to-All of `n/d`-sized pieces), the owner
    /// sums its chunk's contributions in **increasing source-rank
    /// order** (fixed, bit-stable reduction order), and an All-Gather
    /// of the reduced chunks rebuilds the full buffer everywhere.
    ///
    /// Peak extra memory per rank is O(n) — one incoming chunk set plus
    /// the gathered result — independent of `d`, replacing the old
    /// all-gather-of-full-buffers O(d·n) staging.
    pub(crate) fn all_reduce_sum(
        &self,
        rank: usize,
        data: &mut [f32],
    ) -> Result<()> {
        let d = self.d;
        if d == 1 {
            return Ok(());
        }
        let n = data.len();
        let bounds = |k: usize| (k * n / d, (k + 1) * n / d);

        let sends: Vec<(usize, Vec<f32>)> = (0..d)
            .map(|k| {
                let (lo, hi) = bounds(k);
                (k, data[lo..hi].to_vec())
            })
            .collect();
        let received = self.all_to_all(rank, sends)?;
        let (lo, hi) = bounds(rank);
        let mut acc = vec![0.0f32; hi - lo];
        if received.len() != d {
            bail!(
                "all_reduce_sum: a peer skipped the reduce-scatter \
                 round ({} of {d} contributions)",
                received.len()
            );
        }
        // `all_to_all` returns contributions sorted by src, so this
        // accumulates rank 0, 1, …, d-1 for every element.
        for (idx, (src, chunk)) in received.into_iter().enumerate() {
            if src != idx {
                bail!("all_reduce_sum: missing contribution from {idx}");
            }
            if chunk.len() != acc.len() {
                bail!(
                    "all_reduce_sum: rank {src} sent {} elems, \
                     expected {}",
                    chunk.len(),
                    acc.len()
                );
            }
            for (a, x) in acc.iter_mut().zip(&chunk) {
                *a += x;
            }
        }

        let gathered = self.all_gather(rank, acc)?;
        for (k, chunk) in gathered.into_iter().enumerate() {
            let (lo, hi) = bounds(k);
            if chunk.len() != hi - lo {
                bail!(
                    "all_reduce_sum: reduced chunk {k} has {} elems, \
                     expected {}",
                    chunk.len(),
                    hi - lo
                );
            }
            data[lo..hi].copy_from_slice(&chunk);
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Transport impl
// ---------------------------------------------------------------------------

/// The `inproc` backend: one byte-payload collective group shared by
/// `d` worker threads, plus typed groups so gradient buffers and batch
/// shards skip the wire encode/decode round-trip entirely — the shard
/// group moves `Arc`-shared payloads, so a cross-rank send is a
/// refcount bump, not a copy.
pub struct InProcTransport {
    rank: usize,
    bytes: Arc<Collectives<Vec<u8>>>,
    grads: Arc<Collectives<Vec<f32>>>,
    shards: Arc<Collectives<Shard>>,
}

impl Transport for InProcTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world_size(&self) -> usize {
        self.bytes.world_size()
    }

    fn all_to_all_bytes(
        &self,
        sends: Vec<(usize, Vec<u8>)>,
    ) -> Result<Vec<(usize, Vec<u8>)>> {
        let d = self.world_size();
        if let Some(&(dst, _)) = sends.iter().find(|&&(dst, _)| dst >= d) {
            bail!("all_to_all: dst {dst} out of range (d = {d})");
        }
        // The engine already satisfies the ordering contract: results
        // come back grouped by src (ascending) with each source's
        // payloads in deposit (send) order.
        self.bytes.all_to_all(self.rank, sends)
    }

    fn all_gather_bytes(&self, bytes: Vec<u8>) -> Result<Vec<Vec<u8>>> {
        self.bytes.all_gather(self.rank, bytes)
    }

    fn barrier(&self) -> Result<()> {
        self.bytes.barrier(self.rank)
    }

    fn all_reduce_sum(&self, data: &mut [f32]) -> Result<()> {
        // Same chunking and reduction order as the trait default, but
        // over the typed f32 group: no serialization on the gradient
        // path, bit-identical results across backends.
        self.grads.all_reduce_sum(self.rank, data)
    }

    fn all_to_all_shards(
        &self,
        sends: Vec<(usize, Shard)>,
    ) -> Result<Vec<(usize, Shard)>> {
        // Typed fast path: the Shard (and the Arc'd buffer inside it)
        // moves through the cells untouched — no Wire round-trip, no
        // payload copy. Ordering contract is the engine's, identical
        // to the bytes path, so `tcp` (which takes the Wire default)
        // delivers the same logical results.
        let d = self.world_size();
        if let Some(&(dst, _)) = sends.iter().find(|&&(dst, _)| dst >= d) {
            bail!("all_to_all_shards: dst {dst} out of range (d = {d})");
        }
        self.shards.all_to_all(self.rank, sends)
    }
}

/// Factory for the `inproc` backend.
#[derive(Clone, Copy, Debug, Default)]
pub struct InProcFactory {
    /// Barrier-watchdog override for tests; `None` reads
    /// `ORCHMLLM_INPROC_TIMEOUT_SECS` at connect time (default 60 s,
    /// `0` disables). `Some(Duration::ZERO)` also disables.
    pub watchdog: Option<Duration>,
}

impl InProcFactory {
    fn timeout(&self) -> Option<Duration> {
        match self.watchdog {
            Some(t) if t.is_zero() => None,
            Some(t) => Some(t),
            None => watchdog_from_env(),
        }
    }
}

impl TransportFactory for InProcFactory {
    fn name(&self) -> &'static str {
        "inproc"
    }

    fn description(&self) -> &'static str {
        "shared-memory channels between worker threads (NCCL stand-in)"
    }

    fn connect(&self, d: usize) -> Result<Vec<Box<dyn Transport>>> {
        if d == 0 {
            bail!("transport world size must be >= 1");
        }
        let timeout = self.timeout();
        let bytes = Collectives::with_timeout(d, timeout);
        let grads = Collectives::with_timeout(d, timeout);
        let shards = Collectives::with_timeout(d, timeout);
        Ok((0..d)
            .map(|rank| {
                Box::new(InProcTransport {
                    rank,
                    bytes: Arc::clone(&bytes),
                    grads: Arc::clone(&grads),
                    shards: Arc::clone(&shards),
                }) as Box<dyn Transport>
            })
            .collect())
    }
}

// ---------------------------------------------------------------------------
// InProcElastic: thread-world rendezvous across epochs
// ---------------------------------------------------------------------------

/// Per-epoch rendezvous state: who has registered, the sealed
/// membership once a seal happened, and the transport handles the
/// sealing member deposited (keyed by stable member id, taken once by
/// each member).
#[derive(Default)]
struct EpochState {
    registered: BTreeSet<usize>,
    sealed: Option<Vec<usize>>,
    handles: BTreeMap<usize, Box<dyn Transport>>,
}

/// Elastic rendezvous for thread-per-rank worlds — the in-process twin
/// of the file-based [`crate::comm::rendezvous`] protocol that backs
/// `tcp-multiproc` (see [`super::mesh`]).
///
/// Members register at an epoch under their *stable id* (launch-time
/// rank). Membership seals as soon as every expected member has
/// registered, or when the grace window expires — whichever comes
/// first — and whoever observes the seal condition builds a fresh
/// [`InProcFactory`] group sized to the sealed world and deposits one
/// handle per member. A member that registers after its epoch sealed
/// is evicted with an error: the world moved on without it.
pub struct InProcElastic {
    /// Barrier-watchdog override handed to every epoch's group
    /// ([`InProcFactory::watchdog`] semantics).
    watchdog: Option<Duration>,
    /// How long a joiner waits for missing expected members before
    /// sealing the epoch with whoever showed up.
    grace: Duration,
    epochs: Mutex<BTreeMap<u64, EpochState>>,
    cv: Condvar,
}

impl std::fmt::Debug for InProcElastic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("InProcElastic")
            .field("watchdog", &self.watchdog)
            .field("grace", &self.grace)
            .finish_non_exhaustive()
    }
}

impl InProcElastic {
    /// Rendezvous with an explicit grace window and barrier watchdog
    /// (watchdog `None` reads `ORCHMLLM_INPROC_TIMEOUT_SECS` at each
    /// epoch's connect, `Some(ZERO)` disables).
    pub fn new(watchdog: Option<Duration>, grace: Duration) -> InProcElastic {
        InProcElastic {
            watchdog,
            grace,
            epochs: Mutex::new(BTreeMap::new()),
            cv: Condvar::new(),
        }
    }
}

impl ElasticFactory for InProcElastic {
    fn join(
        &self,
        epoch: u64,
        me: usize,
        expected: &[usize],
    ) -> Result<(Vec<usize>, Box<dyn Transport>)> {
        let deadline = Instant::now() + self.grace;
        let mut epochs =
            self.epochs.lock().unwrap_or_else(|p| p.into_inner());
        epochs.entry(epoch).or_default().registered.insert(me);
        self.cv.notify_all();
        loop {
            let state = epochs.get_mut(&epoch).ok_or_else(|| {
                anyhow!("rendezvous epoch {epoch}: state vanished mid-join")
            })?;
            if state.sealed.is_none() {
                let complete = expected
                    .iter()
                    .all(|m| state.registered.contains(m));
                if complete || Instant::now() >= deadline {
                    let members: Vec<usize> =
                        state.registered.iter().copied().collect();
                    let world = InProcFactory {
                        watchdog: self.watchdog,
                    }
                    .connect(members.len())?;
                    for (idx, t) in world.into_iter().enumerate() {
                        state.handles.insert(members[idx], t);
                    }
                    state.sealed = Some(members);
                    self.cv.notify_all();
                }
            }
            if let Some(members) = &state.sealed {
                if !members.contains(&me) {
                    bail!(
                        "rendezvous epoch {epoch}: member {me} arrived \
                         after membership sealed (evicted); sealed \
                         world: {members:?}"
                    );
                }
                let members = members.clone();
                let t = state.handles.remove(&me).ok_or_else(|| {
                    anyhow!(
                        "rendezvous epoch {epoch}: member {me} has no \
                         handle left (double join?)"
                    )
                })?;
                return Ok((members, t));
            }
            let remaining = deadline
                .saturating_duration_since(Instant::now())
                .max(Duration::from_millis(1));
            let (guard, _) = self
                .cv
                .wait_timeout(epochs, remaining)
                .unwrap_or_else(|p| p.into_inner());
            epochs = guard;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn spawn_world<F, R>(d: usize, f: F) -> Vec<R>
    where
        F: Fn(usize) -> R + Send + Sync + 'static,
        R: Send + 'static,
    {
        let f = Arc::new(f);
        let handles: Vec<_> = (0..d)
            .map(|rank| {
                let f = Arc::clone(&f);
                thread::spawn(move || f(rank))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn all_gather_collects_in_rank_order() {
        let c = Collectives::<usize>::new(4);
        let out = spawn_world(4, move |rank| {
            let c = Arc::clone(&c);
            c.all_gather(rank, rank * 10).unwrap()
        });
        for got in out {
            assert_eq!(got, vec![0, 10, 20, 30]);
        }
    }

    #[test]
    fn all_gather_clears_slots_after_the_round() {
        // d = 1 runs the full deposit/read/clear cycle synchronously,
        // so the stale-slot guard is directly observable.
        let c = Collectives::<usize>::new(1);
        for round in 0..3 {
            assert_eq!(c.all_gather(0, round).unwrap(), vec![round]);
            assert!(
                c.slots.lock().unwrap()[0].is_none(),
                "slot must be cleared after round {round}"
            );
        }
    }

    #[test]
    fn all_to_all_routes_payloads() {
        let c = Collectives::<String>::new(3);
        let out = spawn_world(3, move |rank| {
            let c = Arc::clone(&c);
            // Everyone sends one message to every rank (incl. itself).
            let sends = (0..3)
                .map(|dst| (dst, format!("{rank}->{dst}")))
                .collect();
            let mut recv = c.all_to_all(rank, sends).unwrap();
            recv.sort();
            recv
        });
        for (rank, got) in out.into_iter().enumerate() {
            let want: Vec<(usize, String)> = (0..3)
                .map(|src| (src, format!("{src}->{rank}")))
                .collect();
            assert_eq!(got, want);
        }
    }

    #[test]
    fn all_to_all_supports_multiple_payloads_per_pair() {
        let c = Collectives::<u32>::new(2);
        let out = spawn_world(2, move |rank| {
            let c = Arc::clone(&c);
            let sends = if rank == 0 {
                vec![(1, 7), (1, 8), (1, 9)]
            } else {
                vec![]
            };
            c.all_to_all(rank, sends).unwrap()
        });
        assert!(out[0].is_empty());
        let vals: Vec<u32> = out[1].iter().map(|&(_, v)| v).collect();
        assert_eq!(vals, vec![7, 8, 9]);
    }

    #[test]
    fn all_reduce_sums_bit_stably() {
        let d = 4;
        // Lengths that do not divide evenly exercise the chunk bounds;
        // n < d leaves some ranks with empty chunks.
        for n in [0usize, 2, 7, 64] {
            let c = Collectives::<Vec<f32>>::new(d);
            let out = spawn_world(d, move |rank| {
                let c = Arc::clone(&c);
                let mut data: Vec<f32> =
                    (0..n).map(|i| (rank * n + i) as f32 * 0.25).collect();
                c.all_reduce_sum(rank, &mut data).unwrap();
                data
            });
            // Reference: fixed rank-order sum (the bit-stable contract).
            let mut want = vec![0.0f32; n];
            for rank in 0..d {
                for (i, w) in want.iter_mut().enumerate() {
                    *w += (rank * n + i) as f32 * 0.25;
                }
            }
            for got in out {
                assert_eq!(got, want, "n = {n}");
            }
        }
    }

    #[test]
    fn repeated_rounds_do_not_leak() {
        let c = Collectives::<usize>::new(2);
        let out = spawn_world(2, move |rank| {
            let c = Arc::clone(&c);
            let mut sums = Vec::new();
            for round in 0..5 {
                let recv = c
                    .all_to_all(rank, vec![(1 - rank, round * 10 + rank)])
                    .unwrap();
                assert_eq!(recv.len(), 1, "round {round} leaked payloads");
                sums.push(recv[0].1);
            }
            sums
        });
        assert_eq!(out[0], vec![1, 11, 21, 31, 41]);
        assert_eq!(out[1], vec![0, 10, 20, 30, 40]);
    }

    #[test]
    fn watchdog_errors_a_lonely_barrier_out() {
        // Rank 1 never shows up: the waiter must error within the
        // timeout, not block forever.
        let c = Collectives::<usize>::with_timeout(
            2,
            Some(Duration::from_millis(50)),
        );
        let t0 = Instant::now();
        let err = c.barrier(0).unwrap_err();
        // Typed attribution: the only possible culprit is rank 1.
        assert_eq!(crate::comm::transport::peer_dead(&err), Some(1));
        let err = err.to_string();
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "watchdog did not fire in time"
        );
        assert!(err.contains("watchdog"), "{err}");
        // The group is now broken: subsequent rounds fail fast with
        // the original reason instead of waiting out another timeout.
        let t1 = Instant::now();
        let again = c.all_gather(0, 1).unwrap_err().to_string();
        assert!(again.contains("broken"), "{again}");
        assert!(t1.elapsed() < Duration::from_millis(40), "{again}");
    }

    #[test]
    fn watchdog_errors_peers_out_when_a_rank_dies_mid_step() {
        // Rank 0 completes one collective then "dies" (returns early);
        // ranks 1..d keep issuing rounds and must all error out of the
        // next barrier instead of hanging the join below.
        let c = Collectives::<usize>::with_timeout(
            3,
            Some(Duration::from_millis(80)),
        );
        let out = spawn_world(3, move |rank| {
            let c = Arc::clone(&c);
            c.all_gather(rank, rank).unwrap();
            if rank == 0 {
                return Ok(vec![]); // asymmetric death
            }
            c.all_gather(rank, rank * 2)
        });
        assert!(out[0].is_ok());
        for r in &out[1..] {
            let err = r.as_ref().unwrap_err();
            // The dead rank is attributed through the sticky reason.
            assert_eq!(
                crate::comm::transport::peer_dead(err),
                Some(0),
                "peer saw: {err:#}"
            );
            let err = err.to_string();
            assert!(
                err.contains("watchdog") || err.contains("broken"),
                "peer saw: {err}"
            );
        }
    }

    #[test]
    fn healthy_groups_never_trip_the_watchdog() {
        // A tight timeout with a healthy group: many rounds, no error.
        let c = Collectives::<usize>::with_timeout(
            4,
            Some(Duration::from_secs(5)),
        );
        let out = spawn_world(4, move |rank| {
            let c = Arc::clone(&c);
            for _ in 0..50 {
                c.barrier(rank).unwrap();
            }
            c.all_gather(rank, rank).unwrap()
        });
        for got in out {
            assert_eq!(got, vec![0, 1, 2, 3]);
        }
    }

    #[test]
    fn factory_watchdog_override_reaches_the_transport() {
        // One rank drops its transport without the final barrier; the
        // surviving rank errors out through the `dyn Transport` API.
        let factory = InProcFactory {
            watchdog: Some(Duration::from_millis(80)),
        };
        let mut world = factory.connect(2).unwrap();
        let t1 = world.pop().unwrap();
        let t0 = world.pop().unwrap();
        let dead = thread::spawn(move || drop(t0));
        dead.join().unwrap();
        let err = t1.barrier().unwrap_err().to_string();
        assert!(err.contains("watchdog"), "{err}");
    }

    #[test]
    fn shard_fast_path_moves_buffers_without_copying() {
        // Every rank shares the same Arc'd buffer; after the exchange
        // each rank must hold the *same allocation* it sent — proof
        // the typed path moved the Arc instead of serializing bytes.
        let rows: Arc<Vec<f32>> = Arc::new(vec![1.0, 2.0, 3.0]);
        let sent_ptr = Arc::as_ptr(&rows) as usize;
        let ptrs = crate::comm::transport::run_world(
            &InProcFactory::default(),
            2,
            |t| {
                let rank = t.rank();
                let sends = vec![(
                    1 - rank,
                    Shard::f32_shared(rank, Arc::clone(&rows)),
                )];
                let recv = t.all_to_all_shards(sends).unwrap();
                assert_eq!(recv.len(), 1);
                let (src, shard) = recv.into_iter().next().unwrap();
                assert_eq!(src, 1 - rank);
                assert_eq!(shard.id(), 1 - rank);
                let (_, got) = shard.into_f32().unwrap();
                assert_eq!(*got, vec![1.0, 2.0, 3.0]);
                Arc::as_ptr(&got) as usize
            },
        )
        .unwrap();
        for p in ptrs {
            assert_eq!(p, sent_ptr, "shard payload was copied");
        }
    }

    #[test]
    fn transport_handles_route_and_validate() {
        let out = crate::comm::transport::run_world(
            &InProcFactory::default(),
            2,
            |t| {
                let rank = t.rank();
                assert_eq!(t.world_size(), 2);
                // Out-of-range destination must error, not panic.
                assert!(t.all_to_all_bytes(vec![(9, vec![0u8])]).is_err());
                // (The failed call deposited nothing, so the group is
                // still aligned.)
                let recv = t
                    .all_to_all_bytes(vec![(1 - rank, vec![rank as u8])])
                    .unwrap();
                assert_eq!(recv, vec![(1 - rank, vec![(1 - rank) as u8])]);
                let all =
                    t.all_gather_bytes(vec![rank as u8, 0xAA]).unwrap();
                assert_eq!(all, vec![vec![0u8, 0xAA], vec![1u8, 0xAA]]);
                t.barrier().unwrap();
                let mut grads = vec![rank as f32; 6];
                t.all_reduce_sum(&mut grads).unwrap();
                assert_eq!(grads, vec![1.0; 6]); // 0 + 1
            },
        )
        .unwrap();
        assert_eq!(out.len(), 2);
    }

    #[test]
    fn elastic_rendezvous_seals_complete_worlds_immediately() {
        let rdzv = Arc::new(InProcElastic::new(
            Some(Duration::from_secs(5)),
            Duration::from_secs(5),
        ));
        let out = spawn_world(3, move |rank| {
            let rdzv = Arc::clone(&rdzv);
            let (members, t) = rdzv.join(0, rank, &[0, 1, 2]).unwrap();
            assert_eq!(members, vec![0, 1, 2]);
            assert_eq!(t.rank(), rank);
            assert_eq!(t.world_size(), 3);
            t.all_gather_bytes(vec![rank as u8]).unwrap()
        });
        for got in out {
            assert_eq!(got, vec![vec![0u8], vec![1], vec![2]]);
        }
    }

    #[test]
    fn elastic_rendezvous_shrinks_and_renumbers_survivors() {
        // Stable ids {0, 2, 3} re-rendezvous at epoch 1 after id 1
        // died: dense transport ranks must be each survivor's index in
        // the sorted member list.
        let rdzv = Arc::new(InProcElastic::new(
            Some(Duration::from_secs(5)),
            Duration::from_secs(5),
        ));
        let survivors = [0usize, 2, 3];
        let out = spawn_world(3, move |i| {
            let rdzv = Arc::clone(&rdzv);
            let me = survivors[i];
            let (members, t) = rdzv.join(1, me, &survivors).unwrap();
            assert_eq!(members, vec![0, 2, 3]);
            assert_eq!(t.world_size(), 3);
            let rank = members.iter().position(|&m| m == me).unwrap();
            assert_eq!(t.rank(), rank);
            t.all_gather_bytes(vec![me as u8]).unwrap()
        });
        for got in out {
            assert_eq!(got, vec![vec![0u8], vec![2], vec![3]]);
        }
    }

    #[test]
    fn elastic_rendezvous_evicts_latecomers_after_grace() {
        // Member 1 never joins in time; the grace window expires and
        // the world seals without it. When it finally arrives, it is
        // evicted instead of wedging the sealed group.
        let rdzv = InProcElastic::new(
            Some(Duration::from_secs(5)),
            Duration::from_millis(50),
        );
        let (members, t) = rdzv.join(2, 0, &[0, 1]).unwrap();
        assert_eq!(members, vec![0]);
        assert_eq!(t.world_size(), 1);
        let err = rdzv.join(2, 1, &[0, 1]).unwrap_err().to_string();
        assert!(err.contains("evicted"), "{err}");
    }
}
