//! `tcp-multiproc`: the loopback-TCP wire protocol taken out of
//! loopback-land — rank discovery via a file [`FileRendezvous`] so the
//! ranks of one world can live in **separate OS processes** (the
//! `orchmllm worker` subcommand), with concurrent connect + bounded
//! retry instead of the single-threaded dial-then-accept handshake the
//! loopback factory uses.
//!
//! # Mesh build, per member
//!
//! 1. Bind an ephemeral listener, register its address with the
//!    rendezvous at the current epoch.
//! 2. Wait for the sealed membership; my dense rank is my position in
//!    the sorted member list.
//! 3. Dial every higher rank ([`super::tcp::dial_with_retry`] —
//!    peers may still be binding, so refused connects back off and
//!    retry), send the 8-byte hello naming my rank; accept one
//!    connection per lower rank (with a deadline — a member that died
//!    between seal and mesh build must error us out, not hang us).
//! 4. Wrap the streams in the *same* [`TcpLoopbackTransport`] the
//!    loopback backend uses: identical framing, pairwise schedule,
//!    timeouts, and typed `PeerDead` classification, so the whole
//!    conformance battery applies verbatim.
//!
//! [`TcpElastic`] packages steps 1–4 behind the
//! [`ElasticFactory`] epoch API for the recovery protocol in
//! `trainer/elastic.rs`; [`TcpMeshFactory`] is the registry entry that
//! runs one world's members as threads of the calling process — the
//! in-process harness that lets benches and the conformance suite
//! drive the exact rendezvous + concurrent-dial machinery the
//! multi-process path uses.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::tcp::{
    dial_with_retry, read_hello, send_hello, TcpLoopbackFactory,
    TcpLoopbackTransport,
};
use super::{ElasticFactory, Transport, TransportFactory};
use crate::comm::rendezvous::{cleanup, scratch_dir, FileRendezvous, Member};

/// Accept one mesh connection, bounded by `deadline`. The listener
/// stays nonblocking between accepts; each accepted stream is flipped
/// back to blocking before the frame protocol touches it.
fn accept_with_deadline(
    listener: &TcpListener,
    deadline: Instant,
) -> Result<TcpStream> {
    listener
        .set_nonblocking(true)
        .context("setting mesh listener nonblocking")?;
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                stream
                    .set_nonblocking(false)
                    .context("restoring blocking mode on mesh stream")?;
                return Ok(stream);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    bail!(
                        "timed out accepting mesh peers — a sealed \
                         member died before connecting"
                    );
                }
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => {
                return Err(anyhow!(e)).context("accepting a mesh peer")
            }
        }
    }
}

/// Build one member's transport over a sealed membership: dial higher
/// ranks, accept lower ranks, tune every stream, and wrap them in the
/// shared loopback transport. `members` must be sorted by stable id
/// (the rendezvous guarantees it).
fn connect_mesh(
    members: &[Member],
    me: usize,
    listener: TcpListener,
    timeout: Option<Duration>,
) -> Result<Box<dyn Transport>> {
    let d = members.len();
    let rank = members
        .iter()
        .position(|&(id, _)| id == me)
        .ok_or_else(|| anyhow!("member {me} missing from sealed world"))?;

    let mut peers: Vec<Option<TcpStream>> = (0..d).map(|_| None).collect();
    // Dial every higher rank. Loopback/TCP connects complete against
    // the kernel backlog without the peer accepting, and the 8-byte
    // hello fits any socket buffer, so dials cannot deadlock against
    // our own pending accepts.
    for (j, (id, addr)) in members.iter().enumerate().skip(rank + 1) {
        let addr: SocketAddr = addr.parse().with_context(|| {
            format!("member {id} advertised unparsable address '{addr}'")
        })?;
        let stream = dial_with_retry(addr)
            .with_context(|| format!("rank {rank} dialing rank {j}"))?;
        send_hello(&stream, rank)?;
        peers[j] = Some(stream);
    }
    // Accept one connection per lower rank, in whatever order they
    // arrive — the hello names the dialer.
    let accept_deadline = Instant::now()
        + timeout.unwrap_or(Duration::from_secs(30));
    for _ in 0..rank {
        let stream = accept_with_deadline(&listener, accept_deadline)?;
        let peer = read_hello(&stream)?;
        if peer >= rank || peers[peer].is_some() {
            bail!("duplicate or out-of-order mesh handshake from {peer}");
        }
        peers[peer] = Some(stream);
    }

    // Same tuning as the loopback factory: collectives are
    // latency-bound (no Nagle), and both directions must error within
    // the timeout when a peer stalls.
    for stream in peers.iter().flatten() {
        stream.set_nodelay(true).context("set_nodelay")?;
        stream
            .set_read_timeout(timeout)
            .context("set_read_timeout")?;
        stream
            .set_write_timeout(timeout)
            .context("set_write_timeout")?;
    }
    Ok(Box::new(TcpLoopbackTransport::from_streams(rank, d, peers)))
}

// ---------------------------------------------------------------------------
// TcpElastic: the per-process epoch API
// ---------------------------------------------------------------------------

/// Elastic mesh builder for one OS process: every [`ElasticFactory::join`]
/// binds a fresh listener, rendezvouses at the given epoch, and builds
/// the mesh over whoever the commit sealed. This is what the `worker`
/// subcommand drives — epoch 0 at launch, bumped epochs on recovery.
#[derive(Clone, Debug)]
pub struct TcpElastic {
    /// The shared rendezvous (same `--rdzv-dir` in every process).
    pub rdzv: FileRendezvous,
    /// Per-stream read/write timeout ([`TcpLoopbackFactory`] semantics).
    pub timeout: Option<Duration>,
}

impl ElasticFactory for TcpElastic {
    fn join(
        &self,
        epoch: u64,
        me: usize,
        expected: &[usize],
    ) -> Result<(Vec<usize>, Box<dyn Transport>)> {
        let listener = TcpListener::bind(("127.0.0.1", 0))
            .context("binding mesh listener")?;
        let addr = listener.local_addr()?.to_string();
        let members = self
            .rdzv
            .join(epoch, me, &addr, expected)
            .with_context(|| format!("rendezvous epoch {epoch}"))?;
        let ids: Vec<usize> = members.iter().map(|&(id, _)| id).collect();
        let transport = connect_mesh(&members, me, listener, self.timeout)
            .with_context(|| format!("building epoch {epoch} mesh"))?;
        Ok((ids, transport))
    }
}

// ---------------------------------------------------------------------------
// TcpMeshFactory: the registry entry
// ---------------------------------------------------------------------------

/// Factory for the `tcp-multiproc` backend.
///
/// `connect(d)` runs the `d` members as threads of the calling process
/// over a scratch rendezvous directory — the full discovery protocol
/// (register, seal, concurrent dial with retry) with none of the
/// process management, which is exactly what the conformance battery
/// and benches need. Real multi-process worlds don't call `connect`;
/// each `orchmllm worker` process drives its own [`TcpElastic`].
#[derive(Clone, Copy, Debug)]
pub struct TcpMeshFactory {
    /// Per-stream read/write timeout; `None` blocks forever.
    pub timeout: Option<Duration>,
}

impl Default for TcpMeshFactory {
    fn default() -> Self {
        TcpMeshFactory {
            timeout: Some(Duration::from_secs(30)),
        }
    }
}

impl TcpMeshFactory {
    /// Honor `ORCHMLLM_TCP_TIMEOUT_SECS` exactly like the loopback
    /// factory (default 30; 0 = no timeout).
    pub fn from_env() -> Self {
        TcpMeshFactory {
            timeout: TcpLoopbackFactory::from_env().timeout,
        }
    }
}

impl TransportFactory for TcpMeshFactory {
    fn name(&self) -> &'static str {
        "tcp-multiproc"
    }

    fn description(&self) -> &'static str {
        "TCP full mesh with file rendezvous; ranks can be separate \
         OS processes"
    }

    fn connect(&self, d: usize) -> Result<Vec<Box<dyn Transport>>> {
        if d == 0 {
            bail!("transport world size must be >= 1");
        }
        let dir = scratch_dir("mesh");
        let elastic = TcpElastic {
            rdzv: FileRendezvous::new(&dir),
            timeout: self.timeout,
        };
        let expected: Vec<usize> = (0..d).collect();
        let out = std::thread::scope(|scope| {
            let joins: Vec<_> = (0..d)
                .map(|me| {
                    let elastic = &elastic;
                    let expected = &expected;
                    scope.spawn(move || elastic.join(0, me, expected))
                })
                .collect();
            joins
                .into_iter()
                .enumerate()
                .map(|(me, join)| {
                    join.join()
                        .map_err(|_| {
                            anyhow!("mesh join thread {me} panicked")
                        })?
                        .with_context(|| format!("member {me} joining"))
                })
                .collect::<Result<Vec<_>>>()
        });
        cleanup(&dir);
        // Epoch 0 with expected = 0..d seals the complete world, so
        // member i's transport rank is i: the factory contract's
        // "rank i at index i" holds by construction.
        Ok(out?.into_iter().map(|(_, t)| t).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::transport::run_world;

    fn quick_factory() -> TcpMeshFactory {
        TcpMeshFactory {
            timeout: Some(Duration::from_secs(10)),
        }
    }

    #[test]
    fn mesh_worlds_route_collectives() {
        let d = 4;
        let out = run_world(&quick_factory(), d, move |t| {
            let rank = t.rank();
            assert_eq!(t.world_size(), d);
            let sends: Vec<(usize, Vec<u8>)> = (0..d)
                .map(|dst| (dst, vec![(rank * 10 + dst) as u8]))
                .collect();
            let recv = t.all_to_all_bytes(sends).unwrap();
            let want: Vec<(usize, Vec<u8>)> = (0..d)
                .map(|src| (src, vec![(src * 10 + rank) as u8]))
                .collect();
            assert_eq!(recv, want);
            let all = t.all_gather_bytes(vec![rank as u8]).unwrap();
            assert_eq!(
                all,
                (0..d).map(|r| vec![r as u8]).collect::<Vec<_>>()
            );
            t.barrier().unwrap();
            let mut grads = vec![rank as f32; 8];
            t.all_reduce_sum(&mut grads).unwrap();
            assert_eq!(grads, vec![6.0; 8]); // 0+1+2+3
        })
        .unwrap();
        assert_eq!(out.len(), d);
    }

    #[test]
    fn single_rank_mesh_degenerates() {
        let out = run_world(&quick_factory(), 1, |t| {
            assert_eq!(
                t.all_gather_bytes(vec![7u8]).unwrap(),
                vec![vec![7u8]]
            );
            t.barrier().unwrap();
        })
        .unwrap();
        assert_eq!(out.len(), 1);
    }

    #[test]
    fn concurrent_rendezvous_survives_startup_races() {
        // The stress case for the retry-with-backoff dial: several
        // worlds rendezvous and mesh up concurrently, so dials race
        // listener binds, registration scans race renames, and the
        // commit race has real contenders. Any lost race without
        // retry/first-writer-wins semantics deadlocks or errors here.
        let rounds = 4;
        let d = 6;
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..rounds)
                .map(|_| {
                    scope.spawn(move || {
                        run_world(&quick_factory(), d, |t| {
                            let rank = t.rank();
                            for _ in 0..3 {
                                let all = t
                                    .all_gather_bytes(vec![rank as u8])
                                    .unwrap();
                                assert_eq!(all.len(), d);
                            }
                        })
                        .unwrap()
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
    }

    #[test]
    fn dial_with_retry_waits_for_late_listeners() {
        // Grab a free port, release it, and only rebind after the
        // first dial attempts have already failed: the backoff loop
        // must ride through the refused connects.
        let placeholder = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = placeholder.local_addr().unwrap();
        drop(placeholder);
        let late = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(60));
            let listener = TcpListener::bind(addr).unwrap();
            let (stream, _) = listener.accept().unwrap();
            read_hello(&stream).unwrap()
        });
        let stream = dial_with_retry(addr).unwrap();
        send_hello(&stream, 42).unwrap();
        assert_eq!(late.join().unwrap(), 42);
    }

    #[test]
    fn elastic_epochs_shrink_and_renumber() {
        // Epoch 0: members {0, 1, 2}. Member 1 "dies"; epoch 1 reseals
        // {0, 2} and renumbers the survivors densely.
        let dir = scratch_dir("elastic-epochs");
        let elastic = TcpElastic {
            rdzv: FileRendezvous::new(&dir),
            timeout: Some(Duration::from_secs(10)),
        };
        std::thread::scope(|scope| {
            let handles: Vec<_> = [0usize, 1, 2]
                .into_iter()
                .map(|me| {
                    let elastic = elastic.clone();
                    scope.spawn(move || {
                        let (members, t) =
                            elastic.join(0, me, &[0, 1, 2]).unwrap();
                        assert_eq!(members, vec![0, 1, 2]);
                        t.barrier().unwrap();
                        if me == 1 {
                            return; // death between epochs
                        }
                        let (members, t) =
                            elastic.join(1, me, &[0, 2]).unwrap();
                        assert_eq!(members, vec![0, 2]);
                        assert_eq!(t.world_size(), 2);
                        let want_rank = if me == 0 { 0 } else { 1 };
                        assert_eq!(t.rank(), want_rank);
                        let all =
                            t.all_gather_bytes(vec![me as u8]).unwrap();
                        assert_eq!(all, vec![vec![0u8], vec![2u8]]);
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
        cleanup(&dir);
    }
}
