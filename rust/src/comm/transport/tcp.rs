//! TCP loopback transport: the same SPMD worker code over real
//! sockets — the proof that the [`Transport`] abstraction carries the
//! trainer, and the template for genuinely multi-node backends.
//!
//! # Topology
//!
//! `connect(d)` builds a full mesh over `127.0.0.1`: one
//! `TcpStream` per unordered rank pair, established through per-rank
//! listeners (ephemeral ports by default; `ORCHMLLM_TCP_BASE_PORT`
//! pins `base+rank` for sandboxed runners). Each stream opens with an
//! 8-byte handshake naming the connecting rank, so acceptors bind
//! streams to peers regardless of arrival order.
//!
//! # Framing
//!
//! Every collective round moves length-prefixed frames:
//!
//! ```text
//! magic: u32 | op: u8 | round: u64 | count: u64 | count × (len: u64, bytes)
//! ```
//!
//! The `(op, round)` pair is verified on receive, so an SPMD ordering
//! violation (a rank issuing a different collective sequence) surfaces
//! as a loud protocol error instead of silently mismatched data.
//!
//! # Schedule
//!
//! Each collective runs `d-1` pairwise exchange steps: at step `s`,
//! rank `r` sends to `(r+s) mod d` on a scoped writer thread while
//! reading from `(r-s) mod d` on the calling thread. Every posted read
//! has a concurrently posted matching write, so the schedule is
//! deadlock-free for arbitrary payload sizes without relying on kernel
//! socket buffering. A peer that dies or stalls trips the per-stream
//! read timeout (`ORCHMLLM_TCP_TIMEOUT_SECS`, default 30, `0` =
//! blocking) — failure semantics are "error within the timeout", never
//! a silent hang.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use super::{fixed, Transport, TransportError, TransportFactory};

const FRAME_MAGIC: u32 = 0x4f43_4d4c; // "OCML"
pub(crate) const HANDSHAKE_MAGIC: u32 = 0x4f43_4853; // "OCHS"

const OP_ALL_TO_ALL: u8 = 1;
const OP_ALL_GATHER: u8 = 2;
const OP_BARRIER: u8 = 3;

/// Sanity bound on a single payload (4 GiB) — corruption guard, not a
/// capacity target.
const MAX_PAYLOAD_BYTES: u64 = 1 << 32;
/// Sanity bound on payload count per frame.
const MAX_PAYLOAD_COUNT: u64 = 1 << 24;

fn op_name(op: u8) -> &'static str {
    match op {
        OP_ALL_TO_ALL => "all_to_all",
        OP_ALL_GATHER => "all_gather",
        OP_BARRIER => "barrier",
        _ => "unknown",
    }
}

// ---------------------------------------------------------------------------
// Framing
// ---------------------------------------------------------------------------

/// Was any link in the chain a raw I/O failure? Protocol errors (bad
/// magic, SPMD ordering violations, implausible lengths) are *our*
/// bugs or corruption — blaming a peer's liveness for them would send
/// the elastic runtime chasing a death that never happened. Only
/// socket-level failures (EOF, reset, timeout) earn a
/// [`TransportError::PeerDead`] attribution.
fn blame_if_io(err: anyhow::Error, peer: usize) -> anyhow::Error {
    let io_rooted = err
        .chain()
        .any(|cause| cause.downcast_ref::<std::io::Error>().is_some());
    if io_rooted {
        err.context(TransportError::PeerDead { rank: peer })
    } else {
        err
    }
}

pub(crate) fn encode_frame(
    op: u8,
    round: u64,
    payloads: &[Vec<u8>],
) -> Vec<u8> {
    let total: usize =
        21 + payloads.iter().map(|p| 8 + p.len()).sum::<usize>();
    let mut out = Vec::with_capacity(total);
    out.extend_from_slice(&FRAME_MAGIC.to_le_bytes());
    out.push(op);
    out.extend_from_slice(&round.to_le_bytes());
    out.extend_from_slice(&(payloads.len() as u64).to_le_bytes());
    for p in payloads {
        out.extend_from_slice(&(p.len() as u64).to_le_bytes());
        out.extend_from_slice(p);
    }
    out
}

pub(crate) fn write_frame(
    stream: &TcpStream,
    frame: &[u8],
) -> std::io::Result<()> {
    let mut w = stream;
    w.write_all(frame)?;
    w.flush()
}

pub(crate) fn read_frame(
    stream: &TcpStream,
    want_op: u8,
    want_round: u64,
) -> Result<Vec<Vec<u8>>> {
    let mut r = stream;
    let mut header = [0u8; 21];
    r.read_exact(&mut header).with_context(|| {
        format!(
            "reading {} frame header (peer dead, or stalled past the \
             read timeout — SPMD ordering violation?)",
            op_name(want_op)
        )
    })?;
    let magic = u32::from_le_bytes(fixed::<4>(&header[0..4])?);
    let op = header[4];
    let round = u64::from_le_bytes(fixed::<8>(&header[5..13])?);
    let count = u64::from_le_bytes(fixed::<8>(&header[13..21])?);
    if magic != FRAME_MAGIC {
        bail!("tcp transport: bad frame magic {magic:#x} (corrupt stream)");
    }
    if op != want_op || round != want_round {
        bail!(
            "tcp transport: SPMD ordering violation — expected {} round \
             {want_round}, peer sent {} round {round}",
            op_name(want_op),
            op_name(op)
        );
    }
    if count > MAX_PAYLOAD_COUNT {
        bail!("tcp transport: implausible payload count {count}");
    }
    // Cap the up-front reserve: a corrupt header that sneaks past the
    // count guard must not trigger a huge allocation before the first
    // per-payload length read can reject the frame.
    let mut payloads = Vec::with_capacity(count.min(1024) as usize);
    for i in 0..count {
        let mut len_buf = [0u8; 8];
        r.read_exact(&mut len_buf)
            .with_context(|| format!("reading payload {i} length"))?;
        let len = u64::from_le_bytes(len_buf);
        if len > MAX_PAYLOAD_BYTES {
            bail!("tcp transport: implausible payload length {len}");
        }
        let mut buf = vec![0u8; len as usize];
        r.read_exact(&mut buf)
            .with_context(|| format!("reading payload {i} body"))?;
        payloads.push(buf);
    }
    Ok(payloads)
}

// ---------------------------------------------------------------------------
// Transport
// ---------------------------------------------------------------------------

/// One rank's handle into a loopback-TCP collective group.
pub struct TcpLoopbackTransport {
    rank: usize,
    d: usize,
    /// `peers[p]` is the stream to rank `p`; `None` at `p == rank`.
    peers: Vec<Option<TcpStream>>,
    /// Collective round counter; all ranks advance it in lockstep
    /// because the group is SPMD.
    round: AtomicU64,
}

impl TcpLoopbackTransport {
    /// Wrap an already-established mesh of peer streams. The loopback
    /// factory builds its mesh single-threaded below; the
    /// `tcp-multiproc` backend ([`super::mesh`]) builds each rank's row
    /// in its own OS process via rendezvous, then reuses this exact
    /// transport — same framing, same schedule, same failure
    /// semantics, proven by the shared conformance battery.
    pub(crate) fn from_streams(
        rank: usize,
        d: usize,
        peers: Vec<Option<TcpStream>>,
    ) -> TcpLoopbackTransport {
        debug_assert_eq!(peers.len(), d);
        TcpLoopbackTransport {
            rank,
            d,
            peers,
            round: AtomicU64::new(0),
        }
    }

    fn peer(&self, p: usize) -> Result<&TcpStream> {
        self.peers[p]
            .as_ref()
            .ok_or_else(|| anyhow!("no stream for peer {p}"))
    }

    /// One pairwise exchange step: write `frame` to `dst` on a scoped
    /// thread while reading a `(want_op, round)` frame from `src`.
    /// Takes the frame by reference so callers whose frame is constant
    /// across steps (all_gather, barrier) encode it once per round.
    fn exchange(
        &self,
        dst: usize,
        src: usize,
        frame: &[u8],
        want_op: u8,
        round: u64,
    ) -> Result<Vec<Vec<u8>>> {
        let dst_stream = self.peer(dst)?;
        let src_stream = self.peer(src)?;
        std::thread::scope(|scope| {
            let writer =
                scope.spawn(move || write_frame(dst_stream, frame));
            // Read before joining the writer: the matching write on the
            // src side is concurrent with this read, and joining first
            // could close a d>=3 cycle of writers all waiting on
            // unposted reads.
            let got = read_frame(src_stream, want_op, round);
            writer
                .join()
                .map_err(|_| anyhow!("tcp writer thread panicked"))?
                // A failed write is always socket-level: blame dst.
                .map_err(|e| blame_if_io(anyhow::Error::from(e), dst))
                .with_context(|| format!("sending to rank {dst}"))?;
            got.map_err(|e| blame_if_io(e, src))
                .with_context(|| format!("receiving from rank {src}"))
        })
    }
}

impl Transport for TcpLoopbackTransport {
    fn rank(&self) -> usize {
        self.rank
    }

    fn world_size(&self) -> usize {
        self.d
    }

    fn all_to_all_bytes(
        &self,
        sends: Vec<(usize, Vec<u8>)>,
    ) -> Result<Vec<(usize, Vec<u8>)>> {
        let d = self.d;
        let mut per_dst: Vec<Vec<Vec<u8>>> = vec![Vec::new(); d];
        for (dst, payload) in sends {
            if dst >= d {
                // Error before any traffic or round advance, so an
                // SPMD-consistent bad call leaves the group aligned.
                bail!("all_to_all: dst {dst} out of range (d = {d})");
            }
            per_dst[dst].push(payload);
        }
        let round = self.round.fetch_add(1, Ordering::Relaxed);
        let mut per_src: Vec<Vec<Vec<u8>>> = vec![Vec::new(); d];
        per_src[self.rank] = std::mem::take(&mut per_dst[self.rank]);
        for s in 1..d {
            let dst = (self.rank + s) % d;
            let src = (self.rank + d - s) % d;
            let frame = encode_frame(OP_ALL_TO_ALL, round, &per_dst[dst]);
            per_src[src] =
                self.exchange(dst, src, &frame, OP_ALL_TO_ALL, round)?;
        }
        let mut out = Vec::new();
        for (src, payloads) in per_src.into_iter().enumerate() {
            for p in payloads {
                out.push((src, p));
            }
        }
        Ok(out)
    }

    fn all_gather_bytes(&self, bytes: Vec<u8>) -> Result<Vec<Vec<u8>>> {
        let d = self.d;
        let round = self.round.fetch_add(1, Ordering::Relaxed);
        let mut slots: Vec<Option<Vec<u8>>> = vec![None; d];
        // The contribution is identical on every step: encode it once.
        let frame = encode_frame(
            OP_ALL_GATHER,
            round,
            std::slice::from_ref(&bytes),
        );
        for s in 1..d {
            let dst = (self.rank + s) % d;
            let src = (self.rank + d - s) % d;
            let mut got =
                self.exchange(dst, src, &frame, OP_ALL_GATHER, round)?;
            if got.len() != 1 {
                bail!(
                    "all_gather: rank {src} sent {} contributions, \
                     expected exactly 1",
                    got.len()
                );
            }
            // Exactly one element after the length check; if it were
            // somehow absent the slot stays `None` and the missing-
            // contribution collect below reports it as an error.
            slots[src] = got.pop();
        }
        slots[self.rank] = Some(bytes);
        slots
            .into_iter()
            .enumerate()
            .map(|(src, s)| {
                s.ok_or_else(|| {
                    anyhow!("all_gather: missing contribution from {src}")
                })
            })
            .collect()
    }

    fn barrier(&self) -> Result<()> {
        let d = self.d;
        let round = self.round.fetch_add(1, Ordering::Relaxed);
        let frame = encode_frame(OP_BARRIER, round, &[]);
        for s in 1..d {
            let dst = (self.rank + s) % d;
            let src = (self.rank + d - s) % d;
            let got = self.exchange(dst, src, &frame, OP_BARRIER, round)?;
            if !got.is_empty() {
                bail!("barrier: rank {src} attached {} payloads", got.len());
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Dialing
// ---------------------------------------------------------------------------

/// How many times [`dial_with_retry`] attempts a connect before giving
/// up. With exponential backoff from [`DIAL_BACKOFF_START`], eight
/// attempts cover ~2.5 s of peer startup skew.
pub(crate) const DIAL_ATTEMPTS: u32 = 8;
/// First backoff delay; doubles per failed attempt.
pub(crate) const DIAL_BACKOFF_START: Duration = Duration::from_millis(10);

/// Connect with bounded retry + exponential backoff.
///
/// Under *concurrent* rendezvous (the `tcp-multiproc` mesh, where every
/// rank races to dial peers that are still binding their listeners), a
/// refused or reset connect usually means "peer not up yet", not "peer
/// dead" — so transient failures are retried with doubling delays and
/// only the final failure is reported, wrapped in the full attempt
/// count so logs distinguish "never came up" from "refused once". The
/// single-threaded loopback factory never needs this (it binds every
/// listener before the first dial), but uses plain connects against
/// addresses it just bound, so there is nothing to retry there.
pub(crate) fn dial_with_retry(addr: SocketAddr) -> Result<TcpStream> {
    let mut delay = DIAL_BACKOFF_START;
    let mut last: Option<anyhow::Error> = None;
    for attempt in 0..DIAL_ATTEMPTS {
        if attempt > 0 {
            std::thread::sleep(delay);
            delay *= 2;
        }
        match TcpStream::connect(addr) {
            Ok(stream) => return Ok(stream),
            Err(e) => last = Some(anyhow::Error::from(e)),
        }
    }
    Err(last
        .unwrap_or_else(|| anyhow!("no connect attempt ran"))
        .context(format!(
            "dialing {addr} failed after {DIAL_ATTEMPTS} attempts \
             with exponential backoff"
        )))
}

/// Write the 8-byte hello (`HANDSHAKE_MAGIC` + our rank/member id) that
/// opens every mesh stream.
pub(crate) fn send_hello(stream: &TcpStream, id: usize) -> Result<()> {
    let mut hello = [0u8; 8];
    hello[0..4].copy_from_slice(&HANDSHAKE_MAGIC.to_le_bytes());
    hello[4..8].copy_from_slice(&(id as u32).to_le_bytes());
    let mut w = stream;
    w.write_all(&hello)
        .with_context(|| format!("sending handshake as {id}"))
}

/// Read and validate a peer's hello, returning its claimed id.
pub(crate) fn read_hello(stream: &TcpStream) -> Result<usize> {
    let mut hello = [0u8; 8];
    let mut r = stream;
    r.read_exact(&mut hello).context("reading handshake")?;
    let magic = u32::from_le_bytes(fixed::<4>(&hello[0..4])?);
    if magic != HANDSHAKE_MAGIC {
        bail!("bad handshake magic {magic:#x}");
    }
    Ok(u32::from_le_bytes(fixed::<4>(&hello[4..8])?) as usize)
}

// ---------------------------------------------------------------------------
// Factory
// ---------------------------------------------------------------------------

/// Factory for the `tcp` backend (loopback full mesh).
#[derive(Clone, Copy, Debug)]
pub struct TcpLoopbackFactory {
    /// First listener port; rank `r` listens on `base_port + r`.
    /// `0` = ephemeral ports (the default — always safe in parallel
    /// test runs).
    pub base_port: u16,
    /// Per-stream read timeout; `None` blocks forever.
    pub timeout: Option<Duration>,
}

impl Default for TcpLoopbackFactory {
    fn default() -> Self {
        TcpLoopbackFactory {
            base_port: 0,
            timeout: Some(Duration::from_secs(30)),
        }
    }
}

impl TcpLoopbackFactory {
    /// Construct from the environment:
    /// `ORCHMLLM_TCP_BASE_PORT` (default 0 = ephemeral) and
    /// `ORCHMLLM_TCP_TIMEOUT_SECS` (default 30; 0 = no timeout).
    /// Unparsable values warn loudly before falling back — a silently
    /// ignored port override would defeat the pinning it exists for.
    pub fn from_env() -> Self {
        fn parsed<T: std::str::FromStr>(var: &str) -> Option<T> {
            let raw = std::env::var(var).ok()?;
            match raw.trim().parse::<T>() {
                Ok(v) => Some(v),
                Err(_) => {
                    eprintln!(
                        "warning: ignoring unparsable {var}='{raw}', \
                         using the default"
                    );
                    None
                }
            }
        }
        let base_port = parsed::<u16>("ORCHMLLM_TCP_BASE_PORT").unwrap_or(0);
        let timeout = match parsed::<u64>("ORCHMLLM_TCP_TIMEOUT_SECS") {
            Some(0) => None,
            Some(secs) => Some(Duration::from_secs(secs)),
            None => Some(Duration::from_secs(30)),
        };
        TcpLoopbackFactory { base_port, timeout }
    }
}

impl TransportFactory for TcpLoopbackFactory {
    fn name(&self) -> &'static str {
        "tcp"
    }

    fn description(&self) -> &'static str {
        "loopback TCP full mesh, length-prefixed frames per peer pair"
    }

    fn connect(&self, d: usize) -> Result<Vec<Box<dyn Transport>>> {
        if d == 0 {
            bail!("transport world size must be >= 1");
        }
        // The single-threaded dial-then-accept handshake parks up to
        // d-1 completed connections in each listener's accept queue,
        // which is only safe under the kernel's 128-entry backlog.
        if d > 128 {
            bail!(
                "tcp loopback mesh supports at most 128 ranks (got {d}); \
                 use the `tcp-multiproc` backend, whose concurrent \
                 rendezvous (see transport/mesh.rs) has no backlog cap"
            );
        }
        // Bind every rank's listener up front so addresses are known
        // before any connect.
        let mut listeners = Vec::with_capacity(d);
        let mut addrs: Vec<SocketAddr> = Vec::with_capacity(d);
        for rank in 0..d {
            let port = if self.base_port == 0 {
                0
            } else {
                self.base_port.checked_add(rank as u16).ok_or_else(
                    || anyhow!("ORCHMLLM_TCP_BASE_PORT + {rank} overflows"),
                )?
            };
            let listener = TcpListener::bind(("127.0.0.1", port))
                .with_context(|| {
                    format!("binding listener for rank {rank} (port {port})")
                })?;
            addrs.push(listener.local_addr()?);
            listeners.push(listener);
        }

        // Full mesh: rank i dials rank j for every i < j. Loopback
        // connects complete against the listener backlog, so dialing
        // and accepting can run sequentially on this one thread.
        let mut streams: Vec<Vec<Option<TcpStream>>> = (0..d)
            .map(|_| (0..d).map(|_| None).collect())
            .collect();
        for j in 0..d {
            for i in 0..j {
                let stream =
                    TcpStream::connect(addrs[j]).with_context(|| {
                        format!("rank {i} dialing rank {j} at {}", addrs[j])
                    })?;
                let mut hello = [0u8; 8];
                hello[0..4]
                    .copy_from_slice(&HANDSHAKE_MAGIC.to_le_bytes());
                hello[4..8].copy_from_slice(&(i as u32).to_le_bytes());
                (&stream)
                    .write_all(&hello)
                    .with_context(|| format!("handshake {i} -> {j}"))?;
                streams[i][j] = Some(stream);
            }
        }
        for (j, listener) in listeners.iter().enumerate() {
            for _ in 0..j {
                let (stream, _) = listener
                    .accept()
                    .with_context(|| format!("rank {j} accepting a peer"))?;
                let mut hello = [0u8; 8];
                (&stream)
                    .read_exact(&mut hello)
                    .context("reading handshake")?;
                let magic = u32::from_le_bytes(fixed::<4>(&hello[0..4])?);
                let peer =
                    u32::from_le_bytes(fixed::<4>(&hello[4..8])?) as usize;
                if magic != HANDSHAKE_MAGIC {
                    bail!("bad handshake magic {magic:#x} on rank {j}");
                }
                if peer >= j || streams[j][peer].is_some() {
                    bail!("duplicate or out-of-order handshake from {peer}");
                }
                streams[j][peer] = Some(stream);
            }
        }

        // Tune every stream: no Nagle batching (collectives are
        // latency-bound), bounded reads AND writes (a stalled peer
        // also backs up the sender once the kernel buffer fills, so
        // both directions must error within the timeout).
        for row in &streams {
            for stream in row.iter().flatten() {
                stream.set_nodelay(true).context("set_nodelay")?;
                stream
                    .set_read_timeout(self.timeout)
                    .context("set_read_timeout")?;
                stream
                    .set_write_timeout(self.timeout)
                    .context("set_write_timeout")?;
            }
        }

        Ok(streams
            .into_iter()
            .enumerate()
            .map(|(rank, peers)| {
                Box::new(TcpLoopbackTransport {
                    rank,
                    d,
                    peers,
                    round: AtomicU64::new(0),
                }) as Box<dyn Transport>
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_world<R, F>(d: usize, f: F) -> Vec<R>
    where
        F: Fn(Box<dyn Transport>) -> R + Send + Sync,
        R: Send,
    {
        crate::comm::transport::run_world(
            &TcpLoopbackFactory::default(),
            d,
            f,
        )
        .unwrap()
    }

    #[test]
    fn frame_roundtrip() {
        let payloads = vec![vec![1u8, 2, 3], vec![], vec![9u8; 100]];
        let frame = encode_frame(OP_ALL_TO_ALL, 7, &payloads);
        // Loop the frame through a real socket pair.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let tx = TcpStream::connect(addr).unwrap();
        let (rx, _) = listener.accept().unwrap();
        write_frame(&tx, &frame).unwrap();
        let got = read_frame(&rx, OP_ALL_TO_ALL, 7).unwrap();
        assert_eq!(got, payloads);
        // Round/op mismatches are loud.
        write_frame(&tx, &frame).unwrap();
        let err = read_frame(&rx, OP_ALL_GATHER, 7).unwrap_err();
        assert!(err.to_string().contains("SPMD"), "{err}");
    }

    #[test]
    fn mesh_routes_all_collectives() {
        let d = 3;
        let out = run_world(d, move |t| {
            let rank = t.rank();
            assert_eq!(t.world_size(), d);
            // all_to_all: everyone sends rank*10+dst to every dst.
            let sends: Vec<(usize, Vec<u8>)> = (0..d)
                .map(|dst| (dst, vec![(rank * 10 + dst) as u8]))
                .collect();
            let recv = t.all_to_all_bytes(sends).unwrap();
            let want: Vec<(usize, Vec<u8>)> = (0..d)
                .map(|src| (src, vec![(src * 10 + rank) as u8]))
                .collect();
            assert_eq!(recv, want);
            // all_gather in rank order.
            let all = t.all_gather_bytes(vec![rank as u8; 2]).unwrap();
            assert_eq!(
                all,
                (0..d).map(|r| vec![r as u8; 2]).collect::<Vec<_>>()
            );
            t.barrier().unwrap();
            // all_reduce_sum through the generic default impl.
            let mut grads: Vec<f32> =
                (0..10).map(|i| (rank + i) as f32).collect();
            t.all_reduce_sum(&mut grads).unwrap();
            grads
        });
        let want: Vec<f32> = (0..10)
            .map(|i| (0..3).map(|r| (r + i) as f32).sum())
            .collect();
        for got in out {
            assert_eq!(got, want);
        }
    }

    #[test]
    fn single_rank_degenerates() {
        let out = run_world(1, |t| {
            let recv = t
                .all_to_all_bytes(vec![(0, vec![5u8]), (0, vec![6u8])])
                .unwrap();
            assert_eq!(recv, vec![(0, vec![5u8]), (0, vec![6u8])]);
            assert_eq!(
                t.all_gather_bytes(vec![1u8]).unwrap(),
                vec![vec![1u8]]
            );
            t.barrier().unwrap();
            let mut x = vec![3.0f32];
            t.all_reduce_sum(&mut x).unwrap();
            assert_eq!(x, vec![3.0]);
        });
        assert_eq!(out.len(), 1);
    }

    #[test]
    fn dead_peer_surfaces_typed_error() {
        // Rank 0 drops its transport (sockets close) before the round;
        // rank 1 must get a typed PeerDead naming rank 0, not an
        // opaque string and not a hang.
        let factory = TcpLoopbackFactory {
            base_port: 0,
            timeout: Some(Duration::from_millis(200)),
        };
        let mut world = factory.connect(2).unwrap();
        let t1 = world.pop().unwrap();
        let t0 = world.pop().unwrap();
        drop(t0);
        let err = t1.barrier().unwrap_err();
        assert_eq!(
            crate::comm::transport::peer_dead(&err),
            Some(0),
            "{err:#}"
        );
    }

    #[test]
    fn large_payloads_do_not_deadlock() {
        // Bigger than loopback socket buffers in both directions: the
        // scoped-writer schedule must still complete.
        let big = 4 << 20;
        let out = run_world(2, move |t| {
            let rank = t.rank();
            let recv = t
                .all_to_all_bytes(vec![(1 - rank, vec![rank as u8; big])])
                .unwrap();
            assert_eq!(recv.len(), 1);
            assert_eq!(recv[0].0, 1 - rank);
            assert_eq!(recv[0].1.len(), big);
            assert!(recv[0].1.iter().all(|&b| b == (1 - rank) as u8));
        });
        assert_eq!(out.len(), 2);
    }
}
