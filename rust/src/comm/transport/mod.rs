//! Pluggable communication transports — the comm-layer twin of the
//! [`crate::balance::registry`] extension point.
//!
//! The paper's dispatcher assumes a communicator it can retarget: the
//! All-to-All rearrangement (§4.2) is what makes post-balancing cheap,
//! so the *substrate* carrying it must be swappable before any
//! multi-node story exists. This module turns the trainer's hard-wired
//! in-process engine into an API:
//!
//! * [`Transport`] — a rank-scoped handle into one SPMD collective
//!   group: `all_to_all_bytes`, `all_gather_bytes`, `all_reduce_sum`,
//!   `barrier`. Object-safe (the trainer holds `Box<dyn Transport>`),
//!   so the data plane is raw framed bytes; typed payloads ride on top
//!   via [`Wire`] + [`TransportExt`].
//! * [`Wire`] — manifest-based encode/decode for payloads that cross
//!   ranks: every frame starts with a one-byte dtype tag and explicit
//!   lengths, so a decoder can validate what it received instead of
//!   trusting the sender. The trainer's batch shards
//!   (`(example_id, Vec<f32>)` token rows, `(example_id, Vec<i32>)`
//!   text) implement it here.
//! * [`TransportFactory`] + [`registry`] — name → backend resolution
//!   for the `--transport` CLI flag, mirroring the balancer registry:
//!   `inproc` (shared-memory channels, the NCCL stand-in), `tcp`
//!   (loopback sockets with per-peer connections, proving the same
//!   worker code runs over a real network substrate), and
//!   `tcp-multiproc` (the same wire protocol with rank discovery via a
//!   file [`crate::comm::rendezvous`], so workers run as separate OS
//!   processes — see [`mesh`]).
//!
//! Death signals are typed: backends attach [`TransportError::PeerDead`]
//! to the error chain when the substrate points at a specific dead
//! rank, and [`peer_dead`] recovers it through any amount of
//! `.context(...)` wrapping. The elastic runtime
//! (`trainer/elastic.rs`) turns that signal into shrink-the-world
//! recovery instead of a crash.
//!
//! # SPMD contract (pinned by `rust/tests/transport_conformance.rs`)
//!
//! All `d` ranks must issue the *same sequence* of collectives; each
//! call is one round, and rounds never overlap. Backends must deliver:
//!
//! * `all_to_all_bytes`: results sorted by source rank, with each
//!   source's payloads in its send order; self-sends loop back.
//! * `all_gather_bytes`: one contribution per rank, returned in rank
//!   order. A rank that skips a round must fail loudly, never replay a
//!   stale contribution.
//! * `all_reduce_sum`: elementwise sum accumulated in **increasing rank
//!   order** — the fixed reduction order that keeps results bit-stable
//!   across backends and across repeated runs. The default impl is a
//!   reduce-scatter + all-gather over the byte collectives: O(n) extra
//!   memory per rank regardless of `d` (each rank stages one chunk set,
//!   not `d` full buffers).
//! * failure semantics: a protocol mismatch (wrong round, wrong op,
//!   wrong dtype) is an error, not a hang; backends should surface dead
//!   or stalled peers as errors where the substrate allows it.

pub mod inproc;
pub mod mesh;
pub mod tcp;

use std::fmt;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

// ---------------------------------------------------------------------------
// TransportError: typed death signals
// ---------------------------------------------------------------------------

/// Typed failure classification attached to collective errors.
///
/// Backends report substrate-level failures through `anyhow` context
/// chains; `PeerDead` is the one variant the elastic runtime acts on —
/// it names the rank the *local* evidence (a broken socket, a barrier
/// generation the rank never joined) points at. Attribution is a hint,
/// not a verdict: an indirectly-stalled peer can be blamed for a death
/// it only witnessed, which is why recovery re-rendezvouses the whole
/// surviving world instead of trusting any single rank's diagnosis
/// (see `trainer/elastic.rs`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportError {
    /// A peer stopped participating mid-round: its connection died or
    /// it never reached a barrier generation before the watchdog fired.
    PeerDead {
        /// The rank the local evidence points at.
        rank: usize,
    },
}

impl fmt::Display for TransportError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TransportError::PeerDead { rank } => {
                write!(f, "peer rank {rank} is dead or unreachable")
            }
        }
    }
}

impl std::error::Error for TransportError {}

/// Extract the dead-peer rank from an error chain, if any link carries
/// a [`TransportError::PeerDead`]. `anyhow`'s `downcast_ref` walks the
/// whole context chain, so callers can wrap transport errors freely
/// (`.context("encoder dispatch")` etc.) without losing the signal.
pub fn peer_dead(err: &anyhow::Error) -> Option<usize> {
    match err.downcast_ref::<TransportError>() {
        Some(TransportError::PeerDead { rank }) => Some(*rank),
        None => None,
    }
}

// ---------------------------------------------------------------------------
// Wire: manifest-based payload encoding
// ---------------------------------------------------------------------------

/// Dtype tags opening every [`Wire`] manifest.
const TAG_F32S: u8 = 1;
const TAG_I32S: u8 = 2;
const TAG_ID_F32S: u8 = 3;
const TAG_ID_I32S: u8 = 4;
const TAG_U64: u8 = 5;
const TAG_BYTES: u8 = 6;

/// A payload that can cross rank boundaries: encodes itself with a
/// self-describing manifest (dtype tag + element counts) so the
/// receiving side validates shape and dtype before trusting the bytes.
pub trait Wire: Sized + Send {
    /// Append the manifest + payload to `out`.
    fn encode(&self, out: &mut Vec<u8>);

    /// Decode a full buffer produced by [`Wire::encode`].
    fn decode(bytes: &[u8]) -> Result<Self>;

    /// Convenience: encode into a fresh buffer.
    fn to_wire(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode(&mut out);
        out
    }
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Copy a compile-time-sized array out of a slice, as a `Result`.
///
/// Every caller passes a slice it just bounds-checked (or a const-range
/// view of a fixed array), so the error arm is unreachable in practice —
/// but these conversions sit on the collective decode path, where a
/// length confusion must propagate as an error to the peer-death
/// classifier rather than abort the process mid-round.
pub(crate) fn fixed<const N: usize>(b: &[u8]) -> Result<[u8; N]> {
    if b.len() != N {
        bail!("wire: expected {N} bytes, got {}", b.len());
    }
    let mut a = [0u8; N];
    a.copy_from_slice(b);
    Ok(a)
}

fn take_u64(bytes: &[u8], pos: &mut usize) -> Result<u64> {
    let end = *pos + 8;
    let slice = bytes
        .get(*pos..end)
        .ok_or_else(|| anyhow!("wire: truncated u64 at offset {pos}"))?;
    *pos = end;
    Ok(u64::from_le_bytes(fixed::<8>(slice)?))
}

fn take_tag(bytes: &[u8], pos: &mut usize, want: u8) -> Result<()> {
    let got = *bytes
        .get(*pos)
        .ok_or_else(|| anyhow!("wire: empty buffer, wanted tag {want}"))?;
    if got != want {
        bail!("wire: dtype tag mismatch (got {got}, wanted {want})");
    }
    *pos += 1;
    Ok(())
}

/// Encode an `f32` slice as little-endian bytes (no manifest).
pub fn f32s_to_bytes(data: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() * 4);
    for x in data {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Decode little-endian `f32` bytes (no manifest).
pub fn bytes_to_f32s(bytes: &[u8]) -> Result<Vec<f32>> {
    if bytes.len() % 4 != 0 {
        bail!("wire: f32 buffer length {} not a multiple of 4", bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| {
            // chunks_exact(4) yields exactly 4 bytes per chunk.
            let mut a = [0u8; 4];
            a.copy_from_slice(c);
            f32::from_le_bytes(a)
        })
        .collect())
}

fn put_f32s(out: &mut Vec<u8>, data: &[f32]) {
    put_u64(out, data.len() as u64);
    for x in data {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Checked `pos + n * elem_size` — a corrupt or malicious count must
/// error, not wrap around and alias a differently-shaped payload.
fn payload_end(pos: usize, n: usize, elem_size: usize) -> Result<usize> {
    n.checked_mul(elem_size)
        .and_then(|b| pos.checked_add(b))
        .ok_or_else(|| anyhow!("wire: implausible element count {n}"))
}

fn take_f32s(bytes: &[u8], pos: &mut usize) -> Result<Vec<f32>> {
    let n = take_u64(bytes, pos)? as usize;
    let end = payload_end(*pos, n, 4)?;
    let slice = bytes
        .get(*pos..end)
        .ok_or_else(|| anyhow!("wire: truncated f32 payload ({n} elems)"))?;
    *pos = end;
    bytes_to_f32s(slice)
}

fn put_i32s(out: &mut Vec<u8>, data: &[i32]) {
    put_u64(out, data.len() as u64);
    for x in data {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn take_i32s(bytes: &[u8], pos: &mut usize) -> Result<Vec<i32>> {
    let n = take_u64(bytes, pos)? as usize;
    let end = payload_end(*pos, n, 4)?;
    let slice = bytes
        .get(*pos..end)
        .ok_or_else(|| anyhow!("wire: truncated i32 payload ({n} elems)"))?;
    *pos = end;
    Ok(slice
        .chunks_exact(4)
        .map(|c| {
            // chunks_exact(4) yields exactly 4 bytes per chunk.
            let mut a = [0u8; 4];
            a.copy_from_slice(c);
            i32::from_le_bytes(a)
        })
        .collect())
}

fn check_consumed(bytes: &[u8], pos: usize) -> Result<()> {
    if pos != bytes.len() {
        bail!(
            "wire: {} trailing bytes after payload",
            bytes.len() - pos
        );
    }
    Ok(())
}

/// One instantiation per element type generates both the plain
/// `Vec<E>` manifest and the trainer's `(example_id, Vec<E>)` batch-
/// shard manifest: the four impls differ only in dtype tag and element
/// codec, and letting the copies drift is how decoders rot. (A generic
/// `impl<E: Pod> Wire for Vec<E>` would overlap the dedicated
/// `Vec<u8>` raw-bytes impl, so the dedup lives in a macro instead.)
macro_rules! pod_vec_wire {
    ($elem:ty, $tag:expr, $id_tag:expr, $put:ident, $take:ident) => {
        impl Wire for Vec<$elem> {
            fn encode(&self, out: &mut Vec<u8>) {
                out.push($tag);
                $put(out, self);
            }

            fn decode(bytes: &[u8]) -> Result<Self> {
                let mut pos = 0;
                take_tag(bytes, &mut pos, $tag)?;
                let v = $take(bytes, &mut pos)?;
                check_consumed(bytes, pos)?;
                Ok(v)
            }
        }

        /// The trainer's batch shard: `(global example id, payload)`.
        impl Wire for (usize, Vec<$elem>) {
            fn encode(&self, out: &mut Vec<u8>) {
                out.push($id_tag);
                put_u64(out, self.0 as u64);
                $put(out, &self.1);
            }

            fn decode(bytes: &[u8]) -> Result<Self> {
                let mut pos = 0;
                take_tag(bytes, &mut pos, $id_tag)?;
                let id = take_u64(bytes, &mut pos)? as usize;
                let v = $take(bytes, &mut pos)?;
                check_consumed(bytes, pos)?;
                Ok((id, v))
            }
        }
    };
}

pod_vec_wire!(f32, TAG_F32S, TAG_ID_F32S, put_f32s, take_f32s);
pod_vec_wire!(i32, TAG_I32S, TAG_ID_I32S, put_i32s, take_i32s);

// ---------------------------------------------------------------------------
// Shard: the typed batch-shard payload
// ---------------------------------------------------------------------------

/// A typed batch shard crossing ranks during dispatch: the global
/// example id plus an `Arc`-shared payload buffer.
///
/// In-process backends move the `Arc` itself — refcount traffic, zero
/// payload copies (the fast path gradients already enjoy). Byte
/// substrates fall through the [`Wire`] manifest below, which is
/// bit-identical to the `(usize, Vec<f32>)` / `(usize, Vec<i32>)`
/// encodings, so `inproc` and `tcp` deliver interchangeable bytes and
/// the conformance suite can keep comparing them verbatim.
#[derive(Clone, Debug, PartialEq)]
pub enum Shard {
    /// Token rows (encoder embeddings, LLM activations).
    F32(usize, Arc<Vec<f32>>),
    /// Text token ids.
    I32(usize, Arc<Vec<i32>>),
}

impl Shard {
    /// Wrap owned f32 rows.
    pub fn f32(id: usize, rows: Vec<f32>) -> Shard {
        Shard::F32(id, Arc::new(rows))
    }

    /// Share an existing f32 buffer — no copy, the caller keeps its
    /// handle.
    pub fn f32_shared(id: usize, rows: Arc<Vec<f32>>) -> Shard {
        Shard::F32(id, rows)
    }

    /// Wrap owned i32 tokens.
    pub fn i32(id: usize, data: Vec<i32>) -> Shard {
        Shard::I32(id, Arc::new(data))
    }

    /// The global example id this shard belongs to.
    pub fn id(&self) -> usize {
        match self {
            Shard::F32(id, _) | Shard::I32(id, _) => *id,
        }
    }

    /// Expect f32 rows; a shard of the wrong dtype is a protocol error.
    pub fn into_f32(self) -> Result<(usize, Arc<Vec<f32>>)> {
        match self {
            Shard::F32(id, rows) => Ok((id, rows)),
            Shard::I32(id, _) => {
                bail!("shard {id}: dtype mismatch (wanted f32 rows, got i32)")
            }
        }
    }

    /// Expect i32 tokens; a shard of the wrong dtype is a protocol
    /// error.
    pub fn into_i32(self) -> Result<(usize, Arc<Vec<i32>>)> {
        match self {
            Shard::I32(id, data) => Ok((id, data)),
            Shard::F32(id, _) => {
                bail!("shard {id}: dtype mismatch (wanted i32 text, got f32)")
            }
        }
    }
}

impl Wire for Shard {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            Shard::F32(id, rows) => {
                out.push(TAG_ID_F32S);
                put_u64(out, *id as u64);
                put_f32s(out, rows);
            }
            Shard::I32(id, data) => {
                out.push(TAG_ID_I32S);
                put_u64(out, *id as u64);
                put_i32s(out, data);
            }
        }
    }

    fn decode(bytes: &[u8]) -> Result<Self> {
        let tag = *bytes
            .first()
            .ok_or_else(|| anyhow!("wire: empty buffer, wanted a shard"))?;
        let mut pos = 1;
        let id = take_u64(bytes, &mut pos)? as usize;
        let shard = match tag {
            TAG_ID_F32S => Shard::F32(id, Arc::new(take_f32s(bytes, &mut pos)?)),
            TAG_ID_I32S => Shard::I32(id, Arc::new(take_i32s(bytes, &mut pos)?)),
            got => bail!("wire: tag {got} is not a shard dtype"),
        };
        check_consumed(bytes, pos)?;
        Ok(shard)
    }
}

impl Wire for u64 {
    fn encode(&self, out: &mut Vec<u8>) {
        out.push(TAG_U64);
        put_u64(out, *self);
    }

    fn decode(bytes: &[u8]) -> Result<Self> {
        let mut pos = 0;
        take_tag(bytes, &mut pos, TAG_U64)?;
        let v = take_u64(bytes, &mut pos)?;
        check_consumed(bytes, pos)?;
        Ok(v)
    }
}

impl Wire for Vec<u8> {
    fn encode(&self, out: &mut Vec<u8>) {
        out.push(TAG_BYTES);
        put_u64(out, self.len() as u64);
        out.extend_from_slice(self);
    }

    fn decode(bytes: &[u8]) -> Result<Self> {
        let mut pos = 0;
        take_tag(bytes, &mut pos, TAG_BYTES)?;
        let n = take_u64(bytes, &mut pos)? as usize;
        let end = payload_end(pos, n, 1)?;
        let slice = bytes
            .get(pos..end)
            .ok_or_else(|| anyhow!("wire: truncated byte payload"))?;
        let v = slice.to_vec();
        check_consumed(bytes, end)?;
        Ok(v)
    }
}

// ---------------------------------------------------------------------------
// Transport
// ---------------------------------------------------------------------------

/// A rank-scoped handle into one SPMD collective group.
///
/// One `Transport` belongs to exactly one rank; the factory hands out
/// `d` of them, one per worker. All methods take `&self` so a handle
/// can sit behind `Box<dyn Transport>` inside a worker without
/// threading mutability through the training loop.
pub trait Transport: Send {
    /// This handle's rank in `0..world_size()`.
    fn rank(&self) -> usize;

    /// Number of ranks in the group (the paper's `d`).
    fn world_size(&self) -> usize;

    /// Point-to-point rearrangement round: submit `(dst, payload)`
    /// pairs, receive the `(src, payload)` pairs addressed to this
    /// rank, sorted by `src` with each source's payloads in send order.
    /// Self-sends loop back and cost no wire traffic.
    fn all_to_all_bytes(
        &self,
        sends: Vec<(usize, Vec<u8>)>,
    ) -> Result<Vec<(usize, Vec<u8>)>>;

    /// Every rank contributes one buffer; all ranks receive all `d`
    /// buffers in rank order.
    fn all_gather_bytes(&self, bytes: Vec<u8>) -> Result<Vec<Vec<u8>>>;

    /// Synchronization point with no data.
    fn barrier(&self) -> Result<()>;

    /// Liveness probe piggybacked on the barrier: every rank checks in,
    /// and a rank that fails to arrive within the backend's timeout
    /// surfaces as [`TransportError::PeerDead`]. The elastic trainer
    /// runs one heartbeat round per step boundary so death between
    /// steps is detected at the *next* step, not mid-collective.
    fn heartbeat(&self) -> Result<()> {
        self.barrier().context("heartbeat round")
    }

    /// Sum-all-reduce of equally-shaped f32 buffers (gradient sync).
    ///
    /// Default: reduce-scatter + all-gather over the byte collectives.
    /// Each rank owns chunk `r` of the buffer, receives every rank's
    /// slice of its chunk (an All-to-All of `n/d`-sized pieces), sums
    /// them in **increasing source-rank order** (the fixed, bit-stable
    /// reduction order), then all-gathers the reduced chunks. Peak
    /// extra memory is O(n) per rank — independent of `d`, unlike the
    /// all-gather-of-full-buffers strawman's O(d·n).
    // orchlint: allow(collective-asymmetry): the d == 1 early return and
    // the shape bails between phases key on world size and on frames the
    // whole group already exchanged — rank-invariant conditions, so every
    // rank takes the same exit; a genuine peer failure surfaces as Err
    // from the underlying collective before any bail here can diverge.
    fn all_reduce_sum(&self, data: &mut [f32]) -> Result<()> {
        let d = self.world_size();
        let rank = self.rank();
        if d == 1 {
            return Ok(());
        }
        let n = data.len();
        let bounds = |k: usize| (k * n / d, (k + 1) * n / d);

        // Reduce-scatter: ship slice k of my buffer to chunk owner k.
        let sends: Vec<(usize, Vec<u8>)> = (0..d)
            .map(|k| {
                let (lo, hi) = bounds(k);
                (k, f32s_to_bytes(&data[lo..hi]))
            })
            .collect();
        let received = self
            .all_to_all_bytes(sends)
            .context("all_reduce_sum reduce-scatter")?;
        let (lo, hi) = bounds(rank);
        let mut acc = vec![0.0f32; hi - lo];
        if received.len() != d {
            bail!(
                "all_reduce_sum: expected {d} chunk contributions, got {}",
                received.len()
            );
        }
        for (idx, (src, bytes)) in received.into_iter().enumerate() {
            if src != idx {
                bail!(
                    "all_reduce_sum: contribution {idx} came from rank \
                     {src}; a peer skipped the round"
                );
            }
            let chunk = bytes_to_f32s(&bytes)?;
            if chunk.len() != acc.len() {
                bail!(
                    "all_reduce_sum: rank {src} sent chunk of {} elems, \
                     expected {}",
                    chunk.len(),
                    acc.len()
                );
            }
            // Fixed reduction order: contributions arrive sorted by
            // src, so every element sums rank 0, 1, …, d-1.
            for (a, x) in acc.iter_mut().zip(&chunk) {
                *a += x;
            }
        }

        // All-gather the reduced chunks back into the full buffer.
        let gathered = self
            .all_gather_bytes(f32s_to_bytes(&acc))
            .context("all_reduce_sum all-gather")?;
        if gathered.len() != d {
            bail!(
                "all_reduce_sum: expected {d} reduced chunks, got {}",
                gathered.len()
            );
        }
        for (k, bytes) in gathered.into_iter().enumerate() {
            let chunk = bytes_to_f32s(&bytes)?;
            let (lo, hi) = bounds(k);
            if chunk.len() != hi - lo {
                bail!(
                    "all_reduce_sum: reduced chunk {k} has {} elems, \
                     expected {}",
                    chunk.len(),
                    hi - lo
                );
            }
            data[lo..hi].copy_from_slice(&chunk);
        }
        Ok(())
    }

    /// Typed batch-shard rearrangement round — the dispatcher's hot
    /// path. Same ordering contract as [`Transport::all_to_all_bytes`].
    ///
    /// Default: [`Wire`]-encode through the byte collective (what byte
    /// substrates like `tcp` actually ship). In-process backends
    /// override this to move the `Arc`-shared payloads directly,
    /// skipping the encode/decode round-trip entirely.
    fn all_to_all_shards(
        &self,
        sends: Vec<(usize, Shard)>,
    ) -> Result<Vec<(usize, Shard)>> {
        let raw: Vec<(usize, Vec<u8>)> = sends
            .into_iter()
            .map(|(dst, shard)| (dst, shard.to_wire()))
            .collect();
        self.all_to_all_bytes(raw)?
            .into_iter()
            .map(|(src, bytes)| {
                Shard::decode(&bytes)
                    .with_context(|| format!("shard from rank {src}"))
                    .map(|shard| (src, shard))
            })
            .collect()
    }
}

/// Typed collectives over any [`Transport`]: encode with [`Wire`],
/// move bytes, decode, preserving the ordering contract.
pub trait TransportExt: Transport {
    /// Typed [`Transport::all_to_all_bytes`].
    fn all_to_all<T: Wire>(
        &self,
        sends: Vec<(usize, T)>,
    ) -> Result<Vec<(usize, T)>> {
        let raw: Vec<(usize, Vec<u8>)> = sends
            .into_iter()
            .map(|(dst, item)| (dst, item.to_wire()))
            .collect();
        self.all_to_all_bytes(raw)?
            .into_iter()
            .map(|(src, bytes)| {
                T::decode(&bytes)
                    .with_context(|| format!("payload from rank {src}"))
                    .map(|item| (src, item))
            })
            .collect()
    }

    /// Typed [`Transport::all_gather_bytes`].
    fn all_gather<T: Wire>(&self, item: &T) -> Result<Vec<T>> {
        self.all_gather_bytes(item.to_wire())?
            .iter()
            .enumerate()
            .map(|(src, bytes)| {
                T::decode(bytes)
                    .with_context(|| format!("contribution from rank {src}"))
            })
            .collect()
    }
}

impl<X: Transport + ?Sized> TransportExt for X {}

// ---------------------------------------------------------------------------
// Factory + registry
// ---------------------------------------------------------------------------

/// Builds a fully-connected world of `d` rank-scoped [`Transport`]
/// handles. Mirrors the balancer registry: resolved by name, described
/// by metadata the CLI lists.
pub trait TransportFactory: Send + Sync + fmt::Debug {
    /// Registry name (also the `--transport` CLI spelling).
    fn name(&self) -> &'static str;

    /// One-line description for the `transports` CLI listing.
    fn description(&self) -> &'static str;

    /// Construct the `d` handles, rank `i` at index `i`. The handles
    /// are live as soon as this returns; dropping all of them tears the
    /// group down.
    fn connect(&self, d: usize) -> Result<Vec<Box<dyn Transport>>>;
}

/// A factory that can rebuild the world after membership changes — the
/// transport-side half of the shrink-the-world recovery protocol in
/// `trainer/elastic.rs`.
///
/// Members carry *stable ids* (their launch-time rank) across epochs;
/// the dense transport rank of a member in some epoch is its index in
/// the sorted surviving-member list. Epoch 0 is the initial, complete
/// rendezvous: every expected member must show up. Later epochs are
/// recovery rounds: whoever registers before the rendezvous deadline
/// *is* the new world, and the sealed membership is returned so every
/// survivor agrees on it.
pub trait ElasticFactory: Send + Sync + fmt::Debug {
    /// Join `epoch` as stable member `me`, expecting (a superset of)
    /// `expected` to participate. Blocks until membership is sealed.
    /// Returns the sealed member list (sorted stable ids) and this
    /// member's transport handle into the new group (its rank is
    /// `members.iter().position(me)`).
    fn join(
        &self,
        epoch: u64,
        me: usize,
        expected: &[usize],
    ) -> Result<(Vec<usize>, Box<dyn Transport>)>;
}

/// Connect a world of `d` ranks and run `f` on every handle, one
/// thread per rank, returning the per-rank results in rank order. The
/// one SPMD world harness shared by calibration, the conformance
/// suite, the comm bench, and the backend unit tests — a rank that
/// panics becomes an error, not a poisoned join.
///
/// Scoped threads, so `f` may borrow from the caller (no `'static`
/// bound).
pub fn run_world<R, F>(
    factory: &dyn TransportFactory,
    d: usize,
    f: F,
) -> Result<Vec<R>>
where
    F: Fn(Box<dyn Transport>) -> R + Send + Sync,
    R: Send,
{
    let handles = factory
        .connect(d)
        .with_context(|| format!("connecting '{}' world", factory.name()))?;
    std::thread::scope(|scope| {
        let f = &f;
        let joins: Vec<_> = handles
            .into_iter()
            .map(|t| scope.spawn(move || f(t)))
            .collect();
        joins
            .into_iter()
            .enumerate()
            .map(|(rank, join)| {
                join.join()
                    .map_err(|_| anyhow!("rank {rank} thread panicked"))
            })
            .collect()
    })
}

/// Name → implementation resolution for the `--transport` CLI flag,
/// the conformance suite, and the comm benches.
pub mod registry {
    use super::inproc::InProcFactory;
    use super::mesh::TcpMeshFactory;
    use super::tcp::TcpLoopbackFactory;
    use super::*;

    /// Every registered transport name, in presentation order.
    pub const NAMES: &[&str] = &["inproc", "tcp", "tcp-multiproc"];

    /// Resolve a registered transport backend by name (aliases
    /// accepted).
    pub fn create(name: &str) -> Option<Arc<dyn TransportFactory>> {
        Some(match name {
            "inproc" | "in-proc" | "threads" => {
                Arc::new(InProcFactory::default())
            }
            "tcp" | "tcp-loopback" | "loopback" => {
                Arc::new(TcpLoopbackFactory::from_env())
            }
            "tcp-multiproc" | "multiproc" | "mesh" => {
                Arc::new(TcpMeshFactory::from_env())
            }
            _ => return None,
        })
    }

    /// Resolve or panic with the list of valid names — for internal
    /// callers whose names are compile-time constants.
    // orchlint: allow(error-propagation): intentional abort API for
    // compile-time-constant names (a typo here is a build bug, not a
    // runtime condition); fallible callers use `create` instead.
    pub fn must(name: &str) -> Arc<dyn TransportFactory> {
        create(name).unwrap_or_else(|| {
            panic!("unknown transport '{name}' (registered: {NAMES:?})")
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_roundtrips_every_payload_kind() {
        let f: Vec<f32> = vec![1.5, -2.25, 0.0];
        assert_eq!(Vec::<f32>::decode(&f.to_wire()).unwrap(), f);

        let i: Vec<i32> = vec![-7, 0, 123456];
        assert_eq!(Vec::<i32>::decode(&i.to_wire()).unwrap(), i);

        let shard: (usize, Vec<f32>) = (42, vec![3.25; 8]);
        assert_eq!(
            <(usize, Vec<f32>)>::decode(&shard.to_wire()).unwrap(),
            shard
        );

        let text: (usize, Vec<i32>) = (7, vec![1, 2, 3]);
        assert_eq!(
            <(usize, Vec<i32>)>::decode(&text.to_wire()).unwrap(),
            text
        );

        assert_eq!(u64::decode(&99u64.to_wire()).unwrap(), 99);

        let raw: Vec<u8> = vec![0xde, 0xad];
        assert_eq!(Vec::<u8>::decode(&raw.to_wire()).unwrap(), raw);
    }

    #[test]
    fn wire_rejects_mismatched_manifest() {
        let f: Vec<f32> = vec![1.0];
        // Decoding f32 bytes as i32 must fail on the dtype tag.
        assert!(Vec::<i32>::decode(&f.to_wire()).is_err());
        // Truncation must fail, not read garbage.
        let enc = f.to_wire();
        assert!(Vec::<f32>::decode(&enc[..enc.len() - 1]).is_err());
        // Trailing bytes must fail.
        let mut padded = enc.clone();
        padded.push(0);
        assert!(Vec::<f32>::decode(&padded).is_err());
        // Empty buffer.
        assert!(Vec::<f32>::decode(&[]).is_err());
        // A tampered manifest whose element count would overflow the
        // end-offset arithmetic must error, not wrap and alias.
        let mut evil = vec![TAG_F32S];
        evil.extend_from_slice(&(1u64 << 62).to_le_bytes());
        evil.extend_from_slice(&[0u8; 8]);
        assert!(Vec::<f32>::decode(&evil).is_err());
        let mut evil_bytes = vec![TAG_BYTES];
        evil_bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        assert!(Vec::<u8>::decode(&evil_bytes).is_err());
    }

    #[test]
    fn f32_byte_helpers_roundtrip() {
        let data = vec![1.0f32, f32::MIN_POSITIVE, -0.0, 7e30];
        let bytes = f32s_to_bytes(&data);
        assert_eq!(bytes_to_f32s(&bytes).unwrap(), data);
        assert!(bytes_to_f32s(&bytes[..5]).is_err());
    }

    #[test]
    fn registry_resolves_every_listed_name() {
        for name in registry::NAMES {
            let f = registry::create(name)
                .unwrap_or_else(|| panic!("{name} missing from create()"));
            assert_eq!(f.name(), *name, "name() disagrees with registry key");
            assert!(!f.description().is_empty());
        }
        assert!(registry::create("nccl").is_none());
    }

    #[test]
    fn aliases_resolve_to_the_same_backend() {
        assert_eq!(registry::must("in-proc").name(), "inproc");
        assert_eq!(registry::must("loopback").name(), "tcp");
        assert_eq!(registry::must("tcp-loopback").name(), "tcp");
        assert_eq!(registry::must("multiproc").name(), "tcp-multiproc");
        assert_eq!(registry::must("mesh").name(), "tcp-multiproc");
    }

    #[test]
    fn peer_dead_survives_context_wrapping() {
        let err = anyhow::Error::from(TransportError::PeerDead { rank: 3 })
            .context("receiving from rank 3")
            .context("encoder dispatch round");
        assert_eq!(peer_dead(&err), Some(3));
        // Plain errors carry no death signal.
        let plain = anyhow!("wire: dtype tag mismatch");
        assert_eq!(peer_dead(&plain), None);
        // Display names the rank for human logs too.
        let msg = TransportError::PeerDead { rank: 7 }.to_string();
        assert!(msg.contains("rank 7"), "{msg}");
    }

    #[test]
    fn shard_wire_is_bit_identical_to_the_tuple_encodings() {
        // The typed fast path must be invisible on the wire: a Shard
        // and the tuple it replaces produce the same bytes, and each
        // decodes the other's encoding.
        let rows = vec![1.5f32, -2.25, 0.0];
        let shard = Shard::f32(42, rows.clone());
        let tuple: (usize, Vec<f32>) = (42, rows.clone());
        assert_eq!(shard.to_wire(), tuple.to_wire());
        assert_eq!(Shard::decode(&tuple.to_wire()).unwrap(), shard);
        assert_eq!(
            <(usize, Vec<f32>)>::decode(&shard.to_wire()).unwrap(),
            tuple
        );

        let text = vec![-7i32, 0, 123];
        let shard = Shard::i32(9, text.clone());
        let tuple: (usize, Vec<i32>) = (9, text.clone());
        assert_eq!(shard.to_wire(), tuple.to_wire());
        assert_eq!(Shard::decode(&tuple.to_wire()).unwrap(), shard);
        assert_eq!(
            <(usize, Vec<i32>)>::decode(&shard.to_wire()).unwrap(),
            tuple
        );
    }

    #[test]
    fn shard_rejects_wrong_dtype() {
        let f32_shard = Shard::f32(1, vec![1.0]);
        assert!(f32_shard.clone().into_i32().is_err());
        assert!(f32_shard.into_f32().is_ok());
        let i32_shard = Shard::i32(2, vec![3]);
        assert!(i32_shard.clone().into_f32().is_err());
        assert!(i32_shard.into_i32().is_ok());
        // A non-shard manifest must not decode as a shard.
        let plain: Vec<f32> = vec![1.0, 2.0];
        assert!(Shard::decode(&plain.to_wire()).is_err());
    }

    #[test]
    fn decode_never_panics_on_corrupt_manifests() {
        use crate::util::prop::{check, Gen};
        // Start from a valid encoding of a random payload kind, then
        // truncate / bit-flip / pad it. Every decoder must return —
        // Ok when the mutation happens to be benign for that type,
        // Err otherwise — but never panic (the prop harness converts
        // a panic into a test failure with the offending seed).
        check("wire decode is total", 400, |g: &mut Gen| {
            let kind = g.usize(0, 6);
            let n = g.usize(0, 16);
            let mut enc: Vec<u8> = match kind {
                0 => (0..n).map(|i| i as f32 * 0.5).collect::<Vec<f32>>()
                    .to_wire(),
                1 => (0..n).map(|i| i as i32 - 3).collect::<Vec<i32>>()
                    .to_wire(),
                2 => (g.usize(0, 100), vec![1.0f32; n]).to_wire(),
                3 => (g.usize(0, 100), vec![-1i32; n]).to_wire(),
                4 => Shard::f32(g.usize(0, 100), vec![2.0; n]).to_wire(),
                _ => vec![0u8; n].to_wire(),
            };
            match g.usize(0, 3) {
                0 => {
                    let cut = g.usize(0, enc.len() + 1);
                    enc.truncate(cut);
                }
                1 => {
                    if !enc.is_empty() {
                        let i = g.usize(0, enc.len());
                        enc[i] ^= 1 << g.usize(0, 8);
                    }
                }
                _ => enc.push(g.usize(0, 256) as u8),
            }
            let _ = Vec::<f32>::decode(&enc);
            let _ = Vec::<i32>::decode(&enc);
            let _ = <(usize, Vec<f32>)>::decode(&enc);
            let _ = <(usize, Vec<i32>)>::decode(&enc);
            let _ = Shard::decode(&enc);
            let _ = u64::decode(&enc);
            let _ = Vec::<u8>::decode(&enc);
        });
    }
}
