//! Pluggable communication transports — the comm-layer twin of the
//! [`crate::balance::registry`] extension point.
//!
//! The paper's dispatcher assumes a communicator it can retarget: the
//! All-to-All rearrangement (§4.2) is what makes post-balancing cheap,
//! so the *substrate* carrying it must be swappable before any
//! multi-node story exists. This module turns the trainer's hard-wired
//! in-process engine into an API:
//!
//! * [`Transport`] — a rank-scoped handle into one SPMD collective
//!   group: `all_to_all_bytes`, `all_gather_bytes`, `all_reduce_sum`,
//!   `barrier`. Object-safe (the trainer holds `Box<dyn Transport>`),
//!   so the data plane is raw framed bytes; typed payloads ride on top
//!   via [`Wire`] + [`TransportExt`].
//! * [`Wire`] — manifest-based encode/decode for payloads that cross
//!   ranks: every frame starts with a one-byte dtype tag and explicit
//!   lengths, so a decoder can validate what it received instead of
//!   trusting the sender. The trainer's batch shards
//!   (`(example_id, Vec<f32>)` token rows, `(example_id, Vec<i32>)`
//!   text) implement it here.
//! * [`TransportFactory`] + [`registry`] — name → backend resolution
//!   for the `--transport` CLI flag, mirroring the balancer registry:
//!   `inproc` (shared-memory channels, the NCCL stand-in) and `tcp`
//!   (loopback sockets with per-peer connections, proving the same
//!   worker code runs over a real network substrate).
//!
//! # SPMD contract (pinned by `rust/tests/transport_conformance.rs`)
//!
//! All `d` ranks must issue the *same sequence* of collectives; each
//! call is one round, and rounds never overlap. Backends must deliver:
//!
//! * `all_to_all_bytes`: results sorted by source rank, with each
//!   source's payloads in its send order; self-sends loop back.
//! * `all_gather_bytes`: one contribution per rank, returned in rank
//!   order. A rank that skips a round must fail loudly, never replay a
//!   stale contribution.
//! * `all_reduce_sum`: elementwise sum accumulated in **increasing rank
//!   order** — the fixed reduction order that keeps results bit-stable
//!   across backends and across repeated runs. The default impl is a
//!   reduce-scatter + all-gather over the byte collectives: O(n) extra
//!   memory per rank regardless of `d` (each rank stages one chunk set,
//!   not `d` full buffers).
//! * failure semantics: a protocol mismatch (wrong round, wrong op,
//!   wrong dtype) is an error, not a hang; backends should surface dead
//!   or stalled peers as errors where the substrate allows it.

pub mod inproc;
pub mod tcp;

use std::fmt;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

// ---------------------------------------------------------------------------
// Wire: manifest-based payload encoding
// ---------------------------------------------------------------------------

/// Dtype tags opening every [`Wire`] manifest.
const TAG_F32S: u8 = 1;
const TAG_I32S: u8 = 2;
const TAG_ID_F32S: u8 = 3;
const TAG_ID_I32S: u8 = 4;
const TAG_U64: u8 = 5;
const TAG_BYTES: u8 = 6;

/// A payload that can cross rank boundaries: encodes itself with a
/// self-describing manifest (dtype tag + element counts) so the
/// receiving side validates shape and dtype before trusting the bytes.
pub trait Wire: Sized + Send {
    /// Append the manifest + payload to `out`.
    fn encode(&self, out: &mut Vec<u8>);

    /// Decode a full buffer produced by [`Wire::encode`].
    fn decode(bytes: &[u8]) -> Result<Self>;

    /// Convenience: encode into a fresh buffer.
    fn to_wire(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode(&mut out);
        out
    }
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn take_u64(bytes: &[u8], pos: &mut usize) -> Result<u64> {
    let end = *pos + 8;
    let slice = bytes
        .get(*pos..end)
        .ok_or_else(|| anyhow!("wire: truncated u64 at offset {pos}"))?;
    *pos = end;
    Ok(u64::from_le_bytes(slice.try_into().unwrap()))
}

fn take_tag(bytes: &[u8], pos: &mut usize, want: u8) -> Result<()> {
    let got = *bytes
        .get(*pos)
        .ok_or_else(|| anyhow!("wire: empty buffer, wanted tag {want}"))?;
    if got != want {
        bail!("wire: dtype tag mismatch (got {got}, wanted {want})");
    }
    *pos += 1;
    Ok(())
}

/// Encode an `f32` slice as little-endian bytes (no manifest).
pub fn f32s_to_bytes(data: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() * 4);
    for x in data {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Decode little-endian `f32` bytes (no manifest).
pub fn bytes_to_f32s(bytes: &[u8]) -> Result<Vec<f32>> {
    if bytes.len() % 4 != 0 {
        bail!("wire: f32 buffer length {} not a multiple of 4", bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

fn put_f32s(out: &mut Vec<u8>, data: &[f32]) {
    put_u64(out, data.len() as u64);
    for x in data {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Checked `pos + n * elem_size` — a corrupt or malicious count must
/// error, not wrap around and alias a differently-shaped payload.
fn payload_end(pos: usize, n: usize, elem_size: usize) -> Result<usize> {
    n.checked_mul(elem_size)
        .and_then(|b| pos.checked_add(b))
        .ok_or_else(|| anyhow!("wire: implausible element count {n}"))
}

fn take_f32s(bytes: &[u8], pos: &mut usize) -> Result<Vec<f32>> {
    let n = take_u64(bytes, pos)? as usize;
    let end = payload_end(*pos, n, 4)?;
    let slice = bytes
        .get(*pos..end)
        .ok_or_else(|| anyhow!("wire: truncated f32 payload ({n} elems)"))?;
    *pos = end;
    bytes_to_f32s(slice)
}

fn put_i32s(out: &mut Vec<u8>, data: &[i32]) {
    put_u64(out, data.len() as u64);
    for x in data {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn take_i32s(bytes: &[u8], pos: &mut usize) -> Result<Vec<i32>> {
    let n = take_u64(bytes, pos)? as usize;
    let end = payload_end(*pos, n, 4)?;
    let slice = bytes
        .get(*pos..end)
        .ok_or_else(|| anyhow!("wire: truncated i32 payload ({n} elems)"))?;
    *pos = end;
    Ok(slice
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

fn check_consumed(bytes: &[u8], pos: usize) -> Result<()> {
    if pos != bytes.len() {
        bail!(
            "wire: {} trailing bytes after payload",
            bytes.len() - pos
        );
    }
    Ok(())
}

impl Wire for Vec<f32> {
    fn encode(&self, out: &mut Vec<u8>) {
        out.push(TAG_F32S);
        put_f32s(out, self);
    }

    fn decode(bytes: &[u8]) -> Result<Self> {
        let mut pos = 0;
        take_tag(bytes, &mut pos, TAG_F32S)?;
        let v = take_f32s(bytes, &mut pos)?;
        check_consumed(bytes, pos)?;
        Ok(v)
    }
}

impl Wire for Vec<i32> {
    fn encode(&self, out: &mut Vec<u8>) {
        out.push(TAG_I32S);
        put_i32s(out, self);
    }

    fn decode(bytes: &[u8]) -> Result<Self> {
        let mut pos = 0;
        take_tag(bytes, &mut pos, TAG_I32S)?;
        let v = take_i32s(bytes, &mut pos)?;
        check_consumed(bytes, pos)?;
        Ok(v)
    }
}

/// The trainer's f32 batch shard: `(global example id, token rows)`.
impl Wire for (usize, Vec<f32>) {
    fn encode(&self, out: &mut Vec<u8>) {
        out.push(TAG_ID_F32S);
        put_u64(out, self.0 as u64);
        put_f32s(out, &self.1);
    }

    fn decode(bytes: &[u8]) -> Result<Self> {
        let mut pos = 0;
        take_tag(bytes, &mut pos, TAG_ID_F32S)?;
        let id = take_u64(bytes, &mut pos)? as usize;
        let v = take_f32s(bytes, &mut pos)?;
        check_consumed(bytes, pos)?;
        Ok((id, v))
    }
}

/// The trainer's i32 batch shard: `(global example id, text tokens)`.
impl Wire for (usize, Vec<i32>) {
    fn encode(&self, out: &mut Vec<u8>) {
        out.push(TAG_ID_I32S);
        put_u64(out, self.0 as u64);
        put_i32s(out, &self.1);
    }

    fn decode(bytes: &[u8]) -> Result<Self> {
        let mut pos = 0;
        take_tag(bytes, &mut pos, TAG_ID_I32S)?;
        let id = take_u64(bytes, &mut pos)? as usize;
        let v = take_i32s(bytes, &mut pos)?;
        check_consumed(bytes, pos)?;
        Ok((id, v))
    }
}

impl Wire for u64 {
    fn encode(&self, out: &mut Vec<u8>) {
        out.push(TAG_U64);
        put_u64(out, *self);
    }

    fn decode(bytes: &[u8]) -> Result<Self> {
        let mut pos = 0;
        take_tag(bytes, &mut pos, TAG_U64)?;
        let v = take_u64(bytes, &mut pos)?;
        check_consumed(bytes, pos)?;
        Ok(v)
    }
}

impl Wire for Vec<u8> {
    fn encode(&self, out: &mut Vec<u8>) {
        out.push(TAG_BYTES);
        put_u64(out, self.len() as u64);
        out.extend_from_slice(self);
    }

    fn decode(bytes: &[u8]) -> Result<Self> {
        let mut pos = 0;
        take_tag(bytes, &mut pos, TAG_BYTES)?;
        let n = take_u64(bytes, &mut pos)? as usize;
        let end = payload_end(pos, n, 1)?;
        let slice = bytes
            .get(pos..end)
            .ok_or_else(|| anyhow!("wire: truncated byte payload"))?;
        let v = slice.to_vec();
        check_consumed(bytes, end)?;
        Ok(v)
    }
}

// ---------------------------------------------------------------------------
// Transport
// ---------------------------------------------------------------------------

/// A rank-scoped handle into one SPMD collective group.
///
/// One `Transport` belongs to exactly one rank; the factory hands out
/// `d` of them, one per worker. All methods take `&self` so a handle
/// can sit behind `Box<dyn Transport>` inside a worker without
/// threading mutability through the training loop.
pub trait Transport: Send {
    /// This handle's rank in `0..world_size()`.
    fn rank(&self) -> usize;

    /// Number of ranks in the group (the paper's `d`).
    fn world_size(&self) -> usize;

    /// Point-to-point rearrangement round: submit `(dst, payload)`
    /// pairs, receive the `(src, payload)` pairs addressed to this
    /// rank, sorted by `src` with each source's payloads in send order.
    /// Self-sends loop back and cost no wire traffic.
    fn all_to_all_bytes(
        &self,
        sends: Vec<(usize, Vec<u8>)>,
    ) -> Result<Vec<(usize, Vec<u8>)>>;

    /// Every rank contributes one buffer; all ranks receive all `d`
    /// buffers in rank order.
    fn all_gather_bytes(&self, bytes: Vec<u8>) -> Result<Vec<Vec<u8>>>;

    /// Synchronization point with no data.
    fn barrier(&self) -> Result<()>;

    /// Sum-all-reduce of equally-shaped f32 buffers (gradient sync).
    ///
    /// Default: reduce-scatter + all-gather over the byte collectives.
    /// Each rank owns chunk `r` of the buffer, receives every rank's
    /// slice of its chunk (an All-to-All of `n/d`-sized pieces), sums
    /// them in **increasing source-rank order** (the fixed, bit-stable
    /// reduction order), then all-gathers the reduced chunks. Peak
    /// extra memory is O(n) per rank — independent of `d`, unlike the
    /// all-gather-of-full-buffers strawman's O(d·n).
    fn all_reduce_sum(&self, data: &mut [f32]) -> Result<()> {
        let d = self.world_size();
        let rank = self.rank();
        if d == 1 {
            return Ok(());
        }
        let n = data.len();
        let bounds = |k: usize| (k * n / d, (k + 1) * n / d);

        // Reduce-scatter: ship slice k of my buffer to chunk owner k.
        let sends: Vec<(usize, Vec<u8>)> = (0..d)
            .map(|k| {
                let (lo, hi) = bounds(k);
                (k, f32s_to_bytes(&data[lo..hi]))
            })
            .collect();
        let received = self
            .all_to_all_bytes(sends)
            .context("all_reduce_sum reduce-scatter")?;
        let (lo, hi) = bounds(rank);
        let mut acc = vec![0.0f32; hi - lo];
        if received.len() != d {
            bail!(
                "all_reduce_sum: expected {d} chunk contributions, got {}",
                received.len()
            );
        }
        for (idx, (src, bytes)) in received.into_iter().enumerate() {
            if src != idx {
                bail!(
                    "all_reduce_sum: contribution {idx} came from rank \
                     {src}; a peer skipped the round"
                );
            }
            let chunk = bytes_to_f32s(&bytes)?;
            if chunk.len() != acc.len() {
                bail!(
                    "all_reduce_sum: rank {src} sent chunk of {} elems, \
                     expected {}",
                    chunk.len(),
                    acc.len()
                );
            }
            // Fixed reduction order: contributions arrive sorted by
            // src, so every element sums rank 0, 1, …, d-1.
            for (a, x) in acc.iter_mut().zip(&chunk) {
                *a += x;
            }
        }

        // All-gather the reduced chunks back into the full buffer.
        let gathered = self
            .all_gather_bytes(f32s_to_bytes(&acc))
            .context("all_reduce_sum all-gather")?;
        if gathered.len() != d {
            bail!(
                "all_reduce_sum: expected {d} reduced chunks, got {}",
                gathered.len()
            );
        }
        for (k, bytes) in gathered.into_iter().enumerate() {
            let chunk = bytes_to_f32s(&bytes)?;
            let (lo, hi) = bounds(k);
            if chunk.len() != hi - lo {
                bail!(
                    "all_reduce_sum: reduced chunk {k} has {} elems, \
                     expected {}",
                    chunk.len(),
                    hi - lo
                );
            }
            data[lo..hi].copy_from_slice(&chunk);
        }
        Ok(())
    }
}

/// Typed collectives over any [`Transport`]: encode with [`Wire`],
/// move bytes, decode, preserving the ordering contract.
pub trait TransportExt: Transport {
    /// Typed [`Transport::all_to_all_bytes`].
    fn all_to_all<T: Wire>(
        &self,
        sends: Vec<(usize, T)>,
    ) -> Result<Vec<(usize, T)>> {
        let raw: Vec<(usize, Vec<u8>)> = sends
            .into_iter()
            .map(|(dst, item)| (dst, item.to_wire()))
            .collect();
        self.all_to_all_bytes(raw)?
            .into_iter()
            .map(|(src, bytes)| {
                T::decode(&bytes)
                    .with_context(|| format!("payload from rank {src}"))
                    .map(|item| (src, item))
            })
            .collect()
    }

    /// Typed [`Transport::all_gather_bytes`].
    fn all_gather<T: Wire>(&self, item: &T) -> Result<Vec<T>> {
        self.all_gather_bytes(item.to_wire())?
            .iter()
            .enumerate()
            .map(|(src, bytes)| {
                T::decode(bytes)
                    .with_context(|| format!("contribution from rank {src}"))
            })
            .collect()
    }
}

impl<X: Transport + ?Sized> TransportExt for X {}

// ---------------------------------------------------------------------------
// Factory + registry
// ---------------------------------------------------------------------------

/// Builds a fully-connected world of `d` rank-scoped [`Transport`]
/// handles. Mirrors the balancer registry: resolved by name, described
/// by metadata the CLI lists.
pub trait TransportFactory: Send + Sync + fmt::Debug {
    /// Registry name (also the `--transport` CLI spelling).
    fn name(&self) -> &'static str;

    /// One-line description for the `transports` CLI listing.
    fn description(&self) -> &'static str;

    /// Construct the `d` handles, rank `i` at index `i`. The handles
    /// are live as soon as this returns; dropping all of them tears the
    /// group down.
    fn connect(&self, d: usize) -> Result<Vec<Box<dyn Transport>>>;
}

/// Connect a world of `d` ranks and run `f` on every handle, one
/// thread per rank, returning the per-rank results in rank order. The
/// one SPMD world harness shared by calibration, the conformance
/// suite, the comm bench, and the backend unit tests — a rank that
/// panics becomes an error, not a poisoned join.
///
/// Scoped threads, so `f` may borrow from the caller (no `'static`
/// bound).
pub fn run_world<R, F>(
    factory: &dyn TransportFactory,
    d: usize,
    f: F,
) -> Result<Vec<R>>
where
    F: Fn(Box<dyn Transport>) -> R + Send + Sync,
    R: Send,
{
    let handles = factory
        .connect(d)
        .with_context(|| format!("connecting '{}' world", factory.name()))?;
    std::thread::scope(|scope| {
        let f = &f;
        let joins: Vec<_> = handles
            .into_iter()
            .map(|t| scope.spawn(move || f(t)))
            .collect();
        joins
            .into_iter()
            .enumerate()
            .map(|(rank, join)| {
                join.join()
                    .map_err(|_| anyhow!("rank {rank} thread panicked"))
            })
            .collect()
    })
}

/// Name → implementation resolution for the `--transport` CLI flag,
/// the conformance suite, and the comm benches.
pub mod registry {
    use super::inproc::InProcFactory;
    use super::tcp::TcpLoopbackFactory;
    use super::*;

    /// Every registered transport name, in presentation order.
    pub const NAMES: &[&str] = &["inproc", "tcp"];

    /// Resolve a registered transport backend by name (aliases
    /// accepted).
    pub fn create(name: &str) -> Option<Arc<dyn TransportFactory>> {
        Some(match name {
            "inproc" | "in-proc" | "threads" => {
                Arc::new(InProcFactory::default())
            }
            "tcp" | "tcp-loopback" | "loopback" => {
                Arc::new(TcpLoopbackFactory::from_env())
            }
            _ => return None,
        })
    }

    /// Resolve or panic with the list of valid names — for internal
    /// callers whose names are compile-time constants.
    pub fn must(name: &str) -> Arc<dyn TransportFactory> {
        create(name).unwrap_or_else(|| {
            panic!("unknown transport '{name}' (registered: {NAMES:?})")
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_roundtrips_every_payload_kind() {
        let f: Vec<f32> = vec![1.5, -2.25, 0.0];
        assert_eq!(Vec::<f32>::decode(&f.to_wire()).unwrap(), f);

        let i: Vec<i32> = vec![-7, 0, 123456];
        assert_eq!(Vec::<i32>::decode(&i.to_wire()).unwrap(), i);

        let shard: (usize, Vec<f32>) = (42, vec![3.25; 8]);
        assert_eq!(
            <(usize, Vec<f32>)>::decode(&shard.to_wire()).unwrap(),
            shard
        );

        let text: (usize, Vec<i32>) = (7, vec![1, 2, 3]);
        assert_eq!(
            <(usize, Vec<i32>)>::decode(&text.to_wire()).unwrap(),
            text
        );

        assert_eq!(u64::decode(&99u64.to_wire()).unwrap(), 99);

        let raw: Vec<u8> = vec![0xde, 0xad];
        assert_eq!(Vec::<u8>::decode(&raw.to_wire()).unwrap(), raw);
    }

    #[test]
    fn wire_rejects_mismatched_manifest() {
        let f: Vec<f32> = vec![1.0];
        // Decoding f32 bytes as i32 must fail on the dtype tag.
        assert!(Vec::<i32>::decode(&f.to_wire()).is_err());
        // Truncation must fail, not read garbage.
        let enc = f.to_wire();
        assert!(Vec::<f32>::decode(&enc[..enc.len() - 1]).is_err());
        // Trailing bytes must fail.
        let mut padded = enc.clone();
        padded.push(0);
        assert!(Vec::<f32>::decode(&padded).is_err());
        // Empty buffer.
        assert!(Vec::<f32>::decode(&[]).is_err());
        // A tampered manifest whose element count would overflow the
        // end-offset arithmetic must error, not wrap and alias.
        let mut evil = vec![TAG_F32S];
        evil.extend_from_slice(&(1u64 << 62).to_le_bytes());
        evil.extend_from_slice(&[0u8; 8]);
        assert!(Vec::<f32>::decode(&evil).is_err());
        let mut evil_bytes = vec![TAG_BYTES];
        evil_bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        assert!(Vec::<u8>::decode(&evil_bytes).is_err());
    }

    #[test]
    fn f32_byte_helpers_roundtrip() {
        let data = vec![1.0f32, f32::MIN_POSITIVE, -0.0, 7e30];
        let bytes = f32s_to_bytes(&data);
        assert_eq!(bytes_to_f32s(&bytes).unwrap(), data);
        assert!(bytes_to_f32s(&bytes[..5]).is_err());
    }

    #[test]
    fn registry_resolves_every_listed_name() {
        for name in registry::NAMES {
            let f = registry::create(name)
                .unwrap_or_else(|| panic!("{name} missing from create()"));
            assert_eq!(f.name(), *name, "name() disagrees with registry key");
            assert!(!f.description().is_empty());
        }
        assert!(registry::create("nccl").is_none());
    }

    #[test]
    fn aliases_resolve_to_the_same_backend() {
        assert_eq!(registry::must("in-proc").name(), "inproc");
        assert_eq!(registry::must("loopback").name(), "tcp");
        assert_eq!(registry::must("tcp-loopback").name(), "tcp");
    }
}
