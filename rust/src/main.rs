//! `orchmllm` — the leader CLI.
//!
//! Subcommands:
//!   sim          price one system/model/cluster configuration
//!   overall      regenerate the Fig. 8/9 overall comparison
//!   overhead     regenerate the Table-2 overhead scaling
//!   incoherence  regenerate the Fig. 3 dataset analysis
//!   train        run the real tiny-MLLM DP trainer over PJRT artifacts
//!   elastic      run the elastic synthetic trainer (fault injection,
//!                shrink-the-world recovery; `tcp-multiproc` spawns
//!                real OS processes)
//!   worker       one elastic member process (spawned by `elastic`)
//!   archive      inspect / verify / garbage-collect a plan archive
//!   balancers    list the registered post-balancing algorithms
//!   transports   list the registered comm backends (+ calibrate α/β)
//!
//! Options accept `--key value` or `--key=value`; run with no arguments
//! for usage.

use std::path::{Path, PathBuf};

use orchmllm::balance::{registry, select};
use orchmllm::comm::calibrate::{calibrate, CalibrationSpec};
use orchmllm::comm::transport::registry as transport_registry;
use orchmllm::config::{SimRunConfig, TrainRunConfig};
use orchmllm::data::incoherence::IncoherenceReport;
use orchmllm::data::synth::{DatasetConfig, Generator};
use orchmllm::model::config::MllmConfig;
use orchmllm::model::flops::PhaseKind;
use orchmllm::orchestrator::archive;
use orchmllm::sim::engine::{
    simulate_run, simulate_run_opts, SimOptions, SystemKind,
};
use orchmllm::sim::GpuSpec;
use orchmllm::sim::report;
use orchmllm::trainer;
use orchmllm::trainer::elastic::{self, FaultPlan};
use orchmllm::util::cli::Args;
use orchmllm::util::json::Json;

const USAGE: &str = "\
orchmllm — OrchMLLM reproduction CLI

USAGE:
  orchmllm sim         [--system orchmllm] [--model mllm-10b] [--gpus 128]
                       [--mini-batch 60] [--steps 5] [--seed 42]
                       [--balancer auto|greedy|padded|quadratic|convpad|
                                   kk|ilp|none]
                       [--gpu h100|a100]    # accelerator to price against
                       [--pp-stages N]      # model the LLM as an N-stage
                                            # 1F1B pipeline and co-schedule
                                            # encoder work into its bubbles
                       [--microbatches M]   # microbatches in flight
                                            # (default 8; requires
                                            # --pp-stages, M >= N)
                       [--config file.json]
                       [--archive DIR]      # warm-start from a plan archive
                       [--archive-out DIR]  # export the session afterwards
                       [--archive-baseline ci/archive_baseline.json]
                                            # gate warm-start hit rate
  orchmllm overall     [--gpus 2560] [--steps 3]       # Fig. 8 + 9
  orchmllm overhead    [--steps 3]                     # Table 2
  orchmllm incoherence [--n 100000] [--seed 7]         # Fig. 3
  orchmllm train       [--artifacts artifacts/test] [--workers 4]
                       [--mini-batch 4] [--steps 20] [--lr 0.05]
                       [--balancer <name|auto>] [--no-balance]
                       [--pipeline-depth 2] [--plan-cache-size 32]
                       [--transport inproc|tcp] [--calibrate-comm]
                       [--min-world 1]
  orchmllm elastic     [--workers 4] [--mini-batch 4] [--steps 8]
                       [--lr 0.05] [--seed 0] [--min-world 1]
                       [--transport inproc|tcp-multiproc] [--out f.json]
                       [--archive-in DIR] [--archive-out DIR]
                       [--fault-rank R --fault-step N
                        [--fault-collective 0|1|2] [--fault-resign]]
                       [--in-process]   # threads instead of processes
  orchmllm worker      --rank R --rdzv-dir DIR …     # spawned by elastic
  orchmllm archive     inspect DIR                   # manifest summary
  orchmllm archive     verify  DIR                   # full decode; exit 2 on
                                                     # corruption/version skew
  orchmllm archive     gc      DIR [--keep-last 64]
                       [--max-age-secs N]            # prune the plan log
  orchmllm balancers                                 # registry + auto rules
  orchmllm transports  [--calibrate] [--workers 4]   # comm backends
  orchmllm help
";

fn main() {
    let args = Args::from_env();
    match args.subcommand() {
        Some("sim") => cmd_sim(&args),
        Some("overall") => cmd_overall(&args),
        Some("overhead") => cmd_overhead(&args),
        Some("incoherence") => cmd_incoherence(&args),
        Some("train") => cmd_train(&args),
        Some("elastic") => cmd_elastic(&args),
        Some("worker") => {
            std::process::exit(elastic::worker_main(&args))
        }
        Some("archive") => cmd_archive(&args),
        Some("balancers") => cmd_balancers(),
        Some("transports") => cmd_transports(&args),
        _ => print!("{USAGE}"),
    }
}

fn cmd_sim(args: &Args) {
    let cfg = if let Some(path) = args.get("config") {
        SimRunConfig::load(path).expect("config file")
    } else {
        SimRunConfig {
            system: SystemKind::parse(args.get_or("system", "orchmllm"))
                .expect("unknown --system"),
            model: args.get_or("model", "mllm-10b").to_string(),
            gpus: args.usize("gpus", 128),
            mini_batch: args.usize("mini-batch", 60),
            steps: args.usize("steps", 5),
            seed: args.u64("seed", 42),
            balancer: args.get("balancer").map(str::to_string),
            gpu: args.get_or("gpu", "h100").to_string(),
            pp_stages: args
                .get("pp-stages")
                .map(|_| args.usize("pp-stages", 0)),
            microbatches: args
                .get("microbatches")
                .map(|_| args.usize("microbatches", 0)),
        }
    };
    if let Some(name) = &cfg.balancer {
        if !select::is_valid_spec(name) {
            eprintln!(
                "unknown --balancer '{name}'; registered: {:?} (plus \
                 'auto')",
                registry::NAMES
            );
            std::process::exit(2);
        }
    }
    // GPU name and pipeline shape (--pp-stages/--microbatches bounds).
    if let Err(e) = cfg.validate() {
        eprintln!("invalid sim configuration: {e:#}");
        std::process::exit(2);
    }
    let model = MllmConfig::by_name(&cfg.model).expect("unknown model");
    let gpu = GpuSpec::by_name(&cfg.gpu).expect("validated above");
    let opts = SimOptions {
        balancer: cfg.balancer.clone(),
        archive_in: args.get("archive").map(PathBuf::from),
        archive_out: args.get("archive-out").map(PathBuf::from),
        gpu,
        pipeline: cfg.pipeline(&model, &gpu),
    };
    let r = match simulate_run_opts(
        cfg.system,
        &model,
        cfg.gpus,
        cfg.mini_batch,
        cfg.steps,
        cfg.seed,
        &opts,
    ) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("sim plan-archive failure: {e}");
            std::process::exit(1);
        }
    };
    println!(
        "{} | {} | {} GPUs | mb {}\n  MFU  {:.1}%\n  TPT  {:.0} tok/s/GPU\n  \
         step {:.3}s (comm {:.1}ms)\n  mem  {:.1} GB{}\n  dispatcher {:.2}ms\n  \
         plan {:.2}ms/step (p99 {:.2}ms; {:.0}% warm solves, {:.0}% cache \
         hits)",
        r.system.name(),
        r.model_name,
        r.gpus,
        r.mini_batch,
        r.mfu * 100.0,
        r.tpt,
        r.step_secs,
        r.comm_secs * 1e3,
        r.peak_mem_gb,
        if r.oom { " (OOM!)" } else { "" },
        r.dispatcher_overhead_ms,
        r.plan_ms,
        r.plan_stats.p99_ms,
        r.plan_stats.warm_rate * 100.0,
        r.plan_stats.cache_hit_rate * 100.0,
    );
    if let Some(c) = &r.cosched {
        print!("{}", report::render_cosched(c));
    }
    if let Some(a) = &r.archive {
        println!(
            "  archive: {} | warm-start hit rate {:.1}% | first step \
             {} | plan id {}{}",
            match (&a.cold_reason, a.loaded) {
                (Some(reason), _) => format!("cold start ({reason})"),
                (None, true) => "warm start".to_string(),
                (None, false) => "recording".to_string(),
            },
            a.warm_start_hit_rate * 100.0,
            if a.first_step_cache_hit { "replayed" } else { "solved" },
            a.first_plan_id.as_deref().map(|id| &id[..16]).unwrap_or("-"),
            if a.exported { " | exported" } else { "" },
        );
    }
    if let Some(path) = args.get("archive-baseline") {
        let Some(a) = &r.archive else {
            eprintln!(
                "--archive-baseline requires --archive (nothing to gate)"
            );
            std::process::exit(2);
        };
        let floor = read_baseline_floor(path);
        if a.warm_start_hit_rate < floor {
            eprintln!(
                "warm-start hit rate {:.3} below the {path} floor \
                 {floor:.3} — the archive regressed (see the baseline \
                 file for the re-baselining procedure)",
                a.warm_start_hit_rate
            );
            std::process::exit(1);
        }
        println!(
            "  baseline: warm-start hit rate {:.3} >= floor {floor:.3} \
             ({path})",
            a.warm_start_hit_rate
        );
    }
}

/// The `min_warm_start_hit_rate` floor from `ci/archive_baseline.json`.
fn read_baseline_floor(path: &str) -> f64 {
    let text = std::fs::read_to_string(path).unwrap_or_else(|e| {
        eprintln!("reading {path}: {e}");
        std::process::exit(2);
    });
    let j = Json::parse(&text).unwrap_or_else(|e| {
        eprintln!("parsing {path}: {e}");
        std::process::exit(2);
    });
    j.get("min_warm_start_hit_rate").as_f64().unwrap_or_else(|| {
        eprintln!("{path}: missing 'min_warm_start_hit_rate'");
        std::process::exit(2);
    })
}

fn cmd_overall(args: &Args) {
    let gpus = args.usize("gpus", 2560);
    let steps = args.usize("steps", 3);
    let seed = args.u64("seed", 42);
    // Paper §8.1 mini-batch sizes: 80/60/30 balanced, 65/40/15 w/o.
    let mb_orch = [80, 60, 30];
    let mb_none = [65, 40, 15];
    let mut rows = Vec::new();
    for system in
        [SystemKind::OrchMllm, SystemKind::Megatron, SystemKind::NoBalance]
    {
        let mut row = Vec::new();
        for (mi, model) in MllmConfig::all().iter().enumerate() {
            let mb = match system {
                SystemKind::NoBalance => mb_none[mi],
                _ => mb_orch[mi],
            };
            row.push(simulate_run(system, model, gpus, mb, steps, seed));
        }
        rows.push(row);
    }
    println!("Fig. 8/9 — overall MFU and TPT ({gpus} GPUs):\n");
    print!("{}", report::render_overall(&rows));
}

fn cmd_overhead(args: &Args) {
    let steps = args.usize("steps", 3);
    let seed = args.u64("seed", 42);
    let model = MllmConfig::mllm_10b();
    let cells: Vec<_> = [64usize, 128, 256, 512, 1024, 2560]
        .iter()
        .map(|&g| {
            simulate_run(SystemKind::OrchMllm, &model, g, 60, steps, seed)
        })
        .collect();
    println!(
        "Table 2 — dispatcher overhead vs cluster size (MLLM-10B, mb 60):\n"
    );
    print!("{}", report::render_overhead(&cells));
}

fn cmd_incoherence(args: &Args) {
    let n = args.usize("n", 100_000);
    let seed = args.u64("seed", 7);
    let ex = Generator::new(DatasetConfig::default(), seed).batch(n);
    let rep = IncoherenceReport::from_examples(&ex, 20);
    println!("{}", rep.render());
}

fn cmd_train(args: &Args) {
    let defaults = TrainRunConfig::default();
    let cfg = TrainRunConfig {
        artifacts: args.get_or("artifacts", "artifacts/test").to_string(),
        workers: args.usize("workers", 4),
        mini_batch: args.usize("mini-batch", 4),
        steps: args.usize("steps", 20),
        lr: args.f64("lr", 0.05),
        seed: args.u64("seed", 0),
        balance: !args.flag("no-balance"),
        balancer: args.get("balancer").map(str::to_string),
        pipeline_depth: args
            .usize("pipeline-depth", defaults.pipeline_depth),
        plan_cache_size: args
            .usize("plan-cache-size", defaults.plan_cache_size),
        transport: args
            .get_or("transport", &defaults.transport)
            .to_string(),
        calibrate_comm: args.flag("calibrate-comm"),
        min_world: args.usize("min-world", defaults.min_world),
        // Archive endpoints are an elastic-runtime feature: the fixed
        // pipeline trainer moves its session onto a background thread
        // and cannot export it at exit.
        archive_in: None,
        archive_out: None,
    };
    if let Err(e) = cfg.validate() {
        eprintln!("invalid train configuration: {e:#}");
        std::process::exit(2);
    }
    match trainer::run(&cfg) {
        Ok(summary) => println!("{summary}"),
        Err(e) => {
            eprintln!("train failed: {e:#}");
            std::process::exit(1);
        }
    }
}

fn cmd_elastic(args: &Args) {
    let cfg = TrainRunConfig {
        workers: args.usize("workers", 4),
        mini_batch: args.usize("mini-batch", 4),
        steps: args.usize("steps", 8),
        lr: args.f64("lr", 0.05),
        seed: args.u64("seed", 0),
        min_world: args.usize("min-world", 1),
        transport: args.get_or("transport", "tcp-multiproc").to_string(),
        archive_in: args.get("archive-in").map(str::to_string),
        archive_out: args.get("archive-out").map(str::to_string),
        ..TrainRunConfig::default()
    };
    if let Err(e) = cfg.validate() {
        eprintln!("invalid elastic configuration: {e:#}");
        std::process::exit(2);
    }
    let fault = match FaultPlan::from_args(args) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("invalid fault plan: {e:#}");
            std::process::exit(2);
        }
    };
    // `tcp-multiproc` runs every member as a real OS process re-spawning
    // this binary's `worker` subcommand; `--in-process` (and every other
    // transport) keeps members as threads of this process.
    let multiproc =
        cfg.transport == "tcp-multiproc" && !args.flag("in-process");
    let report = if multiproc {
        std::env::current_exe()
            .map_err(anyhow::Error::from)
            .and_then(|bin| elastic::run_multiproc(&cfg, fault, &bin))
    } else {
        elastic::run_elastic_collect(&cfg, fault)
    };
    let report = match report {
        Ok(r) => r,
        Err(e) => {
            eprintln!("elastic run failed: {e:#}");
            std::process::exit(1);
        }
    };
    // CI gate: an injected fault that produced no recorded transition
    // means recovery never actually exercised — fail loudly.
    if fault.rank.is_some() && report.transitions.is_empty() {
        eprintln!(
            "elastic run injected a fault but recorded no world \
             transition — recovery did not engage"
        );
        std::process::exit(1);
    }
    if let Some(path) = args.get("out") {
        if let Err(e) = std::fs::write(
            path,
            elastic::report_to_json(&report).pretty(),
        ) {
            eprintln!("writing {path}: {e}");
            std::process::exit(1);
        }
    }
    println!("{}", report.render());
}

fn cmd_archive(args: &Args) {
    let verb = args.positional.get(1).map(String::as_str);
    let Some(dir) = args.positional.get(2) else {
        eprintln!(
            "usage: orchmllm archive {{inspect|verify|gc}} DIR \
             [--keep-last N] [--max-age-secs N]"
        );
        std::process::exit(2);
    };
    let dir = Path::new(dir);
    match verb {
        Some("inspect") => match archive::inspect(dir) {
            Ok(text) => print!("{text}"),
            Err(e) => {
                eprintln!("archive inspect failed: {e}");
                std::process::exit(2);
            }
        },
        Some("verify") => match archive::verify(dir) {
            Ok(rep) => println!(
                "archive OK: {} payloads verified, {} archived step \
                 plans, plan chain length {} over {} blobs",
                rep.payloads, rep.cached_plans, rep.chain_len, rep.blobs
            ),
            Err(e) => {
                // Exit 2 is the CI contract: corruption, truncation,
                // and schema skew are all typed errors, never panics.
                eprintln!("archive verify failed: {e}");
                std::process::exit(2);
            }
        },
        Some("gc") => {
            let keep = Some(args.usize("keep-last", 64));
            // Age pruning only when asked for; the default is count-only.
            let max_age = args
                .get("max-age-secs")
                .is_some()
                .then(|| args.u64("max-age-secs", u64::MAX));
            match archive::gc(dir, keep, max_age) {
                Ok(g) => println!(
                    "archive gc: kept {} of {} entries ({} pruned), \
                     blobs {} -> {}",
                    g.kept,
                    g.kept + g.pruned,
                    g.pruned,
                    g.blobs_before,
                    g.blobs_after
                ),
                Err(e) => {
                    eprintln!("archive gc failed: {e}");
                    std::process::exit(2);
                }
            }
        }
        _ => {
            eprintln!(
                "unknown archive verb {:?}; expected inspect, verify, \
                 or gc",
                verb.unwrap_or("<none>")
            );
            std::process::exit(2);
        }
    }
}

fn cmd_balancers() {
    println!("registered post-balancing algorithms:\n");
    println!(
        "{:<22}{:<12}{:<16}{}",
        "name", "batching", "cost regime", "notes"
    );
    for name in registry::NAMES {
        let b = registry::must(name);
        let notes = if b.is_identity() {
            "identity"
        } else if b.name() == "ilp" {
            "exact oracle (node-budgeted)"
        } else {
            ""
        };
        println!(
            "{:<22}{:<12}{:<16}{}",
            b.name(),
            format!("{:?}", b.batching_mode()).to_lowercase(),
            format!("{:?}", b.cost_regime()).to_lowercase(),
            notes
        );
    }

    // The `--balancer auto` resolution, per model, with the rule that
    // produced each pick — the selection is metadata-driven, so this
    // listing is the place to inspect it.
    println!("\nauto-selection (`--balancer auto`), by model:\n");
    println!(
        "{:<12}{:<10}{:<12}{}",
        "model", "phase", "balancer", "rule"
    );
    for model in MllmConfig::all() {
        for phase in PhaseKind::ALL {
            let sel =
                select::select_for_phase(&model.phase_traits(phase));
            println!(
                "{:<12}{:<10}{:<12}{}",
                model.name,
                phase.name(),
                sel.balancer.name(),
                sel.rule
            );
        }
    }
    println!(
        "\nselect with `--balancer <name|auto>` on `sim` and `train`.\n\
         `sim` also takes `--gpu h100|a100` and `--pp-stages N \
         [--microbatches M]` to co-schedule the balanced encoder phases \
         into the LLM pipeline's 1F1B bubbles."
    );
}

fn cmd_transports(args: &Args) {
    println!("registered comm transports:\n");
    println!("{:<12}{}", "name", "description");
    for name in transport_registry::NAMES {
        let f = transport_registry::must(name);
        println!("{:<12}{}", f.name(), f.description());
    }
    println!("\nselect with `--transport <name>` on `train`.");
    if !args.flag("calibrate") {
        return;
    }
    let d = args.usize("workers", 4);
    println!("\ncalibrating α/β at d = {d} (quick sweep):");
    for name in transport_registry::NAMES {
        let f = transport_registry::must(name);
        match calibrate(f.as_ref(), d, &CalibrationSpec::quick()) {
            Ok(cal) => print!(
                "{}",
                report::render_calibration(&cal, &trainer::worker_topology(d))
            ),
            Err(e) => {
                eprintln!("calibration of '{name}' failed: {e:#}");
                std::process::exit(1);
            }
        }
    }
}
