//! Metadata-driven balancer auto-selection (`--balancer auto`).
//!
//! §5.1 tailors the post-balancing algorithm to each phase's cost
//! regime; until now that tailoring was hard-coded in
//! `OrchestratorConfig::orchmllm`. This module derives it instead: a
//! phase is summarized as [`PhaseTraits`] (conv front-end? padded
//! batching? how large is the attention share β·L/α at the phase's
//! straggler length?), the traits map to a wanted `(batching_mode,
//! cost_regime)` pair, and the pair resolves against the **registry's
//! own metadata** — so a newly registered algorithm with the right
//! metadata is picked up without touching the selection code.
//!
//! Selection rules, in priority order (documented in DESIGN.md §Exact
//! Balancer & Auto-Selection):
//!
//! 1. conv front-end → `(Padded, ConvAttention)` — conv encoders cannot
//!    pack, and their padded attention term dominates (App. A);
//! 2. padded batching (without conv) → `(Padded, Linear)`;
//! 3. `β·L/α ≥` [`QUADRATIC_ATTENTION_RATIO`] → `(Unpadded,
//!    Quadratic)` — the attention quadratic is no longer negligible at
//!    the phase's longest sequences, so the balancer must trade the
//!    linear and quadratic terms;
//! 4. otherwise → `(Unpadded, Linear)`.
//!
//! Resolution scans [`registry::NAMES`] in presentation order and takes
//! the first non-identity, non-oracle balancer whose metadata matches;
//! if nothing matches (a stripped-down registry), it falls back to
//! `(Unpadded, Linear)` and finally to the identity balancer — `auto`
//! never fails, it only degrades.

use std::sync::Arc;

use super::balancer::{registry, Balancer, CostRegime};
use super::types::BatchingMode;

/// Spelling of the auto-selection pseudo-balancer on `--balancer`.
pub const AUTO: &str = "auto";

/// Attention-to-linear FLOP ratio `β·L/α` (at the phase's maximum
/// sequence length `L`) above which the quadratic-aware balancer is
/// selected. 0.15 ≈ "the stragglers the balancer exists to fix spend
/// ≥ 15% of their time in attention".
pub const QUADRATIC_ATTENTION_RATIO: f64 = 0.15;

/// The per-phase facts auto-selection decides on, derived from the
/// model configuration (`MllmConfig::phase_traits`) or stated directly
/// by a caller that knows its architecture (the trainer).
#[derive(Clone, Copy, Debug)]
pub struct PhaseTraits {
    /// The encoder has a convolutional front-end (Whisper-style
    /// ConvTransformer): attention must pad, cost is `λ·b·max(l)²`.
    pub conv_frontend: bool,
    /// The phase batches with padding (Eq. 1 `L = b·max(l)`).
    pub padded: bool,
    /// `β·L/α`: attention FLOPs over token-linear FLOPs for one
    /// sequence at the phase's maximum length.
    pub beta_len_over_alpha: f64,
}

impl PhaseTraits {
    /// An unpadded phase whose attention share is negligible — the
    /// trainer's tiny encoders and LLM trunk.
    pub fn unpadded_linear() -> PhaseTraits {
        PhaseTraits {
            conv_frontend: false,
            padded: false,
            beta_len_over_alpha: 0.0,
        }
    }

    /// A conv-front-end encoder phase (padding forced).
    pub fn conv_encoder() -> PhaseTraits {
        PhaseTraits {
            conv_frontend: true,
            padded: true,
            beta_len_over_alpha: 0.0,
        }
    }
}

/// One resolved selection: the balancer plus the rule that produced it
/// (surfaced by `orchmllm balancers` so decisions are inspectable).
#[derive(Clone)]
pub struct Selection {
    pub balancer: Arc<dyn Balancer>,
    pub rule: String,
}

impl std::fmt::Debug for Selection {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Selection")
            .field("balancer", &self.balancer.name())
            .field("rule", &self.rule)
            .finish()
    }
}

/// The first registered balancer (scanning `names` in order) whose
/// metadata matches the wanted batching mode + cost regime. Identity
/// balancers and the exact oracle never auto-select: `none` would
/// disable balancing and `ilp` is an oracle, not a per-step solver.
pub fn select_by_metadata(
    names: &[&str],
    mode: BatchingMode,
    regime: CostRegime,
) -> Option<Arc<dyn Balancer>> {
    for name in names {
        let Some(b) = registry::create(name) else { continue };
        if b.is_identity() || b.name() == "ilp" {
            continue;
        }
        if b.batching_mode() == mode && b.cost_regime() == regime {
            return Some(b);
        }
    }
    None
}

/// Resolve a phase's balancer from its traits over the full registry.
pub fn select_for_phase(traits: &PhaseTraits) -> Selection {
    select_for_phase_from(registry::NAMES, traits)
}

/// [`select_for_phase`] over an explicit name list — the testable core,
/// and the definition of "falls back safely": a registry missing the
/// wanted metadata degrades to `(Unpadded, Linear)`, and a registry
/// with no usable balancer at all degrades to the identity.
pub fn select_for_phase_from(
    names: &[&str],
    traits: &PhaseTraits,
) -> Selection {
    let (mode, regime, rule) = if traits.conv_frontend {
        (
            BatchingMode::Padded,
            CostRegime::ConvAttention,
            "conv front-end → conv-attention regime".to_string(),
        )
    } else if traits.padded {
        (
            BatchingMode::Padded,
            CostRegime::Linear,
            "padded batching → padded linear regime".to_string(),
        )
    } else if traits.beta_len_over_alpha >= QUADRATIC_ATTENTION_RATIO {
        (
            BatchingMode::Unpadded,
            CostRegime::Quadratic,
            format!(
                "β·L/α = {:.2} ≥ {QUADRATIC_ATTENTION_RATIO} → \
                 quadratic regime",
                traits.beta_len_over_alpha
            ),
        )
    } else {
        (
            BatchingMode::Unpadded,
            CostRegime::Linear,
            format!(
                "β·L/α = {:.2} < {QUADRATIC_ATTENTION_RATIO} → \
                 linear unpadded regime",
                traits.beta_len_over_alpha
            ),
        )
    };
    if let Some(b) = select_by_metadata(names, mode, regime) {
        return Selection { balancer: b, rule };
    }
    // Requested metadata unavailable: degrade to linear unpadded.
    if let Some(b) =
        select_by_metadata(names, BatchingMode::Unpadded, CostRegime::Linear)
    {
        return Selection {
            balancer: b,
            rule: format!("{rule} (unavailable; linear fallback)"),
        };
    }
    Selection {
        balancer: Arc::new(super::balancer::NoBalance),
        rule: format!("{rule} (no registered balancer; identity fallback)"),
    }
}

/// The trainer's per-phase traits (vision, audio, llm): its tiny model
/// mirrors the paper's architecture — a conv front-end on the audio
/// encoder forces padding there, while the tiny hidden sizes keep the
/// attention share of the other phases negligible.
pub fn trainer_phase_traits() -> [PhaseTraits; 3] {
    [
        PhaseTraits::unpadded_linear(),
        PhaseTraits::conv_encoder(),
        PhaseTraits::unpadded_linear(),
    ]
}

/// Whether `name` is a valid `--balancer` spelling: a registered
/// algorithm (or alias) or the `auto` pseudo-balancer.
pub fn is_valid_spec(name: &str) -> bool {
    name == AUTO || registry::create(name).is_some()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rules_map_traits_to_the_documented_algorithms() {
        let conv = select_for_phase(&PhaseTraits::conv_encoder());
        assert_eq!(conv.balancer.name(), "convpad");
        assert!(conv.rule.contains("conv front-end"), "{}", conv.rule);

        let padded = select_for_phase(&PhaseTraits {
            conv_frontend: false,
            padded: true,
            beta_len_over_alpha: 0.0,
        });
        assert_eq!(padded.balancer.name(), "padded");

        let quad = select_for_phase(&PhaseTraits {
            conv_frontend: false,
            padded: false,
            beta_len_over_alpha: 0.3,
        });
        assert_eq!(quad.balancer.name(), "quadratic");

        let lin = select_for_phase(&PhaseTraits::unpadded_linear());
        assert_eq!(lin.balancer.name(), "greedy");
    }

    #[test]
    fn conv_outranks_the_quadratic_rule() {
        let s = select_for_phase(&PhaseTraits {
            conv_frontend: true,
            padded: true,
            beta_len_over_alpha: 10.0,
        });
        assert_eq!(s.balancer.name(), "convpad");
    }

    #[test]
    fn missing_metadata_falls_back_safely() {
        // A registry without convpad degrades conv phases to linear.
        let s = select_for_phase_from(
            &["none", "greedy", "kk"],
            &PhaseTraits::conv_encoder(),
        );
        assert_eq!(s.balancer.name(), "greedy");
        assert!(s.rule.contains("fallback"), "{}", s.rule);

        // A registry with nothing usable degrades to the identity.
        let s = select_for_phase_from(
            &["none", "bogus"],
            &PhaseTraits::unpadded_linear(),
        );
        assert!(s.balancer.is_identity());
        assert!(s.rule.contains("identity fallback"), "{}", s.rule);
    }

    #[test]
    fn oracle_and_identity_never_auto_select() {
        // ilp matches (Unpadded, Linear) metadata but is excluded, and
        // scanning it first must not shadow greedy.
        let s = select_for_phase_from(
            &["none", "ilp", "greedy"],
            &PhaseTraits::unpadded_linear(),
        );
        assert_eq!(s.balancer.name(), "greedy");
    }

    #[test]
    fn selection_is_deterministic() {
        let t = PhaseTraits {
            conv_frontend: false,
            padded: false,
            beta_len_over_alpha: 0.2,
        };
        let a = select_for_phase(&t);
        let b = select_for_phase(&t);
        assert_eq!(a.balancer.name(), b.balancer.name());
        assert_eq!(a.rule, b.rule);
    }

    #[test]
    fn spec_validation_accepts_auto_and_registry_names() {
        assert!(is_valid_spec("auto"));
        assert!(is_valid_spec("greedy"));
        assert!(is_valid_spec("ilp"));
        assert!(is_valid_spec("lpt")); // alias
        assert!(!is_valid_spec("bogus"));
    }
}
